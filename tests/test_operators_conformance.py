"""Operator conformance: every operator the quadrature core accepts must
honor the same contract (core/operators.py module docstring):

  * ``matvec`` agrees with a dense reference computed independently in
    numpy (never via another operator),
  * ``diag()`` agrees with the reference diagonal,
  * ``n`` is consistent with the reference dimension,
  * the operator survives pytree flatten/unflatten, ``jax.jit`` and
    ``jax.vmap`` round-trips unchanged,
  * ``stack_ops``/``stack_masks`` lane-stacking commutes with per-lane
    matvec.

Parametrized over seeded grids (no hypothesis in the hermetic
container; deterministic seeds play the same role). N=33 is
deliberately not a multiple of the BELL block size so the zero-pad /
slice boundary path is exercised.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Dense, Jacobi, Masked, Shifted, SparseBELL, \
    SparseCOO, bell_from_dense, sparse_from_dense, stack_masks, stack_ops
from conftest import make_spd

OP_KINDS = ["dense", "sparse_coo", "sparse_bell", "masked", "shifted",
            "jacobi"]


def _reference(kind, a, rng):
    """(operator, dense reference matrix) — the reference is built in
    numpy only, independent of the operator's own code paths."""
    n = a.shape[0]
    if kind == "dense":
        return Dense(jnp.asarray(a)), a
    if kind == "sparse_coo":
        return sparse_from_dense(a), a
    if kind == "sparse_bell":
        return bell_from_dense(a, bs=8), a
    if kind == "masked":
        m = (rng.random(n) < 0.6).astype(np.float64)
        ref = np.diag(m) @ a @ np.diag(m) + np.eye(n) - np.diag(m)
        return Masked(Dense(jnp.asarray(a)), jnp.asarray(m)), ref
    if kind == "shifted":
        sigma = 0.75
        return Shifted(Dense(jnp.asarray(a)), jnp.asarray(sigma)), \
            a + sigma * np.eye(n)
    if kind == "jacobi":
        c = 1.0 / np.sqrt(np.diag(a))
        return Jacobi.create(Dense(jnp.asarray(a))), \
            a * np.outer(c, c)
    raise AssertionError(kind)


@pytest.mark.parametrize("kind", OP_KINDS)
@pytest.mark.parametrize("n,seed", [(24, 0), (33, 1), (33, 7)])
def test_matvec_diag_n_match_dense_reference(kind, n, seed):
    rng = np.random.default_rng(seed)
    a = make_spd(n, kappa=50.0, seed=seed, density=0.4)
    op, ref = _reference(kind, a, rng)
    assert op.n == n
    x = rng.standard_normal(n)
    np.testing.assert_allclose(np.asarray(op.matvec(jnp.asarray(x))),
                               ref @ x, rtol=1e-11, atol=1e-12)
    np.testing.assert_allclose(np.asarray(op.diag()), np.diag(ref),
                               rtol=1e-11, atol=1e-12)
    # batched x broadcasts over leading dims
    xs = rng.standard_normal((3, n))
    np.testing.assert_allclose(np.asarray(op.matvec(jnp.asarray(xs))),
                               xs @ ref.T, rtol=1e-11, atol=1e-12)


@pytest.mark.parametrize("kind", OP_KINDS)
def test_pytree_jit_vmap_roundtrip(kind):
    rng = np.random.default_rng(2)
    n = 33
    a = make_spd(n, kappa=50.0, seed=2, density=0.4)
    op, ref = _reference(kind, a, rng)

    leaves, treedef = jax.tree.flatten(op)
    back = jax.tree.unflatten(treedef, leaves)
    assert type(back) is type(op)
    assert back.n == op.n
    if isinstance(op, SparseBELL):
        assert back.mode == op.mode  # static metadata survives

    x = jnp.asarray(rng.standard_normal(n))
    y_ref = ref @ np.asarray(x)
    # operator as a jit ARGUMENT (pytree), not a closure constant
    y_jit = jax.jit(lambda o, v: o.matvec(v))(op, x)
    np.testing.assert_allclose(np.asarray(y_jit), y_ref, rtol=1e-11,
                               atol=1e-12)
    # vmap over the query batch with the operator held fixed
    xs = jnp.asarray(rng.standard_normal((4, n)))
    y_vm = jax.vmap(lambda v: op.matvec(v))(xs)
    np.testing.assert_allclose(np.asarray(y_vm), np.asarray(xs) @ ref.T,
                               rtol=1e-11, atol=1e-12)


@pytest.mark.parametrize("kind", OP_KINDS)
@pytest.mark.parametrize("seed", [0, 5])
def test_stack_ops_commutes_with_per_lane_matvec(kind, seed):
    """stack_ops(ops).matvec(stacked x) == stack of per-lane matvecs."""
    rng = np.random.default_rng(seed)
    n, k = 33, 3
    mats = [make_spd(n, kappa=40.0, seed=seed + i, density=0.4)
            for i in range(k)]
    if kind == "sparse_coo":
        # same-structure lanes need a shared padded-COO capacity
        cap = max(int((m != 0).sum()) for m in mats)
        pairs = [(sparse_from_dense(m, nnz=cap), m) for m in mats]
    else:
        pairs = [_reference(kind, m, rng) for m in mats]
    stacked = stack_ops([op for op, _ in pairs])
    xs = rng.standard_normal((k, n))
    got = np.asarray(stacked.matvec(jnp.asarray(xs)))
    want = np.stack([np.asarray(op.matvec(jnp.asarray(x)))
                     for (op, _), x in zip(pairs, xs)])
    # same per-lane computation, possibly different gemm grouping
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-12)
    refs = np.stack([ref @ x for (_, ref), x in zip(pairs, xs)])
    np.testing.assert_allclose(got, refs, rtol=1e-11, atol=1e-12)
    # diag stacks lane-wise too
    np.testing.assert_allclose(
        np.asarray(stacked.diag()),
        np.stack([np.asarray(op.diag()) for op, _ in pairs]),
        rtol=1e-12)


def test_stack_masks_commutes_with_per_lane_masked_matvec():
    rng = np.random.default_rng(3)
    n, k = 33, 4
    a = make_spd(n, kappa=40.0, seed=3, density=0.4)
    base = Dense(jnp.asarray(a))
    masks = (rng.random((k, n)) < 0.6).astype(np.float64)
    mop = stack_masks(base, jnp.asarray(masks))
    xs = rng.standard_normal((k, n))
    got = np.asarray(mop.matvec(jnp.asarray(xs)))
    for i in range(k):
        one = Masked(base, jnp.asarray(masks[i]))
        np.testing.assert_allclose(
            got[i], np.asarray(one.matvec(jnp.asarray(xs[i]))),
            rtol=1e-11, atol=1e-12)
    # the shared base is NOT copied per lane
    assert mop.base is base


def test_sparse_ops_preserve_explicit_zero_structure():
    """Padded-COO and blocked-ELL must treat padding as structural zeros:
    matvec of a basis vector recovers exactly the stored column."""
    n = 24
    a = make_spd(n, kappa=30.0, seed=4, density=0.2)
    coo = sparse_from_dense(a, nnz=int((a != 0).sum()) + 13)  # extra pad
    bell = bell_from_dense(a, bs=8)
    for j in [0, 7, n - 1]:
        e = np.zeros(n)
        e[j] = 1.0
        np.testing.assert_allclose(np.asarray(coo.matvec(jnp.asarray(e))),
                                   a[:, j], rtol=0, atol=1e-14)
        np.testing.assert_allclose(np.asarray(bell.matvec(jnp.asarray(e))),
                                   a[:, j], rtol=0, atol=1e-14)


def test_wrappers_compose_and_replace():
    """Masked(Shifted(Jacobi)) composes; dataclasses.replace keeps the
    pytree registration intact (frozen dataclasses all the way down)."""
    rng = np.random.default_rng(6)
    n = 24
    a = make_spd(n, kappa=30.0, seed=6)
    m = (rng.random(n) < 0.5).astype(np.float64)
    op = Masked(Shifted(Dense(jnp.asarray(a)), jnp.asarray(0.5)),
                jnp.asarray(m))
    c = a + 0.5 * np.eye(n)
    ref = np.diag(m) @ c @ np.diag(m) + np.eye(n) - np.diag(m)
    x = rng.standard_normal(n)
    np.testing.assert_allclose(np.asarray(op.matvec(jnp.asarray(x))),
                               ref @ x, rtol=1e-11)
    op2 = dataclasses.replace(op, mask=jnp.ones(n))
    np.testing.assert_allclose(np.asarray(op2.matvec(jnp.asarray(x))),
                               c @ x, rtol=1e-11)
    assert isinstance(jax.tree.unflatten(*jax.tree.flatten(op2)[::-1]),
                      Masked)


def test_coo_rejects_overfull_and_reports_n():
    a = make_spd(12, kappa=10.0, seed=0)
    with pytest.raises(ValueError, match="exceeds capacity"):
        sparse_from_dense(a, nnz=3)
    op = sparse_from_dense(a)
    assert isinstance(op, SparseCOO) and op.n == 12
