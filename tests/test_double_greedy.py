"""Retrospective double greedy (Alg. 8/9) vs exact baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Dense, run_double_greedy
from repro.data import random_sparse_spd


@pytest.fixture(scope="module")
def setup():
    n = 40
    a = random_sparse_spd(n, density=0.2, lam_min=5e-2, seed=9)
    # normalize diagonal ~1 so log-det gains are O(1) both signs
    d = np.sqrt(np.diag(a))
    a = a / np.outer(d, d) + 0.05 * np.eye(n)
    w = np.linalg.eigvalsh(a)
    return a, float(w[0] * 0.9), float(w[-1] * 1.1), n


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_matches_exact(setup, seed):
    a, lmn, lmx, n = setup
    op = Dense(jnp.asarray(a))
    key = jax.random.key(seed)
    rq = run_double_greedy(op, key, lmn, lmx, max_iters=n + 2)
    re = run_double_greedy(op, key, lmn, lmx, max_iters=n + 2, exact=True)
    assert bool(jnp.all(rq.selected == re.selected))
    assert int(rq.uncertified) == 0


def test_value_reasonable(setup):
    """Selected set should beat random subsets of the same size."""
    a, lmn, lmx, n = setup
    op = Dense(jnp.asarray(a))
    res = run_double_greedy(op, jax.random.key(0), lmn, lmx,
                            max_iters=n + 2)
    k = int(res.selected.sum())
    ld_sel = float(res.log_det)
    rng = np.random.default_rng(0)

    def logdet_subset(idx):
        sub = a[np.ix_(idx, idx)]
        return float(np.linalg.slogdet(sub)[1])

    rand_vals = [logdet_subset(rng.choice(n, k, replace=False))
                 for _ in range(30)]
    assert ld_sel >= np.mean(rand_vals)


def test_quadrature_work_sublinear(setup):
    a, lmn, lmx, n = setup
    op = Dense(jnp.asarray(a))
    res = run_double_greedy(op, jax.random.key(1), lmn, lmx,
                            max_iters=n + 2)
    avg = int(res.quad_iterations) / n
    assert avg < n / 2, f"avg iters/element {avg} not << n={n}"
