"""Batched execution path (DESIGN.md Sec. 6).

1. Parity: ``solve_batch``/``judge_batch`` must reproduce the
   per-candidate path exactly — same decisions, same per-lane iteration
   counts, same certification — on Dense, SparseCOO, and SparseBELL,
   including lanes that exhaust ``max_iters`` while others resolve
   early. Brackets are bit-exact for SparseCOO (whose scatter matvec
   reduces shape-independently); for Dense/BELL, XLA's gemv and gemm
   reduce in different orders, so brackets agree to 1e-12 while every
   discrete outcome stays exactly equal.
2. SparseBELL is a pytree: jit/vmap round-trips, stacked operators.
3. judge_argmax races to the certified winner; greedy MAP matches the
   exact-solve algorithm.
4. The batched pair judges decide identically to the gap-weighted pair
   driver.
5. BIFEngine flushes mixed judge/bracket traffic in max_batch lanes.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BIFSolver, Dense, Masked, SparseBELL, \
    bell_from_dense, greedy_map, sparse_from_dense, stack_masks, stack_ops
from repro.serve import BIFEngine, BIFRequest, rank_blocks
from repro.serve.engine import flush_trace_count
from conftest import make_spd


def _problem(n=48, k=6, kappa=150.0, seed=0, density=0.3):
    a = make_spd(n, kappa=kappa, seed=seed, density=density)
    w = np.linalg.eigvalsh(a)
    us = np.random.default_rng(seed + 1).standard_normal((k, n))
    true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)
    return a, jnp.asarray(us), true, float(w[0] * 0.99), float(w[-1] * 1.01)


def _ops(a):
    return {"dense": Dense(jnp.asarray(a)),
            "sparse": sparse_from_dense(a),
            "bell": bell_from_dense(a, bs=16)}


@pytest.mark.parametrize("op_kind", ["dense", "sparse", "bell"])
def test_solve_batch_matches_per_candidate(op_kind):
    a, us, true, lmn, lmx = _problem()
    op = _ops(a)[op_kind]
    s = BIFSolver.create(max_iters=50, rtol=1e-4)
    got = s.solve_batch(op, us, lam_min=lmn, lam_max=lmx)
    loop = [s.solve(op, us[i], lam_min=lmn, lam_max=lmx)
            for i in range(us.shape[0])]
    np.testing.assert_array_equal(
        np.asarray(got.iterations), [int(r.iterations) for r in loop])
    np.testing.assert_array_equal(
        np.asarray(got.certified), [bool(r.certified) for r in loop])
    for field in ("lower", "upper", "gauss_lower", "lobatto_upper"):
        batched = np.asarray(getattr(got, field))
        single = np.array([float(getattr(r, field)) for r in loop])
        if op_kind == "sparse":
            np.testing.assert_array_equal(batched, single)
        else:
            np.testing.assert_allclose(batched, single, rtol=1e-12)
    # the vmapped per-lane driver agrees the same way (same lockstep
    # semantics; same gemm caveat)
    vm = jax.vmap(lambda u: s.solve(op, u, lam_min=lmn, lam_max=lmx))(us)
    np.testing.assert_array_equal(np.asarray(vm.iterations),
                                  np.asarray(got.iterations))
    np.testing.assert_allclose(np.asarray(vm.lower), np.asarray(got.lower),
                               rtol=1e-12)
    assert np.all(np.asarray(got.lower) <= true * (1 + 1e-9))
    assert np.all(np.asarray(got.upper) >= true * (1 - 1e-9))


@pytest.mark.parametrize("op_kind", ["dense", "sparse", "bell"])
def test_judge_batch_matches_per_candidate_with_exhaustion(op_kind):
    """One lane's threshold sits on the knife edge (t = true to 1e-12):
    it must burn to max_iters uncertified while the rest exit early."""
    a, us, true, lmn, lmx = _problem(k=5)
    op = _ops(a)[op_kind]
    s = BIFSolver.create(max_iters=12)
    ts = jnp.asarray(true * np.array([0.5, 0.95, 1.0 + 1e-12, 1.05, 2.0]))
    got = s.judge_batch(op, us, ts, lam_min=lmn, lam_max=lmx)
    loop = [s.judge_threshold(op, us[i], ts[i], lam_min=lmn, lam_max=lmx)
            for i in range(us.shape[0])]
    np.testing.assert_array_equal(
        np.asarray(got.decision), [bool(r.decision) for r in loop])
    np.testing.assert_array_equal(
        np.asarray(got.iterations), [int(r.iterations) for r in loop])
    np.testing.assert_array_equal(
        np.asarray(got.certified), [bool(r.certified) for r in loop])
    # the knife-edge lane exhausted; its early-exit neighbors did not
    assert int(got.iterations[2]) == 12 and not bool(got.certified[2])
    assert int(got.iterations[0]) < 12 and bool(got.certified[0])


def test_solve_batch_on_stacked_ops_and_masks():
    """K *different* systems (stack_ops) and K submatrices of one base
    (stack_masks) both run as lanes of one driver."""
    n, k = 32, 4
    mats = [make_spd(n, kappa=60.0, seed=s) for s in range(k)]
    w = [np.linalg.eigvalsh(m) for m in mats]
    lmn = min(v[0] for v in w) * 0.99
    lmx = max(v[-1] for v in w) * 1.01
    us = jnp.asarray(np.random.default_rng(9).standard_normal((k, n)))
    s = BIFSolver.create(max_iters=n + 2, rtol=1e-4)

    for kind in ("dense", "sparse", "bell"):
        stacked = stack_ops([_ops(m)[kind] for m in mats])
        got = s.solve_batch(stacked, us, lam_min=lmn, lam_max=lmx)
        for i, m in enumerate(mats):
            true = float(us[i] @ np.linalg.solve(m, np.asarray(us[i])))
            assert float(got.lower[i]) <= true * 1.0001, kind
            assert float(got.upper[i]) >= true * 0.9999, kind

    base = Dense(jnp.asarray(mats[0]))
    masks = (np.random.default_rng(3).random((k, n)) < 0.6).astype(float)
    mop = stack_masks(base, jnp.asarray(masks))
    usm = us * masks
    got = s.solve_batch(mop, usm, lam_min=lmn, lam_max=lmx)
    loop = [s.solve(Masked(base, jnp.asarray(masks[i])), usm[i],
                    lam_min=lmn, lam_max=lmx) for i in range(k)]
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  [int(r.iterations) for r in loop])
    np.testing.assert_allclose(np.asarray(got.lower),
                               [float(r.lower) for r in loop], rtol=1e-12)


def test_sparse_bell_pytree_jit_vmap_roundtrip():
    a = make_spd(40, kappa=80.0, seed=2, density=0.2)
    op = bell_from_dense(a, bs=8)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(40))

    leaves, treedef = jax.tree.flatten(op)
    back = jax.tree.unflatten(treedef, leaves)
    assert isinstance(back, SparseBELL)
    assert back.n == op.n and back.mode == op.mode

    ref = a @ np.asarray(x)
    jit_y = jax.jit(lambda o, v: o.matvec(v))(op, x)
    np.testing.assert_allclose(np.asarray(jit_y), ref, rtol=1e-9)

    stacked = stack_ops([op, op])
    xs = jnp.stack([x, 2.0 * x])
    vm = jax.vmap(lambda o, v: o.matvec(v))(stacked, xs)
    np.testing.assert_allclose(np.asarray(vm[1]), 2.0 * ref, rtol=1e-9)
    # mode survives the stack (static metadata)
    assert stacked.mode == "reference"


def test_judge_argmax_certified_and_early_exit():
    a, us, true, lmn, lmx = _problem(k=8, seed=5)
    op = Dense(jnp.asarray(a))
    s = BIFSolver.create(max_iters=50)
    res = s.judge_argmax(op, us, lam_min=lmn, lam_max=lmx)
    assert int(res.index) == int(np.argmax(true))
    assert bool(res.certified)
    # dominated lanes froze before the winner finished refining
    iters = np.asarray(res.iterations)
    assert iters.min() < iters[int(res.index)] or iters.max() <= 2
    # per-lane shift/scale: maximize d_k - BIF_k (greedy MAP scoring)
    d = jnp.asarray(30.0 * np.abs(true))
    res2 = s.judge_argmax(op, us, shift=d, scale=-1.0, lam_min=lmn,
                          lam_max=lmx)
    assert int(res2.index) == int(np.argmax(np.asarray(d) - true))
    # valid mask excludes the winner; next-best lane must win
    valid = jnp.ones((8,), bool).at[res.index].set(False)
    res3 = s.judge_argmax(op, us, valid=valid, lam_min=lmn, lam_max=lmx)
    scores = true.copy()
    scores[int(res.index)] = -np.inf
    assert int(res3.index) == int(np.argmax(scores))


def test_greedy_map_matches_exact():
    n = 28
    a = make_spd(n, kappa=60.0, seed=7)
    d = np.sqrt(np.diag(a))
    a = a / np.outer(d, d) + 0.1 * np.eye(n)
    w = np.linalg.eigvalsh(a)
    op = Dense(jnp.asarray(a))
    rq = greedy_map(op, 6, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2)
    re = greedy_map(op, 6, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2,
                    exact=True)
    np.testing.assert_array_equal(np.asarray(rq.order), np.asarray(re.order))
    assert int(rq.uncertified) == 0
    assert int(rq.mask.sum()) == 6


@pytest.mark.parametrize("seed", [0, 4, 9])
def test_batched_pair_judges_match_pair_driver(seed):
    n = 30
    a = make_spd(n, kappa=100.0, seed=seed)
    w = np.linalg.eigvalsh(a)
    lmn, lmx = w[0] * 0.99, w[-1] * 1.01
    rng = np.random.default_rng(seed + 7)
    mask = (rng.random(n) < 0.5).astype(np.float64)
    mask[:2] = [1.0, 0.0]
    u = jnp.asarray(rng.standard_normal(n) * mask)
    v = jnp.asarray(rng.standard_normal(n) * mask)
    p = jnp.asarray(rng.uniform(0.05, 0.95))
    t = jnp.asarray(rng.standard_normal() * 0.1)
    op = Masked(Dense(jnp.asarray(a)), jnp.asarray(mask))
    s = BIFSolver.create(max_iters=n + 2)
    pair = s.judge_kdpp_swap(op, u, op, v, t, p, lam_min=lmn, lam_max=lmx)
    bat = s.judge_kdpp_swap_batch(op, u, v, t, p, lam_min=lmn, lam_max=lmx)
    assert bool(pair.decision) == bool(bat.decision)
    assert bool(bat.certified)

    x_mask = np.zeros(n)
    x_mask[rng.choice(n, 5, replace=False)] = 1.0
    y_mask = np.ones(n)
    i = int(np.argmax(x_mask == 0))
    x_mask[i], y_mask[i] = 0.0, 0.0
    col = a[:, i]
    base = Dense(jnp.asarray(a))
    pair = s.judge_double_greedy(
        Masked(base, jnp.asarray(x_mask)), jnp.asarray(col * x_mask),
        Masked(base, jnp.asarray(y_mask)), jnp.asarray(col * y_mask),
        jnp.asarray(a[i, i]), p, lam_min=lmn, lam_max=lmx)
    bat = s.judge_double_greedy_batch(
        stack_masks(base, jnp.asarray(np.stack([x_mask, y_mask]))),
        jnp.asarray(np.stack([col * x_mask, col * y_mask])),
        jnp.asarray(a[i, i]), p, lam_min=lmn, lam_max=lmx)
    assert bool(pair.decision) == bool(bat.decision)


def test_bif_engine_flushes_mixed_traffic_in_chunks():
    n = 36
    a = make_spd(n, kappa=90.0, seed=1)
    op = Dense(jnp.asarray(a))
    engine = BIFEngine(op, solver=BIFSolver.create(max_iters=n + 2,
                                                   rtol=1e-4),
                       max_batch=4)
    rng = np.random.default_rng(2)
    us = rng.standard_normal((11, n))
    true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)
    reqs = []
    for i, u in enumerate(us):
        t = float(true[i] * (0.9 if i % 2 else 1.1)) if i % 3 else None
        reqs.append(engine.submit(BIFRequest(u=u, t=t)))
    assert engine.pending() == 11
    out = engine.flush()
    assert engine.pending() == 0 and len(out) == 11
    for i, r in enumerate(out):
        assert r.lower <= true[i] * 1.0001
        assert r.upper >= true[i] * 0.9999
        if r.t is None:
            assert r.decision is None
        else:
            assert r.decision == (r.t < true[i])
            assert r.certified
    # masked request against a principal submatrix; the engine restricts
    # the query to the mask itself, so raw (unmasked) u is the natural call
    mask = (rng.random(n) < 0.5).astype(float)
    req = engine.submit(BIFRequest(u=us[0], mask=mask))
    engine.flush()
    um = us[0] * mask
    mm = np.diag(mask)
    am = mm @ a @ mm + np.eye(n) - mm
    tv = um @ np.linalg.solve(am, um)
    assert req.lower <= tv * 1.0001 and req.upper >= tv * 0.9999


def test_bif_engine_rejects_malformed_requests_at_submit():
    n = 12
    a = make_spd(n, kappa=10.0, seed=5)
    engine = BIFEngine(Dense(jnp.asarray(a)), max_batch=4)
    with pytest.raises(ValueError, match="u must have shape"):
        engine.submit(BIFRequest(u=np.ones(n + 1)))
    with pytest.raises(ValueError, match="mask must have shape"):
        engine.submit(BIFRequest(u=np.ones(n), mask=np.ones(n - 1)))
    with pytest.raises(ValueError, match="t must be a scalar"):
        engine.submit(BIFRequest(u=np.ones(n), t=np.array([1.0, 2.0])))
    # a rejected request never enters the queue, so it can't wedge a flush
    assert engine.pending() == 0
    good = engine.submit(BIFRequest(u=np.ones(n)))
    engine.flush()
    assert good.lower is not None and good.lower <= good.upper


def test_rank_blocks_same_bucket_compiles_once():
    """Distinct block counts in one padding bucket share ONE compiled
    flush driver: rank_blocks pads the system size to the bucket and the
    engine's shared jit (serve.engine._flush_run) keys on the padded
    shapes + static solver config, so the second call is a cache hit.
    Counted via the trace-time counter, which only ever increments when
    jit misses its cache and re-traces."""
    rng = np.random.default_rng(11)
    keys_a = rng.standard_normal((24 * 4, 8)).astype(np.float32)  # 24 blocks
    keys_b = rng.standard_normal((20 * 4, 8)).astype(np.float32)  # 20 blocks

    order_a, stats_a = rank_blocks(keys_a, block=4, max_batch=8, bucket=32)
    first = flush_trace_count()
    order_b, stats_b = rank_blocks(keys_b, block=4, max_batch=8, bucket=32)
    assert flush_trace_count() == first, \
        "second rank_blocks call in the same bucket re-traced the driver"
    # repeat of an identical call stays cached too
    rank_blocks(keys_a, block=4, max_batch=8, bucket=32)
    assert flush_trace_count() == first
    # both calls produced real rankings over their own block counts
    assert sorted(order_a.tolist()) == list(range(24))
    assert sorted(order_b.tolist()) == list(range(20))
    assert stats_a["blocks"] == 24 and stats_b["blocks"] == 20
    assert len(stats_b["brackets"]) == 20


def test_bif_engine_failed_round_marks_inflight_and_keeps_tail_order():
    """A driver failure mid-flush drops ONLY the in-flight requests (error
    set), keeps the unadmitted tail queued in submission order, and keeps
    the results of requests that already retired."""
    n = 12
    a = make_spd(n, kappa=10.0, seed=6)
    # chunk_iters > max_iters: every admitted request resolves within ONE
    # scheduler round, so round k serves exactly the k-th admitted pair
    engine = BIFEngine(Dense(jnp.asarray(a)), max_batch=2, chunk_iters=64)
    rng = np.random.default_rng(7)
    reqs = [engine.submit(BIFRequest(u=rng.standard_normal(n)))
            for _ in range(5)]
    orig, calls = engine._step, [0]

    def flaky(*args):
        calls[0] += 1
        if calls[0] == 2:  # second scheduler round fails
            raise RuntimeError("transient driver failure")
        return orig(*args)

    engine._step = flaky
    with pytest.raises(RuntimeError, match="transient"):
        engine.flush()
    # round 1 served the first pool (reqs 0-1); round 2's in-flight pool
    # (reqs 2-3) was dropped with its error set; req 4 was never admitted
    # and stays queued
    assert engine.pending() == 1
    assert [r.error is not None for r in reqs] == [False] * 2 + [True] * 2 \
        + [False]
    assert reqs[0].lower is not None and reqs[1].lower is not None
    engine._step = orig
    out = engine.flush()
    assert [r is reqs[4] for r in out] == [True]  # surviving tail, in order
    assert reqs[4].lower is not None
    # resubmitting a failed request clears the marker and serves it
    engine.submit(reqs[2])
    engine.flush()
    assert reqs[2].error is None and reqs[2].lower is not None


def test_bif_engine_continuous_matches_lockstep_and_preserves_fifo():
    """Continuous batching retires/backfills mid-flight but must return
    per-request outcomes identical to the lockstep flush (decisions and
    iteration counts exact, brackets to the gemm caveat) in submission
    order."""
    n = 36
    a = make_spd(n, kappa=90.0, seed=3)
    w = np.linalg.eigvalsh(a)
    lam = dict(lam_min=float(w[0] * 0.99), lam_max=float(w[-1] * 1.01))
    op = Dense(jnp.asarray(a))
    sv = BIFSolver.create(max_iters=n + 2, rtol=1e-4)
    rng = np.random.default_rng(8)
    us = rng.standard_normal((13, n))
    true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)

    def submit_all(engine):
        reqs = []
        for i, u in enumerate(us):
            t = float(true[i] * (0.8 if i % 2 else 1.2)) if i % 3 else None
            reqs.append(engine.submit(BIFRequest(u=u, t=t)))
        return reqs

    e_cont = BIFEngine(op, solver=sv, max_batch=4, chunk_iters=3, **lam)
    e_lock = BIFEngine(op, solver=sv, max_batch=4, **lam)
    rc = submit_all(e_cont)
    rl = submit_all(e_lock)
    out_c = e_cont.flush()
    out_l = e_lock.flush(mode="lockstep")
    assert out_c == rc and out_l == rl  # FIFO-preserving completion
    for i, (c, l) in enumerate(zip(rc, rl)):
        assert c.decision == l.decision, i
        assert c.certified == l.certified, i
        assert c.iterations == l.iterations, i
        np.testing.assert_allclose([c.lower, c.upper], [l.lower, l.upper],
                                   rtol=1e-12)
        assert c.resolved and c.state is None


def test_bif_engine_budget_partials_resume_bit_exact():
    """A request whose iteration budget expires comes back partial with a
    banked QuadState; resubmitting it resumes the solve and lands on the
    SAME bracket and iteration count as an uninterrupted run."""
    n = 40
    a = make_spd(n, kappa=50.0, seed=9)
    w = np.linalg.eigvalsh(a)
    lam = dict(lam_min=float(w[0] * 0.99), lam_max=float(w[-1] * 1.01))
    op = sparse_from_dense(a)
    sv = BIFSolver.create(max_iters=n + 2, rtol=1e-6)
    rng = np.random.default_rng(10)
    u = rng.standard_normal(n)

    full = BIFEngine(op, solver=sv, max_batch=4, **lam)
    ref = full.submit(BIFRequest(u=u))
    full.flush()
    assert ref.resolved and ref.iterations > 6

    eng = BIFEngine(op, solver=sv, max_batch=4, chunk_iters=2, **lam)
    part = eng.submit(BIFRequest(u=u, max_iters=5))
    eng.flush()
    assert part.resolved is False and part.certified is False
    assert part.iterations == 5 and part.state is not None
    assert part.lower is not None and part.lower <= part.upper
    # the banked bracket is a valid (wider) enclosure of the final one
    assert part.lower <= ref.lower and part.upper >= ref.upper
    # resubmit with the remaining budget: bit-exact with the
    # uninterrupted solve (SparseCOO matvec is shape-independent)
    part.max_iters = None
    eng.submit(part)
    eng.flush()
    assert part.resolved and part.state is None
    assert part.iterations == ref.iterations
    assert part.lower == ref.lower and part.upper == ref.upper
    # the banked state also resumes OUTSIDE the engine, same answer
    part2 = eng.submit(BIFRequest(u=u, max_iters=5))
    eng.flush()
    st = sv.resume(part2.state)
    res = sv.finalize(st)
    assert float(res.lower) == ref.lower and float(res.upper) == ref.upper


def test_bif_engine_rejects_mutated_partial_resubmission():
    """A banked state is only valid for the (u, mask) it was solving;
    resubmitting a partial with a mutated query must be rejected at the
    door (clearing .state re-solves from scratch instead)."""
    n = 40
    a = make_spd(n, kappa=50.0, seed=9)
    w = np.linalg.eigvalsh(a)
    eng = BIFEngine(sparse_from_dense(a),
                    solver=BIFSolver.create(max_iters=n + 2, rtol=1e-6),
                    max_batch=4, chunk_iters=2,
                    lam_min=float(w[0] * 0.99), lam_max=float(w[-1] * 1.01))
    rng = np.random.default_rng(15)
    r = eng.submit(BIFRequest(u=rng.standard_normal(n), max_iters=4))
    eng.flush()
    assert r.resolved is False and r.state is not None
    r.u = rng.standard_normal(n)  # different query, stale state
    with pytest.raises(ValueError, match="banks the solve"):
        eng.submit(r)
    r.state = None                # explicit re-solve is fine
    r.max_iters = None
    eng.submit(r)
    eng.flush()
    assert r.resolved


def test_bif_engine_deadline_retires_partial():
    n = 24
    a = make_spd(n, kappa=200.0, seed=11)
    w = np.linalg.eigvalsh(a)
    eng = BIFEngine(Dense(jnp.asarray(a)),
                    solver=BIFSolver.create(max_iters=n + 2, rtol=1e-12),
                    max_batch=2, chunk_iters=1,
                    lam_min=float(w[0] * 0.99), lam_max=float(w[-1] * 1.01))
    rng = np.random.default_rng(12)
    # a deadline that expires mid-solve retires at the next chunk
    # boundary as a PARTIAL result with the banked state for
    # resubmission (the deadline lands after admission, so the request
    # gets at least its first chunk round)
    steps = 0
    orig_step = eng._step

    def counting_step(*args, **kwargs):
        nonlocal steps
        steps += 1
        return orig_step(*args, **kwargs)

    eng._step = counting_step
    req = eng.submit(BIFRequest(u=rng.standard_normal(n),
                                deadline=time.monotonic() + 0.2))
    eng.flush()
    assert steps >= 1
    assert req.iterations >= 1 and req.lower is not None
    assert req.state is not None or req.resolved


def test_bif_engine_expired_deadline_retires_at_admission():
    """an ALREADY-expired deadline must not burn a chunk_iters x pool
    decision round: the request retires at the door with zero
    iterations and no banked state, in submission order."""
    n = 24
    a = make_spd(n, kappa=200.0, seed=11)
    w = np.linalg.eigvalsh(a)
    eng = BIFEngine(Dense(jnp.asarray(a)),
                    solver=BIFSolver.create(max_iters=n + 2, rtol=1e-12),
                    max_batch=2, chunk_iters=1,
                    lam_min=float(w[0] * 0.99), lam_max=float(w[-1] * 1.01))
    rng = np.random.default_rng(12)
    steps = 0
    orig_step = eng._step

    def counting_step(*args, **kwargs):
        nonlocal steps
        steps += 1
        return orig_step(*args, **kwargs)

    eng._step = counting_step

    # all-expired queue: zero pool rounds, zero iterations, no state
    dead = [eng.submit(BIFRequest(u=rng.standard_normal(n), deadline=0.0))
            for _ in range(3)]
    out = eng.flush()
    assert steps == 0
    assert out == dead  # submission order preserved
    for r in dead:
        assert r.iterations == 0 and r.resolved is False
        assert r.state is None and r.lower is None and r.upper is None
        assert r.certified is False

    # mixed queue: the expired request is skipped at admission while the
    # live one still solves in the same flush, order preserved
    live = BIFRequest(u=rng.standard_normal(n))
    expired = BIFRequest(u=rng.standard_normal(n), deadline=0.0)
    r1 = eng.submit(expired)
    r2 = eng.submit(live)
    out = eng.flush()
    assert out == [r1, r2]
    assert r1.iterations == 0 and r1.state is None
    assert steps >= 1 and r2.iterations >= 1  # the live one really ran


def test_bif_engine_submit_clears_stale_results():
    """resubmission must clear the previous round's results at the door:
    if the refining flush errors, callers must NOT read the coarse
    round's lower/upper/decision as if they were current."""
    n = 32
    a = make_spd(n, kappa=300.0, seed=21)
    w = np.linalg.eigvalsh(a)
    eng = BIFEngine(Dense(jnp.asarray(a)),
                    solver=BIFSolver.create(max_iters=n + 2, rtol=1e-6),
                    max_batch=2, chunk_iters=2,
                    lam_min=float(w[0] * 0.99), lam_max=float(w[-1] * 1.01))
    rng = np.random.default_rng(22)
    r = eng.submit(BIFRequest(u=rng.standard_normal(n), max_iters=2))
    eng.flush()
    assert r.resolved is False and r.lower is not None
    it_coarse = r.iterations
    r.max_iters = None
    eng.submit(r)
    assert r.lower is None and r.upper is None
    assert r.decision is None and r.certified is None
    assert r.iterations is None and r.resolved is None
    assert r.state is not None  # the banked resume state survives
    eng.flush()
    assert r.resolved and r.lower is not None
    # iteration counts stay cumulative across the resubmission (they are
    # restored from the banked lane counter, not the cleared field)
    assert r.iterations > it_coarse


def test_bif_engine_legacy_configs_fall_back_to_lockstep():
    """reorth / preconditioned solvers predate the scheduler and must
    keep flushing (via the lockstep path) rather than raise."""
    n = 16
    a = make_spd(n, kappa=20.0, seed=13)
    rng = np.random.default_rng(14)
    u = rng.standard_normal(n)
    for cfg in (dict(reorth=True), dict(precondition="jacobi")):
        eng = BIFEngine(Dense(jnp.asarray(a)),
                        solver=BIFSolver.create(max_iters=n + 2, rtol=1e-4,
                                                **cfg))
        r = eng.submit(BIFRequest(u=u))
        eng.flush()
        true = float(u @ np.linalg.solve(a, u))
        assert r.lower <= true * 1.0001 and r.upper >= true * 0.9999, cfg
        assert r.resolved
