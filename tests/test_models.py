"""Per-arch smoke + invariants: reduced configs, one train/prefill/decode
step on CPU, output shapes, finiteness, decode==prefill consistency,
gradient flow, chunked attention equivalence, chunked CE equivalence,
MoE and SSM unit behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.launch import shapes
from repro.models import attention as A
from repro.models import losses, model as M
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod

ARCHS = list_archs()


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_arch(name).reduced()
            params, axes = M.init_model(jax.random.key(0), cfg)
            cache[name] = (cfg, params, axes)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_smoke(built, name):
    cfg, params, axes = built(name)
    batch = shapes.make_inputs(cfg, "train", seq=32, batch=2)
    loss, metrics = M.loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0
    # grads exist and are finite on every leaf
    g = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("name", ARCHS)
def test_serve_steps_smoke_and_consistency(built, name):
    cfg, params, _ = built(name)
    T, B = 16, 2
    pre = shapes.make_inputs(cfg, "prefill", seq=T, batch=B, seed=3)
    c_full = M.make_caches(cfg, B, T + 4, jnp.float32)
    c_full, logits_full = M.prefill(cfg, params, pre, c_full)
    assert logits_full.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits_full).all())

    pre_part = dict(pre)
    pre_part["tokens"] = pre["tokens"][:, :-1]
    if cfg.family == "vlm":
        pre_part["positions"] = pre["positions"][:, :, :-1]
        dec = {"tokens": pre["tokens"][:, -1:],
               "position": jnp.full((B, 3, 1), T - 1, jnp.int32)}
    else:
        dec = {"tokens": pre["tokens"][:, -1:],
               "position": jnp.full((1,), T - 1, jnp.int32)}
    if cfg.family == "encdec":
        dec["enc_memory"] = M._encode(cfg, params,
                                      pre["frames"].astype(jnp.float32))
    c_part = M.make_caches(cfg, B, T + 4, jnp.float32)
    c_part, _ = M.prefill(cfg, params, pre_part, c_part)
    c_part, logits_dec = M.decode_step(cfg, params, c_part, dec)
    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    assert err / scale < 2e-2, f"{name}: decode != prefill ({err/scale})"


@pytest.mark.parametrize("name", ARCHS)
def test_param_axes_cover_params(built, name):
    cfg, params, axes = built(name)
    pl = jax.tree.leaves(params)
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    al = jax.tree.leaves(axes, is_leaf=is_ax)
    assert len(pl) == len(al)
    for p, a in zip(pl, al):
        assert len(a) == p.ndim, (a, p.shape)


def test_chunked_ce_equals_full():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 8)), jnp.float32)
    table = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 32, (2, 16)), jnp.int32)
    full_logits = jnp.einsum("btd,vd->btv", x, table)
    lse = jax.nn.logsumexp(full_logits, -1)
    gold = jnp.take_along_axis(full_logits, labels[..., None], -1)[..., 0]
    ce_full = jnp.mean(lse - gold)
    for chunk in (2, 4, 16):
        loss, m = losses.chunked_cross_entropy(x, table, labels,
                                               chunk=chunk, z_loss=0.0)
        np.testing.assert_allclose(float(loss), float(ce_full), rtol=1e-6)


@pytest.mark.parametrize("hkv", [1, 2, 8])
def test_attention_gqa_grouping(hkv):
    rng = np.random.default_rng(hkv)
    q = jnp.asarray(rng.standard_normal((2, 32, 8, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 32, hkv, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 32, hkv, 16)), jnp.float32)
    # oracle with explicit repetition
    kr = A._repeat_kv(k, 8)
    vr = A._repeat_kv(v, 8)
    scores = jnp.einsum("bthd,bshd->bhts", q, kr) / 4.0
    mask = jnp.tril(jnp.ones((32, 32), bool))
    scores = jnp.where(mask, scores, -1e30)
    expected = jnp.einsum("bhts,bshd->bthd", jax.nn.softmax(scores, -1), vr)
    got = A._sdpa_full(q, k, v, causal=True, window=None)
    np.testing.assert_allclose(got, expected, rtol=1e-5, atol=1e-5)


def test_moe_capacity_drops_and_dropless():
    cfg = get_arch("arctic-480b").reduced()
    cfg = dataclasses.replace(cfg, moe_capacity_factor=0.5)
    p, _ = moe_mod.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 64)),
                    jnp.float32)
    y_cap, aux = moe_mod.moe_apply(cfg, p, x)
    y_free, _ = moe_mod.moe_apply(cfg, p, x, dropless=True)
    assert y_cap.shape == x.shape
    assert float(aux) > 0
    # capacity pressure must change outputs (drops happened)
    assert not np.allclose(np.asarray(y_cap), np.asarray(y_free))


def test_moe_router_gradients():
    cfg = get_arch("llama4-maverick-400b-a17b").reduced()
    p, _ = moe_mod.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 8, 64)),
                    jnp.float32)

    def f(p):
        y, aux = moe_mod.moe_apply(cfg, p, x)
        return jnp.sum(y * y) + aux

    g = jax.grad(f)(p)
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0


@pytest.mark.parametrize("variant,arch", [("mamba1", "falcon-mamba-7b"),
                                          ("mamba2", "zamba2-1.2b")])
def test_ssm_scan_vs_stepwise(variant, arch):
    """Prefill scan state must equal token-by-token decode states."""
    cfg = get_arch(arch).reduced()
    p, _ = ssm_mod.ssm_init(jax.random.key(0), cfg, jnp.float32)
    B, T = 2, 8
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (B, T, cfg.d_model)) * 0.3, jnp.float32)
    cache0 = ssm_mod.make_ssm_cache(cfg, B, jnp.float32)
    y_scan, cache_scan = ssm_mod.ssm_apply(cfg, p, x, mode="prefill",
                                           cache=cache0)
    cache = ssm_mod.make_ssm_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(T):
        yt, cache = ssm_mod.ssm_apply(cfg, p, x[:, t:t + 1], mode="decode",
                                      cache=cache)
        ys.append(yt)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cache_scan.state),
                               np.asarray(cache.state), rtol=2e-4,
                               atol=2e-4)


def test_sliding_window_attention():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 16, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 16, 4, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 16, 4, 8)), jnp.float32)
    full = A._sdpa_full(q, k, v, causal=True, window=None)
    win = A._sdpa_full(q, k, v, causal=True, window=4)
    # early tokens (inside window) identical; late tokens differ
    np.testing.assert_allclose(full[:, :4], win[:, :4], rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(np.asarray(full[:, -1]), np.asarray(win[:, -1]))


def test_int8_kv_cache_quality():
    cfg = get_arch("llama3-405b").reduced()
    params, _ = M.init_model(jax.random.key(0), cfg)
    T, B = 16, 2
    pre = shapes.make_inputs(cfg, "prefill", seq=T, batch=B, seed=0)
    c16 = M.make_caches(cfg, B, T + 4, jnp.float32)
    c8 = M.make_caches(cfg, B, T + 4, jnp.float32, quantized_kv=True)
    c16, l16 = M.prefill(cfg, params, pre, c16)
    c8, l8 = M.prefill(cfg, params, pre, c8)
    # prefill logits identical (cache not read during prefill attention)
    np.testing.assert_allclose(np.asarray(l16), np.asarray(l8), rtol=1e-4,
                               atol=1e-4)
    dec = {"tokens": pre["tokens"][:, -1:],
           "position": jnp.full((1,), T - 1, jnp.int32)}
    _, d16 = M.decode_step(cfg, params, c16, dec)
    _, d8 = M.decode_step(cfg, params, c8, dec)
    # int8 decode close to fp (top-1 match)
    assert (np.argmax(np.asarray(d16), -1)
            == np.argmax(np.asarray(d8), -1)).all()


def test_mamba2_chunked_ssd_equals_scan():
    """Beyond-paper SSD optimization must be numerically equivalent."""
    cfg = get_arch("zamba2-1.2b").reduced()
    p, _ = ssm_mod.ssm_init(jax.random.key(0), cfg, jnp.float32)
    B, T = 2, 32
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (B, T, cfg.d_model)) * 0.3, jnp.float32)
    c0 = ssm_mod.make_ssm_cache(cfg, B, jnp.float32)
    y_scan, cs = ssm_mod.ssm_apply(cfg, p, x, mode="prefill", cache=c0)
    cfg2 = dataclasses.replace(cfg, ssm_impl="chunked", ssm_chunk=8)
    y_chunk, cc = ssm_mod.ssm_apply(cfg2, p, x, mode="prefill", cache=c0)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_chunk),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cs.state), np.asarray(cc.state),
                               rtol=1e-4, atol=1e-5)
    # decode continuation from the chunked state matches the scan state
    xt = x[:, :1]
    y_d1, _ = ssm_mod.ssm_apply(cfg, p, xt, mode="decode", cache=cs)
    y_d2, _ = ssm_mod.ssm_apply(cfg2, p, xt, mode="decode", cache=cc)
    np.testing.assert_allclose(np.asarray(y_d1), np.asarray(y_d2),
                               rtol=1e-4, atol=1e-5)
