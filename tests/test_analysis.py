"""quadlint (``python -m repro.analysis``) tests.

Per-rule bad/good fixtures (each bad snippet must produce its rule,
each good twin must not), the suppression contract (reasons are
mandatory, QL000 is unsuppressable), CLI exit codes and output format,
the QL001 mutation checks (an unthreaded QuadState field and a
dropped registry entry must both fail the scan), and the tier-1 pin
that the repo's own tree is clean.
"""
import collections
import os
import re
import subprocess
import sys
import textwrap
from pathlib import Path

import repro.core.solver as solver_mod
from repro.analysis import run_paths
from repro.analysis.engine import main

REPO = Path(__file__).resolve().parent.parent


def _write(tmp_path, rel_parts, code):
    p = tmp_path.joinpath(*rel_parts)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code), encoding="utf-8")
    return p


def _lint(tmp_path, rel_parts, code):
    p = _write(tmp_path, rel_parts, code)
    return run_paths([str(p)], project_checks=False)


def _rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# QL002: tracer leaks


def test_ql002_if_on_traced_value_in_jit(tmp_path):
    findings = _lint(tmp_path, ("m.py",), """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
        """)
    assert _rules(findings) == ["QL002"]
    assert findings[0].line == 6


def test_ql002_concretization_in_while_loop_body(tmp_path):
    findings = _lint(tmp_path, ("m.py",), """
        import jax

        def run(x0):
            def body(c):
                y = float(c)
                return y + 1.0
            return jax.lax.while_loop(lambda c: c < 3.0, body, x0)
        """)
    assert _rules(findings) == ["QL002"]
    assert "float()" in findings[0].message


def test_ql002_static_shapes_and_none_checks_are_fine(tmp_path):
    findings = _lint(tmp_path, ("m.py",), """
        import jax
        import jax.numpy as jnp
        from functools import partial

        @partial(jax.jit, static_argnames=("n",))
        def f(x, n, probe=None):
            if n > 2:
                x = x * 2
            if probe is not None:
                x = x + probe
            if x.ndim == 2:
                x = x.sum(axis=-1)
            return jnp.where(x > 0, x, -x)
        """)
    assert findings == []


# ---------------------------------------------------------------------------
# QL003: jit discipline


def test_ql003_serve_jit_without_trace_counter(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "serve", "m.py"), """
        import jax

        @jax.jit
        def _run(x):
            return x * 2
        """)
    assert _rules(findings) == ["QL003"]
    assert "trace counter" in findings[0].message


def test_ql003_serve_jit_with_trace_counter_is_fine(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "serve", "m.py"), """
        import jax

        _RUN_TRACES = [0]

        @jax.jit
        def _run(x):
            _RUN_TRACES[0] += 1
            return x * 2
        """)
    assert findings == []


def test_ql003_jit_inside_function_body(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        import jax

        def make(f):
            return jax.jit(f)
        """)
    assert _rules(findings) == ["QL003"]
    assert "function body" in findings[0].message


def test_ql003_only_applies_to_library_code(tmp_path):
    findings = _lint(tmp_path, ("scripts", "m.py"), """
        import jax

        def make(f):
            return jax.jit(f)
        """)
    assert findings == []


# ---------------------------------------------------------------------------
# QL004: collective pairing under shard_map


_QL004_BAD = """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map

    def drive(mesh, xs):
        def local_fn(x):
            def cond(c):
                return c[1] < 3

            def body(c):
                g = jax.lax.all_gather(c[0], "lanes")
                return (g.sum(axis=0), c[1] + 1)

            return jax.lax.while_loop(cond, body, (x, 0))

        return shard_map(local_fn, mesh=mesh, in_specs=None,
                         out_specs=None)(xs)
    """


def test_ql004_unguarded_collective_in_while_loop(tmp_path):
    findings = _lint(tmp_path, ("m.py",), _QL004_BAD)
    assert "QL004" in _rules(findings)
    msg = [f for f in findings if f.rule == "QL004"][0].message
    assert "all_gather" in msg


def test_ql004_psum_continue_flag_is_fine(tmp_path):
    findings = _lint(tmp_path, ("m.py",), """
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map

        def drive(mesh, xs):
            def local_fn(x):
                def cond(c):
                    nm = c[1] < 3
                    return jax.lax.psum(
                        jnp.any(nm).astype(jnp.int32), "lanes") > 0

                def body(c):
                    g = jax.lax.all_gather(c[0], "lanes")
                    return (g.sum(axis=0), c[1] + 1)

                return jax.lax.while_loop(cond, body, (x, 0))

            return shard_map(local_fn, mesh=mesh, in_specs=None,
                             out_specs=None)(xs)
        """)
    assert "QL004" not in _rules(findings)


# ---------------------------------------------------------------------------
# QL007: collective cadence in core/ loop bodies


_QL007_BAD = """
    import jax
    import jax.numpy as jnp

    def drive(xs):
        def cond(c):
            return c[1] < 3

        def body(c):
            g = jax.lax.all_gather(c[0], "lanes")
            f = jax.lax.psum(jnp.any(g).astype(jnp.int32), "lanes")
            return (g.sum(axis=0), c[1] + f)

        return jax.lax.while_loop(cond, body, (xs, 0))
    """


def test_ql007_raw_collectives_in_core_loop_body(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "core", "m.py"),
                     _QL007_BAD)
    ql7 = [f for f in findings if f.rule == "QL007"]
    # one finding PER collective call site, anchored at its own line
    assert sorted(f.message.split()[1] for f in ql7) == \
        ["all_gather", "psum"]


def test_ql007_transitive_through_module_helper(tmp_path):
    # QL004's same-scope walk cannot see a module-level helper; QL007's
    # module-wide walk must
    findings = _lint(tmp_path, ("src", "repro", "core", "m.py"), """
        import jax

        def helper(x):
            return jax.lax.all_gather(x, "lanes")

        def drive(xs):
            def cond(c):
                return c[1] < 3

            def body(c):
                g = helper(c[0])
                return (g.sum(axis=0), c[1] + 1)

            return jax.lax.while_loop(cond, body, (xs, 0))
        """)
    ql7 = [f for f in findings if f.rule == "QL007"]
    assert len(ql7) == 1 and "all_gather" in ql7[0].message
    # anchored at the helper's gather line, where a suppression lives
    assert ql7[0].line == 5


def test_ql007_suppressed_cadence_helper_is_fine(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "core", "m.py"), """
        import jax

        def _round_gather(x):
            return jax.lax.all_gather(x, "lanes", tiled=True)  # quadlint: disable=QL007 -- the sanctioned per-round collective

        def drive(xs):
            def cond(c):
                return c[1] < 3

            def body(c):
                g = _round_gather(c[0])
                return (g.sum(axis=0), c[1] + 1)

            return jax.lax.while_loop(cond, body, (xs, 0))
        """)
    assert "QL007" not in _rules(findings)


def test_ql007_collective_outside_the_loop_is_fine(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "core", "m.py"), """
        import jax

        def boundary(x):
            return jax.lax.all_gather(x, "lanes")

        def drive(xs):
            def cond(c):
                return c[1] < 3

            def body(c):
                return (c[0] * 2.0, c[1] + 1)

            out = jax.lax.while_loop(cond, body, (xs, 0))
            return boundary(out[0])
        """)
    assert "QL007" not in _rules(findings)


def test_ql007_only_applies_to_core(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "serve", "m.py"),
                     _QL007_BAD)
    assert "QL007" not in _rules(findings)


# ---------------------------------------------------------------------------
# QL005: removed-shim imports stay removed


def test_ql005_shim_function_and_module_imports(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        from repro.core import bif_bounds
        from repro.core.judge import judge_threshold
        """)
    assert [f.rule for f in findings] == ["QL005", "QL005"]


def test_ql005_solver_imports_are_fine(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        from repro.core import BIFSolver, bif_bounds_trace
        """)
    assert findings == []


# ---------------------------------------------------------------------------
# QL006: unkeyed randomness


def test_ql006_legacy_and_unseeded_randomness(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        import random
        import numpy as np

        x = np.random.rand(3)
        rng = np.random.default_rng()
        """)
    assert [f.rule for f in findings] == ["QL006", "QL006", "QL006"]


def test_ql006_seeded_rng_is_fine(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        import numpy as np

        rng = np.random.default_rng(0)
        x = rng.standard_normal(3)
        """)
    assert findings == []


# ---------------------------------------------------------------------------
# QL008: telemetry is host-side only


def test_ql008_metrics_call_and_print_in_traced_scope(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        import jax
        from repro.obs import metrics as obs_metrics

        def run(x):
            def body(c):
                obs_metrics.counter("steps").inc()
                print(c)
                return c - 1
            return jax.lax.while_loop(lambda c: c > 0, body, x)
        """)
    assert [f.rule for f in findings] == ["QL008", "QL008"]
    assert "host-side-only" in findings[0].message
    assert "trace time" in findings[1].message


def test_ql008_span_through_module_helper(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        import jax
        from repro.obs.spans import span

        def _tick():
            with span("step"):
                return None

        def run(x):
            def body(c):
                _tick()
                return c - 1
            return jax.lax.while_loop(lambda c: c > 0, body, x)
        """)
    assert _rules(findings) == ["QL008"]


def test_ql008_obs_package_attribute_path_in_jit(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        import jax
        from repro import obs

        @jax.jit
        def _run(x):
            obs.spans.trace_events()
            return x * 2
        """)
    assert "QL008" in _rules(findings)


def test_ql008_host_side_and_registry_probe_are_fine(tmp_path):
    # obs.registry.count is the sanctioned trace-time probe — and it
    # satisfies QL003's trace-counter requirement on serve jits
    findings = _lint(tmp_path, ("src", "repro", "serve", "m.py"), """
        import jax
        from repro.obs import metrics as obs_metrics
        from repro.obs import registry as obs_registry

        @jax.jit
        def _run(x):
            obs_registry.count("serve.m.run")
            return x * 2

        def drive(x):
            y = _run(x)
            obs_metrics.counter("calls").inc()
            return y
        """)
    assert findings == []


def test_ql008_suppression_and_non_library_code(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        import jax

        def run(x):
            def body(c):
                # quadlint: disable=QL008 -- trace-time dump, dev only
                print(c)
                return c - 1
            return jax.lax.while_loop(lambda c: c > 0, body, x)
        """)
    assert findings == []
    findings = _lint(tmp_path, ("benchmarks", "m.py"), """
        import jax
        from repro.obs import metrics as obs_metrics

        def run(x):
            def body(c):
                obs_metrics.counter("steps").inc()
                return c - 1
            return jax.lax.while_loop(lambda c: c > 0, body, x)
        """)
    assert findings == []  # QL008 is a library-code contract


# ---------------------------------------------------------------------------
# Suppressions


def test_suppression_with_reason_silences_rule(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        import numpy as np

        # quadlint: disable=QL006 -- fixture generator, determinism n/a
        x = np.random.rand(3)
        """)
    assert findings == []


def test_suppression_without_reason_is_a_finding(tmp_path):
    findings = _lint(tmp_path, ("src", "repro", "pkg", "m.py"), """
        import numpy as np

        x = np.random.rand(3)  # quadlint: disable=QL006
        """)
    # the bare directive does NOT suppress, and is itself QL000
    assert _rules(findings) == ["QL000", "QL006"]


def test_ql000_cannot_be_suppressed(tmp_path):
    findings = _lint(tmp_path, ("m.py",), """
        # quadlint: disable=QL000 -- nice try
        # quadlint: enable-everything
        """)
    assert [f.rule for f in findings] == ["QL000"]
    assert "malformed" in findings[0].message


# ---------------------------------------------------------------------------
# QL001: state-threading mutation checks (the tentpole's teeth)


def _ql001(paths=None):
    findings = run_paths(paths or [str(REPO / "src" / "repro")])
    return [f for f in findings if f.rule == "QL001"]


def test_ql001_unthreaded_quadstate_field_is_caught(monkeypatch):
    mutant = collections.namedtuple(
        "QuadState", solver_mod.QuadState._fields + ("block_basis",))
    monkeypatch.setattr(solver_mod, "QuadState", mutant)
    findings = _ql001()
    msgs = [f.message for f in findings]
    # unclaimed by the registries ...
    assert any("block_basis" in m and "not claimed" in m for m in msgs)
    # ... and every construction site now under-threads it
    assert any("omits field 'block_basis'" in m for m in msgs)


def test_ql001_dropped_registry_entry_is_caught(monkeypatch):
    monkeypatch.setattr(solver_mod, "QUADSTATE_PER_LANE", ("st", "basis"))
    findings = _ql001()
    assert any("'coeffs'" in f.message and "not claimed" in f.message
               for f in findings)


def test_ql001_coeffhistory_mutations_are_caught(monkeypatch):
    import dataclasses

    import repro.core.matfun as matfun_mod

    # dropping the writer-exclusion registry: fnidx is now unhandled
    monkeypatch.setattr(matfun_mod, "COEFF_REPLACE_EXCLUDED", ())
    findings = _ql001()
    assert any("update_coeffs" in f.message and "'fnidx'" in f.message
               for f in findings)

    # a new CoeffHistory field missing from the pytree registration
    mutant = dataclasses.make_dataclass(
        "CoeffHistory", [f.name for f in
                         dataclasses.fields(matfun_mod.CoeffHistory)]
        + ["block_buf"])
    monkeypatch.setattr(matfun_mod, "CoeffHistory", mutant)
    findings = _ql001()
    assert any("block_buf" in f.message and "register_dataclass"
               in f.message for f in findings)


def test_ql001_excluded_field_registry_is_live(monkeypatch):
    import repro.core.sharded as sharded_mod
    monkeypatch.setattr(sharded_mod, "SHARDED_STATE_EXCLUDED", ())
    findings = _ql001()
    assert any("_drive_sharded" in f.message and "'basis'" in f.message
               for f in findings)


def test_ql001_chainfactor_mutations_are_caught(monkeypatch):
    import dataclasses

    import repro.core.update as update_mod

    # dropping the writer-exclusion registry: `n` becomes an unhandled
    # field of `downdate` (which rewrites via dataclasses.replace and
    # deliberately never touches n; `extend` constructs a full
    # ChainFactor so it writes every field either way)
    monkeypatch.setattr(update_mod, "FACTOR_REPLACE_EXCLUDED", ())
    findings = _ql001()
    assert any("downdate" in f.message and "'n'" in f.message
               for f in findings)

    # a new ChainFactor field (say a rank-update cache) missing from the
    # pytree registration AND from the carry writers
    mutant = dataclasses.make_dataclass(
        "ChainFactor", [f.name for f in
                        dataclasses.fields(update_mod.ChainFactor)]
        + ["rank_cache"])
    monkeypatch.setattr(update_mod, "ChainFactor", mutant)
    findings = _ql001()
    msgs = [f.message for f in findings]
    assert any("rank_cache" in m and "register_dataclass" in m
               for m in msgs)
    assert any("rank_cache" in m and "extend" in m for m in msgs)
    assert any("rank_cache" in m and "downdate" in m for m in msgs)


def test_ql001_blockstate_mutations_are_caught(monkeypatch):
    import dataclasses

    import repro.core.block as block_mod

    # a new BlockState field (say a reorth buffer) missing from the
    # pytree registration AND from the step writer
    mutant = dataclasses.make_dataclass(
        "BlockState", [f.name for f in
                       dataclasses.fields(block_mod.BlockState)]
        + ["reorth_buf"])
    monkeypatch.setattr(block_mod, "BlockState", mutant)
    findings = _ql001()
    msgs = [f.message for f in findings]
    assert any("reorth_buf" in m and "register_dataclass" in m
               for m in msgs)
    assert any("reorth_buf" in m and "block_step" in m for m in msgs)


def test_ql001_blockstate_dropped_registry_entry_is_caught(monkeypatch):
    import repro.core.block as block_mod

    # dropping the writer-exclusion registry: r0/fnidx (init-constant
    # fields block_step deliberately never rewrites) become unhandled
    monkeypatch.setattr(block_mod, "BLOCK_REPLACE_EXCLUDED", ())
    findings = _ql001()
    msgs = [f.message for f in findings]
    assert any("block_step" in m and "'r0'" in m for m in msgs)
    assert any("block_step" in m and "'fnidx'" in m for m in msgs)


def test_ql001_round_body_delegation_credit():
    """PR 7 moved the per-substep freeze into ``_round_body``; a handler
    inherits that freeze coverage ONLY if it actually references the
    round driver — a handler that skips it must freeze for itself."""
    import ast as _ast

    from repro.analysis.contracts import _round_body_frozen

    tree = _ast.parse(textwrap.dedent("""
        def _round_body(op, stepfn):
            def substep(i, carry):
                st = tree_freeze(st1, st, frozen)
                coeffs = tree_freeze(coeffs1, coeffs, frozen)
                return carry
            return substep

        def delegating(self, state):
            round_fn = self._round_body(op, stepfn)
            return round_fn

        def freeloading(self, state):
            return state
    """))
    defs = {n.name: n for n in tree.body}
    credited = _round_body_frozen(defs["delegating"], tree)
    assert {"st", "coeffs"} <= credited
    assert _round_body_frozen(defs["freeloading"], tree) == set()


# ---------------------------------------------------------------------------
# CLI + the repo's own cleanliness (tier-1)


def test_cli_exit_codes_and_output_format(tmp_path, capsys):
    bad = _write(tmp_path, ("src", "repro", "pkg", "m.py"),
                 "import random\n")
    good = _write(tmp_path, ("src", "repro", "pkg", "ok.py"),
                  "X = 1\n")
    assert main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert re.search(r"^.+:1 QL006 ", out, re.M)
    assert "1 finding(s)" in out
    assert main([str(good)]) == 0
    assert capsys.readouterr().out == ""


def test_cli_module_entrypoint(tmp_path):
    bad = _write(tmp_path, ("src", "repro", "pkg", "m.py"),
                 "import random\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad),
         "--no-project-checks"],
        capture_output=True, text=True, env=env, cwd=str(REPO))
    assert proc.returncode == 1
    assert re.search(r":1 QL006 ", proc.stdout)


def test_repo_tree_is_clean():
    """The merged tree carries zero findings (the CI `static` job)."""
    findings = run_paths([str(REPO / "src"), str(REPO / "tests"),
                          str(REPO / "benchmarks")])
    assert findings == [], "\n".join(f.render() for f in findings)
