"""Fault tolerance: crash/resume determinism, straggler watchdog,
loss goes down, monitor integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data import DataConfig, TokenStream
from repro.models import model as M
from repro.optim import AdamW, warmup_cosine
from repro.train import LoopConfig, make_monitor, train


@pytest.fixture(scope="module")
def pieces():
    cfg = get_arch("olmo-1b").reduced()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    stream = TokenStream(dc)
    opt = AdamW(lr=warmup_cosine(1e-2, 5, 100))

    def init_state():
        params, _ = M.init_model(jax.random.key(0), cfg)
        return params, opt.init(params)

    def raw_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt_state, om = opt.update(grads, opt_state, params)
        return params, opt_state, dict(metrics, loss=loss, **om)

    return cfg, stream, init_state, jax.jit(raw_step, donate_argnums=(0, 1))


def test_loss_decreases(pieces, tmp_path):
    _, stream, init_state, step_fn = pieces
    res = train(loop_cfg=LoopConfig(total_steps=40, save_every=20),
                ckpt_dir=tmp_path, init_state=init_state, step_fn=step_fn,
                batch_fn=stream.batch_at)
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])


def test_crash_resume_is_deterministic(pieces, tmp_path):
    _, stream, init_state, step_fn = pieces
    lc = LoopConfig(total_steps=30, save_every=10)
    # uninterrupted reference
    ref = train(loop_cfg=lc, ckpt_dir=tmp_path / "ref",
                init_state=init_state, step_fn=step_fn,
                batch_fn=stream.batch_at)
    # crash at 17, resume
    with pytest.raises(RuntimeError):
        train(loop_cfg=lc, ckpt_dir=tmp_path / "cr",
              init_state=init_state, step_fn=step_fn,
              batch_fn=stream.batch_at, fail_at_step=17)
    res = train(loop_cfg=lc, ckpt_dir=tmp_path / "cr",
                init_state=init_state, step_fn=step_fn,
                batch_fn=stream.batch_at)
    assert res.resumed_from == 10
    # steps 10..30 replay identically (deterministic data + state restore)
    np.testing.assert_allclose(res.losses, ref.losses[10:], rtol=1e-5)


def test_straggler_watchdog(pieces, tmp_path):
    _, stream, init_state, step_fn = pieces
    lc = LoopConfig(total_steps=6, save_every=100,
                    step_time_budget_s=1e-9)   # everything is a straggler
    res = train(loop_cfg=lc, ckpt_dir=tmp_path, init_state=init_state,
                step_fn=step_fn, batch_fn=stream.batch_at)
    assert res.straggler_events == 6
    from repro.checkpoint import io as ckpt
    assert ckpt.latest_step(tmp_path) is not None  # early ckpts landed


def test_monitor_hook(pieces, tmp_path):
    cfg, stream, init_state, step_fn = pieces
    mon = make_monitor(M.loss_fn, cfg, per_example=2, sketch_dim=16)
    res = train(loop_cfg=LoopConfig(total_steps=10, save_every=10,
                                    monitor_every=5),
                ckpt_dir=tmp_path, init_state=init_state, step_fn=step_fn,
                batch_fn=stream.batch_at, monitor_fn=mon)
    assert len(res.monitor_log) == 2
    for _, m in res.monitor_log:
        assert m["nat_norm_lower"] <= m["nat_norm_upper"] + 1e-9
        assert m["kappa_lower"] <= m["kappa_upper"] + 1e-9
