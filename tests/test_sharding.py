"""Sharding rules + an actually-executed sharded train step on 8 forced
host devices (subprocess; tests in this process must see 1 device)."""
import subprocess
import sys
from pathlib import Path

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import Plan, spec_for_param, tp_plan


def test_spec_dedupes_mesh_axes():
    plan = tp_plan(fsdp=False)
    # MoE expert weight: expert and mlp both map to 'model'
    spec = spec_for_param(plan, ("layers", "expert", "embed", "mlp"),
                          (4, 128, 512, 2048))
    assert spec == P(None, "model", None, None)


def test_fsdp_picks_largest_free_dim():
    plan = tp_plan(fsdp=True)
    spec = spec_for_param(plan, ("layers", "expert", "embed", "mlp"),
                          (4, 128, 512, 2048))
    # mlp lost 'model' to expert; FSDP shards the largest free dim (mlp)
    assert spec == P(None, "model", None, "data")


def test_fsdp_skips_small_params():
    plan = tp_plan(fsdp=True)
    spec = spec_for_param(plan, ("embed",), (64,))
    assert spec == P(None)


def test_seq_shard_rule():
    plan = tp_plan(seq_shard=True)
    assert plan.rules["seq"] == "model"


DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "{src}")
import jax, numpy as np
import jax.numpy as jnp
from repro.configs import get_arch
from repro.models import model as M
from repro.sharding import api as shapi
from repro.launch import shapes, steps as steps_mod
from repro.launch.mesh import make_host_mesh

cfg = get_arch("{arch}").reduced()
mesh = make_host_mesh(model=2)          # (data=4, model=2)
plan = shapi.tp_plan(data_axes=("data",), model_axis="model", fsdp={fsdp})

params, axes = M.init_model(jax.random.key(0), cfg)
p_sh = shapi.param_shardings(plan, mesh, params, axes)
params = jax.tree.map(jax.device_put, params, p_sh)
opt = steps_mod.default_optimizer()
opt_state = opt.init(params)
o_sh = steps_mod._opt_shardings(mesh, plan, axes, None, p_sh)
opt_state = jax.tree.map(jax.device_put, opt_state, o_sh)

batch = shapes.make_inputs(cfg, "train", seq=32, batch=8)
b_sh = steps_mod.batch_sharding(mesh, plan, batch)
batch = jax.tree.map(jax.device_put, batch, b_sh)

fn = steps_mod.build_train_step(cfg, mesh, plan, opt, microbatches={mb})
jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
              out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
with mesh:
    p2, o2, metrics = jfn(params, opt_state, batch)
loss1 = float(metrics["loss"])
with mesh:
    p3, o3, metrics2 = jfn(p2, o2, batch)
loss2 = float(metrics2["loss"])
assert np.isfinite(loss1) and np.isfinite(loss2)
assert loss2 < loss1, (loss1, loss2)     # same batch twice -> improves

# serve path sharded
kind, specs = shapes.input_specs(cfg, "decode_32k")
print("OK", loss1, loss2)
"""


@pytest.mark.parametrize("arch,fsdp,mb", [
    ("olmo-1b", False, 1),
    ("olmo-1b", True, 2),
    ("llama4-maverick-400b-a17b", False, 1),
    ("zamba2-1.2b", False, 1),
])
def test_sharded_train_step_executes(arch, fsdp, mb, tmp_path):
    src = str(Path(__file__).resolve().parent.parent / "src")
    script = DIST_SCRIPT.format(src=src, arch=arch, fsdp=fsdp, mb=mb)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert "OK" in out.stdout, (out.stdout[-1000:], out.stderr[-3000:])
