"""Property tests of the paper's theorems (Sec. 4) on the GQL core.

Each test maps to a claim: Thm. 2 (bracketing), Thm. 4 / 6 (Radau
dominance orderings), Cor. 7 (monotonicity), Thm. 3/5 (linear rate),
Lemma 15 (exactness at i=N), and the Fig. 1(b,c) sensitivity behavior.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BIFSolver, Dense, bif_bounds_trace
from conftest import make_spd

ATOL = 1e-7


def _setup(n, kappa, seed, density=1.0):
    a = make_spd(n, kappa=kappa, seed=seed, density=density)
    w = np.linalg.eigvalsh(a)
    rng = np.random.default_rng(seed + 1)
    u = rng.standard_normal(n)
    true = u @ np.linalg.solve(a, u)
    op = Dense(jnp.asarray(a, jnp.float64))
    return op, jnp.asarray(u, jnp.float64), w, true


@given(n=st.integers(10, 60), kappa=st.floats(5.0, 5e3),
       seed=st.integers(0, 100))
def test_bracketing_thm2(n, kappa, seed):
    op, u, w, true = _setup(n, kappa, seed)
    tr = bif_bounds_trace(op, u, w[0] * 0.99, w[-1] * 1.01, num_iters=n)
    g, grr, glr, glo = [np.asarray(x) for x in tr]
    scale = abs(true) + 1.0
    assert (g <= true + ATOL * scale).all()
    assert (grr <= true + ATOL * scale).all()
    assert (glr >= true - ATOL * scale).all()
    assert (glo >= true - ATOL * scale).all()


@given(n=st.integers(10, 50), kappa=st.floats(5.0, 1e3),
       seed=st.integers(0, 100))
def test_monotone_cor7(n, kappa, seed):
    op, u, w, true = _setup(n, kappa, seed)
    tr = bif_bounds_trace(op, u, w[0] * 0.99, w[-1] * 1.01, num_iters=n)
    g, grr, glr, glo = [np.asarray(x) for x in tr]
    tol = (abs(true) + 1.0) * 1e-7
    assert (np.diff(g) >= -tol).all()
    assert (np.diff(grr) >= -tol).all()
    assert (np.diff(glr) <= tol).all()
    assert (np.diff(glo) <= tol).all()


@given(n=st.integers(10, 50), kappa=st.floats(5.0, 1e3),
       seed=st.integers(0, 100))
def test_radau_dominance_thm4_thm6(n, kappa, seed):
    op, u, w, true = _setup(n, kappa, seed)
    tr = bif_bounds_trace(op, u, w[0] * 0.99, w[-1] * 1.01, num_iters=n)
    g, grr, glr, glo = [np.asarray(x) for x in tr]
    tol = (abs(true) + 1.0) * 1e-7
    # Thm 4: g_i <= g_i^rr <= g_{i+1}
    assert (grr[:-1] >= g[:-1] - tol).all()
    assert (grr[:-1] <= g[1:] + tol).all()
    # Thm 6: g_{i+1}^lo <= g_i^lr <= g_i^lo
    assert (glr[:-1] <= glo[:-1] + tol).all()
    assert (glr[:-1] >= glo[1:] - tol).all()


@pytest.mark.parametrize("kappa", [10.0, 100.0, 1000.0])
def test_linear_rate_thm3_thm5(kappa):
    n = 80
    op, u, w, true = _setup(n, kappa, seed=7)
    tr = bif_bounds_trace(op, u, w[0] * 0.999, w[-1] * 1.001, num_iters=n)
    g, grr, glr, _ = [np.asarray(x) for x in tr]
    gN = true
    rho = (np.sqrt(kappa) - 1) / (np.sqrt(kappa) + 1)
    kplus = w[-1] / (w[0] * 0.999)
    for i in range(0, n, 5):
        bound = 2 * rho ** (i + 1)
        assert (gN - g[i]) / gN <= bound + 1e-9, (i, kappa)
        assert (gN - grr[i]) / gN <= bound + 1e-9       # Thm 5
        assert (glr[i] - gN) / gN <= 2 * kplus * rho ** (i + 1) + 1e-9


def test_exactness_lemma15():
    n = 40
    op, u, w, true = _setup(n, 50.0, seed=3)
    tr = bif_bounds_trace(op, u, w[0] * 0.99, w[-1] * 1.01,
                          num_iters=n + 5)
    g, grr, glr, glo = [np.asarray(x) for x in tr]
    for seq in (g, grr, glr, glo):
        assert abs(seq[-1] - true) / abs(true) < 1e-8


def test_sensitivity_fig1bc():
    """Conservative spectral intervals still bracket (Fig. 1 b,c)."""
    n = 60
    op, u, w, true = _setup(n, 200.0, seed=11)
    for lmn, lmx in [(w[0] * 0.1, w[-1] * 1.01),
                     (w[0] * 0.99, w[-1] * 10.0),
                     (w[0] * 0.1, w[-1] * 10.0)]:
        tr = bif_bounds_trace(op, u, lmn, lmx, num_iters=n)
        g, grr, glr, glo = [np.asarray(x) for x in tr]
        s = abs(true) + 1.0
        assert (grr <= true + 1e-7 * s).all()
        assert (glr >= true - 1e-7 * s).all()
        # Gauss ignores the interval entirely: same values regardless
        tr2 = bif_bounds_trace(op, u, w[0] * 0.99, w[-1] * 1.01,
                               num_iters=n)
        np.testing.assert_allclose(np.asarray(tr2.gauss), g, rtol=1e-10)


def test_trace_single_iteration_shapes():
    """Regression: num_iters=1 must skip the scan path and still return
    well-formed (1, ...) sequences, batched or not, with or without
    reorthogonalization."""
    n = 20
    op, u, w, true = _setup(n, 50.0, seed=1)
    for reorth in (False, True):
        tr = bif_bounds_trace(op, u, w[0] * 0.99, w[-1] * 1.01,
                              num_iters=1, reorth=reorth)
        for seq in tr:
            assert seq.shape == (1,)
        assert float(tr.radau_lower[0]) <= true + 1e-7 * (abs(true) + 1)
        assert float(tr.radau_upper[0]) >= true - 1e-7 * (abs(true) + 1)
        # the i=1 row must agree with the first row of a longer trace
        tr2 = bif_bounds_trace(op, u, w[0] * 0.99, w[-1] * 1.01,
                               num_iters=5, reorth=reorth)
        for s1, s5 in zip(tr, tr2):
            np.testing.assert_array_equal(np.asarray(s1[0]),
                                          np.asarray(s5[0]))
    # batched lanes
    ub = jnp.stack([u, 2.0 * u])
    opb = Dense(jnp.broadcast_to(op.a, (2,) + op.a.shape))
    trb = bif_bounds_trace(opb, ub, w[0] * 0.99, w[-1] * 1.01, num_iters=1)
    for seq in trb:
        assert seq.shape == (1, 2)
    with pytest.raises(ValueError, match="num_iters"):
        bif_bounds_trace(op, u, w[0] * 0.99, w[-1] * 1.01, num_iters=0)


def test_adaptive_bounds_batched():
    n = 50
    a = make_spd(n, kappa=300.0, seed=5)
    w = np.linalg.eigvalsh(a)
    rng = np.random.default_rng(6)
    u = rng.standard_normal((8, n))
    true = np.einsum("bi,bi->b", u, np.linalg.solve(a, u.T).T)
    op = Dense(jnp.broadcast_to(jnp.asarray(a), (8, n, n)))
    res = BIFSolver.create(max_iters=n + 2, rtol=1e-3).solve(
        op, jnp.asarray(u), lam_min=w[0] * 0.99, lam_max=w[-1] * 1.01)
    lo, hi = np.asarray(res.lower), np.asarray(res.upper)
    assert (lo <= true + 1e-7).all() and (hi >= true - 1e-7).all()
    assert ((hi - lo) <= 1e-3 * np.abs(lo) + 1e-9).all()
    assert np.asarray(res.converged).all()
    assert (np.asarray(res.iterations) < n).all()   # early exit happened


def test_reorthogonalization_float32():
    """Sec. 5.4: full reorth keeps f32 bounds sane on ill-conditioned A."""
    n = 80
    a = make_spd(n, kappa=1e4, seed=9)
    w = np.linalg.eigvalsh(a)
    u = np.random.default_rng(2).standard_normal(n)
    true = u @ np.linalg.solve(a, u)
    op = Dense(jnp.asarray(a, jnp.float32))
    tr = bif_bounds_trace(op, jnp.asarray(u, jnp.float32),
                          float(w[0] * 0.99), float(w[-1] * 1.01),
                          num_iters=60, reorth=True)
    grr = np.asarray(tr.radau_lower)
    glr = np.asarray(tr.radau_upper)
    # f32 + reorth: bounds should still (loosely) bracket
    assert grr[-1] <= true * 1.05
    assert glr[-1] >= true * 0.95
