"""Data pipeline, DPP selection, compression, serving, KV select,
spectrum, preconditioning, configs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs import get_arch, list_archs
from repro.core import BIFSolver, Dense, lanczos_extremal
from repro.data import (DataConfig, DPPBatchStream, DPPSelector,
                        TokenStream, density, graph_laplacian, rbf_kernel)
from repro.models import model as M
from repro.optim import compression
from repro.serve import Engine, Request, rank_blocks, select_diverse_blocks
from conftest import make_spd


# ---------------------------------------------------------------- data
def test_stream_deterministic_and_host_disjoint():
    dc = DataConfig(vocab=100, seq_len=16, global_batch=4)
    s0 = TokenStream(dc, host_id=0, num_hosts=2)
    s1 = TokenStream(dc, host_id=1, num_hosts=2)
    a, b = s0.batch_at(3), s0.batch_at(3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(s0.batch_at(3)["tokens"]),
                              np.asarray(s1.batch_at(3)["tokens"]))
    assert int(a["tokens"].max()) < 100
    # labels are next-token shifted
    full = s0.batch_at(0)
    assert full["tokens"].shape == (2, 16)


def test_kernel_builders_are_pd_and_sparse():
    k = rbf_kernel(80, sigma=0.3)
    assert density(k) < 0.9
    assert np.linalg.eigvalsh(k)[0] > 0
    lap = graph_laplacian(100, mean_degree=8)
    assert density(lap) < 0.2
    assert np.linalg.eigvalsh(lap)[0] > 0


def test_dpp_batch_selection():
    dc = DataConfig(vocab=500, seq_len=24, global_batch=4, selector="dpp")
    stream = DPPBatchStream(TokenStream(dc),
                            DPPSelector(pool_factor=3, steps_per_item=3))
    b = stream.batch_at(0)
    assert b["tokens"].shape == (4, 24)
    st = stream.selector.last_stats
    assert st["uncertified"] == 0
    assert st["quad_iterations"] > 0


# ---------------------------------------------------------- compression
def test_quantize_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = compression.quantize_int8(x)
    err = np.abs(np.asarray(compression.dequantize(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_psum_converges():
    """With EF, repeated compressed reductions track the true mean."""
    mesh = jax.make_mesh((1,), ("d",))
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(256), jnp.float32)

    try:
        from jax import shard_map
    except ImportError:  # older jax exposes it under experimental
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def step(gg, res):
        return compression.compressed_psum(gg, "d", res)

    f = shard_map(step, mesh=mesh, in_specs=(P(), P()),
                  out_specs=(P(), P()))
    res = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(20):
        out, res = f(g, res)
        acc = acc + out
    # average of EF-compressed reductions converges to the true value
    np.testing.assert_allclose(np.asarray(acc / 20), np.asarray(g),
                               atol=2e-3)


# -------------------------------------------------------------- serving
def test_engine_greedy_matches_manual():
    cfg = get_arch("stablelm-1.6b").reduced()
    params, _ = M.init_model(jax.random.key(0), cfg)
    eng = Engine(cfg, params, max_batch=2, max_seq=64)
    prompt = np.arange(5, 13, dtype=np.int32)
    [r] = eng.generate([Request(prompt=prompt, max_new_tokens=4)])
    # manual greedy decode
    caches = M.make_caches(cfg, 1, 64, jnp.float32)
    caches, logits = M.prefill(cfg, params, {"tokens": prompt[None]},
                               caches)
    toks = []
    tok = int(jnp.argmax(logits[0, -1]))
    toks.append(tok)
    for i in range(3):
        dec = {"tokens": jnp.asarray([[tok]], jnp.int32),
               "position": jnp.asarray([len(prompt) + i], jnp.int32)}
        caches, logits = M.decode_step(cfg, params, caches, dec)
        tok = int(jnp.argmax(logits[0, -1]))
        toks.append(tok)
    assert r.out_tokens.tolist() == toks


def test_kv_select_diversity():
    rng = np.random.default_rng(0)
    # two clusters of keys: diverse selection should cover both
    c1 = rng.standard_normal((512, 16)) * 0.05 + 1.0
    c2 = rng.standard_normal((512, 16)) * 0.05 - 1.0
    keys = np.concatenate([c1, c2]).astype(np.float32)
    mask, stats = select_diverse_blocks(keys, block=64)
    assert stats["uncertified"] == 0
    half = len(mask) // 2
    assert mask[:half].sum() >= 1 and mask[half:].sum() >= 1


def test_kv_rank_blocks_flags_near_duplicate_as_redundant():
    rng = np.random.default_rng(3)
    block, n, d = 4, 6, 8
    dirs = rng.standard_normal((n, d))
    dirs[-1] = dirs[0] + 0.01 * rng.standard_normal(d)  # near-duplicate pair
    keys = np.repeat(dirs, block, axis=0).astype(np.float32)
    keys += 0.001 * rng.standard_normal(keys.shape).astype(np.float32)
    order, stats = rank_blocks(keys, block=block, max_batch=4)
    # one of the duplicated pair must rank most redundant, and the pair's
    # leverage scores must clearly separate from the distinct blocks'
    assert order[0] in (0, n - 1)
    mids = np.array([0.5 * (lo + hi) for lo, hi in stats["brackets"]])
    rest = [i for i in range(1, n - 1)]
    assert min(mids[0], mids[-1]) > mids[rest].max() + 0.1


# ---------------------------------------------- spectrum / preconditioning
@given(seed=st.integers(0, 50), kappa=st.floats(5.0, 1e4))
def test_lanczos_extremal_brackets(seed, kappa):
    n = 40
    a = make_spd(n, kappa=kappa, seed=seed)
    w = np.linalg.eigvalsh(a)
    probe = np.random.default_rng(seed).standard_normal(n)
    est = lanczos_extremal(Dense(jnp.asarray(a)), jnp.asarray(probe),
                           num_iters=min(n, 24))
    assert float(est.lam_max) >= w[-1] * (1 - 1e-6)
    assert float(est.lam_min) <= w[0] + 1e-6
    assert float(est.lam_min) > 0


def test_preconditioning_reduces_iterations():
    """Sec. 5.4: Jacobi transform cuts iterations on badly scaled A."""
    n = 100
    rng = np.random.default_rng(0)
    d = np.geomspace(1e-3, 1e3, n)
    base = make_spd(n, kappa=10.0, seed=1)
    a = np.diag(np.sqrt(d)) @ base @ np.diag(np.sqrt(d))
    w = np.linalg.eigvalsh(a)
    u = rng.standard_normal(n)
    true = u @ np.linalg.solve(a, u)
    plain = BIFSolver.create(max_iters=n, rtol=1e-4).solve(
        Dense(jnp.asarray(a)), jnp.asarray(u),
        lam_min=float(w[0] * 0.99), lam_max=float(w[-1] * 1.01))
    pre = BIFSolver.create(max_iters=n, rtol=1e-4, precondition="jacobi",
                           spectrum="lanczos").solve(
        Dense(jnp.asarray(a)), jnp.asarray(u))
    assert int(pre.iterations) < int(plain.iterations)
    assert float(pre.lower) <= true * 1.001
    assert float(pre.upper) >= true * 0.999


# -------------------------------------------------------------- configs
def test_registry_complete():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("name,target_b", [
    ("llama3-405b", 405), ("command-r-plus-104b", 104),
    ("arctic-480b", 480), ("llama4-maverick-400b-a17b", 400),
    ("falcon-mamba-7b", 7), ("olmo-1b", 1.2), ("stablelm-1.6b", 1.6),
    ("zamba2-1.2b", 1.2), ("qwen2-vl-2b", 2), ("whisper-medium", 0.77)])
def test_param_counts_match_names(name, target_b):
    c = get_arch(name)
    got = c.param_count() / 1e9
    assert 0.6 * target_b <= got <= 1.35 * target_b, (name, got)


def test_reduced_preserves_family():
    for n in list_archs():
        c = get_arch(n)
        r = c.reduced()
        assert r.family == c.family
        assert r.d_model <= 64
        if c.moe_experts:
            assert 0 < r.moe_experts <= 4
