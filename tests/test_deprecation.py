"""The legacy shims warn (DeprecationWarning) exactly once each."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bif_bounds, bif_refine_until, deprecation, \
    judge_double_greedy, judge_kdpp_swap, judge_threshold, \
    preconditioned_bif_bounds, Dense
from conftest import make_spd


@pytest.fixture
def prob():
    n = 16
    a = make_spd(n, kappa=30.0, seed=0)
    w = np.linalg.eigvalsh(a)
    u = jnp.asarray(np.random.default_rng(1).standard_normal(n))
    return Dense(jnp.asarray(a)), u, float(w[0] * 0.99), float(w[-1] * 1.01)


def _calls(prob):
    op, u, lmn, lmx = prob
    t = jnp.asarray(0.5)
    p = jnp.asarray(0.5)
    decided = lambda lo, hi: (t < lo) | (t >= hi)  # noqa: E731
    return {
        "bif_bounds": lambda: bif_bounds(op, u, lmn, lmx, max_iters=6),
        "bif_refine_until": lambda: bif_refine_until(
            op, u, lmn, lmx, max_iters=6, decided_fn=decided),
        "judge_threshold": lambda: judge_threshold(
            op, u, t, lmn, lmx, max_iters=6),
        "judge_kdpp_swap": lambda: judge_kdpp_swap(
            op, u, op, u, t, p, lmn, lmx, max_iters=6),
        "judge_double_greedy": lambda: judge_double_greedy(
            op, u, op, u, t, p, lmn, lmx, max_iters=6),
        "preconditioned_bif_bounds": lambda: preconditioned_bif_bounds(
            op, u, max_iters=6),
    }


@pytest.mark.parametrize("name", ["bif_bounds", "bif_refine_until",
                                  "judge_threshold", "judge_kdpp_swap",
                                  "judge_double_greedy",
                                  "preconditioned_bif_bounds"])
def test_shim_warns_deprecation_once(prob, name):
    call = _calls(prob)[name]
    deprecation.reset()
    with pytest.warns(DeprecationWarning, match=name):
        call()
    # second call is silent: once per process, not per call site
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        call()


def test_each_shim_fires_exactly_once_per_process(prob):
    """The whole shim surface, called twice each in one process, emits
    EXACTLY one DeprecationWarning per shim — no repeats, no cross-shim
    suppression (the removal-schedule contract of DESIGN.md Sec. 5)."""
    calls = _calls(prob)
    deprecation.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for call in calls.values():
            call()
        for call in calls.values():
            call()
    dep = [str(w.message) for w in rec
           if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == len(calls), dep
    for name in calls:
        # '.<name> is deprecated' is unambiguous: 'bif_bounds' alone
        # would also match 'preconditioned_bif_bounds'
        hits = sum(f".{name} is deprecated" in msg for msg in dep)
        assert hits == 1, (name, dep)


def test_internal_callers_stay_silent(prob):
    """BIFSolver methods and the applications never trip the shims."""
    from repro.core import BIFSolver, greedy_map, run_double_greedy, \
        sample_dpp
    import jax

    op, u, lmn, lmx = prob
    deprecation.reset()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        s = BIFSolver.create(max_iters=6)
        s.solve(op, u, lam_min=lmn, lam_max=lmx)
        s.judge_threshold(op, u, jnp.asarray(0.5), lam_min=lmn, lam_max=lmx)
        sample_dpp(op, jax.random.key(0), jnp.zeros((op.n,)), 3, lmn, lmx,
                   max_iters=6)
        greedy_map(op, 2, lmn, lmx, max_iters=6)
        run_double_greedy(op, jax.random.key(0), lmn, lmx, max_iters=6)
