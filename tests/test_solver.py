"""BIFSolver redesign tests.

1. Parity: ``BIFSolver`` must reproduce the *pre-refactor*
   implementations bit-for-bit — same brackets, same decisions, same
   iteration counts — on Dense and SparseCOO operators. The reference
   loops below are verbatim copies of the pre-redesign ``bounds.py`` /
   ``judge.py`` drivers (whose deprecation shims were removed on
   DESIGN.md Sec. 5's schedule).
2. Backend consistency: ``backend='pallas'`` (fused kernel) must agree
   with ``backend='reference'`` (the ``recurrence_update`` oracle).
3. Config plumbing: spectrum estimation and Jacobi preconditioning go
   through the same solve() entry point.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BIFSolver, Dense, Masked, SolverConfig, \
    sparse_from_dense, tree_freeze
from repro.core import gql as _gql
from conftest import make_spd


# ---------------------------------------------------------------------------
# Pre-refactor reference implementations (copied from the old bounds.py /
# judge.py; the freeze helper is inlined as those modules had it).


def _legacy_freeze(st_new, st_old, frozen):
    return jax.tree.map(
        lambda new, old: jnp.where(
            jnp.reshape(frozen, frozen.shape + (1,) * (new.ndim - frozen.ndim)),
            old, new),
        st_new, st_old)


def legacy_bif_bounds(op, u, lam_min, lam_max, *, max_iters, rtol=1e-2,
                      atol=0.0):
    def needs_more(st):
        gap = (st.g_lr - st.g_rr) * st.u_norm_sq
        tight = gap <= jnp.maximum(atol, rtol * jnp.abs(_gql.lower_bound(st)))
        return ~st.done & ~tight & (st.it < max_iters)

    st = _gql.gql_init(op, u, lam_min, lam_max)

    def cond(st):
        return jnp.any(needs_more(st))

    def body(st):
        st1 = _gql.gql_step(op, st, lam_min, lam_max)
        return _legacy_freeze(st1, st, ~needs_more(st))

    st = jax.lax.while_loop(cond, body, st)
    gap = (st.g_lr - st.g_rr) * st.u_norm_sq
    conv = st.done | (gap <= jnp.maximum(atol,
                                         rtol * jnp.abs(_gql.lower_bound(st))))
    return (_gql.lower_bound(st), _gql.upper_bound(st), st.it, conv)


def legacy_refine_until(op, u, lam_min, lam_max, *, max_iters, decided_fn):
    st = _gql.gql_init(op, u, lam_min, lam_max)

    def needs_more(st):
        dec = decided_fn(_gql.lower_bound(st), _gql.upper_bound(st))
        return ~st.done & ~dec & (st.it < max_iters)

    def cond(st):
        return jnp.any(needs_more(st))

    def body(st):
        st1 = _gql.gql_step(op, st, lam_min, lam_max)
        return _legacy_freeze(st1, st, ~needs_more(st))

    return jax.lax.while_loop(cond, body, st)


def legacy_judge_threshold(op, u, t, lam_min, lam_max, *, max_iters):
    st = _gql.gql_init(op, u, lam_min, lam_max)

    def resolved(st):
        return (t < _gql.lower_bound(st)) | (t >= _gql.upper_bound(st))

    def needs_more(st):
        return ~st.done & ~resolved(st) & (st.it < max_iters)

    def cond(st):
        return jnp.any(needs_more(st))

    def body(st):
        st1 = _gql.gql_step(op, st, lam_min, lam_max)
        return _legacy_freeze(st1, st, ~needs_more(st))

    st = jax.lax.while_loop(cond, body, st)
    lo, hi = _gql.lower_bound(st), _gql.upper_bound(st)
    decision = jnp.where(t < lo, True,
                         jnp.where(t >= hi, False, t < 0.5 * (lo + hi)))
    return decision, resolved(st), st.it


def legacy_judge_kdpp_swap(op_a, u, op_b, v, t, p, lam_min, lam_max, *,
                           max_iters):
    sa = _gql.gql_init(op_a, u, lam_min, lam_max)
    sb = _gql.gql_init(op_b, v, lam_min, lam_max)
    st = (sa, sb)

    def bounds(st):
        lo = p * _gql.lower_bound(st[1]) - _gql.upper_bound(st[0])
        hi = p * _gql.upper_bound(st[1]) - _gql.lower_bound(st[0])
        return lo, hi

    def resolved(st):
        lo, hi = bounds(st)
        return (t < lo) | (t >= hi)

    def exhausted(st):
        return (st[0].done | (st[0].it >= max_iters)) & \
               (st[1].done | (st[1].it >= max_iters))

    def needs_more(st):
        return ~resolved(st) & ~exhausted(st)

    def cond(st):
        return jnp.any(needs_more(st))

    def body(st):
        d_u = _gql.gap(st[0])
        d_v = _gql.gap(st[1])
        pick_u = (d_u > p * d_v) & ~st[0].done & (st[0].it < max_iters)
        pick_u = pick_u | (st[1].done | (st[1].it >= max_iters))
        a1 = _gql.gql_step(op_a, st[0], lam_min, lam_max)
        b1 = _gql.gql_step(op_b, st[1], lam_min, lam_max)
        nm = needs_more(st)
        return (_legacy_freeze(a1, st[0], ~(nm & pick_u)),
                _legacy_freeze(b1, st[1], ~(nm & ~pick_u)))

    st = jax.lax.while_loop(cond, body, st)
    lo, hi = bounds(st)
    decision = jnp.where(t < lo, True,
                         jnp.where(t >= hi, False, t < 0.5 * (lo + hi)))
    return decision, resolved(st), st[0].it + st[1].it


def _legacy_log_gain_bounds(t, lo_bif, hi_bif):
    big_neg = jnp.asarray(-1e30, lo_bif.dtype)
    arg_hi = t - lo_bif
    arg_lo = t - hi_bif
    hi = jnp.where(arg_hi > 0, jnp.log(jnp.maximum(arg_hi, 1e-30)), big_neg)
    lo = jnp.where(arg_lo > 0, jnp.log(jnp.maximum(arg_lo, 1e-30)), big_neg)
    return lo, hi


def legacy_judge_double_greedy(op_x, u, op_y, v, t, p, lam_min, lam_max, *,
                               max_iters):
    st = (_gql.gql_init(op_x, u, lam_min, lam_max),
          _gql.gql_init(op_y, v, lam_min, lam_max))

    def gain_bounds(st):
        lo_p, hi_p = _legacy_log_gain_bounds(t, _gql.lower_bound(st[0]),
                                             _gql.upper_bound(st[0]))
        lo_log_y, hi_log_y = _legacy_log_gain_bounds(
            t, _gql.lower_bound(st[1]), _gql.upper_bound(st[1]))
        lo_m, hi_m = -hi_log_y, -lo_log_y
        relu = lambda x: jnp.maximum(x, 0.0)  # noqa: E731
        return relu(lo_p), relu(hi_p), relu(lo_m), relu(hi_m)

    def resolved(st):
        lo_p, hi_p, lo_m, hi_m = gain_bounds(st)
        add_safe = p * hi_m <= (1 - p) * lo_p
        rem_safe = p * lo_m > (1 - p) * hi_p
        return add_safe | rem_safe

    def exhausted(st):
        return (st[0].done | (st[0].it >= max_iters)) & \
               (st[1].done | (st[1].it >= max_iters))

    def needs_more(st):
        return ~resolved(st) & ~exhausted(st)

    def cond(st):
        return jnp.any(needs_more(st))

    def body(st):
        lo_p, hi_p, lo_m, hi_m = gain_bounds(st)
        pick_x = ((1 - p) * (hi_p - lo_p) >= p * (hi_m - lo_m))
        pick_x = (pick_x & ~st[0].done & (st[0].it < max_iters)) | \
                 (st[1].done | (st[1].it >= max_iters))
        a1 = _gql.gql_step(op_x, st[0], lam_min, lam_max)
        b1 = _gql.gql_step(op_y, st[1], lam_min, lam_max)
        nm = needs_more(st)
        return (_legacy_freeze(a1, st[0], ~(nm & pick_x)),
                _legacy_freeze(b1, st[1], ~(nm & ~pick_x)))

    st = jax.lax.while_loop(cond, body, st)
    lo_p, hi_p, lo_m, hi_m = gain_bounds(st)
    add_safe = p * hi_m <= (1 - p) * lo_p
    rem_safe = p * lo_m > (1 - p) * hi_p
    mid = (p * 0.5 * (lo_m + hi_m)) <= ((1 - p) * 0.5 * (lo_p + hi_p))
    decision = jnp.where(add_safe, True, jnp.where(rem_safe, False, mid))
    return decision, add_safe | rem_safe, st[0].it + st[1].it


# ---------------------------------------------------------------------------
# Fixtures


def _problem(n=40, kappa=200.0, seed=0, density=1.0):
    a = make_spd(n, kappa=kappa, seed=seed, density=density)
    w = np.linalg.eigvalsh(a)
    u = np.random.default_rng(seed + 1).standard_normal(n)
    true = u @ np.linalg.solve(a, u)
    return a, jnp.asarray(u), float(w[0] * 0.99), float(w[-1] * 1.01), true


def _operators(a):
    """The same matrix as Dense and as padded-COO sparse."""
    return [Dense(jnp.asarray(a)), sparse_from_dense(a)]


# ---------------------------------------------------------------------------
# 1. Solver-vs-legacy parity


@pytest.mark.parametrize("op_kind", ["dense", "sparse"])
@pytest.mark.parametrize("seed", [0, 3, 11])
def test_bif_bounds_parity(op_kind, seed):
    a, u, lmn, lmx, _ = _problem(seed=seed, density=0.3)
    op = _operators(a)[op_kind == "sparse"]
    got = BIFSolver.create(max_iters=45, rtol=1e-3).solve(
        op, u, lam_min=lmn, lam_max=lmx)
    lo, hi, it, conv = legacy_bif_bounds(op, u, lmn, lmx, max_iters=45,
                                         rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(got.lower), np.asarray(lo))
    np.testing.assert_array_equal(np.asarray(got.upper), np.asarray(hi))
    assert int(got.iterations) == int(it)
    assert bool(got.converged) == bool(conv)


@pytest.mark.parametrize("seed", [0, 5])
def test_bif_bounds_parity_batched(seed):
    n = 36
    a = make_spd(n, kappa=150.0, seed=seed)
    w = np.linalg.eigvalsh(a)
    u = jnp.asarray(np.random.default_rng(seed).standard_normal((6, n)))
    op = Dense(jnp.broadcast_to(jnp.asarray(a), (6, n, n)))
    got = BIFSolver.create(max_iters=n + 2, rtol=1e-4).solve(
        op, u, lam_min=w[0] * 0.99, lam_max=w[-1] * 1.01)
    lo, hi, it, conv = legacy_bif_bounds(op, u, w[0] * 0.99, w[-1] * 1.01,
                                         max_iters=n + 2, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(got.lower), np.asarray(lo))
    np.testing.assert_array_equal(np.asarray(got.upper), np.asarray(hi))
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  np.asarray(it))
    np.testing.assert_array_equal(np.asarray(got.converged),
                                  np.asarray(conv))


@pytest.mark.parametrize("op_kind", ["dense", "sparse"])
def test_refine_until_parity(op_kind):
    a, u, lmn, lmx, true = _problem(seed=2, density=0.4)
    op = _operators(a)[op_kind == "sparse"]
    t = jnp.asarray(true * 1.1)

    def decided(lo, hi):
        return (t < lo) | (t >= hi)

    st_new = BIFSolver.create(max_iters=45).solve(
        op, u, decide=decided, lam_min=lmn, lam_max=lmx).state.st
    st_old = legacy_refine_until(op, u, lmn, lmx, max_iters=45,
                                 decided_fn=decided)
    assert int(st_new.it) == int(st_old.it)
    np.testing.assert_array_equal(np.asarray(_gql.lower_bound(st_new)),
                                  np.asarray(_gql.lower_bound(st_old)))
    np.testing.assert_array_equal(np.asarray(_gql.upper_bound(st_new)),
                                  np.asarray(_gql.upper_bound(st_old)))


@pytest.mark.parametrize("op_kind", ["dense", "sparse"])
@pytest.mark.parametrize("factor", [0.5, 0.999, 1.001, 2.0])
def test_judge_threshold_parity(op_kind, factor):
    a, u, lmn, lmx, true = _problem(seed=7, density=0.5)
    op = _operators(a)[op_kind == "sparse"]
    t = jnp.asarray(true * factor)
    got = BIFSolver.create(max_iters=45).judge_threshold(
        op, u, t, lam_min=lmn, lam_max=lmx)
    dec, cert, it = legacy_judge_threshold(op, u, t, lmn, lmx, max_iters=45)
    assert bool(got.decision) == bool(dec)
    assert bool(got.certified) == bool(cert)
    assert int(got.iterations) == int(it)


@pytest.mark.parametrize("seed", [0, 4, 9])
def test_judge_kdpp_swap_parity(seed):
    n = 30
    a = make_spd(n, kappa=100.0, seed=seed)
    w = np.linalg.eigvalsh(a)
    rng = np.random.default_rng(seed + 7)
    mask = (rng.random(n) < 0.5).astype(np.float64)
    mask[:2] = [1.0, 0.0]
    u = jnp.asarray(rng.standard_normal(n) * mask)
    v = jnp.asarray(rng.standard_normal(n) * mask)
    p = jnp.asarray(rng.uniform(0.05, 0.95))
    t = jnp.asarray(rng.standard_normal() * 0.1)
    op = Masked(Dense(jnp.asarray(a)), jnp.asarray(mask))
    got = BIFSolver.create(max_iters=n + 2).judge_kdpp_swap(
        op, u, op, v, t, p, lam_min=w[0] * 0.99, lam_max=w[-1] * 1.01)
    dec, cert, it = legacy_judge_kdpp_swap(op, u, op, v, t, p, w[0] * 0.99,
                                           w[-1] * 1.01, max_iters=n + 2)
    assert bool(got.decision) == bool(dec)
    assert bool(got.certified) == bool(cert)
    assert int(got.iterations) == int(it)


@pytest.mark.parametrize("seed", [0, 4, 9])
def test_judge_double_greedy_parity(seed):
    n = 24
    a = make_spd(n, kappa=50.0, seed=seed)
    d = np.sqrt(np.diag(a))
    a = a / np.outer(d, d) + 0.05 * np.eye(n)
    w = np.linalg.eigvalsh(a)
    rng = np.random.default_rng(seed + 3)
    x_mask = np.zeros(n)
    x_mask[rng.choice(n, 5, replace=False)] = 1.0
    y_mask = np.ones(n)
    i = int(np.argmax(x_mask == 0))
    x_mask[i] = 0.0
    y_mask[i] = 0.0
    col = a[:, i]
    u = jnp.asarray(col * x_mask)
    v = jnp.asarray(col * y_mask)
    t = jnp.asarray(a[i, i])
    p = jnp.asarray(rng.uniform(0.05, 0.95))
    op_x = Masked(Dense(jnp.asarray(a)), jnp.asarray(x_mask))
    op_y = Masked(Dense(jnp.asarray(a)), jnp.asarray(y_mask))
    got = BIFSolver.create(max_iters=n + 2).judge_double_greedy(
        op_x, u, op_y, v, t, p, lam_min=w[0] * 0.99, lam_max=w[-1] * 1.01)
    dec, cert, it = legacy_judge_double_greedy(
        op_x, u, op_y, v, t, p, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2)
    assert bool(got.decision) == bool(dec)
    assert bool(got.certified) == bool(cert)
    assert int(got.iterations) == int(it)


# ---------------------------------------------------------------------------
# 2. Backend consistency: pallas kernel vs reference recurrence


@pytest.mark.parametrize("op_kind", ["dense", "sparse"])
def test_backend_pallas_matches_reference(op_kind):
    a, u, lmn, lmx, true = _problem(n=48, seed=1, density=0.4)
    op = _operators(a)[op_kind == "sparse"]
    ref = BIFSolver(SolverConfig(max_iters=50, rtol=1e-4))
    pls = ref.replace(backend="pallas", pallas_interpret=True)
    r_ref = ref.solve(op, u, lam_min=lmn, lam_max=lmx)
    r_pls = pls.solve(op, u, lam_min=lmn, lam_max=lmx)
    assert int(r_ref.iterations) == int(r_pls.iterations)
    np.testing.assert_allclose(np.asarray(r_pls.lower),
                               np.asarray(r_ref.lower), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(r_pls.upper),
                               np.asarray(r_ref.upper), rtol=1e-10)
    assert float(r_pls.lower) <= true * 1.001
    assert float(r_pls.upper) >= true * 0.999


def test_backend_pallas_matches_reference_batched():
    n = 40
    a = make_spd(n, kappa=120.0, seed=6)
    w = np.linalg.eigvalsh(a)
    u = jnp.asarray(np.random.default_rng(2).standard_normal((5, n)))
    op = Dense(jnp.broadcast_to(jnp.asarray(a), (5, n, n)))
    ref = BIFSolver.create(max_iters=n + 2, rtol=1e-4)
    pls = ref.replace(backend="pallas", pallas_interpret=True)
    r_ref = ref.solve(op, u, lam_min=w[0] * 0.99, lam_max=w[-1] * 1.01)
    r_pls = pls.solve(op, u, lam_min=w[0] * 0.99, lam_max=w[-1] * 1.01)
    np.testing.assert_array_equal(np.asarray(r_ref.iterations),
                                  np.asarray(r_pls.iterations))
    np.testing.assert_allclose(np.asarray(r_pls.lower),
                               np.asarray(r_ref.lower), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(r_pls.upper),
                               np.asarray(r_ref.upper), rtol=1e-10)


def test_backend_pallas_trace_matches_oracle():
    """The trace path wires the kernel against the recurrence_update
    oracle, mirroring tests/test_kernels.py at the API level."""
    a, u, lmn, lmx, _ = _problem(n=32, seed=8)
    op = Dense(jnp.asarray(a))
    ref = BIFSolver.create(max_iters=32)
    tr_ref = ref.trace(op, u, 20, lam_min=lmn, lam_max=lmx)
    tr_pls = ref.replace(backend="pallas", pallas_interpret=True).trace(
        op, u, 20, lam_min=lmn, lam_max=lmx)
    for x, y in zip(tr_ref, tr_pls):
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-10)


# ---------------------------------------------------------------------------
# 3. Config plumbing


def test_spectrum_modes_bracket_truth():
    a, u, _, _, true = _problem(n=40, seed=5)
    op = Dense(jnp.asarray(a))
    for mode in ("gershgorin", "lanczos"):
        res = BIFSolver.create(max_iters=60, rtol=1e-4,
                               spectrum=mode).solve(op, u)
        assert float(res.lower) <= true * 1.0001, mode
        assert float(res.upper) >= true * 0.9999, mode


def test_spectrum_explicit_requires_interval():
    a, u, _, _, _ = _problem(n=20, seed=5)
    with pytest.raises(ValueError, match="explicit"):
        BIFSolver.create(max_iters=10).solve(Dense(jnp.asarray(a)), u)


def test_jacobi_precondition_brackets_truth():
    a, u, _, _, true = _problem(n=40, seed=12)
    op = Dense(jnp.asarray(a))
    res = BIFSolver.create(max_iters=60, rtol=1e-4, precondition="jacobi",
                           spectrum="lanczos").solve(op, u)
    # Sec. 5.4: the Jacobi transform leaves u^T A^-1 u invariant, so the
    # bracket still contains the untransformed truth.
    assert float(res.lower) <= true * 1.0001
    assert float(res.upper) >= true * 0.9999
    assert bool(res.converged)


def test_solver_is_jit_vmap_safe():
    a, u, lmn, lmx, _ = _problem(n=24, seed=4)
    op = Dense(jnp.asarray(a))
    solver = BIFSolver.create(max_iters=26, rtol=1e-3)

    @jax.jit
    def run(x):
        return solver.solve(op, x, lam_min=lmn, lam_max=lmx).lower

    eager = float(solver.solve(op, u, lam_min=lmn, lam_max=lmx).lower)
    assert float(run(u)) == pytest.approx(eager, rel=1e-12)
    # static hashing: two configured solvers compare/hash by value
    assert BIFSolver.create(max_iters=26, rtol=1e-3) == solver


def test_pair_driver_validates_config_and_estimates_spectrum():
    a, u, lmn, lmx, _ = _problem(n=20, seed=3)
    op = Dense(jnp.asarray(a))
    v = jnp.asarray(np.random.default_rng(9).standard_normal(20))
    t, p = jnp.asarray(0.1), jnp.asarray(0.5)
    # unsupported configs fail loudly on every pair entry point,
    # including the generic public solve_pair
    for bad in (dict(precondition="jacobi", spectrum="lanczos"),
                dict(reorth=True)):
        s = BIFSolver.create(max_iters=10, **bad)
        with pytest.raises(NotImplementedError):
            s.judge_kdpp_swap(op, u, op, v, t, p, lam_min=lmn, lam_max=lmx)
        with pytest.raises(NotImplementedError):
            s.solve_pair(op, u, op, v,
                         resolved=lambda st: jnp.asarray(True),
                         pick_a=lambda st: jnp.asarray(True),
                         lam_min=lmn, lam_max=lmx)
    # missing interval + estimating spectrum mode works on the pair path
    # (a far-off threshold must certify quickly)
    s = BIFSolver.create(max_iters=22, spectrum="lanczos")
    res = s.judge_kdpp_swap(op, u, op, v, jnp.asarray(-1e8), p)
    assert bool(res.certified) and bool(res.decision)
    # explicit-spectrum mode without an interval stays a clear error
    with pytest.raises(ValueError, match="explicit"):
        BIFSolver.create(max_iters=10).judge_kdpp_swap(op, u, op, v, t, p)


def test_invalid_config_rejected():
    with pytest.raises(ValueError):
        SolverConfig(spectrum="eigh")
    with pytest.raises(ValueError):
        SolverConfig(precondition="ssor")
    with pytest.raises(ValueError):
        SolverConfig(backend="cuda")
    with pytest.raises(ValueError):
        SolverConfig(max_iters=0)


def test_tree_freeze_broadcasts_trailing_dims():
    new = {"a": jnp.ones((3, 4)), "b": jnp.ones((3,))}
    old = {"a": jnp.zeros((3, 4)), "b": jnp.zeros((3,))}
    frozen = jnp.asarray([True, False, True])
    out = tree_freeze(new, old, frozen)
    np.testing.assert_array_equal(np.asarray(out["a"][:, 0]), [0, 1, 0])
    np.testing.assert_array_equal(np.asarray(out["b"]), [0, 1, 0])


def test_solve_result_reports_rich_stats():
    a, u, lmn, lmx, true = _problem(n=30, seed=13)
    res = BIFSolver.create(max_iters=32, rtol=1e-4).solve(
        Dense(jnp.asarray(a)), u, lam_min=lmn, lam_max=lmx)
    assert float(res.gauss_lower) <= float(res.lower) + 1e-9
    assert float(res.upper) <= float(res.lobatto_upper) + 1e-9
    assert bool(res.converged) and bool(res.certified)
    assert res.state.it.dtype == jnp.int32
