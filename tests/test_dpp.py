"""Retrospective (k-)DPP chains: decision-exactness vs the dense-solve
baseline (the paper's central correctness property) + efficiency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Dense, sample_dpp, sample_kdpp
from repro.data import random_sparse_spd
from conftest import make_spd


@pytest.fixture(scope="module")
def setup():
    n = 48
    a = random_sparse_spd(n, density=0.15, lam_min=5e-2, seed=4)
    w = np.linalg.eigvalsh(a)
    return a, float(w[0] * 0.9), float(w[-1] * 1.1), n


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dpp_chain_matches_exact(setup, seed):
    a, lmn, lmx, n = setup
    op = Dense(jnp.asarray(a))
    init = jnp.asarray((np.random.default_rng(seed).random(n) < 0.3)
                       .astype(np.float64))
    key = jax.random.key(seed)
    st_q = sample_dpp(op, key, init, 150, lmn, lmx, max_iters=n + 2)
    st_e = sample_dpp(op, key, init, 150, lmn, lmx, max_iters=n + 2,
                      exact=True)
    assert bool(jnp.all(st_q.mask == st_e.mask))
    assert int(st_q.stats.accepts) == int(st_e.stats.accepts)
    assert int(st_q.stats.uncertified) == 0


@pytest.mark.parametrize("seed", [0, 1])
def test_kdpp_chain_matches_exact_and_preserves_k(setup, seed):
    a, lmn, lmx, n = setup
    op = Dense(jnp.asarray(a))
    k = 12
    init = np.zeros(n)
    init[np.random.default_rng(seed).choice(n, k, replace=False)] = 1.0
    key = jax.random.key(100 + seed)
    st_q = sample_kdpp(op, key, jnp.asarray(init), 120, lmn, lmx,
                       max_iters=n + 2)
    st_e = sample_kdpp(op, key, jnp.asarray(init), 120, lmn, lmx,
                       max_iters=n + 2, exact=True)
    assert bool(jnp.all(st_q.mask == st_e.mask))
    assert int(st_q.mask.sum()) == k
    assert int(st_q.stats.uncertified) == 0


def test_quadrature_work_sublinear(setup):
    """Average GQL iterations per decision must be << N (the speedup)."""
    a, lmn, lmx, n = setup
    op = Dense(jnp.asarray(a))
    init = jnp.asarray((np.random.default_rng(0).random(n) < 0.3)
                       .astype(np.float64))
    st = sample_dpp(op, jax.random.key(0), init, 200, lmn, lmx,
                    max_iters=n + 2)
    avg = int(st.stats.quad_iterations) / 200
    assert avg < n / 3, f"avg quadrature iters {avg} not << {n}"


def test_dpp_prefers_diverse_sets():
    """On a kernel with two near-duplicate items, the stationary chain
    should rarely hold both (sanity of the sampler's target)."""
    n = 12
    a = make_spd(n, kappa=20.0, seed=2)
    d = np.sqrt(np.diag(a))
    a = a / np.outer(d, d)
    a[0, 1] = a[1, 0] = 0.98        # items 0,1 nearly identical
    a = a + 0.05 * np.eye(n)
    w = np.linalg.eigvalsh(a)
    op = Dense(jnp.asarray(a))
    both = 0
    trials = 60
    for s in range(trials):
        st = sample_dpp(op, jax.random.key(s),
                        jnp.zeros(n, jnp.float64) + (jnp.arange(n) < 4),
                        120, float(w[0] * 0.9), float(w[-1] * 1.1),
                        max_iters=n + 2)
        m = np.asarray(st.mask)
        both += bool(m[0] > 0.5 and m[1] > 0.5)
    assert both / trials < 0.2
