"""Block-Krylov quadrature (core/block.py, DESIGN.md Sec. 13).

Oracles are dense eigendecompositions computed independently in numpy:
the matrix-valued Gauss/Radau rules must be Loewner-ordered PSD
approximants of ``B^T f(A) B`` whose oriented traces bracket
``tr B^T f(A) B``, on every operator kind the quadrature core accepts.
The b = 1 slot of the block recurrence must reproduce the scalar
Lanczos coefficients bit-for-bit (same multiply-then-reduce shapes),
and rank-deficient starting blocks must deflate instead of NaN-ing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BIFSolver, SolverConfig, Dense, Jacobi, Masked, \
    Shifted, bell_from_dense, sparse_from_dense
from repro.core import block as blk
from repro.core import gql as gql_mod
from repro.core import matfun as matfun_mod
from conftest import make_spd

OP_KINDS = ["dense", "sparse_coo", "sparse_bell", "masked", "shifted",
            "jacobi"]

FNS = {"inv": lambda w: 1.0 / w, "log": np.log}


def _reference(kind, a, rng):
    """(operator, dense reference matrix) — the reference is numpy-only,
    independent of the operator's own code paths (the conformance-grid
    construction of tests/test_operators_conformance.py)."""
    n = a.shape[0]
    if kind == "dense":
        return Dense(jnp.asarray(a)), a
    if kind == "sparse_coo":
        return sparse_from_dense(a), a
    if kind == "sparse_bell":
        return bell_from_dense(a, bs=8), a
    if kind == "masked":
        m = (rng.random(n) < 0.6).astype(np.float64)
        ref = np.diag(m) @ a @ np.diag(m) + np.eye(n) - np.diag(m)
        return Masked(Dense(jnp.asarray(a)), jnp.asarray(m)), ref
    if kind == "shifted":
        sigma = 0.75
        return Shifted(Dense(jnp.asarray(a)), jnp.asarray(sigma)), \
            a + sigma * np.eye(n)
    if kind == "jacobi":
        c = 1.0 / np.sqrt(np.diag(a))
        return Jacobi.create(Dense(jnp.asarray(a))), a * np.outer(c, c)
    raise AssertionError(kind)


def _oracle(ref, u, fn):
    """B^T f(A) B by dense eigendecomposition (numpy)."""
    w, v = np.linalg.eigh(ref)
    g = np.asarray(u) @ v                    # (b, N) @ (N, N)
    return (g * FNS[fn](w)) @ g.T, float(w[0]), float(w[-1])


def _chain(op, u, lam_min, lam_max, fn, iters):
    """Run the block recurrence, yielding the state after each
    iteration (block_init counts as iteration 1)."""
    st = blk.block_init(op, u, lam_min, lam_max, fn, iters)
    yield st
    for _ in range(iters - 1):
        st = blk.block_step(op, st, lam_min, lam_max)
        yield st


def _oriented_matrices(st, lam_min, lam_max):
    """(lower_m, upper_m, gauss_m, gauss_is_lower) with the same
    derivative-sign orientation bracket() applies to the traces."""
    mats = np.asarray(blk.bracket_matrices(st, lam_min, lam_max))
    gl = bool(np.asarray(matfun_mod._GAUSS_IS_LOWER)[int(st.fnidx)])
    g_m, rl_m, rr_m = mats[0], mats[1], mats[2]
    return (rr_m, rl_m, g_m, gl) if gl else (rl_m, rr_m, g_m, gl)


# ---------------------------------------------------------------------------
# containment + Loewner ordering vs dense-eigh oracles (conformance grid)


@pytest.mark.parametrize("fn", ["inv", "log"])
@pytest.mark.parametrize("kind", OP_KINDS)
def test_containment_and_loewner_order_vs_eigh(kind, fn):
    rng = np.random.default_rng(5)
    n, b, iters = 33, 3, 6
    a = make_spd(n, kappa=50.0, seed=5, density=0.4)
    op, ref = _reference(kind, a, rng)
    u = jnp.asarray(rng.standard_normal((b, n)))
    oracle, lmn, lmx = _oracle(ref, u, fn)
    lmn, lmx = lmn * 0.99, lmx * 1.01
    tr_true = float(np.trace(oracle))
    scale = max(abs(tr_true), 1.0)

    prev_lo = -np.inf
    for st in _chain(op, u, lmn, lmx, fn, iters):
        lo, hi, loose_lo, loose_hi = (
            float(np.asarray(x)) for x in blk.bracket(st, lmn, lmx))
        # trace containment, tight and loose views
        assert loose_lo - 1e-7 * scale <= lo <= tr_true + 1e-7 * scale
        assert tr_true - 1e-7 * scale <= hi <= loose_hi + 1e-7 * scale
        # the tight lower bound tightens monotonically
        assert lo >= prev_lo - 1e-9 * scale
        prev_lo = lo
        # Loewner PSD ordering of the matrix-valued rules themselves
        lower_m, upper_m, gauss_m, gl = _oriented_matrices(st, lmn, lmx)
        assert np.linalg.eigvalsh(oracle - lower_m).min() >= -1e-6 * scale
        assert np.linalg.eigvalsh(upper_m - oracle).min() >= -1e-6 * scale
        # the Gauss rule sits on its derivative-sign side of the oracle
        gap = (oracle - gauss_m) if gl else (gauss_m - oracle)
        assert np.linalg.eigvalsh(gap).min() >= -1e-6 * scale
    # at the full budget the bracket has actually resolved something
    assert hi - lo <= 0.3 * scale


# ---------------------------------------------------------------------------
# b = 1: bit-exact with the scalar recurrence


def test_b1_coefficients_bit_exact_with_scalar_recurrence():
    n, iters = 24, 10
    a = make_spd(n, kappa=80.0, seed=3)
    op = sparse_from_dense(a)        # COO matvec is bit-exact across shapes
    w = np.linalg.eigvalsh(a)
    lmn, lmx = float(w[0] * 0.99), float(w[-1] * 1.01)
    rng = np.random.default_rng(3)
    u = rng.standard_normal(n)

    sst = gql_mod.gql_init(op, jnp.asarray(u), lmn, lmx)
    s_alpha, s_beta = [sst.lz.alpha], [sst.lz.beta]
    for _ in range(iters - 1):
        sst = gql_mod.gql_step(op, sst, lmn, lmx)
        s_alpha.append(sst.lz.alpha)
        s_beta.append(sst.lz.beta)

    bst = None
    for bst in _chain(op, jnp.asarray(u)[None, :], lmn, lmx, "inv", iters):
        pass
    a_hist = np.asarray(bst.a_hist)[:iters, 0, 0]
    b_hist = np.asarray(bst.b_hist)[:iters, 0, 0]
    # the multiply-then-reduce block contractions reproduce the scalar
    # Lanczos coefficient stream bit-for-bit at b = 1
    np.testing.assert_array_equal(a_hist, np.asarray(s_alpha))
    np.testing.assert_array_equal(b_hist, np.asarray(s_beta))


@pytest.mark.parametrize("fn", ["inv", "log"])
def test_b1_bracket_matches_scalar_driver(fn):
    """The b = 1 block bracket agrees with the scalar driver's bracket
    at every iteration count (the derived pivot/eigensolve routes differ
    in rounding, so allclose rather than bit-equal)."""
    n = 24
    a = make_spd(n, kappa=80.0, seed=4)
    op = sparse_from_dense(a)
    w = np.linalg.eigvalsh(a)
    lmn, lmx = float(w[0] * 0.99), float(w[-1] * 1.01)
    u = np.random.default_rng(4).standard_normal(n)
    solver = BIFSolver(SolverConfig(max_iters=8, fn=fn, rtol=0.0,
                                    atol=0.0, spectrum="explicit"))
    state = solver.init_state(op, jnp.asarray(u), lam_min=lmn, lam_max=lmx)
    for bst in _chain(op, jnp.asarray(u)[None, :], lmn, lmx, fn, 8):
        lo_b, hi_b, _, _ = blk.bracket(bst, lmn, lmx)
        lo_s, hi_s = state.bracket()
        np.testing.assert_allclose(float(lo_b), float(lo_s),
                                   rtol=1e-10, atol=1e-12)
        np.testing.assert_allclose(float(hi_b), float(hi_s),
                                   rtol=1e-10, atol=1e-12)
        state = solver.step_n(state, 1)


def test_block_size_one_config_routes_to_scalar_path():
    """SolverConfig(block_size=1) IS the scalar driver — same state
    type, bit-identical results (no block machinery on the b=1 path)."""
    n = 24
    a = make_spd(n, kappa=50.0, seed=6)
    op = Dense(jnp.asarray(a))
    w = np.linalg.eigvalsh(a)
    u = np.random.default_rng(6).standard_normal((3, n))
    kw = dict(lam_min=float(w[0]), lam_max=float(w[-1]))
    r1 = BIFSolver(SolverConfig(max_iters=12)).solve_batch(
        op, jnp.asarray(u), **kw)
    r2 = BIFSolver(SolverConfig(max_iters=12, block_size=1)).solve_batch(
        op, jnp.asarray(u), **kw)
    assert isinstance(r2.state.st, gql_mod.GQLState)
    np.testing.assert_array_equal(np.asarray(r1.lower), np.asarray(r2.lower))
    np.testing.assert_array_equal(np.asarray(r1.upper), np.asarray(r2.upper))


# ---------------------------------------------------------------------------
# deflation: rank-deficient starting blocks


@pytest.mark.parametrize("fn", ["inv", "log"])
def test_rank_deficient_start_block_deflates_not_nans(fn):
    """Duplicate and zero probe columns deflate at the initial QR; the
    surviving chain matches the scalar recurrence on the unique probe
    and the bracket contains the (duplicated) truth — no NaNs ever."""
    n, iters = 24, 8
    a = make_spd(n, kappa=50.0, seed=7)
    op = Dense(jnp.asarray(a))
    w, v = np.linalg.eigh(a)
    lmn, lmx = float(w[0] * 0.99), float(w[-1] * 1.01)
    z = np.random.default_rng(7).standard_normal(n)
    u = jnp.asarray(np.stack([z, z, np.zeros(n)]))   # rank 1 of b = 3
    c = z @ v
    truth = float(np.sum(c * c * FNS[fn](w)))

    st = blk.block_init(op, u, lmn, lmx, fn, iters)
    assert np.asarray(st.live).sum() <= 1    # slots 1, 2 deflated at init
    for _ in range(iters - 1):
        st = blk.block_step(op, st, lmn, lmx)
        est = np.asarray(blk.estimates(st, lmn, lmx))
        assert np.all(np.isfinite(est)), est
    lo, hi, _, _ = (float(np.asarray(x)) for x in blk.bracket(st, lmn, lmx))
    # tr B^T f(A) B = 2 * z^T f(A) z (the duplicate column counts twice,
    # through r0 — the zero column contributes exactly 0)
    scale = max(abs(truth), 1.0)
    assert lo - 1e-6 * scale <= 2 * truth <= hi + 1e-6 * scale
    assert hi - lo <= 5e-2 * scale


def test_all_zero_block_is_done_at_init():
    n = 16
    a = make_spd(n, kappa=10.0, seed=8)
    op = Dense(jnp.asarray(a))
    st = blk.block_init(op, jnp.zeros((2, 4, n)), 0.1, 2.0, "inv", 4)
    assert np.all(np.asarray(st.done))
    assert not np.any(np.asarray(st.live))
    # exhausted lanes report a collapsed (zero-width, zero-value) bracket
    lo, hi, _, _ = blk.bracket(st, 0.1, 2.0)
    np.testing.assert_array_equal(np.asarray(lo), 0.0)
    np.testing.assert_array_equal(np.asarray(hi), 0.0)


# ---------------------------------------------------------------------------
# solver integration: the stepping API threads BlockState


def test_solver_block_resume_invariant_bit_exact():
    """resume(step_n(s, k)) == resume(s) on every BlockState leaf — the
    freeze/thread contract holds for block lanes exactly as for scalar
    ones (COO matvec makes the comparison bit-exact)."""
    n, k, b = 32, 3, 4
    a = make_spd(n, kappa=50.0, seed=9)
    op = sparse_from_dense(a)
    w = np.linalg.eigvalsh(a)
    u = jnp.asarray(
        np.random.default_rng(9).standard_normal((k, b, n)))
    solver = BIFSolver(SolverConfig(max_iters=10, block_size=b))
    kw = dict(lam_min=float(w[0]), lam_max=float(w[-1]))
    s0 = solver.init_state(op, u, **kw)
    full = solver.resume(solver.init_state(op, u, **kw))
    paused = solver.resume(solver.step_n(s0, 3))
    for name in (f.name for f in dataclasses.fields(blk.BlockState)):
        np.testing.assert_array_equal(
            np.asarray(getattr(full.st, name)),
            np.asarray(getattr(paused.st, name)), err_msg=name)


def test_solver_block_containment_and_certification():
    n, k, b = 32, 3, 4
    a = make_spd(n, kappa=50.0, seed=10)
    op = Dense(jnp.asarray(a))
    w, v = np.linalg.eigh(a)
    us = np.random.default_rng(10).standard_normal((k, b, n))
    truth = np.array([np.trace((us[i] @ v * (1.0 / w)) @ (us[i] @ v).T)
                      for i in range(k)])
    solver = BIFSolver(SolverConfig(max_iters=16, block_size=b))
    res = solver.solve_batch(op, jnp.asarray(us), lam_min=float(w[0]),
                             lam_max=float(w[-1]))
    lo, hi = np.asarray(res.lower), np.asarray(res.upper)
    scale = np.maximum(np.abs(truth), 1.0)
    assert np.all(lo <= truth + 1e-7 * scale)
    assert np.all(hi >= truth - 1e-7 * scale)
    assert np.all(np.asarray(res.certified))


def test_block_config_guards():
    with pytest.raises(ValueError):
        SolverConfig(block_size=0)
    with pytest.raises(NotImplementedError):
        SolverConfig(block_size=2, reorth=True)
    with pytest.raises(NotImplementedError):
        SolverConfig(block_size=2, precondition="jacobi")
    solver = BIFSolver(SolverConfig(max_iters=4, block_size=4))
    op = Dense(jnp.asarray(make_spd(16, seed=0)))
    with pytest.raises(ValueError):      # wrong block width
        solver.init_state(op, jnp.ones((2, 16)), lam_min=0.1, lam_max=2.0)
