"""Minimal stand-in for the ``hypothesis`` API surface this suite uses.

The real library is preferred and used when importable; conftest.py only
installs this stub when ``hypothesis`` is absent (hermetic CI containers),
so the property tests degrade to a deterministic seeded sweep instead of
erroring out at collection.

Covered surface: ``given``, ``settings`` (register_profile/load_profile and
decorator form), ``strategies.integers`` / ``strategies.floats``.
"""
from __future__ import annotations

import functools
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _floats(min_value, max_value, **_kw):
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def _sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, sampled_from=_sampled_from,
    booleans=_booleans)


class settings:  # noqa: N801 — mirrors hypothesis' lowercase class
    _profiles: dict = {}
    _current: dict = {"max_examples": 10}

    def __init__(self, **kwargs):
        self.kwargs = kwargs

    def __call__(self, fn):
        fn._stub_settings = self.kwargs
        return fn

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        cls._current = {**{"max_examples": 10}, **cls._profiles[name]}


def given(*_args, **strategy_kwargs):
    """Run the test body over a deterministic per-test sample sweep."""
    if _args:
        raise NotImplementedError(
            "the hypothesis stub only supports keyword strategies")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_stub_settings", settings._current).get(
                "max_examples", settings._current["max_examples"])
            # Stable across runs/processes (unlike hash()).
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = {k: s._draw(rng)
                         for k, s in strategy_kwargs.items()}
                fn(*args, **kwargs, **drawn)
        # pytest must not introspect the wrapped signature, or it would
        # treat the strategy parameters as fixtures.
        del wrapper.__wrapped__
        wrapper.hypothesis_stub = True
        return wrapper

    return decorate


HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None,
                                    filter_too_much=None)
