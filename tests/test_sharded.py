"""Device-sharded batched quadrature (DESIGN.md Sec. 7).

Two layers:

1. In-process tests on a ONE-device lane mesh (this process must keep a
   single device, see conftest). shard_map still runs — same specs, same
   collectives, degenerate axis — and the local lane stack equals the
   global one, so parity with ``solve_batch`` is bit-exact even on
   gemm-backed operators.
2. The real multi-device contract runs in a subprocess under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
   (tests/sharded_check.py): per-lane decisions / iteration counts /
   certified argmax index exactly equal the single-device batched path,
   brackets bit-exact on COO and 1e-12 on gemm ops, including a
   non-divisible-K padding lane and a mixed-mask BIFEngine flush routed
   through the mesh.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import BIFSolver, Dense, Jacobi, Masked, ShardedBIFSolver, \
    Shifted, bell_from_dense, lane_specs, shard_ops, sparse_from_dense, \
    stack_masks, stack_ops
from repro.core.sharded import _pad_lane_op
from repro.launch.mesh import make_lane_mesh
from repro.sharding import lane_plan, lane_sharding
from conftest import make_spd


def _problem(n=40, k=6, seed=0):
    a = make_spd(n, kappa=80.0, seed=seed, density=0.3)
    w = np.linalg.eigvalsh(a)
    us = np.random.default_rng(seed + 1).standard_normal((k, n))
    return a, jnp.asarray(us), float(w[0] * 0.99), float(w[-1] * 1.01)


# ----------------------------------------------------- lane placement specs

def test_lane_specs_shared_vs_stacked():
    a = make_spd(16, kappa=10.0, seed=0)
    base = Dense(jnp.asarray(a))
    assert lane_specs(base).a == P()

    # K == N deliberately: shape heuristics would misfire here, the
    # type-dispatched rule must keep the (N, N) base replicated while
    # sharding the (K, N) mask stack
    mop = stack_masks(base, jnp.ones((16, 16)))
    specs = lane_specs(mop)
    assert specs.base.a == P() and specs.mask == P("lanes")

    sop = stack_ops([sparse_from_dense(a), sparse_from_dense(a)])
    specs = lane_specs(sop)
    assert specs.rows == P("lanes") and specs.vals == P("lanes")

    bop = stack_ops([bell_from_dense(a, bs=8), bell_from_dense(a, bs=8)])
    specs = lane_specs(bop)
    assert specs.data == P("lanes") and specs.cols == P("lanes")

    wrapped = Shifted(Jacobi.create(base), jnp.zeros((4,)))
    specs = lane_specs(wrapped)
    assert specs.sigma == P("lanes")          # per-lane shift
    assert specs.base.inv_sqrt_diag == P()    # shared preconditioner
    assert specs.base.base.a == P()

    with pytest.raises(ValueError, match="lane dims"):
        lane_specs(Dense(jnp.ones((2, 3, 16, 16))))


def test_pad_lane_op_pads_only_stacked_leaves():
    a = make_spd(12, kappa=10.0, seed=1)
    base = Dense(jnp.asarray(a))
    mop = stack_masks(base, jnp.ones((3, 12)))
    padded = _pad_lane_op(mop, 3, 8, "lanes")
    assert padded.mask.shape == (8, 12)
    assert np.all(np.asarray(padded.mask[3:]) == 0.0)
    assert padded.base.a.shape == (12, 12)  # shared leaf untouched
    assert _pad_lane_op(mop, 3, 3, "lanes") is mop


def test_shard_ops_places_on_lane_mesh():
    mesh = make_lane_mesh()  # single local device in-process
    a = make_spd(12, kappa=10.0, seed=2)
    mop = stack_masks(Dense(jnp.asarray(a)), jnp.ones((4, 12)))
    placed = shard_ops(mop, mesh)
    assert placed.mask.sharding.spec == P("lanes")
    assert placed.base.a.sharding.spec == P()
    np.testing.assert_array_equal(np.asarray(placed.base.a), a)


def test_lane_plan_and_sharding_helpers():
    plan = lane_plan()
    assert plan.mesh_axes("lanes") == "lanes"
    mesh = make_lane_mesh()
    sh = lane_sharding(mesh)
    assert sh.spec == P("lanes", None)


# ------------------------------------------- one-device-mesh driver parity

@pytest.mark.parametrize("op_kind", ["dense", "sparse", "bell"])
def test_sharded_matches_batched_on_unit_mesh(op_kind):
    """On a 1-device mesh the local stack equals the global stack, so the
    sharded driver is bit-exact against solve_batch for EVERY operator."""
    a, us, lmn, lmx = _problem()
    op = {"dense": Dense(jnp.asarray(a)),
          "sparse": sparse_from_dense(a),
          "bell": bell_from_dense(a, bs=8)}[op_kind]
    mesh = make_lane_mesh()
    s = BIFSolver.create(max_iters=42, rtol=1e-4)
    ref = s.solve_batch(op, us, lam_min=lmn, lam_max=lmx)
    got = s.solve_batch_sharded(op, us, mesh=mesh, lam_min=lmn,
                                lam_max=lmx)
    for field in ("lower", "upper", "gauss_lower", "lobatto_upper",
                  "iterations", "certified"):
        np.testing.assert_array_equal(np.asarray(getattr(got, field)),
                                      np.asarray(getattr(ref, field)),
                                      field)


def test_sharded_judges_on_unit_mesh():
    a, us, lmn, lmx = _problem(k=5, seed=4)
    op = Dense(jnp.asarray(a))
    true = np.einsum("ki,ki->k", np.asarray(us),
                     np.linalg.solve(a, np.asarray(us).T).T)
    mesh = make_lane_mesh()
    s = BIFSolver.create(max_iters=42)
    ts = jnp.asarray(true * np.array([0.5, 0.9, 1.1, 2.0, 0.95]))
    ref = s.judge_batch(op, us, ts, lam_min=lmn, lam_max=lmx)
    got = s.judge_batch_sharded(op, us, ts, mesh=mesh, lam_min=lmn,
                                lam_max=lmx)
    np.testing.assert_array_equal(np.asarray(got.decision),
                                  np.asarray(ref.decision))
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  np.asarray(ref.iterations))

    sh = ShardedBIFSolver(s, mesh)
    am = sh.judge_argmax(op, us, lam_min=lmn, lam_max=lmx)
    assert int(am.index) == int(np.argmax(true))
    assert bool(am.certified)


def test_sharded_rejects_bad_inputs():
    a, us, lmn, lmx = _problem(k=4)
    mesh = make_lane_mesh()
    s = BIFSolver.create(max_iters=8)
    with pytest.raises(ValueError, match=r"\(K, N\)"):
        s.solve_batch_sharded(Dense(jnp.asarray(a)), us[0], mesh=mesh,
                              lam_min=lmn, lam_max=lmx)
    with pytest.raises(NotImplementedError, match="reorth"):
        s.replace(reorth=True).solve_batch_sharded(
            Dense(jnp.asarray(a)), us, mesh=mesh, lam_min=lmn,
            lam_max=lmx)


# ------------------------------------------------ the multi-device contract

def test_multi_device_parity_subprocess():
    """The full 8-virtual-device parity suite (tests/sharded_check.py)."""
    here = Path(__file__).resolve().parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    # ~7 min idle; the stats-parity check compiles three engine drivers,
    # so leave slack for loaded CI machines
    out = subprocess.run([sys.executable, str(here / "sharded_check.py")],
                         capture_output=True, text=True, timeout=1800,
                         env=env)
    assert out.returncode == 0 and "OK" in out.stdout, \
        (out.stdout[-1000:], out.stderr[-3000:])
