"""Convergence-rate pins for the paper's central theorem (Sec. 3 /
Thm. 4.2): on an SPD system with condition number kappa, the
Gauss-Radau bracket on ``u^T A^-1 u``

  * always contains the true value, with the lower bounds monotonically
    nondecreasing and the upper bounds nonincreasing in the iteration
    count, and
  * contracts geometrically — the gap shrinks per iteration at least as
    fast as the CG-type rate ``rho = ((sqrt(kappa)-1)/(sqrt(kappa)+1))^2``.

Every assertion here is against a CLOSED-FORM oracle (the dense solve
for the true value; the kappa-rate formula for the contraction), never
against the quadrature implementation itself — so a regression in the
recurrence shows up as a real failure, not a self-consistent fiction.

Spectra are exact by construction: conftest.make_spd with density=1
places eigenvalues on a geometric grid [1/kappa, 1], so lam_min/lam_max
are known, not estimated.

The traces run with ``reorth=True``: the theorem is a statement about
exact arithmetic, and finite-precision Lanczos WITHOUT
reorthogonalization is known to violate the bounds at ~1e-7 relative
for kappa=1000 (paper Sec. 5.4 'Instability' — that is why the solver
grew the option). With full reorthogonalization containment holds to
~1e-14 and the monotone/contraction pins are sharp.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BIFSolver, Dense
from conftest import make_spd

# floating-point slack for monotonicity (the sequences are monotone in
# exact arithmetic; f64 rounding wobbles the last bits near convergence)
_MONO_SLACK = 1e-9
# the fitted per-iteration contraction may exceed the asymptotic bound
# by transient factors; 15% slack keeps the pin meaningful (a wrong
# recurrence converges at a hugely different rate or not at all)
_RATE_SLACK = 1.15
# stop fitting once the gap hits the f64 noise floor relative to scale
_FLOOR = 1e-12


def _trace_problem(kappa, n=64, seed=0, num_iters=None):
    a = make_spd(n, kappa=kappa, seed=seed)          # geomspace spectrum
    u = np.random.default_rng(seed + 1).standard_normal(n)
    true = float(u @ np.linalg.solve(a, u))
    solver = BIFSolver.create(max_iters=n, reorth=True)
    if num_iters is None:
        num_iters = n - 2
    tr = solver.trace(Dense(jnp.asarray(a)), jnp.asarray(u), num_iters,
                      lam_min=1.0 / kappa * 0.999, lam_max=1.001)
    return tr, true


def _rate_bound(kappa):
    rk = np.sqrt(kappa)
    return ((rk - 1.0) / (rk + 1.0)) ** 2


@pytest.mark.parametrize("kappa", [10.0, 100.0, 1000.0])
def test_brackets_contain_truth_and_are_monotone(kappa):
    tr, true = _trace_problem(kappa)
    lower = np.asarray(tr.radau_lower)     # right Gauss-Radau (Thm. 4)
    upper = np.asarray(tr.radau_upper)     # left Gauss-Radau (Thm. 6)
    gauss = np.asarray(tr.gauss)           # plain Gauss (Thm. 2)
    lobatto = np.asarray(tr.lobatto)

    scale = abs(true)
    # (a) every iterate brackets the direct solve
    assert np.all(lower <= true + 1e-9 * scale)
    assert np.all(gauss <= true + 1e-9 * scale)
    assert np.all(upper >= true - 1e-9 * scale)
    assert np.all(lobatto >= true - 1e-9 * scale)
    # Gauss is the loosest lower bound, Radau tightens it (Thm. 4)
    assert np.all(gauss <= lower + _MONO_SLACK * scale)

    # (b) monotone: lower bounds never step down, upper never step up
    assert np.all(np.diff(lower) >= -_MONO_SLACK * scale)
    assert np.all(np.diff(gauss) >= -_MONO_SLACK * scale)
    assert np.all(np.diff(upper) <= _MONO_SLACK * scale)
    assert np.all(np.diff(lobatto) <= _MONO_SLACK * scale)

    # and the final bracket is genuinely tight
    assert upper[-1] - lower[-1] <= 1e-6 * scale


@pytest.mark.parametrize("kappa,seed", [(10.0, 0), (10.0, 3),
                                        (100.0, 0), (100.0, 3),
                                        (1000.0, 0), (1000.0, 3)])
def test_gap_contracts_at_kappa_rate(kappa, seed):
    """Fit the geometric contraction of the Radau gap and pin it below
    the ((sqrt(k)-1)/(sqrt(k)+1))^2 closed-form rate (with slack)."""
    tr, true = _trace_problem(kappa, seed=seed)
    gap = np.asarray(tr.radau_upper) - np.asarray(tr.radau_lower)
    scale = abs(true)

    # fit over iterations where the gap is meaningfully above the noise
    # floor (and strictly positive — exhaustion collapses it to ~0)
    live = gap > _FLOOR * scale
    m = int(np.argmin(live)) if not live.all() else len(gap)
    assert m >= 5, "gap hit the floor too fast to fit a rate"
    ratios = gap[1:m] / gap[:m - 1]
    fitted = float(np.exp(np.mean(np.log(ratios))))

    bound = _rate_bound(kappa)
    assert fitted <= bound * _RATE_SLACK, (
        f"kappa={kappa}: fitted per-iteration contraction {fitted:.4f} "
        f"exceeds the closed-form rate {bound:.4f}")
    # sanity on the oracle itself: a harder problem contracts slower
    assert 0.0 < fitted < 1.0


def test_rate_bound_orders_with_kappa():
    """The pin is discriminating: measured rates order the same way the
    closed-form bound does across two decades of kappa."""
    fits = {}
    for kappa in (10.0, 100.0, 1000.0):
        tr, true = _trace_problem(kappa, seed=1)
        gap = np.asarray(tr.radau_upper) - np.asarray(tr.radau_lower)
        live = gap > _FLOOR * abs(true)
        m = int(np.argmin(live)) if not live.all() else len(gap)
        ratios = gap[1:m] / gap[:m - 1]
        fits[kappa] = float(np.exp(np.mean(np.log(ratios))))
    assert fits[10.0] < fits[100.0] < fits[1000.0]
    assert fits[10.0] < _rate_bound(100.0)  # well-conditioned is FASTER
