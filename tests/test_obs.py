"""Observability layer (DESIGN.md Sec. 14).

1. Metric primitives: counters/gauges, log-bucket histograms whose
   p50/p90/p99 are EXACT (nearest-rank, pinned against numpy's
   ``inverted_cdf``), global enable gate, snapshot shape.
2. Spans: off by default, nestable, Chrome-trace events that validate
   against the checked-in ``obs/trace_schema.json``.
3. Engine request metrics: a scripted mixed workload (judges, brackets,
   an expired-deadline request) produces exactly the expected counter
   ledger and histogram populations.
4. Convergence logs are bit-exact mirrors of the returned brackets on
   both the ``trace`` and ``step_n`` paths.
5. Health: the Thm. 4.2 monitor flags the documented reorth-off
   failure mode (kappa=1000 Krylov exhaustion, paper Sec. 5.4) and
   stays silent on healthy reorth=True runs across kappa.
6. THE invariant everything above rests on: telemetry never changes
   results — metrics/spans on vs off is bit-identical across an
   engine conformance grid (the sharded twin lives in
   tests/sharded_check.py::check_engine_stats_parity).
"""
import json
import math
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import BIFSolver, Dense, sparse_from_dense
from repro.obs import schema as obs_schema
from repro.obs.health import check_contraction
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.serve import BIFEngine, BIFRequest
from conftest import make_spd


@pytest.fixture(autouse=True)
def _obs_defaults():
    """Every test starts from (and restores) the shipped defaults:
    metrics on, spans off, clean span buffer."""
    obs.metrics.set_enabled(True)
    obs.spans.set_enabled(False)
    obs.spans.reset()
    yield
    obs.metrics.set_enabled(True)
    obs.spans.set_enabled(False)
    obs.spans.reset()


# -- 1. metric primitives ---------------------------------------------------

def test_counter_gauge_and_snapshot_shape():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    reg.gauge("g").set(2.5)
    reg.histogram("h").observe(0.125)
    snap = reg.snapshot()
    assert snap["counters"] == {"c": 5}
    assert snap["gauges"] == {"g": 2.5}
    assert snap["histograms"]["h"]["count"] == 1
    # get-or-create returns the SAME object; a kind collision is an error
    assert reg.counter("c") is reg.counter("c")
    with pytest.raises(TypeError):
        reg.gauge("c")
    reg.reset()
    assert reg.snapshot()["counters"] == {"c": 0}


def test_histogram_percentiles_are_exact_nearest_rank():
    rng = np.random.default_rng(0)
    samples = np.concatenate([
        rng.lognormal(mean=-3.0, sigma=2.0, size=257),
        [0.0, -1.0, 5e-12, 3e7],  # under/overflow buckets
    ])
    h = Histogram("lat")
    for v in samples:
        h.observe(float(v))
    for q in (50.0, 90.0, 99.0, 1.0, 100.0):
        assert h.percentile(q) == float(
            np.percentile(samples, q, method="inverted_cdf")), q
    snap = h.snapshot()
    assert snap["count"] == len(samples)
    assert snap["min"] == samples.min() and snap["max"] == samples.max()
    np.testing.assert_allclose(snap["mean"], samples.mean(), rtol=1e-12)
    for q in (50, 90, 99):
        assert snap[f"p{q}"] == float(
            np.percentile(samples, q, method="inverted_cdf"))
    # bucket counts cover every observation exactly once
    assert sum(c for _, c in snap["buckets"]) == len(samples)
    with pytest.raises(ValueError):
        h.percentile(0.0)


def test_histogram_empty_snapshot_is_nan_not_crash():
    snap = Histogram("e").snapshot()
    assert snap["count"] == 0 and snap["buckets"] == []
    assert math.isnan(snap["p50"]) and math.isnan(snap["mean"])
    assert math.isnan(Histogram("e2").percentile(99.0))


def test_metrics_global_gate_stops_writes_not_reads():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    obs.metrics.set_enabled(False)
    reg.counter("c").inc(100)
    reg.gauge("g").set(9.0)
    reg.histogram("h").observe(1.0)
    snap = reg.snapshot()  # reads still work
    assert snap["counters"]["c"] == 1
    assert snap["gauges"]["g"] == 0.0
    assert snap["histograms"]["h"]["count"] == 0
    obs.metrics.set_enabled(True)
    reg.counter("c").inc()
    assert reg.counter("c").value == 2


# -- 2. spans ---------------------------------------------------------------

def test_spans_off_by_default_nest_and_validate_schema(tmp_path):
    with obs.span("dead"):
        pass
    assert obs.trace_events() == []  # collection is opt-in

    obs.spans.set_enabled(True)
    with obs.span("outer", mode="test"):
        with obs.span("inner") as sp:
            assert sp.block_until_ready(jnp.ones(3)) is not None
            time.sleep(0.002)
    events = obs.trace_events()
    assert [e["name"] for e in events] == ["inner", "outer"]  # close order
    inner, outer = events
    assert inner["args"]["depth"] == 1 and outer["args"]["depth"] == 0
    assert outer["args"]["mode"] == "test"
    # timestamp containment is how trace viewers rebuild the flame graph
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert inner["dur"] >= 1e3  # the 2ms sleep, in microseconds

    doc = obs.dump_trace(tmp_path / "trace.json")
    schema = json.loads(
        (Path(obs.spans.__file__).parent / "trace_schema.json").read_text())
    obs_schema.validate(doc, schema)
    on_disk = json.loads((tmp_path / "trace.json").read_text())
    assert on_disk["traceEvents"] == json.loads(json.dumps(
        doc["traceEvents"]))
    obs.spans.reset()
    assert obs.trace_events() == []


def test_span_records_error_annotation():
    obs.spans.set_enabled(True)
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    (ev,) = obs.trace_events()
    assert ev["args"]["error"] == "RuntimeError"


# -- 3. engine request metrics ---------------------------------------------

def _engine_problem(n=32, kappa=60.0, seed=2, k=9):
    a = make_spd(n, kappa=kappa, seed=seed)
    w = np.linalg.eigvalsh(a)
    lam = dict(lam_min=float(w[0] * 0.99), lam_max=float(w[-1] * 1.01))
    us = np.random.default_rng(seed + 1).standard_normal((k, n))
    true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)
    return a, us, true, lam


def test_engine_stats_scripted_mixed_workload():
    a, us, true, lam = _engine_problem()
    n, k = a.shape[0], len(us)
    engine = BIFEngine(Dense(jnp.asarray(a)),
                       solver=BIFSolver.create(max_iters=n + 2, rtol=1e-4),
                       max_batch=4, chunk_iters=3, **lam)
    for i, u in enumerate(us):
        t = float(true[i] * (0.8 if i % 2 else 1.2)) if i % 3 else None
        engine.submit(BIFRequest(u=u, t=t, deadline=time.monotonic() + 60.0))
    # one request whose deadline already passed: retired at the door,
    # zero iterations, queue-wait still recorded
    dead = engine.submit(BIFRequest(u=us[0],
                                    deadline=time.monotonic() - 1.0))
    out = engine.flush()
    assert len(out) == k + 1
    assert dead.resolved is False and dead.iterations == 0

    s = engine.stats()
    c = s["counters"]
    assert c["requests.submitted"] == k + 1
    assert c["requests.retired"] == k + 1
    assert c["requests.resolved"] == k
    assert c["requests.partial"] == 1
    assert c["requests.expired"] == 1
    assert c["flush.count"] == 1
    assert c["flush.rounds"] >= math.ceil(k / 4)
    assert "requests.errored" not in c  # nothing failed

    h = s["histograms"]
    # queue-wait covers EVERY retirement, including the expired-at-door
    # one; admission-to-retire latency only the k admitted requests
    assert h["request.queue_wait_s"]["count"] == k + 1
    assert h["request.latency_s"]["count"] == k
    for field in ("p50", "p90", "p99", "mean", "min", "max"):
        assert field in h["request.latency_s"]
    assert h["request.latency_s"]["p99"] >= h["request.latency_s"]["p50"]
    # every request carried a deadline; slack is negative for the dead one
    assert h["request.deadline_slack_s"]["count"] == k + 1
    assert h["request.deadline_slack_s"]["min"] < 0.0
    assert h["request.iterations"]["count"] == k + 1
    assert h["request.iterations"]["min"] == 0.0  # the expired request
    occ = h["pool.occupancy"]
    assert occ["count"] == c["flush.rounds"] and occ["max"] <= 1.0

    engine.reset_stats()
    assert engine.stats()["counters"]["requests.submitted"] == 0


def test_engine_stats_count_errored_requests():
    a, us, _, lam = _engine_problem(k=3)
    engine = BIFEngine(Dense(jnp.asarray(a)), max_batch=2, **lam)
    for u in us:
        engine.submit(BIFRequest(u=u))

    class _Boom(Exception):
        pass

    orig = engine._step

    def boom(*a_, **k_):
        raise _Boom()

    engine._step = boom
    try:
        with pytest.raises(_Boom):
            engine.flush()
    finally:
        engine._step = orig
    assert engine.stats()["counters"]["requests.errored"] >= 1


def test_retrace_registry_feeds_flush_trace_count():
    from repro.serve.engine import flush_trace_count
    a, us, _, lam = _engine_problem(k=3)
    before_total = flush_trace_count()
    before = dict(obs.retrace_counts())
    engine = BIFEngine(Dense(jnp.asarray(a)), max_batch=4, chunk_iters=4,
                       **lam)
    for u in us:
        engine.submit(BIFRequest(u=u))
    engine.flush()
    after = obs.retrace_counts()
    grown = {k_: v - before.get(k_, 0) for k_, v in after.items()
             if v != before.get(k_, 0)}
    assert grown, "an engine flush must register at least one trace"
    assert all(k_.startswith("serve.engine.") for k_ in grown)
    # the legacy counter is a pure view over the registry
    assert flush_trace_count() - before_total == sum(
        v for k_, v in grown.items()
        if k_.split(".")[-1] in ("pool_admit", "pool_scatter", "pool_step",
                                 "flush"))


# -- 4. convergence logs mirror returned brackets bit-exactly ---------------

def test_convergence_log_matches_trace_bit_exact():
    n, kappa = 48, 100.0
    a = make_spd(n, kappa=kappa, seed=0)
    u = np.random.default_rng(1).standard_normal(n)
    solver = BIFSolver.create(max_iters=n, reorth=True)
    kw = dict(lam_min=1.0 / kappa * 0.999, lam_max=1.001)
    op = Dense(jnp.asarray(a))

    log = obs.ConvergenceLog()
    tr = solver.trace(op, jnp.asarray(u), n - 2, convergence_log=log, **kw)
    assert log.rounds == n - 2
    np.testing.assert_array_equal(log.lowers()[:, 0],
                                  np.asarray(tr.radau_lower))
    np.testing.assert_array_equal(log.uppers()[:, 0],
                                  np.asarray(tr.radau_upper))
    np.testing.assert_array_equal(log.its()[:, 0], np.arange(1, n - 1))
    # passing a log never perturbs the trace itself
    tr2 = solver.trace(op, jnp.asarray(u), n - 2, **kw)
    np.testing.assert_array_equal(np.asarray(tr.radau_lower),
                                  np.asarray(tr2.radau_lower))
    np.testing.assert_array_equal(np.asarray(tr.radau_upper),
                                  np.asarray(tr2.radau_upper))


def test_convergence_log_matches_step_n_states_bit_exact():
    n = 40
    a = make_spd(n, kappa=50.0, seed=3)
    u = np.random.default_rng(4).standard_normal(n)
    solver = BIFSolver.create(max_iters=n, rtol=1e-10)
    log = obs.ConvergenceLog()
    state = solver.init_state(Dense(jnp.asarray(a)), jnp.asarray(u),
                              lam_min=0.01, lam_max=1.1)
    ref = solver.init_state(Dense(jnp.asarray(a)), jnp.asarray(u),
                            lam_min=0.01, lam_max=1.1)
    for _ in range(4):
        state = solver.step_n(state, 5, convergence_log=log)
        ref = solver.step_n(ref, 5)
        lo, hi = state.bracket()
        np.testing.assert_array_equal(log.lowers()[-1],
                                      np.atleast_1d(np.asarray(lo)))
        np.testing.assert_array_equal(log.uppers()[-1],
                                      np.atleast_1d(np.asarray(hi)))
        # and the logged run IS the unlogged run, bit for bit
        rlo, rhi = ref.bracket()
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(rlo))
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(rhi))
    assert log.rounds == 4
    assert int(log.its()[-1, 0]) == int(np.asarray(state.it))


def test_convergence_log_rejects_shape_drift():
    log = obs.ConvergenceLog()
    log.record([1.0, 1.0], [2.0, 2.0], 1)
    with pytest.raises(ValueError):
        log.record([1.0], [2.0], 2)
    with pytest.raises(ValueError):
        log.record([1.0, 1.0], [2.0], 2)


# -- 5. convergence health -------------------------------------------------

def _health_report(kappa, *, reorth, n=64, seed=0):
    """The canonical convergence-pin setup (tests/test_convergence.py)."""
    a = make_spd(n, kappa=kappa, seed=seed)
    u = np.random.default_rng(seed + 1).standard_normal(n)
    solver = BIFSolver.create(max_iters=n, reorth=reorth)
    mon = obs.ContractionMonitor(1.0 / kappa * 0.999, 1.001, dim=n)
    solver.trace(Dense(jnp.asarray(a)), jnp.asarray(u), n - 2,
                 lam_min=1.0 / kappa * 0.999, lam_max=1.001,
                 convergence_log=mon.log)
    return mon.report()


def test_health_flags_reorth_off_instability_kappa_1000():
    """Paper Sec. 5.4: without reorthogonalization the kappa=1000 trace
    exhausts the Krylov dimension with the gap stuck ~1e-6 relative —
    orders of magnitude above the reorth=True floor. The monitor must
    flag it, and the exhaustion check is the signal that fires."""
    rep = _health_report(1000.0, reorth=False)
    assert not rep.ok
    assert bool(rep.unresolved[0])
    assert rep.last_rel_gap[0] > 1e-8  # the gap really is open
    # the early contraction is NOT the tell — finite-precision Lanczos
    # keeps the theorem rate while losing orthogonality
    assert rep.max_window_rate[0] <= rep.bound * 1.15


def test_health_silent_on_healthy_reorth_runs():
    for kappa in (10.0, 100.0, 1000.0):
        rep = _health_report(kappa, reorth=True)
        assert rep.ok, (kappa, rep)
        assert not rep.slow.any() and not rep.stalled.any() \
            and not rep.unresolved.any()
        # healthy runs finish below the floor
        assert rep.last_rel_gap[0] <= 1e-8, kappa


def test_health_rate_bound_and_edge_cases():
    assert obs.rate_bound(1.0, 1.0) == 0.0
    k = 100.0
    assert np.isclose(obs.rate_bound(1.0 / k, 1.0),
                      ((np.sqrt(k) - 1) / (np.sqrt(k) + 1)) ** 2)
    with pytest.raises(ValueError):
        obs.rate_bound(-1.0, 2.0)
    with pytest.raises(ValueError):
        obs.rate_bound(2.0, 1.0)
    # short logs report, never crash
    log = obs.ConvergenceLog()
    rep = check_contraction(log, 0.1, 1.0)
    assert rep.ok and rep.fitted_rate.shape == (0,)
    log.record(1.0, 2.0, 1)
    rep = check_contraction(log, 0.1, 1.0, dim=4)
    assert rep.ok


def test_health_resolved_mask_and_stall_flag():
    log = obs.ConvergenceLog()
    # lane 0 plateaus while live; lane 1 converges geometrically
    for t in range(12):
        log.record([1e-3 * 0.999 ** t, 4.0 * 0.25 ** t],
                   [2e-3 * 0.999 ** t, 8.0 * 0.25 ** t],
                   t + 1)
    rep = check_contraction(log, 1.0 / 100.0, 1.0, window=4)
    assert bool(rep.stalled[0]) and not bool(rep.stalled[1])
    assert bool(rep.flagged[0]) and not bool(rep.flagged[1])
    # a resolved mask silences lanes that finished for non-gap reasons
    rep2 = check_contraction(log, 1.0 / 100.0, 1.0, window=4,
                             resolved=[True, False])
    assert rep2.ok


# -- 6. telemetry is bit-invariant -----------------------------------------

@pytest.mark.parametrize("op_kind", ["dense", "coo"])
def test_engine_results_bit_identical_metrics_on_vs_off(op_kind):
    """The conformance grid: mixed judge/bracket traffic, masked lanes,
    continuous + lockstep modes — every discrete outcome AND every
    bracket float must be bit-identical with telemetry fully on
    (metrics + spans + convergence log) vs fully off."""
    a, us, true, lam = _engine_problem(n=28, kappa=40.0, seed=5, k=7)
    n = a.shape[0]
    op = Dense(jnp.asarray(a)) if op_kind == "dense" \
        else sparse_from_dense(a)
    sv = BIFSolver.create(max_iters=n + 2, rtol=1e-4)
    mask = (np.random.default_rng(6).random(n) < 0.5).astype(float)

    def run(metrics_on, mode):
        if metrics_on:
            obs.enable()
            clog = obs.ConvergenceLog()
        else:
            obs.disable()
            clog = None
        try:
            eng = BIFEngine(op, solver=sv, max_batch=4, chunk_iters=3,
                            metrics=metrics_on, convergence_log=clog,
                            **lam)
            for i, u in enumerate(us):
                t = float(true[i] * (0.9 if i % 2 else 1.1)) \
                    if i % 3 else None
                eng.submit(BIFRequest(u=u, t=t,
                                      mask=mask if i == len(us) - 1
                                      else None))
            out = eng.flush(mode=mode)
        finally:
            obs.metrics.set_enabled(True)
            obs.spans.set_enabled(False)
        return eng, out

    for mode in ("continuous", "lockstep"):
        eng_on, on = run(True, mode)
        eng_off, off = run(False, mode)
        for i, (x, y) in enumerate(zip(on, off)):
            assert x.decision == y.decision, (mode, i)
            assert x.certified == y.certified, (mode, i)
            assert x.iterations == y.iterations, (mode, i)
            assert x.resolved == y.resolved, (mode, i)
            assert (x.lower, x.upper) == (y.lower, y.upper), (mode, i)
        # ... and the telemetry really was on/off respectively
        assert eng_on.stats()["counters"]["requests.submitted"] == len(us)
        assert eng_off.stats() == {"counters": {}, "gauges": {},
                                   "histograms": {}}
        if mode == "continuous":
            assert eng_on.convergence_log.rounds > 0


def test_solver_paths_bit_identical_with_and_without_log():
    n, kappa = 32, 80.0
    a = make_spd(n, kappa=kappa, seed=7)
    u = np.random.default_rng(8).standard_normal(n)
    kw = dict(lam_min=1.0 / kappa * 0.999, lam_max=1.001)
    for reorth in (False, True):
        solver = BIFSolver.create(max_iters=n, reorth=reorth)
        t1 = solver.trace(Dense(jnp.asarray(a)), jnp.asarray(u), 12,
                          convergence_log=obs.ConvergenceLog(), **kw)
        t2 = solver.trace(Dense(jnp.asarray(a)), jnp.asarray(u), 12, **kw)
        for f in ("gauss", "radau_lower", "radau_upper", "lobatto"):
            np.testing.assert_array_equal(np.asarray(getattr(t1, f)),
                                          np.asarray(getattr(t2, f)),
                                          err_msg=f"{reorth} {f}")
