"""Shape-cell table, applicability rules, and input-spec structure."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs
from repro.launch import shapes


def test_cell_table_exact():
    assert shapes.SHAPES["train_4k"].seq == 4096
    assert shapes.SHAPES["train_4k"].batch == 256
    assert shapes.SHAPES["prefill_32k"].seq == 32768
    assert shapes.SHAPES["prefill_32k"].batch == 32
    assert shapes.SHAPES["decode_32k"].seq == 32768
    assert shapes.SHAPES["decode_32k"].batch == 128
    assert shapes.SHAPES["long_500k"].seq == 524288
    assert shapes.SHAPES["long_500k"].batch == 1


def test_long500k_applicability():
    ok_archs = {a for a in list_archs()
                if shapes.cell_applicable(get_arch(a), "long_500k")[0]}
    assert ok_archs == {"falcon-mamba-7b", "zamba2-1.2b"}
    for a in list_archs():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shapes.cell_applicable(get_arch(a), s)[0]


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_input_specs_no_allocation(arch, shape):
    cfg = get_arch(arch)
    ok, _ = shapes.cell_applicable(cfg, shape)
    if not ok:
        pytest.skip("n/a")
    kind, specs = shapes.input_specs(cfg, shape)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    cell = shapes.SHAPES[shape]
    if kind == "train":
        toks = specs["batch"]["tokens"]
        assert toks.shape[0] == cell.batch
        if cfg.family == "vlm":
            tv = specs["batch"]["vision_embeds"].shape[1]
            assert toks.shape[1] + tv == cell.seq
        else:
            assert toks.shape[1] == cell.seq
    else:
        assert specs["batch"]["tokens"].shape == (cell.batch, 1)
        assert "caches" in specs


def test_delta_cfgs_units():
    """Delta-config unit math (replicated from dryrun to avoid importing
    the XLA_FLAGS-setting module in-process)."""
    for arch, expect_units in [("llama3-405b", 126), ("arctic-480b", 35),
                               ("llama4-maverick-400b-a17b", 24),
                               ("whisper-medium", 24.0)]:
        cfg = get_arch(arch)
        unit = {"moe": cfg.moe_every,
                "hybrid": cfg.hybrid_attn_every}.get(cfg.family, 1) or 1
        if cfg.family == "encdec":
            units = float(cfg.n_layers)
        else:
            units = cfg.n_layers / unit
        assert units == expect_units, (arch, units)
    z = get_arch("zamba2-1.2b")
    assert abs(z.n_layers / z.hybrid_attn_every - 38 / 6) < 1e-9
