"""Fused Lanczos-step megakernel parity (DESIGN.md Sec. 11).

``kernels/lanczos_step.py`` runs the whole quadrature iteration —
lane-stacked matvec, three-term Lanczos update, reorth projection, and
the GQL/Sherman-Morrison bracket recurrence — in one ``pallas_call``.
The contract: for every sandwich-decomposable operator the fused step
matches the reference composition (``gql.gql_step``) to 1e-12 on
gemm-backed paths, and operators WITHOUT a sandwich form (SparseCOO)
fall back to the reference composition bit-exactly. The 'fused' solver
backend must therefore be a drop-in: same iterations, same
certificates, brackets within 1e-12 everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BIFSolver, Dense, Jacobi, Masked, Shifted, \
    bell_from_dense, gql, sparse_from_dense
from repro.kernels import ops
from conftest import make_spd

SANDWICH_KINDS = ["dense", "sparse_bell", "masked", "shifted", "jacobi",
                  "masked_bell"]


def _operator(kind, a, rng):
    n = a.shape[0]
    if kind == "dense":
        return Dense(jnp.asarray(a))
    if kind == "sparse_coo":
        return sparse_from_dense(a)
    if kind == "sparse_bell":
        return bell_from_dense(a, bs=8)
    if kind == "masked":
        m = (rng.random(n) < 0.7).astype(np.float64)
        return Masked(Dense(jnp.asarray(a)), jnp.asarray(m))
    if kind == "masked_bell":
        m = (rng.random(n) < 0.7).astype(np.float64)
        return Masked(bell_from_dense(a, bs=8), jnp.asarray(m))
    if kind == "shifted":
        return Shifted(Dense(jnp.asarray(a)), jnp.asarray(0.75))
    if kind == "jacobi":
        return Jacobi.create(Dense(jnp.asarray(a)))
    raise AssertionError(kind)


def _problem(n=33, kappa=150.0, seed=0, lanes=4):
    a = make_spd(n, kappa=kappa, seed=seed, density=0.4)
    w = np.linalg.eigvalsh(a)
    us = np.random.default_rng(seed + 1).standard_normal((lanes, n))
    return a, jnp.asarray(us), float(w[0] * 0.5), float(w[-1] * 2.5)


def _assert_state_close(got, ref, what, *, bit_exact=False):
    for path, g, r in zip(
            [str(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(ref)[0]],
            jax.tree.leaves(got), jax.tree.leaves(ref)):
        g, r = np.asarray(g), np.asarray(r)
        if bit_exact or not np.issubdtype(r.dtype, np.floating):
            np.testing.assert_array_equal(g, r, f"{what}{path}")
        else:
            np.testing.assert_allclose(g, r, rtol=1e-12, atol=1e-12,
                                       err_msg=f"{what}{path}")


@pytest.mark.parametrize("op_kind", SANDWICH_KINDS)
def test_fused_step_matches_reference_composition(op_kind):
    """Step-by-step parity from the SAME state each iteration (no error
    accumulation): every GQLState leaf within 1e-12 of gql.gql_step."""
    rng = np.random.default_rng(3)
    a, us, lmn, lmx = _problem(seed=3)
    op = _operator(op_kind, a, rng)
    st = gql.gql_init(op, us, lmn, lmx)
    for i in range(8):
        fused = ops.gql_step_fused(op, st, lmn, lmx)
        refst = gql.gql_step(op, st, lmn, lmx)
        _assert_state_close(fused, refst, f"{op_kind}@{i}:")
        st = refst


def test_fused_step_coo_fallback_is_bit_exact():
    """No sandwich form -> the fused entry point IS the reference
    composition, bit for bit."""
    rng = np.random.default_rng(5)
    a, us, lmn, lmx = _problem(seed=5)
    op = _operator("sparse_coo", a, rng)
    st = gql.gql_init(op, us, lmn, lmx)
    for i in range(6):
        fused = ops.gql_step_fused(op, st, lmn, lmx)
        refst = gql.gql_step(op, st, lmn, lmx)
        _assert_state_close(fused, refst, f"coo@{i}:", bit_exact=True)
        st = refst


@pytest.mark.parametrize("batch", ["scalar", "grid"])
def test_fused_step_batch_shapes(batch):
    """Lane layouts beyond (K,): a single unbatched lane and a 2-D lane
    grid both round-trip the lane flattening."""
    rng = np.random.default_rng(7)
    a, us, lmn, lmx = _problem(seed=7)
    op = Dense(jnp.asarray(a))
    u = us[0] if batch == "scalar" else \
        jnp.broadcast_to(us, (3, 4, us.shape[-1]))
    st = gql.gql_init(op, u, lmn, lmx)
    for i in range(5):
        fused = ops.gql_step_fused(op, st, lmn, lmx)
        refst = gql.gql_step(op, st, lmn, lmx)
        _assert_state_close(fused, refst, f"{batch}@{i}:")
        st = refst


@pytest.mark.parametrize("op_kind", SANDWICH_KINDS + ["sparse_coo"])
def test_fused_backend_solver_is_drop_in(op_kind):
    """backend='fused' end to end: identical iterations/certificates,
    brackets within 1e-12 (bit-exact on the COO fallback)."""
    rng = np.random.default_rng(11)
    a, us, lmn, lmx = _problem(seed=11)
    op = _operator(op_kind, a, rng)
    ref = BIFSolver.create(max_iters=30, rtol=1e-6) \
        .solve(op, us, lam_min=lmn, lam_max=lmx)
    got = BIFSolver.create(max_iters=30, rtol=1e-6, backend="fused") \
        .solve(op, us, lam_min=lmn, lam_max=lmx)
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  np.asarray(ref.iterations))
    np.testing.assert_array_equal(np.asarray(got.certified),
                                  np.asarray(ref.certified))
    bit_exact = op_kind == "sparse_coo"
    for field in ("lower", "upper", "gauss_lower", "lobatto_upper"):
        g, r = np.asarray(getattr(got, field)), \
            np.asarray(getattr(ref, field))
        if bit_exact:
            np.testing.assert_array_equal(g, r, field)
        else:
            np.testing.assert_allclose(g, r, rtol=1e-12, atol=1e-12,
                                       err_msg=field)


def test_fused_backend_with_reorth_basis():
    """The in-kernel reorth projection against the banked basis matches
    the reference einsum pair (dense path only; BELL+basis falls back)."""
    a, us, lmn, lmx = _problem(seed=13, kappa=500.0)
    op = Dense(jnp.asarray(a))
    for backend in ("reference", "fused"):
        s = BIFSolver.create(max_iters=25, rtol=1e-10, reorth=True,
                             backend=backend)
        res = s.finalize(s.resume(s.init_state(op, us, lam_min=lmn,
                                               lam_max=lmx)))
        if backend == "reference":
            ref = res
    np.testing.assert_array_equal(np.asarray(res.iterations),
                                  np.asarray(ref.iterations))
    np.testing.assert_allclose(np.asarray(res.lower), np.asarray(ref.lower),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(res.upper), np.asarray(ref.upper),
                               rtol=1e-12, atol=1e-12)


def test_fused_backend_matfun_states():
    """fn != 'inv': the fused step only changes HOW alpha/beta are
    produced; the coefficient history and retrospective log bracket must
    match the reference backend within 1e-12."""
    a, us, lmn, lmx = _problem(n=24, seed=17)
    op = Dense(jnp.asarray(a))
    ref = BIFSolver.create(max_iters=24, rtol=1e-5, fn="log") \
        .solve(op, us, lam_min=lmn, lam_max=lmx)
    got = BIFSolver.create(max_iters=24, rtol=1e-5, fn="log",
                           backend="fused") \
        .solve(op, us, lam_min=lmn, lam_max=lmx)
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  np.asarray(ref.iterations))
    np.testing.assert_array_equal(np.asarray(got.certified),
                                  np.asarray(ref.certified))
    np.testing.assert_allclose(np.asarray(got.lower), np.asarray(ref.lower),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(got.upper), np.asarray(ref.upper),
                               rtol=1e-12, atol=1e-12)


def test_fused_backend_composes_with_cadence():
    """decide_every > 1 on the fused backend: the two tentpole halves
    compose — certificates match the R=1 reference run."""
    a, us, lmn, lmx = _problem(seed=19)
    op = Dense(jnp.asarray(a))
    ref = BIFSolver.create(max_iters=30, rtol=1e-6) \
        .solve(op, us, lam_min=lmn, lam_max=lmx)
    got = BIFSolver.create(max_iters=30, rtol=1e-6, backend="fused",
                           decide_every=4) \
        .solve(op, us, lam_min=lmn, lam_max=lmx)
    np.testing.assert_array_equal(np.asarray(got.certified),
                                  np.asarray(ref.certified))
    extra = np.asarray(got.iterations) - np.asarray(ref.iterations)
    assert np.all((extra >= 0) & (extra <= 3)), extra
