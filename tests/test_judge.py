"""The retrospective judges must reproduce exact-arithmetic decisions
(the paper's correctness claim for Alg. 2/4/7/9) while spending far
fewer iterations than full tridiagonalization."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import BIFSolver, Dense, Masked
from conftest import make_spd


def _exact_bif(a, u):
    return u @ np.linalg.solve(a, u)


# Thin local wrappers keeping the original positional signatures; the
# module-level shims they mirror were removed per DESIGN.md Sec. 5.
def judge_threshold(op, u, t, lam_min, lam_max, *, max_iters):
    return BIFSolver.create(max_iters=max_iters).judge_threshold(
        op, u, t, lam_min=lam_min, lam_max=lam_max)


def judge_kdpp_swap(op_a, u, op_b, v, t, p, lam_min, lam_max, *, max_iters):
    return BIFSolver.create(max_iters=max_iters).judge_kdpp_swap(
        op_a, u, op_b, v, t, p, lam_min=lam_min, lam_max=lam_max)


def judge_double_greedy(op_x, u, op_y, v, t, p, lam_min, lam_max, *,
                        max_iters):
    return BIFSolver.create(max_iters=max_iters).judge_double_greedy(
        op_x, u, op_y, v, t, p, lam_min=lam_min, lam_max=lam_max)


@given(seed=st.integers(0, 200))
def test_threshold_judge_matches_exact(seed):
    n = 40
    a = make_spd(n, kappa=200.0, seed=seed)
    w = np.linalg.eigvalsh(a)
    rng = np.random.default_rng(seed)
    u = rng.standard_normal(n)
    true = _exact_bif(a, u)
    # thresholds straddling the true value at many scales
    ts = true + np.array([-1.0, -1e-3, 1e-3, 1.0]) * max(abs(true), 1.0)
    res = judge_threshold(
        Dense(jnp.broadcast_to(jnp.asarray(a), (4, n, n))),
        jnp.broadcast_to(jnp.asarray(u), (4, n)), jnp.asarray(ts),
        w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2)
    np.testing.assert_array_equal(np.asarray(res.decision), ts < true)
    assert np.asarray(res.certified).all()


def test_judge_early_exit_iterations():
    """Far thresholds should resolve in O(1) iterations (the speedup)."""
    n = 120
    a = make_spd(n, kappa=100.0, seed=1)
    w = np.linalg.eigvalsh(a)
    u = np.random.default_rng(1).standard_normal(n)
    true = _exact_bif(a, u)
    res_far = judge_threshold(Dense(jnp.asarray(a)), jnp.asarray(u),
                              jnp.asarray(true * 10), w[0] * 0.99,
                              w[-1] * 1.01, max_iters=n + 2)
    res_near = judge_threshold(Dense(jnp.asarray(a)), jnp.asarray(u),
                               jnp.asarray(true * 0.999), w[0] * 0.99,
                               w[-1] * 1.01, max_iters=n + 2)
    assert int(res_far.iterations) <= 10
    assert int(res_far.iterations) < int(res_near.iterations)
    assert not bool(res_far.decision)
    assert bool(res_near.decision)


@given(seed=st.integers(0, 100))
def test_kdpp_judge_matches_exact(seed):
    n = 30
    a = make_spd(n, kappa=100.0, seed=seed)
    w = np.linalg.eigvalsh(a)
    rng = np.random.default_rng(seed + 7)
    mask = (rng.random(n) < 0.5).astype(np.float64)
    mask[:2] = [1.0, 0.0]
    u = rng.standard_normal(n) * mask
    v = rng.standard_normal(n) * mask
    p = float(rng.uniform(0.05, 0.95))
    a_sub = a * np.outer(mask, mask) + np.diag(1.0 - mask)
    bif_u, bif_v = _exact_bif(a_sub, u), _exact_bif(a_sub, v)
    t = float(p * bif_v - bif_u)
    for off in (-0.5, 0.5):
        op = Masked(Dense(jnp.asarray(a)), jnp.asarray(mask))
        res = judge_kdpp_swap(op, jnp.asarray(u), op, jnp.asarray(v),
                              jnp.asarray(t + off), jnp.asarray(p),
                              w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2)
        assert bool(res.decision) == (t + off < p * bif_v - bif_u)


@given(seed=st.integers(0, 100))
def test_dg_judge_matches_exact(seed):
    n = 24
    a = make_spd(n, kappa=50.0, seed=seed)
    # normalize so diag schur complements are positive and O(1)
    d = np.sqrt(np.diag(a))
    a = a / np.outer(d, d) + 0.05 * np.eye(n)
    w = np.linalg.eigvalsh(a)
    rng = np.random.default_rng(seed + 3)
    x_mask = np.zeros(n)
    x_mask[rng.choice(n, 5, replace=False)] = 1.0
    y_mask = np.ones(n)
    y_mask[rng.choice(n, 3, replace=False)] = 0.0
    i = int(np.argmax(x_mask == 0))
    x_mask[i] = 0.0
    y_mask[i] = 0.0
    col = a[:, i]
    u = col * x_mask
    v = col * y_mask
    t = a[i, i]
    p = float(rng.uniform(0.05, 0.95))
    ax = a * np.outer(x_mask, x_mask) + np.diag(1 - x_mask)
    ay = a * np.outer(y_mask, y_mask) + np.diag(1 - y_mask)
    gain_p = np.log(max(t - _exact_bif(ax, u), 1e-300))
    gain_m = -np.log(max(t - _exact_bif(ay, v), 1e-300))
    exact_add = p * max(gain_m, 0.0) <= (1 - p) * max(gain_p, 0.0)
    res = judge_double_greedy(
        Masked(Dense(jnp.asarray(a)), jnp.asarray(x_mask)), jnp.asarray(u),
        Masked(Dense(jnp.asarray(a)), jnp.asarray(y_mask)), jnp.asarray(v),
        jnp.asarray(t), jnp.asarray(p), w[0] * 0.99, w[-1] * 1.01,
        max_iters=n + 2)
    assert bool(res.decision) == exact_add
