"""Resumable quadrature runtime (DESIGN.md Sec. 8).

The contract: ``BIFSolver.init_state / step_n / resume / finalize`` are
the single source of truth the closed drivers are rebuilt on, and an
interrupted-and-resumed solve reproduces the uninterrupted one —
brackets/decisions bit-exact on SparseCOO (shape-independent scatter
matvec) and to 1e-12 on gemm-backed operators — for EVERY operator the
conformance suite covers. ``trace(n)`` must equal n resumed
``step_n(1)`` brackets bit-exactly, reorth on and off, including the
``num_iters=1`` edge. (The 8-virtual-device sharded twin of these
checks lives in tests/sharded_check.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BIFSolver, Dense, Jacobi, Masked, QuadState, \
    Shifted, bell_from_dense, gql, sparse_from_dense
from conftest import make_spd

OP_KINDS = ["dense", "sparse_coo", "sparse_bell", "masked", "shifted",
            "jacobi"]


def _operator(kind, a, rng):
    n = a.shape[0]
    if kind == "dense":
        return Dense(jnp.asarray(a))
    if kind == "sparse_coo":
        return sparse_from_dense(a)
    if kind == "sparse_bell":
        return bell_from_dense(a, bs=8)
    if kind == "masked":
        m = (rng.random(n) < 0.7).astype(np.float64)
        return Masked(Dense(jnp.asarray(a)), jnp.asarray(m))
    if kind == "shifted":
        return Shifted(Dense(jnp.asarray(a)), jnp.asarray(0.75))
    if kind == "jacobi":
        return Jacobi.create(Dense(jnp.asarray(a)))
    raise AssertionError(kind)


def _problem(n=33, kappa=150.0, seed=0):
    a = make_spd(n, kappa=kappa, seed=seed, density=0.4)
    w = np.linalg.eigvalsh(a)
    us = np.random.default_rng(seed + 1).standard_normal((4, n))
    return a, jnp.asarray(us), float(w[0] * 0.5), float(w[-1] * 2.5)


def _assert_result_parity(ref, got, bit_exact, what):
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  np.asarray(ref.iterations), what)
    np.testing.assert_array_equal(np.asarray(got.certified),
                                  np.asarray(ref.certified), what)
    np.testing.assert_array_equal(np.asarray(got.converged),
                                  np.asarray(ref.converged), what)
    for field in ("lower", "upper", "gauss_lower", "lobatto_upper"):
        b = np.asarray(getattr(got, field))
        s = np.asarray(getattr(ref, field))
        if bit_exact:
            np.testing.assert_array_equal(b, s, f"{what}.{field}")
        else:
            np.testing.assert_allclose(b, s, rtol=1e-12,
                                       err_msg=f"{what}.{field}")


@pytest.mark.parametrize("op_kind", OP_KINDS)
def test_interrupted_resume_matches_uninterrupted_solve(op_kind):
    """step_n checkpoints at several depths, then resume: the final
    SolveResult must reproduce the uninterrupted solve for every
    conformance operator (the masked/jacobi wrappers exercise prepared-
    operator state; BELL the kernel-backed matvec)."""
    rng = np.random.default_rng(3)
    a, us, lmn, lmx = _problem(seed=3)
    op = _operator(op_kind, a, rng)
    s = BIFSolver.create(max_iters=30, rtol=1e-6)
    ref = s.solve(op, us, lam_min=lmn, lam_max=lmx)
    state = s.init_state(op, us, lam_min=lmn, lam_max=lmx)
    for k in (1, 2, 5):
        state = s.step_n(state, k)
    got = s.finalize(s.resume(state))
    _assert_result_parity(ref, got, op_kind == "sparse_coo", op_kind)


@pytest.mark.parametrize("op_kind", ["dense", "sparse_coo"])
def test_interrupted_resume_matches_threshold_judge(op_kind):
    """Decisions (not just brackets) survive interruption: a threshold
    decide stepped in pieces lands on the identical JudgeResult."""
    rng = np.random.default_rng(5)
    a, us, lmn, lmx = _problem(seed=5)
    op = _operator(op_kind, a, rng)
    true = np.einsum("ki,ki->k", np.asarray(us),
                     np.linalg.solve(a, np.asarray(us).T).T)
    t = jnp.asarray(true * np.array([0.7, 0.999, 1.001, 1.3]))
    s = BIFSolver.create(max_iters=35)
    ref = s.judge_threshold(op, us, t, lam_min=lmn, lam_max=lmx)

    def decide(lo, hi):
        return (t < lo) | (t >= hi)

    state = s.init_state(op, us, lam_min=lmn, lam_max=lmx)
    state = s.step_n(state, 4, decide)
    res = s.finalize(s.resume(state, decide), decide)
    decision = BIFSolver.threshold_decision(t, res.lower, res.upper)
    np.testing.assert_array_equal(np.asarray(decision),
                                  np.asarray(ref.decision))
    np.testing.assert_array_equal(np.asarray(res.iterations),
                                  np.asarray(ref.iterations))
    np.testing.assert_array_equal(np.asarray(res.certified),
                                  np.asarray(ref.certified))


@pytest.mark.parametrize("op_kind", ["dense", "sparse_coo"])
@pytest.mark.parametrize("reorth", [False, True])
def test_trace_equals_stepped_brackets_bit_exact(op_kind, reorth):
    """trace(n) == n x step_n(1) resumed brackets, bit-exact — the
    satellite pin for checkpointed stepping, reorth on and off."""
    rng = np.random.default_rng(7)
    a, us, lmn, lmx = _problem(seed=7)
    op = _operator(op_kind, a, rng)
    u = us[0]
    num_iters = 12
    s = BIFSolver.create(max_iters=num_iters, reorth=reorth)
    tr = s.trace(op, u, num_iters, lam_min=lmn, lam_max=lmx)

    never = lambda lo, hi: jnp.zeros(jnp.shape(lo), bool)  # noqa: E731
    state = s.init_state(op, u, lam_min=lmn, lam_max=lmx,
                         basis_rows=num_iters + 1)
    rows = [state]
    for _ in range(num_iters - 1):
        state = s.step_n(state, 1, never)
        rows.append(state)

    got = {
        "gauss": [gql.lower_bound_gauss(st.st) for st in rows],
        "radau_lower": [st.lower for st in rows],
        "radau_upper": [st.upper for st in rows],
        "lobatto": [gql.upper_bound_lobatto(st.st) for st in rows],
    }
    for field in got:
        np.testing.assert_array_equal(
            np.asarray(jnp.stack(got[field])),
            np.asarray(getattr(tr, field)), field)
    # per-step iteration accounting matches the row index
    assert int(rows[-1].it) == num_iters
    assert int(rows[-1].step) == num_iters - 1


def test_trace_num_iters_one_edge_matches_init_state():
    rng = np.random.default_rng(9)
    a, us, lmn, lmx = _problem(seed=9)
    for reorth in (False, True):
        s = BIFSolver.create(max_iters=4, reorth=reorth)
        tr = s.trace(Dense(jnp.asarray(a)), us[0], 1, lam_min=lmn,
                     lam_max=lmx)
        st = s.init_state(Dense(jnp.asarray(a)), us[0], lam_min=lmn,
                          lam_max=lmx, basis_rows=2)
        assert tr.gauss.shape == (1,)
        np.testing.assert_array_equal(np.asarray(tr.radau_lower[0]),
                                      np.asarray(st.lower))
        np.testing.assert_array_equal(np.asarray(tr.radau_upper[0]),
                                      np.asarray(st.upper))
        # step_n(0) is the identity on the checkpoint
        st0 = s.step_n(st, 0)
        assert st0 is st


def test_resume_chunked_and_it_cap_semantics():
    rng = np.random.default_rng(11)
    a, us, lmn, lmx = _problem(seed=11, kappa=400.0)
    op = _operator("sparse_coo", a, rng)
    s = BIFSolver.create(max_iters=30, rtol=1e-8)
    ref = s.resume(s.init_state(op, us, lam_min=lmn, lam_max=lmx))
    # chunked decision rounds are bit-exact with the monolithic drive
    chk = s.resume_chunked(s.init_state(op, us, lam_min=lmn, lam_max=lmx),
                           chunk_iters=4)
    np.testing.assert_array_equal(np.asarray(ref.lower),
                                  np.asarray(chk.lower))
    np.testing.assert_array_equal(np.asarray(ref.it), np.asarray(chk.it))
    # per-lane iteration budgets freeze lanes at their cap...
    cap = jnp.asarray([3, 5, 30, 1], jnp.int32)
    part = s.resume(s.init_state(op, us, lam_min=lmn, lam_max=lmx),
                    it_cap=cap)
    assert np.all(np.asarray(part.it) <= np.asarray(cap))
    # ...and lifting the cap resumes to the same endpoint bit-exactly
    full = s.resume(part)
    np.testing.assert_array_equal(np.asarray(full.lower),
                                  np.asarray(ref.lower))
    np.testing.assert_array_equal(np.asarray(full.it), np.asarray(ref.it))
    # finalize reports a budget-interrupted state as uncertified
    assert not np.all(np.asarray(s.finalize(part).certified))
    assert np.all(np.asarray(s.finalize(full).certified))


def test_quadstate_is_a_jittable_checkpoint():
    """QuadState crosses jit/flatten boundaries: stepping inside jit
    matches eager stepping, and a flatten/unflatten round-trip preserves
    the resume."""
    rng = np.random.default_rng(13)
    a, us, lmn, lmx = _problem(seed=13)
    op = _operator("sparse_coo", a, rng)
    s = BIFSolver.create(max_iters=25, rtol=1e-6)
    state = s.init_state(op, us, lam_min=lmn, lam_max=lmx)
    eager = s.step_n(state, 5)
    jitted = jax.jit(lambda st: s.step_n(st, 5))(state)
    np.testing.assert_array_equal(np.asarray(eager.lower),
                                  np.asarray(jitted.lower))
    leaves, treedef = jax.tree.flatten(eager)
    back = jax.tree.unflatten(treedef, leaves)
    assert isinstance(back, QuadState)
    ref = s.finalize(s.resume(eager))
    got = s.finalize(s.resume(back))
    np.testing.assert_array_equal(np.asarray(ref.lower),
                                  np.asarray(got.lower))


def test_judge_argmax_prior_upper_prunes_and_stays_certified():
    """Banked prior upper bounds shorten the race (dominance and the
    winner's certificate both use the clamped uppers) without changing
    the certified winner — the lazy-greedy mechanism of Sec. 8.3."""
    n = 32
    rng = np.random.default_rng(0)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    evals = np.geomspace(1e-3, 1.0, n)
    a = (q * evals) @ q.T
    op = Dense(jnp.asarray(a))
    # two near-tied leaders (long certification race) + decoys mixing
    # extreme eigvecs (wide first-iteration brackets)
    k = 8
    us = np.zeros((k, n))
    us[0] = rng.standard_normal(n)
    us[1] = us[0] + 0.02 * rng.standard_normal(n)
    for i in range(2, k):
        us[i] = q[:, 0] + q[:, -1] * (0.5 + 0.1 * i)
    us = jnp.asarray(us)
    true = np.einsum("ki,ki->k", np.asarray(us),
                     np.linalg.solve(a, np.asarray(us).T).T)
    s = BIFSolver.create(max_iters=40)
    base = s.judge_argmax(op, us, lam_min=1e-3 * 0.99, lam_max=1.01)
    prior = jnp.asarray(true * 1.001)  # banked (barely loose) uppers
    warm = s.judge_argmax(op, us, prior_upper=prior, lam_min=1e-3 * 0.99,
                          lam_max=1.01)
    assert int(warm.index) == int(base.index) == int(np.argmax(true))
    assert bool(warm.certified) and bool(base.certified)
    assert int(jnp.sum(warm.iterations)) < int(jnp.sum(base.iterations))


def test_greedy_map_warm_start_certified_identical():
    """Lazy-greedy priors never change the selection (still certified
    exact) and never cost extra iterations."""
    from repro.core import greedy_map
    rng = np.random.default_rng(0)
    centers = rng.standard_normal((6, 4)) * 3.0
    pts = np.concatenate(
        [c + 0.15 * rng.standard_normal((8, 4)) for c in centers])
    d2 = ((pts[:, None] - pts[None, :]) ** 2).sum(-1)
    kmat = np.exp(-d2 / 2.0) + 1e-4 * np.eye(len(pts))
    w = np.linalg.eigvalsh(kmat)
    op = Dense(jnp.asarray(kmat))
    base = greedy_map(op, 8, w[0] * 0.99, w[-1] * 1.01, max_iters=50)
    warm = greedy_map(op, 8, w[0] * 0.99, w[-1] * 1.01, max_iters=50,
                      warm_start=True)
    exact = greedy_map(op, 8, w[0] * 0.99, w[-1] * 1.01, max_iters=50,
                       exact=True)
    np.testing.assert_array_equal(np.asarray(warm.order),
                                  np.asarray(exact.order))
    np.testing.assert_array_equal(np.asarray(warm.order),
                                  np.asarray(base.order))
    assert int(warm.uncertified) == 0
    assert int(warm.quad_iterations) <= int(base.quad_iterations)


def test_kdpp_step_chunked_decision_rounds_bit_exact():
    from repro.core import dpp
    n = 28
    a = make_spd(n, kappa=60.0, seed=7)
    d = np.sqrt(np.diag(a))
    a = a / np.outer(d, d) + 0.1 * np.eye(n)
    w = np.linalg.eigvalsh(a)
    op = Dense(jnp.asarray(a))
    st = dpp.init_chain(jax.random.key(0), jnp.zeros(n).at[:5].set(1.0))
    ref = dpp.kdpp_step(op, st, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2)
    chk = dpp.kdpp_step(op, st, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2,
                        chunk_iters=3)
    np.testing.assert_array_equal(np.asarray(ref.mask), np.asarray(chk.mask))
    assert int(ref.stats.quad_iterations) == int(chk.stats.quad_iterations)
    with pytest.raises(ValueError, match="chunk_iters"):
        dpp.kdpp_step(op, st, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2,
                      chunk_iters=3, batched=False)


def test_rank_blocks_two_phase_matches_single_phase():
    """Coarse-budget + banked-state refinement reproduces the single-pass
    ranking; refined blocks RESUME (total iterations don't exceed the
    single-pass count — nothing is re-solved from scratch)."""
    from repro.serve import rank_blocks
    rng = np.random.default_rng(11)
    keys = rng.standard_normal((24 * 4, 8)).astype(np.float32)
    o1, s1 = rank_blocks(keys, block=4, max_batch=8, bucket=32)
    o2, s2 = rank_blocks(keys, block=4, max_batch=8, bucket=32,
                         coarse_iters=3)
    np.testing.assert_array_equal(o1, o2)
    assert s2["refined"] >= 0
    assert s2["iterations"] <= s1["iterations"]
    assert s2["resolved"] >= s2["blocks"] - s2["refined"]
