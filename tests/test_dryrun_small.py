"""Dry-run machinery on a small forced-device mesh (subprocess): proves
the lower/compile/analyze path works end-to-end for each step kind
without the 512-device production mesh."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "{src}")
import json
import jax
from repro.configs import get_arch
from repro.launch import dryrun  # safe: we already set XLA_FLAGS
from repro.launch import shapes as shapes_mod
from repro.sharding import api as shapi
import dataclasses

cfg = get_arch("{arch}").reduced()
# shrink the shape cell for CPU compile
shapes_mod.SHAPES = dict(shapes_mod.SHAPES)
shapes_mod.SHAPES["tiny"] = shapes_mod.ShapeCell("tiny", "{kind}", 64, 8)
mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = shapi.tp_plan(data_axes=("data",), model_axis="model", fsdp=False)
compiled, kind, (tl, tc) = dryrun._lower_and_compile(
    cfg, "tiny", mesh, plan)
m = dryrun._measure(compiled)
mem = compiled.memory_analysis()
assert m["flops"] > 0
assert kind == "{kind}"
print("OK", json.dumps({{"flops": m["flops"], "coll": m["coll"],
                        "temp": int(mem.temp_size_in_bytes)}}))
"""


@pytest.mark.parametrize("arch,kind", [
    ("olmo-1b", "train"),
    ("llama3-405b", "prefill"),
    ("falcon-mamba-7b", "decode"),
    ("zamba2-1.2b", "train"),
    ("whisper-medium", "prefill"),
    ("qwen2-vl-2b", "decode"),
    ("arctic-480b", "train"),
])
def test_dryrun_cell_small_mesh(arch, kind):
    src = str(Path(__file__).resolve().parent.parent / "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=src, arch=arch,
                                             kind=kind)],
        capture_output=True, text=True, timeout=600)
    assert "OK" in out.stdout, (out.stdout[-800:], out.stderr[-3000:])
    payload = json.loads(out.stdout.split("OK", 1)[1])
    assert payload["flops"] > 0


def test_collective_bytes_parser():
    from repro.utils.hlo import collective_bytes
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), dims={0}
  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%sum
  %rs = f32[32]{0} reduce-scatter(f32[256]{0} %z), dimensions={0}
  %cp = f32[64]{0} collective-permute(f32[64]{0} %w)
  %a2a = bf16[4,64]{1,0} all-to-all(bf16[4,64]{1,0} %v), dimensions={0}
  %ard = f32[256]{0} all-reduce-done(f32[256]{0} %ars)
  %dot = f32[8,8]{1,0} dot(f32[8,8] %a, f32[8,8] %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2          # larger buffer
    assert out["all-reduce"] == 2 * 256 * 4          # 2x ring multiplier
    assert out["reduce-scatter"] == 256 * 4
    assert out["collective-permute"] == 64 * 4
    assert out["all-to-all"] == 4 * 64 * 2
    assert out["count"] == 5
