"""Matrix-function quadrature (core/matfun.py, DESIGN.md Sec. 9).

The contract, pinned against dense-eigendecomposition oracles (never
the quadrature itself):

  (a) for f in {inv, log, invsqrt} on every conformance-grid operator,
      all four quadrature estimates bracket the dense ``eigh`` truth at
      EVERY iteration, with the tight (Radau) bracket inside the loose
      (Gauss/Lobatto) one — i.e. the registry's derivative-sign ->
      orientation table is right;
  (b) with reorth=True the brackets tighten monotonically (the
      tests/test_convergence.py discipline, generalized beyond 1/x);
  (c) matfun QuadStates satisfy the PR-4 resume invariant:
      ``resume(step_n(st, k)) == resume(st)`` including the coefficient
      history, chunked decision rounds, it_cap budgets, and jit/flatten
      round-trips;
  (d) ``fn='inv'`` (the default) IS the legacy GQL path — bit-exact,
      no coefficient tracking — while the eigensolve route evaluated at
      the registry's inv entry reproduces the legacy Radau bracket to
      float tolerance (two independent evaluations of the same rules).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BIFSolver, Dense, Jacobi, Masked, QuadState, \
    Shifted, bell_from_dense, matfun, sparse_from_dense
from repro.serve import BIFEngine, BIFRequest
from conftest import make_spd

OP_KINDS = ["dense", "sparse_coo", "sparse_bell", "masked", "shifted",
            "jacobi"]
FNS = ["inv", "log", "invsqrt"]
_F = {"inv": lambda x: 1.0 / x, "log": np.log,
      "invsqrt": lambda x: x ** -0.5, "sqrt": np.sqrt}

# same slack discipline as tests/test_convergence.py
_SLACK = 1e-8


def _operator_and_dense(kind, a, rng):
    """(operator, dense equivalent matrix) — the oracle diagonalizes
    the SAME matrix the operator applies."""
    n = a.shape[0]
    if kind == "dense":
        return Dense(jnp.asarray(a)), a
    if kind == "sparse_coo":
        return sparse_from_dense(a), a
    if kind == "sparse_bell":
        return bell_from_dense(a, bs=8), a
    if kind == "masked":
        m = (rng.random(n) < 0.7).astype(np.float64)
        eq = a * np.outer(m, m) + np.diag(1.0 - m)
        return Masked(Dense(jnp.asarray(a)), jnp.asarray(m)), eq
    if kind == "shifted":
        return Shifted(Dense(jnp.asarray(a)), jnp.asarray(0.75)), \
            a + 0.75 * np.eye(n)
    if kind == "jacobi":
        c = 1.0 / np.sqrt(np.diag(a))
        return Jacobi.create(Dense(jnp.asarray(a))), a * np.outer(c, c)
    raise AssertionError(kind)


def _problem(n=33, kappa=150.0, seed=0):
    a = make_spd(n, kappa=kappa, seed=seed, density=0.4)
    u = np.random.default_rng(seed + 1).standard_normal(n)
    return a, u


def _truth(eq, u, f):
    w, v = np.linalg.eigh(eq)
    c = v.T @ u
    return float(np.sum(c * c * f(w)))


# ------------------------------------------------ (a)+(b): containment

@pytest.mark.parametrize("op_kind", OP_KINDS)
@pytest.mark.parametrize("fn", FNS)
def test_brackets_contain_eigh_truth_and_tighten(op_kind, fn):
    rng = np.random.default_rng(3)
    a, u = _problem(seed=3)
    op, eq = _operator_and_dense(op_kind, a, rng)
    w = np.linalg.eigvalsh(eq)
    lmn, lmx = float(w[0] * 0.999), float(w[-1] * 1.001)
    true = _truth(eq, u, _F[fn])
    scale = max(abs(true), 1.0)

    s = BIFSolver.create(max_iters=40, fn=fn, reorth=True)
    tr = s.trace(op, jnp.asarray(u), 24, lam_min=lmn, lam_max=lmx)
    lower = np.asarray(tr.radau_lower)
    upper = np.asarray(tr.radau_upper)
    loose_lo = np.asarray(tr.gauss)     # oriented loose lower (Sec. 9)
    loose_hi = np.asarray(tr.lobatto)   # oriented loose upper

    # (a) every iterate brackets the eigendecomposition truth, and the
    # loose family sits outside the tight one (orientation table)
    assert np.all(lower <= true + _SLACK * scale)
    assert np.all(upper >= true - _SLACK * scale)
    assert np.all(loose_lo <= lower + _SLACK * scale)
    assert np.all(loose_hi >= upper - _SLACK * scale)

    # (b) monotone tightening under reorthogonalization
    assert np.all(np.diff(lower) >= -_SLACK * scale)
    assert np.all(np.diff(upper) <= _SLACK * scale)
    # and the final bracket is genuinely tight
    assert upper[-1] - lower[-1] <= 1e-5 * scale


def test_registry_orientation_table():
    """The derivative-sign table: completely monotone f (inv, invsqrt)
    keep Gauss in the lower family; log/sqrt swap families. All four
    registered f carry guaranteed bounds."""
    assert matfun.REGISTRY["inv"].gauss_is_lower
    assert matfun.REGISTRY["invsqrt"].gauss_is_lower
    assert not matfun.REGISTRY["log"].gauss_is_lower
    assert not matfun.REGISTRY["sqrt"].gauss_is_lower
    assert all(f.guaranteed for f in matfun.REGISTRY.values())
    with pytest.raises(ValueError, match="fn must be one of"):
        matfun.fn_index("exp")
    with pytest.raises(ValueError, match="fn must be one of"):
        BIFSolver.create(fn="nope")
    with pytest.raises(ValueError, match="precondition"):
        BIFSolver.create(fn="log", precondition="jacobi")


# ------------------------------------------------ (c): resume invariant

def _assert_result_parity(ref, got, bit_exact, what):
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  np.asarray(ref.iterations), what)
    np.testing.assert_array_equal(np.asarray(got.certified),
                                  np.asarray(ref.certified), what)
    for field in ("lower", "upper", "gauss_lower", "lobatto_upper"):
        b = np.asarray(getattr(got, field))
        s = np.asarray(getattr(ref, field))
        if bit_exact:
            np.testing.assert_array_equal(b, s, f"{what}.{field}")
        else:
            np.testing.assert_allclose(b, s, rtol=1e-12,
                                       err_msg=f"{what}.{field}")


@pytest.mark.parametrize("op_kind", ["dense", "sparse_coo", "sparse_bell"])
def test_interrupted_resume_matches_uninterrupted(op_kind):
    rng = np.random.default_rng(5)
    a, _ = _problem(seed=5)
    us = np.random.default_rng(6).standard_normal((4, a.shape[0]))
    w = np.linalg.eigvalsh(a)
    lmn, lmx = float(w[0] * 0.5), float(w[-1] * 2.5)
    op, _ = _operator_and_dense(op_kind, a, rng)
    s = BIFSolver.create(max_iters=30, rtol=1e-6, fn="log")
    ref = s.solve(op, jnp.asarray(us), lam_min=lmn, lam_max=lmx)
    state = s.init_state(op, jnp.asarray(us), lam_min=lmn, lam_max=lmx)
    for k in (1, 2, 5):
        state = s.step_n(state, k)
    got = s.finalize(s.resume(state))
    _assert_result_parity(ref, got, op_kind == "sparse_coo", op_kind)
    # the coefficient history is part of the checkpoint contract
    assert got.state.coeffs is not None
    np.testing.assert_array_equal(np.asarray(got.state.coeffs.fnidx),
                                  np.asarray(ref.state.coeffs.fnidx))
    np.testing.assert_array_equal(np.asarray(got.state.coeffs.alphas),
                                  np.asarray(ref.state.coeffs.alphas))


def test_chunked_caps_and_jit_checkpoints():
    a, _ = _problem(seed=11, kappa=400.0)
    us = np.random.default_rng(12).standard_normal((4, a.shape[0]))
    w = np.linalg.eigvalsh(a)
    lmn, lmx = float(w[0] * 0.9), float(w[-1] * 1.1)
    op = sparse_from_dense(a)
    s = BIFSolver.create(max_iters=30, rtol=1e-8, fn="invsqrt")
    ref = s.resume(s.init_state(op, jnp.asarray(us), lam_min=lmn,
                                lam_max=lmx))
    chk = s.resume_chunked(
        s.init_state(op, jnp.asarray(us), lam_min=lmn, lam_max=lmx),
        chunk_iters=4)
    np.testing.assert_array_equal(np.asarray(ref.lower),
                                  np.asarray(chk.lower))
    np.testing.assert_array_equal(np.asarray(ref.it), np.asarray(chk.it))
    # per-lane budgets freeze, lifting resumes to the same endpoint
    cap = jnp.asarray([3, 5, 30, 1], jnp.int32)
    part = s.resume(s.init_state(op, jnp.asarray(us), lam_min=lmn,
                                 lam_max=lmx), it_cap=cap)
    assert np.all(np.asarray(part.it) <= np.asarray(cap))
    full = s.resume(part)
    np.testing.assert_array_equal(np.asarray(full.lower),
                                  np.asarray(ref.lower))
    # jit + flatten round-trips keep the coeff history working
    state = s.init_state(op, jnp.asarray(us), lam_min=lmn, lam_max=lmx)
    eager = s.step_n(state, 5)
    jitted = jax.jit(lambda st: s.step_n(st, 5))(state)
    np.testing.assert_array_equal(np.asarray(eager.lower),
                                  np.asarray(jitted.lower))
    leaves, treedef = jax.tree.flatten(eager)
    back = jax.tree.unflatten(treedef, leaves)
    assert isinstance(back, QuadState)
    np.testing.assert_array_equal(
        np.asarray(s.finalize(s.resume(back)).lower),
        np.asarray(s.finalize(s.resume(eager)).lower))


def test_threshold_judge_on_matfun_brackets():
    """Alg.-4 judges work unchanged on u^T log(A) u: decisions against
    dense-truth-derived thresholds come back certified-correct."""
    a, u = _problem(seed=7)
    w = np.linalg.eigvalsh(a)
    lmn, lmx = float(w[0] * 0.99), float(w[-1] * 1.01)
    us = np.stack([u] * 4)
    true = _truth(a, u, np.log)
    # log values are negative here; margins on both sides
    t = jnp.asarray(np.array([true - 3.0, true - 0.1, true + 0.1,
                              true + 3.0]))
    s = BIFSolver.create(max_iters=40, fn="log")
    res = s.judge_batch(Dense(jnp.asarray(a)), jnp.asarray(us), t,
                        lam_min=lmn, lam_max=lmx)
    np.testing.assert_array_equal(np.asarray(res.decision),
                                  np.asarray(t) < true)
    assert np.all(np.asarray(res.certified))


# ------------------------------------------------ (d): fn='inv' parity

def test_fn_inv_is_bit_exact_legacy_and_untracked():
    a, _ = _problem(seed=9)
    us = np.random.default_rng(10).standard_normal((3, a.shape[0]))
    w = np.linalg.eigvalsh(a)
    lmn, lmx = float(w[0] * 0.9), float(w[-1] * 1.1)
    op = sparse_from_dense(a)
    legacy = BIFSolver.create(max_iters=30, rtol=1e-8)
    tagged = BIFSolver.create(max_iters=30, rtol=1e-8, fn="inv")
    r0 = legacy.solve(op, jnp.asarray(us), lam_min=lmn, lam_max=lmx)
    r1 = tagged.solve(op, jnp.asarray(us), lam_min=lmn, lam_max=lmx)
    assert r1.state.coeffs is None  # no tracking overhead on the default
    _assert_result_parity(r0, r1, True, "inv-tag")


def test_eigensolve_route_reproduces_inv_recurrence():
    """Evaluating the registry's inv entry on a tracked coefficient
    history reproduces the Sherman-Morrison Radau bracket to float
    tolerance — the eigensolve and the recurrence are two evaluations
    of the same quadrature rules."""
    a, _ = _problem(seed=13)
    us = np.random.default_rng(14).standard_normal((3, a.shape[0]))
    w = np.linalg.eigvalsh(a)
    lmn, lmx = float(w[0] * 0.9), float(w[-1] * 1.1)
    op = Dense(jnp.asarray(a))
    never = lambda lo, hi: jnp.zeros(jnp.shape(lo), bool)  # noqa: E731
    tracked = BIFSolver.create(max_iters=12, fn="log")
    legacy = BIFSolver.create(max_iters=12)
    st_t = tracked.init_state(op, jnp.asarray(us), lam_min=lmn,
                              lam_max=lmx)
    st_l = legacy.init_state(op, jnp.asarray(us), lam_min=lmn,
                             lam_max=lmx)
    for _ in range(8):
        st_t = tracked.step_n(st_t, 1, never)
        st_l = legacy.step_n(st_l, 1, never)
        as_inv = dataclasses.replace(
            st_t.coeffs, fnidx=jnp.zeros_like(st_t.coeffs.fnidx))
        lo, hi, loose_lo, loose_hi = matfun.bracket(
            as_inv, st_t.st, st_t.lam_min, st_t.lam_max)
        np.testing.assert_allclose(np.asarray(lo), np.asarray(st_l.lower),
                                   rtol=1e-9)
        np.testing.assert_allclose(np.asarray(hi), np.asarray(st_l.upper),
                                   rtol=1e-9)


# ------------------------------------------------ engine fn tags

def test_engine_serves_mixed_fn_pool():
    a = make_spd(28, kappa=60.0, seed=2)
    w, v = np.linalg.eigh(a)
    lam = dict(lam_min=float(w[0] * 0.99), lam_max=float(w[-1] * 1.01))
    op = Dense(jnp.asarray(a))
    rng = np.random.default_rng(4)
    us = rng.standard_normal((6, 28))
    sv = BIFSolver.create(max_iters=40, rtol=1e-6, atol=1e-10, fn="log")
    eng = BIFEngine(op, solver=sv, max_batch=4, **lam)
    fns = ["log", "invsqrt", None, "inv", "sqrt", "log"]
    reqs = [eng.submit(BIFRequest(u=u, fn=f)) for u, f in zip(us, fns)]
    out = eng.flush()
    assert out == reqs  # submission order
    for r, f, u in zip(out, fns, us):
        c = v.T @ u
        true = float(np.sum(c * c * _F[f or "log"](w)))
        assert r.resolved
        assert r.lower <= true + 1e-8 * abs(true)
        assert r.upper >= true - 1e-8 * abs(true)

    # budget-interrupted matfun request resumes through the banked state
    r = eng.submit(BIFRequest(u=us[0], fn="log", max_iters=3))
    eng.flush()
    assert not r.resolved and r.iterations == 3
    assert r.state is not None and r.state.coeffs is not None
    eng.submit(r)
    eng.flush()
    assert r.iterations > 3
    c = v.T @ us[0]
    true = float(np.sum(c * c * np.log(w)))
    assert r.lower <= true <= r.upper

    # resubmitting a banked solve under a different fn is rejected
    r2 = eng.submit(BIFRequest(u=us[1], fn="invsqrt", max_iters=2))
    eng.flush()
    assert r2.state is not None
    r2.fn = "log"
    with pytest.raises(ValueError, match="banks a fn='invsqrt'"):
        eng.submit(r2)

    # legacy engines reject matfun tags at the door
    legacy_eng = BIFEngine(op, max_batch=4, **lam)
    with pytest.raises(ValueError, match="legacy f=1/x"):
        legacy_eng.submit(BIFRequest(u=us[0], fn="log"))

    # cross-pool banked states are rejected at the door, both ways: a
    # matfun pool banks CoeffHistory lanes, a legacy pool coeff-free
    # ones — a presence mismatch would poison a flush mid-flight
    r3 = eng.submit(BIFRequest(u=us[2], fn="inv", max_iters=1))
    eng.flush()
    assert r3.state is not None and r3.state.coeffs is not None
    with pytest.raises(ValueError, match="cannot resume on this one"):
        legacy_eng.submit(r3)
    r4 = legacy_eng.submit(BIFRequest(u=us[3], max_iters=1))
    legacy_eng.flush()
    assert r4.state is not None and r4.state.coeffs is None
    r4.fn = "inv"
    with pytest.raises(ValueError, match="cannot resume on this one"):
        eng.submit(r4)


def test_pair_driver_rejects_matfun():
    a = make_spd(16, kappa=30.0, seed=0)
    op = Dense(jnp.asarray(a))
    u = jnp.asarray(np.random.default_rng(0).standard_normal(16))
    s = BIFSolver.create(max_iters=10, fn="log")
    with pytest.raises(NotImplementedError, match="pair driver"):
        s.judge_kdpp_swap(op, u, op, u, 0.0, 0.5, lam_min=0.1,
                          lam_max=10.0)


def test_undersized_coeff_rows_freezes_soundly():
    """A coeff history smaller than max_iters acts like an iteration
    budget: lanes freeze at the buffer capacity with the bracket still
    containing the truth (never silently corrupted past capacity), and
    an unresolved capacity-frozen state finalizes uncertified."""
    a, u = _problem(seed=17)
    w = np.linalg.eigvalsh(a)
    lmn, lmx = float(w[0] * 0.99), float(w[-1] * 1.01)
    true = _truth(a, u, np.log)
    s = BIFSolver.create(max_iters=40, rtol=1e-10, fn="log")
    st = s.init_state(Dense(jnp.asarray(a)), jnp.asarray(u),
                      lam_min=lmn, lam_max=lmx, coeff_rows=4)
    st = s.resume(st)
    assert int(st.it) == 4  # frozen at capacity, not at max_iters
    res = s.finalize(st)
    assert float(res.lower) <= true <= float(res.upper)
    assert not bool(res.certified)


def test_dpp_chain_judges_reject_matfun_solver():
    """The chain judges compare Schur-complement thresholds against the
    BIF; handing them a matfun solver would certify decisions about the
    wrong quantity, so they reject it at the door."""
    from repro.core import dpp, greedy_map
    a = make_spd(16, kappa=30.0, seed=0)
    op = Dense(jnp.asarray(a))
    st = dpp.init_chain(jax.random.key(0), jnp.zeros(16).at[:3].set(1.0))
    s = BIFSolver.create(max_iters=18, fn="log")
    with pytest.raises(ValueError, match="fn='inv'"):
        dpp.dpp_step(op, st, 0.1, 10.0, max_iters=18, solver=s)
    with pytest.raises(ValueError, match="fn='inv'"):
        dpp.kdpp_step(op, st, 0.1, 10.0, max_iters=18, solver=s)
    with pytest.raises(ValueError, match="fn='inv'"):
        greedy_map(op, 3, 0.1, 10.0, max_iters=18, solver=s)
