import os
import sys
from pathlib import Path

# Tests must see ONE device (the dry-run alone forces 512); make sure a
# stray XLA_FLAGS doesn't leak in.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)  # CPU oracles run in f64;
# TPU-target code paths pass explicit f32/bf16 dtypes throughout.

import numpy as np  # noqa: E402
import pytest  # noqa: E402

try:
    import hypothesis  # noqa: E402,F401
except ImportError:
    # Hermetic containers ship without hypothesis; fall back to the local
    # deterministic stub so the suite still collects and runs (the real
    # library is used automatically whenever it is installed).
    import _hypothesis_stub  # noqa: E402

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

from hypothesis import settings  # noqa: E402

settings.register_profile("fast", max_examples=15, deadline=None)
settings.load_profile("fast")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_spd(n: int, kappa: float = 100.0, seed: int = 0,
             density: float = 1.0) -> np.ndarray:
    """Random SPD matrix with controlled condition number."""
    rng = np.random.default_rng(seed)
    if density < 1.0:
        m = rng.standard_normal((n, n)) * (rng.random((n, n)) < density)
        a = (m + m.T) / 2
        w = np.linalg.eigvalsh(a)
        # shift to make lambda_min = lambda_max_target / kappa
        span = w[-1] - w[0]
        lam_min = max(span, 1e-3) / (kappa - 1)
        return a + np.eye(n) * (lam_min - w[0])
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    evals = np.geomspace(1.0 / kappa, 1.0, n)
    return (q * evals) @ q.T


@pytest.fixture
def spd_factory():
    return make_spd
