"""Pallas kernels vs pure-jnp oracles (interpret mode), with shape/dtype
sweeps as required for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Dense, gql, lanczos, operators
from repro.kernels import ops, ref
from conftest import make_spd


@pytest.mark.parametrize("b,n", [(1, 64), (3, 100), (2, 256), (4, 130),
                                 (1, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matvec(b, n, dtype):
    rng = np.random.default_rng(n + b)
    a = jnp.asarray(rng.standard_normal((b, n, n)), dtype)
    a = (a + jnp.swapaxes(a, -1, -2)) / 2
    x = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    y, al = ops.fused_matvec(a, x, interpret=True)
    yr, alr = ref.fused_matvec(a, x)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(y, yr, rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(al, alr, rtol=tol * 5, atol=tol * 100)


@pytest.mark.parametrize("n,bs,density", [(128, 32, 0.05), (256, 64, 0.02),
                                          (300, 32, 0.1), (512, 128, 0.01)])
def test_bell_spmv(n, bs, density):
    rng = np.random.default_rng(n)
    m = rng.standard_normal((n, n)) * (rng.random((n, n)) < density)
    a = (m + m.T) / 2
    data, cols, _ = ops.dense_to_bell(a, bs=bs)
    npad = data.shape[0] * bs
    x = jnp.asarray(rng.standard_normal(npad), jnp.float32)
    y = ops.bell_matvec(data, cols, x, interpret=True)
    yr = ref.bell_matvec(data, cols, x)
    apad = np.zeros((npad, npad), np.float32)
    apad[:n, :n] = a
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(yr, apad @ np.asarray(x), rtol=1e-4,
                               atol=1e-3)


def test_bell_flops_scale_with_sparsity():
    """Blocked-ELL work is proportional to stored blocks (paper's
    'profit from sparsity' on TPU terms). Block-structured sparsity
    (banded Laplacian) is the target regime — uniform random sparsity
    fills every 128x128 block and deserves no savings."""
    from repro.data import graph_laplacian
    n = 512
    rng = np.random.default_rng(0)
    banded = graph_laplacian(n, mean_degree=8, rewire=0.0)
    dense = rng.standard_normal((n, n))
    d1, _, _ = ops.dense_to_bell(banded, bs=64)
    d2, _, _ = ops.dense_to_bell((dense + dense.T) / 2, bs=64)
    assert d1.shape[1] < d2.shape[1]


@pytest.mark.parametrize("bsz", [8, 64, 1000])
def test_gql_update_kernel(bsz):
    """Kernel vs core.gql.recurrence_update on states from a real run."""
    n = 96
    a = make_spd(n, kappa=200.0, seed=1)
    w = np.linalg.eigvalsh(a)
    lmn, lmx = float(w[0] * 0.9), float(w[-1] * 1.1)
    op = Dense(jnp.broadcast_to(jnp.asarray(a, jnp.float32), (bsz, n, n)))
    u = jnp.asarray(np.random.default_rng(2).standard_normal((bsz, n)),
                    jnp.float32)
    st = gql.gql_init(op, u, lmn, lmx)
    for _ in range(15):
        lz1 = lanczos.lanczos_step(op, st.lz)
        live = np.asarray(st.lz.live & lz1.live)
        out = ops.gql_update(lz1.alpha, lz1.beta, lz1.beta_prev, st.g,
                             st.c, st.delta, st.delta_lr, st.delta_rr,
                             lmn, lmx, interpret=True)
        outr = ref.gql_update(lz1.alpha, lz1.beta, lz1.beta_prev, st.g,
                              st.c, st.delta, st.delta_lr, st.delta_rr,
                              jnp.asarray(lmn, jnp.float32),
                              jnp.asarray(lmx, jnp.float32))
        for o, orf in zip(out, outr):
            np.testing.assert_allclose(np.asarray(o)[live],
                                       np.asarray(orf)[live],
                                       rtol=1e-5, atol=1e-6)
        st = gql.gql_step(op, st, lmn, lmx)


@pytest.mark.parametrize("bh,t,s,d", [(2, 64, 64, 32), (1, 128, 128, 64),
                                      (3, 1, 200, 64), (2, 96, 96, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(bh, t, s, d, dtype):
    rng = np.random.default_rng(bh * t)
    q = jnp.asarray(rng.standard_normal((bh, t, d)), dtype)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    for causal in ([True, False] if t == s else [False]):
        o = ops.flash_attention(q, k, v, causal=causal, bt=32, bs=32,
                                interpret=True)
        orf = ref.flash_attention(q, k, v, causal=causal)
        tol = 2e-4 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(o, jnp.float32),
                                   np.asarray(orf, jnp.float32),
                                   rtol=tol, atol=tol * 20)


def test_flash_gqa_wrapper_matches_model_attention():
    from repro.models import attention as A
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((2, 64, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 64, 2, 32)), jnp.float32)
    o_kernel = ops.mha_flash(q, k, v, causal=True, bt=32, bs=32,
                             interpret=True)
    o_model = A._sdpa_full(q, k, v, causal=True, window=None)
    np.testing.assert_allclose(o_kernel, np.asarray(o_model, jnp.float32),
                               rtol=2e-4, atol=2e-4)


def test_fused_matvec_inside_lanczos():
    """End-to-end: the kernel can drive the GQL loop via MatvecFn."""
    n = 128
    a = make_spd(n, kappa=100.0, seed=3).astype(np.float32)
    w = np.linalg.eigvalsh(a)
    u = np.random.default_rng(1).standard_normal((1, n)).astype(np.float32)
    true = float(u[0] @ np.linalg.solve(a, u[0]))
    ab = jnp.asarray(a)[None]

    op = operators.MatvecFn(
        fn=lambda x: ops.fused_matvec(ab, x, interpret=True)[0],
        n_static=n, diag_vals=jnp.asarray(np.diag(a))[None])
    from repro.core import BIFSolver
    res = BIFSolver.create(max_iters=60, rtol=1e-3).solve(
        op, jnp.asarray(u), lam_min=float(w[0] * 0.9),
        lam_max=float(w[-1] * 1.1))
    assert float(res.lower[0]) <= true * 1.001
    assert float(res.upper[0]) >= true * 0.999
