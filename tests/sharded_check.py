"""Multi-device parity checks for the sharded batched driver.

Run as a SUBPROCESS with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(tests/test_sharded.py does; the main pytest process must stay at one
device, see tests/conftest.py). Prints ``OK`` when every check passes.

The contract (DESIGN.md Sec. 7): per-lane decisions, iteration counts,
certification, and the certified argmax index from the sharded driver
exactly match the single-device batched path on identical stacked
inputs; brackets are bit-exact on SparseCOO and agree to 1e-12 on
gemm-backed operators.
"""
import os
import sys
from pathlib import Path

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", ""), "run me under 8 virtual devices"

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
# Initialize backends BEFORE importing conftest: it pops XLA_FLAGS (the
# in-process suite must see one device), which would shrink our mesh if
# jax hadn't locked in the 8 virtual devices yet.
assert len(jax.devices()) == 8, jax.devices()

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from conftest import make_spd  # noqa: E402
from repro.core import BIFSolver, Dense, Masked, ShardedBIFSolver, \
    bell_from_dense, dpp, greedy_map, sparse_from_dense, stack_masks, \
    stack_ops, trace_quad  # noqa: E402
from repro.launch.mesh import make_lane_mesh  # noqa: E402
from repro.serve import BIFEngine, BIFRequest  # noqa: E402


def _problem(n=48, k=16, kappa=150.0, seed=0, density=0.3):
    a = make_spd(n, kappa=kappa, seed=seed, density=density)
    w = np.linalg.eigvalsh(a)
    us = np.random.default_rng(seed + 1).standard_normal((k, n))
    true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)
    return a, jnp.asarray(us), true, float(w[0] * 0.99), float(w[-1] * 1.01)


def _assert_solve_parity(ref, got, bit_exact, what):
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  np.asarray(ref.iterations), what)
    np.testing.assert_array_equal(np.asarray(got.certified),
                                  np.asarray(ref.certified), what)
    for field in ("lower", "upper", "gauss_lower", "lobatto_upper"):
        b, s = np.asarray(getattr(got, field)), np.asarray(getattr(ref,
                                                                   field))
        if bit_exact:
            np.testing.assert_array_equal(b, s, f"{what}.{field}")
        else:
            np.testing.assert_allclose(b, s, rtol=1e-12,
                                       err_msg=f"{what}.{field}")


def check_solve_batch_parity(mesh):
    a, us, true, lmn, lmx = _problem()
    s = BIFSolver.create(max_iters=50, rtol=1e-4)
    for kind, op in [("dense", Dense(jnp.asarray(a))),
                     ("coo", sparse_from_dense(a)),
                     ("bell", bell_from_dense(a, bs=16))]:
        ref = s.solve_batch(op, us, lam_min=lmn, lam_max=lmx)
        got = s.solve_batch_sharded(op, us, mesh=mesh, lam_min=lmn,
                                    lam_max=lmx)
        _assert_solve_parity(ref, got, kind == "coo", kind)
        assert np.all(np.asarray(got.lower) <= true * (1 + 1e-9))
        assert np.all(np.asarray(got.upper) >= true * (1 - 1e-9))


def check_nondivisible_padding(mesh):
    """K=11 over 8 devices: a padding lane per short device, results
    sliced back to the 11 real lanes."""
    a, us, true, lmn, lmx = _problem(k=11, seed=3)
    s = BIFSolver.create(max_iters=50, rtol=1e-4)
    op = sparse_from_dense(a)
    ref = s.solve_batch(op, us, lam_min=lmn, lam_max=lmx)
    got = s.solve_batch_sharded(op, us, mesh=mesh, lam_min=lmn,
                                lam_max=lmx)
    assert got.lower.shape == (11,)
    _assert_solve_parity(ref, got, True, "coo-pad")

    # stacked masks (lane-stacked operator leaves) must pad too
    base = Dense(jnp.asarray(a))
    masks = jnp.asarray(
        (np.random.default_rng(5).random((11, a.shape[0])) < 0.6)
        .astype(float))
    mop = stack_masks(base, masks)
    usm = us * masks
    ref = s.solve_batch(mop, usm, lam_min=lmn, lam_max=lmx)
    got = s.solve_batch_sharded(mop, usm, mesh=mesh, lam_min=lmn,
                                lam_max=lmx)
    _assert_solve_parity(ref, got, False, "masked-pad")


def check_stacked_ops(mesh):
    """K *different* systems (stack_ops): per-lane operator leaves shard
    with the lanes."""
    n, k = 32, 8
    mats = [make_spd(n, kappa=60.0, seed=s) for s in range(k)]
    w = [np.linalg.eigvalsh(m) for m in mats]
    lmn = min(v[0] for v in w) * 0.99
    lmx = max(v[-1] for v in w) * 1.01
    us = jnp.asarray(np.random.default_rng(9).standard_normal((k, n)))
    s = BIFSolver.create(max_iters=n + 2, rtol=1e-4)
    for kind, build in [("coo", sparse_from_dense),
                        ("bell", lambda m: bell_from_dense(m, bs=16))]:
        stacked = stack_ops([build(m) for m in mats])
        ref = s.solve_batch(stacked, us, lam_min=lmn, lam_max=lmx)
        got = s.solve_batch_sharded(stacked, us, mesh=mesh, lam_min=lmn,
                                    lam_max=lmx)
        _assert_solve_parity(ref, got, kind == "coo", f"stack_ops-{kind}")


def check_per_lane_spectrum(mesh):
    """Estimating spectrum modes return PER-LANE lam arrays from
    prepare(); they must shard with the lanes (and pad with the dummy
    lanes) instead of crashing as replicated scalars. On COO the matvec
    floats are bit-exact, so iteration counts must match exactly too.
    ridge mixes a scalar lam_min with a per-lane lam_max — the two specs
    are derived independently."""
    a, us, true, lmn_, lmx_ = _problem(k=16, seed=8)
    op = sparse_from_dense(a)
    for spec, k in [("lanczos", 16), ("lanczos", 11), ("ridge", 16),
                    ("ridge", 11)]:
        s = BIFSolver.create(max_iters=40, rtol=1e-5, spectrum=spec,
                             ridge=1e-3)
        ref = s.solve_batch(op, us[:k])
        got = s.solve_batch_sharded(op, us[:k], mesh=mesh)
        _assert_solve_parity(ref, got, True, f"{spec}-k{k}")

    # explicit per-lane lam arrays shard the same way
    s = BIFSolver.create(max_iters=40, rtol=1e-5)
    lmn = jnp.full((11,), lmn_) * (1 + 0.001 * jnp.arange(11))
    lmx = jnp.full((11,), lmx_)
    ref = s.solve_batch(op, us[:11], lam_min=lmn, lam_max=lmx)
    got = s.solve_batch_sharded(op, us[:11], mesh=mesh, lam_min=lmn,
                                lam_max=lmx)
    _assert_solve_parity(ref, got, True, "explicit-per-lane-lam")


def check_judge_batch(mesh):
    """Thresholds ride the lanes; the knife-edge lane exhausts max_iters
    on both paths."""
    a, us, true, lmn, lmx = _problem(k=5, seed=0)
    op = sparse_from_dense(a)
    s = BIFSolver.create(max_iters=12)
    ts = jnp.asarray(true * np.array([0.5, 0.95, 1.0 + 1e-12, 1.05, 2.0]))
    ref = s.judge_batch(op, us, ts, lam_min=lmn, lam_max=lmx)
    got = s.judge_batch_sharded(op, us, ts, mesh=mesh, lam_min=lmn,
                                lam_max=lmx)
    np.testing.assert_array_equal(np.asarray(got.decision),
                                  np.asarray(ref.decision))
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  np.asarray(ref.iterations))
    np.testing.assert_array_equal(np.asarray(got.certified),
                                  np.asarray(ref.certified))
    assert int(got.iterations[2]) == 12 and not bool(got.certified[2])
    assert int(got.iterations[0]) < 12 and bool(got.certified[0])


def check_judge_argmax(mesh):
    a, us, true, lmn, lmx = _problem(k=16, seed=5)
    op = Dense(jnp.asarray(a))
    s = BIFSolver.create(max_iters=50)
    ref = s.judge_argmax(op, us, lam_min=lmn, lam_max=lmx)
    got = s.judge_argmax_sharded(op, us, mesh=mesh, lam_min=lmn,
                                 lam_max=lmx)
    assert int(got.index) == int(ref.index) == int(np.argmax(true))
    assert bool(got.certified) and bool(ref.certified)
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  np.asarray(ref.iterations))
    np.testing.assert_allclose(np.asarray(got.lower),
                               np.asarray(ref.lower), rtol=1e-12)

    # per-lane shift/scale + valid mask, non-divisible K=11 (pads enter
    # the race invalid)
    us11, true11 = us[:11], true[:11]
    d = jnp.asarray(30.0 * np.abs(true11))
    valid = jnp.ones((11,), bool).at[int(np.argmax(true11))].set(False)
    ref = s.judge_argmax(op, us11, shift=d, scale=-1.0, valid=valid,
                         lam_min=lmn, lam_max=lmx)
    got = s.judge_argmax_sharded(op, us11, shift=d, scale=-1.0,
                                 valid=valid, mesh=mesh, lam_min=lmn,
                                 lam_max=lmx)
    assert int(got.index) == int(ref.index)
    assert bool(got.certified) == bool(ref.certified)
    np.testing.assert_array_equal(np.asarray(got.iterations),
                                  np.asarray(ref.iterations))


def check_engine_flush(mesh):
    """Mixed judge/bracket traffic with a masked request, flushed through
    the mesh: identical chunk shapes => identical per-request outcomes."""
    a = make_spd(32, kappa=60.0, seed=2)
    w = np.linalg.eigvalsh(a)
    lam = dict(lam_min=float(w[0] * 0.9), lam_max=float(w[-1] * 1.1))
    op = Dense(jnp.asarray(a))
    sv = BIFSolver.create(max_iters=40, rtol=1e-3)
    e0 = BIFEngine(op, solver=sv, max_batch=8, **lam)
    e1 = BIFEngine(op, solver=sv, max_batch=6, mesh=mesh, **lam)
    assert e1.max_batch == 8  # rounded up to num_devices x lanes_per_device
    rng = np.random.default_rng(4)
    us = rng.standard_normal((11, 32))
    true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)
    mask = (rng.random(32) < 0.5).astype(float)
    for eng in (e0, e1):
        for i, u in enumerate(us):
            t = float(true[i] * (0.9 if i % 2 else 1.1)) if i % 3 else None
            eng.submit(BIFRequest(u=u, t=t, mask=mask if i == 10 else None))
    r0, r1 = e0.flush(), e1.flush()
    for i, (x, y) in enumerate(zip(r0, r1)):
        assert x.decision == y.decision, i
        assert x.certified == y.certified, i
        assert x.iterations == y.iterations, i
        np.testing.assert_allclose([x.lower, x.upper], [y.lower, y.upper],
                                   rtol=1e-12)
    # the mesh engine really answered the BIF queries
    for i, r in enumerate(r1[:10]):
        assert r.lower <= true[i] * 1.0001 and r.upper >= true[i] * 0.9999


def check_engine_stats_parity(mesh):
    """Telemetry on the sharded engine (DESIGN.md Sec. 14): the mesh
    engine's request ledger matches the single-device engine on
    identical traffic, and metrics on vs off on the mesh is
    BIT-identical — instrumentation must not perturb the sharded path
    either."""
    from repro import obs

    a = make_spd(32, kappa=60.0, seed=11)
    w = np.linalg.eigvalsh(a)
    lam = dict(lam_min=float(w[0] * 0.9), lam_max=float(w[-1] * 1.1))
    op = Dense(jnp.asarray(a))
    sv = BIFSolver.create(max_iters=40, rtol=1e-3)
    rng = np.random.default_rng(12)
    us = rng.standard_normal((13, 32))
    true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)

    e0 = BIFEngine(op, solver=sv, max_batch=8, chunk_iters=4, **lam)
    e1 = BIFEngine(op, solver=sv, max_batch=8, chunk_iters=4, mesh=mesh,
                   **lam)
    e_off = BIFEngine(op, solver=sv, max_batch=8, chunk_iters=4, mesh=mesh,
                      metrics=False, **lam)
    for eng in (e0, e1, e_off):
        for i, u in enumerate(us):
            t = float(true[i] * (0.9 if i % 2 else 1.1)) if i % 3 else None
            eng.submit(BIFRequest(u=u, t=t))
    obs.spans.set_enabled(True)  # spans on for the metered engines...
    r0, r1 = e0.flush(), e1.flush()
    obs.spans.set_enabled(False)  # ...off for the bare one
    r_off = e_off.flush()

    # same compiled driver, same mesh: metrics on vs off is bit-exact
    for i, (x, y) in enumerate(zip(r1, r_off)):
        assert x.decision == y.decision, i
        assert x.certified == y.certified, i
        assert x.iterations == y.iterations, i
        assert (x.lower, x.upper) == (y.lower, y.upper), i
    assert e_off.stats() == {"counters": {}, "gauges": {},
                             "histograms": {}}

    # request-ledger parity across single-device vs mesh: every counter
    # equal, histogram populations equal, and the iteration histogram
    # (whose observations are exact-parity ints) identical
    s0, s1 = e0.stats(), e1.stats()
    assert s0["counters"] == s1["counters"], (s0["counters"],
                                              s1["counters"])
    assert s0["counters"]["requests.submitted"] == len(us)
    assert s0["counters"]["requests.resolved"] == len(us)
    assert set(s0["histograms"]) == set(s1["histograms"])
    for name in s0["histograms"]:
        assert s0["histograms"][name]["count"] == \
            s1["histograms"][name]["count"], name
    for field in ("min", "max", "sum", "p50", "p99"):
        assert s0["histograms"]["request.iterations"][field] == \
            s1["histograms"]["request.iterations"][field], field
    for eng in (e0, e1):
        lat = eng.stats()["histograms"]["request.latency_s"]
        assert lat["count"] == len(us) and lat["p99"] >= lat["p50"]


def check_applications(mesh):
    """greedy MAP + k-DPP swap ride the sharded judges unchanged."""
    n = 28
    a = make_spd(n, kappa=60.0, seed=7)
    d = np.sqrt(np.diag(a))
    a = a / np.outer(d, d) + 0.1 * np.eye(n)
    w = np.linalg.eigvalsh(a)
    op = Dense(jnp.asarray(a))
    r1 = greedy_map(op, 6, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2)
    r2 = greedy_map(op, 6, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2,
                    mesh=mesh)
    np.testing.assert_array_equal(np.asarray(r1.order),
                                  np.asarray(r2.order))
    assert int(r2.uncertified) == 0
    assert int(r1.quad_iterations) == int(r2.quad_iterations)

    # the incremental factor carry composes with the mesh: the sharded
    # race sees the same exact lower/upper priors, so selections AND
    # iteration totals match the single-device incremental run
    r3 = greedy_map(op, 6, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2,
                    incremental=True)
    r4 = greedy_map(op, 6, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2,
                    incremental=True, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(r1.order),
                                  np.asarray(r3.order))
    np.testing.assert_array_equal(np.asarray(r3.order),
                                  np.asarray(r4.order))
    assert int(r4.uncertified) == 0
    assert int(r3.quad_iterations) == int(r4.quad_iterations)
    # exact priors resolve every lane at its first decide check; this
    # well-conditioned regime already sits at the floor from scratch, so
    # parity (not strict savings — test_update.py pins that) is the bar
    assert int(r3.quad_iterations) == 6 * n

    st = dpp.init_chain(jax.random.key(0), jnp.zeros(n).at[:5].set(1.0))
    s1 = dpp.kdpp_step(op, st, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2)
    s2 = dpp.kdpp_step(op, st, w[0] * 0.99, w[-1] * 1.01, max_iters=n + 2,
                       mesh=mesh)
    np.testing.assert_array_equal(np.asarray(s1.mask), np.asarray(s2.mask))
    assert int(s1.stats.quad_iterations) == int(s2.stats.quad_iterations)


def check_resumable_stepping(mesh):
    """The sharded stepping API (DESIGN.md Sec. 8): interrupt with
    step_n_sharded, resume with resume_sharded — the final result equals
    the uninterrupted sharded drive AND the single-device path (bit-exact
    COO, 1e-12 dense), including non-divisible K=11 padding."""
    from repro.core import sharded as core_sharded

    a, us, true, lmn, lmx = _problem(k=11, seed=12)
    s = BIFSolver.create(max_iters=50, rtol=1e-4)
    for kind, op in [("coo", sparse_from_dense(a)),
                     ("dense", Dense(jnp.asarray(a)))]:
        ref = s.solve_batch_sharded(op, us, mesh=mesh, lam_min=lmn,
                                    lam_max=lmx)
        st = core_sharded.init_state_sharded(s, op, us, mesh=mesh,
                                             lam_min=lmn, lam_max=lmx)
        assert st.st.it.shape == (16,)  # padded to the device multiple
        for k in (1, 3):
            st = core_sharded.step_n_sharded(s, st, k, mesh=mesh)
        st = core_sharded.resume_sharded(s, st, mesh=mesh)
        got = core_sharded.finalize_sharded(s, st, nlanes=11)
        _assert_solve_parity(ref, got, kind == "coo", f"stepping-{kind}")
        single = s.solve_batch(op, us, lam_min=lmn, lam_max=lmx)
        _assert_solve_parity(single, got, kind == "coo",
                             f"stepping-vs-single-{kind}")

    # per-lane iteration budgets shard with the lanes; lifting the cap
    # resumes to the uninterrupted endpoint bit-exactly
    op = sparse_from_dense(a)
    ref = s.solve_batch_sharded(op, us, mesh=mesh, lam_min=lmn,
                                lam_max=lmx)
    st = core_sharded.init_state_sharded(s, op, us, mesh=mesh, lam_min=lmn,
                                         lam_max=lmx)
    st = core_sharded.resume_sharded(s, st, it_cap=np.full(16, 3, np.int32),
                                     mesh=mesh)
    assert int(np.asarray(st.it).max()) <= 3
    st = core_sharded.resume_sharded(s, st, mesh=mesh)
    got = core_sharded.finalize_sharded(s, st, nlanes=11)
    _assert_solve_parity(ref, got, True, "budget-resume")


def check_cadence_rounds(mesh):
    """Round-cadenced collectives (DESIGN.md Sec. 11): at every
    ``decide_every`` the sharded drive stays bit-exact with the single-
    device solver at the SAME cadence, decisions and certificates match
    the R=1 run, ``step_n_sharded`` quantizes to whole rounds, and the
    step counter stays round-aligned."""
    from repro.core import sharded as core_sharded

    a, us, true, lmn, lmx = _problem(k=11, seed=33)
    op = sparse_from_dense(a)
    base = None
    for r in (1, 2, 4):
        s = BIFSolver.create(max_iters=50, rtol=1e-4, decide_every=r)
        single = s.solve_batch(op, us, lam_min=lmn, lam_max=lmx)
        got = s.solve_batch_sharded(op, us, mesh=mesh, lam_min=lmn,
                                    lam_max=lmx)
        # sharded == single-device at the same cadence, bit-exact (COO)
        _assert_solve_parity(single, got, True, f"cadence-R{r}")
        if base is None:
            base = got
        else:
            # cadence never flips a decision: certificates match R=1 and
            # deferring the decide costs at most R-1 extra contractions
            np.testing.assert_array_equal(np.asarray(got.certified),
                                          np.asarray(base.certified),
                                          f"cadence-R{r}-certified")
            extra = np.asarray(got.iterations) - np.asarray(base.iterations)
            assert np.all((extra >= 0) & (extra <= r - 1)), \
                f"R={r}: {extra}"
        # interrupted + resumed at this cadence lands on the same result
        st = core_sharded.init_state_sharded(s, op, us, mesh=mesh,
                                             lam_min=lmn, lam_max=lmx)
        small = core_sharded.step_n_sharded(s, st, r - 1, mesh=mesh)
        assert small is st, "n < R must quantize to a no-op"
        for k in (r, 2 * r + 1):
            st = core_sharded.step_n_sharded(s, st, k, mesh=mesh)
            assert int(st.step) % r == 0, "step must stay round-aligned"
        st = core_sharded.resume_sharded(s, st, mesh=mesh)
        got2 = core_sharded.finalize_sharded(s, st, nlanes=11)
        _assert_solve_parity(got, got2, True, f"cadence-R{r}-stepped")
        # the cross-device argmax race at this cadence: same certified
        # winner as the single-device race
        ja = s.judge_argmax_sharded(op, us, mesh=mesh, lam_min=lmn,
                                    lam_max=lmx)
        ja1 = s.judge_argmax(op, us, lam_min=lmn, lam_max=lmx)
        assert int(ja.index) == int(ja1.index) == int(np.argmax(true))
        assert bool(ja.certified) == bool(ja1.certified)


def check_matfun_and_trace_probes(mesh):
    """Matrix-function lanes over the mesh (DESIGN.md Sec. 9): the
    fn='log' batched drive — including its resumable stepping — and the
    trace-probe estimator match the single-device path exactly,
    non-divisible probe counts included."""
    from repro.core import sharded as core_sharded

    a, us, true, lmn, lmx = _problem(k=11, seed=21)
    op = sparse_from_dense(a)
    s = BIFSolver.create(max_iters=50, rtol=1e-6, fn="log")
    ref = s.solve_batch(op, us, lam_min=lmn, lam_max=lmx)
    got = s.solve_batch_sharded(op, us, mesh=mesh, lam_min=lmn,
                                lam_max=lmx)
    _assert_solve_parity(ref, got, True, "matfun-log")
    # dense-oracle containment, not just parity
    w, v = np.linalg.eigh(a)
    c = v.T @ np.asarray(us).T
    truth = np.sum(c * c * np.log(w)[:, None], axis=0)
    assert np.all(np.asarray(got.lower) <= truth + 1e-9 * np.abs(truth))
    assert np.all(np.asarray(got.upper) >= truth - 1e-9 * np.abs(truth))

    # interrupted sharded stepping carries the coefficient history
    st = core_sharded.init_state_sharded(s, op, us, mesh=mesh,
                                         lam_min=lmn, lam_max=lmx)
    assert st.coeffs is not None and st.coeffs.alphas.shape[0] == 16
    for k in (1, 3):
        st = core_sharded.step_n_sharded(s, st, k, mesh=mesh)
    st = core_sharded.resume_sharded(s, st, mesh=mesh)
    got2 = core_sharded.finalize_sharded(s, st, nlanes=11)
    _assert_solve_parity(ref, got2, True, "matfun-stepping")

    # trace probes as sharded lanes: 10 Hutchinson probes over 8 devices
    key = jax.random.key(3)
    single = trace_quad(op, "log", 10, lam_min=lmn, lam_max=lmx, key=key)
    sharded = trace_quad(op, "log", 10, lam_min=lmn, lam_max=lmx,
                         key=key, mesh=mesh)
    assert (sharded.lower, sharded.upper) == (single.lower, single.upper)
    assert sharded.iterations == single.iterations
    np.testing.assert_array_equal(sharded.state.probe_lower,
                                  single.state.probe_lower)
    ldtruth = float(np.sum(np.log(w)))
    assert sharded.stat_lower <= ldtruth <= sharded.stat_upper

    # exact unit probes: deterministic logdet certificate off the mesh
    exact = trace_quad(op, "log", None, lam_min=lmn, lam_max=lmx,
                       mesh=mesh)
    assert exact.lower <= ldtruth <= exact.upper


def check_block_quadrature(mesh):
    """Block-Krylov lanes over the mesh (DESIGN.md Sec. 13): sharded
    block brackets are decision-identical to the single-device block
    driver at every ``decide_every`` cadence (bit-exact on COO — only
    scalar trace summaries cross devices, under the PR 7 round gather),
    non-divisible K and block trace probes included."""
    a, _, _, lmn, lmx = _problem(seed=17)
    op = sparse_from_dense(a)
    n = a.shape[0]
    b, k = 4, 11
    us = jnp.asarray(
        np.random.default_rng(18).standard_normal((k, b, n)))
    wv, vv = np.linalg.eigh(a)
    g = np.asarray(us) @ vv
    truth = np.sum(g * g / wv, axis=(-2, -1))
    for r in (1, 2, 4):
        s = BIFSolver.create(max_iters=24, rtol=1e-6, block_size=b,
                             decide_every=r)
        single = s.solve_batch(op, us, lam_min=lmn, lam_max=lmx)
        got = s.solve_batch_sharded(op, us, mesh=mesh, lam_min=lmn,
                                    lam_max=lmx)
        _assert_solve_parity(single, got, True, f"block-R{r}")
        assert np.all(np.asarray(got.lower) <= truth * (1 + 1e-9))
        assert np.all(np.asarray(got.upper) >= truth * (1 - 1e-9))

    # block trace probes over the mesh match the single-device estimator
    key = jax.random.key(13)
    single = trace_quad(op, "log", 16, lam_min=lmn, lam_max=lmx, key=key,
                        block_size=b)
    sharded = trace_quad(op, "log", 16, lam_min=lmn, lam_max=lmx, key=key,
                         block_size=b, mesh=mesh)
    assert (sharded.lower, sharded.upper) == (single.lower, single.upper)
    assert sharded.std_error == single.std_error
    assert sharded.iterations == single.iterations
    np.testing.assert_array_equal(sharded.state.probe_lower,
                                  single.state.probe_lower)


def check_sharded_solver_wrapper(mesh):
    """ShardedBIFSolver is static: closure-capture under jit works and
    matches the unbound calls."""
    a, us, true, lmn, lmx = _problem(k=8, seed=6)
    op = sparse_from_dense(a)
    sh = ShardedBIFSolver(BIFSolver.create(max_iters=50, rtol=1e-4), mesh)
    res = sh.solve_batch(op, us, lam_min=lmn, lam_max=lmx)
    jres = jax.jit(lambda u: sh.solve_batch(op, u, lam_min=lmn,
                                            lam_max=lmx))(us)
    # outer jit refuses nothing and fuses differently: discrete outcomes
    # stay exact, floats to the usual gemm-caveat tolerance
    np.testing.assert_allclose(np.asarray(res.lower),
                               np.asarray(jres.lower), rtol=1e-12)
    np.testing.assert_array_equal(np.asarray(res.iterations),
                                  np.asarray(jres.iterations))

    ja = sh.judge_argmax(op, us, lam_min=lmn, lam_max=lmx)
    assert int(ja.index) == int(np.argmax(true))


def main():
    mesh = make_lane_mesh()
    assert dict(mesh.shape) == {"lanes": 8}
    for check in (check_solve_batch_parity,
                  check_nondivisible_padding,
                  check_per_lane_spectrum,
                  check_stacked_ops,
                  check_judge_batch,
                  check_judge_argmax,
                  check_resumable_stepping,
                  check_cadence_rounds,
                  check_engine_flush,
                  check_engine_stats_parity,
                  check_applications,
                  check_matfun_and_trace_probes,
                  check_block_quadrature,
                  check_sharded_solver_wrapper):
        check(mesh)
        # progress marker per check: an 8-virtual-device run compiles
        # for minutes, and a silent harness makes a hang look like slow
        print(f"{check.__name__} ok", flush=True)
    print("OK")


if __name__ == "__main__":
    main()
