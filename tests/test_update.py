"""Incremental update/downdate contract (core/update.py, DESIGN.md
Sec. 12) and the streaming serving path built on it.

The hard invariant everywhere: carrying the selected set's Cholesky
factor across rounds changes ITERATION COUNTS, never decisions —
selections are pinned bit-identical against warm_start-only and
from-scratch runs across the operator grid, the chain steps, and the
streaming BlockRanker, while the iteration totals are pinned strictly
smaller.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Dense, Masked, bell_from_dense, dpp, greedy_map, \
    sparse_from_dense, update
from repro.core.solver import SolverConfig
from repro.serve import BlockRanker, apply_block_mask, pool_keys, \
    rank_blocks
from repro.serve.engine import flush_trace_count
from conftest import make_spd


# ---------------------------------------------------------------------------
# the factor itself vs dense oracles


def _dense_chol(a, sel):
    return np.linalg.cholesky(a[np.ix_(sel, sel)])


def test_chain_factor_matches_dense_cholesky():
    n = 12
    a = make_spd(n, kappa=80.0, seed=0)
    f = update.init_factor(n, 8, dtype=jnp.float64)
    sel = []
    for y in (3, 7, 1, 9, 5):
        f = update.extend(f, jnp.asarray(a[:, y]), y)
        sel.append(y)
        c = np.asarray(f.chol)[:len(sel), :len(sel)]
        np.testing.assert_allclose(c, _dense_chol(a, sel), atol=1e-10)
        assert int(f.count) == len(sel) and bool(f.ok)
        assert list(np.asarray(f.idx)[:len(sel)]) == sel

    # exact BIF and all-candidate gains off the factor
    rng = np.random.default_rng(1)
    u = rng.standard_normal(n)
    w = np.linalg.solve(a[np.ix_(sel, sel)], u[sel])
    np.testing.assert_allclose(float(update.bif(f, jnp.asarray(u))),
                               float(u[sel] @ w), atol=1e-10)
    cols = jnp.asarray(a)  # row i of the symmetric base = column i
    g = np.asarray(update.gains(f, jnp.asarray(np.diag(a)), cols))
    for i in range(n):
        wi = np.linalg.solve(a[np.ix_(sel, sel)], a[sel, i])
        np.testing.assert_allclose(g[i], a[i, i] - a[sel, i] @ wi,
                                   atol=1e-9)

    # downdate of a middle item == from-scratch factor of the rest
    f2 = update.downdate(f, 1)
    rest = [y for y in sel if y != 1]
    np.testing.assert_allclose(
        np.asarray(f2.chol)[:len(rest), :len(rest)],
        _dense_chol(a, rest), atol=1e-9)
    assert list(np.asarray(f2.idx)[:len(rest)]) == rest

    # downdate of an ABSENT item is the exact identity (the chains'
    # branchless accept/reject relies on this)
    f3 = update.downdate(f, 4)
    assert jax.tree.all(jax.tree.map(
        lambda x, y: bool(jnp.array_equal(x, y)), f3, f))

    # overflow: extending past capacity flips ok and leaves the rest
    fo = f
    for y in (0, 2, 4, 6):
        fo = update.extend(fo, jnp.asarray(a[:, y]), y)
    assert int(fo.count) == 8 and not bool(fo.ok)


def test_from_mask_matches_incremental_build():
    n = 10
    a = make_spd(n, kappa=50.0, seed=2)
    mask = np.zeros(n)
    mask[[1, 4, 8]] = 1.0
    f = update.from_mask(Dense(jnp.asarray(a)), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(f.chol)[:3, :3],
                               _dense_chol(a, [1, 4, 8]), atol=1e-10)
    assert int(f.count) == 3 and f.capacity == n


# ---------------------------------------------------------------------------
# greedy MAP: bit-identical selections, strictly fewer iterations


def _greedy_case(kind):
    n = 40
    a = make_spd(n, kappa=200.0, seed=5)
    if kind == "dense":
        op, ref = Dense(jnp.asarray(a)), a
    elif kind == "sparse_coo":
        op, ref = sparse_from_dense(a), a
    elif kind == "sparse_bell":
        op, ref = bell_from_dense(a, bs=8), a
    else:  # masked
        rng = np.random.default_rng(6)
        m = (rng.random(n) < 0.8).astype(np.float64)
        ref = np.diag(m) @ a @ np.diag(m) + np.eye(n) - np.diag(m)
        op = Masked(Dense(jnp.asarray(a)), jnp.asarray(m))
    w = np.linalg.eigvalsh(ref)
    return op, float(w[0] * 0.99), float(w[-1] * 1.01)


@pytest.mark.parametrize("kind",
                         ["dense", "sparse_coo", "sparse_bell", "masked"])
def test_greedy_map_incremental_bit_identical_fewer_iters(kind):
    op, lo, hi = _greedy_case(kind)
    t = 16
    kw = dict(max_iters=60)
    cold = greedy_map(op, t, lo, hi, **kw)
    warm = greedy_map(op, t, lo, hi, warm_start=True, **kw)
    inc = greedy_map(op, t, lo, hi, incremental=True, **kw)
    # certified-identical selections, in order
    assert np.array_equal(np.asarray(cold.order), np.asarray(warm.order))
    assert np.array_equal(np.asarray(cold.order), np.asarray(inc.order))
    assert np.array_equal(np.asarray(cold.mask), np.asarray(inc.mask))
    assert int(inc.uncertified) == 0 and int(warm.uncertified) == 0
    # the exact factor seeds both bracket sides, so every lane resolves
    # at its first decide check: N iterations per round, strictly below
    # warm_start alone (which only banks uppers)
    assert int(inc.quad_iterations) == t * op.n
    assert int(inc.quad_iterations) < int(warm.quad_iterations)
    assert int(warm.quad_iterations) <= int(cold.quad_iterations)


def test_greedy_map_incremental_matches_exact_gains():
    op, lo, hi = _greedy_case("dense")
    inc = greedy_map(op, 8, lo, hi, max_iters=60, incremental=True)
    ex = greedy_map(op, 8, lo, hi, max_iters=60, exact=True)
    assert np.array_equal(np.asarray(inc.order), np.asarray(ex.order))
    np.testing.assert_allclose(np.asarray(inc.gains), np.asarray(ex.gains),
                               rtol=1e-9)


# ---------------------------------------------------------------------------
# chain steps: downdate-after-remove round trips, decisions pinned


def test_dpp_step_incremental_roundtrip_parity():
    n = 24
    a = make_spd(n, kappa=100.0, seed=7)
    op = Dense(jnp.asarray(a))
    w = np.linalg.eigvalsh(a)
    lo, hi = float(w[0] * 0.99), float(w[-1] * 1.01)
    mask0 = jnp.zeros(n, jnp.float32).at[:6].set(1.0)
    key = jax.random.key(3)

    s_inc = dpp.init_chain(key, mask0,
                           factor=update.from_mask(op, mask0))
    s_ex = dpp.init_chain(key, mask0)
    s_q = dpp.init_chain(key, mask0)
    for _ in range(30):
        s_inc = dpp.dpp_step(op, s_inc, lo, hi, max_iters=n + 2)
        s_ex = dpp.dpp_step(op, s_ex, lo, hi, max_iters=n + 2, exact=True)
        s_q = dpp.dpp_step(op, s_q, lo, hi, max_iters=n + 2)
        assert np.array_equal(np.asarray(s_inc.mask), np.asarray(s_ex.mask))
        assert np.array_equal(np.asarray(s_inc.mask), np.asarray(s_q.mask))
    assert int(s_inc.stats.quad_iterations) == 0
    assert int(s_inc.stats.uncertified) == 0
    assert int(s_q.stats.quad_iterations) > 0

    # the carried factor round-trips: after 30 add/remove moves it still
    # IS the Cholesky factor of the selected principal submatrix
    f = s_inc.factor
    sel = list(np.asarray(f.idx)[:int(f.count)])
    assert sorted(sel) == list(np.flatnonzero(np.asarray(s_inc.mask) > 0.5))
    np.testing.assert_allclose(
        np.asarray(f.chol)[:len(sel), :len(sel)],
        _dense_chol(a, sel), atol=1e-8)


def test_kdpp_step_incremental_parity_under_scan():
    n = 20
    a = make_spd(n, kappa=60.0, seed=8)
    op = Dense(jnp.asarray(a))
    w = np.linalg.eigvalsh(a)
    lo, hi = float(w[0] * 0.99), float(w[-1] * 1.01)
    mask0 = jnp.zeros(n, jnp.float32).at[:5].set(1.0)
    key = jax.random.key(11)
    base = dpp.run_chain(dpp.kdpp_step, op, key, mask0, 25, lo, hi,
                         max_iters=n + 2, exact=True)
    inc = dpp.run_chain(dpp.kdpp_step, op, key, mask0, 25, lo, hi,
                        max_iters=n + 2,
                        factor=update.from_mask(op, mask0, capacity=5))
    assert np.array_equal(np.asarray(base.mask), np.asarray(inc.mask))
    assert int(inc.stats.quad_iterations) == 0
    assert int(inc.stats.uncertified) == 0
    assert int(np.asarray(inc.mask).sum()) == 5  # k preserved


# ---------------------------------------------------------------------------
# streaming BlockRanker


_BLOCK, _DIM = 8, 6


def _cluster(scale, seed, nb=1, jitter=0.02):
    r = np.random.default_rng(seed)
    c = scale * r.standard_normal((1, _DIM))
    return (c + jitter * r.standard_normal((nb * _BLOCK, _DIM))) \
        .astype(np.float32)


def _cfg():
    return SolverConfig(max_iters=34, rtol=1e-3)


@pytest.mark.parametrize("coarse", [None, 2])
def test_block_ranker_first_call_matches_rank_blocks(coarse):
    keys = np.concatenate([_cluster(3.0, s) for s in range(6)])
    br = BlockRanker(block=_BLOCK, bucket=8, solver_config=_cfg(),
                     coarse_iters=coarse)
    order, info = br.extend(keys).rank()
    cold_order, cold = rank_blocks(keys, block=_BLOCK, bucket=8,
                                   solver_config=_cfg(),
                                   coarse_iters=coarse)
    assert np.array_equal(order, cold_order)
    # every block freshly solved on the same engine/solver: brackets are
    # bit-identical to the one-shot ranker
    assert np.array_equal(np.array(info["brackets"]),
                          np.array(cold["brackets"]))
    assert info["solved"] == info["blocks"] and info["reused"] == 0


def test_block_ranker_grown_cache_resolves_only_new_blocks():
    keys0 = np.concatenate([_cluster(3.0, s) for s in range(5)])
    grown = _cluster(6.0, 99)    # far from every existing cluster
    br = BlockRanker(block=_BLOCK, bucket=8, solver_config=_cfg())
    br.extend(keys0).rank()
    traces_before = flush_trace_count()
    order, info = br.extend(grown).rank()
    # in-place operator swap: same bucket -> the live engine's compiled
    # flush drivers are reused, no rebuild, no fresh trace
    assert br.stats["engine_builds"] == 1
    assert flush_trace_count() == traces_before
    # only the new block re-solved; everyone else kept banked brackets
    assert info["blocks"] == 6
    assert info["solved"] == 1 and info["reused"] == 5
    assert info["flushes"] == 1
    # ... and the streamed ranking still matches a cold re-rank of the
    # full grown cache (the kept blocks were rank-separated, so their
    # stale-but-valid brackets cannot flip the order)
    cold_order, cold = rank_blocks(np.concatenate([keys0, grown]),
                                   block=_BLOCK, bucket=8,
                                   solver_config=_cfg())
    assert np.array_equal(order, cold_order)
    assert 0 < info["iterations"] < cold["iterations"]


def test_block_ranker_bucket_overflow_rebuilds_engine():
    br = BlockRanker(block=_BLOCK, bucket=4, solver_config=_cfg())
    br.extend(np.concatenate([_cluster(3.0, s) for s in range(4)])).rank()
    assert br.stats["engine_builds"] == 1
    br.extend(_cluster(4.0, 41)).rank()   # 5 blocks > bucket of 4
    assert br.stats["engine_builds"] == 2


def test_block_ranker_partial_tail_block_is_rescored():
    # 2 full blocks + a half block; growing the tail must re-pool and
    # re-solve the tail block (its summary changed), not just append
    keys0 = np.concatenate([_cluster(3.0, s) for s in range(2)]
                           + [_cluster(5.0, 9)[:_BLOCK // 2]])
    br = BlockRanker(block=_BLOCK, bucket=8, solver_config=_cfg())
    _, info0 = br.extend(keys0).rank()
    assert info0["blocks"] == 3
    _, info1 = br.extend(_cluster(5.0, 9)[_BLOCK // 2:]).rank()
    assert info1["blocks"] == 3          # tail filled up, no new block
    assert info1["solved"] >= 1          # the tail re-solved


# ---------------------------------------------------------------------------
# pool_keys / apply_block_mask tail-block regressions


def test_pool_keys_pools_partial_tail():
    rng = np.random.default_rng(0)
    keys = rng.standard_normal((10, 4)).astype(np.float32)
    p = pool_keys(keys, block=4)
    assert p.shape == (3, 4)             # ceil(10/4), not floor
    tail = keys[8:].mean(0)
    tail = tail / (np.linalg.norm(tail) + 1e-8)
    np.testing.assert_allclose(p[2], tail, atol=1e-6)
    # full blocks unchanged vs the exact-multiple case
    np.testing.assert_allclose(p[:2], pool_keys(keys[:8], block=4),
                               atol=1e-6)


def test_apply_block_mask_tail_follows_its_block():
    ck = jnp.ones((1, 10, 2, 3))
    cv = jnp.ones((1, 10, 2, 3))
    # ceil-blocks mask: the tail keys follow their block's decision
    k2, v2 = apply_block_mask(ck, cv, np.array([True, False, True]),
                              block=4)
    expect = np.array([1, 1, 1, 1, 0, 0, 0, 0, 1, 1], float)
    np.testing.assert_array_equal(np.asarray(k2[0, :, 0, 0]), expect)
    np.testing.assert_array_equal(np.asarray(v2[0, :, 0, 0]), expect)
    # evicting the tail block really evicts the tail keys now
    k3, _ = apply_block_mask(ck, cv, np.array([True, False, False]),
                             block=4)
    np.testing.assert_array_equal(
        np.asarray(k3[0, :, 0, 0]),
        np.array([1, 1, 1, 1, 0, 0, 0, 0, 0, 0], float))
    # a legacy short mask still pads its uncovered tail as kept
    k4, _ = apply_block_mask(ck, cv, np.array([True, False]), block=4)
    np.testing.assert_array_equal(np.asarray(k4[0, :, 0, 0]), expect)
