"""Decision-round cadence (``SolverConfig.decide_every``, DESIGN.md
Sec. 11).

Thm. 4.2's nested-bracket monotonicity makes deferring the stopping rule
R iterations sound: a lane pays at most R-1 extra contractions and a
certified decision never flips. These tests pin exactly that contract:

  * judge decisions + certificates are bit-identical at every cadence;
  * per-lane iteration counts stay within R-1 of the R=1 run for
    PER-LANE decides (threshold/tolerance). The bound is deliberately
    NOT asserted for the argmax race: cross-lane coupling means a rival
    that keeps tightening can resolve the race EARLIER under R>1 — only
    the winner and its certificate are invariant;
  * the resume invariant ``resume(step_n(st, k)) == resume(st)`` holds
    bit-exactly at every cadence because states stay round-aligned
    (``step_n`` quantizes n down to whole rounds);
  * the cadence plumbing guards: config validation, the pair-driver
    rejection, ``resume_chunked`` chunk alignment.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BIFSolver, Dense, SolverConfig, sparse_from_dense
from conftest import make_spd

CADENCES = [1, 2, 4]


def _problem(n=33, kappa=150.0, seed=0, lanes=4):
    a = make_spd(n, kappa=kappa, seed=seed, density=0.4)
    w = np.linalg.eigvalsh(a)
    us = np.random.default_rng(seed + 1).standard_normal((lanes, n))
    return a, jnp.asarray(us), float(w[0] * 0.5), float(w[-1] * 2.5)


def _solvers(**kw):
    return {r: BIFSolver.create(decide_every=r, **kw) for r in CADENCES}


def test_tolerance_solve_certificates_invariant_iterations_bounded():
    a, us, lmn, lmx = _problem(seed=3)
    op = Dense(jnp.asarray(a))
    results = {r: s.solve(op, us, lam_min=lmn, lam_max=lmx)
               for r, s in _solvers(max_iters=30, rtol=1e-6).items()}
    ref = results[1]
    assert np.all(np.asarray(ref.certified))
    for r in CADENCES[1:]:
        got = results[r]
        np.testing.assert_array_equal(np.asarray(got.certified),
                                      np.asarray(ref.certified), f"R={r}")
        np.testing.assert_array_equal(np.asarray(got.converged),
                                      np.asarray(ref.converged), f"R={r}")
        extra = np.asarray(got.iterations) - np.asarray(ref.iterations)
        assert np.all(extra >= 0), f"R={r}: cadence lost iterations"
        assert np.all(extra <= r - 1), \
            f"R={r}: deferring the decide must cost at most R-1 " \
            f"contractions (Thm. 4.2), got {extra}"
        # the deferred lanes kept contracting: the nested brackets can
        # only tighten, never cross the R=1 bracket
        assert np.all(np.asarray(got.lower) >= np.asarray(ref.lower)
                      - 1e-30)
        assert np.all(np.asarray(got.upper) <= np.asarray(ref.upper)
                      + 1e-30)


def test_threshold_judge_decisions_invariant_across_cadence():
    a, us, lmn, lmx = _problem(seed=5)
    op = sparse_from_dense(a)
    true = np.einsum("ki,ki->k", np.asarray(us),
                     np.linalg.solve(a, np.asarray(us).T).T)
    t = jnp.asarray(true * np.array([0.7, 0.999, 1.001, 1.3]))
    results = {r: s.judge_threshold(op, us, t, lam_min=lmn, lam_max=lmx)
               for r, s in _solvers(max_iters=35).items()}
    ref = results[1]
    for r in CADENCES[1:]:
        got = results[r]
        np.testing.assert_array_equal(np.asarray(got.decision),
                                      np.asarray(ref.decision), f"R={r}")
        np.testing.assert_array_equal(np.asarray(got.certified),
                                      np.asarray(ref.certified), f"R={r}")
        extra = np.asarray(got.iterations) - np.asarray(ref.iterations)
        assert np.all((extra >= 0) & (extra <= r - 1)), f"R={r}: {extra}"


def test_argmax_winner_and_certificate_invariant_across_cadence():
    n = 32
    rng = np.random.default_rng(0)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    evals = np.geomspace(1e-3, 1.0, n)
    a = (q * evals) @ q.T
    us = jnp.asarray(rng.standard_normal((6, n)))
    true = np.einsum("ki,ki->k", np.asarray(us),
                     np.linalg.solve(a, np.asarray(us).T).T)
    results = {r: s.judge_argmax(Dense(jnp.asarray(a)), us,
                                 lam_min=1e-3 * 0.99, lam_max=1.01)
               for r, s in _solvers(max_iters=40).items()}
    for r in CADENCES:
        got = results[r]
        assert int(got.index) == int(np.argmax(true)), f"R={r}"
        assert bool(got.certified), f"R={r}"
        # no iteration-count assertion: the race's cross-lane coupling
        # means R>1 runs may resolve EARLIER than R=1 (rivals keep
        # tightening past their R=1 freeze point)


@pytest.mark.parametrize("r", CADENCES)
def test_resume_invariant_at_every_cadence(r):
    """resume(step_n(st, k)) == resume(st) bit-exact, including k values
    that are not multiples of R (step_n quantizes them down to whole
    rounds, so the interrupted state is always round-aligned)."""
    a, us, lmn, lmx = _problem(seed=7)
    op = sparse_from_dense(a)
    s = BIFSolver.create(max_iters=30, rtol=1e-6, decide_every=r)
    ref = s.resume(s.init_state(op, us, lam_min=lmn, lam_max=lmx))
    for k in (1, 2, 3, 5):
        state = s.init_state(op, us, lam_min=lmn, lam_max=lmx)
        state = s.step_n(state, k)
        if k < r:
            # quantized to zero rounds: a bounded advance below one
            # round is a no-op, never a mid-round checkpoint
            assert state.step == 0
        got = s.resume(state)
        np.testing.assert_array_equal(np.asarray(got.lower),
                                      np.asarray(ref.lower), f"k={k}")
        np.testing.assert_array_equal(np.asarray(got.upper),
                                      np.asarray(ref.upper), f"k={k}")
        np.testing.assert_array_equal(np.asarray(got.it),
                                      np.asarray(ref.it), f"k={k}")
        # round alignment: the step counter is always a multiple of R
        assert int(got.step) % r == 0


def test_resume_chunked_aligns_chunks_up_to_the_cadence():
    """chunk_iters below/offset from R cannot livelock: the chunk is
    aligned UP to a whole number of rounds and the chunked drive stays
    bit-exact with the monolithic one."""
    a, us, lmn, lmx = _problem(seed=11, kappa=400.0)
    op = sparse_from_dense(a)
    s = BIFSolver.create(max_iters=30, rtol=1e-8, decide_every=4)
    ref = s.resume(s.init_state(op, us, lam_min=lmn, lam_max=lmx))
    for chunk in (1, 3, 6):  # all misaligned with R=4
        chk = s.resume_chunked(
            s.init_state(op, us, lam_min=lmn, lam_max=lmx),
            chunk_iters=chunk)
        np.testing.assert_array_equal(np.asarray(ref.lower),
                                      np.asarray(chk.lower), f"chunk={chunk}")
        np.testing.assert_array_equal(np.asarray(ref.it),
                                      np.asarray(chk.it), f"chunk={chunk}")


def test_cadence_with_matfun_states():
    """fn != 'inv' (coefficient-history states) honors the cadence: the
    retrospective logdet bracket certifies identically at every R."""
    a, us, lmn, lmx = _problem(n=24, seed=13)
    op = Dense(jnp.asarray(a))
    results = {r: s.solve(op, us, lam_min=lmn, lam_max=lmx)
               for r, s in _solvers(max_iters=24, rtol=1e-5,
                                    fn="log", precondition="none").items()}
    ref = results[1]
    sign, logdet = np.linalg.slogdet(a)
    assert sign > 0
    for r in CADENCES:
        got = results[r]
        np.testing.assert_array_equal(np.asarray(got.certified),
                                      np.asarray(ref.certified), f"R={r}")
        extra = np.asarray(got.iterations) - np.asarray(ref.iterations)
        assert np.all((extra >= 0) & (extra <= r - 1)), f"R={r}: {extra}"
        # the bracket still contains the truth at every cadence
        true = _logquad(a, np.asarray(us))
        lo = np.minimum(np.asarray(got.lower), np.asarray(got.upper))
        hi = np.maximum(np.asarray(got.lower), np.asarray(got.upper))
        assert np.all((lo <= true + 1e-8) & (true <= hi + 1e-8)), f"R={r}"


def _logquad(a, us):
    w, v = np.linalg.eigh(a)
    proj = us @ v
    return np.einsum("ki,ki->k", proj, proj * np.log(w))


def test_cadence_config_and_pair_driver_guards():
    with pytest.raises(ValueError, match="decide_every"):
        SolverConfig(decide_every=0)
    a, us, lmn, lmx = _problem(seed=17)
    op = Dense(jnp.asarray(a))
    s = BIFSolver.create(max_iters=20, decide_every=2)
    with pytest.raises(NotImplementedError, match="decide_every"):
        s.solve_pair(op, us[0], op, us[1],
                     resolved=lambda ps: jnp.ones((), bool),
                     pick_a=lambda ps: jnp.ones((), bool),
                     lam_min=lmn, lam_max=lmx)
    # step_n below one round is the identity on the checkpoint object
    st = s.init_state(op, us, lam_min=lmn, lam_max=lmx)
    assert s.step_n(st, 1) is st
