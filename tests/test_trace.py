"""Stochastic trace estimation (core/trace.py) + the logdet workloads
(dpp.log_likelihood, train.monitor.logdet_bounds), DESIGN.md Sec. 9.

Oracles are dense eigendecompositions / ``slogdet`` throughout: exact
unit-probe runs must bracket the TRUE trace deterministically; the
Hutchinson runs must bracket the probe-sample mean (recomputed here
from the identical reproducible probe stream) with the statistical
interval containing the truth.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Dense, Masked, sparse_from_dense, trace_quad, \
    logdet_quad, log_likelihood
from repro.core.trace import _rademacher_probe
from repro.train.monitor import logdet_bounds
from conftest import make_spd


def _problem(n=24, kappa=50.0, seed=0):
    a = make_spd(n, kappa=kappa, seed=seed)
    w, v = np.linalg.eigh(a)
    return a, w, v, float(w[0] * 0.99), float(w[-1] * 1.01)


@pytest.mark.parametrize("fn,f", [("log", np.log),
                                  ("invsqrt", lambda x: x ** -0.5),
                                  ("inv", lambda x: 1.0 / x)])
@pytest.mark.parametrize("op_kind", ["dense", "sparse_coo"])
def test_exact_probes_bracket_true_trace(fn, f, op_kind):
    a, w, _, lmn, lmx = _problem()
    op = Dense(jnp.asarray(a)) if op_kind == "dense" \
        else sparse_from_dense(a)
    true = float(np.sum(f(w)))
    r = trace_quad(op, fn, None, lam_min=lmn, lam_max=lmx)
    scale = max(abs(true), 1.0)
    assert r.lower <= true + 1e-8 * scale
    assert r.upper >= true - 1e-8 * scale
    assert r.upper - r.lower <= 1e-3 * scale
    # exact mode: no sampling error, stat interval == det bracket
    assert r.std_error == 0.0
    assert (r.stat_lower, r.stat_upper) == (r.lower, r.upper)
    assert r.num_probes == a.shape[0]


def test_hutchinson_brackets_probe_sample_mean():
    a, w, v, lmn, lmx = _problem(seed=3)
    n = a.shape[0]
    key = jax.random.key(7)
    r = trace_quad(Dense(jnp.asarray(a)), "log", 8, lam_min=lmn,
                   lam_max=lmx, key=key)
    # recompute the identical probes from the reproducible stream
    vals = []
    for i in range(8):
        z = np.asarray(_rademacher_probe(key, i, n, np.float64))
        c = v.T @ z
        vals.append(float(np.sum(c * c * np.log(w))))
    sample_mean = float(np.mean(vals))
    assert r.lower <= sample_mean <= r.upper
    # the per-probe brackets each contain their probe's true value
    for i, val in enumerate(vals):
        assert r.state.probe_lower[i] <= val <= r.state.probe_upper[i]
    # the statistical interval covers the true trace here
    true = float(np.sum(np.log(w)))
    assert r.stat_lower <= true <= r.stat_upper
    assert r.std_error > 0.0


def test_probe_by_probe_resume_matches_direct():
    a, _, _, lmn, lmx = _problem(seed=5)
    op = sparse_from_dense(a)
    key = jax.random.key(11)
    r8 = trace_quad(op, "log", 8, lam_min=lmn, lam_max=lmx, key=key)
    r16 = trace_quad(op, "log", 16, lam_min=lmn, lam_max=lmx, key=key,
                     state=r8.state)
    direct = trace_quad(op, "log", 16, lam_min=lmn, lam_max=lmx, key=key)
    # SparseCOO lanes are bit-exact across batch shapes, so resumed ==
    # direct exactly (probes 0..7 reuse the banked brackets)
    assert (r16.lower, r16.upper) == (direct.lower, direct.upper)
    assert r16.iterations == direct.iterations
    np.testing.assert_array_equal(r16.state.probe_lower,
                                  direct.state.probe_lower)
    # chunked probe batches accumulate the same estimate
    chunked = trace_quad(op, "log", 16, lam_min=lmn, lam_max=lmx,
                         key=key, probe_chunk=4)
    np.testing.assert_array_equal(chunked.state.probe_lower,
                                  direct.state.probe_lower)
    # guardrails
    with pytest.raises(ValueError, match="resume state banks"):
        trace_quad(op, "invsqrt", 16, lam_min=lmn, lam_max=lmx, key=key,
                   state=r8.state)
    with pytest.raises(ValueError, match="can only extend"):
        trace_quad(op, "log", 4, lam_min=lmn, lam_max=lmx, key=key,
                   state=r8.state)
    with pytest.raises(ValueError, match="num_probes"):
        trace_quad(op, "log", 0, lam_min=lmn, lam_max=lmx)
    with pytest.raises(ValueError, match="different key"):
        trace_quad(op, "log", 16, lam_min=lmn, lam_max=lmx,
                   key=jax.random.key(99), state=r8.state)
    with pytest.raises(ValueError, match="spectral interval"):
        trace_quad(op, "log", 16, lam_min=lmn * 0.5, lam_max=lmx,
                   key=key, state=r8.state)


def test_block_probe_extend_matches_direct_bit_exact():
    """The block-mode twin of the scalar extend pin: resuming with a
    larger ``num_probes`` under ``block_size > 1`` adds WHOLE blocks and
    keeps the banked probe stream bit-identical (probe i is still
    ``fold_in(key, i)``; blocks are consecutive index groups)."""
    a, w, _, lmn, lmx = _problem(seed=5)
    op = sparse_from_dense(a)
    key = jax.random.key(11)
    kw = dict(lam_min=lmn, lam_max=lmx, key=key, block_size=4)
    r8 = trace_quad(op, "log", 8, **kw)
    assert len(r8.state.probe_lower) == 2          # 2 banked block lanes
    r16 = trace_quad(op, "log", 16, state=r8.state, **kw)
    direct = trace_quad(op, "log", 16, **kw)
    # SparseCOO lanes are bit-exact across batch shapes, so resumed ==
    # direct exactly (blocks 0..1 reuse the banked lane brackets)
    assert (r16.lower, r16.upper) == (direct.lower, direct.upper)
    assert (r16.estimate, r16.std_error) == (direct.estimate,
                                             direct.std_error)
    assert r16.iterations == direct.iterations
    np.testing.assert_array_equal(r16.state.probe_lower,
                                  direct.state.probe_lower)
    np.testing.assert_array_equal(r16.state.probe_upper,
                                  direct.state.probe_upper)
    np.testing.assert_array_equal(r16.state.iterations,
                                  direct.state.iterations)
    # chunked walks round up to whole blocks and bank identically
    chunked = trace_quad(op, "log", 16, probe_chunk=6, **kw)
    np.testing.assert_array_equal(chunked.state.probe_lower,
                                  direct.state.probe_lower)
    # the statistical interval still covers the truth on this problem
    true = float(np.sum(np.log(w)))
    assert direct.stat_lower <= true <= direct.stat_upper
    # guardrails: whole blocks only, and no re-bucketing a banked state
    with pytest.raises(ValueError, match="multiple of block_size"):
        trace_quad(op, "log", 10, lam_min=lmn, lam_max=lmx, key=key,
                   block_size=4)
    with pytest.raises(ValueError, match="banks block_size"):
        trace_quad(op, "log", 16, lam_min=lmn, lam_max=lmx, key=key,
                   block_size=2, state=r8.state)
    with pytest.raises(ValueError, match="banks block_size"):
        trace_quad(op, "log", 16, lam_min=lmn, lam_max=lmx, key=key,
                   state=r8.state)


def test_block_exact_mode_brackets_true_trace_with_padding():
    """Exact unit-probe mode with a block width that does NOT divide N:
    the final block zero-pads, the pad slots deflate, and the summed
    bracket still certifies the true trace."""
    a, w, _, lmn, lmx = _problem(seed=2)      # N = 24, b = 7 pads to 28
    op = Dense(jnp.asarray(a))
    true = float(np.sum(np.log(w)))
    r = trace_quad(op, "log", None, lam_min=lmn, lam_max=lmx,
                   block_size=7)
    scale = max(abs(true), 1.0)
    assert r.lower <= true + 1e-8 * scale
    assert r.upper >= true - 1e-8 * scale
    assert r.std_error == 0.0
    assert r.num_probes == a.shape[0]
    assert len(r.state.probe_lower) == 4      # ceil(24 / 7) block lanes


def test_log_likelihood_brackets_slogdet_truth():
    a, w, _, lmn, lmx = _problem(seed=9, kappa=30.0)
    n = a.shape[0]
    rng = np.random.default_rng(5)
    for seed in (0, 1):
        mask = (rng.random(n) < 0.6).astype(float)
        idx = np.where(mask > 0.5)[0]
        true = float(np.linalg.slogdet(a[np.ix_(idx, idx)])[1]
                     - np.linalg.slogdet(a + np.eye(n))[1])
        ll = log_likelihood(Dense(jnp.asarray(a)), jnp.asarray(mask),
                            lmn, lmx)
        scale = max(abs(true), 1.0)
        assert ll.lower <= true + 1e-8 * scale
        assert ll.upper >= true - 1e-8 * scale
        assert ll.upper - ll.lower <= 1e-3 * scale
        assert abs(ll.estimate - true) <= 1e-4 * scale
    # the empty set: logdet(L_{}) = 0, so log P = -logdet(L + I)
    ll0 = log_likelihood(Dense(jnp.asarray(a)), jnp.zeros(n), lmn, lmx)
    true0 = -float(np.linalg.slogdet(a + np.eye(n))[1])
    assert ll0.lower <= true0 <= ll0.upper


def test_logdet_quad_masked_needs_no_correction():
    """tr log of the fixed-shape Masked operator IS logdet(A_Y): the
    identity block contributes log(1) = 0 — pinned explicitly because
    every other f would need a (N - |Y|) * f(1) correction."""
    a, _, _, lmn, lmx = _problem(seed=13, kappa=20.0)
    n = a.shape[0]
    mask = (np.random.default_rng(2).random(n) < 0.5).astype(float)
    idx = np.where(mask > 0.5)[0]
    true = float(np.linalg.slogdet(a[np.ix_(idx, idx)])[1])
    r = logdet_quad(Masked(Dense(jnp.asarray(a)), jnp.asarray(mask)),
                    None, lam_min=min(lmn, 1.0), lam_max=max(lmx, 1.0))
    assert r.lower <= true <= r.upper
    assert r.upper - r.lower <= 1e-3 * max(abs(true), 1.0)


def test_monitor_logdet_bounds():
    rng = np.random.default_rng(0)
    sketches = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    lam = 1e-2
    s = np.asarray(sketches, np.float64)
    f = s.T @ s / s.shape[0] + lam * np.eye(16)
    true = float(np.linalg.slogdet(f)[1])
    r = logdet_bounds(sketches, lam=lam, max_iters=32)
    # f32 quadrature against an f64 oracle: containment to f32 slack
    scale = max(abs(true), 1.0)
    assert r.lower <= true + 1e-4 * scale
    assert r.upper >= true - 1e-4 * scale
    assert abs(r.estimate - true) <= 1e-2 * scale
