"""Checkpoint substrate: roundtrip, commit marker, retention, async,
elastic restore onto different shardings (subprocess w/ 8 devices)."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt


def make_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 5, (4,)), jnp.int32),
                  "d": jnp.asarray(rng.standard_normal(()), jnp.float32)}}


def test_roundtrip(tmp_path):
    t = make_tree()
    ckpt.save(tmp_path, 3, t)
    assert ckpt.latest_step(tmp_path) == 3
    r = ckpt.restore(tmp_path, 3, jax.tree.map(jnp.zeros_like, t))
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_uncommitted_ignored(tmp_path):
    t = make_tree()
    ckpt.save(tmp_path, 1, t)
    ckpt.save(tmp_path, 2, t)
    (tmp_path / "step_00000002" / "_COMMITTED").unlink()
    assert ckpt.latest_step(tmp_path) == 1


def test_retention(tmp_path):
    t = make_tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, t)
    ckpt.retain(tmp_path, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert steps == ["step_00000004", "step_00000005"]


def test_async_saver(tmp_path):
    t = make_tree()
    s = ckpt.AsyncSaver()
    s.save(tmp_path, 7, t)
    s.wait()
    assert ckpt.latest_step(tmp_path) == 7
    r = ckpt.restore(tmp_path, 7, jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t["a"]))


def test_leaf_count_mismatch_raises(tmp_path):
    t = make_tree()
    ckpt.save(tmp_path, 1, t)
    with pytest.raises(AssertionError):
        ckpt.restore(tmp_path, 1, {"only": jnp.zeros((2,))})


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={nd}"
import sys
sys.path.insert(0, "{src}")
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import io as ckpt

mesh = jax.make_mesh(({nd},), ("data",))
t = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
if "{mode}" == "save":
    sh = NamedSharding(mesh, P("data", None))
    t = jax.tree.map(lambda x: jax.device_put(x, sh), t)
    ckpt.save("{dir}", 1, t)
else:
    sh = {{"w": NamedSharding(mesh, P(None, "data"))}}
    r = ckpt.restore("{dir}", 1, jax.eval_shape(lambda: t), shardings=sh)
    assert r["w"].sharding.spec == P(None, "data"), r["w"].sharding
    np.testing.assert_array_equal(np.asarray(r["w"]),
                                  np.arange(64).reshape(8, 8))
print("OK-{mode}")
"""


@pytest.mark.parametrize("nd_save,nd_load", [(8, 4), (4, 8)])
def test_elastic_restore_across_device_counts(tmp_path, nd_save, nd_load):
    src = str(Path(__file__).resolve().parent.parent / "src")
    for mode, nd in (("save", nd_save), ("load", nd_load)):
        script = ELASTIC_SCRIPT.format(nd=nd, src=src, dir=tmp_path,
                                       mode=mode)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=120)
        assert f"OK-{mode}" in out.stdout, out.stderr[-2000:]
