"""Pallas kernel validation + arithmetic accounting (interpret mode wall
times on CPU are NOT TPU performance; the derived column reports the
analytic FLOP/byte profile that sizes the kernels for v5e)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import row, time_fn


def run(quick: bool = True):
    rows = []
    rng = np.random.default_rng(0)

    b, n = 4, 512
    a = jnp.asarray(rng.standard_normal((b, n, n)), jnp.float32)
    a = (a + jnp.swapaxes(a, -1, -2)) / 2
    x = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    y, al = ops.fused_matvec(a, x, interpret=True)
    yr, alr = ref.fused_matvec(a, x)
    # alpha accumulates 512^2 f32 terms tile-wise: looser tolerance
    ok = np.allclose(y, yr, rtol=1e-4, atol=1e-3) \
        and np.allclose(al, alr, rtol=1e-3)
    flops = 2 * b * n * n + 2 * b * n
    bytes_ = 4 * b * n * n
    rows.append(row("pallas_fused_matvec_B4_N512", 0.0,
                    f"valid={ok};flops={flops};bytes={bytes_};"
                    f"intensity={flops/bytes_:.2f};"
                    "fusion saves 1 full pass over A per GQL iter"))

    # block-structured sparsity (banded graph Laplacian): the regime the
    # blocked-ELL layout is built for
    from repro.data import graph_laplacian
    nn = 1024
    m = graph_laplacian(nn, mean_degree=8, rewire=0.0, seed=0)
    data, cols, _ = ops.dense_to_bell(m, bs=64)
    xx = jnp.asarray(rng.standard_normal(data.shape[0] * 64), jnp.float32)
    ok = np.allclose(ops.bell_matvec(data, cols, xx, interpret=True),
                     ref.bell_matvec(data, cols, xx), atol=1e-4)
    nb = int(data.shape[0] * data.shape[1])
    dense_nb = int(data.shape[0] ** 2)
    rows.append(row("pallas_bell_spmv_N1024_banded", 0.0,
                    f"valid={ok};stored_blocks={nb};dense_blocks={dense_nb};"
                    f"flop_saving={dense_nb/max(nb,1):.1f}x"))

    # blocked matvec: ONE gemm over a row-stacked probe block vs b
    # stacked gemvs (the block-Krylov workhorse, DESIGN.md Sec. 13).
    # Dense goes through operators.matvec_mrhs; BELL through the mrhs
    # pallas kernel (column-stacked X rides one pass over the blocks).
    from repro.core import operators as _op
    import jax as _jx
    bw = 8
    ad = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    dop = _op.Dense((ad + ad.T) / 2)
    xb = jnp.asarray(rng.standard_normal((bw, 512)), jnp.float32)
    gemm_fn = _jx.jit(lambda x_: _op.matvec_mrhs(dop, x_))
    gemv_fn = _jx.jit(lambda x_: jnp.stack(
        [dop.matvec(x_[i]) for i in range(bw)]))
    ok = np.allclose(gemm_fn(xb), gemv_fn(xb), rtol=1e-5, atol=1e-4)
    t_gemm, t_gemv = time_fn(gemm_fn, xb), time_fn(gemv_fn, xb)
    a_bytes = 4 * 512 * 512
    rows.append(row("dense_matvec_mrhs_b8_N512", t_gemm * 1e6,
                    f"valid={ok};stacked_gemv_us={t_gemv * 1e6:.2f};"
                    f"a_bytes_gemm={a_bytes};a_bytes_gemv={bw * a_bytes};"
                    "one (b,N)@(N,N) gemm reads A once per block-Lanczos "
                    "iter vs b passes (CPU walls are not accel perf)"))
    xc = jnp.asarray(rng.standard_normal((data.shape[0] * 64, bw)),
                     jnp.float32)
    ym = ops.bell_matvec_mrhs(data, cols, xc, interpret=True)
    ys = jnp.stack([ops.bell_matvec(data, cols, xc[:, i], interpret=True)
                    for i in range(bw)], axis=-1)
    ok = np.allclose(ym, ys, atol=1e-4)
    blk_fl = 2 * nb * 64 * 64 * bw
    rows.append(row("pallas_bell_mrhs_b8_N1024", 0.0,
                    f"valid={ok};flops={blk_fl};"
                    "each stored (bs,bs) block does one (bs,bs)@(bs,b) "
                    "MXU gemm -- b columns ride one block walk"))

    # realizable GQL states from a short real run (not random garbage)
    from repro.core import Dense, gql, lanczos
    from .conftest_shim import make_spd
    bb = 256
    aa = make_spd(96, kappa=200.0, seed=1).astype(np.float32)
    wop = Dense(jnp.broadcast_to(jnp.asarray(aa), (bb, 96, 96)))
    uu = jnp.asarray(rng.standard_normal((bb, 96)), jnp.float32)
    wv = np.linalg.eigvalsh(aa)
    lmn, lmx = float(wv[0] * 0.9), float(wv[-1] * 1.1)
    stt = gql.gql_init(wop, uu, lmn, lmx)
    lz1 = lanczos.lanczos_step(wop, stt.lz)
    out = ops.gql_update(lz1.alpha, lz1.beta, lz1.beta_prev, stt.g, stt.c,
                         stt.delta, stt.delta_lr, stt.delta_rr, lmn, lmx,
                         interpret=True)
    outr = ref.gql_update(lz1.alpha, lz1.beta, lz1.beta_prev, stt.g, stt.c,
                          stt.delta, stt.delta_lr, stt.delta_rr,
                          jnp.float32(lmn), jnp.float32(lmx))
    ok = all(np.allclose(a_, b_, rtol=1e-5) for a_, b_ in zip(out, outr))
    rows.append(row("pallas_gql_update_B256", 0.0,
                    f"valid={ok};fuses 8 elementwise lane-ops -> 1 VPU pass"))

    # the fused per-iteration megakernel vs the reference composition
    # (matvec + Lanczos update + recurrence as separate XLA ops): one
    # pallas_call per GQL iteration (DESIGN.md Sec. 11)
    import jax as _jax
    st2 = gql.gql_step(wop, stt, lmn, lmx)  # one real step in
    fused_fn = _jax.jit(lambda s: ops.gql_step_fused(wop, s, lmn, lmx,
                                                     interpret=True))
    ref_fn = _jax.jit(lambda s: gql.gql_step(wop, s, lmn, lmx))
    got, want = fused_fn(st2), ref_fn(st2)
    ok = all(np.allclose(np.asarray(g), np.asarray(w), rtol=1e-5,
                         atol=1e-6)
             for g, w in zip(_jax.tree.leaves(got), _jax.tree.leaves(want))
             if np.asarray(w).dtype.kind == "f")
    t_fused = time_fn(fused_fn, st2)
    t_ref = time_fn(ref_fn, st2)
    # per iteration the fused step reads A once and keeps v/r/recurrence
    # scalars in VMEM; the composition pays A once plus ~6 extra HBM
    # round-trips over the (B, N) vectors for alpha/r/beta/recurrence
    fl = 2 * bb * 96 * 96 + 10 * bb * 96
    extra_hbm = 6 * 4 * bb * 96
    rows.append(row("pallas_fused_step_B256_N96", t_fused * 1e6,
                    f"valid={ok};ref_us={t_ref * 1e6:.2f};"
                    f"flops={fl};vector_hbm_saved={extra_hbm};"
                    "whole GQL iteration in one pallas_call "
                    "(interpret-mode walls, not TPU perf)"))

    q = jnp.asarray(rng.standard_normal((4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((4, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((4, 256, 64)), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=True, bt=64, bs=64,
                            interpret=True)
    ok = np.allclose(o, ref.flash_attention(q, k, v, causal=True),
                     rtol=1e-4, atol=1e-4)
    fl = 4 * 4 * 256 * 256 * 64
    hbm = 4 * (3 * 4 * 256 * 64 + 4 * 256 * 64)
    rows.append(row("pallas_flash_attn_BH4_T256_D64", 0.0,
                    f"valid={ok};flops={fl};hbm_bytes={hbm};"
                    f"intensity={fl/hbm:.0f} (vs ~8 unfused)"))
    return rows, {}
