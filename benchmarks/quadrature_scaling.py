"""Rate verification (Thm. 3/5/8): iterations-to-tolerance should track
sqrt(kappa) (linear convergence with ratio (sqrt(k)-1)/(sqrt(k)+1)), and
per-iteration cost should scale with nnz (matvec-bound)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import BIFSolver, Dense
from .conftest_shim import make_spd

from .common import row, time_fn


def run(quick: bool = True):
    rows = []
    n = 300
    for kappa in [10, 100, 1000]:
        a = make_spd(n, kappa=float(kappa), seed=0)
        w = np.linalg.eigvalsh(a)
        u = np.random.default_rng(0).standard_normal(n)
        op = Dense(jnp.asarray(a))
        res = BIFSolver.create(max_iters=n, rtol=1e-6).solve(
            op, jnp.asarray(u), lam_min=float(w[0] * 0.99),
            lam_max=float(w[-1] * 1.01))
        iters = int(res.iterations)
        rho = (np.sqrt(kappa) - 1) / (np.sqrt(kappa) + 1)
        # theory: iters ~ log(tol/2kappa+) / log(rho)
        pred = int(np.ceil(np.log(1e-6 / (2 * kappa * 1.02))
                           / np.log(rho))) if rho > 0 else 1
        rows.append(row(f"iters_to_1e-6_kappa_{kappa}", iters,
                        f"theory_upper={pred};ratio={iters/max(pred,1):.2f}"))

    for nn in ([200, 400] if quick else [200, 400, 800, 1600]):
        a = make_spd(nn, kappa=100.0, seed=1)
        w = np.linalg.eigvalsh(a)
        u = np.random.default_rng(1).standard_normal(nn)
        op = Dense(jnp.asarray(a))
        import jax
        solver = BIFSolver.create(max_iters=60, rtol=1e-4)
        f = jax.jit(lambda uu: solver.solve(
            op, uu, lam_min=float(w[0] * 0.99),
            lam_max=float(w[-1] * 1.01)).lower)
        t = time_fn(f, jnp.asarray(u), repeats=3)
        rows.append(row(f"bif_bounds_wall_n_{nn}", t * 1e6,
                        "per-iteration cost ~ dense matvec O(n^2)"))
    return rows, {}
