"""Block-Krylov vs scalar quadrature at EQUAL matvec budget (Sec. 13).

The workload: ``tr f(A)`` (f = log for logdet, f = inv for the trace of
the inverse) on a spiked-spectrum SPD matrix — a bulk uniform on [1, 4]
plus a handful of tiny eigenvalues log-spaced in [1e-3, 1e-2] under a
seeded random orthogonal similarity. The spikes are exactly the regime
where scalar Lanczos stalls: each probe's Krylov space must rediscover
the tiny eigenvalues alone, while a width-b block lane shares one
deflated basis across its b probes.

Budget accounting: a width-b lane performs b matvecs per block-Lanczos
iteration (one ``matvec_mrhs`` gemm), and P probes occupy P/b lanes, so
``total matvecs = P * iters`` for EVERY b — equal ``(num_probes,
max_iters)`` is an equal matvec/FLOP budget. Per-iteration FLOPs are
also equal in wall-clock terms on the scalar side: the scalar driver
already gemm-batches its P probe lanes, so the block win reported here
is deflation-driven earlier bracket resolution, not dense-algebra
throughput (DESIGN.md Sec. 13 spells this out).

Two probe regimes per (N, f):

  * exact unit-probe mode (``num_probes=None``, the headline): se = 0,
    so the CI the decision rules consume IS the certified deterministic
    bracket — the block narrowing is pure quadrature convergence;
  * Hutchinson mode at fixed P: the variance-reduced block estimator.
    Sampling noise dominates the CI at practical P, so the honest
    block win there is the per-probe bracket width and the resolved
    count, with the se reduction reported as-is.

Reported per b: wall clock, CI width, deterministic bracket width, mean
iterations to the final width, resolved probes (lanes certified before
the iteration cap), and the headline ratios vs the b = 1 column —
CI width per GFLOP and wall clock per resolved probe.

Tables land in ``BENCH_block_quadrature.json`` at the repo root via
``benchmarks/run.py``; ``BENCH_TINY=1`` shrinks to a smoke size that
does NOT clobber the tracked json (the PR-4 convention).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import row
from repro.core import Dense, trace_quad

_N_SPIKES = 6


def _problem(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    bulk = rng.uniform(1.0, 4.0, n - _N_SPIKES)
    spikes = np.logspace(-3.0, -2.0, _N_SPIKES)
    w = np.concatenate([spikes, bulk])
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    a = (q * w) @ q.T
    a = (a + a.T) / 2
    return a, float(w.min() * 0.999), float(w.max() * 1.001), w


def _time(fn, repeats=3, warmup=1):
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _bench_one(n: int, fn: str, probes, max_iters: int,
               block_sizes: tuple):
    a, lam_min, lam_max, w = _problem(n)
    truth = float(np.sum(np.log(w) if fn == "log" else 1.0 / w))
    op = Dense(jnp.asarray(a))
    key = jax.random.key(0)
    out = {"truth": round(truth, 4),
           "num_probes": "exact" if probes is None else probes,
           "max_iters": max_iters}
    for b in block_sizes:
        def go():
            return trace_quad(op, fn, probes, lam_min=lam_min,
                              lam_max=lam_max, max_iters=max_iters,
                              rtol=1e-5, atol=1e-5, key=key,
                              block_size=b)
        r = go()  # cold call doubles as the jit warmup
        # exact mode at N=1024 runs tens of seconds per solve and is
        # deterministic, so a single warm timing is representative
        wall = _time(go, repeats=1, warmup=0) if probes is None \
            else _time(go)
        its = np.asarray(r.state.iterations)
        resolved = min(int((its < max_iters).sum()) * b, r.num_probes)
        matvecs = r.num_probes * float(its.mean())
        gflops = 2.0 * n * n * matvecs / 1e9
        ci = float(r.stat_upper - r.stat_lower)
        out[f"b{b}"] = {
            "wall_s": round(wall, 5),
            "ci_width": round(ci, 6),
            "det_bracket_width": round(float(r.upper - r.lower), 6),
            "std_error": round(float(r.std_error), 5),
            "iters_mean": round(float(its.mean()), 1),
            "resolved_probes": resolved,
            "matvecs": int(matvecs),
            "ci_width_per_gflop": round(ci / gflops, 6),
            "wall_per_resolved_probe_ms": round(
                wall / max(resolved, 1) * 1e3, 3),
            "stat_contains_truth": bool(r.stat_lower <= truth
                                        <= r.stat_upper),
        }
    b1 = out[f"b{block_sizes[0]}"]
    for b in block_sizes[1:]:
        bb = out[f"b{b}"]
        bb["ci_narrowing_vs_scalar"] = round(
            b1["ci_width"] / max(bb["ci_width"], 1e-300), 2)
        bb["wall_per_probe_speedup_vs_scalar"] = round(
            b1["wall_per_resolved_probe_ms"]
            / max(bb["wall_per_resolved_probe_ms"], 1e-300), 2)
    return out


def run(quick: bool = True):
    if os.environ.get("BENCH_TINY"):
        configs = [(64, "log", None, 12, (1, 4))]
    else:
        configs = [(256, "log", None, 24, (1, 4, 8)),
                   (256, "inv", None, 24, (1, 4, 8)),
                   (1024, "log", None, 24, (1, 4, 8)),
                   (1024, "inv", None, 24, (1, 4, 8)),
                   # P = 64 keeps >= 8 lane means in the block CI --
                   # fewer lanes make the ddof=1 normal interval itself
                   # too noisy to report
                   (256, "log", 64, 24, (1, 4, 8)),
                   (1024, "log", 64, 24, (1, 4, 8))]
    rows, tables = [], {}
    for n, fn, probes, max_iters, bs in configs:
        r = _bench_one(n, fn, probes, max_iters, bs)
        tag = "exact" if probes is None else f"p{probes}"
        tables[f"n{n}_{fn}_{tag}"] = r
        top = r[f"b{bs[-1]}"]
        rows.append(row(
            f"block_quadrature_n{n}_{fn}_{tag}_b{bs[-1]}",
            top["wall_s"] * 1e6,
            f"ci_narrow_{top.get('ci_narrowing_vs_scalar', 1.0)}x_"
            f"wallprobe_{top.get('wall_per_probe_speedup_vs_scalar', 1.0)}x"))
    return rows, tables
