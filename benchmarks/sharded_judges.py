"""1-device vs 8-virtual-device scaling of the sharded batched judges
(DESIGN.md Sec. 7).

Times ``judge_batch`` on one device against ``judge_batch_sharded`` on
an 8-virtual-CPU-device lane mesh for N in {256, 1024} x K in {8, 64}.
On virtual devices (one physical CPU carved up by
``--xla_force_host_platform_device_count``) NO speedup is expected —
the lanes time-share the same cores and pay the all-gather/psum of the
lockstep continue flag on top; the table is the artifact: it records
the collective overhead that real multi-chip lanes must amortize, and
it regresses loudly if the sharded driver's step count or overhead
blows up.

Because the device count must be fixed BEFORE jax initializes, each
timing runs in a subprocess of this file (``--worker``) with its own
``XLA_FLAGS``; the parent assembles the table
(``BENCH_sharded_judges.json`` at the repo root via run.py).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

SIZES = [(256, 8), (256, 64), (1024, 8), (1024, 64)]


def _worker_main(mode: str, sizes) -> None:
    """Runs inside a subprocess whose XLA_FLAGS are already set."""
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    import jax

    jax.config.update("jax_enable_x64", True)
    ndev = len(jax.devices())

    import jax.numpy as jnp
    import numpy as np

    from repro.core import BIFSolver, Dense, gershgorin_bounds

    def problem(n, k, seed=0, bandwidth=128):
        # block-banded diagonally dominant SPD: the certified Gershgorin
        # interval is tight (same generator as batched_judges)
        rng = np.random.default_rng(seed)
        m = rng.standard_normal((n, n))
        band = np.abs(np.arange(n)[:, None]
                      - np.arange(n)[None, :]) < bandwidth
        a = (m + m.T) / 2 * band
        a[np.diag_indices(n)] = np.abs(a).sum(axis=1) + 0.1
        us = rng.standard_normal((k, n))
        true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)
        ts = true * np.where(rng.random(k) < 0.5, 0.97, 1.03)
        return a, jnp.asarray(us), jnp.asarray(ts)

    def time_fn(fn, repeats=3, warmup=1):
        import time
        for _ in range(warmup):
            jax.block_until_ready(fn())
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]

    solver = BIFSolver.create(max_iters=64, rtol=1e-3)
    if mode == "sharded":
        from repro.launch.mesh import make_lane_mesh
        mesh = make_lane_mesh()

    out = {"devices": ndev, "mode": mode, "results": {}}
    for n, k in sizes:
        a, us, ts = problem(n, k)
        op = Dense(jnp.asarray(a))
        est = gershgorin_bounds(op)
        lmn, lmx = float(est.lam_min), float(est.lam_max)
        if mode == "sharded":
            fn = jax.jit(lambda us_, ts_, op=op: solver.judge_batch_sharded(
                op, us_, ts_, mesh=mesh, lam_min=lmn, lam_max=lmx))
        else:
            fn = jax.jit(lambda us_, ts_, op=op: solver.judge_batch(
                op, us_, ts_, lam_min=lmn, lam_max=lmx))
        res = jax.block_until_ready(fn(us, ts))
        out["results"][f"dense_n{n}_k{k}"] = {
            "wall_s": round(time_fn(lambda: fn(us, ts)), 5),
            "iters_max": int(np.asarray(res.iterations).max()),
            "decisions_true": int(np.asarray(res.decision).sum()),
        }
    print("JSON:" + json.dumps(out))


def _spawn(mode: str, devices: int, sizes):
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    proc = subprocess.run(
        [sys.executable, str(Path(__file__).resolve()), "--worker", mode,
         json.dumps(sizes)],
        capture_output=True, text=True, timeout=1200, env=env)
    for line in proc.stdout.splitlines():
        if line.startswith("JSON:"):
            return json.loads(line[5:])
    raise RuntimeError(
        f"sharded_judges worker ({mode}, {devices} devices) failed:\n"
        f"{proc.stdout[-500:]}\n{proc.stderr[-2000:]}")


def run(quick: bool = True):
    # the acceptance grid N in {256,1024} x K in {8,64} runs in BOTH
    # modes; --full adds nothing (the grid IS the artifact)
    sizes = SIZES
    single = _spawn("single", 1, sizes)
    sharded = _spawn("sharded", 8, sizes)
    rows, tables = [], {}
    for key in single["results"]:
        s1, s8 = single["results"][key], sharded["results"][key]
        assert s1["decisions_true"] == s8["decisions_true"], \
            f"sharded decisions diverged on {key}"
        entry = {
            "wall_s_1dev": s1["wall_s"],
            "wall_s_8vdev": s8["wall_s"],
            # >1 means the virtual-device collectives cost that much on
            # one physical CPU; real multi-chip lanes buy this back
            "vdev_overhead": round(s8["wall_s"] / max(s1["wall_s"], 1e-9),
                                   2),
            "iters_max_1dev": s1["iters_max"],
            "iters_max_8vdev": s8["iters_max"],
        }
        tables[key] = entry
        rows.append({"name": f"sharded_judges_{key}",
                     "us_per_call": round(s8["wall_s"] * 1e6, 2),
                     "derived": f"vdev_overhead_{entry['vdev_overhead']}x"})
    return rows, tables


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--worker":
        _worker_main(sys.argv[2], json.loads(sys.argv[3]))
    else:
        rows, tables = run()
        print(json.dumps(tables, indent=1))
