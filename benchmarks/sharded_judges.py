"""1-device vs 8-virtual-device scaling of the sharded batched judges,
swept over the decision-round cadence (DESIGN.md Sec. 7 and 11).

Times ``judge_batch`` on one device against ``judge_batch_sharded`` on
an 8-virtual-CPU-device lane mesh for N in {256, 1024} x K in {8, 64},
at ``decide_every`` R in {1, 4, 8}.

Virtual devices time-share the host's cores (the CI rig has ONE), so
the raw sharded/1-device ratio conflates two different taxes:

  * the *compute floor* — eight serialized lane programs are slower
    than one batched gemm on the same silicon no matter what the
    collectives cost. The benchmark MEASURES this floor instead of
    guessing: a third mode runs the identical lane-sharded drive with
    ZERO collectives (``shard_map`` of the single-device ``judge_batch``
    over the lane shards — valid because the threshold decide is
    per-lane, and asserted to reach identical decisions);
  * the *collective tax* — what the lockstep gather rounds add on top
    of that floor. This is the quantity the round cadence and the
    packed flag-folding gather actually optimize, and it is the
    headline ``vdev_overhead`` (labelled via ``vdev_overhead_baseline``;
    the raw cross-topology ratio stays in the table as
    ``vdev_overhead_vs_1dev`` next to the measured
    ``floor_overhead_vs_1dev`` rig physics).

Each sharded timing also pins the COMPILED collective census: the
worker lowers the jitted drive and counts collective instructions in
the HLO (``repro.utils.hlo.collective_counts``). A ``lax.while`` body
appears once in HLO, so the count reads as collectives-per-round plus
the loop-boundary gather — and it must show zero psum at every cadence.
Decisions are asserted identical across all three modes AND cadences.

Because the device count must be fixed BEFORE jax initializes, each
timing runs in a subprocess of this file (``--worker``) with its own
``XLA_FLAGS``; the parent assembles the table
(``BENCH_sharded_judges.json`` at the repo root via run.py).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

SIZES = [(256, 8), (256, 64), (1024, 8), (1024, 64)]
CADENCES = [1, 4, 8]


def _worker_main(mode: str, sizes, cadences) -> None:
    """Runs inside a subprocess whose XLA_FLAGS are already set."""
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    import jax

    jax.config.update("jax_enable_x64", True)
    ndev = len(jax.devices())

    import jax.numpy as jnp
    import numpy as np

    from repro.core import BIFSolver, Dense, gershgorin_bounds
    from repro.utils.hlo import collective_counts

    def problem(n, k, seed=0, bandwidth=128):
        # block-banded diagonally dominant SPD: the certified Gershgorin
        # interval is tight (same generator as batched_judges)
        rng = np.random.default_rng(seed)
        m = rng.standard_normal((n, n))
        band = np.abs(np.arange(n)[:, None]
                      - np.arange(n)[None, :]) < bandwidth
        a = (m + m.T) / 2 * band
        a[np.diag_indices(n)] = np.abs(a).sum(axis=1) + 0.1
        us = rng.standard_normal((k, n))
        true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)
        ts = true * np.where(rng.random(k) < 0.5, 0.97, 1.03)
        return a, jnp.asarray(us), jnp.asarray(ts)

    from repro.obs.metrics import Histogram

    def time_fn(fn, repeats=5, warmup=2):
        """Median + exact p50/p99 over the repeats, via the obs
        histogram helper (DESIGN.md Sec. 14)."""
        import time
        for _ in range(warmup):
            jax.block_until_ready(fn())
        hist = Histogram("wall_s")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            hist.observe(time.perf_counter() - t0)
        return (hist.percentile(50.0), hist.percentile(50.0),
                hist.percentile(99.0))

    if mode in ("sharded", "floor"):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import make_lane_mesh
        mesh = make_lane_mesh()

    out = {"devices": ndev, "mode": mode, "results": {}}
    for n, k in sizes:
        a, us, ts = problem(n, k)
        op = Dense(jnp.asarray(a))
        est = gershgorin_bounds(op)
        lmn, lmx = float(est.lam_min), float(est.lam_max)
        per_r = {}
        for r in cadences:
            solver = BIFSolver.create(max_iters=64, rtol=1e-3,
                                      decide_every=r)
            if mode == "sharded":
                fn = jax.jit(
                    lambda us_, ts_, op=op, solver=solver:
                    solver.judge_batch_sharded(op, us_, ts_, mesh=mesh,
                                               lam_min=lmn, lam_max=lmx))
            elif mode == "floor":
                # the collective-free control: the SAME lane shards run
                # the single-device drive independently (no gathers, no
                # lockstep). Valid because the threshold decide is
                # per-lane; decisions are asserted identical outside.
                fn = jax.jit(shard_map(
                    lambda us_, ts_, op=op, solver=solver:
                    solver.judge_batch(op, us_, ts_, lam_min=lmn,
                                       lam_max=lmx),
                    mesh=mesh, in_specs=(P("lanes"), P("lanes")),
                    out_specs=P("lanes"), check_rep=False))
            else:
                fn = jax.jit(
                    lambda us_, ts_, op=op, solver=solver:
                    solver.judge_batch(op, us_, ts_, lam_min=lmn,
                                       lam_max=lmx))
            res = jax.block_until_ready(fn(us, ts))
            wall, p50, p99 = time_fn(lambda: fn(us, ts))
            entry = {
                "wall_s": round(wall, 5),
                "wall_s_p50": round(p50, 5),
                "wall_s_p99": round(p99, 5),
                "iters_max": int(np.asarray(res.iterations).max()),
                "decisions_true": int(np.asarray(res.decision).sum()),
            }
            if mode == "sharded":
                # the compiled collective census: the while body appears
                # once in HLO, so this pins collectives-per-round (+ the
                # boundary gather) — and must show ZERO all-reduce/psum
                hlo = fn.lower(us, ts).compile().as_text()
                counts = collective_counts(hlo)
                entry["hlo_collectives"] = {
                    kk: vv for kk, vv in counts.items() if kk != "count"}
                entry["hlo_collective_count"] = counts["count"]
            per_r[f"R{r}"] = entry
        out["results"][f"dense_n{n}_k{k}"] = per_r
    print("JSON:" + json.dumps(out))


def _spawn(mode: str, devices: int, sizes, cadences):
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    proc = subprocess.run(
        [sys.executable, str(Path(__file__).resolve()), "--worker", mode,
         json.dumps(sizes), json.dumps(cadences)],
        capture_output=True, text=True, timeout=2400, env=env)
    for line in proc.stdout.splitlines():
        if line.startswith("JSON:"):
            return json.loads(line[5:])
    raise RuntimeError(
        f"sharded_judges worker ({mode}, {devices} devices) failed:\n"
        f"{proc.stdout[-500:]}\n{proc.stderr[-2000:]}")


def run(quick: bool = True):
    # the acceptance grid N in {256,1024} x K in {8,64} runs in all
    # modes at every cadence; --full adds nothing (the grid IS the
    # artifact)
    sizes = SIZES
    single = _spawn("single", 1, sizes, [1])
    floor = _spawn("floor", 8, sizes, CADENCES)
    sharded = _spawn("sharded", 8, sizes, CADENCES)
    rows, tables = [], {}
    for key in single["results"]:
        s1 = single["results"][key]["R1"]
        entry = {"wall_s_1dev": s1["wall_s"],
                 "iters_max_1dev": s1["iters_max"],
                 "cadence": {}}
        best = best_vs1 = None
        for r in CADENCES:
            s8 = sharded["results"][key][f"R{r}"]
            sf = floor["results"][key][f"R{r}"]
            # the decision set is cadence- and topology-invariant
            # (Thm. 4.2); a divergence here is a correctness bug, not
            # a perf regression
            assert s1["decisions_true"] == s8["decisions_true"], \
                f"sharded decisions diverged on {key} at R={r}"
            assert s1["decisions_true"] == sf["decisions_true"], \
                f"collective-free floor decisions diverged on {key} R={r}"
            assert not s8["hlo_collectives"].get("all-reduce"), \
                f"psum leaked back into the sharded drive on {key} R={r}"
            tax = round(s8["wall_s"] / max(sf["wall_s"], 1e-9), 2)
            vs1 = round(s8["wall_s"] / max(s1["wall_s"], 1e-9), 2)
            entry["cadence"][f"R{r}"] = {
                "wall_s_8vdev": s8["wall_s"],
                "wall_s_p50_8vdev": s8["wall_s_p50"],
                "wall_s_p99_8vdev": s8["wall_s_p99"],
                "wall_s_floor_8vdev": sf["wall_s"],
                "collective_tax": tax,
                "vdev_overhead_vs_1dev": vs1,
                "iters_max_8vdev": s8["iters_max"],
                "hlo_collectives": s8["hlo_collectives"],
            }
            rows.append({
                "name": f"sharded_judges_{key}_R{r}",
                "us_per_call": round(s8["wall_s"] * 1e6, 2),
                "derived": f"collective_tax_{tax}x;"
                           f"vs_1dev_{vs1}x;"
                           f"hlo_collectives_"
                           f"{s8['hlo_collective_count']}"})
            if best is None or tax < best[1]:
                best = (r, tax)
            if best_vs1 is None or vs1 < best_vs1[1]:
                best_vs1 = (r, vs1)
        # headline overhead = what the collectives ADD over the measured
        # collective-free floor at the tuned cadence (decide_every exists
        # precisely to amortize the per-round gather away); the raw
        # cross-topology ratio (at ITS best cadence) and the rig's
        # time-sharing floor (at R1, the natural compute-floor point —
        # coarser cadences inflate iterations) sit next to it so nothing
        # hides
        entry["vdev_overhead"] = best[1]
        entry["vdev_overhead_cadence"] = f"R{best[0]}"
        entry["vdev_overhead_baseline"] = \
            "collective-free lane-local drive on the same 8-vdev mesh"
        entry["vdev_overhead_vs_1dev"] = best_vs1[1]
        entry["vdev_overhead_vs_1dev_cadence"] = f"R{best_vs1[0]}"
        entry["floor_overhead_vs_1dev"] = round(
            floor["results"][key]["R1"]["wall_s"]
            / max(s1["wall_s"], 1e-9), 2)
        tables[key] = entry
    return rows, tables


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--worker":
        cadences = json.loads(sys.argv[4]) if len(sys.argv) > 4 else [1]
        _worker_main(sys.argv[2], json.loads(sys.argv[3]), cadences)
    else:
        rows, tables = run()
        print(json.dumps(tables, indent=1))
