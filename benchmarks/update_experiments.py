"""Regenerate the generated sections of EXPERIMENTS.md from artifacts.

    PYTHONPATH=src python -m benchmarks.update_experiments
"""
from __future__ import annotations

import json
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.launch import roofline as RL  # noqa: E402


def dryrun_summary() -> str:
    rows = ["| arch | shape | single-pod | multi-pod | per-dev GB (arg+temp, single) | compile s (single/multi) |",
            "|---|---|---|---|---|---|"]
    d = ROOT / "experiments/dryrun"
    singles = {p.name.replace("__single.json", ""): json.loads(p.read_text())
               for p in sorted(d.glob("*__single.json"))}
    multis = {p.name.replace("__multi.json", ""): json.loads(p.read_text())
              for p in sorted(d.glob("*__multi.json"))}
    for key in sorted(singles):
        s = singles[key]
        m = multis.get(key, {"status": "missing"})

        def stat(r):
            if r["status"] == "ok":
                return "✅ ok"
            if r["status"] == "skipped":
                return "— skip"
            return f"❌ {r['status']}"

        gb = "—"
        cmp_s = "—"
        if s["status"] == "ok":
            gb = f"{(s['memory']['argument_size_in_bytes'] + s['memory']['temp_size_in_bytes'])/1e9:.1f}"
            cmp_s = f"{s['compile_s']:.0f}/" + (
                f"{m['compile_s']:.0f}" if m.get("status") == "ok" else "—")
        rows.append(f"| {s['arch']} | {s['shape']} | {stat(s)} | {stat(m)} "
                    f"| {gb} | {cmp_s} |")
    return "\n".join(rows)


def roofline_table() -> str:
    recs = RL.load_records(ROOT / "experiments/dryrun", "single")
    return RL.fmt_table(recs)


def bench_csv() -> str:
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run"], capture_output=True,
        text=True, cwd=ROOT, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                  "HOME": "/root"})
    return "```\n" + out.stdout.strip() + "\n```"


def replace(text: str, marker: str, content: str) -> str:
    pat = re.compile(rf"<!-- {marker} -->.*?(?=\n## |\n---|\Z)", re.S)
    if pat.search(text):
        return pat.sub(f"<!-- {marker} -->\n\n{content}\n", text)
    return text.replace(f"<!-- {marker} -->",
                        f"<!-- {marker} -->\n\n{content}\n")


def main(run_bench: bool = False):
    p = ROOT / "EXPERIMENTS.md"
    text = p.read_text()
    text = replace(text, "DRYRUN_TABLE", dryrun_summary())
    text = replace(text, "ROOFLINE_TABLE", roofline_table())
    if run_bench:
        text = replace(text, "BENCH_CSV", bench_csv())
    p.write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main(run_bench="--bench" in sys.argv)
