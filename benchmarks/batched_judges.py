"""Per-candidate loop vs batched driver (DESIGN.md Sec. 6).

The hottest loop of every application (greedy MAP, k-DPP chains, double
greedy, BIF serving) judges K candidate bilinear forms against one
matrix. Pre-batching that was a Python loop of K single-lane retro-
spective solves; ``judge_batch`` runs the K lanes in lockstep under ONE
driver whose matvec covers the whole stack per iteration.

Reported per (operator, N, K) config:

  * wall time of the per-candidate loop vs one ``judge_batch`` call,
  * matvec counts — per-candidate: sum of per-lane iterations (one
    (N,)-vector matvec each); batched: K x driver steps (each driver
    step multiplies the full (K, N) stack, frozen lanes included).

The matrix is block-banded SPD (bandwidth 128) so the SparseBELL rows
hold ~3 dense 128x128 blocks — the regime where blocked-ELL profits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import row, time_fn
from repro.core import BIFSolver, Dense, bell_from_dense, gershgorin_bounds


def _problem(n: int, k: int, seed: int = 0, bandwidth: int = 128):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((n, n))
    band = np.abs(np.arange(n)[:, None] - np.arange(n)[None, :]) < bandwidth
    a = (m + m.T) / 2 * band
    # strict diagonal dominance: SPD with a certified Gershgorin interval
    a[np.diag_indices(n)] = np.abs(a).sum(axis=1) + 0.1
    us = rng.standard_normal((k, n))
    true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)
    ts = true * np.where(rng.random(k) < 0.5, 0.97, 1.03)
    return a, jnp.asarray(us), jnp.asarray(ts)


def _bench_one(op, us, ts, solver, lam_min, lam_max):
    k = us.shape[0]

    one = jax.jit(lambda u1, t1: solver.judge_threshold(
        op, u1, t1, lam_min=lam_min, lam_max=lam_max))

    def loop():
        return [one(us[i], ts[i]) for i in range(k)]

    # us/ts are runtime arguments on BOTH sides so XLA can't specialize
    # the batched call against constant operands
    batch_fn = jax.jit(lambda us_, ts_: solver.judge_batch(
        op, us_, ts_, lam_min=lam_min, lam_max=lam_max))

    def batch():
        return batch_fn(us, ts)

    res_loop = loop()
    res_batch = jax.block_until_ready(batch())
    iters_loop = np.array([int(r.iterations) for r in res_loop])
    iters_batch = np.asarray(res_batch.iterations)
    assert np.array_equal(
        np.array([bool(r.decision) for r in res_loop]),
        np.asarray(res_batch.decision)), "batched decisions diverged"

    t_loop = time_fn(loop, repeats=3, warmup=1)
    t_batch = time_fn(batch, repeats=3, warmup=1)
    return {
        "wall_s_per_candidate": round(t_loop, 5),
        "wall_s_batched": round(t_batch, 5),
        "speedup": round(t_loop / t_batch, 2),
        "matvecs_per_candidate": int(iters_loop.sum()),
        "matvecs_batched": int(k * iters_batch.max()),
        "iters_per_lane_max": int(iters_batch.max()),
    }


def run(quick: bool = True):
    sizes = [(256, 8), (256, 64), (1024, 8), (1024, 64)]
    if not quick:
        sizes += [(4096, 8), (4096, 64)]
    solver = BIFSolver.create(max_iters=64, rtol=1e-3)
    rows, tables = [], {}
    for n, k in sizes:
        a, us, ts = _problem(n, k)
        dense_op = Dense(jnp.asarray(a))
        est = gershgorin_bounds(dense_op)
        lam = (float(est.lam_min), float(est.lam_max))
        ops = {"dense": dense_op, "bell": bell_from_dense(a, bs=128)}
        for kind, op in ops.items():
            r = _bench_one(op, us, ts, solver, *lam)
            tables[f"{kind}_n{n}_k{k}"] = r
            rows.append(row(f"batched_judges_{kind}_n{n}_k{k}",
                            r["wall_s_batched"] * 1e6,
                            f"speedup_{r['speedup']}x"))
    return rows, tables
