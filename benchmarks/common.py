"""Shared benchmark utilities: timing of jitted callables."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, repeats: int = 5, warmup: int = 2, **kwargs):
    """Median wall time (seconds) of a jax callable, fully realized."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str) -> dict:
    return {"name": name, "us_per_call": round(us_per_call, 2),
            "derived": derived}
