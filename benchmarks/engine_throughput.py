"""BIFEngine serving throughput: lockstep flush vs continuous batching
(DESIGN.md Sec. 8).

The workload is the serving engine's worst case for lockstep flushes:
mixed judge/bracket traffic against one ill-conditioned kernel matrix.
Threshold judges with decisive margins (3-8x off the true value)
resolve in a quadrature iteration or two; adaptive brackets at
rtol=1e-8 on a kappa=100 spectrum grind for ~50. A lockstep chunk pays
the SLOWEST lane's iteration count for the whole padded chunk; the
continuous scheduler retires fast lanes and backfills them mid-flight,
so the pool's wall clock tracks the MEAN iteration count instead.

Reported per (N, pool) config:

  * steady-state requests/sec for both modes (+ the speedup),
  * p50/p95 iterations-to-decision over the served requests,
  * p50/p99 admission-to-retire request latency per mode, read off the
    engine's own ``request.latency_s`` histogram (repro.obs.metrics —
    exact nearest-rank percentiles, DESIGN.md Sec. 14),
  * total pool rounds the scheduler ran.

Tables land in ``BENCH_engine_throughput.json`` at the repo root via
``benchmarks/run.py``. ``BENCH_TINY=1`` shrinks everything to a smoke
size (the CI engine-scheduler smoke runs that).
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from .common import row, time_fn
from repro.core import BIFSolver, Dense
from repro.serve import BIFEngine, BIFRequest

_KAPPA = 100.0
_MAX_ITERS = 128
_RTOL = 1e-8
_CHUNK = 4


def _problem(n: int, seed: int = 0):
    """Geomspace-spectrum SPD (kappa=100): brackets at rtol=1e-8 need
    ~50 iterations while decisively-margined judges exit in one or two —
    the heavy-tailed iteration mix continuous batching exists for."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    evals = np.geomspace(1.0 / _KAPPA, 1.0, n)
    a = (q * evals) @ q.T
    return a, 1.0 / _KAPPA * 0.99, 1.01


def _traffic(a, q_count: int, seed: int = 1):
    """3/4 threshold judges (decisive margins), 1/4 adaptive brackets."""
    n = a.shape[0]
    rng = np.random.default_rng(seed)
    us = rng.standard_normal((q_count, n))
    true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)
    ts = []
    for i in range(q_count):
        if i % 4 == 0:
            ts.append(None)                       # bracket to rtol
        else:
            factor = rng.uniform(3.0, 8.0)
            sign = factor if i % 2 else 1.0 / factor
            ts.append(float(true[i] * sign))      # decisive judge
    return us, ts


def _serve(engine: BIFEngine, us, ts, mode: str):
    reqs = [engine.submit(BIFRequest(u=u, t=t)) for u, t in zip(us, ts)]
    out = engine.flush(mode=mode)
    assert len(out) == len(reqs)
    return out


def _bench_one(n: int, pool: int, q_count: int):
    a, lam_min, lam_max = _problem(n)
    us, ts = _traffic(a, q_count)
    op = Dense(jnp.asarray(a))
    solver = BIFSolver.create(max_iters=_MAX_ITERS, rtol=_RTOL)
    engines = {
        mode: BIFEngine(op, solver=solver, max_batch=pool,
                        lam_min=lam_min, lam_max=lam_max,
                        chunk_iters=_CHUNK)
        for mode in ("lockstep", "continuous")
    }

    # correctness guard: both modes must serve identical decisions
    out_l = _serve(engines["lockstep"], us, ts, "lockstep")
    out_c = _serve(engines["continuous"], us, ts, "continuous")
    assert [r.decision for r in out_l] == [r.decision for r in out_c], \
        "modes diverged on decisions"
    iters = np.array([r.iterations for r in out_c])

    walls = {}
    for mode, engine in engines.items():
        engine.reset_stats()  # drop the correctness-guard serve from stats
        walls[mode] = time_fn(lambda m=mode, e=engine: _serve(e, us, ts, m),
                              repeats=3, warmup=1)
    lat = {
        mode: engine.stats()["histograms"]["request.latency_s"]
        for mode, engine in engines.items()
    }
    return {
        "requests": q_count,
        "req_s_lockstep": round(q_count / walls["lockstep"], 2),
        "req_s_continuous": round(q_count / walls["continuous"], 2),
        "speedup": round(walls["lockstep"] / walls["continuous"], 2),
        "wall_s_lockstep": round(walls["lockstep"], 4),
        "wall_s_continuous": round(walls["continuous"], 4),
        "iters_p50": int(np.percentile(iters, 50)),
        "iters_p95": int(np.percentile(iters, 95)),
        "iters_mean": round(float(iters.mean()), 1),
        "iters_max": int(iters.max()),
        "lat_p50_ms_lockstep": round(lat["lockstep"]["p50"] * 1e3, 3),
        "lat_p99_ms_lockstep": round(lat["lockstep"]["p99"] * 1e3, 3),
        "lat_p50_ms_continuous": round(lat["continuous"]["p50"] * 1e3, 3),
        "lat_p99_ms_continuous": round(lat["continuous"]["p99"] * 1e3, 3),
    }


def run(quick: bool = True):
    if os.environ.get("BENCH_TINY"):
        sizes = [(64, 4)]
    else:
        sizes = [(256, 8), (256, 64), (1024, 8), (1024, 64)]
    rows, tables = [], {}
    for n, pool in sizes:
        q_count = max(4 * pool, 16)
        r = _bench_one(n, pool, q_count)
        tables[f"n{n}_pool{pool}"] = r
        rows.append(row(f"engine_throughput_n{n}_pool{pool}",
                        r["wall_s_continuous"] * 1e6 / q_count,
                        f"speedup_{r['speedup']}x_p95_{r['iters_p95']}it"))
    return rows, tables
