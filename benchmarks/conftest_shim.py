"""make_spd without importing pytest machinery (shared w/ tests)."""
import numpy as np


def make_spd(n: int, kappa: float = 100.0, seed: int = 0,
             density: float = 1.0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if density < 1.0:
        m = rng.standard_normal((n, n)) * (rng.random((n, n)) < density)
        a = (m + m.T) / 2
        w = np.linalg.eigvalsh(a)
        span = w[-1] - w[0]
        lam_min = max(span, 1e-3) / (kappa - 1)
        return a + np.eye(n) * (lam_min - w[0])
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    evals = np.geomspace(1.0 / kappa, 1.0, n)
    return (q * evals) @ q.T
