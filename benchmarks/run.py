"""Benchmark harness -- one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (plus a JSON dump of the
Fig. 1 bound traces under experiments/bench/).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

MODULES = [
    "bounds_convergence",     # Fig. 1 a/b/c
    "dpp_speedup",            # Fig. 2 + Table 2 DPP/kDPP rows
    "double_greedy_speedup",  # Table 2 DG rows
    "real_kernels",           # Table 1/2 real-data regimes (stand-ins)
    "quadrature_scaling",     # Thm. 3/5 rate check
    "kernel_report",          # Pallas kernel validation + accounting
    "batched_judges",         # per-candidate loop vs solve_batch (Sec. 6)
    "sharded_judges",         # 1-dev vs 8-virtual-device lanes (Sec. 7)
    "engine_throughput",      # lockstep vs continuous batching (Sec. 8)
    "trace_logdet",           # bracketed logdet vs dense slogdet (Sec. 9)
    "incremental_greedy",     # factor carry vs warm vs scratch (Sec. 12)
    "block_quadrature",       # block-Krylov vs scalar probes (Sec. 13)
]

# Suites whose tables are ALSO written to BENCH_<name>.json at the repo
# root, so the perf trajectory is tracked in-tree across PRs.
ROOT_TRACKED = {"batched_judges", "sharded_judges", "engine_throughput",
                "trace_logdet", "incremental_greedy", "block_quadrature"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    out_dir = Path("experiments/bench")
    out_dir.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    failures = 0
    import importlib
    for mod_name in MODULES:
        if args.only and args.only != mod_name:
            continue
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        try:
            rows, tables = mod.run(quick=not args.full)
        except Exception as e:  # noqa: BLE001
            print(f"{mod_name},,ERROR:{type(e).__name__}:{e}")
            failures += 1
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        if tables:
            (out_dir / f"{mod_name}.json").write_text(
                json.dumps(tables, indent=1))
            # BENCH_TINY smoke runs (the CI engine-scheduler smoke) must
            # not clobber the in-tree perf trajectory with toy sizes
            if mod_name in ROOT_TRACKED and not os.environ.get("BENCH_TINY"):
                repo_root = Path(__file__).resolve().parent.parent
                (repo_root / f"BENCH_{mod_name}.json").write_text(
                    json.dumps(tables, indent=1) + "\n")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
