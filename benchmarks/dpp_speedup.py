"""Paper Fig. 2 / Table 2 (DPP & k-DPP rows): retrospective-quadrature
chains vs exact-BIF chains across matrix density, synthetic data.

Both chains are jitted jax.lax.scan programs making IDENTICAL decisions;
the speedup comes purely from replacing dense solves with early-stopped
quadrature — the paper's claim, measured on this host."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dense, sample_dpp, sample_kdpp
from repro.data import random_sparse_spd

from .common import row, time_fn


def _measure(sampler, op, key, init, steps, lmn, lmx, n):
    f_q = jax.jit(lambda k: sampler(op, k, init, steps, lmn, lmx,
                                    max_iters=n + 2).mask)
    f_e = jax.jit(lambda k: sampler(op, k, init, steps, lmn, lmx,
                                    max_iters=n + 2, exact=True).mask)
    t_q = time_fn(f_q, key, repeats=3, warmup=1)
    t_e = time_fn(f_e, key, repeats=3, warmup=1)
    same = bool(jnp.all(f_q(key) == f_e(key)))
    return t_q, t_e, same


def run(quick: bool = True):
    n = 400 if quick else 2000
    steps = 60 if quick else 500
    rows = []
    for density in ([1e-2, 1e-1] if quick else [1e-3, 1e-2, 1e-1]):
        a = random_sparse_spd(n, density=density, lam_min=5e-2, seed=1)
        w = np.linalg.eigvalsh(a)
        lmn, lmx = float(w[0] * 0.9), float(w[-1] * 1.1)
        op = Dense(jnp.asarray(a, jnp.float64))
        key = jax.random.key(0)

        init = jnp.asarray((np.random.default_rng(0).random(n) < 1 / 3)
                           .astype(np.float64))
        t_q, t_e, same = _measure(sample_dpp, op, key, init, steps,
                                  lmn, lmx, n)
        rows.append(row(f"dpp_density_{density:g}",
                        t_q / steps * 1e6,
                        f"speedup={t_e / t_q:.2f}x;decisions_match={same}"))

        k = n // 8
        initk = np.zeros(n)
        initk[np.random.default_rng(1).choice(n, k, replace=False)] = 1
        t_q, t_e, same = _measure(sample_kdpp, op, key,
                                  jnp.asarray(initk), steps, lmn, lmx, n)
        rows.append(row(f"kdpp_density_{density:g}",
                        t_q / steps * 1e6,
                        f"speedup={t_e / t_q:.2f}x;decisions_match={same}"))
    return rows, {}
