"""Incremental greedy MAP (DESIGN.md Sec. 12): carried Cholesky factor
vs warm-started brackets vs from-scratch quadrature.

All three drivers select the SAME set (certified-identical argmax races;
asserted here and pinned in tests/test_update.py) — what changes is how
much quadrature each round pays. ``warm_start`` banks the previous
round's score upper bounds; ``incremental`` additionally reads exact
scores off the carried factor, seeding BOTH bracket sides so every lane
resolves at its first decide check: total iterations hit the N*T floor.
The iteration counts and wall times per T-round run land in
BENCH_incremental_greedy.json.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dense, greedy_map

from .common import row, time_fn

from .conftest_shim import make_spd


def _measure(op, t, lmn, lmx, n, **kw):
    f = jax.jit(lambda: greedy_map(op, t, lmn, lmx, max_iters=n + 2, **kw))
    secs = time_fn(f, repeats=3, warmup=1)
    res = f()
    return secs, np.asarray(res.order), int(res.quad_iterations), \
        int(res.uncertified)


def run(quick: bool = True):
    rows, tables = [], {}
    sizes = [(64, 16)] if quick else [(64, 16), (256, 32)]
    for n, t in sizes:
        for kappa in ([1e2, 1e4] if quick else [1e2, 1e4, 1e5]):
            a = make_spd(n, kappa=kappa, seed=5)
            w = np.linalg.eigvalsh(a)
            lmn, lmx = float(w[0] * 0.99), float(w[-1] * 1.01)
            op = Dense(jnp.asarray(a))
            s_c, o_c, it_c, u_c = _measure(op, t, lmn, lmx, n)
            s_w, o_w, it_w, u_w = _measure(op, t, lmn, lmx, n,
                                           warm_start=True)
            s_i, o_i, it_i, u_i = _measure(op, t, lmn, lmx, n,
                                           incremental=True)
            same = bool(np.array_equal(o_c, o_w)
                        and np.array_equal(o_c, o_i))
            name = f"greedy_n{n}_T{t}_kappa{kappa:g}"
            rows.append(row(
                name, s_i * 1e6,
                f"iters_scratch={it_c};iters_warm={it_w};iters_inc={it_i};"
                f"same_selection={same};speedup_vs_warm={s_w / s_i:.2f}x"))
            tables[name] = {
                "n": n, "T": t, "kappa": kappa,
                "us_scratch": round(s_c * 1e6, 2),
                "us_warm": round(s_w * 1e6, 2),
                "us_incremental": round(s_i * 1e6, 2),
                "iters_scratch": it_c, "iters_warm": it_w,
                "iters_incremental": it_i,
                "iters_floor_NT": n * t,
                "same_selection": same,
                "uncertified": u_c + u_w + u_i,
            }
            assert same, f"{name}: selections diverged"
            assert it_i < it_w <= it_c, f"{name}: no iteration savings"
    return rows, tables
