"""Paper Table 1/2 on 'real-data' kernels — offline stand-ins with the
same construction recipe (RBF with cutoff; graph Laplacians; +1e-3 I)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dense, sample_dpp
from repro.data import density, graph_laplacian, rbf_kernel

from .common import row, time_fn


def run(quick: bool = True):
    n = 300 if quick else 1500
    mats = {
        "abalone_like_rbf": rbf_kernel(n, sigma=0.15, seed=0),
        "wine_like_rbf": rbf_kernel(n, sigma=1.0, seed=1),
        "gr_like_laplacian": graph_laplacian(n, mean_degree=6, seed=2),
        "hep_like_laplacian": graph_laplacian(n, mean_degree=12, seed=3),
    }
    rows = []
    steps = 40 if quick else 300
    for name, a in mats.items():
        w = np.linalg.eigvalsh(a)
        lmn, lmx = float(max(w[0] * 0.9, 1e-4)), float(w[-1] * 1.1)
        op = Dense(jnp.asarray(a, jnp.float64))
        init = jnp.asarray((np.random.default_rng(0).random(n) < 1 / 3)
                           .astype(np.float64))
        key = jax.random.key(0)
        f_q = jax.jit(lambda k: sample_dpp(op, k, init, steps, lmn, lmx,
                                           max_iters=n + 2).mask)
        f_e = jax.jit(lambda k: sample_dpp(op, k, init, steps, lmn, lmx,
                                           max_iters=n + 2,
                                           exact=True).mask)
        t_q = time_fn(f_q, key, repeats=3, warmup=1)
        t_e = time_fn(f_e, key, repeats=3, warmup=1)
        same = bool(jnp.all(f_q(key) == f_e(key)))
        rows.append(row(f"dpp_{name}", t_q / steps * 1e6,
                        f"speedup={t_e/t_q:.2f}x;density={density(a):.4f};"
                        f"match={same}"))
    return rows, {}
