"""Paper Fig. 1 (a,b,c): evolution of the four Gauss-type bounds, with
exact / pessimistic-lambda_min / pessimistic-lambda_max intervals."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import BIFSolver, Dense
from repro.data import random_sparse_spd

from .common import row, time_fn


def run(quick: bool = True):
    n = 100
    a = random_sparse_spd(n, density=0.1, lam_min=1e-2, seed=0)
    w = np.linalg.eigvalsh(a)
    u = np.random.default_rng(0).standard_normal(n)
    true = float(u @ np.linalg.solve(a, u))
    op = Dense(jnp.asarray(a))
    uu = jnp.asarray(u)
    solver = BIFSolver.create(max_iters=n)

    settings = {
        "fig1a_exact_interval": (w[0] - 1e-5, w[-1] + 1e-5),
        "fig1b_loose_lammin": (0.1 * (w[0] - 1e-5), w[-1] + 1e-5),
        "fig1c_loose_lammax": (w[0] - 1e-5, 10 * (w[-1] + 1e-5)),
    }
    rows = []
    tables = {}
    for name, (lmn, lmx) in settings.items():
        tr = solver.trace(op, uu, num_iters=n, lam_min=float(lmn),
                          lam_max=float(lmx))
        g, grr, glr, glo = [np.asarray(x) for x in tr]
        gap = (glr - grr) / abs(true)
        it_1pct = int(np.argmax(gap < 1e-2)) + 1 if (gap < 1e-2).any() \
            else -1
        t = time_fn(lambda: solver.trace(op, uu, num_iters=25,
                                         lam_min=float(lmn),
                                         lam_max=float(lmx)),
                    repeats=3)
        rows.append(row(name, t * 1e6,
                        f"iters_to_1pct_gap={it_1pct};true={true:.4f}"))
        tables[name] = {"iters": list(range(1, n + 1)),
                        "gauss": g.tolist(), "radau_lower": grr.tolist(),
                        "radau_upper": glr.tolist(),
                        "lobatto": glo.tolist(), "true": true}
    return rows, tables
