"""Quadrature-bracketed logdet vs dense ``slogdet`` (DESIGN.md Sec. 9).

The workload: ``logdet(A) = tr log A`` for a banded SPD system (1-D
Laplacian + ridge — the analytic spectrum gives certified lam bounds
with no eigensolve), estimated by ``core.trace.logdet_quad`` with P
Hutchinson probes running as lanes of the batched matfun driver, against
``numpy.linalg.slogdet`` on the dense matrix.

Reported per (N, probes) config and operator (Dense / SparseCOO):

  * wall time for the bracketed estimate vs the dense factorization,
  * the deterministic bracket width (quadrature error, certified) and
    the statistical 95% interval width (sampling error),
  * the actual estimate error vs the slogdet truth,
  * mean quadrature iterations per probe.

Tables land in ``BENCH_trace_logdet.json`` at the repo root via
``benchmarks/run.py``; ``BENCH_TINY=1`` shrinks to a smoke size that
does NOT clobber the tracked json (the PR-4 convention).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import row
from repro.core import Dense, logdet_quad, sparse_from_dense

_RIDGE = 0.05
_MAX_ITERS = 64


def _problem(n: int):
    """Banded SPD: 1-D Laplacian + ridge. spec = ridge + 2 - 2cos(k pi /
    (n+1)), so the certified interval is analytic."""
    a = np.zeros((n, n))
    idx = np.arange(n)
    a[idx, idx] = 2.0 + _RIDGE
    a[idx[:-1], idx[:-1] + 1] = -1.0
    a[idx[:-1] + 1, idx[:-1]] = -1.0
    lam_min = _RIDGE + 2.0 - 2.0 * np.cos(np.pi / (n + 1))
    lam_max = _RIDGE + 2.0 - 2.0 * np.cos(n * np.pi / (n + 1))
    return a, float(lam_min * 0.999), float(lam_max * 1.001)


def _time(fn, repeats=3, warmup=1):
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _bench_one(n: int, probes: int):
    a, lam_min, lam_max = _problem(n)
    truth = float(np.linalg.slogdet(a)[1])
    key = jax.random.key(0)
    out = {}
    wall_dense = _time(lambda: np.linalg.slogdet(a))
    out["wall_s_slogdet"] = round(wall_dense, 5)
    for kind, op in [("dense", Dense(jnp.asarray(a))),
                     ("coo", sparse_from_dense(a))]:
        r = logdet_quad(op, probes, lam_min=lam_min, lam_max=lam_max,
                        max_iters=_MAX_ITERS, rtol=1e-6, atol=1e-6,
                        key=key)
        wall = _time(lambda: logdet_quad(
            op, probes, lam_min=lam_min, lam_max=lam_max,
            max_iters=_MAX_ITERS, rtol=1e-6, atol=1e-6, key=key))
        out[kind] = {
            "wall_s": round(wall, 5),
            "speedup_vs_slogdet": round(wall_dense / wall, 3),
            "det_bracket_width": float(r.upper - r.lower),
            "stat_interval_width": float(r.stat_upper - r.stat_lower),
            "abs_err": round(abs(r.estimate - truth), 4),
            "rel_err": round(abs(r.estimate - truth) / abs(truth), 5),
            "iters_per_probe": round(r.iterations / r.num_probes, 1),
            "stat_contains_truth": bool(r.stat_lower <= truth
                                        <= r.stat_upper),
        }
    out["logdet_truth"] = round(truth, 4)
    return out


def run(quick: bool = True):
    if os.environ.get("BENCH_TINY"):
        sizes = [(64, 4)]
    else:
        sizes = [(256, 8), (256, 32), (1024, 8), (1024, 32)]
    rows, tables = [], {}
    for n, probes in sizes:
        r = _bench_one(n, probes)
        tables[f"n{n}_p{probes}"] = r
        rows.append(row(
            f"trace_logdet_n{n}_p{probes}",
            r["coo"]["wall_s"] * 1e6,
            f"relerr_{r['coo']['rel_err']}_"
            f"{r['coo']['iters_per_probe']}it"))
    return rows, tables
