"""Paper Table 2 (DG rows): retrospective double greedy vs exact."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dense, run_double_greedy
from repro.data import random_sparse_spd

from .common import row, time_fn


def run(quick: bool = True):
    n = 200 if quick else 1000
    rows = []
    for density in ([1e-2, 1e-1] if quick else [1e-3, 1e-2, 1e-1]):
        a = random_sparse_spd(n, density=density, lam_min=5e-2, seed=2)
        d = np.sqrt(np.diag(a))
        a = a / np.outer(d, d) + 0.05 * np.eye(n)
        w = np.linalg.eigvalsh(a)
        lmn, lmx = float(w[0] * 0.9), float(w[-1] * 1.1)
        op = Dense(jnp.asarray(a, jnp.float64))
        key = jax.random.key(3)
        f_q = jax.jit(lambda k: run_double_greedy(
            op, k, lmn, lmx, max_iters=n + 2).selected)
        f_e = jax.jit(lambda k: run_double_greedy(
            op, k, lmn, lmx, max_iters=n + 2, exact=True).selected)
        t_q = time_fn(f_q, key, repeats=3, warmup=1)
        t_e = time_fn(f_e, key, repeats=3, warmup=1)
        same = bool(jnp.all(f_q(key) == f_e(key)))
        rows.append(row(f"double_greedy_density_{density:g}",
                        t_q / n * 1e6,
                        f"speedup={t_e / t_q:.2f}x;selections_match={same}"))
    return rows, {}
