"""End-to-end driver: train a language model for a few hundred steps with
DPP-selected batches, the GQL spectral monitor, and fault-tolerant
checkpointing — the paper's machinery running inside a real training
loop.

    PYTHONPATH=src python examples/train_lm_dpp.py \
        [--steps 200] [--scale 100m|small] [--selector dpp|uniform]

``--scale small`` (default) is a ~6M-param model that runs on this CPU
container in minutes; ``--scale 100m`` is the ~100M-param config for a
real machine.
"""
import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.data import (DataConfig, DPPBatchStream, DPPSelector,
                        TokenStream)
from repro.models import model as M
from repro.optim import AdamW, warmup_cosine
from repro.train import LoopConfig, make_monitor, train


def build_cfg(scale: str) -> ArchConfig:
    if scale == "100m":
        return ArchConfig(name="lm-100m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=12, d_ff=3072,
                          vocab=32000, dtype="float32",
                          tie_embeddings=True)
    return ArchConfig(name="lm-small", n_layers=4, d_model=256, n_heads=8,
                      n_kv_heads=8, d_ff=1024, vocab=4096,
                      dtype="float32", tie_embeddings=True,
                      logits_chunk=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--scale", default="small", choices=["small", "100m"])
    ap.add_argument("--selector", default="dpp",
                    choices=["dpp", "uniform"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = build_cfg(args.scale)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch, selector=args.selector)
    stream = TokenStream(dc)
    if args.selector == "dpp":
        stream = DPPBatchStream(stream, DPPSelector(pool_factor=3,
                                                    steps_per_item=2))

    opt = AdamW(lr=warmup_cosine(3e-4, 20, args.steps))

    def init_state():
        params, _ = M.init_model(jax.random.key(0), cfg)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"model: {cfg.name} ({n/1e6:.1f}M params)")
        return params, opt.init(params)

    def raw_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt_state, om = opt.update(grads, opt_state, params)
        return params, opt_state, dict(metrics, loss=loss, **om)

    step_fn = jax.jit(raw_step, donate_argnums=(0, 1))
    monitor = make_monitor(M.loss_fn, cfg, per_example=4, sketch_dim=32)

    t0 = time.time()
    res = train(
        loop_cfg=LoopConfig(total_steps=args.steps, save_every=50,
                            log_every=20, monitor_every=50),
        ckpt_dir=Path(args.ckpt) / cfg.name,
        init_state=init_state, step_fn=step_fn,
        batch_fn=stream.batch_at, monitor_fn=monitor)

    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({dt/args.steps*1e3:.0f} ms/step)")
    print(f"loss: {res.losses[0]:.3f} -> {np.mean(res.losses[-10:]):.3f}")
    if res.resumed_from:
        print(f"(resumed from step {res.resumed_from})")
    for step, m in res.monitor_log:
        print(f"  monitor@{step}: nat-grad-norm in "
              f"[{m['nat_norm_lower']:.3e}, {m['nat_norm_upper']:.3e}], "
              f"kappa(F) ~ [{m['kappa_lower']:.1f}, "
              f"{m['kappa_upper']:.1f}]")
    if args.selector == "dpp" and stream.selector.last_stats:
        print(f"  dpp selector last-step stats: "
              f"{stream.selector.last_stats}")


if __name__ == "__main__":
    main()
