"""Quickstart: certified brackets on a bilinear inverse form in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import BIFSolver, Dense, SolverConfig
from repro.data import random_sparse_spd

# The paper's Sec. 4.4 setup: 100x100, 10% dense, lambda_min = 1e-2.
N = 100
A = random_sparse_spd(N, density=0.1, lam_min=1e-2, seed=0)
w = np.linalg.eigvalsh(A)
u = np.random.default_rng(0).standard_normal(N)
true = u @ np.linalg.solve(A, u)

op = Dense(jnp.asarray(A))
uu = jnp.asarray(u)

# One solver object carries the whole policy: stopping rule, spectrum
# source, preconditioning, and kernel backend.
solver = BIFSolver(SolverConfig(max_iters=N, rtol=1e-3))

# Fig. 1: all four Gauss-type estimates, iteration by iteration.
tr = solver.trace(op, uu, num_iters=30, lam_min=w[0] * 0.999,
                  lam_max=w[-1] * 1.001)
print(f"true BIF = {true:.6f}\n")
print("iter   gauss(lo)    radau(lo)    radau(hi)    lobatto(hi)")
for i in [0, 1, 4, 9, 14, 19, 24, 29]:
    print(f"{i+1:4d} {float(tr.gauss[i]):12.4f} "
          f"{float(tr.radau_lower[i]):12.4f} "
          f"{float(tr.radau_upper[i]):12.4f} "
          f"{float(tr.lobatto[i]):12.4f}")

# Adaptive: stop as soon as the bracket is tight enough.
res = solver.solve(op, uu, lam_min=w[0] * 0.999, lam_max=w[-1] * 1.001)
print(f"\nadaptive: [{float(res.lower):.5f}, {float(res.upper):.5f}] "
      f"in {int(res.iterations)} iterations (N={N})")

# No eigendecomposition at hand? Let the solver estimate the interval.
auto = solver.replace(spectrum="lanczos").solve(op, uu)
print(f"auto-spectrum: [{float(auto.lower):.5f}, {float(auto.upper):.5f}] "
      f"in {int(auto.iterations)} iterations")

# Retrospective judge: decide `t < u^T A^-1 u` without the exact value.
for t in (true * 0.5, true * 2.0):
    j = solver.judge_threshold(op, uu, jnp.asarray(t),
                               lam_min=w[0] * 0.999, lam_max=w[-1] * 1.001)
    print(f"judge(t={t:9.3f} < BIF) -> {bool(j.decision)} "
          f"(certified={bool(j.certified)}, "
          f"iterations={int(j.iterations)})")
