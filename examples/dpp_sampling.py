"""DPP / k-DPP sampling with retrospective quadrature (paper Sec. 5.1).

Builds an RBF kernel over a point cloud, runs both chains with the
GQL-accelerated judge and with exact dense solves, and shows: identical
trajectories, far less work.

    PYTHONPATH=src python examples/dpp_sampling.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import BIFSolver, Dense, SolverConfig, sample_dpp, \
    sample_kdpp
from repro.data import density, rbf_kernel

N = 500
# hard-truncated RBF kernels can lose PSD-ness; the paper adds a ridge to
# "ensure positive definiteness" (Table 1) — size it to cover truncation
K = rbf_kernel(N, sigma=0.5, seed=0, ridge=0.05)
w = np.linalg.eigvalsh(K)
assert w[0] > 0, "kernel must be positive definite"
print(f"kernel: N={N}, density={density(K):.3f}, "
      f"kappa={w[-1]/w[0]:.1f}")

op = Dense(jnp.asarray(K))
lmn, lmx = float(w[0] * 0.9), float(w[-1] * 1.1)
init = jnp.asarray((np.random.default_rng(0).random(N) < 1 / 3)
                   .astype(np.float64))
key = jax.random.key(0)
steps = 300

# The chains thread one quadrature policy through every MH decision.
solver = BIFSolver(SolverConfig(max_iters=N + 2))

for name, fn in (("DPP", sample_dpp), ("k-DPP", sample_kdpp)):
    run_q = jax.jit(lambda k: fn(op, k, init, steps, lmn, lmx,
                                 max_iters=N + 2, solver=solver))
    run_e = jax.jit(lambda k: fn(op, k, init, steps, lmn, lmx,
                                 max_iters=N + 2, exact=True))
    st_q = run_q(key)
    jax.block_until_ready(st_q)
    t0 = time.perf_counter()
    st_q = run_q(key)
    jax.block_until_ready(st_q)
    t_q = time.perf_counter() - t0
    st_e = run_e(key)
    jax.block_until_ready(st_e)
    t0 = time.perf_counter()
    st_e = run_e(key)
    jax.block_until_ready(st_e)
    t_e = time.perf_counter() - t0
    same = bool(jnp.all(st_q.mask == st_e.mask))
    print(f"{name}: {steps} steps | quadrature {t_q:.2f}s vs exact "
          f"{t_e:.2f}s -> {t_e/t_q:.1f}x speedup | identical chains: "
          f"{same} | avg GQL iters/step: "
          f"{int(st_q.stats.quad_iterations)/steps:.1f} (N={N}) | "
          f"uncertified: {int(st_q.stats.uncertified)}")
