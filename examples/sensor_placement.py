"""Sensor placement by log-det maximization (paper Sec. 2 'Submodular
optimization, Sensing' + Sec. 5.2): retrospective double greedy on a
Gaussian-process covariance over a spatial grid.

    PYTHONPATH=src python examples/sensor_placement.py
"""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import Dense, run_double_greedy

# GP covariance on a 2-D grid of candidate sensor sites
G = 18
xs, ys = np.meshgrid(np.linspace(0, 1, G), np.linspace(0, 1, G))
pts = np.stack([xs.ravel(), ys.ravel()], 1)
N = len(pts)
d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
# Joint-entropy objective H(X_S) = log det(K_S) + const*|S| (Sec. 2):
# the per-sensor noise floor enters as the kernel scale, so each
# informative (non-redundant) site contributes ~log(scale) > 0.
K = 1.5 * (np.exp(-d2 / (2 * 0.08 ** 2)) + 1e-2 * np.eye(N))
w = np.linalg.eigvalsh(K)

op = Dense(jnp.asarray(K))
res = run_double_greedy(op, jax.random.key(0), float(w[0] * 0.9),
                        float(w[-1] * 1.1), max_iters=N + 2)
sel = np.asarray(res.selected) > 0.5
print(f"candidates: {N} grid sites | selected: {sel.sum()} sensors")
print(f"joint entropy (log det): {float(res.log_det):.2f}")
print(f"quadrature iterations total: {int(res.quad_iterations)} "
      f"(avg {int(res.quad_iterations)/N:.1f}/site vs N={N} for exact)")
print(f"uncertified decisions: {int(res.uncertified)}")

rng = np.random.default_rng(0)
rand_vals = []
for _ in range(20):
    idx = rng.choice(N, int(sel.sum()), replace=False)
    rand_vals.append(np.linalg.slogdet(K[np.ix_(idx, idx)])[1])
print(f"random-placement log det (mean of 20): {np.mean(rand_vals):.2f} "
      f"(double greedy is +{float(res.log_det)-np.mean(rand_vals):.1f})")

# ASCII map of the placement
grid = sel.reshape(G, G)
print("\nplacement (#=sensor):")
for r in range(G):
    print("".join("#" if grid[r, c] else "." for c in range(G)))
