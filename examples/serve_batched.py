"""Batched serving with KV-cache diversification (paper tie-in #3).

Serves a small LM with batched requests, then demonstrates log-det KV
block selection for long-context budgets — every keep/evict decision
certified by Gauss-Radau brackets (Alg. 8/9).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import model as M
from repro.serve import Engine, Request, select_diverse_blocks

cfg = ArchConfig(name="serve-demo", n_layers=4, d_model=256, n_heads=8,
                 n_kv_heads=2, d_ff=1024, vocab=4096, dtype="float32",
                 tie_embeddings=True, logits_chunk=128)
params, _ = M.init_model(jax.random.key(0), cfg)
n = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {n/1e6:.1f}M params | GQA {cfg.n_heads}q/{cfg.n_kv_heads}kv")

eng = Engine(cfg, params, max_batch=4, max_seq=256)
rng = np.random.default_rng(0)
reqs = [Request(prompt=rng.integers(1, 4000, size=plen).astype(np.int32),
                max_new_tokens=24)
        for plen in (12, 31, 7, 20)]
t0 = time.time()
out = eng.generate(reqs)
dt = time.time() - t0
ntok = sum(r.max_new_tokens for r in out)
print(f"served batch of {len(reqs)} ({ntok} new tokens) in {dt:.2f}s "
      f"incl. compile")
for i, r in enumerate(out):
    print(f"  req{i} (prompt {len(r.prompt):2d} toks) -> "
          f"{r.out_tokens[:10].tolist()}...")

# --- KV diversification under a budget -------------------------------
print("\nKV diversification (certified log-det selection):")
keys = rng.standard_normal((2048, 64)).astype(np.float32)
# inject redundancy: second half repeats the first half (e.g. looping ctx)
keys[1024:] = keys[:1024] + 0.01 * rng.standard_normal((1024, 64))
mask, stats = select_diverse_blocks(keys, block=128)
print(f"  {stats['blocks']} key blocks -> kept {stats['kept']} "
      f"(log det {stats['log_det']:.3f})")
print(f"  quadrature iterations: {stats['quad_iterations']} "
      f"(exact would need ~{stats['blocks']}^2/2 solve dims/decision); "
      f"uncertified: {stats['uncertified']}")
kept_first = mask[:len(mask) // 2].sum()
kept_second = mask[len(mask) // 2:].sum()
print(f"  redundant second half kept: {kept_second}/{len(mask)//2} vs "
      f"first half {kept_first}/{len(mask)//2}")
