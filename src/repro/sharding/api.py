"""Logical-axis sharding: rules, context, and constraint helpers.

``Plan`` maps logical axis names (used by model init/apply code) to mesh
axes of the production mesh (pod, data, model). Model code calls
``constrain(x, ("batch", "seq", "embed"))`` — a no-op unless a plan+mesh
context is active, so the same model runs unsharded on CPU tests and
fully sharded under the dry-run/launcher.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


@dataclasses.dataclass(frozen=True)
class Plan:
    """Logical axis -> mesh axis (or tuple of mesh axes, or None)."""
    rules: dict
    fsdp: bool = False            # additionally shard big dense dims on data
    fsdp_axis: str = "data"
    fsdp_min_size: int = 1024     # don't FSDP-shard tiny params

    def mesh_axes(self, logical: Optional[str]):
        return self.rules.get(logical) if logical else None


def tp_plan(*, data_axes=("pod", "data"), model_axis="model",
            fsdp: bool = False, seq_shard: bool = False,
            embed_shard: bool = False, tp_full: bool = False) -> Plan:
    """The production plan: TP over `model`, DP over (pod, data),
    optional FSDP (zero-3) and sequence sharding.

    ``embed_shard`` (2-D TP experiment): activations' embed dim sharded
    over the data axis to match the FSDP weight layout (measured and
    REFUTED for batched decode — kept for the record, EXPERIMENTS §Perf).

    ``tp_full`` (serving): weights tensor-parallel over ALL mesh axes
    (fused head/mlp/vocab dims divide 256/512 cleanly for the assigned
    archs). Params are fully sharded with NO ZeRO gathers; matmuls psum
    small activations instead — the winning decode layout.
    """
    wide = tuple(data_axes) + (model_axis,)
    w_axis = wide if tp_full else model_axis
    rules = {
        "batch": None if (embed_shard or tp_full) else data_axes,
        "cache_batch": None if tp_full else data_axes,
        "seq": model_axis if seq_shard else None,
        "kv_seq": wide if tp_full else model_axis,   # KV cache seq dim
        "embed": "data" if embed_shard else None,
        "heads": w_axis,
        "kv_heads": w_axis,
        "mlp": w_axis,
        "vocab": w_axis,
        "expert": w_axis,
        "ssm_inner": w_axis,
        "layers": None,
        "lanes": None,   # quadrature lanes: replicated under the prod plan
    }
    return Plan(rules=rules, fsdp=fsdp and not tp_full)


def lane_plan(mesh_axis: str = "lanes") -> Plan:
    """Plan for the quadrature lane axis (DESIGN.md Sec. 7): stacked
    query vectors, masks, and thresholds carry a leading ``lanes``
    logical axis mapped onto the 1-D lane mesh of
    ``launch.mesh.make_lane_mesh``; everything else (the operator's
    shared leaves) is replicated."""
    return Plan(rules={"lanes": mesh_axis})


def lane_sharding(mesh: Mesh, *, ndim: int = 2,
                  plan: Optional[Plan] = None) -> NamedSharding:
    """NamedSharding for a lane-stacked (K, ...) array: leading dim on
    the lane axis, trailing dims replicated."""
    plan = lane_plan() if plan is None else plan
    entries = [plan.mesh_axes("lanes")] + [None] * (ndim - 1)
    return NamedSharding(mesh, P(*entries))


def spec_for_param(plan: Plan, axes: tuple, shape: tuple) -> P:
    """PartitionSpec for one parameter from its logical axes tuple.

    A mesh axis may appear at most once per spec: when two logical dims
    map to the same mesh axis (e.g. MoE 'expert' and 'mlp' both -> model)
    the FIRST (leftmost) keeps it and later ones are replicated.

    FSDP: additionally shard the largest still-unsharded dim over the
    fsdp axis when the parameter is large enough (ZeRO-3 style).
    """
    entries = [plan.mesh_axes(a) for a in axes]
    used: set = set()
    for i, e in enumerate(entries):
        names = e if isinstance(e, (tuple, list)) else (e,) if e else ()
        if any(n in used for n in names):
            entries[i] = None
        else:
            used.update(names)
    if plan.fsdp and plan.fsdp_axis not in used:
        size = 1
        for s in shape:
            size *= s
        if size >= plan.fsdp_min_size:
            cand = [i for i, e in enumerate(entries) if e is None]
            if cand:
                big = max(cand, key=lambda i: shape[i])
                entries[big] = plan.fsdp_axis
    return P(*entries)


def param_shardings(plan: Plan, mesh: Mesh, params: Any, axes: Any):
    """NamedSharding tree for a (params, axes) pair, with divisibility
    fallback: a dim that doesn't divide by its mesh axes is replicated."""
    def one(p, ax):
        spec = spec_for_param(plan, ax, p.shape)
        spec = _fix_divisibility(spec, p.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, params, axes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= mesh.shape[e]
        return n
    return mesh.shape[entry]


def _fix_divisibility(spec: P, shape: tuple, mesh: Mesh) -> P:
    fixed = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            fixed.append(None)
        else:
            fixed.append(entry)
    return P(*fixed)


# ---------------------------------------------------------------------------
# Activation constraints via context


@contextlib.contextmanager
def activation_context(mesh: Mesh, plan: Plan):
    prev = getattr(_ctx, "val", None)
    _ctx.val = (mesh, plan)
    try:
        yield
    finally:
        _ctx.val = prev


def constrain(x: jax.Array, logical_axes: tuple) -> jax.Array:
    ctx = getattr(_ctx, "val", None)
    if ctx is None:
        return x
    mesh, plan = ctx
    entries = [plan.mesh_axes(a) for a in logical_axes]
    spec = _fix_divisibility(P(*entries), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
