from .api import (Plan, activation_context, constrain,  # noqa: F401
                  param_shardings, spec_for_param, tp_plan)
