from .api import (Plan, activation_context, constrain,  # noqa: F401
                  lane_plan, lane_sharding, param_shardings,
                  spec_for_param, tp_plan)
