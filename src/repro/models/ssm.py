"""Mamba-1 / Mamba-2 state-space blocks.

Train/prefill uses an associative scan over time (log-depth on TPU);
decode is the O(1) single-step recurrence on carried state — this is what
makes the long_500k cells sub-quadratic (DESIGN.md Sec. 5).

Mamba-1 (falcon-mamba): per-channel diagonal A (d_inner, n_state), input-
dependent B/C/dt (selective scan).
Mamba-2 (zamba2): multi-head SSD simplification — scalar a_t per head,
rank-1 (B_t x_t^T) state update, shared across head_dim.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import common as cm

Array = jax.Array


class SSMCache(NamedTuple):
    conv: Array    # (B, K-1, d_inner) last conv inputs
    state: Array   # mamba1: (B, d_inner, n) | mamba2: (B, H, dh, n)


def ssm_init(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    ks = jax.random.split(key, 8)
    p_in, a_in = cm.dense_init(ks[0], d, 2 * di, "embed", "ssm_inner",
                               bias=False, dtype=dtype)
    p_out, a_out = cm.dense_init(ks[1], di, d, "ssm_inner", "embed",
                                 bias=False, dtype=dtype)
    conv_w = cm.trunc_normal(ks[2], (cfg.ssm_conv, di), 1.0, dtype)
    p = {"in_proj": p_in, "out_proj": p_out, "conv_w": conv_w,
         "conv_b": jnp.zeros((di,), dtype)}
    a = {"in_proj": a_in, "out_proj": a_out,
         "conv_w": (None, "ssm_inner"), "conv_b": ("ssm_inner",)}

    if cfg.ssm_variant == "mamba1":
        # A_log: (di, n); x-dependent B, C, dt
        p["a_log"] = jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)).copy())
        a["a_log"] = ("ssm_inner", None)
        p_bc, a_bc = cm.dense_init(ks[3], di, 2 * n + 1, "ssm_inner", None,
                                   bias=False, dtype=dtype)
        p["bcdt_proj"], a["bcdt_proj"] = p_bc, a_bc
        p_dt, a_dt = cm.dense_init(ks[4], 1, di, None, "ssm_inner",
                                   bias=True, dtype=dtype)
        p["dt_proj"], a["dt_proj"] = p_dt, a_dt
        p["d_skip"] = jnp.ones((di,), jnp.float32)
        a["d_skip"] = ("ssm_inner",)
    else:  # mamba2
        h = cfg.ssm_heads
        p["a_log"] = jnp.zeros((h,), jnp.float32)
        a["a_log"] = (None,)
        p_bc, a_bc = cm.dense_init(ks[3], di, 2 * n + h, "ssm_inner", None,
                                   bias=False, dtype=dtype)
        p["bcdt_proj"], a["bcdt_proj"] = p_bc, a_bc
        p["d_skip"] = jnp.ones((h,), jnp.float32)
        a["d_skip"] = (None,)
        p["norm_scale"] = jnp.ones((di,), dtype)
        a["norm_scale"] = ("ssm_inner",)
    return p, a


def _causal_conv(x: Array, w: Array, b: Array,
                 carry: Optional[Array] = None):
    """x: (B, T, di); w: (K, di) depthwise causal conv.
    Returns (y, new_carry) with carry = last K-1 inputs."""
    k = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    # depthwise: y[t] = sum_j w[j] * xp[t+j]
    y = sum(xp[:, j:j + x.shape[1], :] * w[j] for j in range(k))
    new_carry = xp[:, -(k - 1):, :] if k > 1 else carry
    return y + b, new_carry


def _scan_linear(a: Array, b: Array, h0: Optional[Array] = None):
    """h_t = a_t * h_{t-1} + b_t via associative scan over axis 1 (time).

    a, b: (B, T, ...). Returns h (B, T, ...)."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def op(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h


def mamba1_core(cfg, p, xz: Array, cache: Optional[SSMCache], mode: str):
    di = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    x, z = jnp.split(xz, 2, axis=-1)
    conv_carry = cache.conv if cache is not None else None
    xc, new_conv = _causal_conv(x, p["conv_w"], p["conv_b"], conv_carry)
    xc = jax.nn.silu(xc)

    bcdt = cm.dense_apply(p["bcdt_proj"], xc)      # (B,T,2n+1)
    bmat = bcdt[..., :n].astype(jnp.float32)       # (B,T,n)
    cmat = bcdt[..., n:2 * n].astype(jnp.float32)
    dt_in = bcdt[..., 2 * n:]                      # (B,T,1)
    dt = jax.nn.softplus(cm.dense_apply(p["dt_proj"], dt_in)
                         .astype(jnp.float32))     # (B,T,di)
    a = -jnp.exp(p["a_log"])                       # (di,n)
    xf = xc.astype(jnp.float32)

    # discretization: abar = exp(dt A), bbar x = dt * B * x
    abar = jnp.exp(dt[..., None] * a)                       # (B,T,di,n)
    bx = dt[..., None] * bmat[..., None, :] * xf[..., None]  # (B,T,di,n)

    if mode == "decode":
        h = abar[:, 0] * cache.state + bx[:, 0]             # (B,di,n)
        y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None]
        new_state = h
    else:
        h0 = cache.state if cache is not None else None
        h = _scan_linear(abar, bx, h0)                      # (B,T,di,n)
        y = jnp.einsum("btdn,btn->btd", h, cmat)
        new_state = h[:, -1]

    y = y + p["d_skip"] * xf
    y = y.astype(xz.dtype) * jax.nn.silu(z)
    return y, SSMCache(conv=new_conv, state=new_state)


def _ssd_chunked(abar, dtx, bmat, cmat, h0, chunk: int, unroll: bool):
    """Mamba-2 SSD in matmul form (beyond-paper memory optimization).

    Instead of materializing the (B, T, H, dh, n) state sequence, split T
    into chunks of Q and compute per chunk

        y_t = decay(t) * C_t . H_in                (inter-chunk, carried)
            + sum_{s<=t} M_ts (B_s . C_t) dtx_s    (intra-chunk, matmul)

    with M_ts the causal decay mask — the (Q, Q, H) score tensor replaces
    the (Q, H, dh, n) state tensor: ~dh*n/Q times fewer bytes.

    abar: (B,T,H) decay; dtx: (B,T,H,dh); bmat/cmat: (B,T,n).
    Returns (y (B,T,H,dh), h_final (B,H,dh,n)).
    """
    b, t, h = abar.shape
    dh = dtx.shape[-1]
    n = bmat.shape[-1]
    q = min(chunk, t)
    while t % q:
        q //= 2
    nc = t // q

    la = jnp.log(jnp.maximum(abar, 1e-30)).reshape(b, nc, q, h)
    dtxc = dtx.reshape(b, nc, q, h, dh)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)
    cum = jnp.cumsum(la, axis=2)                       # (B,nc,Q,H)

    def body(hin, xs):
        la_c, cum_c, dtx_c, b_c, c_c = xs              # per-chunk slices
        # inter-chunk: y_t += decay(0..t) * C_t @ h_in
        decay_in = jnp.exp(cum_c)                      # (B,Q,H)
        y_inter = jnp.einsum("bqn,bhdn->bqhd", c_c, hin) \
            * decay_in[..., None]
        # intra-chunk: scores (B,H,Q,Q) with causal decay mask
        scores = jnp.einsum("bqn,bsn->bqs", c_c, b_c)  # (B,Q,Q)
        m = cum_c[:, :, None, :] - cum_c[:, None, :, :]  # (B,Q,S,H)
        causal = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])
        mask = jnp.where(causal[None, :, :, None], jnp.exp(m), 0.0)
        y_intra = jnp.einsum("bqs,bqsh,bshd->bqhd", scores, mask, dtx_c)
        # chunk-final state: h_out = decay(full) h_in + sum decay(s..Q) B_s dtx_s
        decay_out = jnp.exp(cum_c[:, -1:, :] - cum_c)  # (B,Q,H)
        hout = hin * jnp.exp(cum_c[:, -1])[:, :, None, None]
        hout = hout + jnp.einsum("bsh,bshd,bsn->bhdn", decay_out, dtx_c,
                                 b_c)
        return hout, y_inter + y_intra

    xs = (jnp.moveaxis(la, 1, 0), jnp.moveaxis(cum, 1, 0),
          jnp.moveaxis(dtxc, 1, 0), jnp.moveaxis(bc, 1, 0),
          jnp.moveaxis(cc, 1, 0))
    h_fin, ys = jax.lax.scan(body, h0, xs,
                             unroll=nc if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, dh)
    return y, h_fin


def mamba2_core(cfg, p, xz: Array, cache: Optional[SSMCache], mode: str):
    di = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    nh = cfg.ssm_heads
    dh = di // nh
    x, z = jnp.split(xz, 2, axis=-1)
    conv_carry = cache.conv if cache is not None else None
    xc, new_conv = _causal_conv(x, p["conv_w"], p["conv_b"], conv_carry)
    xc = jax.nn.silu(xc)

    bcdt = cm.dense_apply(p["bcdt_proj"], xc)
    bmat = bcdt[..., :n].astype(jnp.float32)             # (B,T,n)
    cmat = bcdt[..., n:2 * n].astype(jnp.float32)        # (B,T,n)
    dt = jax.nn.softplus(bcdt[..., 2 * n:].astype(jnp.float32))  # (B,T,H)
    a = -jnp.exp(p["a_log"])                             # (H,)
    xh = xc.astype(jnp.float32).reshape(*xc.shape[:2], nh, dh)  # (B,T,H,dh)

    abar = jnp.exp(dt * a)                               # (B,T,H)

    if mode == "decode":
        bx = dt[:, 0, :, None, None] * xh[:, 0, :, :, None] \
            * bmat[:, 0, None, None, :]                  # (B,H,dh,n)
        h = abar[:, 0, :, None, None] * cache.state + bx
        y = jnp.einsum("bhdn,bn->bhd", h, cmat[:, 0])[:, None]
        y = y.reshape(y.shape[0], 1, di)
        new_state = h
    elif cfg.ssm_impl == "chunked":
        h0 = cache.state if cache is not None else \
            jnp.zeros((xz.shape[0], nh, dh, n), jnp.float32)
        dtx = dt[..., None] * xh                         # (B,T,H,dh)
        y, new_state = _ssd_chunked(abar, dtx, bmat, cmat, h0,
                                    chunk=cfg.ssm_chunk,
                                    unroll=cfg.scan_unroll)
        y = y.reshape(*y.shape[:2], di)
    else:
        # reference: full associative scan over materialized states
        bx = dt[..., None, None] * xh[..., None] \
            * bmat[..., None, None, :]                   # (B,T,H,dh,n)
        h0 = cache.state if cache is not None else None
        h = _scan_linear(abar[..., None, None], bx, h0)  # (B,T,H,dh,n)
        y = jnp.einsum("bthdn,btn->bthd", h, cmat)
        y = y.reshape(*y.shape[:2], di)
        new_state = h[:, -1]

    y = y + (p["d_skip"][:, None] * xh).reshape(*xc.shape[:2], di)
    y = cm.norm_apply("rmsnorm", {"scale": p["norm_scale"]},
                      y.astype(xz.dtype))
    y = y * jax.nn.silu(z)
    return y, SSMCache(conv=new_conv, state=new_state)


def ssm_apply(cfg, p, x: Array, *, mode: str,
              cache: Optional[SSMCache] = None):
    """x: (B, T, d) -> (B, T, d). Returns (y, new_cache)."""
    xz = cm.dense_apply(p["in_proj"], x)
    core = mamba1_core if cfg.ssm_variant == "mamba1" else mamba2_core
    y, new_cache = core(cfg, p, xz, cache, mode)
    return cm.dense_apply(p["out_proj"], y), new_cache


def make_ssm_cache(cfg, batch: int, dtype=jnp.bfloat16) -> SSMCache:
    di = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype)
    if cfg.ssm_variant == "mamba1":
        state = jnp.zeros((batch, di, n), jnp.float32)
    else:
        nh = cfg.ssm_heads
        state = jnp.zeros((batch, nh, di // nh, n), jnp.float32)
    return SSMCache(conv=conv, state=state)
