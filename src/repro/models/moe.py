"""Mixture-of-Experts layer (GShard-style einsum dispatch).

Top-k routing with a capacity factor; dispatch/combine are one-hot
einsums so XLA SPMD lowers the expert contraction to all-to-all when
experts are sharded over the ``model`` axis and tokens over ``data``
(DESIGN.md Sec. 6 EP). Tokens are processed in fixed groups to bound the
(S, E, C) dispatch tensor.

Variants: shared expert (llama4-maverick) and parallel dense-residual MLP
(arctic) are handled in blocks.py; this module is the routed core.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as cm

Array = jax.Array


def moe_init(key, cfg, dtype):
    e = cfg.moe_experts
    ks = jax.random.split(key, 4)
    router, a_router = cm.dense_init(ks[0], cfg.d_model, e, "embed",
                                     "expert", bias=False, dtype=jnp.float32)
    # expert weights: stacked (E, d, ff) / (E, ff, d)
    mult = 3 if cfg.act == "swiglu" else 2
    wi = cm.trunc_normal(ks[1], (e, cfg.d_model, cfg.d_ff), 1.0, dtype)
    wo = cm.trunc_normal(ks[2], (e, cfg.d_ff, cfg.d_model), 1.0, dtype)
    p = {"router": router, "wi": wi, "wo": wo}
    a = {"router": a_router, "wi": ("expert", "embed", "mlp"),
         "wo": ("expert", "mlp", "embed")}
    if mult == 3:
        p["wg"] = cm.trunc_normal(ks[3], (e, cfg.d_model, cfg.d_ff), 1.0,
                                  dtype)
        a["wg"] = ("expert", "embed", "mlp")
    return p, a


def moe_apply(cfg, p, x: Array, *, group_size: int = 4096,
              dropless: bool = False):
    """x: (B, T, d) -> (B, T, d), plus aux load-balancing loss.

    ``dropless=True`` (decode): capacity = group size, so no token is ever
    dropped — a single decode token must not be subject to batch-
    composition-dependent drops.
    """
    b, t, d = x.shape
    e = cfg.moe_experts
    k = cfg.moe_top_k
    n_tok = b * t
    g = max(1, min(group_size, n_tok))
    while n_tok % g:
        g //= 2
    n_groups = n_tok // g
    if dropless:
        cap = g
    else:
        cap = max(1, int(g * k * cfg.moe_capacity_factor / e))

    xt = x.reshape(n_groups, g, d)
    logits = jnp.einsum("nsd,de->nse", xt.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)

    # iterative top-k with capacity assignment
    dispatch = jnp.zeros((n_groups, g, e, cap), x.dtype)
    combine = jnp.zeros((n_groups, g, e, cap), jnp.float32)
    remaining = probs
    # position counters per expert accumulate across the k rounds
    fill = jnp.zeros((n_groups, e), jnp.int32)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)                     # (n, g)
        gate = jnp.take_along_axis(remaining, idx[..., None],
                                   axis=-1)[..., 0]              # (n, g)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)         # (n, g, e)
        pos = jnp.cumsum(onehot, axis=1) - 1 + fill[:, None, :]  # (n, g, e)
        fill = fill + jnp.sum(onehot, axis=1)
        within = pos < cap
        pos_c = jnp.clip(pos, 0, cap - 1)
        sel = (onehot > 0) & within                              # (n, g, e)
        cap_oh = jax.nn.one_hot(pos_c, cap, dtype=jnp.float32)   # (n,g,e,cap)
        contrib = sel[..., None] * cap_oh
        dispatch = dispatch + contrib.astype(x.dtype)
        combine = combine + contrib * gate[..., None, None]
        remaining = remaining * (1.0 - onehot.astype(remaining.dtype))

    # dispatch tokens -> (E, n, cap, d); all-to-all under EP sharding
    xe = jnp.einsum("ngd,ngec->encd", xt, dispatch)
    h = jnp.einsum("encd,edf->encf", xe, p["wi"])
    if "wg" in p:
        h = jax.nn.silu(h) * jnp.einsum("encd,edf->encf", xe, p["wg"])
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("encf,efd->encd", h, p["wo"])
    y = jnp.einsum("encd,ngec->ngd", ye, combine.astype(ye.dtype))

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                 # mean router prob
    ce = jnp.mean(
        jax.nn.one_hot(jnp.argmax(probs, -1), e, dtype=jnp.float32),
        axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, t, d), aux
