"""Model assembly: decoder-only LM, MoE LM, SSM LM, hybrid, enc-dec, VLM.

One config-driven ``init_model`` + three pure entry points:

  loss_fn(cfg, params, batch)                -> (loss, metrics)      train
  prefill(cfg, params, batch)                -> (caches, logits)     serve
  decode_step(cfg, params, caches, tokens)   -> (caches, logits)     serve

Layer stacks are ``lax.scan`` over stacked parameter pytrees (compile
size O(1) in depth) with per-block ``jax.checkpoint`` when
``cfg.remat == "block"``. Heterogeneous patterns scan over *units*
(e.g. llama4: [dense, moe]; zamba2: 6 mamba + 1 shared-param attention
block). Activation sharding is annotated via ``sharding.api.constrain``.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..sharding.api import constrain
from . import attention as attn
from . import common as cm
from . import losses
from . import moe as moe_mod
from . import ssm as ssm_mod

Array = jax.Array


# ---------------------------------------------------------------------------
# Stacked init helper


def stack_init(key, n: int, init_fn):
    """vmap an init over n keys; prefix every axes tuple with 'layers'."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)  # structure only
    axes = jax.tree.map(
        lambda a: ("layers",) + a,
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return params, axes


def _is_axes(x):
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


# ---------------------------------------------------------------------------
# Blocks


def dense_block_init(key, cfg, dtype, kind: str = "decoder"):
    """kind: decoder | encoder | cross-decoder | moe | moe-dense."""
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["ln1"], a["ln1"] = cm.norm_init(cfg.norm, cfg.d_model, dtype)
    p["attn"], a["attn"] = attn.attn_init(ks[0], cfg, dtype)
    p["ln2"], a["ln2"] = cm.norm_init(cfg.norm, cfg.d_model, dtype)
    if kind == "cross-decoder":
        p["lnx"], a["lnx"] = cm.norm_init(cfg.norm, cfg.d_model, dtype)
        p["xattn"], a["xattn"] = attn.attn_init(ks[1], cfg, dtype)
    if kind == "moe":
        p["moe"], a["moe"] = moe_mod.moe_init(ks[2], cfg, dtype)
        if cfg.moe_shared_expert or cfg.moe_dense_residual:
            p["mlp"], a["mlp"] = cm.mlp_init(ks[3], cfg, cfg.d_ff, dtype)
    else:
        p["mlp"], a["mlp"] = cm.mlp_init(ks[3], cfg, cfg.d_ff, dtype)
    return p, a


def dense_block_apply(cfg, p, x, *, mode, positions, cache=None,
                      cross_kv=None, window=None):
    aux = jnp.zeros((), jnp.float32)
    h = cm.norm_apply(cfg.norm, p["ln1"], x)
    o, new_cache = attn.attn_apply(cfg, p["attn"], h, positions=positions,
                                   mode=mode, cache=cache, window=window)
    x = x + o
    x = constrain(x, ("batch", "seq", "embed"))
    if "xattn" in p:
        h = cm.norm_apply(cfg.norm, p["lnx"], x)
        o, _ = attn.attn_apply(cfg, p["xattn"], h, positions=positions,
                               mode="cross", cross_kv=cross_kv)
        x = x + o
    h = cm.norm_apply(cfg.norm, p["ln2"], x)
    if "moe" in p:
        o, aux = moe_mod.moe_apply(cfg, p["moe"], h,
                                   dropless=(mode == "decode"))
        if "mlp" in p:   # shared expert / dense residual path
            o = o + cm.mlp_apply(cfg, p["mlp"], h)
    else:
        o = cm.mlp_apply(cfg, p["mlp"], h)
    x = x + o
    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_cache, aux


def ssm_block_init(key, cfg, dtype):
    p, a = {}, {}
    p["ln"], a["ln"] = cm.norm_init(cfg.norm, cfg.d_model, dtype)
    p["ssm"], a["ssm"] = ssm_mod.ssm_init(key, cfg, dtype)
    return p, a


def ssm_block_apply(cfg, p, x, *, mode, cache=None):
    h = cm.norm_apply(cfg.norm, p["ln"], x)
    o, new_cache = ssm_mod.ssm_apply(cfg, p["ssm"], h, mode=mode,
                                     cache=cache)
    x = x + o
    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_cache


# ---------------------------------------------------------------------------
# Model init


def init_model(key, cfg):
    dtype = cm._dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p: dict = {}
    a: dict = {}
    p["embed"], a["embed"] = cm.embed_init(ks[0], cfg.vocab, cfg.d_model,
                                           dtype)
    p["final_norm"], a["final_norm"] = cm.norm_init(cfg.norm, cfg.d_model,
                                                    dtype)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        p["blocks"], a["blocks"] = stack_init(
            ks[1], cfg.n_layers,
            lambda k: dense_block_init(k, cfg, dtype))
    elif fam == "moe":
        per_unit = cfg.moe_every
        assert cfg.n_layers % per_unit == 0, (cfg.n_layers, per_unit)
        kinds = ["decoder"] * (per_unit - 1) + ["moe"]

        def unit_init(k):
            kk = jax.random.split(k, per_unit)
            ps, as_ = {}, {}
            for i, kind in enumerate(kinds):
                ps[f"sub{i}"], as_[f"sub{i}"] = dense_block_init(
                    kk[i], cfg, dtype, kind=kind)
            return ps, as_

        p["units"], a["units"] = stack_init(ks[1], cfg.n_layers // per_unit,
                                            unit_init)
    elif fam == "ssm":
        p["blocks"], a["blocks"] = stack_init(
            ks[1], cfg.n_layers, lambda k: ssm_block_init(k, cfg, dtype))
    elif fam == "hybrid":
        k_unit = cfg.hybrid_attn_every
        n_units = cfg.n_layers // k_unit
        tail = cfg.n_layers - n_units * k_unit

        def unit_init(k):
            return stack_init(k, k_unit,
                              lambda kk: ssm_block_init(kk, cfg, dtype))

        p["units"], a["units"] = stack_init(ks[1], n_units, unit_init)
        if tail:
            p["tail"], a["tail"] = stack_init(
                ks[2], tail, lambda k: ssm_block_init(k, cfg, dtype))
        # ONE parameter-shared attention block (zamba2)
        p["shared_attn"], a["shared_attn"] = dense_block_init(
            ks[3], cfg, dtype)
    elif fam == "encdec":
        p["enc_blocks"], a["enc_blocks"] = stack_init(
            ks[1], cfg.enc_layers,
            lambda k: dense_block_init(k, cfg, dtype, kind="encoder"))
        p["blocks"], a["blocks"] = stack_init(
            ks[2], cfg.n_layers,
            lambda k: dense_block_init(k, cfg, dtype, kind="cross-decoder"))
        p["enc_norm"], a["enc_norm"] = cm.norm_init(cfg.norm, cfg.d_model,
                                                    dtype)
    else:
        raise ValueError(fam)

    if not cfg.tie_embeddings:
        p["unembed"], a["unembed"] = cm.embed_init(ks[4], cfg.vocab,
                                                   cfg.d_model, dtype)
    return p, a


# ---------------------------------------------------------------------------
# Stacked application (scan over layers / units)


def _maybe_remat(cfg, fn):
    if cfg.remat == "block":
        return jax.checkpoint(fn)
    return fn


def _scan_stack(cfg, stack_params, x, apply_one, caches=None, length=None):
    """Scan a stacked block tree; caches (if given) are stacked alike."""

    def body(x, inp):
        p_i, c_i = inp
        x, new_c, aux = apply_one(p_i, x, c_i)
        return x, (new_c, aux)

    body = _maybe_remat(cfg, body)
    xs = (stack_params, caches) if caches is not None else \
        (stack_params, _none_like_stack(stack_params, length))
    n = jax.tree.leaves(stack_params)[0].shape[0]
    x, (new_caches, auxs) = jax.lax.scan(
        body, x, xs, unroll=n if cfg.scan_unroll else 1)
    return x, new_caches, jnp.sum(auxs)


def _none_like_stack(stack_params, length):
    leaf = jax.tree.leaves(stack_params)[0]
    n = leaf.shape[0]
    return jnp.zeros((n,), jnp.float32)   # dummy per-layer carry


def _sinusoid(t: int, d: int, offset=0) -> Array:
    pos = (jnp.arange(t) + offset)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((t, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d + 1) // 2]))
    return pe


def _embed_inputs(cfg, params, batch):
    """Token (+vision) embedding; returns (x, positions, label_mask)."""
    tokens = batch["tokens"]
    x = cm.embed_apply(params["embed"], tokens)
    mask = jnp.ones(tokens.shape, jnp.float32)
    if cfg.family == "vlm":
        vis = batch["vision_embeds"].astype(x.dtype)     # (B, Tv, d)
        x = jnp.concatenate([vis, x], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros(vis.shape[:2], jnp.float32), mask], axis=1)
        positions = batch["positions"]                   # (B, 3, T) M-RoPE
    else:
        t = x.shape[1]
        positions = jnp.arange(t)[None, :]
    if cfg.rope == "none":  # whisper: sinusoidal absolute positions
        x = x + _sinusoid(x.shape[1], x.shape[2]).astype(x.dtype)
    x = constrain(x, ("batch", "seq", "embed"))
    return x, positions, mask


def _apply_stacks(cfg, params, x, *, mode, positions, caches=None,
                  enc_memory=None):
    """Run the full block stack. Returns (x, new_caches, aux)."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    window = cfg.attn_window if mode != "train" else None

    if fam in ("dense", "vlm", "encdec"):
        cross = None

        def one(p_i, x, c_i):
            cache = c_i if caches is not None else None
            cross_kv = cross(p_i) if cross else None
            x, nc, aux = dense_block_apply(
                cfg, p_i, x, mode=mode, positions=positions,
                cache=cache, cross_kv=cross_kv, window=window)
            return x, (nc if caches is not None else jnp.zeros((), jnp.float32)), aux

        if fam == "encdec":
            def cross(p_i):
                hd = cfg.head_dim_
                k = attn._split_heads(
                    cm.dense_apply(p_i["xattn"]["wk"], enc_memory),
                    cfg.n_kv_heads)
                v = attn._split_heads(
                    cm.dense_apply(p_i["xattn"]["wv"], enc_memory),
                    cfg.n_kv_heads)
                return (k, v)
        else:
            cross = None
        x, new_caches, aux = _scan_stack(cfg, params["blocks"], x, one,
                                         caches)
        return x, new_caches, aux

    if fam == "moe":
        kinds = ["decoder"] * (cfg.moe_every - 1) + ["moe"]

        def one(p_u, x, c_u):
            aux = jnp.zeros((), jnp.float32)
            ncs = {}
            for i in range(len(kinds)):
                cache = c_u[f"sub{i}"] if caches is not None else None
                x, nc, a1 = dense_block_apply(
                    cfg, p_u[f"sub{i}"], x, mode=mode, positions=positions,
                    cache=cache, window=window)
                ncs[f"sub{i}"] = nc if caches is not None else \
                    jnp.zeros((), jnp.float32)
                aux = aux + a1
            return x, ncs, aux

        return _scan_stack(cfg, params["units"], x, one, caches)

    if fam == "ssm":
        def one(p_i, x, c_i):
            cache = c_i if caches is not None else None
            x, nc = ssm_block_apply(cfg, p_i, x, mode=mode, cache=cache)
            return x, (nc if caches is not None else
                       jnp.zeros((), jnp.float32)), jnp.zeros((), jnp.float32)

        x, new_caches, aux = _scan_stack(cfg, params["blocks"], x, one,
                                         caches)
        return x, new_caches, aux

    if fam == "hybrid":
        shared = params["shared_attn"]

        def unit_one(p_u, x, c_u):
            # k_unit mamba blocks then the shared attention block
            def inner(x, inp):
                p_i, c_i = inp
                cache = c_i if caches is not None else None
                x, nc = ssm_block_apply(cfg, p_i, x, mode=mode, cache=cache)
                return x, (nc if caches is not None else
                           jnp.zeros((), jnp.float32))

            ssm_caches = c_u["ssm"] if caches is not None else \
                _none_like_stack(p_u, None)
            k_unit = jax.tree.leaves(p_u["ssm_stack"])[0].shape[0]
            x, new_ssm = jax.lax.scan(
                inner, x, (p_u["ssm_stack"], ssm_caches),
                unroll=k_unit if cfg.scan_unroll else 1)
            attn_cache = c_u["attn"] if caches is not None else None
            x, new_attn, aux = dense_block_apply(
                cfg, shared, x, mode=mode, positions=positions,
                cache=attn_cache, window=window)
            ncs = {"ssm": new_ssm,
                   "attn": (new_attn if caches is not None else
                            jnp.zeros((), jnp.float32))}
            return x, ncs, aux

        # rewrap unit params so the inner scan sees a clean stacked tree
        units = {"ssm_stack": params["units"]}
        caches_u = caches["units"] if caches is not None else None

        def one(p_u, x, c_u):
            return unit_one(p_u, x, c_u)

        x, new_units, aux = _scan_stack(cfg, units_tree(params), x, one,
                                        caches_u)
        new_caches = {"units": new_units}
        if "tail" in params:
            def tail_one(p_i, x, c_i):
                cache = c_i if caches is not None else None
                x, nc = ssm_block_apply(cfg, p_i, x, mode=mode, cache=cache)
                return x, (nc if caches is not None else
                           jnp.zeros((), jnp.float32)), \
                    jnp.zeros((), jnp.float32)

            caches_t = caches["tail"] if caches is not None else None
            x, new_tail, a2 = _scan_stack(cfg, params["tail"], x, tail_one,
                                          caches_t)
            aux = aux + a2
            new_caches["tail"] = new_tail
        return x, new_caches, aux

    raise ValueError(fam)


def units_tree(params):
    return {"ssm_stack": params["units"]}


# ---------------------------------------------------------------------------
# Entry points


def _encode(cfg, params, frames):
    """Whisper encoder over stub frame embeddings (B, S, d)."""
    x = frames
    if cfg.rope == "none":
        x = x + _sinusoid(x.shape[1], x.shape[2]).astype(x.dtype)
    pos = jnp.arange(x.shape[1])[None, :]

    def one(p_i, x, c_i):
        x, _, aux = dense_block_apply(cfg, p_i, x, mode="encoder",
                                      positions=pos)
        return x, jnp.zeros((), jnp.float32), aux

    x, _, _ = _scan_stack(cfg, params["enc_blocks"], x, one, None)
    return cm.norm_apply(cfg.norm, params["enc_norm"], x)


def loss_fn(cfg, params, batch):
    """Training forward + chunked CE. batch keys per family:
    dense/moe/ssm/hybrid: tokens, labels
    vlm:    tokens, vision_embeds, positions, labels
    encdec: frames, tokens, labels
    """
    enc_memory = None
    if cfg.is_encdec:
        enc_memory = _encode(cfg, params, batch["frames"].astype(
            cm._dtype(cfg.dtype)))
    x, positions, mask = _embed_inputs(cfg, params, batch)
    x, _, aux = _apply_stacks(cfg, params, x, mode="train",
                              positions=positions, enc_memory=enc_memory)
    x = cm.norm_apply(cfg.norm, params["final_norm"], x)
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["unembed"]["table"]
    labels = batch["labels"]
    if cfg.family == "vlm":
        # labels cover only the text positions; prepend ignore labels
        tv = x.shape[1] - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.zeros((labels.shape[0], tv), labels.dtype), labels], axis=1)
    loss, metrics = losses.chunked_cross_entropy(
        x, table, labels, chunk=cfg.logits_chunk, mask=mask,
        unroll=cfg.scan_unroll)
    loss = loss + 1e-2 * aux
    metrics["aux"] = aux
    return loss, metrics


def make_caches(cfg, batch: int, s_max: int, dtype=jnp.bfloat16,
                quantized_kv: bool = False):
    """Decode caches matching the layer-stack structure."""
    hd = cfg.head_dim_

    def kv():
        return attn.make_cache(batch, s_max, cfg.n_kv_heads, hd, dtype,
                               quantized=quantized_kv)

    def stack(n, make_one):
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[make_one() for _ in range(n)])

    fam = cfg.family
    if fam in ("dense", "vlm", "encdec"):
        return stack(cfg.n_layers, kv)
    if fam == "moe":
        per_unit = cfg.moe_every
        unit = lambda: {f"sub{i}": kv() for i in range(per_unit)}
        return stack(cfg.n_layers // per_unit, unit)
    if fam == "ssm":
        return stack(cfg.n_layers,
                     lambda: ssm_mod.make_ssm_cache(cfg, batch, dtype))
    if fam == "hybrid":
        k_unit = cfg.hybrid_attn_every
        n_units = cfg.n_layers // k_unit
        tail = cfg.n_layers - n_units * k_unit
        unit = lambda: {
            "ssm": stack(k_unit,
                         lambda: ssm_mod.make_ssm_cache(cfg, batch, dtype)),
            "attn": kv()}
        out = {"units": stack(n_units, unit)}
        if tail:
            out["tail"] = stack(
                tail, lambda: ssm_mod.make_ssm_cache(cfg, batch, dtype))
        return out
    raise ValueError(fam)


def prefill(cfg, params, batch, caches):
    """Consume the prompt, fill caches, return logits of the last token."""
    enc_memory = None
    if cfg.is_encdec:
        enc_memory = _encode(cfg, params, batch["frames"].astype(
            cm._dtype(cfg.dtype)))
    x, positions, _ = _embed_inputs(cfg, params, batch)
    x, new_caches, _ = _apply_stacks(cfg, params, x, mode="prefill",
                                     positions=positions, caches=caches,
                                     enc_memory=enc_memory)
    x = cm.norm_apply(cfg.norm, params["final_norm"], x)
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["unembed"]["table"]
    logits = cm.unembed_logits({"table": table}, x[:, -1:, :])
    return new_caches, logits


def decode_step(cfg, params, caches, batch):
    """One token: batch['tokens'] (B, 1). Returns (caches, logits)."""
    enc_memory = batch.get("enc_memory") if cfg.is_encdec else None
    tokens = batch["tokens"]
    x = cm.embed_apply(params["embed"], tokens)
    pos = batch["position"]                   # (1,) or (B, 3, 1) for mrope
    if cfg.rope == "none":
        x = x + _sinusoid(1, x.shape[2], offset=pos.reshape(-1)[0]
                          ).astype(x.dtype)
    x = constrain(x, ("batch", "seq", "embed"))
    x, new_caches, _ = _apply_stacks(cfg, params, x, mode="decode",
                                     positions=pos, caches=caches,
                                     enc_memory=enc_memory)
    x = cm.norm_apply(cfg.norm, params["final_norm"], x)
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["unembed"]["table"]
    logits = cm.unembed_logits({"table": table}, x)
    return new_caches, logits
