"""Composable pure-JAX layer library with logical sharding axes.

Every ``*_init`` returns ``(params, axes)``: two pytrees of identical
structure, the second holding per-dimension *logical axis names* (or None)
that ``repro.sharding.rules`` later maps onto the physical mesh
(pod, data, model). This is the t5x/MaxText convention without the flax
dependency — params are plain nested dicts, apply functions are pure.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def trunc_normal(key, shape, scale, dtype):
    fan_in = shape[0] if len(shape) else 1
    std = scale / max(fan_in, 1) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# Dense projections


def dense_init(key, in_dim: int, out_dim: int, in_ax: Optional[str],
               out_ax: Optional[str], *, bias: bool, dtype,
               scale: float = 1.0):
    p = {"w": trunc_normal(key, (in_dim, out_dim), scale, dtype)}
    a = {"w": (in_ax, out_ax)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        a["b"] = (out_ax,)
    return p, a


def dense_apply(p, x: Array) -> Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms (paper-relevant detail: olmo uses NON-PARAMETRIC LayerNorm)


def norm_init(kind: str, dim: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}, {"scale": ("embed",)}
    if kind == "layernorm":
        return ({"scale": jnp.ones((dim,), dtype),
                 "bias": jnp.zeros((dim,), dtype)},
                {"scale": ("embed",), "bias": ("embed",)})
    if kind == "layernorm_np":   # non-parametric
        return {}, {}
    raise ValueError(kind)


def norm_apply(kind: str, p, x: Array) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True)
                               + 1e-6)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    if kind == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + multimodal M-RoPE)


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (D/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,T,1,D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float) -> Array:
    """Qwen2-VL M-RoPE: positions3 (..., 3, T) = (temporal, h, w) ids; the
    head dim is split into three bands, one rotated per id stream."""
    d = x.shape[-1]
    b1 = d // 2 // 2 * 2          # temporal band (half the dim, even)
    b2 = (d - b1) // 2 // 2 * 2   # height band
    b3 = d - b1 - b2              # width band
    parts = jnp.split(x, [b1, b1 + b2], axis=-1)
    out = []
    for band, pos in zip(parts, jnp.moveaxis(positions3, -2, 0)):
        out.append(apply_rope(band, pos, theta) if band.shape[-1] >= 2
                   else band)
    return jnp.concatenate(out, axis=-1)


# ---------------------------------------------------------------------------
# MLPs


def mlp_init(key, cfg, d_ff: int, dtype, ff_ax: str = "mlp"):
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        p_in, a_in = dense_init(ks[0], cfg.d_model, d_ff, "embed", ff_ax,
                                bias=cfg.use_bias, dtype=dtype)
        p_gate, a_gate = dense_init(ks[1], cfg.d_model, d_ff, "embed", ff_ax,
                                    bias=cfg.use_bias, dtype=dtype)
        p_out, a_out = dense_init(ks[2], d_ff, cfg.d_model, ff_ax, "embed",
                                  bias=cfg.use_bias, dtype=dtype)
        return ({"wi": p_in, "wg": p_gate, "wo": p_out},
                {"wi": a_in, "wg": a_gate, "wo": a_out})
    p_in, a_in = dense_init(ks[0], cfg.d_model, d_ff, "embed", ff_ax,
                            bias=cfg.use_bias, dtype=dtype)
    p_out, a_out = dense_init(ks[2], d_ff, cfg.d_model, ff_ax, "embed",
                              bias=cfg.use_bias, dtype=dtype)
    return {"wi": p_in, "wo": p_out}, {"wi": a_in, "wo": a_out}


def mlp_apply(cfg, p, x: Array) -> Array:
    if "wg" in p:
        h = jax.nn.silu(dense_apply(p["wi"], x)) * dense_apply(p["wg"], x)
    else:
        h = jax.nn.gelu(dense_apply(p["wi"], x))
    return dense_apply(p["wo"], h)


# ---------------------------------------------------------------------------
# Embedding / unembedding


def embed_init(key, vocab: int, dim: int, dtype):
    return ({"table": trunc_normal(key, (vocab, dim), 1.0, dtype)},
            {"table": ("vocab", "embed")})


def embed_apply(p, tokens: Array) -> Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed_logits(p_embed, x: Array) -> Array:
    """Tied unembedding (x @ table^T) in f32 for stable CE."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p_embed["table"].astype(jnp.float32))
