"""GQA attention: full, chunked (memory-efficient prefill), and decode.

``full``     materializes (T, S) scores — fine for train_4k scales.
``chunked``  scans query tiles with an online softmax (pure JAX flash
             pattern) — required for 32k prefill where full scores would
             be petabytes; per-step live memory is O(bq * S).
``decode``   single-query attention against a (possibly int8) KV cache.

Sliding-window masking (zamba2 long-context mode) is applied in all three.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import common as cm

Array = jax.Array
NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    hd = cfg.head_dim_
    ks = jax.random.split(key, 4)
    pq, aq = cm.dense_init(ks[0], cfg.d_model, cfg.n_heads * hd,
                           "embed", "heads", bias=cfg.use_bias, dtype=dtype)
    pk, ak = cm.dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd,
                           "embed", "kv_heads", bias=cfg.use_bias,
                           dtype=dtype)
    pv, av = cm.dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd,
                           "embed", "kv_heads", bias=cfg.use_bias,
                           dtype=dtype)
    po, ao = cm.dense_init(ks[3], cfg.n_heads * hd, cfg.d_model,
                           "heads", "embed", bias=cfg.use_bias, dtype=dtype)
    return ({"wq": pq, "wk": pk, "wv": pv, "wo": po},
            {"wq": aq, "wk": ak, "wv": av, "wo": ao})


def _split_heads(x: Array, n: int) -> Array:
    b, t, _ = x.shape
    return x.reshape(b, t, n, -1)


def _repeat_kv(k: Array, n_heads: int) -> Array:
    hkv = k.shape[2]
    if hkv == n_heads:
        return k
    return jnp.repeat(k, n_heads // hkv, axis=2)


def _mask(rows: Array, cols: Array, *, causal: bool,
          window: Optional[int], s_valid: Optional[int | Array]) -> Array:
    m = jnp.ones(jnp.broadcast_shapes(rows.shape, cols.shape), bool)
    if causal:
        m &= cols <= rows
    if window is not None:
        m &= cols > rows - window
    if s_valid is not None:
        m &= cols < s_valid
    return m


def _group_q(q, hkv):
    """(B, T, H, D) -> (B, T, Hkv, G, D): grouped-query layout that
    contracts directly against un-replicated KV (no _repeat_kv blowup)."""
    b, t, h, d = q.shape
    return q.reshape(b, t, hkv, h // hkv, d)


def _sdpa_full(q, k, v, *, causal, window, positions_q=None):
    """q: (B,T,H,D), k/v: (B,S,Hkv,D) -> (B,T,H,D)."""
    b, t, h, d = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    qg = _group_q(q, hkv)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    rows = jnp.arange(t)[:, None] if positions_q is None \
        else positions_q[..., :, None]
    cols = jnp.arange(s)[None, :]
    m = _mask(rows, cols, causal=causal, window=window, s_valid=None)
    scores = jnp.where(m, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v)
    return out.reshape(b, t, h, d)


def _sdpa_chunked(q, k, v, *, causal, window, chunk: int,
                  unroll: bool = False):
    """Scan over query tiles; O(bq*S) live scores; grouped GQA."""
    b, t, h, d = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    nq = t // chunk
    assert t % chunk == 0, (t, chunk)
    qb = _group_q(q, hkv).reshape(b, nq, chunk, hkv, h // hkv, d)

    cols = jnp.arange(s)[None, :]

    def body(_, qi_idx):
        qi, idx = qi_idx                  # qi: (b, chunk, hkv, g, d)
        rows = idx * chunk + jnp.arange(chunk)[:, None]
        scores = jnp.einsum("btkgd,bskd->bkgts", qi.astype(jnp.float32),
                            k.astype(jnp.float32)) / (d ** 0.5)
        m = _mask(rows, cols, causal=causal, window=window, s_valid=None)
        scores = jnp.where(m, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v)
        return None, out.reshape(b, chunk, h, d)

    _, outs = jax.lax.scan(body, None,
                           (jnp.moveaxis(qb, 1, 0), jnp.arange(nq)),
                           unroll=nq if unroll else 1)
    return jnp.moveaxis(outs, 0, 1).reshape(b, t, h, d)


class KVCache(NamedTuple):
    k: Array        # (B, S_max, Hkv, D) in cache dtype
    v: Array
    length: Array   # () int32 — tokens currently stored
    k_scale: Optional[Array] = None   # int8 quantization scales (B,S,Hkv,1)
    v_scale: Optional[Array] = None


def make_cache(batch: int, s_max: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16, quantized: bool = False) -> KVCache:
    shape = (batch, s_max, n_kv, head_dim)
    if quantized:
        return KVCache(k=jnp.zeros(shape, jnp.int8),
                       v=jnp.zeros(shape, jnp.int8),
                       length=jnp.zeros((), jnp.int32),
                       k_scale=jnp.zeros(shape[:-1] + (1,), jnp.float32),
                       v_scale=jnp.zeros(shape[:-1] + (1,), jnp.float32))
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((), jnp.int32))


def _quantize(x: Array):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) \
        / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def cache_update(cache: KVCache, k_new: Array, v_new: Array,
                 onehot: bool = False) -> KVCache:
    """Append k/v (B, T_new, Hkv, D) at position ``length``.

    ``onehot=True`` (single-token decode only): write via a one-hot mask
    instead of dynamic_update_slice. Elementwise selects stay in the
    cache's sequence-sharded layout, so XLA never reshards/gathers the
    cache around the update (the decode collective hillclimb fix —
    EXPERIMENTS.md §Perf).
    """
    from ..sharding.api import constrain as _c
    z = jnp.zeros((), cache.length.dtype)

    if onehot and k_new.shape[1] == 1:
        s = cache.k.shape[1]
        oh = (jnp.arange(s, dtype=cache.length.dtype)
              == cache.length)[None, :, None, None]

        def upd(buf, val):
            out = jnp.where(oh, val.astype(buf.dtype), buf)
            return _c(out, ("batch", "kv_seq", None, None))
    else:
        def upd(buf, val):
            return jax.lax.dynamic_update_slice(
                buf, val.astype(buf.dtype), (z, cache.length, z, z))

    if cache.k_scale is not None:
        kq, ks = _quantize(k_new)
        vq, vs = _quantize(v_new)
        return KVCache(k=upd(cache.k, kq), v=upd(cache.v, vq),
                       length=cache.length + k_new.shape[1],
                       k_scale=upd(cache.k_scale, ks),
                       v_scale=upd(cache.v_scale, vs))
    return KVCache(k=upd(cache.k, k_new.astype(cache.k.dtype)),
                   v=upd(cache.v, v_new.astype(cache.v.dtype)),
                   length=cache.length + k_new.shape[1])


def _cache_kv(cache: KVCache):
    if cache.k_scale is not None:
        k = cache.k.astype(jnp.float32) * cache.k_scale
        v = cache.v.astype(jnp.float32) * cache.v_scale
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    return cache.k, cache.v


def _sdpa_decode(q, cache: KVCache, *, window, constrain_kv=False):
    """q: (B, 1, H, D) against the cache; masks unwritten tail.

    ``constrain_kv``: pin the sequence-sharded KV layout through the
    score/PV einsums so XLA reduces softmax over the sharded axis instead
    of gathering the cache (collective-bound decode hillclimb knob)."""
    from ..sharding.api import constrain as _c
    b, t, h, d = q.shape
    k, v = _cache_kv(cache)
    s = k.shape[1]
    hkv = k.shape[2]
    if constrain_kv:
        k = _c(k, ("batch", "kv_seq", None, None))
        v = _c(v, ("batch", "kv_seq", None, None))
    qg = _group_q(q, hkv)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    if constrain_kv:
        scores = _c(scores, ("batch", None, None, None, "kv_seq"))
    rows = (cache.length - 1)[None, None]     # query position = length-1
    cols = jnp.arange(s)[None, :]
    m = _mask(rows, cols, causal=True, window=window, s_valid=cache.length)
    scores = jnp.where(m[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    if constrain_kv:
        p = _c(p, ("batch", None, None, None, "kv_seq"))
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v)
    return out.reshape(b, t, h, d)


def attn_apply(cfg, p, x: Array, *, positions: Array, mode: str,
               cache: Optional[KVCache] = None, cross_kv=None,
               window: Optional[int] = None):
    """mode: 'train' | 'prefill' | 'decode' | 'encoder' | 'cross'.

    Returns (out, new_cache). 'prefill' also fills ``cache``.
    """
    hd = cfg.head_dim_
    b, t, _ = x.shape
    q = _split_heads(cm.dense_apply(p["wq"], x), cfg.n_heads)
    if mode == "cross":
        k, v = cross_kv
    else:
        k = _split_heads(cm.dense_apply(p["wk"], x), cfg.n_kv_heads)
        v = _split_heads(cm.dense_apply(p["wv"], x), cfg.n_kv_heads)

    if cfg.rope == "rope" and mode != "cross":
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope" and mode != "cross":
        q = cm.apply_mrope(q, positions, cfg.rope_theta)
        k = cm.apply_mrope(k, positions, cfg.rope_theta)

    new_cache = cache
    if mode == "decode":
        new_cache = cache_update(cache, k, v,
                                 onehot=cfg.decode_constrain_kv)
        out = _sdpa_decode(q, new_cache, window=window,
                           constrain_kv=cfg.decode_constrain_kv)
    elif mode == "prefill":
        new_cache = cache_update(cache, k, v)
        impl = _select_impl(cfg, t)
        if impl == "chunked":
            out = _sdpa_chunked(q, k, v, causal=True, window=window,
                                chunk=cfg.attn_chunk,
                                unroll=cfg.scan_unroll)
        else:
            out = _sdpa_full(q, k, v, causal=True, window=window)
    else:
        causal = mode == "train"
        impl = _select_impl(cfg, t)
        if impl == "chunked" and t % cfg.attn_chunk == 0:
            out = _sdpa_chunked(q, k, v, causal=causal, window=window,
                                chunk=cfg.attn_chunk,
                                unroll=cfg.scan_unroll)
        else:
            out = _sdpa_full(q, k, v, causal=causal, window=window)

    out = out.reshape(b, t, cfg.n_heads * hd)
    return cm.dense_apply(p["wo"], out), new_cache


def _select_impl(cfg, t: int) -> str:
    if cfg.attn_impl != "auto":
        return cfg.attn_impl
    return "chunked" if t >= 8192 else "full"
