from . import attention, common, losses, model, moe, ssm  # noqa: F401
from .model import (decode_step, init_model, loss_fn, make_caches,  # noqa
                    prefill)
