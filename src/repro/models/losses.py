"""Loss functions. Chunked cross-entropy never materializes (B, T, V)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def chunked_cross_entropy(x: Array, embed_table: Array, labels: Array,
                          *, chunk: int, mask: Array | None = None,
                          z_loss: float = 1e-4, unroll: bool = False):
    """x: (B, T, d) final hidden states; labels: (B, T) int32.

    Computes mean token CE by scanning T in chunks: per step only a
    (B, chunk, V) logits slab is live. ``mask``: 1.0 = count this token.
    """
    b, t, d = x.shape
    chunk = max(1, min(chunk, t))
    while t % chunk:
        chunk //= 2
    n = t // chunk
    xc = x.reshape(b, n, chunk, d).swapaxes(0, 1)          # (n, B, c, d)
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)        # (n, B, c)
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mc = mask.reshape(b, n, chunk).swapaxes(0, 1)

    def body(carry, inp):
        tot, cnt, zacc = carry
        xi, li, mi = inp
        logits = jnp.einsum("bcd,vd->bcv", xi.astype(jnp.float32),
                            embed_table.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mi
        z = (lse ** 2) * mi
        return (tot + ce.sum(), cnt + mi.sum(), zacc + z.sum()), None

    (tot, cnt, zacc), _ = jax.lax.scan(body, (0.0, 0.0, 0.0), (xc, lc, mc),
                                       unroll=n if unroll else 1)
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt + z_loss * zacc / cnt, {"ce": tot / cnt,
                                             "tokens": cnt}
