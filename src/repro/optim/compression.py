"""Error-feedback int8 gradient compression for DP all-reduce.

Halves (vs bf16) or quarters (vs f32) the bytes on the data-parallel
gradient reduce — the distributed-optimization trick for collective-bound
training (DESIGN.md Sec. 6). Compression error is carried in a residual
and re-injected next step (error feedback), which keeps SGD/Adam
convergence intact (Karimireddy et al. 2019).

Usage is via shard_map: the train loop computes *local* gradients inside
``shard_map`` over the data axes and calls ``compressed_psum`` instead of
relying on XLA's implicit f32 reduce.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8 quantization."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name, residual: jax.Array):
    """int8-quantized psum with error feedback.

    x, residual: local f32 tensors. Returns (mean-reduced x_hat,
    new_residual). Wire bytes: 1 byte/elem + one f32 scale, vs 4.
    """
    x_fb = x + residual
    q, scale = quantize_int8(x_fb)
    new_residual = x_fb - dequantize(q, scale)
    # psum int32 accumulations of int8 payloads (bytes on the wire are the
    # int8 tensor; the widening happens at the reducer)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # scales differ per shard; use the mean scale (bias absorbed by EF)
    out = total.astype(jnp.float32) * (scale_sum / n) / n
    return out, new_residual


def compress_tree_psum(grads: Any, axis_name, residuals: Any):
    out, new_res = {}, {}
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r, _ = jax.tree.flatten(residuals)
    outs, ress = [], []
    for g, r in zip(flat_g, flat_r):
        o, nr = compressed_psum(g.astype(jnp.float32), axis_name, r)
        outs.append(o)
        ress.append(nr)
    return jax.tree.unflatten(treedef, outs), \
        jax.tree.unflatten(treedef, ress)


def init_residuals(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
