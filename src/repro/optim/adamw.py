"""AdamW with decoupled weight decay, global-norm clipping, bf16-safe.

Moments are kept in float32 regardless of param dtype (mixed precision:
bf16 params + f32 optimizer state). State shardings mirror the params'
(ZeRO: whatever FSDP sharding the plan assigns to a param applies to its
moments too).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any


class AdamW(NamedTuple):
    lr: Any                      # callable step -> lr, or float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(count=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm:
            gnorm = jnp.sqrt(sum(jnp.sum(g * g)
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.clip_norm
                                / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = jnp.zeros(())
        count = state.count + 1
        lr = self.lr(count) if callable(self.lr) else self.lr
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)

        m = jax.tree.map(lambda mm, g: self.b1 * mm + (1 - self.b1) * g,
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g,
                         state.v, grads)

        def upd(p, mm, vv):
            mhat = mm / b1c
            vhat = vv / b2c
            step = mhat / (jnp.sqrt(vhat) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(count=count, m=m, v=v), \
            {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    """Linear warmup then cosine decay to floor*peak."""
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak_lr + (1 - floor) * peak_lr \
            * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr
