from .adamw import AdamW, AdamWState, warmup_cosine  # noqa: F401
from .compression import (compress_tree_psum, compressed_psum,  # noqa
                          init_residuals, quantize_int8)
