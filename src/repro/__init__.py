"""repro: Gauss quadrature for matrix inverse forms (Li, Sra, Jegelka
2015) as a production-grade multi-pod JAX training/inference framework.

Subpackages: core (the paper), kernels (Pallas TPU), models, sharding,
data, optim, checkpoint, train, serve, configs, launch, utils.
"""
__version__ = "1.0.0"
