"""Fused batched GQL recurrence update — one VPU pass per iteration.

The per-iteration scalar update of Alg. 5 (Sherman-Morrison + the three
modified-Jacobi extensions) is ~40 elementwise ops on 8 state lanes. As
separate XLA ops on a (B,)-batch this is eight kernel launches of tiny
arithmetic; fused in Pallas it is a single VPU pass over 8x128 lanes.

The kernel body re-implements the arithmetic explicitly (it is the unit
under test); the oracle is ``repro.core.gql.recurrence_update``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-30


def recurrence_math(alpha_n, beta_n, beta_p, g, c, delta, d_lr, d_rr,
                    lam_min, lam_max):
    """Traced arithmetic of one Alg. 5 recurrence update, written for
    in-kernel use (plain jnp elementwise ops on values, not refs). Shared
    by the standalone ``gql_update`` kernel below and the fused step
    megakernel (``kernels/lanczos_step.py``); the oracle is
    ``repro.core.gql.recurrence_update``."""
    b2p = beta_p * beta_p
    delta_s = jnp.maximum(delta, _EPS)
    dlr_s = jnp.maximum(d_lr, _EPS)
    drr_s = jnp.minimum(d_rr, -_EPS)

    den_g = delta_s * (alpha_n * delta_s - b2p)
    g_new = g + b2p * (c * c) / jnp.maximum(den_g, _EPS)
    c_new = c * beta_p / delta_s
    delta_new = alpha_n - b2p / delta_s
    dlr_new = alpha_n - lam_min - b2p / dlr_s
    drr_new = alpha_n - lam_max - b2p / drr_s

    # extensions with beta_{i+1}
    b2 = beta_n * beta_n
    dlr_c = jnp.maximum(dlr_new, _EPS)
    drr_c = jnp.minimum(drr_new, -_EPS)
    dn_c = jnp.maximum(delta_new, _EPS)
    alpha_lr = lam_min + b2 / dlr_c
    alpha_rr = lam_max + b2 / drr_c
    den_lo = drr_c - dlr_c
    b2_lo = (lam_max - lam_min) * dlr_c * drr_c / den_lo
    alpha_lo = (lam_max * drr_c - lam_min * dlr_c) / den_lo

    c2 = c_new * c_new

    def sm(alpha_hat, b2_hat):
        # identical guard to core.gql._extensions (the oracle)
        den = dn_c * (alpha_hat * dn_c - b2_hat)
        safe = jnp.where(den >= 0, jnp.maximum(den, _EPS),
                         jnp.minimum(den, -_EPS))
        return g_new + b2_hat * c2 / safe

    return (g_new, c_new, delta_new, dlr_new, drr_new,
            sm(alpha_rr, b2), sm(alpha_lr, b2), sm(alpha_lo, b2_lo))


def _kernel(alpha_ref, beta_ref, betap_ref, g_ref, c_ref, delta_ref,
            dlr_ref, drr_ref, lmin_ref, lmax_ref,
            g_o, c_o, delta_o, dlr_o, drr_o, grr_o, glr_o, glo_o):
    (g_o[...], c_o[...], delta_o[...], dlr_o[...], drr_o[...],
     grr_o[...], glr_o[...], glo_o[...]) = recurrence_math(
        alpha_ref[...], beta_ref[...], betap_ref[...], g_ref[...],
        c_ref[...], delta_ref[...], dlr_ref[...], drr_ref[...],
        lmin_ref[...], lmax_ref[...])


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gql_update(alpha_n, beta_n, beta_p, g, c, delta, d_lr, d_rr,
               lam_min, lam_max, *, block: int = 1024,
               interpret: bool = True):
    """Batched fused recurrence update over (B,) lanes."""
    bsz = alpha_n.shape[-1]
    lam_min = jnp.broadcast_to(jnp.asarray(lam_min, g.dtype), g.shape)
    lam_max = jnp.broadcast_to(jnp.asarray(lam_max, g.dtype), g.shape)
    block = min(block, bsz)
    pad = -bsz % block
    ins = [alpha_n, beta_n, beta_p, g, c, delta, d_lr, d_rr,
           lam_min, lam_max]
    if pad:
        # pad with benign values (delta=1, drr=-1) to avoid spurious infs
        fills = [1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 1.0, -1.0, 0.0, 1.0]
        ins = [jnp.pad(v, (0, pad), constant_values=f)
               for v, f in zip(ins, fills)]
    n = bsz + pad
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    outs = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec] * 10,
        out_specs=[spec] * 8,
        out_shape=[jax.ShapeDtypeStruct((n,), g.dtype)] * 8,
        interpret=interpret,
    )(*ins)
    return tuple(o[:bsz] for o in outs)
