"""Fused Lanczos step megakernel — one ``pallas_call`` per iteration.

The runtime's hot loop previously round-tripped state through HBM
between the lane-stacked matvec, the three-term Lanczos update, the
reorth projection, and the ``gql_update`` recurrence (four dispatch
points per iteration). This module fuses all of them into a single
Pallas kernel per iteration, in two payload flavors:

* **Dense tile** (``_dense_kernel``): grid ``(lane_blocks, col_blocks)``
  streams column blocks of A through the MXU into a VMEM accumulator
  (the ``bilinear_matvec`` pattern); the last column step runs the tail
  — w assembly, alpha, residual, optional reorth against the banked
  basis, beta, and the Sherman-Morrison recurrence — entirely in VMEM.
* **Blocked-ELL** (``_bell_kernel``): the scalar-prefetch walk of
  ``spmv_bell.py`` over ``(block_row, block_col)`` pairs, with the same
  fused tail at the final grid step (one lane per call, vmapped).

Wrapped operators reach the kernel through the diagonal-sandwich form
(``core.operators.fused_operands``):

    matvec(x) = s_out * (A @ (s_in * x)) + t * x

which is closed under Masked / Shifted / Jacobi. The kernel emits *raw*
step outputs (alpha, beta = ||r||, residual r, and the eight recurrence
scalars); breakdown detection, freezing, and bracket collapse run
outside through the exact same ``lanczos_assemble`` / ``gql_assemble``
code as the reference path, so the two routes cannot drift in their
select logic. Operators with no sandwich form (SparseCOO, MatvecFn)
fall back to the reference composition bit-for-bit.

Off-TPU the kernels run in interpret mode in the native dtype, so the
fused path only differs from the reference by summation order inside
the matvec / reductions (<= 1e-12 relative on gemm-backed operators).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import gql as _gql
from ..core import lanczos as _lanczos
from ..core import operators as _operators
from . import gql_update as _gu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

_LANE_BLOCK = 8       # lanes per grid step (dense flavor)
_COL_BLOCK = 128      # A columns streamed per grid step (dense flavor)

# benign fill values for padded lanes: delta=1 / d_rr=-1 keep every
# guard denominator away from zero (same convention as gql_update)
_SCALAR_FILLS = {"beta": 0.0, "g": 0.0, "c": 0.0, "delta": 1.0,
                 "d_lr": 1.0, "d_rr": -1.0, "lam_min": 0.0, "lam_max": 1.0}
_SCALAR_ORDER = ("beta", "g", "c", "delta", "d_lr", "d_rr",
                 "lam_min", "lam_max")


def _tail(acc, s_out, t, v, v_prev, basis, scalars):
    """Fused step tail: finish the matvec sandwich, take the Lanczos
    update + optional reorth, and run the recurrence. Pure traced math,
    shared verbatim by both kernel flavors. ``scalars`` is the 8-tuple
    in ``_SCALAR_ORDER``; returns (alpha, beta_new, r, raw8)."""
    beta_p, g, c, delta, d_lr, d_rr, lam_min, lam_max = scalars
    w = s_out * acc + t * v
    alpha = jnp.sum(v * w, axis=-1)
    r = w - alpha[..., None] * v - beta_p[..., None] * v_prev
    if basis is not None:
        # one classical Gram-Schmidt pass against the banked vectors
        coeff = jax.lax.dot_general(
            basis, r, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=r.dtype)
        r = r - jax.lax.dot_general(
            coeff, basis, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=r.dtype)
    beta_new = jnp.sqrt(jnp.sum(r * r, axis=-1))
    raw = _gu.recurrence_math(alpha, beta_new, beta_p, g, c, delta,
                              d_lr, d_rr, lam_min, lam_max)
    return alpha, beta_new, r, raw


def _write_tail(alpha, beta_new, r, raw, alpha_o, beta_o, r_o, *raw_o):
    alpha_o[...] = alpha
    beta_o[...] = beta_new
    r_o[...] = r
    for val, ref in zip(raw, raw_o):
        ref[...] = val


# ---------------------------------------------------------------------------
# Dense flavor


def _dense_kernel(shared_a, has_basis, nj, bn, *refs):
    a_ref, so_ref, si_ref, t_ref, v_ref, vp_ref = refs[:6]
    scalar_refs = refs[6:14]
    basis_ref = refs[14] if has_basis else None
    out_refs = refs[14 + has_basis:14 + has_basis + 11]
    acc = refs[14 + has_basis + 11]

    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    cols = pl.ds(j * bn, bn)
    xblk = si_ref[:, cols] * v_ref[:, cols]          # (bk, bn)
    if shared_a:
        # a_ref block: (N, bn); contract the column block
        acc[...] += jax.lax.dot_general(
            xblk, a_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=acc.dtype)
    else:
        # a_ref block: (bk, N, bn), batched over lanes
        acc[...] += jax.lax.dot_general(
            a_ref[...], xblk, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=acc.dtype)

    @pl.when(j == nj - 1)
    def _():
        alpha, beta_new, r, raw = _tail(
            acc[...], so_ref[...], t_ref[...], v_ref[...], vp_ref[...],
            basis_ref[...] if has_basis else None,
            tuple(ref[...] for ref in scalar_refs))
        _write_tail(alpha, beta_new, r, raw, *out_refs)


@functools.partial(jax.jit, static_argnames=("shared_a", "interpret"))
def fused_step_dense(a, s_out, s_in, t, v, v_prev, scalars, basis=None, *,
                     shared_a: bool, interpret: bool = True):
    """One fused step over (K, N) lanes with a dense A.

    ``a``: (N, N) when ``shared_a`` else (K, N, N); ``scalars``: 8-tuple
    of (K,) arrays in ``_SCALAR_ORDER``. Returns
    ``(alpha, beta_new, r, raw8)`` with raw8 the recurrence outputs.
    """
    kk, n = v.shape
    dtype = v.dtype
    bk = min(_LANE_BLOCK, kk)
    bn = min(_COL_BLOCK, n)
    pad_k = -kk % bk
    pad_n = -n % bn

    def pad2(x):
        return jnp.pad(x, ((0, pad_k), (0, pad_n))) if (pad_k or pad_n) else x

    if shared_a:
        if pad_n:
            a = jnp.pad(a, ((0, pad_n), (0, pad_n)))
    elif pad_k or pad_n:
        a = jnp.pad(a, ((0, pad_k), (0, pad_n), (0, pad_n)))
    s_out, s_in, t, v, v_prev = map(pad2, (s_out, s_in, t, v, v_prev))
    scalars = tuple(
        jnp.pad(s, (0, pad_k), constant_values=_SCALAR_FILLS[name])
        if pad_k else s
        for name, s in zip(_SCALAR_ORDER, scalars))
    has_basis = basis is not None
    if basis is not None and (pad_k or pad_n):
        basis = jnp.pad(basis, ((0, pad_k), (0, 0), (0, pad_n)))

    kp, np_ = kk + pad_k, n + pad_n
    nj = np_ // bn
    row = pl.BlockSpec((bk, np_), lambda k, j: (k, 0))
    lane = pl.BlockSpec((bk,), lambda k, j: (k,))
    a_spec = (pl.BlockSpec((np_, bn), lambda k, j: (0, j)) if shared_a
              else pl.BlockSpec((bk, np_, bn), lambda k, j: (k, 0, j)))
    in_specs = [a_spec] + [row] * 5 + [lane] * 8
    ins = [a, s_out, s_in, t, v, v_prev, *scalars]
    if basis is not None:
        m = basis.shape[1]
        in_specs.append(pl.BlockSpec((bk, m, np_), lambda k, j: (k, 0, 0)))
        ins.append(basis)
    out_specs = [lane, lane, row] + [lane] * 8
    out_shape = ([jax.ShapeDtypeStruct((kp,), dtype)] * 2
                 + [jax.ShapeDtypeStruct((kp, np_), dtype)]
                 + [jax.ShapeDtypeStruct((kp,), dtype)] * 8)
    extra = {}
    if _CompilerParams is not None:
        extra["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    outs = pl.pallas_call(
        functools.partial(_dense_kernel, shared_a, has_basis, nj, bn),
        grid=(kp // bk, nj),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bk, np_), dtype)],
        interpret=interpret,
        **extra,
    )(*ins)
    alpha, beta_new, r = outs[0][:kk], outs[1][:kk], outs[2][:kk, :n]
    return alpha, beta_new, r, tuple(o[:kk] for o in outs[3:])


# ---------------------------------------------------------------------------
# Blocked-ELL flavor (one lane per call; vmapped by the dispatcher)


def _bell_kernel(nr, nk, bs, *refs):
    cols_ref, d_ref, vg_ref, sg_ref = refs[:4]
    so_ref, t_ref, v_ref, vp_ref = refs[4:8]
    scalar_refs = refs[8:16]
    out_refs = refs[16:27]
    acc = refs[27]

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    rblk = i // nk
    xblk = sg_ref[...] * vg_ref[...]                 # gathered (bs,)
    contrib = jax.lax.dot_general(
        d_ref[0, 0].astype(acc.dtype), xblk, (((1,), (0,)), ((), ())),
        preferred_element_type=acc.dtype)
    acc[pl.ds(rblk * bs, bs)] += contrib

    @pl.when(i == nr * nk - 1)
    def _():
        alpha, beta_new, r, raw = _tail(
            acc[...][None], so_ref[...][None], t_ref[...][None],
            v_ref[...][None], vp_ref[...][None], None,
            tuple(ref[...] for ref in scalar_refs))
        _write_tail(alpha, beta_new, r[0], raw, *out_refs)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_step_bell(data, cols, s_out, s_in, t, v, v_prev, scalars, *,
                    interpret: bool = True):
    """One fused step for a single lane with a blocked-ELL A.

    ``data``: (R, K, bs, bs), ``cols``: (R, K); vectors are (N_pad,)
    with N_pad = R * bs (caller zero-pads); ``scalars``: 8-tuple of
    (1,) arrays in ``_SCALAR_ORDER``. No reorth (the dispatcher falls
    back to the reference composition when a basis is banked).
    """
    nr, nk, bs, _ = data.shape
    n_pad = nr * bs
    dtype = v.dtype
    full = pl.BlockSpec((n_pad,), lambda i, cols: (0,))
    one = pl.BlockSpec((1,), lambda i, cols: (0,))
    gathered = pl.BlockSpec((bs,), lambda i, cols: (cols[i // nk, i % nk],))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nr * nk,),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs),
                         lambda i, cols: (i // nk, i % nk, 0, 0)),
            gathered, gathered,
            full, full, full, full,
            *([one] * 8),
        ],
        out_specs=[one, one, full] + [one] * 8,
        scratch_shapes=[pltpu.VMEM((n_pad,), dtype)],
    )
    extra = {}
    if _CompilerParams is not None:
        extra["compiler_params"] = _CompilerParams(
            dimension_semantics=("arbitrary",))
    outs = pl.pallas_call(
        functools.partial(_bell_kernel, nr, nk, bs),
        grid_spec=grid_spec,
        out_shape=([jax.ShapeDtypeStruct((1,), dtype)] * 2
                   + [jax.ShapeDtypeStruct((n_pad,), dtype)]
                   + [jax.ShapeDtypeStruct((1,), dtype)] * 8),
        interpret=interpret,
        **extra,
    )(cols, data, v, s_in, s_out, t, v, v_prev, *scalars)
    alpha, beta_new, r = outs[0][0], outs[1][0], outs[2]
    return alpha, beta_new, r, tuple(o[0] for o in outs[3:])


# ---------------------------------------------------------------------------
# Dispatcher


def _flatten_lanes(x, batch, trailing):
    """Broadcast ``x`` against ``batch + trailing`` and flatten ``batch``."""
    x = jnp.broadcast_to(x, batch + trailing)
    return x.reshape((-1,) + trailing)


def gql_step_fused(op, st: _gql.GQLState, lam_min, lam_max,
                   basis=None, interpret: bool | None = None
                   ) -> _gql.GQLState:
    """Drop-in replacement for ``core.gql.gql_step`` routing the whole
    iteration through the fused megakernel when ``op`` admits the
    sandwich form; reference composition otherwise (bit-exact).
    ``interpret=None`` auto-selects interpret mode off-TPU."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    form = _operators.fused_operands(op)
    if form is not None and isinstance(form[0], _operators.SparseBELL) \
            and basis is not None:
        form = None  # reorth not fused on the BELL flavor
    if form is None:
        return _gql.gql_step(op, st, lam_min, lam_max, basis=basis)
    base, s_out, s_in, t = form

    dtype = st.lz.v.dtype
    batch = st.lz.v.shape[:-1]
    n = st.lz.v.shape[-1]
    lam_min = jnp.asarray(lam_min, dtype)
    lam_max = jnp.asarray(lam_max, dtype)
    vecs = tuple(_flatten_lanes(jnp.asarray(x, dtype), batch, (n,))
                 for x in (s_out, s_in, t, st.lz.v, st.lz.v_prev))
    scalars = tuple(_flatten_lanes(jnp.asarray(x, dtype), batch, ())
                    for x in (st.lz.beta, st.g, st.c, st.delta,
                              st.delta_lr, st.delta_rr, lam_min, lam_max))

    if isinstance(base, _operators.Dense):
        shared_a = base.a.ndim == 2
        a = base.a if shared_a else _flatten_lanes(base.a, batch, (n, n))
        bas = (None if basis is None
               else _flatten_lanes(basis, batch, basis.shape[-2:]))
        alpha, beta_new, r, raw = fused_step_dense(
            a, *vecs, scalars, bas, shared_a=shared_a, interpret=interpret)
    else:
        nr, nk, bs, _ = base.data.shape[-4:]
        n_pad = nr * bs
        pad = n_pad - n

        def padv(x):
            return jnp.pad(x, ((0, 0), (0, pad))) if pad else x

        vecs_p = tuple(padv(x) for x in vecs)
        scal_1 = tuple(s[:, None] for s in scalars)  # (K, 1) per lane
        shared = base.data.ndim == 4
        if shared:
            in_axes = (None, None) + (0,) * 7
            dat, col = base.data, base.cols
        else:
            in_axes = (0,) * 9
            dat = _flatten_lanes(base.data, batch, base.data.shape[-4:])
            col = _flatten_lanes(base.cols, batch, base.cols.shape[-2:])
        step = jax.vmap(
            lambda d, c, so, si, tt, vv, vp, sc: fused_step_bell(
                d, c, so, si, tt, vv, vp, sc, interpret=interpret),
            in_axes=(in_axes[:2] + (0, 0, 0, 0, 0, 0)))
        alpha, beta_new, r, raw = step(
            dat, col, vecs_p[0], vecs_p[1], vecs_p[2], vecs_p[3],
            vecs_p[4], scal_1)
        r = r[:, :n]
        raw = tuple(x for x in raw)

    def unflatten(x, trailing=()):
        return x.reshape(batch + trailing)

    lz = _lanczos.lanczos_assemble(
        st.lz, unflatten(alpha), unflatten(beta_new), unflatten(r, (n,)))
    raw = tuple(unflatten(x) for x in raw)
    return _gql.gql_assemble(st, lz, raw)
