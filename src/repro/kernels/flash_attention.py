"""Flash-style streaming attention (forward) — beyond-paper kernel.

Used by the serving path and the prefill hillclimb (EXPERIMENTS.md
Sec. Perf): online-softmax attention that streams K/V tiles through VMEM,
never materializing the (T, S) score matrix in HBM.

Layout: q (BH, T, D), k/v (BH, S, D); GQA is handled by the wrapper
(kv heads repeated to q heads before flattening). Causal masking uses
global row/col indices; padded key tail (S_pad > s_len) is masked the
same way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *, scale, causal,
            s_len, bt, bs):
    t = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, _NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q = q_ref[0].astype(jnp.float32)            # (bt, d)
    k = k_ref[0].astype(jnp.float32)            # (bs, d)
    v = v_ref[0].astype(jnp.float32)            # (bs, d)
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * scale

    cols = s * bs + jax.lax.broadcasted_iota(jnp.int32, (bt, bs), 1)
    valid = cols < s_len
    if causal:
        rows = t * bt + jax.lax.broadcasted_iota(jnp.int32, (bt, bs), 0)
        valid = valid & (cols <= rows)
    qk = jnp.where(valid, qk, _NEG_INF)

    m_new = jnp.maximum(m_i[...], jnp.max(qk, axis=1, keepdims=True))
    p = jnp.exp(qk - m_new)
    alpha = jnp.exp(m_i[...] - m_new)
    l_i[...] = l_i[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_i[...] = m_new

    @pl.when(s == pl.num_programs(2) - 1)
    def _():
        o_ref[0] = (acc[...] / jnp.maximum(l_i[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bt", "bs", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bt: int = 128, bs: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (BH, T, D); k, v: (BH, S, D) -> (BH, T, D)."""
    bh, t_len, d = q.shape
    _, s_len, _ = k.shape
    scale = 1.0 / (d ** 0.5)
    bt = min(bt, t_len)
    bs = min(bs, s_len)
    tp = -t_len % bt
    sp = -s_len % bs
    if tp:
        q = jnp.pad(q, ((0, 0), (0, tp), (0, 0)))
    if sp:
        k = jnp.pad(k, ((0, 0), (0, sp), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp), (0, 0)))
    tt, ss = t_len + tp, s_len + sp
    grid = (bh, tt // bt, ss // bs)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, s_len=s_len,
                          bt=bt, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda b, t, s: (b, t, 0)),
            pl.BlockSpec((1, bs, d), lambda b, t, s: (b, s, 0)),
            pl.BlockSpec((1, bs, d), lambda b, t, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda b, t, s: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tt, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, d), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :t_len, :]


def mha_flash(q, k, v, *, causal=True, interpret=True, bt=128, bs=128):
    """Convenience multi-head wrapper: q (B, T, H, D), k/v (B, S, Hkv, D);
    repeats kv heads for GQA and flattens (B, H)."""
    b, t, h, d = q.shape
    _, s, hkv, _ = k.shape
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    of = flash_attention(qf, kf, vf, causal=causal, interpret=interpret,
                         bt=bt, bs=bs)
    return of.reshape(b, h, t, d).transpose(0, 2, 1, 3)
