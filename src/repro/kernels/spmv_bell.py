"""Blocked-ELL sparse matvec Pallas kernel.

The paper's speedups live on *sparse* kernels (Table 1: densities 0.009%
to 11%). CSR gather/scatter is hostile to the MXU and to Pallas' static
shapes, so we store A as blocked-ELL (DESIGN.md Sec. 3 item 3):

    data: (R, K, bs, bs)   R = N/bs block-rows, K = max blocks per row
    cols: (R, K) int32     block-column index of each stored block
                           (padding blocks point at column 0 with zero data)

The kernel walks (r, k) with the block-column table scalar-prefetched so
the x tile for step (r, k) is fetched by index_map — dense 128x128 MXU
multiplies at FLOPs proportional to stored blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cols_ref, d_ref, x_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        d_ref[0, 0], x_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bell_matvec(data: jax.Array, cols: jax.Array, x: jax.Array, *,
                interpret: bool = True) -> jax.Array:
    """y = A @ x for blocked-ELL A; x: (N,) with N = R * bs."""
    r, k, bs, _ = data.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, k),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda r, k, cols: (r, k, 0, 0)),
            pl.BlockSpec((bs,), lambda r, k, cols: (cols[r, k],)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda r, k, cols: (r,)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r * bs,), jnp.float32),
        interpret=interpret,
    )(cols, data, x)


def dense_to_bell(a, bs: int = 128, k_max: int | None = None):
    """Convert a dense (numpy) symmetric matrix to blocked-ELL arrays.

    Returns (data (R,K,bs,bs) f32, cols (R,K) i32, n). Zero-pads N up to
    a multiple of ``bs``; rows with fewer than K non-zero blocks are
    padded with zero blocks pointing at column 0.
    """
    a = np.asarray(a, np.float32)
    n = a.shape[0]
    npad = -n % bs
    if npad:
        a = np.pad(a, ((0, npad), (0, npad)))
    nn = a.shape[0]
    r = nn // bs
    blocks = a.reshape(r, bs, r, bs).transpose(0, 2, 1, 3)  # (R, R, bs, bs)
    nz = np.abs(blocks).max(axis=(2, 3)) > 0                # (R, R)
    per_row = nz.sum(axis=1)
    k = int(per_row.max()) if k_max is None else k_max
    k = max(k, 1)
    data = np.zeros((r, k, bs, bs), np.float32)
    cols = np.zeros((r, k), np.int32)
    for i in range(r):
        js = np.nonzero(nz[i])[0][:k]
        data[i, :len(js)] = blocks[i, js]
        cols[i, :len(js)] = js
    return jnp.asarray(data), jnp.asarray(cols), n
