"""Blocked-ELL sparse matvec Pallas kernel.

The paper's speedups live on *sparse* kernels (Table 1: densities 0.009%
to 11%). CSR gather/scatter is hostile to the MXU and to Pallas' static
shapes, so we store A as blocked-ELL (DESIGN.md Sec. 3 item 3):

    data: (R, K, bs, bs)   R = N/bs block-rows, K = max blocks per row
    cols: (R, K) int32     block-column index of each stored block
                           (padding blocks point at column 0 with zero data)

The kernel walks (r, k) with the block-column table scalar-prefetched so
the x tile for step (r, k) is fetched by index_map — dense 128x128 MXU
multiplies at FLOPs proportional to stored blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(cols_ref, d_ref, x_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        d_ref[0, 0], x_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bell_matvec(data: jax.Array, cols: jax.Array, x: jax.Array, *,
                interpret: bool = True) -> jax.Array:
    """y = A @ x for blocked-ELL A; x: (N,) with N = R * bs."""
    r, k, bs, _ = data.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, k),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda r, k, cols: (r, k, 0, 0)),
            pl.BlockSpec((bs,), lambda r, k, cols: (cols[r, k],)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda r, k, cols: (r,)),
    )
    extra = {}
    if _CompilerParams is not None:
        # the output block for step (r, k) accumulates over k: the block-row
        # axis is parallel, the block-column walk is not
        extra["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r * bs,), jnp.float32),
        interpret=interpret,
        **extra,
    )(cols, data, x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bell_matvec_mrhs(data: jax.Array, cols: jax.Array, x: jax.Array, *,
                     interpret: bool = True) -> jax.Array:
    """Y = A @ X for blocked-ELL A; X: (N, m) column-stacked right-hand
    sides, N = R * bs. Same scalar-prefetch walk as :func:`bell_matvec`
    but each (r, k) step is a (bs, bs) @ (bs, m) MXU gemm — the m block
    columns ride one pass over the stored blocks instead of m passes
    (``_kernel`` is shape-agnostic over the trailing dims of x)."""
    r, k, bs, _ = data.shape
    m = x.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, k),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda r, k, cols: (r, k, 0, 0)),
            pl.BlockSpec((bs, m), lambda r, k, cols: (cols[r, k], 0)),
        ],
        out_specs=pl.BlockSpec((bs, m), lambda r, k, cols: (r, 0)),
    )
    extra = {}
    if _CompilerParams is not None:
        extra["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r * bs, m), jnp.float32),
        interpret=interpret,
        **extra,
    )(cols, data, x)


def bell_matvec_ref(data: jax.Array, cols: jax.Array, x: jax.Array
                    ) -> jax.Array:
    """Reference blocked-ELL SpMV in pure jnp, batched over leading dims.

    ``data``/``cols`` may carry leading batch dims matching ``x``'s (a
    stacked operator), or none (one matrix shared across all lanes of
    ``x``). Computes in ``x.dtype`` (the Pallas kernel is fixed to f32).
    """
    r, k, bs, _ = data.shape[-4:]
    xb = x.reshape(x.shape[:-1] + (r, bs))
    if cols.ndim == 2:
        gathered = xb[..., cols, :]                     # (..., R, K, bs)
        y = jnp.einsum("rkij,...rkj->...ri", data.astype(x.dtype), gathered)
    else:
        # stacked operator: gather each lane's x blocks by its own table
        # (x must carry the same leading lane dims as cols)
        flat_idx = cols.reshape(cols.shape[:-2] + (r * k,))
        gathered = jnp.take_along_axis(xb, flat_idx[..., None], axis=-2)
        gathered = gathered.reshape(cols.shape[:-2] + (r, k, bs))
        y = jnp.einsum("...rkij,...rkj->...ri", data.astype(x.dtype),
                       gathered)
    return y.reshape(x.shape[:-1] + (r * bs,))


def dense_to_bell(a, bs: int = 128, k_max: int | None = None,
                  dtype=np.float32):
    """Convert a dense (numpy) symmetric matrix to blocked-ELL arrays.

    Returns (data (R,K,bs,bs), cols (R,K) i32, n). Zero-pads N up to
    a multiple of ``bs``; rows with fewer than K non-zero blocks are
    padded with zero blocks pointing at column 0.
    """
    a = np.asarray(a, dtype)
    n = a.shape[0]
    npad = -n % bs
    if npad:
        a = np.pad(a, ((0, npad), (0, npad)))
    nn = a.shape[0]
    r = nn // bs
    blocks = a.reshape(r, bs, r, bs).transpose(0, 2, 1, 3)  # (R, R, bs, bs)
    nz = np.abs(blocks).max(axis=(2, 3)) > 0                # (R, R)
    per_row = nz.sum(axis=1)
    k = int(per_row.max()) if k_max is None else k_max
    k = max(k, 1)
    data = np.zeros((r, k, bs, bs), a.dtype)
    cols = np.zeros((r, k), np.int32)
    for i in range(r):
        js = np.nonzero(nz[i])[0][:k]
        data[i, :len(js)] = blocks[i, js]
        cols[i, :len(js)] = js
    return jnp.asarray(data), jnp.asarray(cols), n
