"""Fused bilinear matvec Pallas kernel — the Lanczos hot spot.

One pass over A computes BOTH
    y     = A @ x                (the next Krylov direction)
    alpha = x^T A x              (the Lanczos diagonal coefficient)
so HBM traffic for A (the dominant term: N^2 elements vs N for vectors)
is paid once per GQL iteration instead of twice.

TPU mapping: A is streamed HBM->VMEM in (bm, bn) tiles (128-aligned for
the MXU); the per-row accumulator and the alpha accumulator live in VMEM
scratch. Batched over independent quadrature systems on the leading grid
dimension (DESIGN.md Sec. 3 item 2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(a_ref, xj_ref, xi_ref, y_ref, al_ref, acc_y, acc_al):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        acc_y[...] = jnp.zeros_like(acc_y)

    @pl.when((i == 0) & (j == 0))
    def _():
        acc_al[...] = jnp.zeros_like(acc_al)

    a = a_ref[0]            # (bm, bn)
    xj = xj_ref[0]          # (bn,)
    t = jax.lax.dot_general(a, xj.astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bm,)
    acc_y[...] += t
    acc_al[0] += jnp.sum(xi_ref[0].astype(jnp.float32) * t)

    @pl.when(j == pl.num_programs(2) - 1)
    def _():
        y_ref[0] = acc_y[...].astype(y_ref.dtype)

    @pl.when((i == pl.num_programs(1) - 1) & (j == pl.num_programs(2) - 1))
    def _():
        al_ref[0] = acc_al[0].astype(al_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fused_matvec(a: jax.Array, x: jax.Array, *, bm: int = 128,
                 bn: int = 128, interpret: bool = True):
    """y = A @ x and alpha = x^T A x, batched.

    a: (B, N, N) symmetric blocks; x: (B, N). N is zero-padded up to the
    tile size by the wrapper (zero rows/cols change neither y's valid
    entries nor alpha).
    """
    b, n, _ = a.shape
    bm = bn = min(bm, bn, n)
    n_pad = -n % bm
    if n_pad:
        a = jnp.pad(a, ((0, 0), (0, n_pad), (0, n_pad)))
        x = jnp.pad(x, ((0, 0), (0, n_pad)))
    npad = n + n_pad
    grid = (b, npad // bm, npad // bn)

    y, al = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, bn), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, bm), lambda b, i, j: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda b, i, j: (b, i)),
            pl.BlockSpec((1,), lambda b, i, j: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, npad), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, x, x)
    return y[:, :n], al
