"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import gql as _gql


def fused_matvec(a: jax.Array, x: jax.Array):
    """Oracle for kernels.bilinear_matvec.fused_matvec."""
    y = jnp.einsum("bij,bj->bi", a.astype(jnp.float32),
                   x.astype(jnp.float32))
    alpha = jnp.einsum("bi,bi->b", x.astype(jnp.float32), y)
    return y, alpha


def bell_matvec(data: jax.Array, cols: jax.Array, x: jax.Array):
    """Oracle for kernels.spmv_bell.bell_matvec."""
    r, k, bs, _ = data.shape
    xb = x.reshape(-1, bs)                       # (R, bs)
    gathered = xb[cols]                          # (R, K, bs)
    y = jnp.einsum("rkij,rkj->ri", data.astype(jnp.float32),
                   gathered.astype(jnp.float32))
    return y.reshape(r * bs)


def gql_update(alpha_n, beta_n, beta_p, g, c, delta, d_lr, d_rr,
               lam_min, lam_max):
    """Oracle for kernels.gql_update.gql_update — the core recurrence."""
    return _gql.recurrence_update(alpha_n, beta_n, beta_p, g, c, delta,
                                  d_lr, d_rr,
                                  jnp.asarray(lam_min, g.dtype),
                                  jnp.asarray(lam_max, g.dtype))


def flash_attention(q, k, v, *, causal=True):
    """Oracle for kernels.flash_attention.flash_attention."""
    d = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        t_len, s_len = s.shape[-2], s.shape[-1]
        # query at global position i attends keys j <= i (zero-aligned)
        rows = jnp.arange(t_len)[:, None]
        cols = jnp.arange(s_len)[None, :]
        s = jnp.where(cols <= rows, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32)).astype(q.dtype)
