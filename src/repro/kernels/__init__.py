"""Pallas TPU kernels for the paper's compute hot spots (+ attention).

Each kernel module pairs with a pure-jnp oracle in ``ref.py``; ``ops.py``
exposes jit'd wrappers that select interpret mode off-TPU.
"""
from . import ops, ref  # noqa: F401
