"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernel bodies execute in Python/jnp for correctness validation) and False
on a real TPU backend.
"""
from __future__ import annotations

import jax

from . import bilinear_matvec as _bmv
from . import flash_attention as _fa
from . import gql_update as _gu
from . import lanczos_step as _ls
from . import spmv_bell as _sb


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def gql_step_fused(op, st, lam_min, lam_max, basis=None, *,
                   interpret: bool | None = None):
    """Fused Lanczos+GQL step megakernel (one pallas_call per iteration);
    falls back to the reference composition for non-sandwich operators."""
    itp = _default_interpret() if interpret is None else interpret
    return _ls.gql_step_fused(op, st, lam_min, lam_max, basis=basis,
                              interpret=itp)


def fused_matvec(a, x, *, bm: int = 128, bn: int = 128,
                 interpret: bool | None = None):
    """(y, alpha) = (A @ x, x^T A x), batched over the leading dim."""
    itp = _default_interpret() if interpret is None else interpret
    return _bmv.fused_matvec(a, x, bm=bm, bn=bn, interpret=itp)


def bell_matvec(data, cols, x, *, interpret: bool | None = None):
    """Blocked-ELL SpMV."""
    itp = _default_interpret() if interpret is None else interpret
    return _sb.bell_matvec(data, cols, x, interpret=itp)


def bell_matvec_mrhs(data, cols, x, *, interpret: bool | None = None):
    """Blocked-ELL SpMM: x is (N, m) column-stacked right-hand sides."""
    itp = _default_interpret() if interpret is None else interpret
    return _sb.bell_matvec_mrhs(data, cols, x, interpret=itp)


def gql_update(alpha_n, beta_n, beta_p, g, c, delta, d_lr, d_rr,
               lam_min, lam_max, *, interpret: bool | None = None):
    """Fused batched GQL recurrence update."""
    itp = _default_interpret() if interpret is None else interpret
    return _gu.gql_update(alpha_n, beta_n, beta_p, g, c, delta, d_lr, d_rr,
                          lam_min, lam_max, interpret=itp)


def flash_attention(q, k, v, *, causal: bool = True, bt: int = 128,
                    bs: int = 128, interpret: bool | None = None):
    """Streaming attention forward over (BH, T/S, D) layouts."""
    itp = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, bt=bt, bs=bs,
                               interpret=itp)


def mha_flash(q, k, v, *, causal: bool = True, bt: int = 128, bs: int = 128,
              interpret: bool | None = None):
    itp = _default_interpret() if interpret is None else interpret
    return _fa.mha_flash(q, k, v, causal=causal, bt=bt, bs=bs, interpret=itp)


dense_to_bell = _sb.dense_to_bell
