"""Production mesh construction (assigned: 16x16 single pod; 2x16x16
multi-pod). A FUNCTION, not a module constant — importing this module
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever devices exist locally (tests / smoke), data x model."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes_for(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
