"""Production mesh construction (assigned: 16x16 single pod; 2x16x16
multi-pod). A FUNCTION, not a module constant — importing this module
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever devices exist locally (tests / smoke), data x model."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes_for(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_lane_mesh(num_devices: int | None = None, *, axis: str = "lanes"):
    """1-D mesh for data-parallel quadrature lanes (DESIGN.md Sec. 7).

    The K candidate systems of the batched retrospective driver shard
    over this single axis (``core.sharded``); operators are replicated.
    Defaults to every local device — on CPU tests, launch with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get 8
    virtual devices.
    """
    n = len(jax.devices()) if num_devices is None else int(num_devices)
    return jax.make_mesh((n,), (axis,))
