"""Jittable train / prefill / decode steps with full sharding wiring.

``build_train_step`` returns (step_fn, in_shardings, out_shardings) ready
for ``jax.jit(..., donate_argnums=(0, 1))`` — this is what both the real
launcher and the multi-pod dry-run lower. Gradient accumulation scans
microbatches so the DP gradient reduce of microbatch k overlaps the
compute of k+1 (XLA async collectives; DESIGN.md Sec. 6).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import model as M
from ..optim.adamw import AdamW, warmup_cosine
from ..sharding import api as shapi

Array = jax.Array


def default_optimizer(total_steps: int = 10000) -> AdamW:
    return AdamW(lr=warmup_cosine(3e-4, 200, total_steps))


# ---------------------------------------------------------------------------
# Sharding helpers


def batch_sharding(mesh: Mesh, plan: shapi.Plan, batch_specs: Any):
    """Shard every batch leaf on its leading (batch) dim over data axes."""
    data_axes = plan.rules["batch"]

    def one(x):
        spec = [None] * len(x.shape)
        if len(x.shape) >= 1 and x.shape[0] % _size(mesh, data_axes) == 0:
            spec[0] = data_axes
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_specs)


def cache_sharding(cfg, mesh: Mesh, plan: shapi.Plan, cache_specs: Any):
    """Path-aware cache shardings.

    KV cache k/v/(scales): logical (B, S, Hkv, D) -> batch over data,
    seq over model (GQA kv heads rarely divide a 16-way model axis, so
    the cache seq dim carries TP; attention softmax reduces over the
    sharded axis with small collectives).
    SSM conv (B, K, di): di over model. SSM state: mamba1 (B, di, n) ->
    di over model; mamba2 (B, H, dh, n) -> H over model.
    Any leading stack dims (layers / units) are replicated.
    """
    data_axes = plan.rules["batch"]
    model_axis = plan.rules["heads"]

    data_axes = plan.rules.get("cache_batch") or data_axes

    logical_rank = {"k": 4, "v": 4, "k_scale": 4, "v_scale": 4,
                    "conv": 3,
                    "state": 3 if cfg.ssm_variant == "mamba1" else 4,
                    "length": 0}

    def logical_spec(name: str, shape):
        if name in ("k", "v", "k_scale", "v_scale"):
            sp = [None, None, None, None]
            if shape[0] % _size(mesh, data_axes) == 0:
                sp[0] = data_axes
            if shape[1] % _size(mesh, model_axis) == 0:
                sp[1] = model_axis
            return sp
        if name == "conv":
            sp = [None, None, None]
            if shape[0] % _size(mesh, data_axes) == 0:
                sp[0] = data_axes
            if shape[2] % _size(mesh, model_axis) == 0:
                sp[2] = model_axis
            return sp
        if name == "state":
            sp = [None] * len(shape)
            if shape[0] % _size(mesh, data_axes) == 0:
                sp[0] = data_axes
            if shape[1] % _size(mesh, model_axis) == 0:
                sp[1] = model_axis
            return sp
        return []

    def dispatch(path, x):
        name = None
        for entry in reversed(path):
            attr = getattr(entry, "name", getattr(entry, "key", None))
            if attr in logical_rank:
                name = attr
                break
        if name is None or name == "length" or len(x.shape) <= 1:
            return NamedSharding(mesh, P())
        rank = logical_rank[name]
        lead = len(x.shape) - rank
        sp = logical_spec(name, x.shape[lead:])
        return NamedSharding(mesh, P(*([None] * lead), *sp))

    return jax.tree_util.tree_map_with_path(dispatch, cache_specs)


def _size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, (tuple, list)):
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axes]


# ---------------------------------------------------------------------------
# Train step


def build_train_step(cfg, mesh: Mesh, plan: shapi.Plan,
                     optimizer: Optional[AdamW] = None,
                     microbatches: int = 1):
    """Returns (fn, shardings) for
    fn(params, opt_state, batch) -> (params, opt_state, metrics)."""
    optimizer = optimizer or default_optimizer()

    def loss_wrapped(params, batch):
        with shapi.activation_context(mesh, plan):
            return M.loss_fn(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_wrapped, has_aux=True)(params, mb)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches,
                    gacc, grads)
                return (gacc, lacc + loss / microbatches), None

            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            # scan_unroll: cost-analysis mode must unroll this loop too,
            # or per-microbatch work is counted once (see dryrun.py)
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, 0.0), mbs,
                unroll=microbatches if cfg.scan_unroll else 1)
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_wrapped, has_aux=True)(params, batch)
            metrics = dict(metrics, loss=loss)
        params, opt_state, opt_metrics = optimizer.update(
            grads, opt_state, params)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def train_shardings(cfg, mesh: Mesh, plan: shapi.Plan, params_axes,
                    params_specs, opt_state_specs, batch_specs):
    """(in_shardings, out_shardings) trees for jit."""
    p_sh = shapi.param_shardings(plan, mesh, params_specs, params_axes)
    o_sh = _opt_shardings(mesh, plan, params_axes, opt_state_specs, p_sh)
    b_sh = batch_sharding(mesh, plan, batch_specs)
    repl = NamedSharding(mesh, P())
    m_sh = None  # metrics: let XLA decide (scalars)
    return (p_sh, o_sh, b_sh), (p_sh, o_sh, repl)


def _opt_shardings(mesh, plan, params_axes, opt_state_specs, p_sh):
    """AdamW state: count replicated; m/v shard like their params."""
    from ..optim.adamw import AdamWState
    return AdamWState(
        count=NamedSharding(mesh, P()),
        m=jax.tree.map(lambda s: s, p_sh),
        v=jax.tree.map(lambda s: s, p_sh))


# ---------------------------------------------------------------------------
# Serve steps


def build_prefill_step(cfg, mesh: Mesh, plan: shapi.Plan):
    def prefill_step(params, batch, caches):
        with shapi.activation_context(mesh, plan):
            return M.prefill(cfg, params, batch, caches)

    return prefill_step


def build_decode_step(cfg, mesh: Mesh, plan: shapi.Plan):
    def decode_step(params, caches, batch):
        with shapi.activation_context(mesh, plan):
            return M.decode_step(cfg, params, caches, batch)

    return decode_step
