"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --smoke --requests 4 --new-tokens 16 [--int8-kv] [--kv-select]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_arch
from ..models import model as M
from ..serve import Engine, Request, select_diverse_blocks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--kv-select", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.family in ("encdec",):
        raise SystemExit("serve CLI demo supports decoder-only archs")

    params, _ = M.init_model(jax.random.key(0), cfg)
    eng = Engine(cfg, params, max_batch=args.requests,
                 max_seq=args.max_seq, quantized_kv=args.int8_kv)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(
                1, cfg.vocab - 1, size=int(rng.integers(8, 32)))
                .astype(np.int32),
                max_new_tokens=args.new_tokens,
                temperature=args.temperature)
            for _ in range(args.requests)]
    t0 = time.time()
    out = eng.generate(reqs)
    dt = time.time() - t0
    total = sum(r.max_new_tokens for r in out)
    print(f"[serve] {cfg.name}: {args.requests} reqs, {total} tokens in "
          f"{dt:.2f}s (incl. compile), int8_kv={args.int8_kv}")
    for i, r in enumerate(out):
        print(f"  req{i}: {r.out_tokens[:12].tolist()}")

    if args.kv_select:
        keys = rng.standard_normal((1024, cfg.head_dim_)) \
            .astype(np.float32)
        mask, stats = select_diverse_blocks(keys, block=64)
        print(f"[kv-select] {stats}")


if __name__ == "__main__":
    main()
