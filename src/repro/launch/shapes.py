"""Assigned input-shape table and per-(arch, shape) input specs.

``input_specs`` builds jax.ShapeDtypeStruct stand-ins (no allocation) for
every model input of a given cell — the dry-run lowers against these.
``make_inputs`` materializes small real arrays for smoke tests.

Modality frontends are STUBS per the assignment: whisper gets precomputed
frame embeddings (B, S, d); qwen2-vl gets patch embeddings (B, Tv, d) and
M-RoPE position ids (B, 3, T).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (DESIGN.md Sec. 5)."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch " \
            "(quadratic); run only for SSM/hybrid per assignment"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _model_dtype(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def batch_specs(cfg, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStructs for the data batch of one cell."""
    b, t = cell.batch, cell.seq
    dt = _model_dtype(cfg)
    i32 = jnp.int32
    fam = cfg.family
    if cell.kind == "train":
        if fam == "vlm":
            tv = min(cfg.vision_tokens, t // 2)
            return {"tokens": _sds((b, t - tv), i32),
                    "vision_embeds": _sds((b, tv, cfg.d_model), dt),
                    "positions": _sds((b, 3, t), i32),
                    "labels": _sds((b, t - tv), i32)}
        if fam == "encdec":
            return {"frames": _sds((b, t, cfg.d_model), dt),
                    "tokens": _sds((b, t), i32),
                    "labels": _sds((b, t), i32)}
        return {"tokens": _sds((b, t), i32), "labels": _sds((b, t), i32)}
    if cell.kind == "prefill":
        if fam == "vlm":
            tv = min(cfg.vision_tokens, t // 2)
            return {"tokens": _sds((b, t - tv), i32),
                    "vision_embeds": _sds((b, tv, cfg.d_model), dt),
                    "positions": _sds((b, 3, t), i32)}
        if fam == "encdec":
            return {"frames": _sds((b, t, cfg.d_model), dt),
                    "tokens": _sds((b, t), i32)}
        return {"tokens": _sds((b, t), i32)}
    # decode
    out = {"tokens": _sds((b, 1), i32)}
    if fam == "vlm":
        out["position"] = _sds((b, 3, 1), i32)
    else:
        out["position"] = _sds((1,), i32)
    if fam == "encdec":
        out["enc_memory"] = _sds((b, t, cfg.d_model), dt)
    return out


def cache_specs(cfg, cell: ShapeCell, quantized_kv: bool = False):
    """ShapeDtypeStructs for decode/prefill caches (via eval_shape)."""
    dt = _model_dtype(cfg)
    return jax.eval_shape(
        lambda: M.make_caches(cfg, cell.batch, cell.seq, dt,
                              quantized_kv=quantized_kv))


def input_specs(cfg, shape_name: str, quantized_kv: bool = False):
    """All lowering inputs for one (arch, shape) cell.

    Returns (step_kind, specs dict) where specs contains 'batch' and
    (for serve kinds) 'caches'.
    """
    cell = SHAPES[shape_name]
    specs = {"batch": batch_specs(cfg, cell)}
    if cell.kind in ("prefill", "decode"):
        specs["caches"] = cache_specs(cfg, cell, quantized_kv)
    return cell.kind, specs


# ---------------------------------------------------------------------------
# Real (small) inputs for smoke tests


def make_inputs(cfg, kind: str, seq: int, batch: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    dt = _model_dtype(cfg)
    fam = cfg.family

    def toks(shape):
        return jnp.asarray(rng.integers(0, cfg.vocab, shape), jnp.int32)

    if kind == "train":
        if fam == "vlm":
            tv = min(cfg.vision_tokens, seq // 2)
            pos = np.broadcast_to(np.arange(seq), (batch, 3, seq)).copy()
            return {"tokens": toks((batch, seq - tv)),
                    "vision_embeds": jnp.asarray(
                        rng.standard_normal((batch, tv, cfg.d_model)), dt),
                    "positions": jnp.asarray(pos, jnp.int32),
                    "labels": toks((batch, seq - tv))}
        if fam == "encdec":
            return {"frames": jnp.asarray(
                        rng.standard_normal((batch, seq, cfg.d_model)), dt),
                    "tokens": toks((batch, seq)),
                    "labels": toks((batch, seq))}
        return {"tokens": toks((batch, seq)), "labels": toks((batch, seq))}
    if kind == "prefill":
        if fam == "vlm":
            tv = min(cfg.vision_tokens, seq // 2)
            pos = np.broadcast_to(np.arange(seq), (batch, 3, seq)).copy()
            return {"tokens": toks((batch, seq - tv)),
                    "vision_embeds": jnp.asarray(
                        rng.standard_normal((batch, tv, cfg.d_model)), dt),
                    "positions": jnp.asarray(pos, jnp.int32)}
        if fam == "encdec":
            return {"frames": jnp.asarray(
                        rng.standard_normal((batch, seq, cfg.d_model)), dt),
                    "tokens": toks((batch, seq))}
        return {"tokens": toks((batch, seq))}
    # decode
    out = {"tokens": toks((batch, 1))}
    if fam == "vlm":
        out["position"] = jnp.full((batch, 3, 1), seq - 1, jnp.int32)
    else:
        out["position"] = jnp.full((1,), seq - 1, jnp.int32)
    if fam == "encdec":
        out["enc_memory"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model)), dt)
    return out
