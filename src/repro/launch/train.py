"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --smoke --steps 20 [--selector dpp] [--monitor] \
        [--mesh host --model-parallel 2]

``--smoke`` uses the reduced config (CPU-runnable). On a real cluster the
same entry point runs the full config on the production mesh; this
container exercises everything except real chips.
"""
from __future__ import annotations

import argparse
from pathlib import Path

import jax

from ..configs import get_arch
from ..data import DataConfig, DPPBatchStream, DPPSelector, TokenStream
from ..models import model as M
from ..optim.adamw import AdamW, warmup_cosine
from ..sharding import api as shapi
from ..train import LoopConfig, make_monitor, train as run_train
from . import mesh as mesh_mod
from . import steps as steps_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--selector", default="uniform",
                    choices=["uniform", "dpp"])
    ap.add_argument("--monitor", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()

    mesh = mesh_mod.make_host_mesh(model=args.model_parallel)
    plan = shapi.tp_plan(data_axes=("data",), model_axis="model",
                         fsdp=args.fsdp)
    opt = AdamW(lr=warmup_cosine(args.lr, max(args.steps // 10, 1),
                                 args.steps))

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch, selector=args.selector)
    stream = TokenStream(dc)
    if args.selector == "dpp":
        stream = DPPBatchStream(stream, DPPSelector(pool_factor=3,
                                                    steps_per_item=2))
    if cfg.family != "dense" and args.selector == "dpp":
        print("note: dpp selector demo stream emits tokens/labels only")

    def init_state():
        params, axes = M.init_model(jax.random.key(0), cfg)
        p_sh = shapi.param_shardings(plan, mesh, params, axes)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = opt.init(params)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"[train] {cfg.name}: {n/1e6:.1f}M params on mesh "
              f"{dict(mesh.shape)}")
        return params, opt_state

    fn = steps_mod.build_train_step(cfg, mesh, plan, opt,
                                    microbatches=args.microbatches)
    # quadlint: disable=QL003 -- jitted once per process in the launcher
    step_fn = jax.jit(fn, donate_argnums=(0, 1))

    def stepper(params, opt_state, batch):
        with mesh:
            return step_fn(params, opt_state, batch)

    monitor = make_monitor(M.loss_fn, cfg, per_example=2,
                           sketch_dim=16) if args.monitor else None
    res = run_train(
        loop_cfg=LoopConfig(total_steps=args.steps,
                            save_every=args.save_every,
                            monitor_every=args.save_every
                            if args.monitor else 0),
        ckpt_dir=Path(args.ckpt_dir) / cfg.name,
        init_state=init_state, step_fn=stepper,
        batch_fn=stream.batch_at, monitor_fn=monitor)
    print(f"[train] done: loss {res.losses[0]:.3f} -> "
          f"{res.losses[-1]:.3f}"
          + (f" (resumed from {res.resumed_from})"
             if res.resumed_from else ""))
    for step, m in res.monitor_log:
        print(f"[monitor@{step}] {m}")


if __name__ == "__main__":
    main()
