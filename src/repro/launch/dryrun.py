import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
production mesh, record memory/cost/collective analysis.

The two lines above MUST run before any jax import (device count locks at
first init), which is why this module must never be imported by anything
except the CLI entry point:

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md Sec. Dry-run / Sec. Roofline.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import get_arch, list_archs
from ..optim.adamw import AdamW
from ..sharding import api as shapi
from ..utils import hlo as hlo_utils
from . import mesh as mesh_mod
from . import shapes as shapes_mod
from . import steps as steps_mod
from ..models import model as M

# TPU v5e constants (assignment)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link


def _plan_for(cfg, *, seq_shard=False, fsdp=None, embed_shard=False,
              tp_full=False):
    if fsdp is None:
        fsdp = cfg.param_count() > 8e9
    return shapi.tp_plan(data_axes=("pod", "data"), model_axis="model",
                         fsdp=fsdp, seq_shard=seq_shard,
                         embed_shard=embed_shard, tp_full=tp_full)


def _mesh(kind: str):
    if kind == "multi":
        return mesh_mod.make_production_mesh(multi_pod=True)
    m = mesh_mod.make_production_mesh(multi_pod=False)
    return m


def _single_pod_plan_axes(mesh, plan):
    """On the single-pod mesh there is no 'pod' axis; strip it."""
    names = set(mesh.axis_names)

    def fix(v):
        if isinstance(v, tuple):
            t = tuple(a for a in v if a in names)
            return t if t else None
        return v if v in names else None

    rules = {k: fix(v) for k, v in plan.rules.items()}
    return shapi.Plan(rules=rules, fsdp=plan.fsdp,
                      fsdp_axis=plan.fsdp_axis,
                      fsdp_min_size=plan.fsdp_min_size)


def _lower_and_compile(cfg, shape_name: str, mesh, plan, *,
                       microbatches: int = 1, quantized_kv: bool = False):
    """AOT lower + compile one cell; returns (compiled, kind, timings)."""
    t0 = time.time()
    kind, specs = shapes_mod.input_specs(cfg, shape_name,
                                         quantized_kv=quantized_kv)
    params_specs = jax.eval_shape(lambda: M.init_model(jax.random.key(0),
                                                       cfg)[0])
    axes = _axes_only(cfg)
    p_sh = shapi.param_shardings(plan, mesh, params_specs, axes)

    if kind == "train":
        opt = steps_mod.default_optimizer()
        opt_specs = jax.eval_shape(opt.init, params_specs)
        o_sh = steps_mod._opt_shardings(mesh, plan, axes, opt_specs, p_sh)
        b_sh = steps_mod.batch_sharding(mesh, plan, specs["batch"])
        fn = steps_mod.build_train_step(cfg, mesh, plan, opt,
                                        microbatches=microbatches)
        # quadlint: disable=QL003 -- one-shot AOT lowering in a launcher
        jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                      out_shardings=(p_sh, o_sh, None),
                      donate_argnums=(0, 1))
        with mesh:
            lowered = jfn.lower(params_specs, opt_specs, specs["batch"])
    elif kind == "prefill":
        c_sh = steps_mod.cache_sharding(cfg, mesh, plan, specs["caches"])
        b_sh = steps_mod.batch_sharding(mesh, plan, specs["batch"])
        fn = steps_mod.build_prefill_step(cfg, mesh, plan)
        # quadlint: disable=QL003 -- one-shot AOT lowering in a launcher
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh),
                      out_shardings=(c_sh, None),
                      donate_argnums=(2,))
        with mesh:
            lowered = jfn.lower(params_specs, specs["batch"],
                                specs["caches"])
    else:  # decode
        c_sh = steps_mod.cache_sharding(cfg, mesh, plan, specs["caches"])
        b_sh = steps_mod.batch_sharding(mesh, plan, specs["batch"])
        fn = steps_mod.build_decode_step(cfg, mesh, plan)
        # quadlint: disable=QL003 -- one-shot AOT lowering in a launcher
        jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh),
                      out_shardings=(c_sh, None),
                      donate_argnums=(1,))
        with mesh:
            lowered = jfn.lower(params_specs, specs["caches"],
                                specs["batch"])
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    return compiled, kind, (t_lower, t_compile)


def _measure(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jaxlib: one dict per device
        cost = cost[0] if cost else {}
    coll = hlo_utils.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll.get("total", 0.0)),
            "coll_detail": {k: v for k, v in coll.items()
                            if k not in ("total",)}}


def _delta_cfgs(cfg):
    """Small unrolled configs for the per-unit cost delta.

    Returns (cfg2, cfg4, u2, u4, u_full). XLA's cost analysis counts
    while-loop bodies once, so scanned stacks undercount by ~depth; the
    unrolled 2-unit/4-unit lowers give exact per-unit costs:
        X_true(L) = X(2u) + (U - 2) * (X(4u) - X(2u)) / 2.
    Hybrid tails are folded in as fractional units (slight attn
    overcount on the tail, noted in EXPERIMENTS.md).
    """
    import dataclasses as dc
    fam = cfg.family
    if fam == "encdec":
        c2 = dc.replace(cfg, n_layers=2, enc_layers=2, scan_unroll=True)
        c4 = dc.replace(cfg, n_layers=4, enc_layers=4, scan_unroll=True)
        return c2, c4, 2, 4, float(cfg.n_layers)
    unit = {"moe": cfg.moe_every,
            "hybrid": cfg.hybrid_attn_every}.get(fam, 1) or 1
    c2 = dc.replace(cfg, n_layers=2 * unit, scan_unroll=True)
    c4 = dc.replace(cfg, n_layers=4 * unit, scan_unroll=True)
    return c2, c4, 2, 4, cfg.n_layers / unit


def _apply_overrides(cfg, overrides):
    if not overrides:
        return cfg
    import dataclasses as dc
    kw = {}
    for item in overrides:
        k, v = item.split("=", 1)
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            v = v.lower() in ("1", "true", "on")
        elif isinstance(cur, int):
            v = int(v)
        elif isinstance(cur, float):
            v = float(v)
        kw[k] = v
    return dc.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             out_dir: Path, microbatches: int = 1, seq_shard: bool = False,
             fsdp=None, embed_shard: bool = False, tp_full: bool = False,
             quantized_kv: bool = False, skip_delta: bool = False,
             overrides=None, tag: str = "") -> dict:
    cfg = _apply_overrides(get_arch(arch), overrides)
    ok, why = shapes_mod.cell_applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "tag": tag, "status": "skipped", "reason": why}
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    if not ok:
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] SKIP {arch} {shape_name} {mesh_kind}: {why}",
              flush=True)
        return rec

    mesh = _mesh(mesh_kind)
    plan = _plan_for(cfg, seq_shard=seq_shard, fsdp=fsdp,
                     embed_shard=embed_shard, tp_full=tp_full)
    plan = _single_pod_plan_axes(mesh, plan)
    use_fsdp = plan.fsdp

    try:
        # 1) full scanned compile: THE compile-proof + memory analysis
        compiled, kind, (t_lower, t_compile) = _lower_and_compile(
            cfg, shape_name, mesh, plan, microbatches=microbatches,
            quantized_kv=quantized_kv)
        mem = compiled.memory_analysis()
        raw = _measure(compiled)

        # 2) delta analysis on small unrolled configs (exact loop costs)
        if skip_delta:
            corrected = dict(raw)
            u2 = u4 = u_full = None
        else:
            c2, c4, u2, u4, u_full = _delta_cfgs(cfg)
            comp2, _, _ = _lower_and_compile(
                c2, shape_name, mesh, plan, microbatches=microbatches,
                quantized_kv=quantized_kv)
            m2 = _measure(comp2)
            del comp2
            comp4, _, _ = _lower_and_compile(
                c4, shape_name, mesh, plan, microbatches=microbatches,
                quantized_kv=quantized_kv)
            m4 = _measure(comp4)
            del comp4
            corrected = {}
            for k in ("flops", "bytes", "coll"):
                per_unit = (m4[k] - m2[k]) / (u4 - u2)
                corrected[k] = m2[k] + (u_full - u2) * per_unit
            corrected["per_unit"] = {
                k: (m4[k] - m2[k]) / (u4 - u2)
                for k in ("flops", "bytes", "coll")}
            corrected["base_2u"] = {k: m2[k]
                                    for k in ("flops", "bytes", "coll")}
    except Exception as e:  # noqa: BLE001 — failures are data here
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] FAIL {arch} {shape_name} {mesh_kind}: {e}",
              flush=True)
        return rec

    n_chips = 1
    for s in mesh.shape.values():
        n_chips *= s

    mf = _model_flops(cfg, shape_name)
    roof = {
        "compute_s": corrected["flops"] / PEAK_FLOPS,
        "memory_s": corrected["bytes"] / HBM_BW,
        "collective_s": corrected["coll"] / ICI_BW,
    }
    model_flops_per_chip = mf["model_flops"] / n_chips
    rec.update(
        status="ok",
        n_chips=n_chips,
        fsdp=use_fsdp,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={k: int(getattr(mem, k, 0)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes")},
        cost_raw=raw,
        cost_corrected=corrected,
        roofline=roof,
        model_flops_info=mf,
        useful_flops_ratio=(model_flops_per_chip
                            / max(corrected["flops"], 1.0)),
        bound_step_time_s=max(roof.values()),
        roofline_fraction=(model_flops_per_chip / PEAK_FLOPS)
        / max(max(roof.values()), 1e-30),
    )
    rec["dominant"] = max(roof, key=roof.get)
    out_path.write_text(json.dumps(rec, indent=2))
    print(f"[dryrun] OK {arch} {shape_name} {mesh_kind}{tag} "
          f"chips={n_chips} compile={t_compile:.1f}s "
          f"dominant={rec['dominant']} "
          f"compute={roof['compute_s']:.4f}s "
          f"memory={roof['memory_s']:.4f}s "
          f"coll={roof['collective_s']:.4f}s "
          f"roofline_frac={rec['roofline_fraction']:.3f}", flush=True)
    return rec


def _axes_only(cfg):
    """Axes tree without materializing params (init under eval_shape)."""
    out = {}

    def capture():
        nonlocal out
        p, a = M.init_model(jax.random.key(0), cfg)
        out = a
        return p

    jax.eval_shape(capture)
    return out


def _model_flops(cfg, shape_name: str) -> dict:
    cell = shapes_mod.SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        d_tokens = cell.seq * cell.batch
        mf = 6.0 * n_active * d_tokens
    elif cell.kind == "prefill":
        d_tokens = cell.seq * cell.batch
        mf = 2.0 * n_active * d_tokens
    else:
        d_tokens = cell.batch          # one token per sequence
        mf = 2.0 * n_active * d_tokens
    return {"model_flops": mf, "tokens": d_tokens,
            "active_params": n_active}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--fsdp", default=None,
                    choices=[None, "on", "off"])
    ap.add_argument("--quantized-kv", action="store_true")
    ap.add_argument("--embed-shard", action="store_true")
    ap.add_argument("--tp-full", action="store_true")
    ap.add_argument("--skip-delta", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override, e.g. --override remat=none "
                         "--override ssm_impl=chunked")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shps = list(shapes_mod.SHAPES) if args.all or not args.shape \
        else [args.shape]
    fsdp = None if args.fsdp is None else (args.fsdp == "on")

    failures = 0
    for mk in meshes:
        for a in archs:
            for s in shps:
                rec = run_cell(a, s, mk, out_dir=out_dir,
                               microbatches=args.microbatches,
                               seq_shard=args.seq_shard, fsdp=fsdp,
                               embed_shard=args.embed_shard,
                               tp_full=args.tp_full,
                               quantized_kv=args.quantized_kv,
                               skip_delta=args.skip_delta,
                               overrides=args.override,
                               tag=args.tag)
                failures += rec["status"] == "error"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
