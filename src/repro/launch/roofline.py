"""Roofline report generator: reads experiments/dryrun/*.json and emits
the EXPERIMENTS.md Sec. Roofline table + per-cell analysis.

    PYTHONPATH=src python -m repro.launch.roofline \
        [--dir experiments/dryrun] [--mesh single] [--markdown]

Also computes the analytic TPU-projected memory floor (params + optimizer
+ caches + checkpointed activations) as a supplement: the HLO-derived
bytes term is an upper bound because the CPU-lowered module materializes
intermediates a TPU backend would fuse (noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from ..configs import get_arch
from .shapes import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analytic_memory_bytes(arch: str, shape: str, n_chips: int,
                          fsdp: bool) -> float:
    """Lower-bound HBM traffic per device per step (fusion-ideal TPU)."""
    cfg = get_arch(arch)
    cell = SHAPES[shape]
    p = cfg.param_count()
    p_active = cfg.active_param_count()
    d = cfg.d_model
    if cell.kind == "train":
        tokens = cell.seq * cell.batch / n_chips * 16  # model-shard share
        # params bf16 read (fwd+bwd) + fp32 m/v read+write + grads
        param_bytes = p / n_chips * (2 * 2 + 4 * 4 + 4)
        # remat(block): block inputs stored+read + recompute reads
        act_bytes = cfg.n_layers * tokens * d * 2 * 4
        return param_bytes + act_bytes
    if cell.kind == "prefill":
        tokens = cell.seq * cell.batch / n_chips * 16
        param_bytes = p_active / n_chips * 2
        act_bytes = cfg.n_layers * tokens * d * 2 * 2
        kv_bytes = (cfg.n_layers * cell.seq * cell.batch
                    * cfg.n_kv_heads * cfg.head_dim_ * 2 * 2) / n_chips
        return param_bytes + act_bytes + kv_bytes
    # decode: whole model + whole KV read once per token
    param_bytes = p_active / n_chips * 2
    kv_bytes = (cfg.n_layers * cell.seq * cell.batch * cfg.n_kv_heads
                * cfg.head_dim_ * 2 * 2) / n_chips
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm_expand * cfg.d_model
        kv_bytes = cfg.n_layers * cell.batch * di * cfg.ssm_state * 4 \
            / n_chips
    return param_bytes + kv_bytes


def load_records(dir_: Path, mesh: str, tag: str = ""):
    recs = []
    for p in sorted(dir_.glob(f"*__{mesh}{tag}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_table(recs, markdown: bool = True):
    lines = []
    hdr = ("| arch | shape | compute_s | memory_s | coll_s | dominant | "
           "MODEL_FLOPS/chip | useful ratio | roofline frac | HBM GB/chip |")
    sep = "|" + "---|" * 10
    lines.append(hdr)
    lines.append(sep)
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | — | — |")
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                         f"{r.get('error','?')[:60]} |" + " — |" * 7)
            continue
        roof = r["roofline"]
        mem_gb = (r["memory"]["argument_size_in_bytes"]
                  + r["memory"]["temp_size_in_bytes"]) / 1e9
        mf = r["model_flops_info"]["model_flops"] / r["n_chips"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {roof['compute_s']:.4f} | "
            f"{roof['memory_s']:.4f} | {roof['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s','')} | {mf:.3e} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {mem_gb:.1f} |")
    return "\n".join(lines)


def bottleneck_note(r) -> str:
    if r["status"] != "ok":
        return ""
    dom = r["dominant"]
    arch, shape = r["arch"], r["shape"]
    if dom == "memory_s":
        return (f"{arch}/{shape}: memory-bound — cut HLO bytes via bf16 "
                "intermediates, fewer f32 upcasts, larger fusion regions "
                "(remat policy), or (decode) int8 KV.")
    if dom == "collective_s":
        return (f"{arch}/{shape}: collective-bound — reshape the KV/"
                "activation sharding to avoid resharding copies, overlap "
                "DP reduce with compute, or compress gradients (int8 EF).")
    return (f"{arch}/{shape}: compute-bound — already near the MXU "
            "ceiling; improve useful-flops ratio (less remat recompute).")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load_records(Path(args.dir), args.mesh, args.tag)
    print(fmt_table(recs))
    print()
    for r in recs:
        n = bottleneck_note(r)
        if n:
            print("  *", n)


if __name__ == "__main__":
    main()
