from .engine import BIFEngine, BIFRequest, Engine, Request  # noqa: F401
from .kv_select import rank_blocks, select_diverse_blocks  # noqa: F401
