from .engine import BIFEngine, BIFRequest, Engine, Request, \
    flush_trace_count  # noqa: F401
from .kv_select import rank_blocks, select_diverse_blocks  # noqa: F401
