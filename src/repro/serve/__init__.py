from .engine import Engine, Request  # noqa: F401
from .kv_select import select_diverse_blocks  # noqa: F401
