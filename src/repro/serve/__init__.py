from .engine import BIFEngine, BIFRequest, Engine, Request, \
    flush_trace_count  # noqa: F401
from .kv_select import BlockRanker, apply_block_mask, pool_keys, \
    rank_blocks, select_diverse_blocks  # noqa: F401
