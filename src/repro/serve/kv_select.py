"""Log-det KV diversification — paper tie-in #3 (DESIGN.md Sec. 4.3).

Long-context decode keeps a KV budget per layer. To choose WHICH entries
to keep, we run the paper's retrospective double greedy (Alg. 8/9) on
F(S) = log det(K_S) over a key-similarity kernel: the kept subset is
provably within 1/2 of the max-diversity subset, and every keep/evict
decision is certified by Gauss-Radau brackets rather than exact solves.

This operates on pooled key blocks (block-mean keys), so the ground set
stays ~hundreds even for 500k contexts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import double_greedy as dg
from ..core import operators as core_ops
from ..core import spectrum as core_spectrum
from ..core.solver import BIFSolver, SolverConfig
from .engine import BIFEngine, BIFRequest


def pool_keys(keys: np.ndarray, block: int = 128) -> np.ndarray:
    """(S, D) keys -> (ceil(S/block), D) block-mean summaries, L2-normalized.

    The trailing partial block (``S % block`` keys) pools into a final
    partial-block summary — the mean over the keys it actually holds —
    instead of being silently dropped (it used to be truncated away, so
    up to ``block - 1`` tail keys were never scored and
    :func:`apply_block_mask` padded them as always-kept)."""
    s, d = keys.shape
    n = -(-s // block)
    pad = n * block - s
    padded = np.concatenate([keys, np.zeros((pad, d), keys.dtype)]) \
        if pad else keys
    counts = np.minimum(block, s - np.arange(n) * block)
    pooled = padded.reshape(n, block, d).sum(1) / counts[:, None]
    return pooled / (np.linalg.norm(pooled, axis=-1, keepdims=True) + 1e-8)


def _rbf_kernel(pooled: np.ndarray, ridge: float,
                bandwidth: float) -> np.ndarray:
    """RBF similarity kernel over block summaries, ridge-regularized
    (shared by the one-shot rankers and the streaming BlockRanker so
    their systems are bit-identical)."""
    n = len(pooled)
    d2 = ((pooled[:, None, :] - pooled[None, :, :]) ** 2).sum(-1)
    return np.exp(-d2 / (2 * bandwidth ** 2)) + ridge * np.eye(n)


def select_diverse_blocks(keys: np.ndarray, *, block: int = 128,
                          ridge: float = 1e-3, bandwidth: float = 0.5,
                          seed: int = 0,
                          solver_config: SolverConfig | None = None):
    """Returns (block_mask, stats): which key blocks to keep.

    The retrospective double greedy maximizes log det of the RBF kernel
    over block summaries; `stats.quad_iterations` shows the certified
    early-stopping at work. ``solver_config`` tunes the quadrature engine
    (e.g. ``SolverConfig(max_iters=32, backend='pallas')`` on TPU serving
    paths); the default matches the exhaustive-certainty setting.
    """
    pooled = pool_keys(keys, block)
    n = len(pooled)
    kmat = _rbf_kernel(pooled, ridge, bandwidth)
    op = core_ops.Dense(jnp.asarray(kmat, jnp.float32))
    if solver_config is None:
        solver_config = SolverConfig(max_iters=n + 2)
    res = dg.double_greedy(op, jax.random.key(seed), ridge * 0.5,
                           float(n) + 1.0, max_iters=solver_config.max_iters,
                           solver=BIFSolver(solver_config))
    mask = np.asarray(res.selected) > 0.5
    return mask, {"quad_iterations": int(res.quad_iterations),
                  "uncertified": int(res.uncertified),
                  "log_det": float(res.log_det),
                  "kept": int(mask.sum()), "blocks": n}


def rank_blocks(keys: np.ndarray, *, block: int = 128, ridge: float = 1e-3,
                bandwidth: float = 0.5, max_batch: int = 32,
                bucket: int = 32, mesh=None,
                solver_config: SolverConfig | None = None,
                coarse_iters: int | None = None):
    """Certified redundancy ranking of pooled key blocks, served batched.

    Block i's score is the leverage-style bilinear form
    ``k_i[-i]^T K_{-i}^-1 k_i[-i]``: its kernel column against the
    system with block i itself *excluded* (via the request mask) — high
    means block i is well explained by the others (safe to evict first).
    Excluding i matters: against the full K the form collapses to
    ``K_ii = 1 + ridge`` identically for every block. All N candidate
    BIFs go through a :class:`BIFEngine` lane pool: one continuous-
    batching scheduler instead of N sequential solves.

    ``coarse_iters`` turns on the two-phase warm-started ranking of
    DESIGN.md Sec. 8.3: phase 1 brackets every block under a small
    per-request iteration budget; only blocks whose bracket still
    overlaps another block's (rank-ambiguous) are resubmitted — carrying
    their banked :class:`~repro.core.solver.QuadState` — and resume
    where they stopped instead of re-solving from scratch. Blocks whose
    coarse bracket already separates keep their cheap answer.

    The kernel's system size is padded to a multiple of ``bucket``
    (identity rows, masked out of every request), so nearby block counts
    land on one flush-driver shape: the engine's shared jitted drivers
    then reuse a single compile across calls whose ``n`` falls in the
    same bucket instead of tracing afresh per block count (pinned in
    tests via ``serve.engine.flush_trace_count``). ``mesh`` routes the
    pool steps through the device-sharded driver (DESIGN.md Sec. 7).

    Returns ``(order, stats)`` with ``order`` the block indices most-
    redundant first and per-block certified brackets in ``stats``.
    """
    pooled = pool_keys(keys, block)
    n = len(pooled)
    n_pad = -(-n // bucket) * bucket
    kmat = _rbf_kernel(pooled, ridge, bandwidth)
    kfull = np.eye(n_pad, dtype=np.float32)
    kfull[:n, :n] = kmat
    op = core_ops.Dense(jnp.asarray(kfull))
    if solver_config is None:
        # ceiling derived from the BUCKETED size so every call in the
        # bucket shares one (static) solver config
        solver_config = SolverConfig(max_iters=min(n_pad + 2, 64),
                                     rtol=1e-3)
    engine = BIFEngine(op, solver=BIFSolver(solver_config),
                       max_batch=max_batch, mesh=mesh)
    base_mask = np.zeros(n_pad, dtype=np.float32)
    base_mask[:n] = 1.0
    reqs = []
    for i in range(n):
        mask = base_mask.copy()
        mask[i] = 0.0
        u = np.zeros(n_pad, dtype=np.float32)
        u[:n] = kmat[:, i]
        reqs.append(engine.submit(BIFRequest(u=u, mask=mask,
                                             max_iters=coarse_iters)))
    engine.flush()
    flushes = 1
    refined = 0
    if coarse_iters is not None:
        los = np.array([r.lower for r in reqs])
        his = np.array([r.upper for r in reqs])
        for i, r in enumerate(reqs):
            if r.resolved:
                continue  # already at the solver's tolerance
            # rank-ambiguous: bracket overlaps some other block's
            others = np.arange(n) != i
            if np.any((los[others] < his[i]) & (los[i] < his[others])):
                r.max_iters = None  # full budget; resumes banked state
                engine.submit(r)
                refined += 1
        if refined:
            engine.flush()
            flushes += 1
    mids = np.array([0.5 * (r.lower + r.upper) for r in reqs])
    order = np.argsort(-mids)
    return order, {
        "brackets": [(r.lower, r.upper) for r in reqs],
        "iterations": int(sum(r.iterations for r in reqs)),
        "certified": int(sum(r.certified for r in reqs)),
        "resolved": int(sum(bool(r.resolved) for r in reqs)),
        "refined": refined,
        # scheduler passes over the lane pool (the continuous engine has
        # no per-max_batch chunks; each flush call is one scheduler run)
        "flushes": flushes, "blocks": n}


class BlockRanker:
    """Streaming certified redundancy ranking of a GROWING KV cache.

    :func:`rank_blocks` re-solves all N blocks from scratch on every
    call; during decode the cache grows by one block at a time, so that
    rebuilds the engine and re-pays N solves to re-rank a ground set
    that changed by one item. ``BlockRanker`` instead maintains the
    padded kernel operator and one :class:`BIFEngine` across cache
    growth:

      * ``extend(keys)`` appends raw keys; ``rank()`` re-pools, grows
        the kernel, and — as long as the padded system size stays inside
        the current ``bucket`` — swaps the new operator into the LIVE
        engine in place (the engine's jitted flush drivers read
        ``engine.op`` at call time, so the swap reuses the existing
        compile; pinned via ``flush_trace_count``). Only a bucket
        overflow rebuilds the engine.
      * each ``rank()`` re-solves only the *changed* blocks (new blocks,
        plus a trailing partial block whose summary absorbed new keys)
        and the *rank-ambiguous* neighbors — previously-scored blocks
        whose banked bracket overlaps a changed block's fresh bracket,
        so their relative order is genuinely in doubt. Everything else
        keeps its banked bracket: no resubmission, no iterations.
      * within a ``rank()``, re-solves run the two-phase warm-started
        schedule of :func:`rank_blocks` when ``coarse_iters`` is set:
        coarse brackets first, then only still-ambiguous unresolved
        blocks resubmit carrying their banked
        :class:`~repro.core.solver.QuadState` (PR 4) and resume where
        they stopped.

    The streaming tradeoff, documented here because it is the point:
    a kept (non-resubmitted) block's banked score was computed against
    the SMALLER ground set. Leverage scores are non-DEcreasing as the
    cache grows (more blocks explain you at least as well — the Schur-
    complement monotonicity of DESIGN.md Sec. 12 read in reverse), so
    banked brackets stay valid LOWER bounds but their uppers can go
    stale. ``rank()`` treats bracket overlap against the freshly-solved
    blocks as the re-solve trigger; well-separated stale blocks keep
    their cheap answer. Callers who need every bracket current for the
    full ground set should call :func:`rank_blocks`.

    ``rank()`` returns ``(order, info)`` like :func:`rank_blocks`;
    ``info`` additionally reports ``solved`` (fresh re-solves),
    ``reused`` (banked brackets kept) and per-call ``iterations`` /
    ``flushes``. ``self.stats`` accumulates across calls.
    """

    def __init__(self, *, block: int = 128, ridge: float = 1e-3,
                 bandwidth: float = 0.5, max_batch: int = 32,
                 bucket: int = 32, mesh=None,
                 solver_config: SolverConfig | None = None,
                 coarse_iters: int | None = None):
        self.block = int(block)
        self.ridge = float(ridge)
        self.bandwidth = float(bandwidth)
        self.max_batch = int(max_batch)
        self.bucket = int(bucket)
        self.mesh = mesh
        self.solver_config = solver_config
        self.coarse_iters = coarse_iters
        self._keys: np.ndarray | None = None   # raw (S, D) key buffer
        self._kmat: np.ndarray | None = None
        self._engine: BIFEngine | None = None
        self._n_pad = 0
        # per-block banked results from the last rank(): parallel lists
        self._reqs: list[BIFRequest] = []
        self._sizes: np.ndarray = np.zeros(0, np.int64)  # keys per block
        self.stats = {"iterations": 0, "flushes": 0, "solved": 0,
                      "refined": 0, "reused": 0, "engine_builds": 0}

    def extend(self, keys: np.ndarray) -> "BlockRanker":
        """Append raw keys (the cache grew); returns self for chaining."""
        keys = np.asarray(keys)
        if keys.ndim != 2:
            raise ValueError(f"keys must be (S, D), got {keys.shape}")
        self._keys = keys if self._keys is None \
            else np.concatenate([self._keys, keys])
        return self

    # -- internals ---------------------------------------------------------

    def _sync_engine(self, n: int) -> None:
        """Point the live engine at the grown kernel — in place when the
        padded size stays inside the current bucket."""
        n_pad = -(-n // self.bucket) * self.bucket
        kfull = np.eye(n_pad, dtype=np.float32)
        kfull[:n, :n] = self._kmat
        op = core_ops.Dense(jnp.asarray(kfull))
        if self._engine is not None and self._n_pad == n_pad:
            # in-place operator swap: the flush drivers read engine.op /
            # engine.lam_* at call time, so the existing compile is
            # reused (no new trace for same-bucket growth). Refresh the
            # spectrum interval with the SAME estimator the engine ctor
            # uses, so streaming brackets stay bit-identical to a cold
            # rank_blocks on the grown cache.
            est = core_spectrum.gershgorin_bounds_spd(op)
            self._engine.op = op
            self._engine.lam_min = float(est.lam_min)
            self._engine.lam_max = float(est.lam_max)
            return
        cfg = self.solver_config
        if cfg is None:
            cfg = SolverConfig(max_iters=min(n_pad + 2, 64), rtol=1e-3)
        self._engine = BIFEngine(op, solver=BIFSolver(cfg),
                                 max_batch=self.max_batch, mesh=self.mesh)
        self._n_pad = n_pad
        self.stats["engine_builds"] += 1

    def _fresh_request(self, i: int, n: int,
                       max_iters: int | None) -> BIFRequest:
        """Block i's leverage query against the CURRENT ground set."""
        mask = np.zeros(self._n_pad, dtype=np.float32)
        mask[:n] = 1.0
        mask[i] = 0.0
        u = np.zeros(self._n_pad, dtype=np.float32)
        u[:n] = self._kmat[:, i]
        return BIFRequest(u=u, mask=mask, max_iters=max_iters)

    # -- the streaming rank ------------------------------------------------

    def rank(self):
        """Re-rank the current cache; returns ``(order, info)``."""
        if self._keys is None or len(self._keys) == 0:
            raise ValueError("no keys: call extend() first")
        pooled = pool_keys(self._keys, self.block)
        n = len(pooled)
        self._kmat = _rbf_kernel(pooled, self.ridge, self.bandwidth)
        self._sync_engine(n)
        eng = self._engine

        # changed blocks must re-solve: brand-new ones, plus a partial
        # tail block whose summary absorbed fresh keys (keys are append-
        # only, so same key-count == same contents)
        sizes = np.minimum(self.block,
                           len(self._keys) - np.arange(n) * self.block)
        n_old = len(self._sizes)
        changed = [i for i in range(n)
                   if i >= n_old or sizes[i] != self._sizes[i]]
        self._sizes = sizes
        self._reqs = self._reqs[:n] + [None] * (n - len(self._reqs))

        # phase 1: fresh solves for the changed blocks (new ground set ->
        # new (u, mask) -> banked states don't transfer; submit() clears
        # the stale results)
        for i in changed:
            self._reqs[i] = eng.submit(
                self._fresh_request(i, n, self.coarse_iters))
        flushes = 0
        if changed:
            eng.flush()
            flushes += 1

        # phase 2: previously-scored blocks whose banked bracket overlaps
        # a changed block's fresh bracket are rank-ambiguous — their
        # order against the newcomers is in doubt — and re-solve against
        # the grown ground set. Others keep their banked (valid-lower,
        # possibly stale-upper) bracket: the streaming tradeoff.
        chg = set(changed)
        if chg and len(chg) < n:
            clo = np.array([self._reqs[i].lower for i in changed])
            chi = np.array([self._reqs[i].upper for i in changed])
            ambiguous = [
                i for i in range(n) if i not in chg
                and np.any((clo < self._reqs[i].upper)
                           & (self._reqs[i].lower < chi))]
            for i in ambiguous:
                self._reqs[i] = eng.submit(
                    self._fresh_request(i, n, self.coarse_iters))
            if ambiguous:
                eng.flush()
                flushes += 1
            solved = changed + ambiguous
        else:
            solved = changed

        # phase 3: two-phase refinement inside this call — unresolved
        # coarse solves that still overlap each other resume their
        # banked QuadState under the full budget (rank_blocks' schedule)
        refined = 0
        if self.coarse_iters is not None and solved:
            los = np.array([r.lower for r in self._reqs])
            his = np.array([r.upper for r in self._reqs])
            for i in solved:
                r = self._reqs[i]
                if r.resolved:
                    continue
                others = np.arange(n) != i
                if np.any((los[others] < his[i]) & (los[i] < his[others])):
                    r.max_iters = None  # full budget; resumes banked state
                    eng.submit(r)
                    refined += 1
            if refined:
                eng.flush()
                flushes += 1

        mids = np.array([0.5 * (r.lower + r.upper) for r in self._reqs])
        order = np.argsort(-mids)
        info = {
            "blocks": n,
            "solved": len(solved),
            "refined": refined,
            "reused": n - len(solved),
            "flushes": flushes,
            # every re-solved block started from scratch THIS call and
            # in-call refinement accumulates through its banked state,
            # so the final counters of the solved set are the call cost;
            # reused blocks cost zero
            "iterations": int(sum(int(self._reqs[i].iterations or 0)
                                  for i in solved)),
            "brackets": [(r.lower, r.upper) for r in self._reqs],
        }
        for k in ("iterations", "flushes", "solved", "refined", "reused"):
            self.stats[k] += info[k]
        return order, info


def apply_block_mask(cache_k: jax.Array, cache_v: jax.Array,
                     mask: np.ndarray, block: int = 128):
    """Zero out evicted blocks (a real engine would compact; zeroing keeps
    shapes static and attention ignores evicted keys via -inf scores when
    combined with the validity mask)."""
    s = cache_k.shape[1]
    # ceil-block masks (pool_keys) cover the tail: the last (partial)
    # block's decision applies to its actual keys, so slice the repeat
    # down to the cache length. A short mask (legacy truncating pooling)
    # still pads its uncovered tail as kept.
    full = np.repeat(mask, block)
    if len(full) < s:
        full = np.pad(full, (0, s - len(full)), constant_values=True)
    m = jnp.asarray(full[:s], cache_k.dtype)[None, :, None, None]
    return cache_k * m, cache_v * m
