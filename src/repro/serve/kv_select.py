"""Log-det KV diversification — paper tie-in #3 (DESIGN.md Sec. 4.3).

Long-context decode keeps a KV budget per layer. To choose WHICH entries
to keep, we run the paper's retrospective double greedy (Alg. 8/9) on
F(S) = log det(K_S) over a key-similarity kernel: the kept subset is
provably within 1/2 of the max-diversity subset, and every keep/evict
decision is certified by Gauss-Radau brackets rather than exact solves.

This operates on pooled key blocks (block-mean keys), so the ground set
stays ~hundreds even for 500k contexts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import double_greedy as dg
from ..core import operators as core_ops
from ..core.solver import BIFSolver, SolverConfig
from .engine import BIFEngine, BIFRequest


def pool_keys(keys: np.ndarray, block: int = 128) -> np.ndarray:
    """(S, D) keys -> (S/block, D) block-mean summaries, L2-normalized."""
    s, d = keys.shape
    n = s // block
    pooled = keys[:n * block].reshape(n, block, d).mean(1)
    return pooled / (np.linalg.norm(pooled, axis=-1, keepdims=True) + 1e-8)


def select_diverse_blocks(keys: np.ndarray, *, block: int = 128,
                          ridge: float = 1e-3, bandwidth: float = 0.5,
                          seed: int = 0,
                          solver_config: SolverConfig | None = None):
    """Returns (block_mask, stats): which key blocks to keep.

    The retrospective double greedy maximizes log det of the RBF kernel
    over block summaries; `stats.quad_iterations` shows the certified
    early-stopping at work. ``solver_config`` tunes the quadrature engine
    (e.g. ``SolverConfig(max_iters=32, backend='pallas')`` on TPU serving
    paths); the default matches the exhaustive-certainty setting.
    """
    pooled = pool_keys(keys, block)
    n = len(pooled)
    d2 = ((pooled[:, None, :] - pooled[None, :, :]) ** 2).sum(-1)
    kmat = np.exp(-d2 / (2 * bandwidth ** 2)) + ridge * np.eye(n)
    op = core_ops.Dense(jnp.asarray(kmat, jnp.float32))
    if solver_config is None:
        solver_config = SolverConfig(max_iters=n + 2)
    res = dg.double_greedy(op, jax.random.key(seed), ridge * 0.5,
                           float(n) + 1.0, max_iters=solver_config.max_iters,
                           solver=BIFSolver(solver_config))
    mask = np.asarray(res.selected) > 0.5
    return mask, {"quad_iterations": int(res.quad_iterations),
                  "uncertified": int(res.uncertified),
                  "log_det": float(res.log_det),
                  "kept": int(mask.sum()), "blocks": n}


def rank_blocks(keys: np.ndarray, *, block: int = 128, ridge: float = 1e-3,
                bandwidth: float = 0.5, max_batch: int = 32,
                bucket: int = 32, mesh=None,
                solver_config: SolverConfig | None = None,
                coarse_iters: int | None = None):
    """Certified redundancy ranking of pooled key blocks, served batched.

    Block i's score is the leverage-style bilinear form
    ``k_i[-i]^T K_{-i}^-1 k_i[-i]``: its kernel column against the
    system with block i itself *excluded* (via the request mask) — high
    means block i is well explained by the others (safe to evict first).
    Excluding i matters: against the full K the form collapses to
    ``K_ii = 1 + ridge`` identically for every block. All N candidate
    BIFs go through a :class:`BIFEngine` lane pool: one continuous-
    batching scheduler instead of N sequential solves.

    ``coarse_iters`` turns on the two-phase warm-started ranking of
    DESIGN.md Sec. 8.3: phase 1 brackets every block under a small
    per-request iteration budget; only blocks whose bracket still
    overlaps another block's (rank-ambiguous) are resubmitted — carrying
    their banked :class:`~repro.core.solver.QuadState` — and resume
    where they stopped instead of re-solving from scratch. Blocks whose
    coarse bracket already separates keep their cheap answer.

    The kernel's system size is padded to a multiple of ``bucket``
    (identity rows, masked out of every request), so nearby block counts
    land on one flush-driver shape: the engine's shared jitted drivers
    then reuse a single compile across calls whose ``n`` falls in the
    same bucket instead of tracing afresh per block count (pinned in
    tests via ``serve.engine.flush_trace_count``). ``mesh`` routes the
    pool steps through the device-sharded driver (DESIGN.md Sec. 7).

    Returns ``(order, stats)`` with ``order`` the block indices most-
    redundant first and per-block certified brackets in ``stats``.
    """
    pooled = pool_keys(keys, block)
    n = len(pooled)
    n_pad = -(-n // bucket) * bucket
    d2 = ((pooled[:, None, :] - pooled[None, :, :]) ** 2).sum(-1)
    kmat = np.exp(-d2 / (2 * bandwidth ** 2)) + ridge * np.eye(n)
    kfull = np.eye(n_pad, dtype=np.float32)
    kfull[:n, :n] = kmat
    op = core_ops.Dense(jnp.asarray(kfull))
    if solver_config is None:
        # ceiling derived from the BUCKETED size so every call in the
        # bucket shares one (static) solver config
        solver_config = SolverConfig(max_iters=min(n_pad + 2, 64),
                                     rtol=1e-3)
    engine = BIFEngine(op, solver=BIFSolver(solver_config),
                       max_batch=max_batch, mesh=mesh)
    base_mask = np.zeros(n_pad, dtype=np.float32)
    base_mask[:n] = 1.0
    reqs = []
    for i in range(n):
        mask = base_mask.copy()
        mask[i] = 0.0
        u = np.zeros(n_pad, dtype=np.float32)
        u[:n] = kmat[:, i]
        reqs.append(engine.submit(BIFRequest(u=u, mask=mask,
                                             max_iters=coarse_iters)))
    engine.flush()
    flushes = 1
    refined = 0
    if coarse_iters is not None:
        los = np.array([r.lower for r in reqs])
        his = np.array([r.upper for r in reqs])
        for i, r in enumerate(reqs):
            if r.resolved:
                continue  # already at the solver's tolerance
            # rank-ambiguous: bracket overlaps some other block's
            others = np.arange(n) != i
            if np.any((los[others] < his[i]) & (los[i] < his[others])):
                r.max_iters = None  # full budget; resumes banked state
                engine.submit(r)
                refined += 1
        if refined:
            engine.flush()
            flushes += 1
    mids = np.array([0.5 * (r.lower + r.upper) for r in reqs])
    order = np.argsort(-mids)
    return order, {
        "brackets": [(r.lower, r.upper) for r in reqs],
        "iterations": int(sum(r.iterations for r in reqs)),
        "certified": int(sum(r.certified for r in reqs)),
        "resolved": int(sum(bool(r.resolved) for r in reqs)),
        "refined": refined,
        # scheduler passes over the lane pool (the continuous engine has
        # no per-max_batch chunks; each flush call is one scheduler run)
        "flushes": flushes, "blocks": n}


def apply_block_mask(cache_k: jax.Array, cache_v: jax.Array,
                     mask: np.ndarray, block: int = 128):
    """Zero out evicted blocks (a real engine would compact; zeroing keeps
    shapes static and attention ignores evicted keys via -inf scores when
    combined with the validity mask)."""
    s = cache_k.shape[1]
    full = np.repeat(mask, block)
    full = np.pad(full, (0, s - len(full)), constant_values=True)
    m = jnp.asarray(full, cache_k.dtype)[None, :, None, None]
    return cache_k * m, cache_v * m
