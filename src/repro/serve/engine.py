"""Batched serving engine: prefill + decode with KV caches.

A deliberately small but real engine: request queue, padded batching,
greedy/temperature sampling, per-request stop handling, int8 KV option.
The heavy lifting (sharded steps) comes from launch.steps; on CPU tests
this runs the same code unsharded.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[np.ndarray] = None


class Engine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_seq: int = 256, kv_dtype=jnp.float32,
                 quantized_kv: bool = False, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.kv_dtype = kv_dtype
        self.quantized_kv = quantized_kv
        self.key = jax.random.key(seed)
        self._prefill = jax.jit(
            lambda p, b, c: M.prefill(cfg, p, b, c))
        self._decode = jax.jit(
            lambda p, c, b: M.decode_step(cfg, p, c, b))

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits[:, -1, :], axis=-1)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits[:, -1, :] / temperature)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a batch of requests (padded to a common prompt length)."""
        assert len(requests) <= self.max_batch
        b = len(requests)
        t = max(len(r.prompt) for r in requests)
        prompts = np.zeros((b, t), np.int32)
        for i, r in enumerate(requests):
            prompts[i, t - len(r.prompt):] = r.prompt  # left-pad
        caches = M.make_caches(self.cfg, b, self.max_seq, self.kv_dtype,
                               quantized_kv=self.quantized_kv)
        batch = {"tokens": jnp.asarray(prompts)}
        caches, logits = self._prefill(self.params, batch, caches)
        max_new = max(r.max_new_tokens for r in requests)
        outs = np.zeros((b, max_new), np.int32)
        tok = self._sample(logits, requests[0].temperature)
        outs[:, 0] = np.asarray(tok)
        for step in range(1, max_new):
            dec = {"tokens": jnp.asarray(tok)[:, None],
                   "position": jnp.asarray([t + step - 1], jnp.int32)}
            caches, logits = self._decode(self.params, caches, dec)
            tok = self._sample(logits, requests[0].temperature)
            outs[:, step] = np.asarray(tok)
        for i, r in enumerate(requests):
            r.out_tokens = outs[i, :r.max_new_tokens]
        return requests
