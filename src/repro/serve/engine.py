"""Batched serving engines.

``Engine``: prefill + decode with KV caches — request queue, padded
batching, greedy/temperature sampling, per-request stop handling, int8
KV option. The heavy lifting (sharded steps) comes from launch.steps; on
CPU tests this runs the same code unsharded.

``BIFEngine``: the quadrature-serving counterpart (DESIGN.md Sec. 6) —
queues incoming bilinear-inverse-form requests against one kernel
matrix and flushes them through ``BIFSolver.solve_batch`` in padded
lanes of ``max_batch``, so K concurrent judges cost one batched driver
instead of K sequential solves.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from functools import partial

from ..core import operators as core_ops
from ..core import sharded as core_sharded
from ..core import spectrum as core_spectrum
from ..core.solver import BIFSolver
from ..models import model as M


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[np.ndarray] = None


class Engine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_seq: int = 256, kv_dtype=jnp.float32,
                 quantized_kv: bool = False, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.kv_dtype = kv_dtype
        self.quantized_kv = quantized_kv
        self.key = jax.random.key(seed)
        self._prefill = jax.jit(
            lambda p, b, c: M.prefill(cfg, p, b, c))
        self._decode = jax.jit(
            lambda p, c, b: M.decode_step(cfg, p, c, b))

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits[:, -1, :], axis=-1)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits[:, -1, :] / temperature)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a batch of requests (padded to a common prompt length)."""
        assert len(requests) <= self.max_batch
        b = len(requests)
        t = max(len(r.prompt) for r in requests)
        prompts = np.zeros((b, t), np.int32)
        for i, r in enumerate(requests):
            prompts[i, t - len(r.prompt):] = r.prompt  # left-pad
        caches = M.make_caches(self.cfg, b, self.max_seq, self.kv_dtype,
                               quantized_kv=self.quantized_kv)
        batch = {"tokens": jnp.asarray(prompts)}
        caches, logits = self._prefill(self.params, batch, caches)
        max_new = max(r.max_new_tokens for r in requests)
        outs = np.zeros((b, max_new), np.int32)
        tok = self._sample(logits, requests[0].temperature)
        outs[:, 0] = np.asarray(tok)
        for step in range(1, max_new):
            dec = {"tokens": jnp.asarray(tok)[:, None],
                   "position": jnp.asarray([t + step - 1], jnp.int32)}
            caches, logits = self._decode(self.params, caches, dec)
            tok = self._sample(logits, requests[0].temperature)
            outs[:, step] = np.asarray(tok)
        for i, r in enumerate(requests):
            r.out_tokens = outs[i, :r.max_new_tokens]
        return requests


@dataclasses.dataclass
class BIFRequest:
    """One bilinear-inverse-form query against the engine's matrix.

    ``t`` set: threshold judge (decision = t < u^T A^-1 u, Alg. 4);
    ``t`` None: adaptive bracket to the solver's rtol/atol.
    ``mask``: optional principal-submatrix mask (the A_Y of a chain).
    """
    u: np.ndarray
    t: Optional[float] = None
    mask: Optional[np.ndarray] = None
    # filled by BIFEngine.flush():
    lower: Optional[float] = None
    upper: Optional[float] = None
    decision: Optional[bool] = None
    certified: Optional[bool] = None
    iterations: Optional[int] = None
    # set when a flush failed on this request's chunk (the request is
    # dropped from the queue; resubmit to retry a transient failure)
    error: Optional[Exception] = None


# Trace-time counter for the shared flush driver: increments once per
# fresh compile (jit cache miss), never on cache hits. Tests pin the
# bucketed-padding contract of serve.kv_select.rank_blocks with it.
_FLUSH_TRACES = [0]


def flush_trace_count() -> int:
    """How many times the shared BIFEngine flush driver has been traced
    (== compiled) in this process."""
    return _FLUSH_TRACES[0]


@partial(jax.jit, static_argnames=("mesh", "axis"))
def _flush_run(solver, op, us, masks, ts, has_t, lam_min, lam_max, *,
               mesh=None, axis: str = "lanes"):
    """ONE shared jitted flush driver for every BIFEngine.

    Module-level on purpose: the jit cache keys on (solver config, op
    treedef, shapes, mesh), so two engines around same-shaped systems —
    e.g. consecutive ``rank_blocks`` calls whose block counts fall in
    the same padding bucket — reuse one compile instead of tracing a
    fresh per-engine closure each time. ``lam_min``/``lam_max`` ride
    along as runtime scalars for the same reason.
    """
    _FLUSH_TRACES[0] += 1
    mop = core_ops.Masked(op, masks)

    def decide(lo, hi, ts, has_t):
        # judge lanes resolve on their threshold, bracket lanes on the
        # solver's own tolerance rule
        thr = (ts < lo) | (ts >= hi)
        return jnp.where(has_t, thr, solver.tolerance_resolved(lo, hi))

    if mesh is None:
        res = solver.solve_batch(mop, us,
                                 decide=lambda lo, hi: decide(lo, hi, ts,
                                                              has_t),
                                 lam_min=lam_min, lam_max=lam_max)
    else:
        res = core_sharded.solve_batch_sharded(
            solver, mop, us, decide, decide_args=(ts, has_t), mesh=mesh,
            axis=axis, lam_min=lam_min, lam_max=lam_max)
    decision = BIFSolver.threshold_decision(ts, res.lower, res.upper)
    return (res.lower, res.upper, decision,
            decide(res.lower, res.upper, ts, has_t), res.iterations)


class BIFEngine:
    """Batches BIF requests into ``solve_batch`` flushes.

    Requests accumulate via ``submit`` and are served by ``flush`` in
    padded lane groups of ``max_batch`` (one compiled driver shape per
    engine, shared across engines via the module-level ``_flush_run``).
    Mixed traffic is fine: judge lanes resolve on their threshold,
    bracket lanes on tolerance, and every resolved lane freezes while
    the rest continue — the per-lane early exit of DESIGN.md Sec. 6.
    Dummy padding lanes (zero query) resolve at iteration one and cost
    only their share of the stacked matvec.

    With ``mesh`` set (a 1-D lane mesh from
    ``launch.mesh.make_lane_mesh``), each flush runs the sharded driver
    of DESIGN.md Sec. 7: ``max_batch`` is rounded up to a whole number
    of lanes per device and the flush's lanes split across the mesh.
    """

    def __init__(self, op, *, solver: BIFSolver | None = None,
                 max_batch: int = 64, lam_min: float | None = None,
                 lam_max: float | None = None, mesh=None,
                 lane_axis: str = "lanes"):
        self.op = op
        self.solver = solver if solver is not None \
            else BIFSolver.create(max_iters=64, rtol=1e-3)
        self.mesh = mesh
        self.lane_axis = lane_axis
        max_batch = int(max_batch)
        if mesh is not None:
            # padded flushes must round up to num_devices x lanes_per_device
            ndev = mesh.shape[lane_axis]
            max_batch = -(-max_batch // ndev) * ndev
        self.max_batch = max_batch
        if lam_min is None or lam_max is None:
            # one-time certified interval, valid for every request mask
            # by interlacing (DESIGN.md Sec. 3.2)
            est = core_spectrum.gershgorin_bounds_spd(op)
            if lam_min is None:
                lam_min = float(est.lam_min)
            if lam_max is None:
                lam_max = float(est.lam_max)
        self.lam_min, self.lam_max = float(lam_min), float(lam_max)
        self._queue: List[BIFRequest] = []
        self._dtype = np.dtype(np.asarray(self.op.diag()).dtype)

        def run(us, masks, ts, has_t):
            return _flush_run(
                self.solver, self.op, us, masks, ts, has_t,
                jnp.asarray(self.lam_min, us.dtype),
                jnp.asarray(self.lam_max, us.dtype),
                mesh=self.mesh, axis=self.lane_axis)

        self._run = run

    def submit(self, req: BIFRequest) -> BIFRequest:
        """Queue one request. Shapes are validated here so a malformed
        request is rejected at the door instead of poisoning a flush."""
        n = self.op.n
        u = np.asarray(req.u)
        if u.shape != (n,):
            raise ValueError(
                f"BIFRequest.u must have shape ({n},), got {u.shape}")
        if req.mask is not None and np.asarray(req.mask).shape != (n,):
            raise ValueError(
                f"BIFRequest.mask must have shape ({n},), got "
                f"{np.asarray(req.mask).shape}")
        if req.t is not None:
            try:
                req.t = float(req.t)
            except (TypeError, ValueError) as e:
                raise ValueError(
                    f"BIFRequest.t must be a scalar, got {req.t!r}") from e
        req.error = None
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    def flush(self) -> List[BIFRequest]:
        """Serve every queued request; returns them in submission order.

        If the driver fails on a chunk, that chunk's requests get their
        ``error`` set and are dropped (resubmit to retry), the untried
        tail stays queued, and the exception propagates.
        """
        queue, self._queue = self._queue, []
        n, b = self.op.n, self.max_batch
        for start in range(0, len(queue), b):
            chunk = queue[start:start + b]
            try:
                us = np.zeros((b, n), self._dtype)
                masks = np.ones((b, n), self._dtype)
                ts = np.zeros((b,), self._dtype)
                has_t = np.zeros((b,), bool)
                for i, r in enumerate(chunk):
                    if r.mask is not None:
                        masks[i] = r.mask
                    # restrict the query to the mask: Masked is only the
                    # true submatrix system for u supported on it (Sec. 3.2)
                    us[i] = np.asarray(r.u) * masks[i]
                    if r.t is not None:
                        ts[i] = r.t
                        has_t[i] = True
                lo, hi, dec, cert, it = self._run(
                    jnp.asarray(us), jnp.asarray(masks), jnp.asarray(ts),
                    jnp.asarray(has_t))
            except Exception as e:
                # keep the un-served tail, but NOT the failing chunk: a
                # poison request requeued at the head would re-raise on
                # every flush and wedge everything behind it. The chunk's
                # requests carry the error so callers can tell "dropped
                # by a failed flush" from "never flushed" and resubmit
                # the innocent ones after a transient driver failure.
                for r in chunk:
                    r.error = e
                self._queue = queue[start + len(chunk):] + self._queue
                raise
            for i, r in enumerate(chunk):
                r.lower, r.upper = float(lo[i]), float(hi[i])
                r.decision = bool(dec[i]) if r.t is not None else None
                r.certified = bool(cert[i])
                r.iterations = int(it[i])
        return queue
