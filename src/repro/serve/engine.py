"""Batched serving engines.

``Engine``: prefill + decode with KV caches — request queue, padded
batching, greedy/temperature sampling, per-request stop handling, int8
KV option. The heavy lifting (sharded steps) comes from launch.steps; on
CPU tests this runs the same code unsharded.

``BIFEngine``: the quadrature-serving counterpart (DESIGN.md Sec. 8) —
a continuous-batching scheduler over a fixed pool of ``max_batch``
quadrature lanes. Requests queue via ``submit``; ``flush`` admits them
into free lanes, steps the whole pool in fixed-size chunks through the
resumable runtime (``BIFSolver.step_n``), retires lanes the moment
their decision resolves (or their iteration/deadline budget runs out),
and backfills the vacated lanes from the queue mid-flight — no
pad-to-``max_batch`` lockstep flushes, so one straggler bracket no
longer stalls a whole chunk of fast judges. Budget-interrupted requests
come back as partial results carrying their banked bracket and
:class:`~repro.core.solver.QuadState`; resubmitting them resumes the
solve bit-exactly where it stopped.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from functools import partial

from ..core import matfun as core_matfun
from ..core import operators as core_ops
from ..core import sharded as core_sharded
from ..core import spectrum as core_spectrum
from ..core.loop_utils import tree_freeze
from ..core.solver import BIFSolver, QuadState
from ..models import model as M
from ..obs import metrics as obs_metrics
from ..obs import registry as _obs_registry
from ..obs import spans as obs_spans


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[np.ndarray] = None


# Trace-time counters for the shared generation drivers (prefill +
# decode), reported through the central obs.registry (one
# ``retrace_counts()`` snapshot covers every serve/ jit): each
# ``count()`` call runs at trace time only, so it increments once per
# fresh compile. The jit cache keys on (cfg, shapes), so two Engines
# around the same reduced arch reuse one compile — instance-level jits
# here used to rebuild the cache per Engine.
_GEN_TRACE_KEYS = ("serve.engine.prefill", "serve.engine.decode")


def generate_trace_count() -> int:
    """How many times the shared prefill/decode drivers have been traced
    (== compiled) in this process."""
    return sum(_obs_registry.value(k) for k in _GEN_TRACE_KEYS)


@partial(jax.jit, static_argnames=("cfg",))
def _prefill_run(cfg, params, batch, caches):
    _obs_registry.count("serve.engine.prefill")
    return M.prefill(cfg, params, batch, caches)


@partial(jax.jit, static_argnames=("cfg",))
def _decode_run(cfg, params, caches, batch):
    _obs_registry.count("serve.engine.decode")
    return M.decode_step(cfg, params, caches, batch)


class Engine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_seq: int = 256, kv_dtype=jnp.float32,
                 quantized_kv: bool = False, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.kv_dtype = kv_dtype
        self.quantized_kv = quantized_kv
        self.key = jax.random.key(seed)

    def _prefill(self, params, batch, caches):
        return _prefill_run(self.cfg, params, batch, caches)

    def _decode(self, params, caches, batch):
        return _decode_run(self.cfg, params, caches, batch)

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits[:, -1, :], axis=-1)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits[:, -1, :] / temperature)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a batch of requests (padded to a common prompt length)."""
        assert len(requests) <= self.max_batch
        b = len(requests)
        t = max(len(r.prompt) for r in requests)
        prompts = np.zeros((b, t), np.int32)
        for i, r in enumerate(requests):
            prompts[i, t - len(r.prompt):] = r.prompt  # left-pad
        caches = M.make_caches(self.cfg, b, self.max_seq, self.kv_dtype,
                               quantized_kv=self.quantized_kv)
        batch = {"tokens": jnp.asarray(prompts)}
        caches, logits = self._prefill(self.params, batch, caches)
        max_new = max(r.max_new_tokens for r in requests)
        outs = np.zeros((b, max_new), np.int32)
        tok = self._sample(logits, requests[0].temperature)
        outs[:, 0] = np.asarray(tok)
        for step in range(1, max_new):
            dec = {"tokens": jnp.asarray(tok)[:, None],
                   "position": jnp.asarray([t + step - 1], jnp.int32)}
            caches, logits = self._decode(self.params, caches, dec)
            tok = self._sample(logits, requests[0].temperature)
            outs[:, step] = np.asarray(tok)
        for i, r in enumerate(requests):
            r.out_tokens = outs[i, :r.max_new_tokens]
        return requests


@dataclasses.dataclass
class BIFRequest:
    """One bilinear-inverse-form query against the engine's matrix.

    ``t`` set: threshold judge (decision = t < u^T f(A) u, Alg. 4);
    ``t`` None: adaptive bracket to the solver's rtol/atol.
    ``fn``: spectral function tag (matfun registry; None = the engine
    solver's ``config.fn``). A matfun engine (solver ``fn != 'inv'``)
    serves MIXED spectral functions per-lane in one pool — the Jacobi-
    matrix eigensolve is fn-independent, lanes just select their f on
    the shared Ritz values; a legacy f=1/x engine only takes
    'inv'-tagged (or untagged) requests and stays bit-exact with the
    pre-matfun scheduler.
    ``mask``: optional principal-submatrix mask (the A_Y of a chain).
    ``max_iters``: per-submission quadrature-iteration budget (on top of
    the solver's ``max_iters`` ceiling); ``deadline``: wall-clock cutoff
    (a ``time.monotonic()`` instant, checked at admission — an already-
    expired request retires immediately with zero iterations — and at
    chunk boundaries). A
    request whose budget/deadline expires before its decision resolves
    comes back PARTIAL: ``resolved=False``, the banked bracket in
    ``lower``/``upper``, and the lane's quadrature state in ``state`` —
    resubmit it (optionally with a new budget) to resume the solve
    bit-exactly where it stopped instead of starting over.
    """
    u: np.ndarray
    t: Optional[float] = None
    mask: Optional[np.ndarray] = None
    fn: Optional[str] = None
    max_iters: Optional[int] = None
    deadline: Optional[float] = None
    # filled by BIFEngine.flush():
    lower: Optional[float] = None
    upper: Optional[float] = None
    decision: Optional[bool] = None
    certified: Optional[bool] = None
    iterations: Optional[int] = None      # cumulative across resubmissions
    resolved: Optional[bool] = None       # decision/tolerance resolved OR
    #                                       Krylov-exhausted (bracket exact)
    state: Optional[Any] = None           # banked per-lane QuadState (partial)
    # set when a flush failed on this request's chunk (the request is
    # dropped from the queue; resubmit to retry a transient failure)
    error: Optional[Exception] = None


# Trace-time counters for the shared flush drivers (lockstep _flush_run
# + continuous-batching _pool_admit_run/_pool_scatter_run/
# _pool_step_run), one obs.registry key per driver: each increments once
# per fresh compile (jit cache miss), never on cache hits. Tests pin the
# bucketed-padding contract of serve.kv_select.rank_blocks with the
# aggregate (flush_trace_count below).
_FLUSH_TRACE_KEYS = ("serve.engine.pool_admit", "serve.engine.pool_scatter",
                     "serve.engine.pool_step", "serve.engine.flush")

# QuadState threading contract (quadlint QL001): per-lane fields the
# continuous-batching pool does NOT merge/bank. `basis` (reorth storage)
# never reaches the scheduler — _flush_continuous falls back to the
# lockstep path for reorth configs — so admission and banking
# legitimately skip it (banked states carry basis=None).
ENGINE_ADMIT_EXCLUDED = ("basis",)


def flush_trace_count() -> int:
    """How many times the shared BIFEngine flush drivers have been traced
    (== compiled) in this process."""
    return sum(_obs_registry.value(k) for k in _FLUSH_TRACE_KEYS)


def _mixed_decide(solver, lo, hi, ts, has_t):
    """The engine's per-lane resolution rule: judge lanes resolve on
    their threshold, bracket lanes on the solver's tolerance rule."""
    thr = (ts < lo) | (ts >= hi)
    return jnp.where(has_t, thr, solver.tolerance_resolved(lo, hi))


@jax.jit
def _pool_admit_run(solver, op, st, coeffs, us, masks, fresh, fnidx,
                    lam_min, lam_max):
    """Seed the ``fresh`` lanes of the pool from (pre-masked) ``us`` /
    ``masks``; every other lane's quadrature state passes through
    untouched. ``st=None`` initializes the whole pool (unoccupied lanes
    carry zero queries, which ``gql_init`` marks done at iteration one —
    the usual dummy-lane rule). On a matfun pool (tracking solver)
    ``fnidx`` is the authoritative per-lane spectral-function index and
    ``coeffs`` the prior pool coefficient history, frozen the same way.
    Module-level jit shared across engines, keyed on (solver config, op
    treedef, pool shapes)."""
    _obs_registry.count("serve.engine.pool_admit")
    state = solver.init_state(core_ops.Masked(op, masks), us,
                              lam_min=lam_min, lam_max=lam_max)
    if st is not None:
        state = state._replace(st=tree_freeze(state.st, st, ~fresh))
        if coeffs is not None:
            state = state._replace(
                coeffs=tree_freeze(state.coeffs, coeffs, ~fresh))
    if state.coeffs is not None and fnidx is not None:
        state = state._replace(
            coeffs=dataclasses.replace(state.coeffs, fnidx=fnidx))
    return state


@jax.jit
def _pool_scatter_run(st, lane_st, idx):
    """Insert one banked lane state (GQLState, and the lane's coeff
    history on matfun pools) at pool slot ``idx`` (warm admission of a
    resubmitted partial request)."""
    _obs_registry.count("serve.engine.pool_scatter")
    return jax.tree.map(lambda pool, lane: pool.at[idx].set(lane),
                        st, lane_st)


@partial(jax.jit, static_argnames=("n", "mesh", "axis"))
def _pool_step_run(solver, state, ts, has_t, it_cap, *, n, mesh=None,
                   axis: str = "lanes"):
    """One scheduler round: advance the pool by at most ``n`` quadrature
    iterations through the resumable runtime (``BIFSolver.step_n``, or
    its sharded twin when the engine is mesh-bound), freezing lanes the
    moment they resolve or exhaust their per-request ``it_cap`` budget.
    Returns the stepped state plus everything the host scheduler needs
    to retire lanes."""
    _obs_registry.count("serve.engine.pool_step")
    if mesh is None:
        state = solver.step_n(
            state, n, lambda lo, hi: _mixed_decide(solver, lo, hi, ts,
                                                   has_t),
            it_cap=it_cap)
    else:
        state = core_sharded.step_n_sharded(
            solver, state, n,
            lambda lo, hi, ts_, ht_: _mixed_decide(solver, lo, hi, ts_,
                                                   ht_),
            decide_args=(ts, has_t), it_cap=it_cap, mesh=mesh, axis=axis)
    lo, hi = solver._bracket2(state.st, state.coeffs, state.lam_min,
                              state.lam_max)
    resolved = _mixed_decide(solver, lo, hi, ts, has_t)
    decision = BIFSolver.threshold_decision(ts, lo, hi)
    return state, lo, hi, resolved, decision, state.st.done, state.st.it


@partial(jax.jit, static_argnames=("mesh", "axis"))
def _flush_run(solver, op, us, masks, ts, has_t, lam_min, lam_max, *,
               mesh=None, axis: str = "lanes"):
    """ONE shared jitted flush driver for every BIFEngine.

    Module-level on purpose: the jit cache keys on (solver config, op
    treedef, shapes, mesh), so two engines around same-shaped systems —
    e.g. consecutive ``rank_blocks`` calls whose block counts fall in
    the same padding bucket — reuse one compile instead of tracing a
    fresh per-engine closure each time. ``lam_min``/``lam_max`` ride
    along as runtime scalars for the same reason.
    """
    _obs_registry.count("serve.engine.flush")
    mop = core_ops.Masked(op, masks)

    def decide(lo, hi, ts, has_t):
        # judge lanes resolve on their threshold, bracket lanes on the
        # solver's own tolerance rule
        thr = (ts < lo) | (ts >= hi)
        return jnp.where(has_t, thr, solver.tolerance_resolved(lo, hi))

    if mesh is None:
        res = solver.solve_batch(mop, us,
                                 decide=lambda lo, hi: decide(lo, hi, ts,
                                                              has_t),
                                 lam_min=lam_min, lam_max=lam_max)
    else:
        res = core_sharded.solve_batch_sharded(
            solver, mop, us, decide, decide_args=(ts, has_t), mesh=mesh,
            axis=axis, lam_min=lam_min, lam_max=lam_max)
    decision = BIFSolver.threshold_decision(ts, res.lower, res.upper)
    return (res.lower, res.upper, decision,
            decide(res.lower, res.upper, ts, has_t), res.iterations,
            res.converged)


class BIFEngine:
    """Continuous-batching scheduler for BIF requests (DESIGN.md Sec. 8).

    Requests accumulate via ``submit``; ``flush`` serves them through a
    fixed pool of ``max_batch`` quadrature lanes. Each scheduler round
    admits queued requests into free lanes (FIFO), steps the WHOLE pool
    by ``chunk_iters`` quadrature iterations (aligned up to a whole
    number of ``decide_every`` rounds) through the resumable runtime
    (one stacked matvec per iteration; resolved lanes frozen
    bit-exactly), then retires every lane whose decision resolved — or
    whose per-request iteration/deadline budget ran out — and backfills
    the vacated lanes from the queue mid-flight. A straggler bracket
    therefore occupies one lane while fast judges stream through the
    rest, instead of stalling a padded lockstep chunk behind it
    (``flush(mode='lockstep')`` keeps the old pad-to-``max_batch``
    behavior for comparison; ``benchmarks/engine_throughput.py`` tracks
    the gap). Completion is FIFO-preserving: ``flush`` returns requests
    in submission order regardless of retirement order.

    Mixed traffic is fine: judge lanes resolve on their threshold,
    bracket lanes on tolerance. Unoccupied lanes (zero query) resolve at
    iteration one and cost only their share of the stacked matvec.

    With ``mesh`` set (a 1-D lane mesh from
    ``launch.mesh.make_lane_mesh``), pool steps run the sharded stepping
    driver of DESIGN.md Sec. 7/8: ``max_batch`` is rounded up to a whole
    number of lanes per device and the pool's lanes split across the
    mesh (the pool state shards with them).
    """

    def __init__(self, op, *, solver: BIFSolver | None = None,
                 max_batch: int = 64, lam_min: float | None = None,
                 lam_max: float | None = None, mesh=None,
                 lane_axis: str = "lanes", chunk_iters: int = 8,
                 metrics: bool = True, convergence_log=None):
        self.op = op
        self.solver = solver if solver is not None \
            else BIFSolver.create(max_iters=64, rtol=1e-3)
        if self.solver.config.block_size > 1:
            raise NotImplementedError(
                "the serving engine batches scalar (u, mask) queries; "
                "block_size > 1 brackets tr B^T f(A) B probe blocks and "
                "has no per-request semantics — use a block_size=1 "
                "solver (block traces go through trace_quad)")
        self.mesh = mesh
        self.lane_axis = lane_axis
        # step_n quantises to whole decide_every rounds — align the
        # serving chunk UP to the cadence so every flush makes progress
        # (a chunk smaller than one round would be a no-op and livelock
        # the pool)
        r = self.solver.config.decide_every
        self.chunk_iters = -(-max(1, int(chunk_iters)) // r) * r
        max_batch = int(max_batch)
        if mesh is not None:
            # padded flushes must round up to num_devices x lanes_per_device
            ndev = mesh.shape[lane_axis]
            max_batch = -(-max_batch // ndev) * ndev
        self.max_batch = max_batch
        if lam_min is None or lam_max is None:
            # one-time certified interval, valid for every request mask
            # by interlacing (DESIGN.md Sec. 3.2)
            est = core_spectrum.gershgorin_bounds_spd(op)
            if lam_min is None:
                lam_min = float(est.lam_min)
            if lam_max is None:
                lam_max = float(est.lam_max)
        self.lam_min, self.lam_max = float(lam_min), float(lam_max)
        self._queue: List[BIFRequest] = []
        self._dtype = np.dtype(np.asarray(self.op.diag()).dtype)
        # Observability (DESIGN.md Sec. 14): per-engine metric registry,
        # written HOST-SIDE only — every observation below reads values
        # the scheduler already materialized with np.asarray, so metrics
        # on/off cannot perturb a single compiled computation (pinned by
        # tests/test_obs.py bit-parity). `convergence_log` (an
        # obs.health.ConvergenceLog) records per-round per-lane brackets
        # off the same host copies.
        self._metrics_on = bool(metrics)
        self._metrics = obs_metrics.MetricsRegistry()
        self.convergence_log = convergence_log

        def run(us, masks, ts, has_t):
            return _flush_run(
                self.solver, self.op, us, masks, ts, has_t,
                jnp.asarray(self.lam_min, us.dtype),
                jnp.asarray(self.lam_max, us.dtype),
                mesh=self.mesh, axis=self.lane_axis)

        self._run = run

    def submit(self, req: BIFRequest) -> BIFRequest:
        """Queue one request. Shapes are validated here so a malformed
        request is rejected at the door instead of poisoning a flush."""
        n = self.op.n
        u = np.asarray(req.u)
        if u.shape != (n,):
            raise ValueError(
                f"BIFRequest.u must have shape ({n},), got {u.shape}")
        if req.mask is not None and np.asarray(req.mask).shape != (n,):
            raise ValueError(
                f"BIFRequest.mask must have shape ({n},), got "
                f"{np.asarray(req.mask).shape}")
        cfg_fn = self.solver.config.fn
        fn = cfg_fn if req.fn is None else req.fn
        core_matfun.fn_index(fn)  # raises on unknown tags
        if cfg_fn == "inv" and fn != "inv":
            raise ValueError(
                f"this engine's solver runs the legacy f=1/x recurrence; "
                f"fn={fn!r} requests need an engine built with a matfun "
                f"solver (BIFSolver.create(fn=...), any registry fn — "
                f"mixed-fn pools are fine there)")
        if req.state is not None:
            # a banked state continues the ORIGINAL (u, mask) query: the
            # Lanczos recurrence is only valid for the system it was
            # started on. A mutated query must re-solve from scratch.
            mask = np.ones((n,), self._dtype) if req.mask is None \
                else np.asarray(req.mask, self._dtype)
            banked = getattr(req, "_banked_query", None)
            if banked is None \
                    or not np.array_equal(u.astype(self._dtype) * mask,
                                          banked) \
                    or not np.array_equal(mask,
                                          np.asarray(req.state.op.mask,
                                                     self._dtype)):
                raise ValueError(
                    "BIFRequest.state banks the solve of the originally "
                    "submitted (u, mask); changing either invalidates "
                    "the banked recurrence — set state=None to re-solve "
                    "the new query from scratch")
            banked_fn = "inv" if req.state.coeffs is None else \
                core_matfun.fn_name(int(req.state.coeffs.fnidx))
            if banked_fn != fn:
                raise ValueError(
                    f"BIFRequest.state banks a fn={banked_fn!r} solve; "
                    f"resubmitting it as fn={fn!r} would misread the "
                    f"banked history — set state=None to re-solve")
            if (req.state.coeffs is None) != (cfg_fn == "inv"):
                # a matfun pool scatters CoeffHistory lanes, a legacy
                # pool coeff-free ones; a presence mismatch would blow
                # up mid-flush and poison the in-flight requests
                raise ValueError(
                    "BIFRequest.state was banked by a "
                    f"{'legacy f=1/x' if req.state.coeffs is None else 'matfun'} "
                    f"engine pool and cannot resume on this one "
                    f"(solver fn={cfg_fn!r}) — set state=None to re-solve")
        if req.t is not None:
            try:
                req.t = float(req.t)
            except (TypeError, ValueError) as e:
                raise ValueError(
                    f"BIFRequest.t must be a scalar, got {req.t!r}") from e
        # Clear EVERY stale result field, not just the error: a request
        # resubmitted for refinement must not let a failed flush leave
        # the previous round's lower/upper/decision readable as if they
        # were current. The banked state/query stay — they are what a
        # resubmission resumes from (cumulative iteration counts live in
        # state.it and are restored at retirement).
        req.lower = req.upper = None
        req.decision = None
        req.certified = None
        req.iterations = None
        req.resolved = None
        req.error = None
        self._queue.append(req)
        req._obs_submit_t = time.monotonic()
        self._count("requests.submitted")
        if req.state is not None:
            self._count("requests.resubmitted")
        return req

    def pending(self) -> int:
        return len(self._queue)

    # -- observability (host-side only; see DESIGN.md Sec. 14) ------------

    def _count(self, name: str, n: int = 1) -> None:
        if self._metrics_on:
            self._metrics.counter(name).inc(n)

    def _observe(self, name: str, value: float) -> None:
        if self._metrics_on:
            self._metrics.histogram(name).observe(value)

    def _retire_obs(self, r: BIFRequest, now: float, *,
                    expired: bool = False) -> None:
        """Record one retirement. `now` is the scheduler's own clock
        read for this round — reused, never re-read, so the metrics see
        exactly the instants the scheduling decisions saw."""
        if not self._metrics_on:
            return
        self._count("requests.retired")
        sub_t = getattr(r, "_obs_submit_t", None)
        adm_t = getattr(r, "_obs_admit_t", None)
        if adm_t is not None:
            self._observe("request.latency_s", now - adm_t)
        elif sub_t is not None:
            # expired at the door: never admitted, queue-wait only
            self._observe("request.queue_wait_s", now - sub_t)
        if r.deadline is not None:
            self._observe("request.deadline_slack_s", r.deadline - now)
        if r.iterations is not None:
            self._observe("request.iterations", float(r.iterations))
        if r.resolved:
            self._count("requests.resolved")
        else:
            self._count("requests.partial")
            if expired:
                self._count("requests.expired")

    def stats(self) -> dict:
        """Plain-dict snapshot of this engine's request metrics:
        ``{"counters": {...}, "gauges": {...}, "histograms": {name:
        {count, sum, min, max, mean, p50, p90, p99, buckets}}}`` —
        queue-wait / admission-to-retire latency / deadline-slack /
        iteration histograms, submitted / resolved / partial / expired /
        errored / resubmitted counters, per-round pool occupancy."""
        return self._metrics.snapshot()

    def reset_stats(self) -> None:
        self._metrics.reset()

    def _step(self, state, ts, has_t, it_cap):
        """One pool decision round (seam for tests / fault injection)."""
        return _pool_step_run(self.solver, state, ts, has_t, it_cap,
                              n=self.chunk_iters, mesh=self.mesh,
                              axis=self.lane_axis)

    def flush(self, *, mode: str = "continuous") -> List[BIFRequest]:
        """Serve every queued request; returns them in submission order
        (FIFO-preserving completion — retirement order never reorders the
        returned list). Budget/deadline-interrupted requests come back
        partial (``resolved=False``) with their banked bracket + state.

        ``mode='lockstep'`` keeps the legacy padded chunk flushes (no
        backfill, budgets and deadlines ignored) — the benchmark
        baseline. Solver configs the scheduler does not take (reorth,
        preconditioning) fall back to it automatically.

        If the driver fails, the in-flight requests get their ``error``
        set and are dropped (resubmit to retry), the unadmitted tail
        stays queued in order, and the exception propagates; requests
        that already retired keep their results.
        """
        if mode not in ("continuous", "lockstep"):
            raise ValueError(f"mode must be 'continuous' or 'lockstep', "
                             f"got {mode!r}")
        self._count("flush.count")
        with obs_spans.span("engine.flush", mode=mode,
                            queued=len(self._queue)):
            if mode == "continuous":
                return self._flush_continuous()
            return self._flush_lockstep()

    # -- the continuous-batching scheduler --------------------------------

    def _flush_continuous(self) -> List[BIFRequest]:
        cfg = self.solver.config
        if cfg.reorth or cfg.precondition != "none":
            # the stepping scheduler banks/merges plain lane states;
            # reorth bases and preconditioned transforms keep the legacy
            # lockstep path (per-request budgets/deadlines don't apply
            # there, but such configs never could use them before)
            return self._flush_lockstep()
        queue, self._queue = self._queue, []
        if not queue:
            return queue
        solver = self.solver
        n, p = self.op.n, self.max_batch
        dt = self._dtype
        max_iters = cfg.max_iters
        tracking = cfg.fn != "inv"          # matfun pool (per-lane fns)

        # host-side pool bookkeeping; device-side state in `state`
        us = np.zeros((p, n), dt)
        masks = np.ones((p, n), dt)
        ts = np.zeros((p,), dt)
        has_t = np.zeros((p,), bool)
        caps = np.zeros((p,), np.int32)   # 0 = vacated/dead lane (frozen)
        fnidx = np.full((p,), core_matfun.fn_index(cfg.fn), np.int32)
        slots: List[Optional[BIFRequest]] = [None] * p
        pending = list(queue)
        state = None
        lam_min = jnp.asarray(self.lam_min, dt)
        lam_max = jnp.asarray(self.lam_max, dt)

        try:
            while pending or any(r is not None for r in slots):
                # --- admit: backfill free lanes from the queue (FIFO) ---
                fresh = np.zeros((p,), bool)
                warm = []
                dirty = state is None
                now = time.monotonic()
                for i in range(p):
                    if slots[i] is not None:
                        continue
                    r = None
                    while pending:
                        cand = pending.pop(0)
                        if cand.deadline is not None \
                                and now >= cand.deadline:
                            # already expired at the door: retire with
                            # ZERO pool rounds burned — no lane, no
                            # banked state, results stay cleared; FIFO
                            # order is preserved because the queue list
                            # itself is returned in submission order
                            cand.certified = False
                            cand.resolved = False
                            cand.iterations = 0
                            cand.state = None
                            self._retire_obs(cand, now, expired=True)
                            continue
                        r = cand
                        break
                    if r is None:
                        continue
                    slots[i] = r
                    r._obs_admit_t = now
                    self._observe("request.queue_wait_s",
                                  now - getattr(r, "_obs_submit_t", now))
                    m = np.ones((n,), dt) if r.mask is None \
                        else np.asarray(r.mask, dt)
                    masks[i] = m
                    # restrict the query to the mask: Masked is only the
                    # true submatrix system for u supported on it (Sec. 3.2)
                    us[i] = np.asarray(r.u, dt) * m
                    ts[i] = 0.0 if r.t is None else r.t
                    has_t[i] = r.t is not None
                    fnidx[i] = core_matfun.fn_index(
                        cfg.fn if r.fn is None else r.fn)
                    budget = max_iters if r.max_iters is None \
                        else max(int(r.max_iters), 0)
                    if r.state is not None:
                        # warm admission: resume the banked state
                        warm.append((i, (r.state.st, r.state.coeffs)))
                        caps[i] = min(int(r.state.it) + budget, max_iters)
                    else:
                        fresh[i] = True
                        caps[i] = min(budget, max_iters)
                    dirty = True
                if all(r is None for r in slots):
                    # every queued request expired at admission — there
                    # is nothing to step (a pool round here would burn
                    # chunk_iters x pool work on dead lanes)
                    break
                if dirty:
                    if state is None or fresh.any():
                        # fresh lanes seed from a POOL-SHAPED init on
                        # purpose: per-lane (1, N) inits would be cheaper
                        # (~1 pool matvec per backfill round) but change
                        # the matvec shape, and gemv-vs-gemm rounding
                        # noise can flip marginal iteration counts vs the
                        # lockstep baseline (the Sec. 6.1 caveat)
                        state = _pool_admit_run(
                            solver, self.op,
                            None if state is None else state.st,
                            None if state is None else state.coeffs,
                            jnp.asarray(us), jnp.asarray(masks),
                            jnp.asarray(fresh),
                            jnp.asarray(fnidx) if tracking else None,
                            lam_min, lam_max)
                    else:
                        # warm-only round: every admitted lane scatters a
                        # banked state in, so skip the pool init matvec
                        # and just rebind the masks on the pool operator
                        state = state._replace(op=dataclasses.replace(
                            state.op, mask=jnp.asarray(masks, dt)))
                    for i, lane_sc in warm:
                        st_new, coeffs_new = _pool_scatter_run(
                            (state.st, state.coeffs), lane_sc,
                            jnp.asarray(i))
                        state = state._replace(st=st_new,
                                               coeffs=coeffs_new)

                # --- one decision round over the whole pool ---
                occupied = sum(1 for s in slots if s is not None)
                self._count("flush.rounds")
                self._observe("pool.occupancy", occupied / p)
                with obs_spans.span("engine.pool_step",
                                    occupied=occupied) as sp:
                    state, lo, hi, res, dec, done, its = self._step(
                        state, jnp.asarray(ts), jnp.asarray(has_t),
                        jnp.asarray(caps))
                    # charge the device work to THIS span, not to
                    # whichever np.asarray below happens to block first
                    sp.block_until_ready((lo, hi, res, dec, done, its))
                lo_h, hi_h = np.asarray(lo), np.asarray(hi)
                res_h, dec_h = np.asarray(res), np.asarray(dec)
                done_h, it_h = np.asarray(done), np.asarray(its)
                now = time.monotonic()
                if self.convergence_log is not None:
                    # host-side copies the retire loop reads anyway —
                    # logging cannot perturb the compiled round
                    self.convergence_log.record(lo_h, hi_h, it_h)

                # --- retire: resolved lanes + expired budgets/deadlines ---
                for i in range(p):
                    r = slots[i]
                    if r is None:
                        continue
                    resolved = bool(res_h[i]) or bool(done_h[i])
                    capped = int(it_h[i]) >= min(int(caps[i]), max_iters)
                    timed_out = r.deadline is not None and now >= r.deadline
                    if not (resolved or capped or timed_out):
                        continue
                    r.lower, r.upper = float(lo_h[i]), float(hi_h[i])
                    r.decision = bool(dec_h[i]) if r.t is not None else None
                    r.certified = bool(res_h[i])
                    r.resolved = resolved
                    r.iterations = int(it_h[i])
                    if not resolved and int(it_h[i]) < max_iters:
                        # interrupted with headroom left: bank a per-lane
                        # QuadState so resubmission resumes bit-exactly
                        # (plus the premasked query, so submit() can
                        # reject a mutated u/mask at the door)
                        r.state = QuadState(
                            op=dataclasses.replace(
                                state.op, mask=state.op.mask[i]),
                            st=jax.tree.map(lambda l: l[i], state.st),
                            lam_min=state.lam_min, lam_max=state.lam_max,
                            basis=None, step=state.step,
                            coeffs=None if state.coeffs is None else
                            jax.tree.map(lambda l: l[i], state.coeffs))
                        r._banked_query = us[i].copy()
                    else:
                        r.state = None
                    self._retire_obs(r, now,
                                     expired=timed_out and not resolved)
                    slots[i] = None
                    caps[i] = 0  # freeze the vacated lane until backfill
        except Exception as e:
            # In-flight requests carry the error and are dropped (a
            # poison request must not wedge everything behind it); the
            # unadmitted tail stays queued IN ORDER; already-retired
            # requests keep their results.
            for r in slots:
                if r is not None:
                    r.error = e
                    self._count("requests.errored")
            self._queue = pending + self._queue
            raise
        return queue

    # -- the legacy lockstep flush (benchmark baseline) --------------------

    def _flush_lockstep(self) -> List[BIFRequest]:
        cfg_fn = self.solver.config.fn
        for r in self._queue:
            if r.fn is not None and r.fn != cfg_fn:
                # lockstep chunks run ONE solve_batch under the solver's
                # static fn; per-lane mixing is a continuous-pool feature
                raise ValueError(
                    f"flush(mode='lockstep') serves the solver's "
                    f"fn={cfg_fn!r} only (got a fn={r.fn!r} request); "
                    f"mixed-fn traffic needs mode='continuous'")
        queue, self._queue = self._queue, []
        n, b = self.op.n, self.max_batch
        for start in range(0, len(queue), b):
            chunk = queue[start:start + b]
            now = time.monotonic()
            self._count("flush.rounds")
            self._observe("pool.occupancy", len(chunk) / b)
            for r in chunk:
                r._obs_admit_t = now
                self._observe("request.queue_wait_s",
                              now - getattr(r, "_obs_submit_t", now))
            try:
                us = np.zeros((b, n), self._dtype)
                masks = np.ones((b, n), self._dtype)
                ts = np.zeros((b,), self._dtype)
                has_t = np.zeros((b,), bool)
                for i, r in enumerate(chunk):
                    if r.mask is not None:
                        masks[i] = r.mask
                    # restrict the query to the mask: Masked is only the
                    # true submatrix system for u supported on it (Sec. 3.2)
                    us[i] = np.asarray(r.u) * masks[i]
                    if r.t is not None:
                        ts[i] = r.t
                        has_t[i] = True
                with obs_spans.span("engine.lockstep_chunk",
                                    size=len(chunk)) as sp:
                    lo, hi, dec, cert, it, conv = self._run(
                        jnp.asarray(us), jnp.asarray(masks),
                        jnp.asarray(ts), jnp.asarray(has_t))
                    sp.block_until_ready((lo, hi, dec, cert, it, conv))
            except Exception as e:
                # keep the un-served tail, but NOT the failing chunk: a
                # poison request requeued at the head would re-raise on
                # every flush and wedge everything behind it. The chunk's
                # requests carry the error so callers can tell "dropped
                # by a failed flush" from "never flushed" and resubmit
                # the innocent ones after a transient driver failure.
                for r in chunk:
                    r.error = e
                    self._count("requests.errored")
                self._queue = queue[start + len(chunk):] + self._queue
                raise
            now = time.monotonic()
            for i, r in enumerate(chunk):
                r.lower, r.upper = float(lo[i]), float(hi[i])
                r.decision = bool(dec[i]) if r.t is not None else None
                r.certified = bool(cert[i])
                r.iterations = int(it[i])
                # same rule as the scheduler: resolved by the decision
                # OR by Krylov exhaustion (the bracket is then exact)
                r.resolved = bool(conv[i])
                self._retire_obs(r, now)
        return queue
