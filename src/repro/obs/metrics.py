"""Process-local metrics: counters, gauges, log-scale histograms.

Stdlib-only on purpose — the metrics layer must be importable (and
cheap) everywhere the serving stack runs, including tooling contexts
with no jax. All writes are host-side only (quadlint QL008): a counter
bumped inside a traced function would fire at TRACE time, not run time,
and silently count compiles instead of events.

Histograms use fixed log-scale buckets (so the memory footprint is
bounded and two snapshots merge bucket-wise) but additionally retain the
raw samples, so ``p50``/``p90``/``p99`` in a snapshot are EXACT
(nearest-rank on the sorted samples), not bucket-interpolated. Benchmark
and serving workloads here are thousands of observations, not millions;
exactness is worth the list.

Recording is globally gated by :func:`set_enabled` — the bit-parity
tests flip it to pin that telemetry never changes results.
"""
from __future__ import annotations

import bisect
import math
import threading

_LOCK = threading.RLock()
_ENABLED = [True]


def set_enabled(flag: bool) -> None:
    """Globally enable/disable metric writes (reads always work)."""
    _ENABLED[0] = bool(flag)


def enabled() -> bool:
    return _ENABLED[0]


def _log_bucket_edges(lo: float, hi: float, per_decade: int) -> list:
    """Geometric bucket upper edges covering [lo, hi]; observations
    outside land in the first/last (unbounded) bucket."""
    if not (lo > 0.0 and hi > lo and per_decade > 0):
        raise ValueError("need 0 < lo < hi and per_decade >= 1")
    decades = math.log10(hi / lo)
    k = int(math.ceil(decades * per_decade))
    return [lo * 10.0 ** (i / per_decade) for i in range(k + 1)]


class Counter:
    """Monotone event count."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if not _ENABLED[0]:
            return
        with _LOCK:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def snapshot(self) -> int:
        return self._value

    def reset(self) -> None:
        with _LOCK:
            self._value = 0


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, value: float) -> None:
        if not _ENABLED[0]:
            return
        with _LOCK:
            self._value = float(value)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> float:
        return self._value

    def reset(self) -> None:
        with _LOCK:
            self._value = 0.0


class Histogram:
    """Log-scale fixed-bucket histogram with exact percentile readout.

    Default edges span 1e-9 .. 1e6 at 5 buckets/decade — wide enough for
    seconds-scale latencies at one end and iteration counts at the
    other. Non-positive observations land in the underflow bucket.
    """

    __slots__ = ("name", "_edges", "_counts", "_samples", "_sum",
                 "_min", "_max")

    def __init__(self, name: str, *, lo: float = 1e-9, hi: float = 1e6,
                 per_decade: int = 5):
        self.name = name
        self._edges = _log_bucket_edges(lo, hi, per_decade)
        self._reset_locked()

    def _reset_locked(self) -> None:
        self._counts = [0] * (len(self._edges) + 1)  # +underflow/overflow
        self._samples: list = []
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, value: float) -> None:
        if not _ENABLED[0]:
            return
        v = float(value)
        with _LOCK:
            # bucket i holds values <= edges[i]; the last holds overflow
            self._counts[bisect.bisect_left(self._edges, v)] += 1
            self._samples.append(v)
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        return len(self._samples)

    def percentile(self, q: float) -> float:
        """Exact nearest-rank percentile (numpy's ``inverted_cdf``):
        the smallest sample with at least ``ceil(q/100 * n)`` samples at
        or below it. NaN on an empty histogram."""
        with _LOCK:
            n = len(self._samples)
            if n == 0:
                return math.nan
            if not 0.0 < q <= 100.0:
                raise ValueError(f"percentile q must be in (0, 100], got {q}")
            rank = max(1, math.ceil(q / 100.0 * n))
            return sorted(self._samples)[rank - 1]

    def snapshot(self) -> dict:
        with _LOCK:
            n = len(self._samples)
            out = {
                "count": n,
                "sum": self._sum,
                "min": self._min if n else math.nan,
                "max": self._max if n else math.nan,
                "mean": (self._sum / n) if n else math.nan,
            }
            if n:
                s = sorted(self._samples)
                for q in (50, 90, 99):
                    out[f"p{q}"] = s[max(1, math.ceil(q / 100.0 * n)) - 1]
            else:
                out["p50"] = out["p90"] = out["p99"] = math.nan
            # only the occupied buckets — snapshots stay readable
            out["buckets"] = [
                [self._edges[i] if i < len(self._edges) else math.inf, c]
                for i, c in enumerate(self._counts) if c
            ]
            return out

    def reset(self) -> None:
        with _LOCK:
            self._reset_locked()


class MetricsRegistry:
    """Named get-or-create store for counters/gauges/histograms.

    One module-level default registry backs the free functions below;
    subsystems that need isolated lifecycles (each ``BIFEngine``) hold
    their own instance.
    """

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, cls, **kwargs):
        with _LOCK:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, **kwargs)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kwargs) -> Histogram:
        return self._get(name, Histogram, **kwargs)

    def snapshot(self) -> dict:
        """Plain-dict snapshot: ``{"counters": {...}, "gauges": {...},
        "histograms": {name: {count, sum, min, max, mean, p50, p90,
        p99, buckets}}}``."""
        with _LOCK:
            out = {"counters": {}, "gauges": {}, "histograms": {}}
            for name in sorted(self._metrics):
                m = self._metrics[name]
                kind = {Counter: "counters", Gauge: "gauges",
                        Histogram: "histograms"}[type(m)]
                out[kind][name] = m.snapshot()
            return out

    def reset(self) -> None:
        with _LOCK:
            for m in self._metrics.values():
                m.reset()


REGISTRY = MetricsRegistry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str, **kwargs) -> Histogram:
    return REGISTRY.histogram(name, **kwargs)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
