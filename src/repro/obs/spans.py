"""Nestable monotonic-clock timing spans with Chrome-trace export.

``with span("engine.flush"):`` times a region on ``time.monotonic()``;
completed spans accumulate as Chrome-trace "complete" events ("ph": "X",
microsecond ts/dur) and :func:`dump_trace` writes the standard JSON
object wrapper — load it in https://ui.perfetto.dev or
chrome://tracing. Nesting is by timestamp containment per thread, which
is exactly how the trace viewers reconstruct the flame graph.

THE ATTRIBUTION CAVEAT: jax dispatch is asynchronous — a span that only
wraps the call that LAUNCHES device work closes long before the device
finishes, and the wall time shows up in whichever later span happens to
block on the result (usually an innocent ``np.asarray``). Use the
span's :meth:`Span.block_until_ready` hook on the launched values to
charge the device time to the span that caused it::

    with span("engine.pool_step") as sp:
        state, lo, hi = step(state)
        sp.block_until_ready((lo, hi))

Collection is OFF by default (a long-running service would accumulate
events without bound) — ``set_enabled(True)`` or ``obs.enable()`` turns
it on. Host-side only (quadlint QL008): under a jit trace the monotonic
clock would measure TRACE time once, not run time.
"""
from __future__ import annotations

import json
import os
import threading
import time

_LOCK = threading.RLock()
_ENABLED = [False]
_EVENTS: list = []
_EPOCH = time.monotonic()  # trace timestamps are relative to import
_TLS = threading.local()


def set_enabled(flag: bool) -> None:
    _ENABLED[0] = bool(flag)


def enabled() -> bool:
    return _ENABLED[0]


class Span:
    """One timed region; use via the :func:`span` context manager."""

    __slots__ = ("name", "args", "_t0", "_depth", "_live")

    def __init__(self, name: str, **args):
        self.name = name
        self.args = args
        self._t0 = 0.0
        self._depth = 0
        self._live = False

    def __enter__(self) -> "Span":
        if _ENABLED[0]:
            self._live = True
            stack = getattr(_TLS, "stack", None)
            if stack is None:
                stack = _TLS.stack = []
            self._depth = len(stack)
            stack.append(self)
            self._t0 = time.monotonic()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._live:
            return
        t1 = time.monotonic()
        _TLS.stack.pop()
        self._live = False
        args = dict(self.args)
        args["depth"] = self._depth
        if exc_type is not None:
            args["error"] = exc_type.__name__
        event = {
            "name": self.name,
            "cat": "obs",
            "ph": "X",
            "ts": (self._t0 - _EPOCH) * 1e6,
            "dur": (t1 - self._t0) * 1e6,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "args": args,
        }
        with _LOCK:
            _EVENTS.append(event)

    def block_until_ready(self, value):
        """Block on in-flight device work so it is charged to THIS span
        (no-op when span collection is off, and when jax is absent).
        Returns ``value`` unchanged — never alters results."""
        if self._live:
            try:
                import jax
            except ImportError:
                return value
            jax.block_until_ready(value)
        return value


def span(name: str, **args) -> Span:
    """``with span("engine.flush", mode="continuous"): ...``"""
    return Span(name, **args)


def trace_events() -> list:
    """Copy of the accumulated Chrome-trace events."""
    with _LOCK:
        return list(_EVENTS)


def reset() -> None:
    with _LOCK:
        _EVENTS.clear()


def dump_trace(path: str) -> dict:
    """Write the accumulated spans as Chrome-trace JSON (object form)
    and return the written document."""
    with _LOCK:
        doc = {
            "traceEvents": list(_EVENTS),
            "displayTimeUnit": "ms",
            "otherData": {
                "producer": "repro.obs.spans",
                "clock": "monotonic-since-import",
            },
        }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return doc
