"""Minimal JSON-Schema-subset validator (stdlib-only).

The containers this repo targets do not ship ``jsonschema``; the trace
schema (``obs/trace_schema.json``) only needs the core keywords —
``type``, ``required``, ``properties``, ``items``, ``enum`` — so a
30-line structural walk covers it. Unknown keywords are ignored, same
as full JSON Schema.
"""
from __future__ import annotations

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "number": (int, float),
    "integer": int,
    "boolean": bool,
    "null": type(None),
}


def validate(instance, schema: dict, path: str = "$") -> None:
    """Raise ``ValueError`` naming the offending path on mismatch."""
    t = schema.get("type")
    if t is not None:
        py = _TYPES[t]
        ok = isinstance(instance, py)
        if ok and t in ("number", "integer") and isinstance(instance, bool):
            ok = False  # bool is an int subclass; schemas mean numbers
        if not ok:
            raise ValueError(
                f"{path}: expected {t}, got {type(instance).__name__}")
    if "enum" in schema and instance not in schema["enum"]:
        raise ValueError(
            f"{path}: {instance!r} not in enum {schema['enum']!r}")
    if isinstance(instance, dict):
        for key in schema.get("required", ()):
            if key not in instance:
                raise ValueError(f"{path}: missing required key {key!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in instance:
                validate(instance[key], sub, f"{path}.{key}")
    if isinstance(instance, list) and "items" in schema:
        for i, item in enumerate(instance):
            validate(item, schema["items"], f"{path}[{i}]")
