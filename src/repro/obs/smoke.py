"""CI smoke for the observability layer: run a tiny engine workload
with metrics + spans on, dump the Chrome trace and metrics snapshot,
and validate both against the contracts CI relies on.

Run as ``PYTHONPATH=src python -m repro.obs.smoke [outdir]``. Exits
non-zero (with a message on stderr) on any violated contract:

* the dumped trace document must validate against
  ``obs/trace_schema.json`` (via the stdlib validator in
  ``obs.schema``);
* ``obs.registry.retrace_counts()`` must be non-empty after a flush —
  the engine's jit'd drivers traced at least once;
* the engine ``stats()`` snapshot must carry the request counters and
  the percentile fields of the latency histogram.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def run(outdir: Path) -> dict:
    import jax.numpy as jnp
    import numpy as np

    from .. import obs
    from . import schema as obs_schema
    from ..core import Dense
    from ..serve import BIFEngine, BIFRequest

    obs.spans.reset()
    obs.spans.set_enabled(True)
    obs.registry.reset()

    n = 16
    rng = np.random.default_rng(0)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lam = np.geomspace(1.0, 50.0, n)
    a = (q * lam) @ q.T
    a = 0.5 * (a + a.T)

    log = obs.ConvergenceLog()
    engine = BIFEngine(Dense(jnp.asarray(a)), max_batch=4, chunk_iters=4,
                       lam_min=0.99, lam_max=50.5, convergence_log=log)
    us = rng.standard_normal((6, n))
    true = np.einsum("ki,ki->k", us, np.linalg.solve(a, us.T).T)
    for i, u in enumerate(us):
        t = float(true[i] * (0.8 if i % 2 else 1.2)) if i % 3 else None
        engine.submit(BIFRequest(u=u, t=t))
    done = engine.flush()

    outdir.mkdir(parents=True, exist_ok=True)
    trace_path = outdir / "trace.json"
    doc = obs.dump_trace(trace_path)
    stats = engine.stats()
    (outdir / "metrics.json").write_text(
        json.dumps(stats, indent=2, sort_keys=True), encoding="utf-8")

    schema = json.loads(
        (Path(__file__).parent / "trace_schema.json").read_text(
            encoding="utf-8"))
    obs_schema.validate(doc, schema)
    if not doc["traceEvents"]:
        raise AssertionError("trace has no events despite enabled spans")

    retraces = obs.retrace_counts()
    if not retraces:
        raise AssertionError("retrace_counts() empty after an engine flush")

    counters = stats["counters"]
    if counters.get("requests.submitted") != len(us):
        raise AssertionError(f"submitted counter wrong: {counters}")
    if counters.get("requests.retired") != len(us):
        raise AssertionError(f"retired counter wrong: {counters}")
    lat = stats["histograms"]["request.latency_s"]
    for field in ("count", "p50", "p90", "p99"):
        if field not in lat:
            raise AssertionError(f"latency histogram missing {field!r}")
    if not all(r.resolved for r in done):
        raise AssertionError("smoke workload should fully resolve")
    if log.rounds == 0:
        raise AssertionError("convergence log recorded no rounds")

    return {"events": len(doc["traceEvents"]), "retraces": retraces,
            "counters": counters, "rounds": log.rounds,
            "out": str(outdir)}


def main(argv=None) -> int:
    args = sys.argv[1:] if argv is None else argv
    outdir = Path(args[0]) if args else Path("obs_smoke_out")
    try:
        summary = run(outdir)
    except Exception as e:  # noqa: BLE001 - CI wants one-line verdicts
        print(f"obs smoke FAILED: {type(e).__name__}: {e}", file=sys.stderr)
        return 1
    print("obs smoke OK: "
          f"{summary['events']} span events, retraces={summary['retraces']}, "
          f"counters={summary['counters']}, "
          f"convergence rounds={summary['rounds']} -> {summary['out']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
