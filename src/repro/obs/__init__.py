"""Runtime observability (DESIGN.md Sec. 14).

Host-side-only telemetry for the quadrature serving stack:

``obs.metrics``
    process-local counters / gauges / log-scale histograms with exact
    p50/p90/p99 readout (stdlib-only).
``obs.spans``
    nestable monotonic-clock timing spans exporting Chrome-trace JSON
    (load a dump in https://ui.perfetto.dev), with an explicit
    ``block_until_ready`` hook so asynchronous device work is attributed
    to the span that launched it.
``obs.registry``
    the central retrace-count registry every module-level jit in
    ``serve/`` reports through (``registry.count("name")`` at trace
    time; ``retrace_counts()`` for one snapshot).
``obs.health``
    online convergence-health checks on recorded bracket gaps against
    the Thm. 4.2 contraction rate (the reorth-off failure mode).

THE CONTRACT (enforced by quadlint QL008): ``obs.metrics`` and
``obs.spans`` are written from HOST code only — never inside
jit/while_loop/scan/shard_map scopes. Telemetry therefore cannot change
what gets compiled: solver brackets, decisions, iteration counts, and
engine flush order are bit-identical with observability on or off
(pinned by tests/test_obs.py, single-device and sharded). The one
sanctioned trace-time side effect is ``obs.registry.count`` — a
compile-count probe, same role as the legacy ``*_TRACES[0] += 1``.
"""
from . import health, metrics, registry, spans
from .health import ContractionMonitor, ConvergenceLog, rate_bound
from .metrics import MetricsRegistry
from .registry import retrace_counts
from .spans import dump_trace, span, trace_events


def enable() -> None:
    """Turn on both metrics recording and span collection."""
    metrics.set_enabled(True)
    spans.set_enabled(True)


def disable() -> None:
    """Turn off metrics recording and span collection (the default for
    spans; metrics default on). Never affects ``obs.registry`` — retrace
    accounting is a correctness signal, not telemetry."""
    metrics.set_enabled(False)
    spans.set_enabled(False)


__all__ = [
    "ContractionMonitor",
    "ConvergenceLog",
    "MetricsRegistry",
    "disable",
    "dump_trace",
    "enable",
    "health",
    "metrics",
    "rate_bound",
    "registry",
    "retrace_counts",
    "span",
    "spans",
    "trace_events",
]
