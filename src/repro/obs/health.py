"""Online convergence health: bracket-gap logs + Thm. 4.2 rate checks.

The paper's central theorem guarantees the Gauss-Radau gap on
``u^T f(A) u`` contracts per iteration at least as fast as
``rho = ((sqrt(kappa)-1)/(sqrt(kappa)+1))^2`` for a spectral interval
of condition number kappa — in EXACT arithmetic. Finite-precision
Lanczos without reorthogonalization keeps the early contraction but
loses the superlinear finish: ghost Ritz values burn iterations and the
gap flattens out orders of magnitude above the f64 resolution floor
(paper Sec. 5.4 'Instability'; tests/test_convergence.py pins the
healthy behavior). This module turns that theorem into a runtime check:

* :class:`ConvergenceLog` records per-round per-lane brackets HOST-SIDE
  off returned :class:`~repro.core.solver.QuadState` values — the
  compiled loops are untouched, so logging is bit-invariant.
* :func:`check_contraction` / :class:`ContractionMonitor` fit the
  geometric rate (windowed, iteration-normalized) and flag lanes that
  (a) contract SLOWER than the theorem rate allows, (b) plateau while
  the gap is still live, or (c) exhaust the Krylov dimension with the
  gap still open — in exact arithmetic Lanczos on an n-dim system
  terminates by n steps with an exact bracket, so (c) is the classic
  lost-orthogonality diagnosis and the most robust reorth-off signal.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


def rate_bound(lam_min: float, lam_max: float) -> float:
    """Thm. 4.2 per-iteration contraction rate for the interval."""
    if not (0.0 < lam_min <= lam_max):
        raise ValueError(
            f"need 0 < lam_min <= lam_max, got [{lam_min}, {lam_max}]")
    rk = float(np.sqrt(lam_max / lam_min))
    return ((rk - 1.0) / (rk + 1.0)) ** 2


class ConvergenceLog:
    """Per-round record of (lower, upper, it), any lane shape.

    Recording happens AFTER compiled calls return (``np.asarray`` on the
    state's ``lower``/``upper``/``it`` views) — never under a trace.
    """

    def __init__(self):
        self._lower: list = []
        self._upper: list = []
        self._it: list = []

    def record(self, lower, upper, it) -> None:
        lo = np.atleast_1d(np.asarray(lower, np.float64))
        hi = np.atleast_1d(np.asarray(upper, np.float64))
        itr = np.broadcast_to(
            np.atleast_1d(np.asarray(it, np.int64)), lo.shape)
        if hi.shape != lo.shape:
            raise ValueError(
                f"lower/upper shape mismatch: {lo.shape} vs {hi.shape}")
        if self._lower and lo.shape != self._lower[0].shape:
            raise ValueError(
                f"lane shape changed mid-log: {self._lower[0].shape} -> "
                f"{lo.shape}")
        self._lower.append(lo.copy())
        self._upper.append(hi.copy())
        self._it.append(np.array(itr, np.int64))

    def record_state(self, state) -> None:
        """Record one round off a returned QuadState (host-side)."""
        lo, hi = state.bracket()
        self.record(np.asarray(lo), np.asarray(hi), np.asarray(state.it))

    def record_trace(self, tr) -> None:
        """Record a full :meth:`BIFSolver.trace` run — one round per
        quadrature iteration, Gauss-Radau brackets (iteration k is the
        k-th recorded estimate, matching the trace convention)."""
        lo = np.asarray(tr.radau_lower)
        hi = np.asarray(tr.radau_upper)
        for k in range(lo.shape[0]):
            self.record(lo[k], hi[k], k + 1)

    @property
    def rounds(self) -> int:
        return len(self._lower)

    def lowers(self) -> np.ndarray:
        """(rounds, lanes) lower bounds."""
        return np.stack(self._lower) if self._lower else \
            np.zeros((0, 0))

    def uppers(self) -> np.ndarray:
        return np.stack(self._upper) if self._upper else \
            np.zeros((0, 0))

    def its(self) -> np.ndarray:
        return np.stack(self._it) if self._it else \
            np.zeros((0, 0), np.int64)

    def gaps(self) -> np.ndarray:
        """(rounds, lanes) bracket gaps (upper - lower)."""
        return self.uppers() - self.lowers()

    def reset(self) -> None:
        self._lower.clear()
        self._upper.clear()
        self._it.clear()


@dataclasses.dataclass(frozen=True)
class HealthReport:
    """Per-lane verdicts; ``flagged = slow | stalled | unresolved``."""
    bound: float                 # Thm. 4.2 rate for the interval
    fitted_rate: np.ndarray      # per-iteration geometric fit, live prefix
    max_window_rate: np.ndarray  # worst trailing-window rate observed
    last_rel_gap: np.ndarray     # final gap / lane scale
    slow: np.ndarray             # windowed rate > bound * rate_slack
    stalled: np.ndarray          # live plateau: windowed rate ~ 1
    unresolved: np.ndarray       # Krylov budget exhausted, gap still open
    flagged: np.ndarray

    @property
    def ok(self) -> bool:
        return not bool(self.flagged.any())


def _lane_scale(lowers: np.ndarray, uppers: np.ndarray) -> np.ndarray:
    s = np.maximum(np.abs(lowers), np.abs(uppers)).max(axis=0)
    return np.maximum(s, np.finfo(np.float64).tiny)


def check_contraction(log: ConvergenceLog, lam_min: float, lam_max: float,
                      *, window: int = 8, rate_slack: float = 1.15,
                      stall_ratio: float = 0.995, floor: float = 1e-8,
                      dim: Optional[int] = None,
                      resolved: Optional[Sequence[bool]] = None
                      ) -> HealthReport:
    """Check a recorded gap log against the Thm. 4.2 contraction rate.

    ``floor`` is the relative-gap resolution floor: lanes at or below it
    are converged and never flagged. ``dim`` (the system dimension, when
    the caller knows it) arms the exhaustion check: a gap still above
    the floor after ``dim`` Lanczos steps is impossible in exact
    arithmetic — the standard lost-orthogonality signature. ``resolved``
    masks lanes (e.g. threshold judges) that finished for reasons the
    gap cannot express.

    Rates are iteration-normalized — a log recorded every ``chunk``
    iterations (the engine's scheduler cadence) fits the same
    per-iteration rate as a per-iteration trace log.
    """
    lowers, uppers, its = log.lowers(), log.uppers(), log.its()
    rounds, lanes = lowers.shape
    nan = np.full((lanes,), np.nan)
    false = np.zeros((lanes,), bool)
    if rounds < 2:
        return HealthReport(rate_bound(lam_min, lam_max), nan, nan,
                            nan if rounds == 0 else
                            (uppers[-1] - lowers[-1]) /
                            _lane_scale(lowers, uppers),
                            false, false.copy(), false.copy(),
                            false.copy())

    bound = rate_bound(lam_min, lam_max)
    gaps = uppers - lowers
    scale = _lane_scale(lowers, uppers)
    rel = gaps / scale
    live = rel > floor

    fitted = np.full((lanes,), np.nan)
    max_win = np.full((lanes,), np.nan)
    slow = np.zeros((lanes,), bool)
    stalled = np.zeros((lanes,), bool)
    unresolved = np.zeros((lanes,), bool)
    skip = np.zeros((lanes,), bool)
    if resolved is not None:
        skip = np.asarray(resolved, bool).reshape((lanes,))

    for j in range(lanes):
        g, it, lv = gaps[:, j], its[:, j], live[:, j]
        # live prefix: stop at the first recorded round at/below floor
        m = rounds if lv.all() else int(np.argmin(lv))
        if m < 2:
            continue
        d_it = np.diff(it[:m])
        ok_pair = (d_it > 0) & (g[1:m] > 0.0) & (g[:m - 1] > 0.0)
        if ok_pair.any():
            logr = np.log(g[1:m][ok_pair] / g[:m - 1][ok_pair])
            fitted[j] = float(np.exp(logr.sum() / d_it[ok_pair].sum()))
        # trailing windows of `window` recorded rounds, per-iteration
        w = min(window, m - 1)
        rates = []
        for t in range(w, m):
            dit = int(it[t] - it[t - w])
            if dit > 0 and g[t - w] > 0.0 and g[t] > 0.0:
                rates.append((g[t] / g[t - w]) ** (1.0 / dit))
        if rates:
            max_win[j] = max(rates)
        if skip[j]:
            continue
        if rates and max(rates) > bound * rate_slack:
            slow[j] = True
        # plateau: the LAST window shows ~no contraction on a live gap
        if rates and lv[m - 1] and rates[-1] >= stall_ratio:
            stalled[j] = True
        # exhaustion: past the Krylov termination bound and still open
        if dim is not None and lv[-1] and int(its[-1, j]) >= dim - 2:
            unresolved[j] = True

    flagged = slow | stalled | unresolved
    return HealthReport(bound, fitted, max_win, rel[-1], slow, stalled,
                        unresolved, flagged)


class ContractionMonitor:
    """Online wrapper: feed rounds as they retire, ask for a report.

    >>> mon = ContractionMonitor(lam_min, lam_max, dim=n)
    >>> for _ in range(rounds):
    ...     state = solver.step_n(state, 8, convergence_log=mon.log)
    >>> mon.report().ok
    """

    def __init__(self, lam_min: float, lam_max: float, *,
                 window: int = 8, rate_slack: float = 1.15,
                 stall_ratio: float = 0.995, floor: float = 1e-8,
                 dim: Optional[int] = None):
        self.lam_min, self.lam_max = float(lam_min), float(lam_max)
        self.window = window
        self.rate_slack = rate_slack
        self.stall_ratio = stall_ratio
        self.floor = floor
        self.dim = dim
        self.log = ConvergenceLog()

    def observe(self, lower, upper, it) -> None:
        self.log.record(lower, upper, it)

    def observe_state(self, state) -> None:
        self.log.record_state(state)

    def report(self, *, resolved: Optional[Sequence[bool]] = None
               ) -> HealthReport:
        return check_contraction(
            self.log, self.lam_min, self.lam_max, window=self.window,
            rate_slack=self.rate_slack, stall_ratio=self.stall_ratio,
            floor=self.floor, dim=self.dim, resolved=resolved)
