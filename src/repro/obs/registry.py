"""Central retrace-count registry.

Every module-level jit in ``serve/`` reports compiles through one
``count("name")`` call placed INSIDE the jitted function body: python
side effects run at trace time only, so the count increments once per
fresh compile (jit cache miss) and never on cache hits. This is the one
sanctioned trace-time side effect in the tree — quadlint QL003 requires
it on serve/ module-level jits, and QL008 (which bans obs.metrics /
obs.spans in traced scopes) explicitly allows it.

Deliberately NOT gated by ``obs.metrics.set_enabled``: retrace counts
are a correctness/perf-contract signal (tests pin padding-bucket reuse
with them), not optional telemetry.
"""
from __future__ import annotations

import threading

_LOCK = threading.Lock()
_COUNTS: dict = {}


def count(name: str) -> int:
    """Record one (re)trace of ``name``; returns the new count."""
    with _LOCK:
        c = _COUNTS.get(name, 0) + 1
        _COUNTS[name] = c
        return c


def value(name: str) -> int:
    """Current count for ``name`` (0 if never traced)."""
    return _COUNTS.get(name, 0)


def retrace_counts() -> dict:
    """One snapshot of every registered retrace counter."""
    with _LOCK:
        return dict(sorted(_COUNTS.items()))


def reset() -> None:
    with _LOCK:
        _COUNTS.clear()
