"""Kernel-matrix builders mirroring the paper's Table 1 data regimes.

The container is offline, so UCI/SNAP datasets are replaced by synthetic
stand-ins with matched size/density/conditioning:

  * ``rbf_kernel``      — RBF with hard cutoff at 3*sigma (Abalone/Wine
                          regime: geometric point clouds, ~0.8-11% dense)
  * ``graph_laplacian`` — Watts-Strogatz-style sparse graphs (GR/HEP/
                          Epinions/Slashdot regime, 0.009-0.12% dense)

All kernels get ``+ ridge * I`` exactly as the paper does ("we add an
1e-3 times identity matrix to ensure positive definiteness").
"""
from __future__ import annotations

import numpy as np


def rbf_kernel(n: int, dim: int = 4, sigma: float = 0.5, cutoff: float = 3.0,
               ridge: float = 1e-3, seed: int = 0) -> np.ndarray:
    """Point cloud scaled so the 3-sigma cutoff keeps only local
    neighborhoods (matching the ~1-10% densities of paper Table 1)."""
    rng = np.random.default_rng(seed)
    box = (n ** (1.0 / dim)) * sigma * 1.2
    pts = rng.random((n, dim)).astype(np.float64) * box
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    k = np.exp(-d2 / (2 * sigma ** 2))
    k[np.sqrt(d2) > cutoff * sigma] = 0.0
    np.fill_diagonal(k, 1.0)
    return k + ridge * np.eye(n)


def graph_laplacian(n: int, mean_degree: int = 12, rewire: float = 0.1,
                    ridge: float = 1e-3, seed: int = 0) -> np.ndarray:
    """Watts-Strogatz ring lattice + rewiring; returns L + ridge*I."""
    rng = np.random.default_rng(seed)
    half = max(mean_degree // 2, 1)
    a = np.zeros((n, n), np.float64)
    for k in range(1, half + 1):
        idx = np.arange(n)
        a[idx, (idx + k) % n] = 1.0
    mask = rng.random(a.shape) < rewire
    rw = np.argwhere((a > 0) & mask)
    for i, j in rw:
        a[i, j] = 0.0
        t = rng.integers(0, n)
        if t != i:
            a[i, t] = 1.0
    a = np.maximum(a, a.T)
    np.fill_diagonal(a, 0.0)
    lap = np.diag(a.sum(1)) - a
    return lap + ridge * np.eye(n)


def random_sparse_spd(n: int, density: float, lam_min: float = 1e-2,
                      seed: int = 0) -> np.ndarray:
    """Paper Sec. 4.4 generator: sparse symmetric + diagonal shift."""
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((n, n)) * (rng.random((n, n)) < density)
    a = (m + m.T) / 2
    w = np.linalg.eigvalsh(a)
    return a + np.eye(n) * (lam_min - w[0])


def density(a: np.ndarray) -> float:
    return float((a != 0).sum()) / a.size
