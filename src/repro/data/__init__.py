from .dpp_selection import DPPBatchStream, DPPSelector  # noqa: F401
from .kernel_matrices import (density, graph_laplacian,  # noqa: F401
                              random_sparse_spd, rbf_kernel)
from .synthetic import DataConfig, TokenStream, sequence_embeddings  # noqa
