"""DPP diverse-batch selection — the paper's sampler as a data-pipeline
feature (DESIGN.md Sec. 4.1).

Per step: draw a candidate pool of ``pool_factor * batch`` sequences,
embed them (cheap random projection), build an RBF similarity kernel, and
run the retrospective k-DPP chain (Alg. 6/7, GQL-accelerated) to pick a
diverse subset of size ``batch``. Every MCMC accept/reject decision is
certified by quadrature bounds, so the selected set is a true k-DPP
sample — no approximation is introduced by the acceleration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import dpp as dpp_mod
from ..core import operators as ops_mod
from .synthetic import TokenStream, sequence_embeddings


class DPPSelector:
    def __init__(self, *, pool_factor: int = 4, bandwidth: float = 0.7,
                 ridge: float = 1e-3, steps_per_item: int = 4,
                 max_quad_iters: int = 48, seed: int = 0):
        self.pool_factor = pool_factor
        self.bandwidth = bandwidth
        self.ridge = ridge
        self.steps_per_item = steps_per_item
        self.max_quad_iters = max_quad_iters
        self.seed = seed
        self.last_stats = None

    def kernel(self, emb: np.ndarray) -> np.ndarray:
        d2 = ((emb[:, None, :] - emb[None, :, :]) ** 2).sum(-1)
        k = np.exp(-d2 / (2 * self.bandwidth ** 2))
        return k + self.ridge * np.eye(len(emb))

    def select(self, pool_tokens: np.ndarray, k: int, step: int = 0
               ) -> np.ndarray:
        """Returns indices of a diverse size-k subset of the pool."""
        n = len(pool_tokens)
        emb = sequence_embeddings(pool_tokens, seed=self.seed)
        kmat = self.kernel(emb)
        op = ops_mod.Dense(jnp.asarray(kmat, jnp.float32))
        # ridge gives a certain lower spectral bound; power-iterate the top
        from ..core import spectrum
        probe = jnp.asarray(np.random.default_rng(step).standard_normal(n),
                            jnp.float32)
        est = spectrum.lanczos_extremal(op, probe, num_iters=12)
        lam_min = float(self.ridge) * 0.5
        lam_max = float(est.lam_max)

        init = np.zeros(n, np.float32)
        init[np.random.default_rng((self.seed, step)).choice(
            n, k, replace=False)] = 1.0
        state = dpp_mod.sample_kdpp(
            op, jax.random.key(step), jnp.asarray(init),
            num_steps=self.steps_per_item * k, lam_min=lam_min,
            lam_max=lam_max, max_iters=self.max_quad_iters)
        self.last_stats = jax.tree.map(int, state.stats._asdict())
        idx = np.where(np.asarray(state.mask) > 0.5)[0]
        return idx[:k]


class DPPBatchStream:
    """TokenStream wrapper: oversample a pool, keep the k-DPP subset."""

    def __init__(self, stream: TokenStream, selector: DPPSelector):
        self.stream = stream
        self.selector = selector

    def batch_at(self, step: int) -> dict:
        cfg = self.stream.cfg
        pool_parts = [self.stream.batch_at(step * 131 + i)
                      for i in range(self.selector.pool_factor)]
        tokens = np.concatenate([np.asarray(p["tokens"])
                                 for p in pool_parts], 0)
        labels = np.concatenate([np.asarray(p["labels"])
                                 for p in pool_parts], 0)
        idx = self.selector.select(tokens, self.stream.local_batch,
                                   step=step)
        return {"tokens": jnp.asarray(tokens[idx]),
                "labels": jnp.asarray(labels[idx])}
