"""Deterministic synthetic token pipeline.

Host-sharded: each data-parallel host derives its stream from
(seed, host_id, step) so restarts resume exactly (fault tolerance) and no
two hosts ever see the same tokens. A real deployment swaps this for a
tokenized corpus reader with the same interface.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    selector: str = "uniform"      # uniform | dpp
    pool_factor: int = 4           # dpp: candidates per selected sequence


class TokenStream:
    """Stateless per-step batch generator (markov-ish synthetic text)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0,
                 num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for ``step`` (resume == replay)."""
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, self.host_id, step]))
        # zipf-ish marginal over vocab with local repetition structure
        base = rng.zipf(1.3, size=(self.local_batch, c.seq_len + 1))
        tokens = (base % (c.vocab - 2)) + 1
        # inject repeated spans (gives the model something learnable)
        span_hi = max(min(32, c.seq_len // 4), 2)
        for b in range(self.local_batch):
            span = int(rng.integers(1, span_hi))
            src = int(rng.integers(0, max(c.seq_len - 2 * span, 1)))
            dst = int(rng.integers(0, max(c.seq_len - span, 1)))
            tokens[b, dst:dst + span] = tokens[b, src:src + span]
        tokens = tokens.astype(np.int32)
        return {"tokens": jnp.asarray(tokens[:, :-1]),
                "labels": jnp.asarray(tokens[:, 1:])}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def sequence_embeddings(tokens: np.ndarray, dim: int = 64,
                        seed: int = 0) -> np.ndarray:
    """Cheap fixed random-projection bag-of-tokens embedding used by the
    DPP selector (B, dim), L2-normalized."""
    rng = np.random.default_rng(seed)
    vocab_hash = rng.standard_normal((4096, dim)).astype(np.float32)
    idx = np.asarray(tokens) % 4096
    emb = vocab_hash[idx].mean(axis=1)
    norm = np.linalg.norm(emb, axis=-1, keepdims=True) + 1e-8
    return emb / norm
