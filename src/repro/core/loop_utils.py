"""Shared helpers for lockstep-batched retrospective loops.

Every adaptive driver in this package advances a pytree of per-lane state
under ``lax.while_loop`` and must keep lanes that already resolved their
decision *bit-exactly* frozen while other lanes continue (DESIGN.md
Sec. 3.1). ``tree_freeze`` is the single implementation of that rule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_freeze(new, old, frozen):
    """Select ``old`` leaves wherever ``frozen`` is True, else ``new``.

    ``frozen`` is a boolean array over the batch (lane) dims; each leaf of
    the state pytree may carry extra trailing dims (e.g. Lanczos vectors of
    shape (..., N)), which are broadcast by appending singleton axes.
    ``new`` and ``old`` must share a treedef.
    """
    return jax.tree.map(
        lambda new_leaf, old_leaf: jnp.where(
            jnp.reshape(frozen,
                        frozen.shape + (1,) * (new_leaf.ndim - frozen.ndim)),
            old_leaf, new_leaf),
        new, old)
