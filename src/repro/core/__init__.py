"""Paper core: Gauss-type quadrature bounds for bilinear inverse forms.

The central abstraction is the **unified retrospective solver**: every
workload — adaptive brackets, threshold judges, pair judges — is one
configurable driver that iterates Gauss/Radau/Lobatto quadrature until the
bracket on ``u^T A^-1 u`` resolves the caller's decision (paper Alg. 2):

    from repro.core import BIFSolver, SolverConfig, Dense

    solver = BIFSolver(SolverConfig(
        max_iters=64, rtol=1e-3,        # stopping policy
        spectrum='lanczos',             # or 'explicit'|'gershgorin'|'ridge'
        precondition='jacobi',          # or 'none'        (Sec. 5.4)
        backend='pallas',               # or 'reference'   (fused VPU update)
    ))
    res = solver.solve(op, u)                       # SolveResult: bracket,
    #                                                 iterations, certified
    res = solver.solve(op, u, decide=lambda lo, hi: (t < lo) | (t >= hi))
    jt  = solver.judge_threshold(op, u, t)          # Alg. 4
    jk  = solver.judge_kdpp_swap(op_a, u, op_b, v, t, p)    # Alg. 7
    jd  = solver.judge_double_greedy(op_x, u, op_y, v, t, p)  # Alg. 9
    tr  = solver.trace(op, u, num_iters=30)         # Fig. 1 sequences

``BIFSolver``/``SolverConfig`` are frozen and pytree-static: safe to close
over or pass through ``jit``/``vmap``/``scan``.

Batched execution (DESIGN.md Sec. 6): ``solve_batch``/``judge_batch``
run K candidate systems as lockstep lanes of one driver (one stacked
matvec per iteration, per-lane early exit), and ``judge_argmax`` races
lanes to a certified best candidate — greedy MAP's inner loop::

    op2 = stack_masks(base_op, masks)               # K submatrices, shared base
    res = solver.judge_batch(op2, us, ts)           # K judges, one loop
    am  = solver.judge_argmax(op2, us, shift=d, scale=-1.0)

Device sharding (DESIGN.md Sec. 7): the K lanes split across a 1-D
``lanes`` mesh via ``shard_map`` — ``solve_batch_sharded`` /
``judge_batch_sharded`` / ``judge_argmax_sharded`` (or the bound
``ShardedBIFSolver``), with per-lane results matching the single-device
batched path exactly::

    mesh = launch.mesh.make_lane_mesh()             # all local devices
    am = solver.judge_argmax_sharded(op2, us, shift=d, scale=-1.0,
                                     mesh=mesh)

Matrix functions beyond f=1/x (DESIGN.md Sec. 9): ``SolverConfig.fn``
picks a spectral function from the matfun registry ('inv' | 'log' |
'invsqrt' | 'sqrt') and the same runtime brackets ``u^T f(A) u`` with
sign-aware orientation; ``trace_quad`` runs Hutchinson (or exact unit)
probes as lanes for bracketed ``tr f(A)`` — ``logdet_quad`` /
``dpp.log_likelihood`` are the logdet workloads on top::

    s = BIFSolver.create(max_iters=64, rtol=1e-4, fn='log')
    res = s.solve(op, u, lam_min=lmn, lam_max=lmx)  # brackets u^T log(A) u
    ld = trace_quad(op, 'log', None, lam_min=lmn, lam_max=lmx)  # logdet

Block-Krylov mode (DESIGN.md Sec. 13): ``SolverConfig(block_size=b)``
runs each lane as a b-wide probe BLOCK through the block three-term
recurrence (core/block.py) and brackets ``tr B^T f(A) B`` with
matrix-valued Gauss/Radau rules — one gemm per iteration instead of b
gemvs, near-parallel probes deflate. ``trace_quad(block_size=b)``
groups its Hutchinson probes into blocks on the same stream::

    s = BIFSolver.create(max_iters=32, fn='log', block_size=8)
    res = s.solve_batch(op, zs, lam_min=lmn, lam_max=lmx)  # zs: (K, 8, N)
    tr = trace_quad(op, 'log', 64, block_size=8, lam_min=lmn, lam_max=lmx)

Public API:

  solver.{BIFSolver, SolverConfig, SolveResult, JudgeResult,
          ArgmaxResult, QuadratureTrace}            -- THE entry point
  matfun.{REGISTRY, SpectralFn, CoeffHistory}       -- u^T f(A) u brackets
  block.{BlockState, block_init, block_step}        -- tr B^T f(A) B blocks
  trace.{trace_quad, logdet_quad, TraceQuadResult}  -- stochastic traces
  dpp.log_likelihood                                -- bracketed log P(Y)
  sharded.{ShardedBIFSolver, solve_batch_sharded, judge_batch_sharded,
           judge_argmax_sharded, judge_kdpp_swap_batch_sharded}
  operators.{lane_specs, shard_ops}                 -- lane placement
  operators.{Dense, SparseCOO, SparseBELL, Masked, Shifted, Jacobi,
             MatvecFn, stack_ops, stack_masks}
  gql.{gql_init, gql_step, GQLState}               -- Alg. 5 stepping
  dpp.{sample_dpp, sample_kdpp, dpp_step, kdpp_step, greedy_map}
  double_greedy.double_greedy
  spectrum.{lanczos_extremal, gershgorin_bounds, ridge_bounds}
  loop_utils.tree_freeze                           -- lane freezing (once)
  bounds.{bif_bounds_trace, BIFTrace, BIFBounds}   -- Fig. 1 sequences

The PR-2 deprecation shims (``bif_bounds``, ``bif_refine_until``,
``judge_threshold``, ``judge_kdpp_swap``, ``judge_double_greedy``,
``preconditioned_bif_bounds``) were removed on DESIGN.md Sec. 5's
schedule — use the ``BIFSolver.create(...)`` equivalents; quadlint
QL005 (``python -m repro.analysis``) keeps them from coming back.
"""
from . import block, bounds, double_greedy, dpp, gql, lanczos, \
    loop_utils, matfun, operators, sharded, solver, spectrum, \
    trace, update  # noqa: F401

from .solver import ArgmaxResult, BIFSolver, JudgeResult, PairState, \
    QuadratureTrace, QuadState, SolveResult, SolverConfig  # noqa: F401
from .block import BlockState  # noqa: F401
from .sharded import ShardedBIFSolver  # noqa: F401
from .loop_utils import tree_freeze  # noqa: F401
from .matfun import CoeffHistory, SpectralFn  # noqa: F401
from .trace import TraceQuadResult, TraceQuadState, logdet_quad, \
    trace_quad  # noqa: F401
from .operators import Dense, Jacobi, Masked, MatvecFn, Shifted, SparseBELL, \
    SparseCOO, bell_from_dense, lane_specs, shard_ops, sparse_from_dense, \
    stack_masks, stack_ops  # noqa: F401
from .dpp import ChainState, GreedyMapResult, LogLikelihoodResult, \
    greedy_map, log_likelihood, sample_dpp, sample_kdpp  # noqa: F401
from .update import ChainFactor  # noqa: F401
from .double_greedy import DGResult, double_greedy as run_double_greedy  # noqa: F401
from .spectrum import SpectrumBounds, gershgorin_bounds, lanczos_extremal, \
    ridge_bounds  # noqa: F401
from .bounds import BIFBounds, BIFTrace, bif_bounds_trace  # noqa: F401
