"""Paper core: Gauss-type quadrature bounds for bilinear inverse forms.

Public API:

  operators.{Dense, SparseCOO, Masked, Shifted, Jacobi, MatvecFn}
  gql.{gql_init, gql_step, GQLState}            -- Alg. 5 stepping
  bounds.{bif_bounds, bif_bounds_trace}         -- brackets on u^T A^-1 u
  judge.{judge_threshold, judge_kdpp_swap, judge_double_greedy}
  dpp.{sample_dpp, sample_kdpp, dpp_step, kdpp_step}
  double_greedy.double_greedy
  spectrum.{lanczos_extremal, gershgorin_bounds, ridge_bounds}
  precond.preconditioned_bif_bounds
"""
from . import bounds, double_greedy, dpp, gql, judge, lanczos, operators, \
    precond, spectrum  # noqa: F401

from .bounds import BIFBounds, BIFTrace, bif_bounds, bif_bounds_trace  # noqa: F401
from .double_greedy import DGResult, double_greedy as run_double_greedy  # noqa: F401
from .dpp import ChainState, sample_dpp, sample_kdpp  # noqa: F401
from .judge import JudgeResult, judge_double_greedy, judge_kdpp_swap, \
    judge_threshold  # noqa: F401
from .operators import Dense, Jacobi, Masked, MatvecFn, Shifted, SparseCOO, \
    sparse_from_dense  # noqa: F401
from .spectrum import SpectrumBounds, gershgorin_bounds, lanczos_extremal, \
    ridge_bounds  # noqa: F401
