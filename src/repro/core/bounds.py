"""User-facing BIF bound computation (fixed-trace and adaptive).

``bif_bounds_trace`` reproduces paper Fig. 1 (all four estimate sequences);
``bif_bounds`` is the production entry point: a ``lax.while_loop`` that
stops as soon as every lane's bracket [g^rr, g^lr] is tight enough — the
building block of the retrospective framework (Alg. 2).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import gql as _gql

Array = jax.Array


class BIFTrace(NamedTuple):
    gauss: Array       # (iters, ...) lower
    radau_lower: Array  # (iters, ...) right Gauss-Radau
    radau_upper: Array  # (iters, ...) left Gauss-Radau
    lobatto: Array     # (iters, ...) upper


class BIFBounds(NamedTuple):
    lower: Array
    upper: Array
    iterations: Array
    converged: Array


def bif_bounds_trace(op, u: Array, lam_min, lam_max, num_iters: int,
                     reorth: bool = False) -> BIFTrace:
    """Run exactly ``num_iters`` GQL iterations, returning every estimate."""
    st = _gql.gql_init(op, u, lam_min, lam_max)
    scale = st.u_norm_sq

    basis0 = None
    if reorth:
        # Rows 0..num_iters hold v_0 .. v_{num_iters}; unfilled rows are zero.
        basis0 = jnp.zeros(u.shape[:-1] + (num_iters + 1, u.shape[-1]), u.dtype)
        basis0 = jax.lax.dynamic_update_index_in_dim(
            basis0, st.lz.v_prev, 0, axis=-2)  # v_0
        basis0 = jax.lax.dynamic_update_index_in_dim(
            basis0, st.lz.v, 1, axis=-2)       # v_1

    def body(carry, i):
        st, basis = carry
        st1 = _gql.gql_step(op, st, lam_min, lam_max, basis=basis)
        if reorth:
            basis = jax.lax.dynamic_update_index_in_dim(
                basis, st1.lz.v, i + 2, axis=-2)  # v_{i+2}
        out = (st1.g * scale, st1.g_rr * scale, st1.g_lr * scale,
               st1.g_lo * scale)
        return (st1, basis), out

    first = (st.g * scale, st.g_rr * scale, st.g_lr * scale, st.g_lo * scale)
    (_, _), rest = jax.lax.scan(body, (st, basis0),
                                jnp.arange(num_iters - 1))
    seqs = [jnp.concatenate([f[None], r], axis=0) for f, r in zip(first, rest)]
    return BIFTrace(*seqs)


def bif_bounds(op, u: Array, lam_min, lam_max, *, max_iters: int,
               rtol: float = 1e-2, atol: float = 0.0) -> BIFBounds:
    """Adaptive bracket on u^T A^-1 u, batched with lockstep early exit."""

    def needs_more(st: _gql.GQLState) -> Array:
        gap = (st.g_lr - st.g_rr) * st.u_norm_sq
        tight = gap <= jnp.maximum(atol, rtol * jnp.abs(_gql.lower_bound(st)))
        return ~st.done & ~tight & (st.it < max_iters)

    st = _gql.gql_init(op, u, lam_min, lam_max)

    def cond(st):
        return jnp.any(needs_more(st))

    def body(st):
        st1 = _gql.gql_step(op, st, lam_min, lam_max)
        # freeze lanes that no longer need refinement
        frozen = ~needs_more(st)
        return jax.tree.map(
            lambda new, old: jnp.where(
                jnp.reshape(frozen, frozen.shape + (1,) * (new.ndim - frozen.ndim)),
                old, new),
            st1, st)

    st = jax.lax.while_loop(cond, body, st)
    gap = (st.g_lr - st.g_rr) * st.u_norm_sq
    conv = st.done | (gap <= jnp.maximum(atol, rtol * jnp.abs(_gql.lower_bound(st))))
    return BIFBounds(lower=_gql.lower_bound(st), upper=_gql.upper_bound(st),
                     iterations=st.it, converged=conv)


def bif_refine_until(op, u: Array, lam_min, lam_max, *, max_iters: int,
                     decided_fn: Callable[[Array, Array], Array]):
    """Generic retrospective loop (Alg. 2): iterate GQL until
    ``decided_fn(lower, upper)`` is True on every lane (or exhaustion).

    Returns the final GQLState; the caller extracts its decision from the
    final bracket, which is guaranteed to contain the true BIF, so the
    decision matches the exact-value decision whenever decided_fn resolved.
    """
    st = _gql.gql_init(op, u, lam_min, lam_max)

    def needs_more(st):
        dec = decided_fn(_gql.lower_bound(st), _gql.upper_bound(st))
        return ~st.done & ~dec & (st.it < max_iters)

    def cond(st):
        return jnp.any(needs_more(st))

    def body(st):
        st1 = _gql.gql_step(op, st, lam_min, lam_max)
        frozen = ~needs_more(st)
        return jax.tree.map(
            lambda new, old: jnp.where(
                jnp.reshape(frozen, frozen.shape + (1,) * (new.ndim - frozen.ndim)),
                old, new),
            st1, st)

    return jax.lax.while_loop(cond, body, st)
