"""Legacy BIF bound entry points — thin shims over ``solver.BIFSolver``.

``bif_bounds_trace`` reproduces paper Fig. 1 (all four estimate sequences);
``bif_bounds`` adaptively brackets ``u^T A^-1 u``; ``bif_refine_until`` is
the generic retrospective loop (Alg. 2).  All three are deprecated aliases
kept for API stability: new code should configure a
:class:`repro.core.solver.BIFSolver` and call ``solve``/``trace`` directly
(which also unlocks spectrum estimation, Jacobi preconditioning, and the
fused Pallas backend through one interface).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax

from . import solver as _solver
from .deprecation import warn_once as _warn_once

Array = jax.Array

# Re-exported so existing ``bounds.BIFTrace`` consumers keep working.
BIFTrace = _solver.QuadratureTrace


class BIFBounds(NamedTuple):
    lower: Array
    upper: Array
    iterations: Array
    converged: Array


def bif_bounds_trace(op, u: Array, lam_min, lam_max, num_iters: int,
                     reorth: bool = False) -> BIFTrace:
    """Run exactly ``num_iters`` GQL iterations, returning every estimate.

    .. deprecated:: use ``BIFSolver(SolverConfig(reorth=...)).trace(...)``.
    """
    return _solver.BIFSolver.create(reorth=reorth).trace(
        op, u, num_iters, lam_min=lam_min, lam_max=lam_max)


def bif_bounds(op, u: Array, lam_min, lam_max, *, max_iters: int,
               rtol: float = 1e-2, atol: float = 0.0) -> BIFBounds:
    """Adaptive bracket on u^T A^-1 u, batched with lockstep early exit.

    .. deprecated:: use ``BIFSolver(SolverConfig(...)).solve(op, u, ...)``,
       whose ``SolveResult`` also carries the Gauss/Lobatto estimates,
       certification, and the final quadrature state.
    """
    _warn_once("bounds.bif_bounds", "BIFSolver.solve")
    res = _solver.BIFSolver.create(
        max_iters=max_iters, rtol=rtol, atol=atol).solve(
            op, u, lam_min=lam_min, lam_max=lam_max)
    return BIFBounds(lower=res.lower, upper=res.upper,
                     iterations=res.iterations, converged=res.converged)


def bif_refine_until(op, u: Array, lam_min, lam_max, *, max_iters: int,
                     decided_fn: Callable[[Array, Array], Array]):
    """Generic retrospective loop (Alg. 2): iterate GQL until
    ``decided_fn(lower, upper)`` is True on every lane (or exhaustion).

    Returns the final GQLState; the caller extracts its decision from the
    final bracket, which is guaranteed to contain the true BIF, so the
    decision matches the exact-value decision whenever decided_fn resolved.

    .. deprecated:: use ``BIFSolver(...).solve(op, u, decide=decided_fn,
       ...)`` and read ``SolveResult.state`` (a resumable ``QuadState``
       whose ``.st`` is this GQLState).
    """
    _warn_once("bounds.bif_refine_until", "BIFSolver.solve(decide=...)")
    return _solver.BIFSolver.create(max_iters=max_iters).solve(
        op, u, decide=decided_fn, lam_min=lam_min, lam_max=lam_max).state.st
