"""Fig.-1 trace entry point and the legacy result container.

``bif_bounds_trace`` reproduces paper Fig. 1 (all four estimate
sequences) as sugar over ``BIFSolver.trace``; :class:`BIFBounds` is the
lean (lower, upper, iterations, converged) result tuple a few consumers
(train/monitor.py) prefer over the full :class:`SolveResult`.

The PR-2 deprecation shims that used to live here (``bif_bounds``,
``bif_refine_until``) were removed on DESIGN.md Sec. 5's schedule:
configure a :class:`repro.core.solver.BIFSolver` and call
``solve``/``trace`` directly (quadlint QL005 keeps the shims out).
"""
from __future__ import annotations

from typing import NamedTuple

import jax

from . import solver as _solver

Array = jax.Array

# Re-exported so existing ``bounds.BIFTrace`` consumers keep working.
BIFTrace = _solver.QuadratureTrace


class BIFBounds(NamedTuple):
    lower: Array
    upper: Array
    iterations: Array
    converged: Array


def bif_bounds_trace(op, u: Array, lam_min, lam_max, num_iters: int,
                     reorth: bool = False) -> BIFTrace:
    """Run exactly ``num_iters`` GQL iterations, returning every
    estimate sequence (sugar over ``BIFSolver.trace``)."""
    return _solver.BIFSolver.create(reorth=reorth).trace(
        op, u, num_iters, lam_min=lam_min, lam_max=lam_max)
