"""Batched Lanczos iteration.

The tridiagonalization driving GQL (paper Alg. 5) and the extremal
eigenvalue estimates (spectrum.py). All state carries arbitrary leading
batch dims; the TPU execution model is lockstep-batched with masked
freezing (DESIGN.md Sec. 3.1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

BREAKDOWN_TOL = 1e-12


class LanczosState(NamedTuple):
    v_prev: Array   # (..., N) u_{i-2}
    v: Array        # (..., N) u_{i-1}
    alpha: Array    # (...,)  alpha_i   (diagonal entry produced this step)
    beta: Array     # (...,)  beta_i    (off-diagonal produced this step)
    beta_prev: Array  # (...,) beta_{i-1}
    it: Array       # (...,) int32 iteration counter (1-based)
    live: Array     # (...,) bool — False after breakdown (Krylov exhausted)


def lanczos_init(op, u: Array) -> LanczosState:
    """First Lanczos step: alpha_1 = u0^T A u0, beta_1 = ||(A - a1 I) u0||."""
    unorm = jnp.linalg.norm(u, axis=-1, keepdims=True)
    v0 = u / jnp.maximum(unorm, 1e-30)
    w = op.matvec(v0)
    alpha1 = jnp.sum(v0 * w, axis=-1)
    r = w - alpha1[..., None] * v0
    beta1 = jnp.linalg.norm(r, axis=-1)
    live = beta1 > BREAKDOWN_TOL * jnp.maximum(jnp.abs(alpha1), 1.0)
    v1 = jnp.where(live[..., None], r / jnp.maximum(beta1, 1e-30)[..., None], 0.0)
    it = jnp.ones(alpha1.shape, jnp.int32)
    return LanczosState(v_prev=v0, v=v1, alpha=alpha1, beta=beta1,
                        beta_prev=jnp.zeros_like(beta1), it=it, live=live)


def lanczos_assemble(st: LanczosState, alpha: Array, beta: Array,
                     r: Array) -> LanczosState:
    """Fold one step's raw outputs (``alpha``, ``beta = ||r||``, residual
    ``r``) into the next state: breakdown detection, residual
    normalization, and pass-through of frozen lanes. The ONE home for
    this select logic — shared by :func:`lanczos_step` and the fused
    step kernel (``kernels/lanczos_step.py``), so the two routes cannot
    drift. Dead lanes (``st.live`` False) may carry garbage in the raw
    inputs; every output masks them back to the old state."""
    still = st.live & (beta > BREAKDOWN_TOL * jnp.maximum(jnp.abs(alpha), 1.0))
    v_new = jnp.where(still[..., None], r / jnp.maximum(beta, 1e-30)[..., None], 0.0)

    keep = st.live
    return LanczosState(
        v_prev=jnp.where(keep[..., None], st.v, st.v_prev),
        v=jnp.where(keep[..., None], v_new, st.v),
        alpha=jnp.where(keep, alpha, st.alpha),
        beta=jnp.where(keep, beta, st.beta),
        beta_prev=jnp.where(keep, st.beta, st.beta_prev),
        it=st.it + keep.astype(jnp.int32),
        live=still,
    )


def lanczos_step(op, st: LanczosState, basis: Array | None = None) -> LanczosState:
    """One three-term-recurrence step; frozen lanes are passed through.

    ``basis``: optional (..., M, N) stored Lanczos vectors for full
    reorthogonalization (paper Sec. 5.4 'Instability'); rows past the
    current iteration must be zero.
    """
    w = op.matvec(st.v)
    alpha = jnp.sum(st.v * w, axis=-1)
    r = w - alpha[..., None] * st.v - st.beta[..., None] * st.v_prev
    if basis is not None:
        # r <- r - V^T (V r): one pass of classical Gram-Schmidt against all
        # stored vectors (zero rows contribute nothing).
        coeff = jnp.einsum("...mn,...n->...m", basis, r)
        r = r - jnp.einsum("...mn,...m->...n", basis, coeff)
    beta = jnp.linalg.norm(r, axis=-1)
    return lanczos_assemble(st, alpha, beta, r)


def tridiag_coefficients(op, u: Array, num_iters: int):
    """Run ``num_iters`` Lanczos steps, returning (alphas, betas, valid).

    alphas: (num_iters, ...), betas: (num_iters, ...) with beta_i the
    off-diagonal produced at step i; valid[i] marks pre-breakdown entries.
    Mostly used by oracles/tests; GQL consumes the state stream directly.
    """
    st0 = lanczos_init(op, u)

    def body(st, _):
        st1 = lanczos_step(op, st)
        return st1, (st1.alpha, st1.beta, st1.live)

    _, (al, be, lv) = jax.lax.scan(body, st0, None, length=num_iters - 1)
    alphas = jnp.concatenate([st0.alpha[None], al], axis=0)
    betas = jnp.concatenate([st0.beta[None], be], axis=0)
    valid = jnp.concatenate([st0.live[None], lv], axis=0)
    return alphas, betas, valid
