"""Preconditioned quadrature (paper Sec. 5.4).

u^T A^-1 u == (Cu)^T (C A C)^-1 (Cu) for symmetric non-singular C; with
C = diag(A)^{-1/2} (Jacobi) the transformed matrix has unit diagonal and
typically a far smaller kappa, which the linear rate (√kappa-1)/(√kappa+1)
turns directly into fewer iterations-to-decide.

This whole module collapsed into one solver configuration::

    BIFSolver(SolverConfig(precondition='jacobi', spectrum='lanczos', ...))

``preconditioned_bif_bounds`` stays as the legacy shim.
"""
from __future__ import annotations

from . import bounds as _bounds
from . import solver as _solver
from .deprecation import warn_once as _warn_once


def preconditioned_bif_bounds(op, u, *, max_iters: int, rtol: float = 1e-2,
                              atol: float = 0.0, probe=None,
                              spectrum_iters: int = 16):
    """Jacobi-preconditioned adaptive bounds on u^T A^-1 u.

    The spectral interval is estimated on the *transformed* operator
    (whose kappa governs convergence). Returns the same BIFBounds as
    ``bounds.bif_bounds`` — the value is invariant under the transform.

    .. deprecated:: use ``BIFSolver(SolverConfig(precondition='jacobi',
       spectrum='lanczos', ...))`` directly.
    """
    _warn_once("precond.preconditioned_bif_bounds",
               "BIFSolver with SolverConfig(precondition='jacobi')")
    res = _solver.BIFSolver.create(
        max_iters=max_iters, rtol=rtol, atol=atol, precondition="jacobi",
        spectrum="lanczos", spectrum_iters=spectrum_iters).solve(
            op, u, probe=probe)
    return _bounds.BIFBounds(lower=res.lower, upper=res.upper,
                             iterations=res.iterations,
                             converged=res.converged)
