"""Preconditioned quadrature (paper Sec. 5.4).

u^T A^-1 u == (Cu)^T (C A C)^-1 (Cu) for symmetric non-singular C; with
C = diag(A)^{-1/2} (Jacobi) the transformed matrix has unit diagonal and
typically a far smaller kappa, which the linear rate (√kappa-1)/(√kappa+1)
turns directly into fewer iterations-to-decide.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import bounds as _bounds
from . import operators as _ops
from . import spectrum as _spectrum


def preconditioned_bif_bounds(op, u, *, max_iters: int, rtol: float = 1e-2,
                              atol: float = 0.0, probe=None,
                              spectrum_iters: int = 16):
    """Jacobi-preconditioned adaptive bounds on u^T A^-1 u.

    The spectral interval is estimated on the *transformed* operator
    (whose kappa governs convergence). Returns the same BIFBounds as
    ``bounds.bif_bounds`` — the value is invariant under the transform.
    """
    pop = _ops.Jacobi.create(op)
    cu = pop.transform_vector(u)
    if probe is None:
        probe = jnp.where(jnp.abs(cu) > 0, cu, jnp.ones_like(cu))
    est = _spectrum.lanczos_extremal(pop, probe, num_iters=spectrum_iters)
    return _bounds.bif_bounds(pop, cu, est.lam_min, est.lam_max,
                              max_iters=max_iters, rtol=rtol, atol=atol)
