"""Stochastic trace estimation on the quadrature runtime (DESIGN.md Sec. 9).

``tr f(A) = E[z^T f(A) z]`` for Rademacher probes z (Hutchinson), and
each probe's bilinear form gets a RETROSPECTIVE quadrature bracket from
the matfun drive (core/matfun.py) — so the estimator inherits the
paper's machinery wholesale: probes run as lanes of the batched (or
device-sharded) driver, tighten monotonically, freeze per-lane the
moment their bracket resolves, and are resumable probe-by-probe.

Two probe regimes:

  * ``num_probes=None`` — EXACT mode: the N unit vectors e_i. The
    probe sum IS ``tr f(A)`` (no stochastic error), so the combined
    bracket is a deterministic certificate containing the true trace.
    This is what ``dpp.log_likelihood`` uses for bracketed logdet
    normalizers.
  * ``num_probes=P`` — Hutchinson mode: P Rademacher probes, drawn as
    ``fold_in(key, i)`` per probe index so the stream is reproducible
    and EXTENDABLE (resuming with a larger ``num_probes`` adds probes
    without re-running the old ones). The deterministic bracket then
    contains the probe-sample mean (not the trace itself); the
    statistical interval widens it by a normal-approximation
    confidence-interval half-width over the probe midpoints.

Interval semantics (the ``TraceQuadResult`` fields):

    lower/upper            deterministic quadrature bracket on the
                           CURRENT probe-sample mean — retrospective,
                           tightens with more quadrature iterations,
                           contains tr f(A) exactly in exact mode
    estimate               mean of the per-probe bracket midpoints
    stat_lower/stat_upper  [lower, upper] widened by the CI half-width
                           z_conf * std(mid)/sqrt(P) — covers BOTH error
                           sources (quadrature + sampling); collapses to
                           the deterministic bracket in exact mode
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import solver as _solver

Array = jax.Array


class TraceQuadState(NamedTuple):
    """Probe-by-probe resume handle: which probes ran, and their banked
    brackets. Host-side bookkeeping (numpy), cheap to checkpoint.
    ``key_fp``/``interval`` fingerprint the probe stream and the
    spectral interval so a resume with a different key or lam bounds is
    rejected instead of silently mixing incompatible probes."""
    fn: str
    count: int                 # probes consumed so far
    exact: bool                # unit-vector mode (num_probes=None)
    probe_lower: np.ndarray    # (lanes,) per-lane bracket lowers; a lane
    #                            is one probe (block_size=1) or one
    #                            b-probe block (block_size=b)
    probe_upper: np.ndarray    # (lanes,)
    iterations: np.ndarray     # (lanes,) quadrature iterations per lane
    key_fp: tuple = ()         # PRNG-key fingerprint (empty in exact mode)
    interval: tuple = ()       # (lam_min, lam_max) the brackets used
    block_size: int = 1        # probes per lane (DESIGN.md Sec. 13);
    #                            resumes must match — banked lane
    #                            brackets are tr over b-probe blocks


class TraceQuadResult(NamedTuple):
    lower: float               # deterministic bracket on the probe mean
    upper: float
    estimate: float            # mean of per-probe bracket midpoints
    stat_lower: float          # det bracket widened by the CI half-width
    stat_upper: float
    std_error: float           # std(mid) / sqrt(P)  (0.0 in exact mode)
    num_probes: int
    iterations: int            # total quadrature iterations spent
    state: TraceQuadState      # resume handle (probe-by-probe)


def _rademacher_probe(key: Array, index: int, n: int, dtype) -> Array:
    """Probe ``index`` of the reproducible Hutchinson stream (tests and
    resumed runs re-derive the identical probe from (key, index))."""
    return jax.random.rademacher(jax.random.fold_in(key, index), (n,),
                                 dtype)


def _probes(key, start: int, stop: int, n: int, dtype, exact: bool):
    if exact:
        # only the chunk's rows of I_N — never the full (N, N) identity,
        # which would defeat probe_chunk's memory bounding at large N.
        # Indices >= n (block-mode padding of the last block) produce
        # exact-zero rows, which the block init QR deflates: dead slots
        # contribute exactly 0 to the block trace.
        return jax.nn.one_hot(jnp.arange(start, stop), n, dtype=dtype)
    # one vmapped draw over the index range: bit-identical to per-index
    # _rademacher_probe calls (fold_in per index), one dispatch per chunk
    return jax.vmap(lambda i: _rademacher_probe(key, i, n, dtype))(
        jnp.arange(start, stop))


def trace_quad(op, fn: str = "log", num_probes: Optional[int] = None, *,
               lam_min, lam_max, solver: _solver.BIFSolver | None = None,
               max_iters: int = 64, rtol: float = 1e-4, atol: float = 1e-8,
               key: Array | None = None, probe_chunk: int | None = None,
               confidence: float = 0.95, mesh=None,
               lane_axis: str = "lanes", block_size: int = 1,
               state: TraceQuadState | None = None) -> TraceQuadResult:
    """Bracketed stochastic (or exact-probe) estimate of ``tr f(A)``.

    Probes run as lanes of the batched matfun driver — one stacked
    matvec per quadrature iteration across the whole probe block, lanes
    frozen as their brackets resolve — sharded over ``mesh`` when given
    (the multi-device trace-probe path of tests/sharded_check.py).

    ``state`` resumes probe-by-probe: pass a previous result's
    ``.state`` with a larger ``num_probes`` and only the NEW probes are
    solved; the accumulated per-probe brackets merge deterministically
    (the probe stream is keyed by index). ``fn``/mode must match the
    banked state.

    ``block_size = b > 1`` groups consecutive probes into b-wide blocks
    and runs each block as ONE lane of the block-Krylov driver
    (DESIGN.md Sec. 13): a lane brackets ``tr Z^T f(A) Z`` over its b
    probes — one gemm-shaped stacked matvec per iteration instead of b
    gemvs — and near-parallel probe directions deflate instead of
    burning separate Krylov chains. ``num_probes`` must be a multiple
    of b (whole blocks); the probe STREAM is unchanged (probe i is
    still ``fold_in(key, i)``), so extending a banked state adds whole
    blocks bit-identically. The CI is over the per-block means
    (block bracket midpoint / b), each an unbiased ``tr f(A)``
    estimate. In exact mode the last block zero-pads past N; zero
    columns deflate at the init QR and contribute exactly 0.

    ``lam_min``/``lam_max`` must bound the operator's spectrum (the
    Radau bounds need true outer estimates — the usual contract). Note
    the trace is of the operator AS GIVEN: for a ``Masked`` operator
    the identity block contributes ``(N - |Y|) * f(1)`` — zero for
    f=log, which is exactly why masked logdets need no correction.
    """
    b = int(block_size)
    if b < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    if solver is None:
        solver = _solver.BIFSolver.create(max_iters=max_iters, rtol=rtol,
                                          atol=atol, fn=fn,
                                          block_size=b)
    else:
        if solver.config.fn != fn:
            solver = solver.replace(fn=fn)  # SolverConfig validates the tag
        if solver.config.block_size != b:
            solver = solver.replace(block_size=b)

    n = op.n
    exact = num_probes is None
    total = n if exact else int(num_probes)
    if total < 1:
        raise ValueError(f"num_probes must be >= 1, got {num_probes}")
    if b > 1 and not exact and total % b:
        raise ValueError(
            f"num_probes={total} is not a multiple of block_size={b}; "
            f"block mode consumes whole probe blocks (the banked probe "
            f"stream stays extendable only on block boundaries)")
    if key is None:
        key = jax.random.key(0)
    key_fp = () if exact else \
        tuple(np.asarray(jax.random.key_data(key)).ravel().tolist())
    interval = tuple(np.asarray(x, np.float64).ravel().tolist()
                     for x in (lam_min, lam_max))

    if state is not None:
        if state.fn != fn or state.exact != exact:
            raise ValueError(
                f"resume state banks fn={state.fn!r} (exact={state.exact}); "
                f"got fn={fn!r} (exact={exact}) — trace states resume the "
                f"estimator they were started as")
        if state.key_fp != key_fp:
            raise ValueError(
                "resume state banks probes drawn from a different key; "
                "extending with a new key would mix incompatible probe "
                "streams — pass the original key (or state=None)")
        if state.interval != interval:
            raise ValueError(
                f"resume state banks brackets for the spectral interval "
                f"{state.interval}, got {interval} — mixed intervals "
                f"would mix incomparable brackets (pass state=None)")
        if state.block_size != b:
            raise ValueError(
                f"resume state banks block_size={state.block_size} lane "
                f"brackets; got block_size={b} — block traces are "
                f"tr over b-probe blocks and cannot be re-bucketed "
                f"(pass state=None)")
        if total < state.count:
            raise ValueError(
                f"num_probes={total} < {state.count} probes already banked; "
                f"resuming can only extend")
        done_lo = [state.probe_lower]
        done_hi = [state.probe_upper]
        done_it = [state.iterations]
        start = state.count
    else:
        done_lo, done_hi, done_it = [], [], []
        start = 0

    dtype = np.asarray(op.diag()).dtype
    # block mode walks padded probe indices (whole blocks; exact mode's
    # final block zero-pads past N) and rounds the chunk up to blocks
    walk_total = -(-total // b) * b
    chunk = walk_total - start if probe_chunk is None \
        else max(int(probe_chunk), 1)
    if b > 1:
        chunk = -(-chunk // b) * b
    pos = -(-start // b) * b   # banked lanes end on a block boundary
    while pos < walk_total:
        stop = min(pos + chunk, walk_total)
        us = _probes(key, pos, stop, n, dtype, exact)
        if b > 1:
            us = us.reshape((stop - pos) // b, b, n)
        if mesh is None:
            res = solver.solve_batch(op, us, lam_min=lam_min,
                                     lam_max=lam_max)
        else:
            res = solver.solve_batch_sharded(op, us, mesh=mesh,
                                             axis=lane_axis,
                                             lam_min=lam_min,
                                             lam_max=lam_max)
        done_lo.append(np.asarray(res.lower))
        done_hi.append(np.asarray(res.upper))
        done_it.append(np.asarray(res.iterations))
        pos = stop

    lo = np.concatenate(done_lo) if done_lo else np.zeros((0,), dtype)
    hi = np.concatenate(done_hi) if done_hi else np.zeros((0,), dtype)
    it = np.concatenate(done_it) if done_it \
        else np.zeros((0,), np.int32)

    # deterministic bracket: in exact mode the SUM over the N unit
    # probes is tr f(A) (a true certificate; block lanes sum their b
    # slots already, padding slots contribute exactly 0); in Hutchinson
    # mode the MEAN over the lanes, divided by the probes-per-lane b,
    # is the sample estimate of it. The CI is over the per-lane means
    # mid/b — each an unbiased tr f(A) estimate (the variance-reduced
    # block estimator: a lane averages b probes).
    mid = 0.5 * (lo + hi)
    if exact:
        mean_lo, mean_hi = float(lo.sum()), float(hi.sum())
        estimate = float(mid.sum())
        se = 0.0
    else:
        lane_mid = mid / b
        mean_lo, mean_hi = float(lo.mean() / b), float(hi.mean() / b)
        estimate = float(lane_mid.mean())
        se = float(np.std(lane_mid, ddof=1) / np.sqrt(len(lane_mid))) \
            if len(lane_mid) > 1 else 0.0
    from jax.scipy.special import ndtri
    z = float(ndtri(0.5 + 0.5 * confidence)) if se > 0.0 else 0.0
    half = z * se

    new_state = TraceQuadState(fn=fn, count=total, exact=exact,
                               probe_lower=lo, probe_upper=hi,
                               iterations=it, key_fp=key_fp,
                               interval=interval, block_size=b)
    return TraceQuadResult(
        lower=mean_lo, upper=mean_hi, estimate=estimate,
        stat_lower=mean_lo - half, stat_upper=mean_hi + half,
        std_error=se, num_probes=total,
        iterations=int(it.sum()), state=new_state)


def logdet_quad(op, num_probes: Optional[int] = None, *, lam_min, lam_max,
                **kwargs) -> TraceQuadResult:
    """Bracketed ``logdet(A) = tr log(A)``  (Bai & Golub 1996, on the
    retrospective runtime): sugar for ``trace_quad(op, 'log', ...)``."""
    return trace_quad(op, "log", num_probes, lam_min=lam_min,
                      lam_max=lam_max, **kwargs)
