"""Extremal-eigenvalue estimation for the quadrature interval.

Gauss-Radau/Lobatto need lam_min < lambda_1(A) and lam_max > lambda_N(A).
Two estimators:

  * ``gershgorin_bounds`` — always safe, often loose;
  * ``lanczos_extremal`` — a few Lanczos iterations give Ritz values; the
    top Ritz value is a *lower* bound on lambda_N so we inflate it, and the
    bottom Ritz value is an *upper* bound on lambda_1 so we deflate it
    (Fig. 1 shows the rules tolerate conservative intervals).

For principal submatrices A_Y, eigenvalue interlacing makes any valid
interval for A valid for every A_Y — computed once per kernel matrix.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import lanczos as _lz

Array = jax.Array


class SpectrumBounds(NamedTuple):
    lam_min: Array
    lam_max: Array


def gershgorin_bounds(op, probe_rows: Array | None = None) -> SpectrumBounds:
    """Gershgorin discs via |A| row sums computed with matvecs on sign
    patterns is not exact for general A; for the dense/sparse operators we
    use the explicit rows when available."""
    a = getattr(op, "a", None)
    if a is None:
        raise ValueError("gershgorin_bounds needs an explicit-matrix operator")
    d = jnp.diagonal(a, axis1=-2, axis2=-1)
    r = jnp.sum(jnp.abs(a), axis=-1) - jnp.abs(d)
    return SpectrumBounds(jnp.min(d - r, axis=-1), jnp.max(d + r, axis=-1))


def gershgorin_bounds_spd(op) -> SpectrumBounds:
    """Gershgorin interval clamped for an SPD matrix.

    Gershgorin discs of an SPD matrix may still dip below zero; f(x)=1/x
    quadrature needs lam_min > 0, and a tiny positive lam_min only
    loosens the upper bounds (Fig. 1b), never breaks them. The ONE clamp
    rule shared by ``BIFSolver.prepare`` and ``serve.BIFEngine``.
    """
    est = gershgorin_bounds(op)
    return SpectrumBounds(
        jnp.maximum(est.lam_min, est.lam_max * 1e-9 + 1e-30), est.lam_max)


def lanczos_extremal(op, probe: Array, num_iters: int = 16,
                     slack: float = 1e-2) -> SpectrumBounds:
    """Ritz-value interval from ``num_iters`` Lanczos steps on ``probe``.

    Returns (lo*(1-slack_adj), hi*(1+slack)) — conservative on both ends.
    Batched over leading dims of ``probe``.
    """
    alphas, betas, valid = _lz.tridiag_coefficients(op, probe, num_iters)
    # Build the (batched) tridiagonal J_m and take its eigenvalue range.
    m = alphas.shape[0]
    al = jnp.moveaxis(alphas, 0, -1)          # (..., m)
    be = jnp.moveaxis(betas, 0, -1)[..., :-1]  # (..., m-1)
    va = jnp.moveaxis(valid, 0, -1)
    # freeze dead coefficients to keep J well-formed
    al = jnp.where(va, al, al[..., :1])
    be = jnp.where(va[..., 1:], be, 0.0)
    # vectorized tridiagonal assembly
    eye = jnp.eye(m, dtype=al.dtype)
    up = jnp.eye(m, k=1, dtype=al.dtype)
    bp = be_pad(be, m)
    J = (al[..., :, None] * eye      # diag:      J[i, i]   = alpha_i
         + bp[..., :, None] * up     # upper:     J[i, i+1] = beta_i
         + bp[..., None, :] * up.T)  # lower:     J[i+1, i] = beta_i
    evals = jnp.linalg.eigvalsh(J)
    lo = evals[..., 0]
    hi = evals[..., -1]
    width = jnp.maximum(hi - lo, jnp.abs(hi) * 1e-3 + 1e-12)
    # lam_min must stay positive for f(x)=1/x quadrature: clamp to a tiny
    # positive floor (valid for any PD A with kappa <= ~1e9; a too-small
    # lam_min only slows the upper bounds, Fig. 1(b), never breaks them).
    lam_min = jnp.maximum(lo - slack * width, hi * 1e-9 + 1e-30)
    return SpectrumBounds(lam_min, hi + slack * width)


def be_pad(be: Array, m: int) -> Array:
    """Pad betas (..., m-1) to (..., m) so the k=1 shift lines up."""
    return jnp.concatenate([be, jnp.zeros(be.shape[:-1] + (1,), be.dtype)],
                           axis=-1)


def ridge_bounds(op, ridge: float, probe: Array,
                 num_iters: int = 16) -> SpectrumBounds:
    """For kernels built as K + ridge*I (paper Table 1: +1e-3 I), the ridge
    is a certain lower bound; the top is estimated by Lanczos."""
    est = lanczos_extremal(op, probe, num_iters=num_iters)
    return SpectrumBounds(jnp.asarray(ridge * 0.5, probe.dtype), est.lam_max)
