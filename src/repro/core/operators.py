"""Linear-operator abstraction used by the quadrature core.

Every operator is a registered pytree (so it can cross ``jit``/``vmap``/
``scan`` boundaries) exposing:

  * ``matvec(x)``   -- y = A @ x, batched over leading dims of ``x``;
  * ``diag()``      -- the diagonal (for Jacobi preconditioning / Gershgorin);
  * ``n``           -- the (static) dimension N.

Operators compose: ``Masked(Dense(A), m)`` is the TPU-friendly fixed-shape
stand-in for the principal submatrix A_Y (mask semantics below), and
``Jacobi(...)`` applies the similarity transform of paper Sec. 5.4.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


def _register(cls, data_fields, meta_fields=()):
    jax.tree_util.register_dataclass(cls, data_fields=list(data_fields),
                                     meta_fields=list(meta_fields))
    return cls


@dataclasses.dataclass(frozen=True)
class Dense:
    """Explicit dense symmetric matrix, shape (..., N, N)."""
    a: Array

    @property
    def n(self) -> int:
        return self.a.shape[-1]

    def matvec(self, x: Array) -> Array:
        return jnp.einsum("...ij,...j->...i", self.a, x)

    def diag(self) -> Array:
        return jnp.diagonal(self.a, axis1=-2, axis2=-1)


_register(Dense, ["a"])


@dataclasses.dataclass(frozen=True)
class SparseCOO:
    """Symmetric sparse matrix in padded COO form, fixed nnz (jit-stable).

    ``rows``/``cols``/``vals`` have shape (nnz,); padding entries carry
    ``rows == n`` (scattered with drop semantics). Only the single-system
    (unbatched) layout is supported; batch by ``vmap`` over vals if needed.
    """
    rows: Array
    cols: Array
    vals: Array
    n_static: int
    diag_vals: Array  # (N,) dense diagonal, kept explicitly

    @property
    def n(self) -> int:
        return self.n_static

    def matvec(self, x: Array) -> Array:
        # y[r] += v * x[c]; out-of-range rows dropped.
        contrib = self.vals * jnp.take(x, self.cols, axis=-1, fill_value=0.0)
        y = jnp.zeros(x.shape[:-1] + (self.n_static,), x.dtype)
        return y.at[..., self.rows].add(contrib, mode="drop")

    def diag(self) -> Array:
        return self.diag_vals


_register(SparseCOO, ["rows", "cols", "vals", "diag_vals"], ["n_static"])


def sparse_from_dense(a, nnz: int | None = None) -> SparseCOO:
    """Build a padded-COO operator from a dense (numpy/jnp) matrix."""
    import numpy as np

    a = np.asarray(a)
    n = a.shape[-1]
    r, c = np.nonzero(a)
    v = a[r, c]
    cap = int(nnz) if nnz is not None else len(r)
    if len(r) > cap:
        raise ValueError(f"nnz={len(r)} exceeds capacity {cap}")
    pad = cap - len(r)
    r = np.concatenate([r, np.full(pad, n, dtype=r.dtype)])
    c = np.concatenate([c, np.zeros(pad, dtype=c.dtype)])
    v = np.concatenate([v, np.zeros(pad, dtype=v.dtype)])
    return SparseCOO(jnp.asarray(r), jnp.asarray(c), jnp.asarray(v), n,
                     jnp.asarray(np.diagonal(a, axis1=-2, axis2=-1)))


@dataclasses.dataclass(frozen=True)
class Masked:
    """Fixed-shape principal-submatrix operator.

    With projector P = diag(mask), represents  P A P + (I - P).
    For any u supported on the mask, Lanczos on this operator is *exactly*
    Lanczos on the true submatrix A_Y (the identity block is invisible:
    Krylov vectors stay supported on the mask). Eigenvalue interlacing
    guarantees spec(A_Y) within [lam_min(A), lam_max(A)], so global
    spectral bounds on A remain valid for every Y.
    ``mask`` has shape (..., N) and may be batched.
    """
    base: Any
    mask: Array  # float {0.,1.} or bool

    @property
    def n(self) -> int:
        return self.base.n

    def matvec(self, x: Array) -> Array:
        m = self.mask.astype(x.dtype)
        return m * self.base.matvec(m * x) + (1.0 - m) * x

    def diag(self) -> Array:
        m = self.mask.astype(self.base.diag().dtype)
        return m * self.base.diag() + (1.0 - m)


_register(Masked, ["base", "mask"])


@dataclasses.dataclass(frozen=True)
class Shifted:
    """A + sigma * I."""
    base: Any
    sigma: Array

    @property
    def n(self) -> int:
        return self.base.n

    def matvec(self, x: Array) -> Array:
        return self.base.matvec(x) + self.sigma * x

    def diag(self) -> Array:
        return self.base.diag() + self.sigma


_register(Shifted, ["base", "sigma"])


@dataclasses.dataclass(frozen=True)
class Jacobi:
    """Jacobi-preconditioned similarity transform (paper Sec. 5.4).

    With C = diag(A)^(-1/2):   u^T A^-1 u = (Cu)^T (C A C)^-1 (Cu).
    This operator *is* C A C; use ``transform_vector`` for Cu. The
    transformed matrix has unit diagonal, typically shrinking kappa.
    """
    base: Any
    inv_sqrt_diag: Array  # (..., N)

    @classmethod
    def create(cls, base) -> "Jacobi":
        d = base.diag()
        return cls(base, jax.lax.rsqrt(jnp.maximum(d, 1e-30)))

    @property
    def n(self) -> int:
        return self.base.n

    def matvec(self, x: Array) -> Array:
        return self.inv_sqrt_diag * self.base.matvec(self.inv_sqrt_diag * x)

    def diag(self) -> Array:
        return self.inv_sqrt_diag**2 * self.base.diag()

    def transform_vector(self, u: Array) -> Array:
        return self.inv_sqrt_diag * u


_register(Jacobi, ["base", "inv_sqrt_diag"])


@dataclasses.dataclass(frozen=True)
class MatvecFn:
    """Wrap a closure as an operator (used by the distributed monitor,
    where the matvec embeds psums over mesh axes)."""
    fn: Any  # static: callable (..., N) -> (..., N)
    n_static: int
    diag_vals: Array

    @property
    def n(self) -> int:
        return self.n_static

    def matvec(self, x: Array) -> Array:
        return self.fn(x)

    def diag(self) -> Array:
        return self.diag_vals


_register(MatvecFn, ["diag_vals"], ["fn", "n_static"])
