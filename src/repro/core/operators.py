"""Linear-operator abstraction used by the quadrature core.

Every operator is a registered pytree (so it can cross ``jit``/``vmap``/
``scan`` boundaries) exposing:

  * ``matvec(x)``   -- y = A @ x, batched over leading dims of ``x``;
  * ``diag()``      -- the diagonal (for Jacobi preconditioning / Gershgorin);
  * ``n``           -- the (static) dimension N.

Operators compose: ``Masked(Dense(A), m)`` is the TPU-friendly fixed-shape
stand-in for the principal submatrix A_Y (mask semantics below), and
``Jacobi(...)`` applies the similarity transform of paper Sec. 5.4.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


def _register(cls, data_fields, meta_fields=()):
    jax.tree_util.register_dataclass(cls, data_fields=list(data_fields),
                                     meta_fields=list(meta_fields))
    return cls


@dataclasses.dataclass(frozen=True)
class Dense:
    """Explicit dense symmetric matrix, shape (..., N, N)."""
    a: Array

    @property
    def n(self) -> int:
        return self.a.shape[-1]

    def matvec(self, x: Array) -> Array:
        return jnp.einsum("...ij,...j->...i", self.a, x)

    def diag(self) -> Array:
        return jnp.diagonal(self.a, axis1=-2, axis2=-1)


_register(Dense, ["a"])


@dataclasses.dataclass(frozen=True)
class SparseCOO:
    """Symmetric sparse matrix in padded COO form, fixed nnz (jit-stable).

    ``rows``/``cols``/``vals`` have shape (..., nnz); padding entries carry
    ``rows == n`` (scattered with drop semantics). With a shared pattern
    (1-D ``rows``/``cols``) any leading batch dims of ``x`` and/or ``vals``
    broadcast. A *stacked* operator (from ``stack_ops``) carries leading
    lane dims on the index arrays too; ``x`` must then match those dims.
    """
    rows: Array
    cols: Array
    vals: Array
    n_static: int
    diag_vals: Array  # (..., N) dense diagonal, kept explicitly

    @property
    def n(self) -> int:
        return self.n_static

    def matvec(self, x: Array) -> Array:
        if self.rows.ndim == 1:
            # y[r] += v * x[c]; out-of-range rows dropped. The output
            # carries the broadcast batch dims of vals AND x.
            contrib = self.vals * jnp.take(x, self.cols, axis=-1,
                                           fill_value=0.0)
            y = jnp.zeros(contrib.shape[:-1] + (self.n_static,), x.dtype)
            return y.at[..., self.rows].add(contrib, mode="drop")
        # Batched sparsity pattern: per-lane scatter in lockstep.
        b = jnp.broadcast_shapes(self.rows.shape[:-1], x.shape[:-1])
        nnz = self.rows.shape[-1]
        n = self.n_static

        def flat(a, last):
            return jnp.broadcast_to(a, b + (last,)).reshape((-1, last))

        def one(r, c, v, xx):
            contrib = v * jnp.take(xx, c, fill_value=0.0)
            return jnp.zeros((n,), xx.dtype).at[r].add(contrib, mode="drop")

        y = jax.vmap(one)(flat(self.rows, nnz), flat(self.cols, nnz),
                          flat(self.vals, nnz), flat(x, x.shape[-1]))
        return y.reshape(b + (n,))

    def diag(self) -> Array:
        return self.diag_vals


_register(SparseCOO, ["rows", "cols", "vals", "diag_vals"], ["n_static"])


def sparse_from_dense(a, nnz: int | None = None) -> SparseCOO:
    """Build a padded-COO operator from a dense (numpy/jnp) matrix."""
    import numpy as np

    a = np.asarray(a)
    n = a.shape[-1]
    r, c = np.nonzero(a)
    v = a[r, c]
    cap = int(nnz) if nnz is not None else len(r)
    if len(r) > cap:
        raise ValueError(f"nnz={len(r)} exceeds capacity {cap}")
    pad = cap - len(r)
    r = np.concatenate([r, np.full(pad, n, dtype=r.dtype)])
    c = np.concatenate([c, np.zeros(pad, dtype=c.dtype)])
    v = np.concatenate([v, np.zeros(pad, dtype=v.dtype)])
    return SparseCOO(jnp.asarray(r), jnp.asarray(c), jnp.asarray(v), n,
                     jnp.asarray(np.diagonal(a, axis1=-2, axis2=-1)))


_BELL_MODES = ("reference", "pallas")


@dataclasses.dataclass(frozen=True)
class SparseBELL:
    """Symmetric sparse matrix in blocked-ELL form (DESIGN.md Sec. 6).

    The layout of ``kernels/spmv_bell.py``: ``data`` (..., R, K, bs, bs)
    holds up to K non-zero bs x bs blocks per block-row, ``cols``
    (..., R, K) their block-column indices (padding blocks point at
    column 0 with zero data). N may be smaller than R*bs; matvec
    zero-pads and slices at the boundary.

    ``mode`` picks the execution path: 'reference' is the pure-jnp einsum
    (CPU / oracle), 'pallas' the scalar-prefetch MXU kernel
    (``interpret=None`` auto-selects interpret mode off-TPU). The solver
    rebinds both from ``SolverConfig.backend`` via ``configure_backend``.

    Leading lane dims on ``data``/``cols`` (a ``stack_ops`` stack) batch
    the system; ``x`` must then carry matching lane dims.
    """
    data: Array
    cols: Array
    diag_vals: Array  # (..., N)
    n_static: int
    mode: str = "reference"
    interpret: bool | None = None

    def __post_init__(self):
        if self.mode not in _BELL_MODES:
            raise ValueError(f"mode must be one of {_BELL_MODES}, "
                             f"got {self.mode!r}")

    @property
    def n(self) -> int:
        return self.n_static

    def configured(self, backend: str, interpret: bool | None
                   ) -> "SparseBELL":
        mode = "pallas" if backend == "pallas" else "reference"
        if mode == self.mode and interpret == self.interpret:
            return self
        return dataclasses.replace(self, mode=mode, interpret=interpret)

    def matvec(self, x: Array) -> Array:
        from ..kernels import spmv_bell as _sb  # deferred: pulls in pallas
        r, _, bs, _ = self.data.shape[-4:]
        pad = r * bs - x.shape[-1]
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
        if self.mode == "reference":
            y = _sb.bell_matvec_ref(self.data, self.cols, xp)
        else:
            from ..kernels import ops as _kops
            lanes = jnp.broadcast_shapes(self.data.shape[:-4], xp.shape[:-1])
            xb = jnp.broadcast_to(xp, lanes + xp.shape[-1:])
            kern = lambda d, c, v: _kops.bell_matvec(  # noqa: E731
                d, c, v.astype(jnp.float32), interpret=self.interpret)
            if not lanes:
                y = kern(self.data, self.cols, xb)
            elif self.data.ndim == 4:
                flat = xb.reshape((-1, xb.shape[-1]))
                y = jax.vmap(lambda v: kern(self.data, self.cols, v))(flat)
            else:
                db = jnp.broadcast_to(self.data, lanes + self.data.shape[-4:])
                cb = jnp.broadcast_to(self.cols, lanes + self.cols.shape[-2:])
                y = jax.vmap(kern)(
                    db.reshape((-1,) + db.shape[-4:]),
                    cb.reshape((-1,) + cb.shape[-2:]),
                    xb.reshape((-1, xb.shape[-1])))
            y = y.reshape(lanes + y.shape[-1:]).astype(x.dtype)
        return y[..., :self.n_static] if pad else y

    def diag(self) -> Array:
        return self.diag_vals


_register(SparseBELL, ["data", "cols", "diag_vals"],
          ["n_static", "mode", "interpret"])


def bell_from_dense(a, bs: int = 128, k_max: int | None = None,
                    dtype=None, mode: str = "reference",
                    interpret: bool | None = None) -> SparseBELL:
    """Build a blocked-ELL operator from a dense (numpy/jnp) matrix.

    ``dtype=None`` keeps the input dtype (the Pallas kernel itself always
    accumulates in f32; pass f32 data for the TPU path).
    """
    import numpy as np

    from ..kernels import spmv_bell as _sb

    a = np.asarray(a)
    data, cols, n = _sb.dense_to_bell(
        a, bs=bs, k_max=k_max, dtype=a.dtype if dtype is None else dtype)
    return SparseBELL(data, cols, jnp.asarray(np.diagonal(a).copy(),
                                              data.dtype),
                      n, mode=mode, interpret=interpret)


@dataclasses.dataclass(frozen=True)
class Masked:
    """Fixed-shape principal-submatrix operator.

    With projector P = diag(mask), represents  P A P + (I - P).
    For any u supported on the mask, Lanczos on this operator is *exactly*
    Lanczos on the true submatrix A_Y (the identity block is invisible:
    Krylov vectors stay supported on the mask). Eigenvalue interlacing
    guarantees spec(A_Y) within [lam_min(A), lam_max(A)], so global
    spectral bounds on A remain valid for every Y.
    ``mask`` has shape (..., N) and may be batched.
    """
    base: Any
    mask: Array  # float {0.,1.} or bool

    @property
    def n(self) -> int:
        return self.base.n

    def matvec(self, x: Array) -> Array:
        m = self.mask.astype(x.dtype)
        return m * self.base.matvec(m * x) + (1.0 - m) * x

    def diag(self) -> Array:
        m = self.mask.astype(self.base.diag().dtype)
        return m * self.base.diag() + (1.0 - m)


_register(Masked, ["base", "mask"])


@dataclasses.dataclass(frozen=True)
class Shifted:
    """A + sigma * I. ``sigma`` is a scalar, or (..., ) lane-batched (a
    ``stack_ops`` stack): batch dims pair with the batch dims of ``x``,
    never with the vector dim."""
    base: Any
    sigma: Array

    @property
    def n(self) -> int:
        return self.base.n

    def _sigma_col(self) -> Array:
        s = jnp.asarray(self.sigma)
        return s[..., None] if s.ndim else s

    def matvec(self, x: Array) -> Array:
        return self.base.matvec(x) + self._sigma_col() * x

    def diag(self) -> Array:
        return self.base.diag() + self._sigma_col()


_register(Shifted, ["base", "sigma"])


@dataclasses.dataclass(frozen=True)
class Jacobi:
    """Jacobi-preconditioned similarity transform (paper Sec. 5.4).

    With C = diag(A)^(-1/2):   u^T A^-1 u = (Cu)^T (C A C)^-1 (Cu).
    This operator *is* C A C; use ``transform_vector`` for Cu. The
    transformed matrix has unit diagonal, typically shrinking kappa.
    """
    base: Any
    inv_sqrt_diag: Array  # (..., N)

    @classmethod
    def create(cls, base) -> "Jacobi":
        d = base.diag()
        return cls(base, jax.lax.rsqrt(jnp.maximum(d, 1e-30)))

    @property
    def n(self) -> int:
        return self.base.n

    def matvec(self, x: Array) -> Array:
        return self.inv_sqrt_diag * self.base.matvec(self.inv_sqrt_diag * x)

    def diag(self) -> Array:
        return self.inv_sqrt_diag**2 * self.base.diag()

    def transform_vector(self, u: Array) -> Array:
        return self.inv_sqrt_diag * u


_register(Jacobi, ["base", "inv_sqrt_diag"])


@dataclasses.dataclass(frozen=True)
class MatvecFn:
    """Wrap a closure as an operator (used by the distributed monitor,
    where the matvec embeds psums over mesh axes)."""
    fn: Any  # static: callable (..., N) -> (..., N)
    n_static: int
    diag_vals: Array

    @property
    def n(self) -> int:
        return self.n_static

    def matvec(self, x: Array) -> Array:
        return self.fn(x)

    def diag(self) -> Array:
        return self.diag_vals


_register(MatvecFn, ["diag_vals"], ["fn", "n_static"])


# ---------------------------------------------------------------------------
# Multi-vector right-hand sides (block-Krylov mode, DESIGN.md Sec. 13)


def matvec_mrhs(op, x: Array) -> Array:
    """y = A @ X for a row-stacked block X of shape (..., b, N) — the
    block-Lanczos workhorse. Row i of the output is ``op.matvec`` of row
    i of ``x``, but shaped so Dense and BELL backends see ONE gemm per
    operator application instead of b gemvs. Leading dims of ``x``
    before the block axis are lanes and pair with lane-stacked operator
    leaves exactly as in :meth:`matvec`; the block axis is always local
    to each lane.

    Semantics (not bit-level equality with b gemvs — a gemm may reduce
    in a different order) match ``matvec`` row by row; the b = 1 slot of
    every backend used by the solver reduces identically.
    """
    if isinstance(op, Dense):
        # lanes broadcast against op.a's batch dims; b rides the gemm
        return jnp.einsum("...ij,...bj->...bi", op.a, x)
    if isinstance(op, SparseCOO):
        if op.rows.ndim == 1:
            return op.matvec(x)  # shared pattern broadcasts over (..., b)
        # lane-stacked pattern: give the index arrays a length-1 block
        # axis so the lockstep scatter broadcasts over the block slots
        return dataclasses.replace(
            op, rows=op.rows[..., None, :], cols=op.cols[..., None, :],
            vals=op.vals[..., None, :],
            diag_vals=op.diag_vals[..., None, :]).matvec(x)
    if isinstance(op, SparseBELL):
        return _bell_mrhs(op, x)
    if isinstance(op, Masked):
        m = op.mask.astype(x.dtype)
        mb = m[..., None, :] if m.ndim > 1 else m
        return mb * matvec_mrhs(op.base, mb * x) + (1.0 - mb) * x
    if isinstance(op, Shifted):
        s = jnp.asarray(op.sigma)
        sb = s[..., None, None] if s.ndim else s
        return matvec_mrhs(op.base, x) + sb * x
    if isinstance(op, Jacobi):
        c = op.inv_sqrt_diag
        cb = c[..., None, :] if c.ndim > 1 else c
        return cb * matvec_mrhs(op.base, cb * x)
    # MatvecFn and anything else: closures take (..., N) batches, so the
    # block axis is just another batch dim (no gemm shaping available)
    return op.matvec(x)


def _bell_mrhs(op: SparseBELL, x: Array) -> Array:
    from ..kernels import spmv_bell as _sb  # deferred: pulls in pallas

    r, _, bs, _ = op.data.shape[-4:]
    pad = r * bs - x.shape[-1]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    if op.mode == "reference":
        if op.cols.ndim == 2:
            y = _sb.bell_matvec_ref(op.data, op.cols, xp)
        else:
            # lane-stacked tables: length-1 block axis so the per-lane
            # gather broadcasts over the (..., b, N) block rows
            y = _sb.bell_matvec_ref(op.data[..., None, :, :, :, :],
                                    op.cols[..., None, :, :], xp)
    else:
        from ..kernels import ops as _kops
        lanes = jnp.broadcast_shapes(op.data.shape[:-4], xp.shape[:-2])
        xb = jnp.broadcast_to(xp, lanes + xp.shape[-2:])
        # kernel layout is column-stacked (N, b): one gemm per stored
        # block across all b columns of the lane's block
        xt = jnp.swapaxes(xb, -1, -2).astype(jnp.float32)
        kern = lambda d, c, v: _kops.bell_matvec_mrhs(  # noqa: E731
            d, c, v, interpret=op.interpret)
        if not lanes:
            y = kern(op.data, op.cols, xt)
        elif op.data.ndim == 4:
            flat = xt.reshape((-1,) + xt.shape[-2:])
            y = jax.vmap(lambda v: kern(op.data, op.cols, v))(flat)
        else:
            db = jnp.broadcast_to(op.data, lanes + op.data.shape[-4:])
            cb = jnp.broadcast_to(op.cols, lanes + op.cols.shape[-2:])
            y = jax.vmap(kern)(
                db.reshape((-1,) + db.shape[-4:]),
                cb.reshape((-1,) + cb.shape[-2:]),
                xt.reshape((-1,) + xt.shape[-2:]))
        y = jnp.swapaxes(y, -1, -2)
        y = y.reshape(lanes + y.shape[-2:]).astype(x.dtype)
    return y[..., :op.n_static] if pad else y


# ---------------------------------------------------------------------------
# Batched-system helpers (DESIGN.md Sec. 6)


def stack_ops(ops):
    """Stack K same-structure operators into ONE lane-batched operator.

    Every array leaf gains a leading lane axis (``Dense.a`` becomes
    (K, N, N), ``SparseBELL.data`` (K, R, Kb, bs, bs), ...); static
    metadata (n, mode, ...) must agree. The result is a single pytree the
    batched driver can ``matvec`` once per iteration over all K systems.
    For K masks of one shared base matrix prefer :func:`stack_masks`,
    which does not copy the base.
    """
    ops = list(ops)
    if not ops:
        raise ValueError("stack_ops needs at least one operator")
    treedef = jax.tree.structure(ops[0])
    for o in ops[1:]:
        if jax.tree.structure(o) != treedef:
            raise ValueError(
                f"stack_ops needs same-structure operators; got {treedef} "
                f"vs {jax.tree.structure(o)}")
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *ops)


def stack_masks(base, masks) -> Masked:
    """K candidate principal-submatrix masks of ONE base matrix as a
    single lane-batched ``Masked`` operator (the base is shared, not
    copied: only the (K, N) mask is materialized per lane).

    ``masks``: (K, N) array or a sequence of (N,) masks. Feed the result
    plus (K, N)-stacked query vectors to ``BIFSolver.solve_batch`` /
    ``judge_batch`` to score all K candidates in one driver.
    """
    if not isinstance(masks, jax.Array):
        masks = jnp.stack([jnp.asarray(m) for m in masks])
    if masks.ndim < 2:
        raise ValueError(f"stack_masks wants (K, N) masks, got shape "
                         f"{masks.shape}")
    return Masked(base, masks)


# ---------------------------------------------------------------------------
# Lane sharding (DESIGN.md Sec. 7)

# Rank of each array field on an UNBATCHED operator. Leaves whose rank
# exceeds this carry a leading lane axis (a stack_ops / stack_masks
# stack) and are sharded across devices; base-rank leaves are the shared
# problem data and stay replicated. Type-dispatched on purpose: shape
# heuristics would misfire when K == N (greedy MAP runs N lanes against
# an (N, N) base matrix).
_LANE_BASE_RANK = {
    Dense: {"a": 2},
    SparseCOO: {"rows": 1, "cols": 1, "vals": 1, "diag_vals": 1},
    SparseBELL: {"data": 4, "cols": 2, "diag_vals": 1},
    Masked: {"mask": 1},
    Shifted: {"sigma": 0},
    Jacobi: {"inv_sqrt_diag": 1},
    MatvecFn: {"diag_vals": 1},
}

_LANE_WRAPPERS = (Masked, Shifted, Jacobi)


def _lane_spec_for(leaf, base_rank: int, axis: str):
    from jax.sharding import PartitionSpec as P

    extra = jnp.ndim(leaf) - base_rank
    if extra == 0:
        return P()
    if extra == 1:
        return P(axis)  # leading lane dim sharded, trailing dims replicated
    raise ValueError(
        f"operator leaf has {extra} leading lane dims (shape "
        f"{jnp.shape(leaf)}, base rank {base_rank}); the sharded driver "
        f"supports exactly one lane axis")


def lane_specs(op, axis: str = "lanes"):
    """PartitionSpec pytree for ``op`` under lane sharding.

    Same treedef as ``op`` with a ``PartitionSpec`` per array leaf:
    lane-stacked leaves (one extra leading dim over the operator's
    unbatched rank) are sharded on ``axis``; shared leaves replicated.
    Feed to ``shard_map`` in_specs or :func:`shard_ops`.
    """
    cls = type(op)
    if cls not in _LANE_BASE_RANK:
        raise TypeError(f"lane_specs does not know operator type "
                        f"{cls.__name__}")
    ranks = _LANE_BASE_RANK[cls]
    fields = {name: _lane_spec_for(getattr(op, name), rank, axis)
              for name, rank in ranks.items()}
    if cls in _LANE_WRAPPERS:
        fields["base"] = lane_specs(op.base, axis)
    return dataclasses.replace(op, **fields)


def shard_ops(op, mesh, axis: str = "lanes"):
    """Place an operator pytree on a lane mesh: lane-stacked leaves
    sharded over ``axis``, shared leaves (the base matrix) replicated on
    every device. Purely a placement hint — ``shard_map`` in_specs from
    :func:`lane_specs` define the semantics either way."""
    from jax.sharding import NamedSharding

    specs = lane_specs(op, axis)
    return jax.tree.map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        op, specs)


def configure_backend(op, backend: str, interpret: bool | None):
    """Rebind the execution mode of every ``SparseBELL`` inside ``op``
    (walking Masked/Shifted/Jacobi wrappers) to the solver's backend."""
    if isinstance(op, SparseBELL):
        return op.configured(backend, interpret)
    if isinstance(op, (Masked, Shifted, Jacobi)):
        new_base = configure_backend(op.base, backend, interpret)
        if new_base is op.base:
            return op
        return dataclasses.replace(op, base=new_base)
    return op


def fused_operands(op):
    """Flatten ``op`` into the diagonal-sandwich form consumed by the
    fused step kernel (``kernels/lanczos_step.py``):

        matvec(x) = s_out * base.matvec(s_in * x) + t * x

    with ``base`` a :class:`Dense` or :class:`SparseBELL` and ``s_out`` /
    ``s_in`` / ``t`` scalars or arrays broadcastable against ``(..., N)``.
    Every Masked/Shifted/Jacobi wrapper is closed under this form:

        Dense / BELL:  (base, 1, 1, 0)
        Shifted(F, s): t' = t + s
        Jacobi(F, c):  s_out' = c*s_out, s_in' = s_in*c, t' = c*t*c
        Masked(F, m):  s_out' = m*s_out, s_in' = s_in*m,
                       t' = m*t*m + (1 - m)

    Returns ``(base, s_out, s_in, t)`` or ``None`` when ``op`` bottoms
    out in an operator the fused kernel cannot stream (SparseCOO,
    MatvecFn, ...) — callers fall back to the reference composition.
    """
    if isinstance(op, (Dense, SparseBELL)):
        one = jnp.ones((), _dtype_of(op))
        return op, one, one, jnp.zeros((), _dtype_of(op))
    if isinstance(op, Shifted):
        inner = fused_operands(op.base)
        if inner is None:
            return None
        base, s_out, s_in, t = inner
        return base, s_out, s_in, t + op._sigma_col()
    if isinstance(op, Jacobi):
        inner = fused_operands(op.base)
        if inner is None:
            return None
        base, s_out, s_in, t = inner
        c = op.inv_sqrt_diag
        return base, c * s_out, s_in * c, c * t * c
    if isinstance(op, Masked):
        inner = fused_operands(op.base)
        if inner is None:
            return None
        base, s_out, s_in, t = inner
        m = op.mask.astype(_dtype_of(base))
        return base, m * s_out, s_in * m, m * t * m + (1.0 - m)
    return None


def _dtype_of(op):
    if isinstance(op, Dense):
        return op.a.dtype
    if isinstance(op, SparseBELL):
        return op.data.dtype
    return op.diag().dtype
