"""Retrospective Markov-chain DPP / k-DPP samplers (paper Alg. 3 / 6).

State transitions compare a uniform draw against determinant ratios that
are Schur complements ``L_yy - L_{y,Y} L_Y^{-1} L_{Y,y}`` — a constant
minus a BIF. The retrospective judges resolve each comparison from
iteratively tightened quadrature bounds, so every chain makes *exactly*
the same accept/reject decisions as with exact BIF values (the paper's
central correctness claim; verified against the exact baselines in
tests/test_dpp.py).

Masks replace dynamic index sets: the principal submatrix L_Y is the
fixed-shape ``Masked`` operator, and eigenvalue interlacing lets one
global spectral interval serve every Y (DESIGN.md Sec. 3).

Acceptance rule note: for the removal move the paper's Alg. 3 listing
passes ``L_yy - p`` to DPPJUDGE, which yields acceptance probability
``1 - q`` rather than the Metropolis ``min(1, 1/q)`` used by the samplers
it cites [Kang'13; Anari et al.'16] (and required for detailed balance
w.r.t. the DPP). We implement the Metropolis rule — threshold
``L_yy - 1/p`` — and note the listing discrepancy here.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import operators as _ops
from . import solver as _solver
from . import update as _update

Array = jax.Array


def _as_solver(solver: _solver.BIFSolver | None,
               max_iters: int) -> _solver.BIFSolver:
    """Chain steps take either a configured BIFSolver or a bare max_iters."""
    if solver is None:
        return _solver.BIFSolver.create(max_iters=max_iters)
    if solver.config.fn != "inv":
        raise ValueError(
            "the chain judges compare Schur-complement thresholds against "
            "u^T A^-1 u; a matfun solver (fn != 'inv') would bracket a "
            "different quantity and judge it as if it were the BIF — pass "
            "an fn='inv' solver (bracketed log-likelihoods go through "
            "dpp.log_likelihood instead)")
    return solver


class ChainStats(NamedTuple):
    steps: Array
    accepts: Array
    quad_iterations: Array  # total GQL iterations spent
    uncertified: Array      # judged by fallback (should stay 0)


class ChainState(NamedTuple):
    mask: Array  # (..., N) float {0,1}
    key: Array
    stats: ChainStats
    # Optional ChainFactor of L_Y carried across accepted moves
    # (incremental scoring, DESIGN.md Sec. 12); None keeps the
    # quadrature/exact paths and the pre-PR-8 pytree leaves.
    factor: Any = None


def init_chain(key: Array, init_mask: Array, factor=None) -> ChainState:
    z = jnp.zeros((), jnp.int32)
    return ChainState(mask=init_mask.astype(jnp.float32), key=key,
                      stats=ChainStats(z, z, z, z), factor=factor)


def _column(op, y: Array, n: int) -> Array:
    """Column y of the symmetric base matrix via a one-hot matvec."""
    e = jax.nn.one_hot(y, n, dtype=op.diag().dtype)
    return op.matvec(e)


def _exact_bif(op, mask: Array, u: Array) -> Array:
    """Oracle BIF via a dense solve on the masked system (baseline path)."""
    a = op.a if isinstance(op, _ops.Dense) else None
    if a is None:
        raise ValueError("exact baseline needs a Dense operator")
    m = mask.astype(a.dtype)
    a_masked = a * m[..., :, None] * m[..., None, :] + (1.0 - m)[..., :, None] * jnp.eye(a.shape[-1], dtype=a.dtype)
    x = jnp.linalg.solve(a_masked, u[..., None])[..., 0]
    return jnp.sum(u * x, axis=-1)


def dpp_step(op, state: ChainState, lam_min, lam_max, *, max_iters: int,
             exact: bool = False,
             solver: _solver.BIFSolver | None = None) -> ChainState:
    """One add/remove MH move (Alg. 3).

    When ``state.factor`` carries a :class:`~repro.core.update.ChainFactor`
    of L_Y (``init_chain(..., factor=update.from_mask(op, mask))``), the
    Schur comparison is evaluated EXACTLY from the maintained factor —
    two O(|Y|^2) triangular solves instead of a quadrature solve — and
    the factor is carried across accepted moves (downdate on remove,
    extend on add; DESIGN.md Sec. 12). Accept/reject decisions match the
    ``exact=True`` oracle; ``stats.quad_iterations`` stays flat.
    """
    incremental = state.factor is not None
    if incremental and exact:
        raise ValueError(
            "state.factor already scores moves exactly from the "
            "maintained Cholesky factor; exact=True would shadow it — "
            "drop the factor (init_chain(..., factor=None)) for the "
            "dense-solve oracle")
    n = op.n
    key, k_y, k_p = jax.random.split(state.key, 3)
    y = jax.random.randint(k_y, (), 0, n)
    p = jax.random.uniform(k_p, (), dtype=state.mask.dtype)

    in_y = state.mask[y] > 0.5
    hot = jax.nn.one_hot(y, n, dtype=state.mask.dtype)
    m_wo = state.mask * (1.0 - hot)          # Y \ {y}: the conditioning set
    col = _column(op, y, n)
    u = col * m_wo
    l_yy = op.diag()[y]

    # Schur complement q = l_yy - bif.  Add move: accept iff p < q
    # <=> NOT (l_yy - p < bif).  Remove move (Metropolis): accept iff
    # p < 1/q <=> q < 1/p <=> l_yy - 1/p < bif.
    t = jnp.where(in_y, l_yy - 1.0 / jnp.maximum(p, 1e-12), l_yy - p)
    mop = _ops.Masked(op, m_wo)
    f_wo = None
    if incremental:
        # f_wo represents Y \ {y} either way: downdate of an absent item
        # is the exact identity. Both move outcomes reuse it below.
        f_wo = _update.downdate(state.factor, y)
        bif = _update.bif(f_wo, u)
        res = _solver.JudgeResult(decision=t < bif,
                                  certified=f_wo.ok,
                                  iterations=jnp.zeros((), jnp.int32))
    elif exact:
        bif = _exact_bif(op, m_wo, u)
        decision = t < bif
        res = _solver.JudgeResult(decision=decision,
                                  certified=jnp.ones((), bool),
                                  iterations=jnp.zeros((), jnp.int32))
    else:
        res = _as_solver(solver, max_iters).judge_threshold(
            mop, u, t, lam_min=lam_min, lam_max=lam_max)

    accept = jnp.where(in_y, res.decision, ~res.decision)
    new_mask = jnp.where(in_y,
                         jnp.where(accept, m_wo, state.mask),
                         jnp.where(accept, state.mask + hot, state.mask))
    new_factor = state.factor
    if incremental:
        # accepted remove keeps the downdated factor; accepted add
        # extends it with y's (unmasked) column; reject restores the
        # original — all branchless, the scan carry stays fixed-shape
        grown = _update.tree_select(in_y, f_wo,
                                    _update.extend(f_wo, col, y))
        new_factor = _update.tree_select(accept, grown, state.factor)
    st = state.stats
    stats = ChainStats(steps=st.steps + 1,
                       accepts=st.accepts + accept.astype(jnp.int32),
                       quad_iterations=st.quad_iterations + res.iterations,
                       uncertified=st.uncertified
                       + (~res.certified).astype(jnp.int32))
    return ChainState(mask=new_mask, key=key, stats=stats,
                      factor=new_factor)


def kdpp_step(op, state: ChainState, lam_min, lam_max, *, max_iters: int,
              exact: bool = False, batched: bool = True,
              solver: _solver.BIFSolver | None = None, mesh=None,
              lane_axis: str = "lanes",
              chunk_iters: int | None = None) -> ChainState:
    """One swap move of the k-DPP chain (Alg. 6/7): remove v in Y, add
    u not in Y; accept iff p < (L_uu - bif_u) / (L_vv - bif_v).

    ``batched=True`` (default) scores both candidate systems as two lanes
    of the batched driver (one stacked matvec per iteration, DESIGN.md
    Sec. 6); ``batched=False`` keeps the sequential gap-weighted pair
    driver. ``mesh`` places the batched lanes on a lane mesh (DESIGN.md
    Sec. 7) — useful when the chain state already lives on the mesh.
    ``chunk_iters`` runs the batched judge through the resumable runtime
    in fixed-size decision rounds, carrying the unresolved systems'
    banked QuadState between rounds instead of re-solving (DESIGN.md
    Sec. 8) — the hook an async chain scheduler steps through.
    Decisions are certified-identical every way.

    With ``state.factor`` set (a maintained
    :class:`~repro.core.update.ChainFactor` of L_Y), both candidate BIFs
    come EXACTLY off the factor of Y' = Y \\ {v} — one downdate plus two
    triangular solves per move, zero quadrature iterations — and the
    factor carries across accepted swaps (DESIGN.md Sec. 12)."""
    incremental = state.factor is not None
    if incremental and (exact or mesh is not None
                        or chunk_iters is not None):
        raise ValueError(
            "state.factor scores the swap exactly from the maintained "
            "factor (no quadrature lanes run) — exact/mesh/chunk_iters "
            "do not apply; drop the factor to use those paths")
    if mesh is not None and (exact or not batched):
        raise ValueError(
            "mesh requires the batched driver: pass batched=True, "
            "exact=False (the exact and pair drivers run single-device)")
    if chunk_iters is not None and (exact or not batched
                                    or mesh is not None):
        raise ValueError(
            "chunk_iters requires the single-device batched driver: pass "
            "batched=True, exact=False, mesh=None")
    n = op.n
    key, k_v, k_u, k_p = jax.random.split(state.key, 4)
    # Gumbel-max uniform picks from inside / outside the mask.
    g_v = jax.random.gumbel(k_v, (n,), state.mask.dtype)
    g_u = jax.random.gumbel(k_u, (n,), state.mask.dtype)
    neg = jnp.asarray(-1e30, state.mask.dtype)
    v = jnp.argmax(jnp.where(state.mask > 0.5, g_v, neg))
    uu = jnp.argmax(jnp.where(state.mask > 0.5, neg, g_u))
    p = jax.random.uniform(k_p, (), dtype=state.mask.dtype)

    hot_v = jax.nn.one_hot(v, n, dtype=state.mask.dtype)
    hot_u = jax.nn.one_hot(uu, n, dtype=state.mask.dtype)
    m_wo = state.mask * (1.0 - hot_v)        # Y' = Y \ {v}
    raw_u = _column(op, uu, n)               # unmasked: extend() reads the
    #                                          full column of the base
    col_u = raw_u * m_wo
    col_v = _column(op, v, n) * m_wo
    d = op.diag()
    # accept iff p (L_vv - bif_v) < L_uu - bif_u
    #        iff t := p L_vv - L_uu < p bif_v - bif_u   (Alg. 7)
    t = p * d[v] - d[uu]
    mop = _ops.Masked(op, m_wo)
    f_wo = None
    if incremental:
        f_wo = _update.downdate(state.factor, v)   # factor of Y'
        bif_u = _update.bif(f_wo, col_u)
        bif_v = _update.bif(f_wo, col_v)
        res = _solver.JudgeResult(decision=t < p * bif_v - bif_u,
                                  certified=f_wo.ok,
                                  iterations=jnp.zeros((), jnp.int32))
    elif exact:
        bif_u = _exact_bif(op, m_wo, col_u)
        bif_v = _exact_bif(op, m_wo, col_v)
        decision = t < p * bif_v - bif_u
        res = _solver.JudgeResult(decision=decision,
                                  certified=jnp.ones((), bool),
                                  iterations=jnp.zeros((), jnp.int32))
    elif batched and mesh is not None:
        from . import sharded as _sharded
        res = _sharded.judge_kdpp_swap_batch_sharded(
            _as_solver(solver, max_iters), mop, col_u, col_v, t, p,
            mesh=mesh, axis=lane_axis, lam_min=lam_min, lam_max=lam_max)
    elif batched:
        res = _as_solver(solver, max_iters).judge_kdpp_swap_batch(
            mop, col_u, col_v, t, p, lam_min=lam_min, lam_max=lam_max,
            chunk_iters=chunk_iters)
    else:
        res = _as_solver(solver, max_iters).judge_kdpp_swap(
            mop, col_u, mop, col_v, t, p, lam_min=lam_min, lam_max=lam_max)

    new_mask = jnp.where(res.decision, m_wo + hot_u, state.mask)
    new_factor = state.factor
    if incremental:
        new_factor = _update.tree_select(
            res.decision, _update.extend(f_wo, raw_u, uu), state.factor)
    st = state.stats
    stats = ChainStats(steps=st.steps + 1,
                       accepts=st.accepts + res.decision.astype(jnp.int32),
                       quad_iterations=st.quad_iterations + res.iterations,
                       uncertified=st.uncertified
                       + (~res.certified).astype(jnp.int32))
    return ChainState(mask=new_mask, key=key, stats=stats,
                      factor=new_factor)


def run_chain(step_fn, op, key: Array, init_mask: Array, num_steps: int,
              lam_min, lam_max, *, max_iters: int, exact: bool = False,
              solver: _solver.BIFSolver | None = None,
              factor=None) -> ChainState:
    """Drive ``num_steps`` moves under ``lax.scan`` (jit-friendly).

    ``factor`` (a ChainFactor of the INITIAL mask, e.g.
    ``update.from_mask(op, init_mask)``) switches the step to the
    incremental exact scorer and rides the scan carry."""
    def body(state, _):
        return step_fn(op, state, lam_min, lam_max, max_iters=max_iters,
                       exact=exact, solver=solver), None

    state0 = init_chain(key, init_mask, factor=factor)
    state, _ = jax.lax.scan(body, state0, None, length=num_steps)
    return state


class GreedyMapResult(NamedTuple):
    mask: Array             # (N,) float — the selected set
    order: Array            # (k,) int32 — items in selection order
    gains: Array            # (k,) certified gain bracket midpoints
    certified: Array        # (k,) bool — per-step argmax certification
    quad_iterations: Array  # total GQL iterations across all steps
    uncertified: Array      # steps decided by exhaustion fallback


def greedy_map(op, k: int, lam_min, lam_max, *, max_iters: int,
               exact: bool = False,
               solver: _solver.BIFSolver | None = None, mesh=None,
               lane_axis: str = "lanes",
               warm_start: bool = False,
               incremental: bool = False) -> GreedyMapResult:
    """Greedy MAP for the DPP (paper Alg. 4), batched over candidates.

    Per step, EVERY candidate's marginal gain  L_ii - u_i^T L_Y^-1 u_i
    (the Schur complement of adding i to Y) is scored as one lane of a
    single batched driver, and ``judge_argmax`` races the lanes: a
    candidate freezes as soon as its bracket is dominated, and the step
    ends when the winner's lower bound clears every rival — certified
    identical to greedy with exact solves. One (N, N)-stacked matvec per
    quadrature iteration replaces N sequential judges.

    ``warm_start=True`` carries each round's final score brackets into
    the next round as priors (lazy greedy, DESIGN.md Sec. 8.3): the
    Lanczos state itself cannot carry over — growing Y changes every
    candidate's system — but the score UPPER bounds stay valid because
    the Schur complement is non-increasing in Y, so candidates a banked
    bound already rules out freeze after their first bracket instead of
    re-solving. Selections stay certified-identical; only iteration
    counts drop.

    ``incremental=True`` additionally carries the small Cholesky factor
    of L_Y across the scan rounds (:mod:`repro.core.update`, DESIGN.md
    Sec. 12): each round it (a) reads the winner's exact gain off the
    factor (no quadrature midpoint) and (b) tightens EVERY surviving
    candidate's banked upper bound to its exact current score before the
    argmax race admits it — the exact Schur complement is itself a valid
    (the tightest) upper bound, so rivals freeze after their first
    bracket and the winner certifies against exact rival scores.
    Selections stay certified-identical to ``warm_start``-only and
    from-scratch runs while total quadrature iterations drop further
    (pinned in tests/test_update.py; tracked in
    BENCH_incremental_greedy.json). Composes with ``mesh``.

    ``mesh`` shards the N candidate lanes across a lane mesh
    (``judge_argmax_sharded``, DESIGN.md Sec. 7): the race's dominance
    checks become cross-device reductions, selections stay certified-
    identical to the single-device path.
    """
    quad = _as_solver(solver, max_iters)
    if mesh is not None and exact:
        raise ValueError("mesh requires the quadrature path: the exact "
                         "scorer runs single-device (pass exact=False)")
    if incremental and exact:
        raise ValueError(
            "incremental=True maintains the exact factor to ACCELERATE "
            "the quadrature race; the exact scorer has no race to "
            "accelerate (pass exact=False)")
    if mesh is not None:
        from . import sharded as _sharded
        quad_argmax = lambda mop_, u_, **kw: _sharded.judge_argmax_sharded(  # noqa: E731,E501
            quad, mop_, u_, mesh=mesh, axis=lane_axis, **kw)
    else:
        quad_argmax = quad.judge_argmax
    n = op.n
    d = op.diag()
    # candidate columns, once: row i of A (symmetric) = column i
    cols = op.matvec(jnp.eye(n, dtype=d.dtype))

    use_prior = warm_start or incremental

    def step(carry, _):
        if incremental:
            mask, prior, factor = carry
        else:
            mask, prior = carry
        u = cols * mask[None, :]            # lane i: col_i restricted to Y
        valid = mask < 0.5
        if exact:
            bif = _exact_bif(op, mask, u)
            score = jnp.where(valid, d - bif, -jnp.inf)
            idx = jnp.argmax(score).astype(jnp.int32)
            gain, cert = score[idx], jnp.ones((), bool)
            iters = jnp.zeros((), jnp.int32)
        else:
            if incremental:
                # exact current scores off the maintained factor: the
                # tightest valid uppers the race can be seeded with —
                # AND, being exact, equally valid lowers. Seeded on both
                # sides, every lane resolves at its first decide check
                # (dominated or certified winner), so the race costs one
                # iteration per lane instead of a full Lanczos.
                ex = _update.gains(factor, d, cols)
                prior = jnp.minimum(prior, ex)
            res = quad_argmax(_ops.Masked(op, mask), u, shift=d,
                              scale=-1.0, valid=valid,
                              prior_upper=prior if use_prior else None,
                              prior_lower=ex if incremental else None,
                              lam_min=lam_min, lam_max=lam_max)
            idx, cert = res.index, res.certified
            if incremental:
                # the winner's EXACT gain, straight off the factor
                gain = ex[idx]
            else:
                gain = 0.5 * (res.lower[idx] + res.upper[idx])
            iters = jnp.sum(res.iterations)
            if use_prior:
                # bank this round's upper bounds: still valid next round
                # (invalid lanes carry the -1e30 sentinel and stay
                # excluded by `valid` anyway)
                prior = jnp.minimum(prior, res.upper)
        new_mask = mask + jax.nn.one_hot(idx, n, dtype=mask.dtype)
        if incremental:
            factor = _update.extend(factor, cols[idx], idx)
            return (new_mask, prior, factor), (idx, gain, cert, iters)
        return (new_mask, prior), (idx, gain, cert, iters)

    mask0 = jnp.zeros((n,), d.dtype)
    prior0 = jnp.full((n,), jnp.inf, d.dtype)
    if incremental:
        carry0 = (mask0, prior0, _update.init_factor(n, k, dtype=d.dtype))
    else:
        carry0 = (mask0, prior0)
    (mask, *_), (order, gains, cert, iters) = jax.lax.scan(
        step, carry0, None, length=k)
    return GreedyMapResult(
        mask=mask, order=order, gains=gains, certified=cert,
        quad_iterations=jnp.sum(iters),
        uncertified=jnp.sum((~cert).astype(jnp.int32)))


class LogLikelihoodResult(NamedTuple):
    """Bracketed DPP log-likelihood (DESIGN.md Sec. 9).

    ``lower``/``upper`` bracket ``log P(Y) = logdet(L_Y) - logdet(L+I)``
    deterministically when both logdets use exact unit probes
    (``num_probes=None``); with Hutchinson probes they bracket the
    probe-sample estimate and ``stat_lower``/``stat_upper`` add the
    sampling CI. ``logdet_y``/``logdet_norm`` expose the two
    :class:`~repro.core.trace.TraceQuadResult` terms (each resumable).
    """
    lower: float
    upper: float
    estimate: float
    stat_lower: float
    stat_upper: float
    logdet_y: object
    logdet_norm: object
    iterations: int


def log_likelihood(op, mask: Array, lam_min, lam_max, *,
                   max_iters: int = 64, num_probes: int | None = None,
                   solver: _solver.BIFSolver | None = None, key=None,
                   mesh=None, lane_axis: str = "lanes",
                   rtol: float = 1e-6, atol: float = 1e-8
                   ) -> LogLikelihoodResult:
    """Bracketed L-ensemble log-likelihood of the set ``Y`` = ``mask``:

        log P(Y) = logdet(L_Y) - logdet(L + I)

    Both terms are retrospective quadrature logdets
    (:func:`repro.core.trace.trace_quad` with f=log). The submatrix
    term needs NO correction: the fixed-shape ``Masked`` operator is
    ``P L P + (I - P)`` whose spectrum is spec(L_Y) plus ones, and
    log(1) = 0 — so ``tr log Masked(L, m) == logdet(L_Y)`` exactly.
    The normalizer runs on ``Shifted(L, 1)``.

    ``lam_min``/``lam_max`` bound spec(L) (the usual chain contract);
    the masked term's interval is widened to include the identity
    block's 1s, the shifted term's interval moves up by 1. Defaults
    (``num_probes=None``) give a deterministic bracket containing the
    dense ``slogdet`` truth; a configured ``solver`` overrides the
    stopping policy (its ``fn`` is forced to 'log').
    """
    from . import trace as _trace

    mask = jnp.asarray(mask)
    quad = solver if solver is not None else _solver.BIFSolver.create(
        max_iters=max_iters, rtol=rtol, atol=atol, fn="log")
    lam_min = jnp.asarray(lam_min)
    lam_max = jnp.asarray(lam_max)
    one = jnp.asarray(1.0, lam_min.dtype)
    keys = (None, None) if key is None else jax.random.split(key)
    ld_y = _trace.trace_quad(
        _ops.Masked(op, mask), "log", num_probes, solver=quad,
        lam_min=jnp.minimum(lam_min, one), lam_max=jnp.maximum(lam_max, one),
        key=keys[0], mesh=mesh, lane_axis=lane_axis)
    ld_n = _trace.trace_quad(
        _ops.Shifted(op, one), "log", num_probes, solver=quad,
        lam_min=lam_min + 1.0, lam_max=lam_max + 1.0, key=keys[1],
        mesh=mesh, lane_axis=lane_axis)
    return LogLikelihoodResult(
        lower=ld_y.lower - ld_n.upper,
        upper=ld_y.upper - ld_n.lower,
        estimate=ld_y.estimate - ld_n.estimate,
        stat_lower=ld_y.stat_lower - ld_n.stat_upper,
        stat_upper=ld_y.stat_upper - ld_n.stat_lower,
        logdet_y=ld_y, logdet_norm=ld_n,
        iterations=ld_y.iterations + ld_n.iterations)


def sample_dpp(op, key, init_mask, num_steps, lam_min, lam_max, *,
               max_iters: int, exact: bool = False,
               solver: _solver.BIFSolver | None = None,
               incremental: bool = False,
               capacity: int | None = None) -> ChainState:
    factor = _update.from_mask(op, jnp.asarray(init_mask), capacity) \
        if incremental else None
    return run_chain(dpp_step, op, key, init_mask, num_steps, lam_min,
                     lam_max, max_iters=max_iters, exact=exact,
                     solver=solver, factor=factor)


def sample_kdpp(op, key, init_mask, num_steps, lam_min, lam_max, *,
                max_iters: int, exact: bool = False,
                solver: _solver.BIFSolver | None = None,
                incremental: bool = False,
                capacity: int | None = None) -> ChainState:
    factor = _update.from_mask(op, jnp.asarray(init_mask), capacity) \
        if incremental else None
    return run_chain(kdpp_step, op, key, init_mask, num_steps, lam_min,
                     lam_max, max_iters=max_iters, exact=exact,
                     solver=solver, factor=factor)
