"""Retrospective stochastic double greedy (paper Alg. 8 / 9).

Maximizes the (generally non-monotone) submodular F(S) = log det(L_S)
with the 1/2-approximation algorithm of Buchbinder et al. [14], replacing
each pair of exact marginal-gain evaluations with retrospective
quadrature brackets. Decisions provably match the exact algorithm run
with the same uniform draws (tests/test_double_greedy.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import operators as _ops
from . import solver as _solver
from .dpp import _as_solver, _exact_bif

Array = jax.Array


class DGResult(NamedTuple):
    selected: Array          # (N,) float mask X_N
    quad_iterations: Array
    uncertified: Array
    log_det: Array           # F(X_N), exact (for reporting)


def _logdet_masked(op, mask: Array) -> Array:
    a = op.a
    m = mask.astype(a.dtype)
    a_masked = a * m[..., :, None] * m[..., None, :] + (1.0 - m)[..., :, None] * jnp.eye(a.shape[-1], dtype=a.dtype)
    sign, ld = jnp.linalg.slogdet(a_masked)
    return ld


def double_greedy(op, key: Array, lam_min, lam_max, *, max_iters: int,
                  exact: bool = False, batched: bool = True,
                  solver: _solver.BIFSolver | None = None) -> DGResult:
    """Run Alg. 8 over the full ground set [N] (sequential by definition).

    ``batched=True`` (default) scores each element's X- and Y-side
    systems as two stacked-mask lanes of one batched driver (DESIGN.md
    Sec. 6); ``batched=False`` keeps the gap-weighted pair driver.
    Decisions are certified-identical either way."""
    quad = _as_solver(solver, max_iters)
    n = op.n
    d = op.diag()
    keys = jax.random.split(key, n)

    def step(carry, inp):
        x_mask, y_mask = carry
        i, k = inp
        hot = jax.nn.one_hot(i, n, dtype=x_mask.dtype)
        y_wo = y_mask * (1.0 - hot)              # Y' = Y_{i-1} \ {i}
        col = op.matvec(hot)
        u = col * x_mask                         # vs L_{X_{i-1}}
        v = col * y_wo                           # vs L_{Y'}
        t = d[i]
        p = jax.random.uniform(k, (), dtype=x_mask.dtype)

        if exact:
            bif_x = _exact_bif(op, x_mask, u)
            bif_y = _exact_bif(op, y_wo, v)
            big_neg = jnp.asarray(-1e30, t.dtype)
            gain_p = jnp.where(t - bif_x > 0,
                               jnp.log(jnp.maximum(t - bif_x, 1e-30)), big_neg)
            gain_m = -jnp.where(t - bif_y > 0,
                                jnp.log(jnp.maximum(t - bif_y, 1e-30)), big_neg)
            add = p * jnp.maximum(gain_m, 0.0) <= \
                (1 - p) * jnp.maximum(gain_p, 0.0)
            res = _solver.JudgeResult(decision=add,
                                      certified=jnp.ones((), bool),
                                      iterations=jnp.zeros((), jnp.int32))
        elif batched:
            op2 = _ops.stack_masks(op, jnp.stack([x_mask, y_wo]))
            res = quad.judge_double_greedy_batch(
                op2, jnp.stack([u, v]), t, p, lam_min=lam_min,
                lam_max=lam_max)
        else:
            res = quad.judge_double_greedy(
                _ops.Masked(op, x_mask), u, _ops.Masked(op, y_wo), v, t, p,
                lam_min=lam_min, lam_max=lam_max)

        x_new = jnp.where(res.decision, x_mask + hot, x_mask)
        y_new = jnp.where(res.decision, y_mask, y_wo)
        out = (res.iterations, (~res.certified).astype(jnp.int32))
        return (x_new, y_new), out

    x0 = jnp.zeros((n,), jnp.float32)
    y0 = jnp.ones((n,), jnp.float32)
    (x_fin, _), (iters, unc) = jax.lax.scan(
        step, (x0, y0), (jnp.arange(n), keys))
    ld = _logdet_masked(op, x_fin) if isinstance(op, _ops.Dense) \
        else jnp.asarray(jnp.nan, jnp.float32)
    return DGResult(selected=x_fin, quad_iterations=jnp.sum(iters),
                    uncertified=jnp.sum(unc), log_det=ld)
