"""Unified retrospective-quadrature solver (the paper's Alg. 2, once).

Every workload in this package — adaptive brackets on ``u^T A^-1 u``,
threshold judges for DPP chains, swap judges for k-DPP chains, the
double-greedy gain comparison — is the same loop: iterate Gauss /
Gauss-Radau / Gauss-Lobatto quadrature until the bracket resolves the
caller's decision, freezing lanes that are done (DESIGN.md Sec. 5).
``BIFSolver`` is that loop, exactly once, behind a policy-carrying config:

    solver = BIFSolver(SolverConfig(max_iters=64, rtol=1e-3))
    res = solver.solve(op, u, lam_min=lmn, lam_max=lmx)   # SolveResult
    res = solver.solve(op, u, decide=lambda lo, hi: t < lo)

The loop is an explicit, resumable state machine (DESIGN.md Sec. 8):
``init_state`` / ``step_n`` / ``resume`` / ``finalize`` operate on a
checkpointable :class:`QuadState` pytree, and ``solve`` is just
``finalize(resume(init_state(...)))`` — a consumer can pause a solve at
any iteration, bank its bracket, ship the state, and resume later
bit-exactly (the serving engine's continuous batching and the warm-
started greedy chains are built on exactly this).

Config axes:

  * ``spectrum``     -- where [lam_min, lam_max] comes from when not given
                        explicitly: 'explicit' | 'gershgorin' | 'lanczos'
                        | 'ridge' (spectrum.py estimators, paper Sec. 4.1);
  * ``precondition`` -- 'none' | 'jacobi' (similarity transform, Sec. 5.4);
  * ``reorth``       -- full reorthogonalization of the Lanczos basis
                        (Sec. 5.4 'Instability');
  * ``backend``      -- 'reference' (pure-jnp ``gql.recurrence_update``)
                        | 'pallas' (fused ``kernels/gql_update.py`` VPU
                        kernel for the scalar recurrence only)
                        | 'fused' (``kernels/lanczos_step.py`` megakernel:
                        matvec + Lanczos update + reorth + recurrence in
                        ONE pallas_call per iteration; operators with no
                        sandwich form fall back to the reference
                        composition bit-exactly);
  * ``decide_every`` -- round cadence R of the stopping rule: the loop
                        runs R shard-local steps between decision rounds
                        (DESIGN.md Sec. 11). Sound by Thm. 4.2 bracket
                        nesting — costs at most R-1 extra contractions
                        per lane, never flips a certified decision.

``BIFSolver`` and ``SolverConfig`` are frozen, hashable, and registered as
static pytrees, so they cross ``jit`` / ``vmap`` / ``scan`` boundaries and
can be closure-captured or passed as arguments freely.

The PR-2 legacy entry points (``bounds.bif_bounds``, ``judge.*``,
``precond.preconditioned_bif_bounds``) that used to shim this driver
were removed per DESIGN.md Sec. 5; quadlint QL005 keeps them out.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import block as _block
from . import gql as _gql
from . import matfun as _matfun
from . import operators as _ops
from . import spectrum as _spectrum
from .loop_utils import tree_freeze

Array = jax.Array

_SPECTRA = ("explicit", "gershgorin", "lanczos", "ridge")
_PRECONDITIONS = ("none", "jacobi")
_BACKENDS = ("reference", "pallas", "fused")


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Policy knobs for the retrospective driver (all static metadata)."""
    max_iters: int = 64
    rtol: float = 1e-2
    atol: float = 0.0
    spectrum: str = "explicit"       # 'explicit'|'gershgorin'|'lanczos'|'ridge'
    precondition: str = "none"       # 'none'|'jacobi'
    reorth: bool = False
    backend: str = "reference"       # 'reference'|'pallas'|'fused'
    decide_every: int = 1            # decision-round cadence R (>= 1):
    #                                  evaluate the stopping rule every R
    #                                  steps; states stay round-aligned
    #                                  (step_n quantizes to floor(n/R)*R)
    spectrum_iters: int = 16         # Lanczos steps for spectrum estimation
    ridge: float = 0.0               # known ridge for spectrum='ridge'
    pallas_interpret: bool | None = None  # None: auto (off-TPU -> interpret)
    fn: str = "inv"                  # spectral function (matfun.REGISTRY):
    #                                  'inv' = the legacy GQL recurrence,
    #                                  bit-exact; others bracket u^T f(A) u
    #                                  via the Jacobi-matrix eigensolve
    #                                  (DESIGN.md Sec. 9)
    block_size: int = 1              # block-Krylov width b (DESIGN.md
    #                                  Sec. 13): b > 1 runs the block
    #                                  three-term recurrence on (..., b, N)
    #                                  probe blocks, bracketing
    #                                  tr B^T f(A) B per lane; b = 1 IS
    #                                  the scalar driver (same code path,
    #                                  bit-exact)

    def __post_init__(self):
        if self.spectrum not in _SPECTRA:
            raise ValueError(f"spectrum must be one of {_SPECTRA}, "
                             f"got {self.spectrum!r}")
        if self.precondition not in _PRECONDITIONS:
            raise ValueError(f"precondition must be one of {_PRECONDITIONS}, "
                             f"got {self.precondition!r}")
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, "
                             f"got {self.backend!r}")
        if self.max_iters < 1:
            raise ValueError("max_iters must be >= 1")
        if self.decide_every < 1:
            raise ValueError(
                f"decide_every must be >= 1, got {self.decide_every}")
        _matfun.fn_index(self.fn)  # raises on unknown fn tags
        if self.fn != "inv" and self.precondition != "none":
            raise ValueError(
                "precondition='jacobi' is an identity for u^T A^-1 u only "
                "(u^T f(A) u has no similarity-transform counterpart); "
                "fn != 'inv' requires precondition='none'")
        if self.block_size < 1:
            raise ValueError(
                f"block_size must be >= 1, got {self.block_size}")
        if self.block_size > 1:
            if self.reorth:
                raise NotImplementedError(
                    "reorthogonalization is not implemented for the block "
                    "recurrence; block_size > 1 requires reorth=False")
            if self.precondition != "none":
                raise NotImplementedError(
                    "preconditioning transforms each probe column "
                    "separately and would break the block bracket's "
                    "tr B^T f(A) B semantics; block_size > 1 requires "
                    "precondition='none'")


class SolveResult(NamedTuple):
    """Rich per-lane outcome of one retrospective solve."""
    lower: Array          # best lower bound (right Gauss-Radau, Thm. 4)
    upper: Array          # best upper bound (left Gauss-Radau, Thm. 6)
    gauss_lower: Array    # plain Gauss lower bound (Thm. 2)
    lobatto_upper: Array  # Gauss-Lobatto upper bound
    iterations: Array     # int32 quadrature iterations spent per lane
    converged: Array      # resolved by bounds OR Krylov space exhausted
    certified: Array      # resolved by the bounds alone (no exhaustion)
    state: Any            # final QuadState (resume()-able checkpoint)


class JudgeResult(NamedTuple):
    decision: Array     # bool
    certified: Array    # bool — True if resolved by bounds (not fallback)
    iterations: Array   # int32 total quadrature iterations spent


class ArgmaxResult(NamedTuple):
    """Outcome of a certified argmax race over K candidate lanes."""
    index: Array        # int32 — winning lane (last axis of the batch)
    certified: Array    # bool — winner's lower bound cleared every rival
    iterations: Array   # (..., K) int32 per-lane iterations spent
    lower: Array        # (..., K) final score lower bounds
    upper: Array        # (..., K) final score upper bounds


class QuadratureTrace(NamedTuple):
    gauss: Array        # (iters, ...) lower
    radau_lower: Array  # (iters, ...) right Gauss-Radau
    radau_upper: Array  # (iters, ...) left Gauss-Radau
    lobatto: Array      # (iters, ...) upper


class PairState(NamedTuple):
    a: Any  # GQLState for the first (u-side) system
    b: Any  # GQLState for the second (v-side) system


class QuadState(NamedTuple):
    """Checkpointable retrospective-solve state (DESIGN.md Sec. 8).

    The full resumable runtime state of one (batched) Alg.-2 drive: the
    *prepared* operator (backend-configured, preconditioned), the GQL
    recurrence state (Lanczos vectors + bracket + per-lane done/it
    flags), the spectral interval the recurrence was started with, the
    reorthogonalization basis (or None), and the global step counter
    (the basis write cursor). It is an ordinary pytree: it crosses
    ``jit`` boundaries, checkpoints, ships between processes, and —
    leaves sharded on their leading lane axis — lives on a lane mesh.

    Invariant: for any k, ``resume(step_n(state, k))`` is the SAME
    computation as ``resume(state)`` — interrupting and resuming a solve
    reproduces the uninterrupted drive (pinned in tests/test_runtime.py).

    ``coeffs`` (a :class:`~repro.core.matfun.CoeffHistory`, or None on
    the legacy f=1/x path) carries the per-lane alpha/beta Lanczos
    history plus the spectral-function index, making matfun states
    (``SolverConfig.fn != 'inv'``) exactly as checkpointable: the
    ``lower``/``upper`` views below reorient per the registry's
    derivative-sign table (DESIGN.md Sec. 9).
    """
    op: Any           # prepared operator (pytree)
    st: Any           # gql.GQLState — recurrence + bracket + done/it
    lam_min: Array
    lam_max: Array
    basis: Any        # (..., M, N) reorth storage, or None
    step: Array       # int32 — global steps taken since init
    coeffs: Any = None  # matfun.CoeffHistory, or None (fn='inv')

    # Convenience views (the banked bracket a consumer can act on any
    # time; `it`/`done` for budget accounting).
    def bracket(self) -> tuple[Array, Array]:
        """(lower, upper) in ONE pass — on matfun and block states the
        two sides share a single Jacobi-matrix eigensolve, so prefer
        this over reading ``.lower`` and ``.upper`` separately (each
        property alone re-runs it)."""
        if isinstance(self.st, _block.BlockState):
            lo, hi, _, _ = _block.bracket(self.st, self.lam_min,
                                          self.lam_max)
            return lo, hi
        if self.coeffs is None:
            return _gql.lower_bound(self.st), _gql.upper_bound(self.st)
        lo, hi, _, _ = _matfun.bracket(self.coeffs, self.st, self.lam_min,
                                       self.lam_max)
        return lo, hi

    @property
    def lower(self) -> Array:
        return self.bracket()[0]

    @property
    def upper(self) -> Array:
        return self.bracket()[1]

    @property
    def it(self) -> Array:
        return self.st.it

    @property
    def done(self) -> Array:
        return self.st.done


# The QuadState threading contract (DESIGN.md Sec. 10, enforced by
# quadlint QL001): every field lives in exactly ONE bucket, and the
# handler layers are checked against the buckets —
#   per-lane : advanced by the loop and frozen per lane as lanes resolve
#              (step_n/resume tree_freeze carries), sharded with the
#              lanes by core/sharded.py, banked/scattered per lane by
#              serve/engine.py's pool;
#   carried  : whole-state bookkeeping threaded through every drive's
#              _replace (no per-lane freeze semantics);
#   prepared : resolved once by init_state and read-only afterwards.
# A new QuadState field (block-Krylov buffers, rank-update caches, ...)
# that is not added to a bucket AND to every non-excluded handler is a
# CI failure, not a review catch.
QUADSTATE_PER_LANE = ("st", "basis", "coeffs")
QUADSTATE_CARRIED = ("step",)
QUADSTATE_PREPARED = ("op", "lam_min", "lam_max")


def _argmax_scores(lo: Array, hi: Array, shift, scale, valid,
                   prior_upper=None, prior_lower=None):
    """Per-lane score brackets ``shift + scale * [lo, hi]`` for the argmax
    race, with invalid lanes pinned at a large negative sentinel. Shared
    by ``judge_argmax`` and the sharded driver (core/sharded.py) so the
    two paths race on bit-identical values.

    ``prior_upper`` (optional, per-lane) is an externally-known valid
    upper bound on the score — e.g. a previous greedy round's bracket,
    valid by Schur-complement monotonicity (DESIGN.md Sec. 8.3). The
    effective upper bound is clamped to it (never below the lane's own
    lower bound, so a slightly-stale prior can only stop helping, never
    corrupt the race).

    ``prior_lower`` (optional, per-lane) is the dual: an externally-known
    valid lower bound on the score — e.g. the exact Schur complement read
    off a maintained factor (core/update.py, DESIGN.md Sec. 12). Clamped
    to never exceed the effective upper bound. With both priors exact the
    race resolves at its very first decide check."""
    big_neg = jnp.asarray(-1e30, lo.dtype)
    a = shift + scale * lo
    b = shift + scale * hi
    slo, shi = jnp.minimum(a, b), jnp.maximum(a, b)
    if prior_upper is not None:
        shi = jnp.maximum(jnp.minimum(shi, prior_upper), slo)
    if prior_lower is not None:
        slo = jnp.minimum(jnp.maximum(slo, prior_lower), shi)
    if valid is not None:
        slo = jnp.where(valid, slo, big_neg)
        shi = jnp.where(valid, shi, big_neg)
    return slo, shi


def _argmax_race(slo: Array, shi: Array):
    """(dominated, winner) per lane of the certified argmax race."""
    k = shi.shape[-1]
    if k == 1:
        return jnp.zeros_like(shi, bool), jnp.ones_like(shi, bool)
    best_lo = jnp.max(slo, axis=-1, keepdims=True)
    dominated = shi < best_lo
    order = jnp.sort(shi, axis=-1)
    top1, top2 = order[..., -1:], order[..., -2:-1]
    leader = jnp.argmax(shi, axis=-1, keepdims=True)
    rival_hi = jnp.where(jnp.arange(k) == leader, top2, top1)
    winner = slo >= rival_hi
    return dominated, winner


# Log-gain brackets for the greedy / double-greedy judges live in the
# matfun registry (one home for bound orientation); kept under the old
# private name for the judges below.
_log_gain_bounds = _matfun.log_gain_bounds


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class BIFSolver:
    """One retrospective quadrature driver; see module docstring."""
    config: SolverConfig = SolverConfig()

    # -- construction sugar -------------------------------------------------

    @classmethod
    def create(cls, **config_kwargs) -> "BIFSolver":
        return cls(SolverConfig(**config_kwargs))

    def replace(self, **config_kwargs) -> "BIFSolver":
        return BIFSolver(dataclasses.replace(self.config, **config_kwargs))

    # -- backend / problem preparation --------------------------------------

    def _stepper(self):
        """One-iteration GQL step implementation per ``config.backend``:
        ``stepfn(op, st, lam_min, lam_max, basis)``. 'fused' routes the
        whole iteration (matvec + Lanczos + reorth + recurrence) through
        the ``kernels/lanczos_step.py`` megakernel; 'reference'/'pallas'
        compose ``gql.gql_step`` with the configured recurrence.

        With ``block_size > 1`` every backend steps the block recurrence
        (``block.block_step``): the per-iteration work is already
        gemm-shaped through ``operators.matvec_mrhs`` (the BELL pallas
        path uses the multi-RHS kernel), so there is no separate fused
        megakernel — the backend knob still picks the operator's matvec
        execution mode via ``configure_backend``."""
        if self.config.block_size > 1:
            def block_step(op, st, lam_min, lam_max, basis=None):
                return _block.block_step(op, st, lam_min, lam_max)

            return block_step
        if self.config.backend == "fused":
            from ..kernels import ops as _kops  # deferred: pulls in pallas
            interpret = self.config.pallas_interpret

            def fused_step(op, st, lam_min, lam_max, basis=None):
                return _kops.gql_step_fused(op, st, lam_min, lam_max,
                                            basis=basis, interpret=interpret)

            return fused_step
        rec = self._recurrence()

        def composed_step(op, st, lam_min, lam_max, basis=None):
            return _gql.gql_step(op, st, lam_min, lam_max, basis=basis,
                                 recurrence=rec)

        return composed_step

    def _recurrence(self):
        """Scalar-recurrence implementation per ``config.backend``."""
        if self.config.backend != "pallas":
            return None  # gql_step default: gql.recurrence_update
        from ..kernels import ops as _kops  # deferred: pulls in pallas
        interpret = self.config.pallas_interpret

        def pallas_recurrence(alpha_n, beta_n, beta_p, g, c, delta,
                              d_lr, d_rr, lam_min, lam_max):
            shape = g.shape

            def flat(x):
                return jnp.broadcast_to(jnp.asarray(x, g.dtype),
                                        shape).reshape((-1,))

            outs = _kops.gql_update(
                flat(alpha_n), flat(beta_n), flat(beta_p), flat(g), flat(c),
                flat(delta), flat(d_lr), flat(d_rr), flat(lam_min),
                flat(lam_max), interpret=interpret)
            return tuple(o.reshape(shape) for o in outs)

        return pallas_recurrence

    def prepare(self, op, u: Array, lam_min=None, lam_max=None, probe=None):
        """Apply preconditioning and resolve the spectral interval.

        Returns ``(op, u, lam_min, lam_max)`` ready for ``gql_init``.
        Explicitly passed ``lam_min``/``lam_max`` always win; missing ends
        are filled per ``config.spectrum``.

        With ``precondition='jacobi'`` the quadrature runs on the
        *transformed* operator ``D^-1/2 A D^-1/2``, so an explicitly
        passed interval must bound THAT spectrum (not A's — the two
        intervals differ in general). Prefer leaving the interval to an
        estimating spectrum mode, which runs on the transformed operator
        automatically.
        """
        cfg = self.config
        op = _ops.configure_backend(op, cfg.backend, cfg.pallas_interpret)
        if cfg.precondition == "jacobi":
            pop = _ops.Jacobi.create(op)
            u = pop.transform_vector(u)
            op = pop
        if lam_min is not None and lam_max is not None:
            return op, u, lam_min, lam_max

        if cfg.spectrum == "explicit":
            raise ValueError(
                "spectrum='explicit' requires lam_min and lam_max; pass "
                "them to solve()/judge_*() or pick an estimating spectrum "
                "mode ('gershgorin' | 'lanczos' | 'ridge')")
        if cfg.spectrum == "gershgorin":
            est = _spectrum.gershgorin_bounds_spd(op)
        else:
            if probe is None:
                probe = jnp.where(jnp.abs(u) > 0, u, jnp.ones_like(u))
            if cfg.spectrum == "ridge":
                est = _spectrum.ridge_bounds(op, cfg.ridge, probe,
                                             num_iters=cfg.spectrum_iters)
            else:  # 'lanczos'
                est = _spectrum.lanczos_extremal(
                    op, probe, num_iters=cfg.spectrum_iters)
        lam_min = est.lam_min if lam_min is None else lam_min
        lam_max = est.lam_max if lam_max is None else lam_max
        return op, u, lam_min, lam_max

    # -- the resumable runtime (DESIGN.md Sec. 8) -----------------------------
    #
    # init_state / step_n / resume / finalize are the single source of
    # truth for the retrospective loop: solve, solve_batch, trace, the
    # judges, the sharded driver (core/sharded.py), and the serving
    # engine (serve/engine.py) are all built on them. The state machine
    # is explicit so a consumer can pause a solve at any iteration, bank
    # its bracket, checkpoint/ship the QuadState, and resume later —
    # bit-exact with an uninterrupted run.

    def _bracket2(self, st, coeffs, lam_min, lam_max):
        """The (lower, upper) bracket the stopping rules act on:
        the legacy GQL Radau views for fn='inv' (coeffs is None,
        bit-exact with the pre-matfun solver), the block-quadrature
        trace bracket on block states (DESIGN.md Sec. 13), else the
        sign-aware matfun bracket (DESIGN.md Sec. 9)."""
        if isinstance(st, _block.BlockState):
            lo, hi, _, _ = _block.bracket(st, lam_min, lam_max)
            return lo, hi
        if coeffs is None:
            return _gql.lower_bound(st), _gql.upper_bound(st)
        lo, hi, _, _ = _matfun.bracket(coeffs, st, lam_min, lam_max)
        return lo, hi

    def _bracket4(self, st, coeffs, lam_min, lam_max):
        """(lower, upper, loose_lower, loose_upper): the tight Radau
        bracket plus the loose Gauss/Lobatto pair, oriented per fn."""
        if isinstance(st, _block.BlockState):
            return _block.bracket(st, lam_min, lam_max)
        if coeffs is None:
            return (_gql.lower_bound(st), _gql.upper_bound(st),
                    _gql.lower_bound_gauss(st), _gql.upper_bound_lobatto(st))
        return _matfun.bracket(coeffs, st, lam_min, lam_max)

    def _needs_more_fn(self, decide, it_cap=None, *, lam_min=None,
                       lam_max=None):
        """(needs_more(st, coeffs), resolved(st, coeffs)) for the loop:
        a lane keeps stepping while it is not done (breakdown), not
        resolved by ``decide`` (None = the tolerance rule), and below
        both the config's ``max_iters`` and the optional per-lane
        ``it_cap`` (the serving engine's per-request iteration budget).
        ``lam_min``/``lam_max`` feed the matfun bracket (unused on the
        fn='inv' path, where coeffs is None)."""
        local_ok = self._local_ok_fn(it_cap)

        if decide is None:
            def resolved(st, coeffs):
                return self.tolerance_resolved(
                    *self._bracket2(st, coeffs, lam_min, lam_max))
        else:
            def resolved(st, coeffs):
                return decide(*self._bracket2(st, coeffs, lam_min, lam_max))

        def needs_more(st, coeffs):
            return local_ok(st, coeffs) & ~resolved(st, coeffs)

        return needs_more, resolved

    def _local_ok_fn(self, it_cap=None):
        """The *decide-free* per-lane continuation conditions: not broken
        down, below ``max_iters``, within the coefficient history, and
        below the optional per-lane ``it_cap``. These freeze a lane
        immediately even inside a ``decide_every`` round (unlike the
        stopping rule, which is only consulted at round boundaries —
        deferring a decide costs at most R-1 extra contractions by
        Thm. 4.2, but overrunning a budget or the history buffer would
        be a correctness bug, not a latency trade)."""
        max_iters = self.config.max_iters

        def local_ok(st, coeffs):
            ok = ~st.done & (st.it < max_iters)
            if coeffs is not None:
                # never advance a lane past its recorded alpha/beta
                # history: an undersized ``coeff_rows`` buffer freezes
                # like an iteration budget (bracket stops tightening but
                # stays sound) instead of silently corrupting estimates
                ok = ok & (st.it < coeffs.alphas.shape[-1])
            elif isinstance(st, _block.BlockState):
                # same rule for the block A/B history buffer
                ok = ok & (st.it < st.a_hist.shape[-3])
            if it_cap is not None:
                ok = ok & (st.it < it_cap)
            return ok

        return local_ok

    def _advance(self, op, st, lam_min, lam_max, basis, coeffs, step,
                 stepfn):
        """One unconditional GQL step + reorth-basis / coefficient-
        history bookkeeping (no freezing — the caller applies its own
        rule). ``stepfn`` comes from :meth:`_stepper` (reference /
        pallas-recurrence / fused-megakernel backends)."""
        st1 = stepfn(op, st, lam_min, lam_max, basis)
        if coeffs is not None:
            coeffs = _matfun.update_coeffs(coeffs, st, st1)
        if basis is None:
            return st1, None, coeffs
        basis1 = jax.lax.dynamic_update_index_in_dim(
            basis, st1.lz.v, step + 2, axis=-2)
        return st1, basis1, coeffs

    def init_state(self, op, u: Array, *, lam_min=None, lam_max=None,
                   probe=None, basis_rows: int | None = None,
                   coeff_rows: int | None = None) -> QuadState:
        """Prepare the problem and take iteration 1 (Alg. 5 init).

        The returned :class:`QuadState` is self-contained: it carries the
        prepared (backend-configured, preconditioned) operator and the
        resolved spectral interval, so ``step_n``/``resume`` need nothing
        else. ``basis_rows`` sizes the reorthogonalization storage when
        ``config.reorth`` (default ``max_iters + 1``); ``coeff_rows``
        the alpha/beta history when ``config.fn != 'inv'`` (default
        ``max_iters``).

        With ``config.block_size = b > 1`` the query is a row-stacked
        probe BLOCK ``u`` of shape (..., b, N) and the state brackets
        ``tr B^T f(A) B`` per lane via the block recurrence
        (``coeff_rows`` then sizes the block A/B history, in block
        iterations). b = 1 takes the scalar path below unchanged.
        """
        cfg = self.config
        if cfg.block_size > 1:
            u = jnp.asarray(u)
            if u.ndim < 2 or u.shape[-2] != cfg.block_size:
                raise ValueError(
                    f"block_size={cfg.block_size} wants (..., b, N) "
                    f"row-stacked probe blocks with b={cfg.block_size}, "
                    f"got shape {u.shape}")
            op, u, lam_min, lam_max = self.prepare(op, u, lam_min, lam_max,
                                                   probe)
            # estimating spectrum modes return per-probe bounds: take the
            # union interval over the lane's block slots
            lam_min = jnp.asarray(lam_min)
            lam_max = jnp.asarray(lam_max)
            if lam_min.ndim > u.ndim - 2:
                lam_min = jnp.min(lam_min, axis=-1)
            if lam_max.ndim > u.ndim - 2:
                lam_max = jnp.max(lam_max, axis=-1)
            st0 = _block.block_init(
                op, u, lam_min, lam_max, cfg.fn,
                cfg.max_iters if coeff_rows is None else coeff_rows)
            return QuadState(op=op, st=st0, lam_min=lam_min,
                             lam_max=lam_max, basis=None,
                             step=jnp.zeros((), jnp.int32), coeffs=None)
        op, u, lam_min, lam_max = self.prepare(op, u, lam_min, lam_max,
                                               probe)
        st0 = _gql.gql_init(op, u, lam_min, lam_max)
        if cfg.reorth:
            rows = cfg.max_iters + 1 if basis_rows is None else basis_rows
            basis = self._alloc_basis(st0, u, rows)
        else:
            basis = None
        if cfg.fn != "inv":
            coeffs = _matfun.init_coeffs(
                st0, cfg.fn,
                cfg.max_iters if coeff_rows is None else coeff_rows)
        else:
            coeffs = None
        return QuadState(op=op, st=st0, lam_min=jnp.asarray(lam_min),
                         lam_max=jnp.asarray(lam_max), basis=basis,
                         step=jnp.zeros((), jnp.int32), coeffs=coeffs)

    def _round_body(self, op, lam_min, lam_max, stepfn, local_ok):
        """One ``decide_every`` round: R substeps with *local-only*
        freezing (breakdown / max_iters / history / it_cap apply
        immediately; the stopping rule is deferred to the boundary).
        Returns ``round_fn((st, basis, coeffs, step, nm)) -> same`` with
        ``nm`` the entry round-boundary needs_more; the caller evaluates
        the next boundary's needs_more on the result. With R=1 this is
        exactly the historical one-step body (the single substep's
        freeze mask IS the boundary needs_more)."""
        r = self.config.decide_every

        def substep(i, carry):
            st, basis, coeffs, step, nm = carry
            st1, basis1, coeffs1 = self._advance(op, st, lam_min, lam_max,
                                                 basis, coeffs, step, stepfn)
            frozen = ~nm
            st1 = tree_freeze(st1, st, frozen)
            if basis is not None:
                basis1 = tree_freeze(basis1, basis, frozen)
            if coeffs is not None:
                coeffs1 = tree_freeze(coeffs1, coeffs, frozen)
            nm1 = nm & local_ok(st1, coeffs1)
            return st1, basis1, coeffs1, step + 1, nm1

        if r == 1:
            return lambda carry: substep(0, carry)
        return lambda carry: jax.lax.fori_loop(0, r, substep, carry)

    def step_n(self, state: QuadState, n: int, decide=None, *,
               it_cap=None, convergence_log=None) -> QuadState:
        """Advance ``state`` by at most ``n`` quadrature iterations.

        Lanes that already resolved ``decide`` (None = the tolerance
        rule), broke down, or hit ``max_iters`` / the optional per-lane
        ``it_cap`` budget are frozen bit-exactly — the same rule
        ``resume`` applies, so ``resume(step_n(state, k))`` reproduces
        ``resume(state)`` exactly. ``n`` is a static bound on this call's
        steps; the loop exits early once every lane is frozen.

        With ``decide_every = R > 1`` the stopping rule is evaluated
        every R steps and states stay *round-aligned*: ``step_n``
        advances at most ``floor(n / R) * R`` steps (``n < R`` is a
        no-op), keeping the resume invariant exact at every cadence.

        ``convergence_log`` (an :class:`repro.obs.health.ConvergenceLog`)
        records the returned state's bracket + iteration counts — a
        HOST-side read of the already-computed result, so the compiled
        loop above is untouched and logging is bit-invariant. Only legal
        outside a trace (under jit the views are tracers); the engine
        and other jitted callers simply never pass it.
        """
        if n < 0:
            raise ValueError(f"n must be >= 0, got {n}")
        r = self.config.decide_every
        rounds = n // r
        if rounds == 0:
            return state
        stepfn = self._stepper()
        op, lam_min, lam_max = state.op, state.lam_min, state.lam_max
        needs_more, _ = self._needs_more_fn(decide, it_cap,
                                            lam_min=lam_min, lam_max=lam_max)
        round_fn = self._round_body(op, lam_min, lam_max, stepfn,
                                    self._local_ok_fn(it_cap))

        # needs_more is carried through the loop (computed once per
        # round, like the sharded driver): for matfun states it is the
        # stacked Jacobi eigensolve — evaluating it in both cond and
        # body would double the dominant per-round cost
        def cond(carry):
            (_, _, _, _, nm), taken = carry
            return jnp.any(nm) & (taken < rounds)

        def body(carry):
            inner, taken = carry
            st, basis, coeffs, step, _ = round_fn(inner)
            nm = needs_more(st, coeffs)
            return (st, basis, coeffs, step, nm), taken + 1

        (st, basis, coeffs, step, _), _ = jax.lax.while_loop(
            cond, body,
            ((state.st, state.basis, state.coeffs, state.step,
              needs_more(state.st, state.coeffs)),
             jnp.zeros((), jnp.int32)))
        out = state._replace(st=st, basis=basis, coeffs=coeffs, step=step)
        if convergence_log is not None:
            convergence_log.record_state(out)
        return out

    def resume(self, state: QuadState, decide=None, *,
               it_cap=None) -> QuadState:
        """Run the retrospective loop (Alg. 2) from ``state`` until
        ``decide`` resolves on every lane (or breakdown / ``max_iters`` /
        the per-lane ``it_cap`` budget), freezing resolved lanes
        bit-exactly. Starting from a fresh ``init_state`` this IS the
        uninterrupted drive; starting from a ``step_n`` checkpoint it
        continues it bit-exactly (``step_n`` keeps states round-aligned,
        so the cadence-R decision schedule lines up too)."""
        stepfn = self._stepper()
        op, lam_min, lam_max = state.op, state.lam_min, state.lam_max
        needs_more, _ = self._needs_more_fn(decide, it_cap,
                                            lam_min=lam_min, lam_max=lam_max)
        round_fn = self._round_body(op, lam_min, lam_max, stepfn,
                                    self._local_ok_fn(it_cap))

        # nm carried through the loop — one bracket evaluation per round
        # (see step_n)
        def cond(carry):
            return jnp.any(carry[4])

        def body(carry):
            st, basis, coeffs, step, _ = round_fn(carry)
            return st, basis, coeffs, step, needs_more(st, coeffs)

        st, basis, coeffs, step, _ = jax.lax.while_loop(
            cond, body, (state.st, state.basis, state.coeffs, state.step,
                         needs_more(state.st, state.coeffs)))
        return state._replace(st=st, basis=basis, coeffs=coeffs, step=step)

    def resume_chunked(self, state: QuadState, decide=None, *,
                       chunk_iters: int, it_cap=None) -> QuadState:
        """``resume`` as repeated ``step_n(chunk_iters)`` decision rounds:
        each round continues from the banked state of the still-unresolved
        lanes instead of re-solving. Bit-exact with ``resume`` (same step
        computation, same freezing) — this is the jit-side skeleton of
        the serving engine's scheduler and the chunked chain judges.

        Matfun cost note: the chunk-boundary check here re-evaluates the
        bracket that ``step_n`` also evaluates for its own carry — one
        extra eigensolve per round (chunk_iters+2 instead of
        chunk_iters+1). Accepted: deduplicating would mean threading
        precomputed freeze flags through ``step_n``'s public signature."""
        if chunk_iters < 1:
            raise ValueError(f"chunk_iters must be >= 1, got {chunk_iters}")
        # align the round size up to the decision cadence: a chunk below
        # ``decide_every`` would make step_n a round-aligned no-op (and
        # this loop livelock); rounding up preserves "at most chunk_iters
        # per round" spirit at the configured cadence granularity
        r = self.config.decide_every
        chunk_iters = -(-chunk_iters // r) * r
        needs_more, _ = self._needs_more_fn(decide, it_cap,
                                            lam_min=state.lam_min,
                                            lam_max=state.lam_max)

        def cond(s):
            return jnp.any(needs_more(s.st, s.coeffs))

        def body(s):
            return self.step_n(s, chunk_iters, decide, it_cap=it_cap)

        return jax.lax.while_loop(cond, body, state)

    def finalize(self, state: QuadState, decide=None) -> SolveResult:
        """Read a :class:`SolveResult` off a (partial or completed) state.

        ``certified`` re-evaluates ``decide`` (None = tolerance rule) on
        the banked bracket, so finalizing a budget-interrupted state
        reports honestly whether the decision already resolved.

        For matfun states (``config.fn != 'inv'``) the result fields
        are oriented per the registry's sign table: ``lower``/``upper``
        hold the tight Radau bracket and ``gauss_lower``/
        ``lobatto_upper`` the loose Gauss/Lobatto pair as lower/upper
        respectively (for log-like f the underlying rules swap sides —
        DESIGN.md Sec. 9)."""
        _, resolved = self._needs_more_fn(decide, lam_min=state.lam_min,
                                          lam_max=state.lam_max)
        st = state.st
        certified = resolved(st, state.coeffs)
        lower, upper, loose_lo, loose_hi = self._bracket4(
            st, state.coeffs, state.lam_min, state.lam_max)
        return SolveResult(
            lower=lower, upper=upper,
            gauss_lower=loose_lo, lobatto_upper=loose_hi,
            iterations=st.it, converged=st.done | certified,
            certified=certified, state=state)

    def _alloc_basis(self, st0, u: Array, num_rows: int):
        """Reorthogonalization storage: rows 0..num_rows-1 hold v_0..v_M."""
        basis = jnp.zeros(u.shape[:-1] + (num_rows, u.shape[-1]), u.dtype)
        basis = jax.lax.dynamic_update_index_in_dim(
            basis, st0.lz.v_prev, 0, axis=-2)  # v_0
        return jax.lax.dynamic_update_index_in_dim(
            basis, st0.lz.v, 1, axis=-2)       # v_1

    def tolerance_resolved(self, lower: Array, upper: Array) -> Array:
        """The ``decide=None`` stopping rule: bracket gap within the
        configured ``atol``/``rtol`` of the lower bound. The single
        definition shared by ``solve`` and ``serve.BIFEngine`` so the
        serving path can't drift from the solver's rule."""
        return (upper - lower) <= jnp.maximum(
            self.config.atol, self.config.rtol * jnp.abs(lower))

    @staticmethod
    def threshold_decision(t: Array, lower: Array, upper: Array) -> Array:
        """Alg. 4 decision from a bracket: certified when ``t`` clears
        [lower, upper); bracket-midpoint tie-break when it doesn't."""
        return jnp.where(t < lower, True,
                         jnp.where(t >= upper, False,
                                   t < 0.5 * (lower + upper)))

    def solve(self, op, u: Array,
              decide: Callable[[Array, Array], Array] | None = None, *,
              lam_min=None, lam_max=None, probe=None) -> SolveResult:
        """Retrospective solve for ``u^T A^-1 u``: iterate quadrature until
        ``decide(lower, upper)`` is True on every lane (or exhaustion).

        ``decide`` gets the current scaled bracket and must return a bool
        array (True = this lane's decision is resolved).  With
        ``decide=None`` the driver brackets to the configured
        ``rtol``/``atol`` tolerance (legacy ``bif_bounds`` behavior).

        Sugar for ``finalize(resume(init_state(...), decide), decide)``;
        callers that need to pause/checkpoint/resume use the runtime
        methods directly (``SolveResult.state`` is the final QuadState).
        """
        state = self.init_state(op, u, lam_min=lam_min, lam_max=lam_max,
                                probe=probe)
        state = self.resume(state, decide)
        return self.finalize(state, decide)

    def trace(self, op, u: Array, num_iters: int, *, lam_min=None,
              lam_max=None, probe=None,
              convergence_log=None) -> QuadratureTrace:
        """Run exactly ``num_iters`` iterations, recording all four estimate
        sequences (paper Fig. 1).  Honors spectrum/precondition/backend and
        ``reorth`` from the config.

        With ``config.fn != 'inv'`` the fields are oriented per the
        matfun sign table: ``radau_lower``/``radau_upper`` are the tight
        oriented Radau bracket and ``gauss``/``lobatto`` the loose
        lower/upper (for log-like f those are the Lobatto/Gauss rules
        respectively — DESIGN.md Sec. 9).

        ``convergence_log`` (an :class:`repro.obs.health.ConvergenceLog`)
        records the returned Radau bracket per iteration — read off the
        finished trace HOST-side, bit-identical to the returned fields.
        Only legal outside a trace (see ``step_n``)."""
        if num_iters < 1:
            raise ValueError(f"num_iters must be >= 1, got {num_iters}")
        # Rows 0..num_iters of the reorth basis hold v_0..v_{num_iters}.
        state = self.init_state(op, u, lam_min=lam_min, lam_max=lam_max,
                                probe=probe, basis_rows=num_iters + 1,
                                coeff_rows=num_iters)
        stepfn = self._stepper()

        def estimates(st, coeffs):
            lo, hi, loose_lo, loose_hi = self._bracket4(
                st, coeffs, state.lam_min, state.lam_max)
            return (loose_lo, lo, hi, loose_hi)

        first = estimates(state.st, state.coeffs)
        if num_iters == 1:
            # No scan: a zero-length jnp.arange trips older jax versions and
            # buys nothing.
            tr = QuadratureTrace(*(f[None] for f in first))
            if convergence_log is not None:
                convergence_log.record_trace(tr)
            return tr

        def body(carry, _):
            st, basis, coeffs, step = carry
            st1, basis1, coeffs1 = self._advance(state.op, st, state.lam_min,
                                                 state.lam_max, basis,
                                                 coeffs, step, stepfn)
            return (st1, basis1, coeffs1, step + 1), estimates(st1, coeffs1)

        _, rest = jax.lax.scan(body, (state.st, state.basis, state.coeffs,
                                      state.step),
                               None, length=num_iters - 1)
        seqs = [jnp.concatenate([f[None], r], axis=0)
                for f, r in zip(first, rest)]
        tr = QuadratureTrace(*seqs)
        if convergence_log is not None:
            convergence_log.record_trace(tr)
        return tr

    # -- single-system judges -----------------------------------------------

    def judge_threshold(self, op, u: Array, t: Array, *, lam_min=None,
                        lam_max=None, probe=None) -> JudgeResult:
        """Alg. 4 (DPPJUDGE): True iff  t < u^T A^-1 u."""
        res = self.solve(op, u, decide=lambda lo, hi: (t < lo) | (t >= hi),
                         lam_min=lam_min, lam_max=lam_max, probe=probe)
        decision = self.threshold_decision(t, res.lower, res.upper)
        return JudgeResult(decision=decision, certified=res.certified,
                           iterations=res.iterations)

    # -- the batched driver (K candidate systems, one loop) ------------------

    def solve_batch(self, op, u: Array,
                    decide: Callable[[Array, Array], Array] | None = None, *,
                    lam_min=None, lam_max=None, probe=None) -> SolveResult:
        """Retrospective solve over K candidate systems as lockstep lanes
        of ONE driver (DESIGN.md Sec. 6).

        ``u`` is (..., K, N): one query vector per lane. ``op`` is either
        a single operator shared by every lane, a lane-batched operator
        from ``operators.stack_ops``, or a stacked-mask operator from
        ``operators.stack_masks`` (K principal submatrices of one base).
        The matvec runs once over the whole stack per iteration; lanes
        whose decision resolves are frozen bit-exactly
        (``loop_utils.tree_freeze``) while the rest continue.

        ``decide(lower, upper)`` receives the full (..., K) brackets and
        returns per-lane resolution flags — it may reduce *across* lanes
        (the argmax race in ``judge_argmax`` does). ``decide=None``
        brackets every lane to the configured rtol/atol. Per-lane results
        are identical to running ``solve`` on each lane alone.
        """
        u = jnp.asarray(u)
        min_ndim = 3 if self.config.block_size > 1 else 2
        if u.ndim < min_ndim:
            if self.config.block_size > 1:
                raise ValueError(
                    f"solve_batch with block_size={self.config.block_size} "
                    f"wants (..., K, b, N) stacked probe blocks, got shape "
                    f"{u.shape}; use solve() for a single block")
            raise ValueError(
                f"solve_batch wants (..., K, N) stacked queries, got shape "
                f"{u.shape}; use solve() for a single system")
        return self.solve(op, u, decide, lam_min=lam_min, lam_max=lam_max,
                          probe=probe)

    def judge_batch(self, op, u: Array, t: Array, *, lam_min=None,
                    lam_max=None, probe=None) -> JudgeResult:
        """K threshold judges (Alg. 4) in one batched driver:
        ``decision[k] = t[k] < u_k^T A_k^-1 u_k`` with per-lane early exit.
        ``t`` broadcasts against the (..., K) lane shape."""
        u = jnp.asarray(u)
        if u.ndim < 2:
            raise ValueError(
                f"judge_batch wants (..., K, N) stacked queries, got shape "
                f"{u.shape}; use judge_threshold() for a single system")
        return self.judge_threshold(op, u, jnp.asarray(t), lam_min=lam_min,
                                    lam_max=lam_max, probe=probe)

    def judge_argmax(self, op, u: Array, *, shift=None, scale=None,
                     valid=None, prior_upper=None, prior_lower=None,
                     lam_min=None, lam_max=None, probe=None) -> ArgmaxResult:
        """Certified argmax over K candidate scores
        ``shift_k + scale_k * u_k^T A_k^-1 u_k`` (greedy MAP's inner loop).

        Lanes race: a lane freezes as soon as it is *dominated* (its score
        upper bound is below the best lower bound — it cannot win) and the
        loop ends once the surviving lane's lower bound clears every
        rival's upper bound (or exhaustion; then the bracket midpoints
        pick, with ``certified=False``). ``valid`` (bool, (..., K))
        excludes lanes from the race (e.g. already-selected candidates).

        ``prior_upper`` (per-lane) banks externally-known valid upper
        bounds on the scores — e.g. a previous greedy round's brackets,
        still valid by Schur-complement monotonicity — so lanes a stale
        bound already rules out freeze after their very first bracket
        (lazy greedy, DESIGN.md Sec. 8.3). The certificate stays exact.

        ``prior_lower`` (per-lane) banks externally-known valid LOWER
        bounds — e.g. exact scores read off a maintained selection
        factor (core/update.py): with exact priors on both sides every
        lane resolves at its first decide check, so the whole race costs
        one iteration per lane (DESIGN.md Sec. 12).
        """
        u = jnp.asarray(u)
        if u.ndim < 2:
            raise ValueError(f"judge_argmax wants (..., K, N) stacked "
                             f"queries, got shape {u.shape}")
        shift = jnp.zeros((), u.dtype) if shift is None else \
            jnp.asarray(shift, u.dtype)
        scale = jnp.ones((), u.dtype) if scale is None else \
            jnp.asarray(scale, u.dtype)

        def scores(lo, hi):
            return _argmax_scores(lo, hi, shift, scale, valid, prior_upper,
                                  prior_lower)

        def resolved(lo, hi):
            dominated, winner = _argmax_race(*scores(lo, hi))
            return dominated | winner

        res = self.solve_batch(op, u, decide=resolved, lam_min=lam_min,
                               lam_max=lam_max, probe=probe)
        slo, shi = scores(res.lower, res.upper)
        _, winner = _argmax_race(slo, shi)
        certified = jnp.any(winner, axis=-1)
        mid = 0.5 * (slo + shi)
        index = jnp.where(certified, jnp.argmax(winner, axis=-1),
                          jnp.argmax(mid, axis=-1)).astype(jnp.int32)
        return ArgmaxResult(index=index, certified=certified,
                            iterations=res.iterations, lower=slo, upper=shi)

    # -- device-sharded batched driver (lanes over a mesh axis) --------------

    def solve_batch_sharded(self, op, u: Array, decide=None, *, mesh,
                            axis: str = "lanes", lam_min=None, lam_max=None,
                            probe=None, decide_args=()) -> SolveResult:
        """``solve_batch`` with the K lanes data-parallel over ``mesh``'s
        ``axis`` via ``shard_map`` (core/sharded.py, DESIGN.md Sec. 7).
        Per-lane results match the single-device batched path exactly."""
        from . import sharded as _sharded
        return _sharded.solve_batch_sharded(
            self, op, u, decide, mesh=mesh, axis=axis, lam_min=lam_min,
            lam_max=lam_max, probe=probe, decide_args=decide_args)

    def judge_batch_sharded(self, op, u: Array, t: Array, *, mesh,
                            axis: str = "lanes", lam_min=None, lam_max=None,
                            probe=None) -> JudgeResult:
        """``judge_batch`` over a lane mesh (DESIGN.md Sec. 7)."""
        from . import sharded as _sharded
        return _sharded.judge_batch_sharded(
            self, op, u, t, mesh=mesh, axis=axis, lam_min=lam_min,
            lam_max=lam_max, probe=probe)

    def judge_argmax_sharded(self, op, u: Array, *, mesh,
                             axis: str = "lanes", shift=None, scale=None,
                             valid=None, prior_upper=None, prior_lower=None,
                             lam_min=None, lam_max=None,
                             probe=None) -> ArgmaxResult:
        """``judge_argmax`` over a lane mesh: the race's cross-lane
        reductions become cross-device collectives (DESIGN.md Sec. 7)."""
        from . import sharded as _sharded
        return _sharded.judge_argmax_sharded(
            self, op, u, mesh=mesh, axis=axis, shift=shift, scale=scale,
            valid=valid, prior_upper=prior_upper, prior_lower=prior_lower,
            lam_min=lam_min, lam_max=lam_max, probe=probe)

    def judge_kdpp_swap_batch(self, op, u: Array, v: Array, t: Array,
                              p: Array, *, lam_min=None, lam_max=None,
                              chunk_iters: int | None = None) -> JudgeResult:
        """Alg. 7 with both systems as two lanes of the batched driver.

        The gap-weighted pair driver (``judge_kdpp_swap``) computes both
        matvecs every loop step and discards one; here the (..., 2, N)
        stack advances both sides per step in a single matvec, so the
        decision resolves in no more loop steps for the same per-step
        cost. Decisions remain certified-exact; per-side iteration counts
        differ from the pair driver's refinement schedule.

        ``chunk_iters`` runs the judge through the resumable runtime in
        fixed-size decision rounds (``resume_chunked``): each round
        carries the unresolved systems' banked :class:`QuadState` forward
        instead of re-solving — bit-exact with the monolithic drive.
        """
        if self.config.block_size > 1:
            raise NotImplementedError(
                "judge_kdpp_swap_batch stacks two scalar query systems; "
                "block_size > 1 brackets tr B^T f(A) B and has no swap-"
                "judge semantics — use block_size=1")
        uv = jnp.stack([jnp.asarray(u), jnp.asarray(v)], axis=-2)

        def bounds(lo, hi):
            return (p * lo[..., 1] - hi[..., 0],
                    p * hi[..., 1] - lo[..., 0])

        def resolved(lo, hi):
            blo, bhi = bounds(lo, hi)
            done = (t < blo) | (t >= bhi)
            return jnp.broadcast_to(done[..., None], lo.shape)

        if chunk_iters is None:
            res = self.solve_batch(op, uv, decide=resolved, lam_min=lam_min,
                                   lam_max=lam_max)
        else:
            state = self.init_state(op, uv, lam_min=lam_min,
                                    lam_max=lam_max)
            state = self.resume_chunked(state, resolved,
                                        chunk_iters=chunk_iters)
            res = self.finalize(state, resolved)
        blo, bhi = bounds(res.lower, res.upper)
        decision = self.threshold_decision(t, blo, bhi)
        return JudgeResult(decision=decision,
                           certified=(t < blo) | (t >= bhi),
                           iterations=jnp.sum(res.iterations, axis=-1,
                                              dtype=res.iterations.dtype))

    def judge_double_greedy_batch(self, op2, uv: Array, t: Array, p: Array,
                                  *, lam_min=None,
                                  lam_max=None) -> JudgeResult:
        """Alg. 9 with the X- and Y-side systems as two lanes of the
        batched driver. ``op2`` is a 2-lane stacked operator (use
        ``operators.stack_masks(base, [x_mask, y_mask])``), ``uv`` the
        (..., 2, N) stacked queries. Same decision formulas as
        ``judge_double_greedy``; one stacked matvec per loop step."""
        if self.config.block_size > 1:
            raise NotImplementedError(
                "judge_double_greedy_batch stacks two scalar query "
                "systems; block_size > 1 brackets tr B^T f(A) B and has "
                "no gain-judge semantics — use block_size=1")

        def gain_bounds(lo, hi):
            lo_p, hi_p = _log_gain_bounds(t, lo[..., 0], hi[..., 0])
            lo_log_y, hi_log_y = _log_gain_bounds(t, lo[..., 1], hi[..., 1])
            lo_m, hi_m = -hi_log_y, -lo_log_y
            relu = lambda x: jnp.maximum(x, 0.0)  # noqa: E731
            return relu(lo_p), relu(hi_p), relu(lo_m), relu(hi_m)

        def safety(lo, hi):
            lo_p, hi_p, lo_m, hi_m = gain_bounds(lo, hi)
            add_safe = p * hi_m <= (1 - p) * lo_p
            rem_safe = p * lo_m > (1 - p) * hi_p
            return add_safe, rem_safe

        def resolved(lo, hi):
            add_safe, rem_safe = safety(lo, hi)
            return jnp.broadcast_to((add_safe | rem_safe)[..., None],
                                    lo.shape)

        res = self.solve_batch(op2, uv, decide=resolved, lam_min=lam_min,
                               lam_max=lam_max)
        lo_p, hi_p, lo_m, hi_m = gain_bounds(res.lower, res.upper)
        add_safe = p * hi_m <= (1 - p) * lo_p
        rem_safe = p * lo_m > (1 - p) * hi_p
        mid = (p * 0.5 * (lo_m + hi_m)) <= ((1 - p) * 0.5 * (lo_p + hi_p))
        decision = jnp.where(add_safe, True, jnp.where(rem_safe, False, mid))
        return JudgeResult(decision=decision, certified=add_safe | rem_safe,
                           iterations=jnp.sum(res.iterations, axis=-1,
                                              dtype=res.iterations.dtype))

    # -- the pair driver (gap-weighted two-system refinement) ----------------

    def _prepare_pair(self, op_a, u, op_b, v, lam_min, lam_max):
        if self.config.block_size > 1:
            raise NotImplementedError(
                "the gap-weighted pair driver refines two scalar systems; "
                "block_size > 1 has no pair-judge semantics — use "
                "block_size=1")
        if self.config.fn != "inv":
            raise NotImplementedError(
                "the gap-weighted pair driver scores u^T A^-1 u only; "
                "matfun judges go through the batched driver "
                "(judge_kdpp_swap_batch / solve_batch with fn set)")
        if self.config.precondition != "none":
            raise NotImplementedError(
                "preconditioning is per-operator and would shift the two "
                "systems' quadrature scales differently; pair judges "
                "require precondition='none'")
        if self.config.reorth:
            raise NotImplementedError(
                "reorth is not implemented for the two-system driver; "
                "pair judges require reorth=False")
        if self.config.decide_every != 1:
            raise NotImplementedError(
                "the gap-weighted pair driver re-picks which side to "
                "refine from the bracket every iteration, so its decision "
                "rule cannot be deferred; pair judges require "
                "decide_every=1 (the batched kdpp/double-greedy judges "
                "support any cadence)")
        if lam_min is None or lam_max is None:
            _, _, lmn_a, lmx_a = self.prepare(op_a, u, lam_min, lam_max)
            _, _, lmn_b, lmx_b = self.prepare(op_b, v, lam_min, lam_max)
            lam_min = jnp.minimum(jnp.asarray(lmn_a), jnp.asarray(lmn_b))
            lam_max = jnp.maximum(jnp.asarray(lmx_a), jnp.asarray(lmx_b))
        return lam_min, lam_max

    def solve_pair(self, op_a, u: Array, op_b, v: Array, *,
                   resolved: Callable[[PairState], Array],
                   pick_a: Callable[[PairState], Array],
                   lam_min=None, lam_max=None) -> PairState:
        """Generic two-system retrospective loop (Alg. 7/9 skeleton).

        Per step, exactly one side of each lane advances: side a if
        ``pick_a(state)`` (and side a can still move), else side b — the
        gap-weighted refinement of paper Sec. 5.1.  Stops when
        ``resolved(state)`` everywhere or both sides are exhausted.

        A missing ``lam_min``/``lam_max`` is estimated per the config's
        spectrum mode on both operators (the union interval is used).
        """
        lam_min, lam_max = self._prepare_pair(op_a, u, op_b, v, lam_min,
                                              lam_max)
        max_iters = self.config.max_iters
        stepfn = self._stepper()
        cfg = self.config
        op_a = _ops.configure_backend(op_a, cfg.backend, cfg.pallas_interpret)
        op_b = _ops.configure_backend(op_b, cfg.backend, cfg.pallas_interpret)
        st0 = PairState(a=_gql.gql_init(op_a, u, lam_min, lam_max),
                        b=_gql.gql_init(op_b, v, lam_min, lam_max))

        def exhausted(st):
            return (st.a.done | (st.a.it >= max_iters)) & \
                   (st.b.done | (st.b.it >= max_iters))

        def needs_more(st):
            return ~resolved(st) & ~exhausted(st)

        def cond(st):
            return jnp.any(needs_more(st))

        def body(st):
            pick = pick_a(st)
            pick = (pick & ~st.a.done & (st.a.it < max_iters)) | \
                   (st.b.done | (st.b.it >= max_iters))
            a1 = stepfn(op_a, st.a, lam_min, lam_max, None)
            b1 = stepfn(op_b, st.b, lam_min, lam_max, None)
            nm = needs_more(st)
            return PairState(a=tree_freeze(a1, st.a, ~(nm & pick)),
                             b=tree_freeze(b1, st.b, ~(nm & ~pick)))

        return jax.lax.while_loop(cond, body, st0)

    def judge_kdpp_swap(self, op_a, u: Array, op_b, v: Array, t: Array,
                        p: Array, *, lam_min=None,
                        lam_max=None) -> JudgeResult:
        """Alg. 7 (kDPP-JudgeGauss): True iff t < p * v^T B^-1 v - u^T A^-1 u."""
        def bounds(st):
            # accept-safe requires t < p*lower_v - upper_u;
            # reject-safe requires t >= p*upper_v - lower_u.
            lo = p * _gql.lower_bound(st.b) - _gql.upper_bound(st.a)
            hi = p * _gql.upper_bound(st.b) - _gql.lower_bound(st.a)
            return lo, hi

        def resolved(st):
            lo, hi = bounds(st)
            return (t < lo) | (t >= hi)

        st = self.solve_pair(
            op_a, u, op_b, v, resolved=resolved,
            pick_a=lambda st: _gql.gap(st.a) > p * _gql.gap(st.b),
            lam_min=lam_min, lam_max=lam_max)
        lo, hi = bounds(st)
        decision = self.threshold_decision(t, lo, hi)
        return JudgeResult(decision=decision, certified=resolved(st),
                           iterations=st.a.it + st.b.it)

    def judge_double_greedy(self, op_x, u: Array, op_y, v: Array, t: Array,
                            p: Array, *, lam_min=None,
                            lam_max=None) -> JudgeResult:
        """Alg. 9 (DG-JudgeGauss): True (add element) iff

            p * [Delta^-]_+ <= (1 - p) * [Delta^+]_+

        with Delta^+ = log(t - u^T A_X^-1 u)   (gain of adding to X)
             Delta^- = -log(t - v^T A_Y'^-1 v) (gain of removing from Y)

        (Sec. 5.2 of the paper swaps the +/- formulas relative to its own
        Sec. 2 definitions; we follow Sec. 2 / Buchbinder et al., which the
        exact-baseline tests verify.)
        """
        def gain_bounds(st):
            lo_p, hi_p = _log_gain_bounds(t, _gql.lower_bound(st.a),
                                          _gql.upper_bound(st.a))
            lo_log_y, hi_log_y = _log_gain_bounds(
                t, _gql.lower_bound(st.b), _gql.upper_bound(st.b))
            # Delta^- = -log(...): bounds swap
            lo_m, hi_m = -hi_log_y, -lo_log_y
            relu = lambda x: jnp.maximum(x, 0.0)  # noqa: E731
            return relu(lo_p), relu(hi_p), relu(lo_m), relu(hi_m)

        def resolved(st):
            lo_p, hi_p, lo_m, hi_m = gain_bounds(st)
            add_safe = p * hi_m <= (1 - p) * lo_p
            rem_safe = p * lo_m > (1 - p) * hi_p
            return add_safe | rem_safe

        def pick_a(st):
            lo_p, hi_p, lo_m, hi_m = gain_bounds(st)
            # tighten the Delta^+ side if its weighted gap dominates
            return (1 - p) * (hi_p - lo_p) >= p * (hi_m - lo_m)

        st = self.solve_pair(op_x, u, op_y, v, resolved=resolved,
                             pick_a=pick_a, lam_min=lam_min, lam_max=lam_max)
        lo_p, hi_p, lo_m, hi_m = gain_bounds(st)
        add_safe = p * hi_m <= (1 - p) * lo_p
        rem_safe = p * lo_m > (1 - p) * hi_p
        mid = (p * 0.5 * (lo_m + hi_m)) <= ((1 - p) * 0.5 * (lo_p + hi_p))
        decision = jnp.where(add_safe, True, jnp.where(rem_safe, False, mid))
        return JudgeResult(decision=decision, certified=add_safe | rem_safe,
                           iterations=st.a.it + st.b.it)
