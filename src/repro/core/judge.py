"""Retrospective comparison judges (paper Alg. 2 / 4 / 7 / 9) — thin shims
over ``solver.BIFSolver``.

Each judge decides a comparison involving BIFs by iterating Gauss-Radau
quadrature only until the bracket [g^rr, g^lr] resolves it — the consumer
then makes *exactly* the decision it would have made with the exact value
(the bracket always contains the truth, Thm. 2 + Cor. 7).

All judges are batched (leading dims) and jit/vmap-safe. ``max_iters``
bounds work; if a lane is still undecided at exhaustion (bracket width at
machine precision), we fall back to the bracket midpoint — with
``max_iters >= N`` this never triggers in exact arithmetic (Lemma 15).

.. deprecated:: the module-level functions are kept for API stability; new
   code should call the identically-named ``BIFSolver`` methods, which add
   spectrum estimation and backend selection through the shared config.
"""
from __future__ import annotations

import jax

from . import solver as _solver
from .deprecation import warn_once as _warn_once

Array = jax.Array

# Re-exported result type (defined next to the driver it comes from).
JudgeResult = _solver.JudgeResult


def judge_threshold(op, u: Array, t: Array, lam_min, lam_max, *,
                    max_iters: int) -> JudgeResult:
    """Alg. 4 (DPPJUDGE): True iff  t < u^T A^-1 u."""
    _warn_once("judge.judge_threshold", "BIFSolver.judge_threshold")
    return _solver.BIFSolver.create(max_iters=max_iters).judge_threshold(
        op, u, t, lam_min=lam_min, lam_max=lam_max)


def judge_kdpp_swap(op_a, u: Array, op_b, v: Array, t: Array, p: Array,
                    lam_min, lam_max, *, max_iters: int) -> JudgeResult:
    """Alg. 7 (kDPP-JudgeGauss): True iff  t < p * v^T B^-1 v - u^T A^-1 u.

    Gap-weighted refinement (paper Sec. 5.1 'Refinements'): per loop step
    tighten the side whose weighted gap dominates — u-side if
    d_u > p * d_v, else v-side.
    """
    _warn_once("judge.judge_kdpp_swap", "BIFSolver.judge_kdpp_swap")
    return _solver.BIFSolver.create(max_iters=max_iters).judge_kdpp_swap(
        op_a, u, op_b, v, t, p, lam_min=lam_min, lam_max=lam_max)


def judge_double_greedy(op_x, u: Array, op_y, v: Array, t: Array, p: Array,
                        lam_min, lam_max, *, max_iters: int) -> JudgeResult:
    """Alg. 9 (DG-JudgeGauss): True (add element) iff

        p * [Delta^-]_+ <= (1 - p) * [Delta^+]_+

    See ``BIFSolver.judge_double_greedy`` for the formula notes.
    """
    _warn_once("judge.judge_double_greedy", "BIFSolver.judge_double_greedy")
    return _solver.BIFSolver.create(max_iters=max_iters).judge_double_greedy(
        op_x, u, op_y, v, t, p, lam_min=lam_min, lam_max=lam_max)
