"""Retrospective comparison judges (paper Alg. 2 / 4 / 7 / 9).

Each judge decides a comparison involving BIFs by iterating Gauss-Radau
quadrature only until the bracket [g^rr, g^lr] resolves it — the consumer
then makes *exactly* the decision it would have made with the exact value
(the bracket always contains the truth, Thm. 2 + Cor. 7).

All judges are batched (leading dims) and jit/vmap-safe. ``max_iters``
bounds work; if a lane is still undecided at exhaustion (bracket width at
machine precision), we fall back to the bracket midpoint — with
``max_iters >= N`` this never triggers in exact arithmetic (Lemma 15).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import gql as _gql

Array = jax.Array


class JudgeResult(NamedTuple):
    decision: Array     # bool
    certified: Array    # bool — True if resolved by bounds (not fallback)
    iterations: Array   # int32 total quadrature iterations spent


def _freeze(st_new, st_old, frozen):
    return jax.tree.map(
        lambda new, old: jnp.where(
            jnp.reshape(frozen, frozen.shape + (1,) * (new.ndim - frozen.ndim)),
            old, new),
        st_new, st_old)


def judge_threshold(op, u: Array, t: Array, lam_min, lam_max, *,
                    max_iters: int) -> JudgeResult:
    """Alg. 4 (DPPJUDGE): True iff  t < u^T A^-1 u."""
    st = _gql.gql_init(op, u, lam_min, lam_max)

    def resolved(st):
        return (t < _gql.lower_bound(st)) | (t >= _gql.upper_bound(st))

    def needs_more(st):
        return ~st.done & ~resolved(st) & (st.it < max_iters)

    def cond(st):
        return jnp.any(needs_more(st))

    def body(st):
        st1 = _gql.gql_step(op, st, lam_min, lam_max)
        return _freeze(st1, st, ~needs_more(st))

    st = jax.lax.while_loop(cond, body, st)
    lo, hi = _gql.lower_bound(st), _gql.upper_bound(st)
    decision = jnp.where(t < lo, True,
                         jnp.where(t >= hi, False, t < 0.5 * (lo + hi)))
    return JudgeResult(decision=decision, certified=resolved(st),
                       iterations=st.it)


class _PairState(NamedTuple):
    a: Any  # GQLState for the u-side
    b: Any  # GQLState for the v-side


def judge_kdpp_swap(op_a, u: Array, op_b, v: Array, t: Array, p: Array,
                    lam_min, lam_max, *, max_iters: int) -> JudgeResult:
    """Alg. 7 (kDPP-JudgeGauss): True iff  t < p * v^T B^-1 v - u^T A^-1 u.

    Gap-weighted refinement (paper Sec. 5.1 'Refinements'): per loop step
    tighten the side whose weighted gap dominates — u-side if
    d_u > p * d_v, else v-side.
    """
    st = _PairState(a=_gql.gql_init(op_a, u, lam_min, lam_max),
                    b=_gql.gql_init(op_b, v, lam_min, lam_max))

    def bounds(st):
        # accept-safe requires t < p*lower_v - upper_u;
        # reject-safe requires t >= p*upper_v - lower_u.
        lo = p * _gql.lower_bound(st.b) - _gql.upper_bound(st.a)
        hi = p * _gql.upper_bound(st.b) - _gql.lower_bound(st.a)
        return lo, hi

    def resolved(st):
        lo, hi = bounds(st)
        return (t < lo) | (t >= hi)

    def exhausted(st):
        return (st.a.done | (st.a.it >= max_iters)) & \
               (st.b.done | (st.b.it >= max_iters))

    def needs_more(st):
        return ~resolved(st) & ~exhausted(st)

    def cond(st):
        return jnp.any(needs_more(st))

    def body(st):
        d_u = _gql.gap(st.a)
        d_v = _gql.gap(st.b)
        pick_u = (d_u > p * d_v) & ~st.a.done & (st.a.it < max_iters)
        pick_u = pick_u | (st.b.done | (st.b.it >= max_iters))
        a1 = _gql.gql_step(op_a, st.a, lam_min, lam_max)
        b1 = _gql.gql_step(op_b, st.b, lam_min, lam_max)
        nm = needs_more(st)
        a2 = _freeze(a1, st.a, ~(nm & pick_u))
        b2 = _freeze(b1, st.b, ~(nm & ~pick_u))
        return _PairState(a=a2, b=b2)

    st = jax.lax.while_loop(cond, body, st)
    lo, hi = bounds(st)
    decision = jnp.where(t < lo, True,
                         jnp.where(t >= hi, False, t < 0.5 * (lo + hi)))
    return JudgeResult(decision=decision, certified=resolved(st),
                       iterations=st.a.it + st.b.it)


def _log_gain_bounds(t: Array, lo_bif: Array, hi_bif: Array):
    """Bounds on log(t - bif) given bif in [lo_bif, hi_bif]; the true Schur
    complement t - bif is positive, but a loose *upper* BIF bound can push
    t - hi_bif <= 0, in which case the log lower bound is -inf."""
    big_neg = jnp.asarray(-1e30, lo_bif.dtype)
    arg_hi = t - lo_bif
    arg_lo = t - hi_bif
    hi = jnp.where(arg_hi > 0, jnp.log(jnp.maximum(arg_hi, 1e-30)), big_neg)
    lo = jnp.where(arg_lo > 0, jnp.log(jnp.maximum(arg_lo, 1e-30)), big_neg)
    return lo, hi


def judge_double_greedy(op_x, u: Array, op_y, v: Array, t: Array, p: Array,
                        lam_min, lam_max, *, max_iters: int) -> JudgeResult:
    """Alg. 9 (DG-JudgeGauss): True (add element) iff

        p * [Delta^-]_+ <= (1 - p) * [Delta^+]_+

    with Delta^+ = log(t - u^T A_X^-1 u)   (gain of adding to X)
         Delta^- = -log(t - v^T A_Y'^-1 v) (gain of removing from Y)

    (Sec. 5.2 of the paper swaps the +/- formulas relative to its own
    Sec. 2 definitions; we follow Sec. 2 / Buchbinder et al., which the
    exact-baseline tests verify.)
    """
    st = _PairState(a=_gql.gql_init(op_x, u, lam_min, lam_max),
                    b=_gql.gql_init(op_y, v, lam_min, lam_max))

    def gain_bounds(st):
        lo_p, hi_p = _log_gain_bounds(t, _gql.lower_bound(st.a),
                                      _gql.upper_bound(st.a))
        lo_log_y, hi_log_y = _log_gain_bounds(t, _gql.lower_bound(st.b),
                                              _gql.upper_bound(st.b))
        # Delta^- = -log(...): bounds swap
        lo_m, hi_m = -hi_log_y, -lo_log_y
        relu = lambda x: jnp.maximum(x, 0.0)
        return relu(lo_p), relu(hi_p), relu(lo_m), relu(hi_m)

    def resolved(st):
        lo_p, hi_p, lo_m, hi_m = gain_bounds(st)
        add_safe = p * hi_m <= (1 - p) * lo_p
        rem_safe = p * lo_m > (1 - p) * hi_p
        return add_safe | rem_safe

    def exhausted(st):
        return (st.a.done | (st.a.it >= max_iters)) & \
               (st.b.done | (st.b.it >= max_iters))

    def needs_more(st):
        return ~resolved(st) & ~exhausted(st)

    def cond(st):
        return jnp.any(needs_more(st))

    def body(st):
        lo_p, hi_p, lo_m, hi_m = gain_bounds(st)
        # tighten Delta^+ side if its weighted gap dominates
        pick_x = ((1 - p) * (hi_p - lo_p) >= p * (hi_m - lo_m))
        pick_x = (pick_x & ~st.a.done & (st.a.it < max_iters)) | \
                 (st.b.done | (st.b.it >= max_iters))
        a1 = _gql.gql_step(op_x, st.a, lam_min, lam_max)
        b1 = _gql.gql_step(op_y, st.b, lam_min, lam_max)
        nm = needs_more(st)
        a2 = _freeze(a1, st.a, ~(nm & pick_x))
        b2 = _freeze(b1, st.b, ~(nm & ~pick_x))
        return _PairState(a=a2, b=b2)

    st = jax.lax.while_loop(cond, body, st)
    lo_p, hi_p, lo_m, hi_m = gain_bounds(st)
    add_safe = p * hi_m <= (1 - p) * lo_p
    rem_safe = p * lo_m > (1 - p) * hi_p
    mid = (p * 0.5 * (lo_m + hi_m)) <= ((1 - p) * 0.5 * (lo_p + hi_p))
    decision = jnp.where(add_safe, True, jnp.where(rem_safe, False, mid))
    return JudgeResult(decision=decision, certified=add_safe | rem_safe,
                       iterations=st.a.it + st.b.it)
