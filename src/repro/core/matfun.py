"""Matrix-function quadrature: ``u^T f(A) u`` brackets beyond f(x)=1/x.

The GQL recurrence (core/gql.py) hardcodes the Sherman-Morrison pivot
updates that evaluate ``e_1^T J_i^{-1} e_1`` in O(1) per iteration — a
specialization to the paper's f(x) = 1/x. But the machinery around it
(Lanczos -> Jacobi matrix -> Gauss/Radau/Lobatto rules with
retrospective, monotonically tightening brackets) applies to ANY
spectral function whose derivatives have constant sign on the spectral
interval (Golub & Meurant; Zimmerling-Druskin-Simoncini 2024 for the
block/phi(A) setting). This module supplies that generalization:

  * a REGISTRY of spectral functions (inv, log, invsqrt, sqrt) carrying
    the derivative-sign data that decides which of the four quadrature
    rules bounds ``u^T f(A) u`` from above vs below;
  * :class:`CoeffHistory` — the alpha/beta coefficient history of the
    Lanczos tridiagonalization, threaded through the resumable
    :class:`~repro.core.solver.QuadState` (the scalar pivot recurrences
    alone cannot reconstruct J_i for a general f);
  * :func:`estimates` / :func:`bracket` — all four quadrature estimates
    at iteration i, by materializing the iteration-i Jacobi tridiagonal
    (plus its Radau/Lobatto extensions, Golub 1973) and taking
    ``e_1^T f(J) e_1`` via a fixed-size symmetric eigensolve, then
    orienting the bracket per the sign table.

Derivative-sign -> bracket-orientation table (on (0, inf); verified
against dense-eigendecomposition oracles in tests/test_matfun.py):

  quadrature-rule error sign   = s_even  (Gauss)      [I - Q = f^(2i)(x) * (+)]
                               = s_odd   (Radau-left)  [weight (x - a) >= 0]
                               = -s_odd  (Radau-right) [weight (x - b) <= 0]
                               = -s_even (Lobatto)     [weight (x-a)(x-b) <= 0]

  f        s_even  s_odd   lower family          upper family
  inv       +       -      Gauss, Radau-right    Radau-left, Lobatto
  invsqrt   +       -      Gauss, Radau-right    Radau-left, Lobatto
  log       -       +      Radau-left, Lobatto   Gauss, Radau-right
  sqrt      -       +      Radau-left, Lobatto   Gauss, Radau-right

(`I - Q > 0` means the rule UNDERestimates, i.e. bounds from below.)
In every case the two Radau rules form the tight bracket (degree of
exactness 2i vs 2i-1 for Gauss/Lobatto at the same Lanczos depth);
``bracket`` returns them as (lower, upper) and the Gauss/Lobatto pair
as the loose (lower, upper). All four f here have constant-sign
derivatives on (0, inf), so every bracket is a GUARANTEED bound (up to
finite-precision Lanczos; reorthogonalize for sharp containment, the
same caveat as f=1/x — tests/test_convergence.py). A registry entry
with ``guaranteed=False`` would mark an f whose derivatives change
sign on the interval: the four estimates still converge to the true
value but the lower/upper labels become estimates-only.

The per-lane ``fnidx`` array (rather than a static tag) lets ONE
batched drive mix spectral functions across lanes — the serving
engine's mixed-fn request pools ride on exactly this: the eigensolve
is fn-independent, so mixed lanes share it and only the cheap
``f(theta)`` contraction differs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import gql as _gql

Array = jax.Array

_EPS = 1e-30


def _safe_inv(x):
    return 1.0 / jnp.maximum(x, _EPS)


def _safe_log(x):
    return jnp.log(jnp.maximum(x, _EPS))


def _safe_invsqrt(x):
    return jax.lax.rsqrt(jnp.maximum(x, _EPS))


def _safe_sqrt(x):
    return jnp.sqrt(jnp.maximum(x, 0.0))


@dataclasses.dataclass(frozen=True)
class SpectralFn:
    """One registry entry: how to evaluate f on Ritz values and which
    way each quadrature rule bounds (the derivative-sign table above).

    ``s_even``/``s_odd``: sign of the even/odd derivatives of f on
    (0, inf). ``guaranteed``: constant-sign derivatives hold, so the
    four rules are true bounds (not just estimates). ``apply`` clamps
    its argument away from 0 so post-breakdown / padding eigenvalues
    never produce non-finite values (dead lanes are collapsed onto the
    exact Gauss value before these can matter).
    """
    name: str
    index: int
    s_even: int
    s_odd: int
    apply: Callable[[Array], Array]
    guaranteed: bool = True

    @property
    def gauss_is_lower(self) -> bool:
        return self.s_even > 0


REGISTRY: dict[str, SpectralFn] = {
    "inv": SpectralFn("inv", 0, +1, -1, _safe_inv),
    "log": SpectralFn("log", 1, -1, +1, _safe_log),
    "invsqrt": SpectralFn("invsqrt", 2, +1, -1, _safe_invsqrt),
    "sqrt": SpectralFn("sqrt", 3, -1, +1, _safe_sqrt),
}

_FNS = tuple(REGISTRY.values())
# static orientation table, indexed by fnidx
_GAUSS_IS_LOWER = tuple(f.gauss_is_lower for f in _FNS)


def fn_index(fn: str) -> int:
    if fn not in REGISTRY:
        raise ValueError(f"fn must be one of {tuple(REGISTRY)}, got {fn!r}")
    return REGISTRY[fn].index


def fn_name(index: int) -> str:
    return _FNS[int(index)].name


@dataclasses.dataclass(frozen=True)
class CoeffHistory:
    """Per-lane Lanczos coefficient history riding the QuadState.

    ``alphas``/``betas`` have shape (..., M): entry j holds
    alpha_{j+1}/beta_{j+1} of the lane's tridiagonalization, valid for
    j < it (the lane's iteration counter). Writes are indexed by the
    PER-LANE ``it`` (not the global step), so budget-frozen lanes that
    resume later keep a gapless history. ``fnidx`` ((..., ) int32)
    names each lane's spectral function by registry index — a data
    leaf, so it freezes, shards, and checkpoints with the lanes.
    """
    alphas: Array
    betas: Array
    fnidx: Array


jax.tree_util.register_dataclass(
    CoeffHistory, data_fields=["alphas", "betas", "fnidx"], meta_fields=[])

# CoeffHistory threading contract (quadlint QL001): fields the per-step
# writer deliberately never rewrites. `fnidx` names each lane's spectral
# function — set at init/admission, constant across steps; update_coeffs
# only records the new (alpha, beta) row.
COEFF_REPLACE_EXCLUDED = ("fnidx",)


def init_coeffs(st0, fn: str | Array, rows: int) -> CoeffHistory:
    """Coefficient storage for a fresh drive: capacity ``rows``
    iterations, row 0 = iteration 1 (``gql_init``'s alpha_1/beta_1).
    ``fn`` is a registry name (all lanes) or a per-lane index array."""
    dtype = st0.g.dtype
    shape = st0.it.shape
    al = jnp.zeros(shape + (rows,), dtype).at[..., 0].set(st0.lz.alpha)
    be = jnp.zeros(shape + (rows,), dtype).at[..., 0].set(st0.lz.beta)
    if isinstance(fn, str):
        fnidx = jnp.full(shape, fn_index(fn), jnp.int32)
    else:
        fnidx = jnp.broadcast_to(jnp.asarray(fn, jnp.int32), shape)
    return CoeffHistory(alphas=al, betas=be, fnidx=fnidx)


def update_coeffs(coeffs: CoeffHistory, st_prev, st_new) -> CoeffHistory:
    """Record the new iteration's (alpha, beta) at each advancing lane's
    own write cursor (its pre-step ``it``); finished lanes don't write.
    The caller's ``tree_freeze`` still applies on top, exactly like the
    reorth basis."""
    m = coeffs.alphas.shape[-1]
    it = st_prev.it
    hit = (jnp.arange(m, dtype=it.dtype) == it[..., None]) \
        & (~st_prev.done)[..., None]
    return dataclasses.replace(
        coeffs,
        alphas=jnp.where(hit, st_new.lz.alpha[..., None], coeffs.alphas),
        betas=jnp.where(hit, st_new.lz.beta[..., None], coeffs.betas))


def _extension_scalars(st, lam_min, lam_max):
    """Modified last-row entries of the Radau/Lobatto extensions of J_i,
    from the running pivot recurrences the GQL state already carries —
    the SAME ``gql.extension_coefficients`` the Sherman-Morrison
    recurrence uses, so the two routes cannot drift."""
    alpha_lr, alpha_rr, alpha_lo, b2_lo, _ = _gql.extension_coefficients(
        st.lz.beta, st.delta_lr, st.delta_rr, lam_min, lam_max)
    return alpha_lr, alpha_rr, alpha_lo, jnp.sqrt(jnp.maximum(b2_lo, 0.0)), \
        st.lz.beta


def estimates(coeffs: CoeffHistory, st, lam_min, lam_max) -> Array:
    """All four unit-normalized quadrature estimates of
    ``e_1^T f(J) e_1`` at the current iteration, stacked on a trailing
    axis in the order (gauss, radau_left, radau_right, lobatto).

    Materializes the iteration-i Jacobi tridiagonal J_i and its three
    one-row extensions inside ONE fixed-size (M+1, M+1) buffer — rows
    past the active block are decoupled (off-diagonal zero, diagonal 1),
    so they contribute eigenpairs with zero weight — and diagonalizes
    the stacked (..., 4, M+1, M+1) batch in one ``eigh``. The estimate
    is then sum_j w_j f(theta_j) with w_j the squared first components,
    with every registered f evaluated on the shared Ritz values and the
    per-lane ``fnidx`` selecting among them (this is what lets one
    batched drive mix spectral functions across lanes).

    Exhausted lanes (Krylov breakdown — the measure is fully resolved,
    Lemma 15) collapse all four estimates onto the exact Gauss value.
    """
    al, be = coeffs.alphas, coeffs.betas
    dtype = al.dtype
    m = al.shape[-1]
    m1 = m + 1
    it = st.it
    lam_min = jnp.asarray(lam_min, dtype)
    lam_max = jnp.asarray(lam_max, dtype)

    j1 = jnp.arange(m1, dtype=it.dtype)
    jm = jnp.arange(m, dtype=it.dtype)
    # active history, embedded in the fixed buffer with a decoupled
    # identity tail (zero off-diagonal => block-diagonal => the tail's
    # eigenvectors carry zero first component and drop out of e_1^T...)
    diag_base = jnp.where(j1 < it[..., None],
                          jnp.concatenate(
                              [al, jnp.ones(al.shape[:-1] + (1,), dtype)],
                              axis=-1),
                          jnp.asarray(1.0, dtype))
    off_gauss = jnp.where(jm < (it - 1)[..., None], be, 0.0)
    off_ext = jnp.where(jm < it[..., None], be, 0.0)  # + beta_i at row i

    a_lr, a_rr, a_lo, b_lo, _ = _extension_scalars(st, lam_min, lam_max)
    at_ext = j1 == it[..., None]           # the appended extension row
    at_blo = jm == (it - 1)[..., None]     # its off-diagonal slot

    def ext_diag(alpha_hat):
        return jnp.where(at_ext, alpha_hat[..., None], diag_base)

    diags = jnp.stack([diag_base, ext_diag(a_lr), ext_diag(a_rr),
                       ext_diag(a_lo)], axis=-2)            # (..., 4, m1)
    offs = jnp.stack([off_gauss, off_ext, off_ext,
                      jnp.where(at_blo, b_lo[..., None], off_gauss)],
                     axis=-2)                               # (..., 4, m)

    eye = jnp.eye(m1, dtype=dtype)
    up = jnp.eye(m1, k=1, dtype=dtype)
    op = jnp.concatenate([offs, jnp.zeros(offs.shape[:-1] + (1,), dtype)],
                         axis=-1)
    t = (diags[..., :, None] * eye
         + op[..., :, None] * up
         + op[..., None, :] * up.T)
    theta, vecs = jnp.linalg.eigh(t)
    weights = vecs[..., 0, :] ** 2                          # (..., 4, m1)

    # every registered f on the shared Ritz values; per-lane select
    est = jnp.sum(weights * _FNS[0].apply(theta), axis=-1)  # (..., 4)
    for f in _FNS[1:]:
        est = jnp.where((coeffs.fnidx == f.index)[..., None],
                        jnp.sum(weights * f.apply(theta), axis=-1), est)

    # breakdown => the Gauss estimate is exact; collapse the bracket
    return jnp.where(st.done[..., None], est[..., :1], est)


def bracket(coeffs: CoeffHistory, st, lam_min, lam_max):
    """Sign-aware oriented views of :func:`estimates`, scaled by
    ``||u||^2``: ``(lower, upper, loose_lower, loose_upper)`` with
    (lower, upper) the tight Radau bracket and (loose_lower,
    loose_upper) the Gauss/Lobatto pair, each oriented per the
    registry's derivative-sign table."""
    est = estimates(coeffs, st, lam_min, lam_max)
    scale = st.u_norm_sq[..., None]
    est = jnp.where(scale > 0.0, est * scale, 0.0)
    g, rl, rr, lo = (est[..., 0], est[..., 1], est[..., 2], est[..., 3])
    gauss_lower = jnp.asarray(_GAUSS_IS_LOWER)[coeffs.fnidx]
    lower = jnp.where(gauss_lower, rr, rl)
    upper = jnp.where(gauss_lower, rl, rr)
    loose_lower = jnp.where(gauss_lower, g, lo)
    loose_upper = jnp.where(gauss_lower, lo, g)
    return lower, upper, loose_lower, loose_upper


def log_gain_bounds(t: Array, lo_bif: Array, hi_bif: Array):
    """Bounds on ``log(t - bif)`` given ``bif in [lo_bif, hi_bif]`` —
    the log-gain scorer of the greedy / double-greedy judges, routed
    through the registry's ``log`` entry so the bound orientation lives
    in one place: x -> log(t - x) is DECREASING in x, so the log upper
    bound comes from the BIF lower bound and vice versa. The true Schur
    complement t - bif is positive, but a loose BIF *upper* bound can
    push t - hi_bif <= 0, in which case the log lower bound is -inf
    (the -1e30 sentinel)."""
    log = REGISTRY["log"].apply
    big_neg = jnp.asarray(-1e30, lo_bif.dtype)
    arg_hi = t - lo_bif
    arg_lo = t - hi_bif
    hi = jnp.where(arg_hi > 0, log(arg_hi), big_neg)
    lo = jnp.where(arg_lo > 0, log(arg_lo), big_neg)
    return lo, hi
