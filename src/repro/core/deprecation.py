"""One-shot DeprecationWarnings for the legacy shim entry points.

The module-level shims (``bounds.bif_bounds``, ``judge.judge_*``,
``precond.preconditioned_bif_bounds``) stay for API stability but warn
exactly once per process so migration pressure exists without log spam.
Internal code must call ``BIFSolver`` directly and never trips these.
"""
from __future__ import annotations

import warnings

_WARNED: set[str] = set()


def warn_once(name: str, replacement: str) -> None:
    """Emit a DeprecationWarning for ``name``, at most once per process."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"repro.core.{name} is deprecated; use {replacement} instead",
        DeprecationWarning, stacklevel=3)


def reset() -> None:
    """Forget which shims have warned (test hook)."""
    _WARNED.clear()
