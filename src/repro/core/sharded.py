"""Device-sharded batched quadrature: lanes over the mesh (DESIGN.md Sec. 7).

The K candidate systems of the batched Alg.-2 driver (solver.py,
``solve_batch``) are embarrassingly parallel in everything but the
decision rule: each lane's Lanczos recurrence touches only its own query
vector, so the per-iteration (K, N) stacked matvec splits cleanly into
(K/D, N) shards, one per device of a 1-D ``lanes`` mesh
(``launch.mesh.make_lane_mesh``). This module runs exactly that split
via ``shard_map``:

  * stacked queries / masks / thresholds are sharded on their leading
    lane axis (the ``lanes`` logical axis of ``sharding.api.lane_plan``;
    ``operators.lane_specs`` derives the per-leaf specs, with shared
    operator leaves — the base matrix — replicated on every device);
  * the ONE retrospective loop runs per device on its lane shard, with
    lanes frozen bit-exactly as they resolve, just like the
    single-device driver;
  * the loop is *round-cadenced* (``SolverConfig.decide_every = R``,
    DESIGN.md Sec. 11): each ``lax.while_loop`` trip runs R shard-local
    steps (zero collectives — within-round freezing uses only per-lane
    local conditions) and then evaluates the decision rule once, at the
    round boundary;
  * the round boundary pays exactly ONE collective: the per-lane
    brackets and the lane's local can-continue flag travel together in a
    single packed ``all_gather`` (``_round_gather``). Every device then
    computes the SAME global resolution flags — cross-lane rules like
    the ``judge_argmax`` race see every lane — AND the same global
    continue flag from the gathered data, so while_loop trip counts stay
    lockstep with no separate ``psum``: a pool whose lanes all resolved
    exits after one last gather instead of paying a collective pair per
    iteration.

K that does not divide the device count is padded with zero-query lanes,
which ``gql_init`` marks done at iteration one (the same dummy-lane rule
the serving engine uses); padded results are sliced off before returning.

Per-lane outcomes (decisions, iteration counts, certification) are
exactly those of the single-device ``solve_batch``; bracket floats are
bit-exact for ``SparseCOO`` and agree to ~1e-12 on gemm-backed operators
(XLA reduces gemms of different shapes in different orders — the same
caveat as batched-vs-single-lane, DESIGN.md Sec. 6.1).

Everything here runs on CPU under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for local testing
(tests/test_sharded.py) — the mesh does not care that the devices are
virtual.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from . import block as _block
from . import gql as _gql
from . import matfun as _matfun
from . import operators as _ops
from .solver import ArgmaxResult, BIFSolver, JudgeResult, QuadState, \
    SolveResult, _argmax_race, _argmax_scores

Array = jax.Array

# it_cap sentinel when no per-lane budget applies: `st.it < cap` is then
# always True and the needs_more rule reduces to the unbudgeted one.
_NO_CAP = jnp.iinfo(jnp.int32).max

# QuadState threading contract (quadlint QL001): per-lane fields the
# sharded driver does NOT thread. `basis` (reorthogonalization storage)
# is rejected up front by _check_state — reorth is unsupported sharded —
# so _drive_sharded legitimately never carries or freezes it.
SHARDED_STATE_EXCLUDED = ("basis",)


def _pad_lane_arg(a, k: int, kp: int):
    """Zero-pad the leading lane dim of a (K, ...) decide argument to Kp;
    scalars and non-lane arrays pass through untouched."""
    a = jnp.asarray(a)
    if kp == k or a.ndim == 0 or a.shape[0] != k:
        return a
    return jnp.pad(a, [(0, kp - k)] + [(0, 0)] * (a.ndim - 1))


def _pad_lane_lam(lam, k: int, kp: int):
    """Pad a per-lane spectrum bound to the padded lane count with ones
    (a harmless positive interval for the done-at-init dummy lanes);
    scalar bounds pass through untouched."""
    lam = jnp.asarray(lam)
    if kp == k or lam.ndim == 0:
        return lam
    return jnp.pad(lam, (0, kp - k), constant_values=1.0)


def _pad_lane_op(op, k: int, kp: int, axis: str):
    """Zero-pad the lane axis of every lane-stacked operator leaf (stacked
    masks / stacked-op pytrees) to the padded lane count. Zeros keep the
    dead lanes' matvecs finite (A_pad @ x = 0), which is all the
    done-at-init padding lanes need."""
    if kp == k:
        return op
    specs = _ops.lane_specs(op, axis)

    def pad(leaf, spec):
        if len(spec) and spec[0] == axis:
            return jnp.pad(leaf,
                           [(0, kp - k)] + [(0, 0)] * (leaf.ndim - 1))
        return leaf

    return jax.tree.map(pad, op, specs)


# ---------------------------------------------------------------------------
# The resumable sharded runtime (DESIGN.md Sec. 8): the QuadState of
# core/solver.py with its per-lane leaves sharded over the mesh.
# init_state_sharded / step_n_sharded / resume_sharded / finalize_sharded
# mirror the single-device stepping API; solve_batch_sharded (and every
# judge on top of it) is rebuilt on them.


def _lam_specs(lam_min, lam_max, axis: str):
    """Per-lane spectrum bounds (estimating modes return (K,) arrays from
    prepare()) shard with the lanes; scalar bounds replicate."""
    return tuple(P(axis) if jnp.ndim(lam) else P()
                 for lam in (lam_min, lam_max))


def _check_state(solver: BIFSolver, state: QuadState, what: str):
    if solver.config.reorth or state.basis is not None:
        raise NotImplementedError(
            f"reorth is not implemented for the sharded driver; "
            f"{what} requires reorth=False")
    if state.st.it.ndim != 1:
        raise ValueError(
            f"{what} wants a (K,)-lane state, got lane shape "
            f"{state.st.it.shape}")


def init_state_sharded(solver: BIFSolver, op, u: Array, *, mesh,
                       axis: str = "lanes", lam_min=None, lam_max=None,
                       probe=None) -> QuadState:
    """Prepare + iteration 1 with the K lanes sharded over ``mesh``.

    Spectrum estimation / preconditioning run globally before sharding
    (so resolved intervals match the single-device path bit-for-bit);
    ``gql_init`` then runs per-device on each lane shard, exactly like
    the drive's steps. K that does not divide the device count pads with
    zero-query done-at-init lanes (Sec. 7.3); the returned state is the
    PADDED (K',) state — ``finalize_sharded(..., nlanes=K)`` slices back.

    With ``config.block_size = b > 1`` the queries are (K, b, N)
    row-stacked probe blocks and each lane carries a
    :class:`block.BlockState` (DESIGN.md Sec. 13) — same padding rule
    (zero blocks deflate fully at the init QR, so padding lanes are done
    at iteration one), same per-leaf lane sharding.
    """
    cfg = solver.config
    if cfg.reorth:
        raise NotImplementedError(
            "reorth is not implemented for the sharded driver; "
            "init_state_sharded requires reorth=False")
    u = jnp.asarray(u)
    if cfg.block_size > 1:
        if u.ndim != 3 or u.shape[-2] != cfg.block_size:
            raise ValueError(
                f"init_state_sharded with block_size={cfg.block_size} "
                f"wants (K, b, N) stacked probe blocks with "
                f"b={cfg.block_size}, got shape {u.shape}")
    elif u.ndim != 2:
        raise ValueError(
            f"init_state_sharded wants (K, N) stacked queries, got shape "
            f"{u.shape}")
    op, u, lam_min, lam_max = solver.prepare(op, u, lam_min, lam_max, probe)
    lam_min = jnp.asarray(lam_min)
    lam_max = jnp.asarray(lam_max)
    if cfg.block_size > 1:
        # estimating spectrum modes return per-probe bounds: union over
        # the lane's block slots (same rule as the single-device init)
        if lam_min.ndim > 1:
            lam_min = jnp.min(lam_min, axis=-1)
        if lam_max.ndim > 1:
            lam_max = jnp.max(lam_max, axis=-1)
    k = u.shape[0]
    ndev = mesh.shape[axis]
    kp = -(-k // ndev) * ndev
    if kp != k:
        u = jnp.pad(u, [(0, kp - k)] + [(0, 0)] * (u.ndim - 1))
        op = _pad_lane_op(op, k, kp, axis)
        lam_min = _pad_lane_lam(lam_min, k, kp)
        lam_max = _pad_lane_lam(lam_max, k, kp)

    if cfg.block_size > 1:
        def init_loc(op_loc, u_loc, lmn, lmx):
            return _block.block_init(op_loc, u_loc, lmn, lmx, cfg.fn,
                                     cfg.max_iters)
    else:
        def init_loc(op_loc, u_loc, lmn, lmx):
            return _gql.gql_init(op_loc, u_loc, lmn, lmx)

    fn = shard_map(
        init_loc,
        mesh=mesh,
        in_specs=(_ops.lane_specs(op, axis), P(axis))
        + _lam_specs(lam_min, lam_max, axis),
        out_specs=P(axis), check_rep=False)
    st = fn(op, u, lam_min, lam_max)
    # the coefficient history is elementwise over lanes; allocated
    # globally (like spectrum resolution) and sharded by the next drive.
    # Block states carry fn in the state itself (fnidx) — no coeffs.
    coeffs = _matfun.init_coeffs(st, cfg.fn, cfg.max_iters) \
        if cfg.fn != "inv" and cfg.block_size == 1 else None
    return QuadState(op=op, st=st, lam_min=lam_min, lam_max=lam_max,
                     basis=None, step=jnp.zeros((), jnp.int32),
                     coeffs=coeffs)


def _round_gather(x, axis: str):
    """The cadence collective: the ONE ``all_gather`` a decision round is
    allowed to pay.  Packs per-lane round-boundary scalars (brackets plus
    the folded can-continue flag) into a single tiled gather so the
    sharded drive needs no separate ``psum`` for its loop-lockstep
    continue flag — every device derives it from the gathered data.
    """
    return jax.lax.all_gather(x, axis, axis=0, tiled=True)  # quadlint: disable=QL007 -- the cadence helper itself: the single sanctioned per-round collective


def _drive_sharded(solver: BIFSolver, state: QuadState, decide,
                   decide_args, it_cap, mesh, axis: str,
                   n: int | None):
    """Advance the sharded state: ``n`` bounded steps (step_n) or to
    completion (``n=None``, resume).

    ``decide(lo, hi, *decide_args)`` sees the GLOBAL (K',) brackets and
    returns per-lane resolution flags; ``decide_args`` are replicated on
    every device, ``it_cap`` (per-lane iteration budgets) shards with
    the lanes.

    The loop is round-cadenced: each ``lax.while_loop`` trip runs
    ``R = solver.config.decide_every`` shard-local steps (collective-
    free; within-round freezing reuses the single-device local rule via
    ``BIFSolver._round_body``) and then pays exactly one collective —
    ``_round_gather`` of ``stack([lo, hi, can], -1)``.  Every device
    evaluates ``decide`` on the same gathered brackets and derives the
    same global continue flag ``any(can & ~resolved)``, so while_loop
    trip counts stay lockstep with no psum, and an all-resolved pool
    exits after one final gather instead of a collective pair per
    iteration.  ``n`` is quantised to whole rounds (``n // R``), exactly
    like the single-device ``step_n``, so sharded and single-device
    states stay round-aligned and bit-identical.
    """
    _check_state(solver, state, "the sharded stepping driver")
    cfg = solver.config
    r = cfg.decide_every
    stepfn = solver._stepper()
    kp = state.st.it.shape[0]
    kd = kp // mesh.shape[axis]
    if decide is None:
        def decide(lo, hi):  # noqa: F811 — tolerance rule, no extra args
            return solver.tolerance_resolved(lo, hi)
    rounds = None if n is None else n // r
    if rounds == 0:
        return state
    cap = jnp.full((kp,), _NO_CAP, jnp.int32) if it_cap is None \
        else jnp.broadcast_to(jnp.asarray(it_cap, jnp.int32), (kp,))

    def local_fn(op_loc, st_coeffs_loc, lmn, lmx, cap_loc, *dargs):
        st_loc, coeffs_loc = st_coeffs_loc
        idx = jax.lax.axis_index(axis)
        local_ok = solver._local_ok_fn(cap_loc)
        round_fn = solver._round_body(op_loc, lmn, lmx, stepfn, local_ok)

        def boundary(st, coeffs):
            # ONE collective per round: brackets and the local
            # can-continue flag travel together.  The gather's result
            # feeds only the next round's freeze masks — not the matvec
            # inputs — so the compiler is free to overlap it with the
            # first shard-local matvec of the following round.
            # (fn-aware brackets — the matfun eigensolve — run
            # shard-local; only the scalars travel)
            lo, hi = solver._bracket2(st, coeffs, lmn, lmx)
            can = local_ok(st, coeffs)
            packed = _round_gather(
                jnp.stack([lo, hi, can.astype(lo.dtype)], axis=-1), axis)
            res = decide(packed[..., 0], packed[..., 1], *dargs)
            nm_glob = packed[..., 2].astype(bool) & ~res
            nm = jax.lax.dynamic_slice_in_dim(nm_glob, idx * kd, kd)
            # global "any lane anywhere still needs work" — computed
            # identically on every device from the gathered flags, so
            # while_loop trip counts stay lockstep without a psum.
            return nm, jnp.any(nm_glob)

        nm0, cont0 = boundary(st_loc, coeffs_loc)

        def cond(carry):
            cont = carry[2]
            return cont if rounds is None else cont & (carry[3] < rounds)

        def body(carry):
            (st, coeffs), nm, _, taken = carry

            def run_round(sc):
                st1, _, coeffs1, _, _ = round_fn(
                    (sc[0], None, sc[1], jnp.zeros((), jnp.int32), nm))
                return st1, coeffs1

            # a device whose local lanes are ALL frozen skips its dead
            # shard-local matvecs for the round (the frozen substep is
            # the identity, so the branch is bit-exact); it still reaches
            # the boundary gather, keeping trip counts lockstep.
            st, coeffs = jax.lax.cond(jnp.any(nm), run_round,
                                      lambda sc: sc, (st, coeffs))
            nm1, cont1 = boundary(st, coeffs)
            return (st, coeffs), nm1, cont1, taken + 1

        (st, coeffs), _, _, taken = jax.lax.while_loop(
            cond, body,
            ((st_loc, coeffs_loc), nm0, cont0, jnp.zeros((), jnp.int32)))
        return st, coeffs, jnp.full((kd,), taken, jnp.int32)

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(_ops.lane_specs(state.op, axis),
                  jax.tree.map(lambda _: P(axis),
                               (state.st, state.coeffs)))
        + _lam_specs(state.lam_min, state.lam_max, axis)
        + (P(axis),) + tuple(P() for _ in decide_args),
        out_specs=P(axis), check_rep=False)
    st, coeffs, taken = fn(state.op, (state.st, state.coeffs),
                           state.lam_min, state.lam_max, cap, *decide_args)
    # basis-free states use `step` only as bookkeeping; rounds-taken is
    # replicated across devices, so its max IS the shared trip count,
    # and `step` advances by a whole round per trip — matching the
    # single-device round accounting exactly.
    return state._replace(st=st, coeffs=coeffs,
                          step=state.step + r * jnp.max(taken))


def step_n_sharded(solver: BIFSolver, state: QuadState, n: int,
                   decide=None, *, decide_args=(), it_cap=None, mesh,
                   axis: str = "lanes") -> QuadState:
    """Advance a sharded :class:`QuadState` by at most ``n`` iterations —
    the sharded twin of ``BIFSolver.step_n`` (same freezing rule, so
    resume-after-step_n is bit-exact with the uninterrupted drive).

    Like the single-device ``step_n``, ``n`` is quantised down to whole
    decision rounds: with ``decide_every = R`` this advances
    ``(n // R) * R`` iterations, a no-op when ``n < R``."""
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if n == 0:
        return state
    return _drive_sharded(solver, state, decide, decide_args, it_cap,
                          mesh, axis, n)


def resume_sharded(solver: BIFSolver, state: QuadState, decide=None, *,
                   decide_args=(), it_cap=None, mesh,
                   axis: str = "lanes") -> QuadState:
    """Run a sharded :class:`QuadState` to completion — the sharded twin
    of ``BIFSolver.resume``."""
    return _drive_sharded(solver, state, decide, decide_args, it_cap,
                          mesh, axis, None)


def finalize_sharded(solver: BIFSolver, state: QuadState, decide=None, *,
                     decide_args=(), nlanes: int | None = None
                     ) -> SolveResult:
    """Read a :class:`SolveResult` off a (partial or completed) sharded
    state, slicing padding lanes back to ``nlanes``. ``certified``
    re-evaluates ``decide`` on the full padded brackets first (cross-lane
    rules like the argmax race see every lane), then slices."""
    st = state.st
    lo, hi, loose_lo, loose_hi = solver._bracket4(
        st, state.coeffs, state.lam_min, state.lam_max)
    if decide is None:
        certified = solver.tolerance_resolved(lo, hi)
    else:
        certified = decide(lo, hi, *decide_args)
    k = lo.shape[0] if nlanes is None else nlanes
    certified = certified[:k]
    return SolveResult(
        lower=lo[:k], upper=hi[:k],
        gauss_lower=loose_lo[:k],
        lobatto_upper=loose_hi[:k],
        iterations=st.it[:k], converged=st.done[:k] | certified,
        certified=certified, state=state)


def solve_batch_sharded(solver: BIFSolver, op, u: Array, decide=None, *,
                        mesh, axis: str = "lanes", lam_min=None,
                        lam_max=None, probe=None,
                        decide_args=()) -> SolveResult:
    """``BIFSolver.solve_batch`` with the K lanes sharded over ``mesh``.

    ``u`` is (K, N) — exactly one lane axis (the sharded path does not
    take extra leading batch dims). ``decide`` receives the global (K',)
    brackets (K' = K rounded up to a device multiple; padding lanes
    carry zero queries and resolve at iteration one) plus
    ``decide_args``, each of which is zero-padded on a leading lane dim
    and replicated across devices. ``decide=None`` brackets each lane to
    the solver's rtol/atol. Spectrum estimation / preconditioning run
    globally before sharding, so resolved intervals match the
    single-device path bit-for-bit.

    Sugar for ``finalize_sharded(resume_sharded(init_state_sharded(...)))``
    — callers that pause/checkpoint/resume use the stepping API directly.
    Returns a :class:`SolveResult` over the original K lanes whose
    ``state`` is the final PADDED :class:`QuadState` (resume it with
    ``resume_sharded``; per-lane GQL leaves stay sharded on their
    devices).
    """
    u = jnp.asarray(u)
    b = solver.config.block_size
    if b > 1:
        if u.ndim != 3 or u.shape[-2] != b:
            raise ValueError(
                f"solve_batch_sharded with block_size={b} wants (K, b, N) "
                f"stacked probe blocks with b={b}, got shape {u.shape}")
    elif u.ndim != 2:
        raise ValueError(
            f"solve_batch_sharded wants (K, N) stacked queries, got shape "
            f"{u.shape}")
    k = u.shape[0]
    state = init_state_sharded(solver, op, u, mesh=mesh, axis=axis,
                               lam_min=lam_min, lam_max=lam_max,
                               probe=probe)
    kp = state.st.it.shape[0]
    args = tuple(_pad_lane_arg(a, k, kp) for a in decide_args) \
        if decide is not None else ()
    state = resume_sharded(solver, state, decide, decide_args=args,
                           mesh=mesh, axis=axis)
    return finalize_sharded(solver, state, decide, decide_args=args,
                            nlanes=k)


def judge_batch_sharded(solver: BIFSolver, op, u: Array, t: Array, *,
                        mesh, axis: str = "lanes", lam_min=None,
                        lam_max=None, probe=None) -> JudgeResult:
    """K threshold judges (Alg. 4) sharded over the lane mesh. With
    ``block_size = b > 1`` the lanes are (K, b, N) probe blocks and the
    thresholds apply to the per-lane ``tr B^T f(A) B`` brackets."""
    u = jnp.asarray(u)
    b = solver.config.block_size
    if b > 1:
        if u.ndim != 3 or u.shape[-2] != b:
            raise ValueError(
                f"judge_batch_sharded with block_size={b} wants (K, b, N) "
                f"stacked probe blocks with b={b}, got shape {u.shape}")
    elif u.ndim != 2:
        raise ValueError(
            f"judge_batch_sharded wants (K, N) stacked queries, got shape "
            f"{u.shape}")
    lane_shape = u.shape[:-2] if b > 1 else u.shape[:-1]
    ts = jnp.broadcast_to(jnp.asarray(t), lane_shape)

    def decide(lo, hi, ts):
        return (ts < lo) | (ts >= hi)

    res = solve_batch_sharded(solver, op, u, decide, mesh=mesh, axis=axis,
                              lam_min=lam_min, lam_max=lam_max, probe=probe,
                              decide_args=(ts,))
    decision = BIFSolver.threshold_decision(ts, res.lower, res.upper)
    return JudgeResult(decision=decision, certified=res.certified,
                       iterations=res.iterations)


def judge_argmax_sharded(solver: BIFSolver, op, u: Array, *, mesh,
                         axis: str = "lanes", shift=None, scale=None,
                         valid=None, prior_upper=None, prior_lower=None,
                         lam_min=None, lam_max=None,
                         probe=None) -> ArgmaxResult:
    """Certified argmax race over K sharded lanes.

    The race itself is the cross-device reduction of the tentpole: each
    iteration every device gathers ALL lane brackets, computes the same
    dominance / winner flags as the single-device race (best lower bound
    = a max over the full lane set; the winner's certificate = its lower
    bound clearing every rival's upper bound), and freezes its local
    dominated lanes. Padding lanes ride along with ``valid=False`` and
    the usual -1e30 score sentinel, so they can neither win nor keep the
    loop alive.
    """
    u = jnp.asarray(u)
    bsz = solver.config.block_size
    if bsz > 1:
        if u.ndim != 3 or u.shape[-2] != bsz:
            raise ValueError(
                f"judge_argmax_sharded with block_size={bsz} wants "
                f"(K, b, N) stacked probe blocks with b={bsz}, got shape "
                f"{u.shape}")
    elif u.ndim != 2:
        raise ValueError(f"judge_argmax_sharded wants (K, N) stacked "
                         f"queries, got shape {u.shape}")
    k = u.shape[0]
    shift = jnp.zeros((), u.dtype) if shift is None else \
        jnp.asarray(shift, u.dtype)
    scale = jnp.ones((), u.dtype) if scale is None else \
        jnp.asarray(scale, u.dtype)
    shift_k = jnp.broadcast_to(shift, (k,))
    scale_k = jnp.broadcast_to(scale, (k,))
    valid_k = jnp.ones((k,), bool) if valid is None else \
        jnp.broadcast_to(jnp.asarray(valid, bool), (k,))
    ndev = mesh.shape[axis]
    kp = -(-k // ndev) * ndev
    # padding lanes enter the race invalid: score sentinel -1e30, done at
    # iteration one — they change neither dominance nor the certificate
    valid_p = jnp.pad(valid_k, (0, kp - k)) if kp != k else valid_k
    prior_k = None if prior_upper is None else \
        jnp.broadcast_to(jnp.asarray(prior_upper, u.dtype), (k,))
    prior_lo_k = None if prior_lower is None else \
        jnp.broadcast_to(jnp.asarray(prior_lower, u.dtype), (k,))

    if prior_k is None and prior_lo_k is None:
        def decide(lo, hi, shift, scale, valid):
            dominated, winner = _argmax_race(
                *_argmax_scores(lo, hi, shift, scale, valid))
            return dominated | winner

        dargs = (shift_k, scale_k, valid_p)
    else:
        # either prior alone rides as a no-op sentinel (+/-inf clamps to
        # the lane's own bracket); padding lanes are pinned by `valid`
        # AFTER the prior clamps, so zero-padded prior args are harmless
        pu_k = jnp.full((k,), jnp.inf, u.dtype) if prior_k is None \
            else prior_k
        pl_k = jnp.full((k,), -jnp.inf, u.dtype) if prior_lo_k is None \
            else prior_lo_k

        def decide(lo, hi, shift, scale, valid, pu, pl):
            dominated, winner = _argmax_race(
                *_argmax_scores(lo, hi, shift, scale, valid, pu, pl))
            return dominated | winner

        dargs = (shift_k, scale_k, valid_p, pu_k, pl_k)

    res = solve_batch_sharded(
        solver, op, u, decide, mesh=mesh, axis=axis, lam_min=lam_min,
        lam_max=lam_max, probe=probe, decide_args=dargs)
    slo, shi = _argmax_scores(res.lower, res.upper, shift_k, scale_k,
                              valid_k, prior_k, prior_lo_k)
    _, winner = _argmax_race(slo, shi)
    certified = jnp.any(winner, axis=-1)
    mid = 0.5 * (slo + shi)
    index = jnp.where(certified, jnp.argmax(winner, axis=-1),
                      jnp.argmax(mid, axis=-1)).astype(jnp.int32)
    return ArgmaxResult(index=index, certified=certified,
                        iterations=res.iterations, lower=slo, upper=shi)


def judge_kdpp_swap_batch_sharded(solver: BIFSolver, op, u: Array,
                                  v: Array, t: Array, p: Array, *, mesh,
                                  axis: str = "lanes", lam_min=None,
                                  lam_max=None) -> JudgeResult:
    """Alg. 7 with the two systems as two sharded lanes (the remaining
    devices carry padding lanes; with D > 2 devices this trades idle
    devices for API uniformity — worth it only inside a larger sharded
    pipeline such as a mesh-resident k-DPP chain)."""
    if solver.config.block_size > 1:
        raise NotImplementedError(
            "judge_kdpp_swap_batch_sharded stacks two scalar query "
            "systems; block_size > 1 brackets tr B^T f(A) B and has no "
            "swap-judge semantics — use block_size=1")
    uv = jnp.stack([jnp.asarray(u), jnp.asarray(v)], axis=0)
    t = jnp.asarray(t)
    p = jnp.asarray(p)

    def bounds(lo, hi):
        return (p * lo[..., 1] - hi[..., 0],
                p * hi[..., 1] - lo[..., 0])

    def decide(lo, hi, t, p):
        blo, bhi = bounds(lo, hi)
        done = (t < blo) | (t >= bhi)
        return jnp.broadcast_to(done[..., None], lo.shape)

    res = solve_batch_sharded(solver, op, uv, decide, mesh=mesh, axis=axis,
                              lam_min=lam_min, lam_max=lam_max,
                              decide_args=(t, p))
    blo, bhi = bounds(res.lower, res.upper)
    decision = BIFSolver.threshold_decision(t, blo, bhi)
    return JudgeResult(decision=decision,
                       certified=(t < blo) | (t >= bhi),
                       iterations=jnp.sum(res.iterations, axis=-1,
                                          dtype=res.iterations.dtype))


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class ShardedBIFSolver:
    """A :class:`BIFSolver` bound to a lane mesh.

        mesh = make_lane_mesh()                     # all local devices
        sh = ShardedBIFSolver(BIFSolver.create(max_iters=64), mesh)
        res = sh.judge_argmax(op, us, shift=d, scale=-1.0)

    Static like the solver itself (``Mesh`` is hashable), so it crosses
    jit boundaries and can be closure-captured freely.
    """
    solver: BIFSolver
    mesh: object
    axis: str = "lanes"

    def solve_batch(self, op, u: Array, decide=None, **kw) -> SolveResult:
        return solve_batch_sharded(self.solver, op, u, decide,
                                   mesh=self.mesh, axis=self.axis, **kw)

    def judge_batch(self, op, u: Array, t: Array, **kw) -> JudgeResult:
        return judge_batch_sharded(self.solver, op, u, t, mesh=self.mesh,
                                   axis=self.axis, **kw)

    def judge_argmax(self, op, u: Array, **kw) -> ArgmaxResult:
        return judge_argmax_sharded(self.solver, op, u, mesh=self.mesh,
                                    axis=self.axis, **kw)

    def judge_kdpp_swap_batch(self, op, u: Array, v: Array, t: Array,
                              p: Array, **kw) -> JudgeResult:
        return judge_kdpp_swap_batch_sharded(
            self.solver, op, u, v, t, p, mesh=self.mesh, axis=self.axis,
            **kw)
