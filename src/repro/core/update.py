"""Incremental update/downdate of the selected set's Cholesky factor.

The chain drivers (greedy MAP, DPP/k-DPP moves) repeatedly score Schur
complements ``L_ii - L_{Y,i}^T L_Y^{-1} L_{Y,i}`` against a set Y that
changes by ONE item per round. Re-running the quadrature from scratch
pays a full Lanczos per candidate per round; this module instead
maintains the small Cholesky factor of the selected principal submatrix
``L_Y`` under single-item add/remove (the ITAL ``extend_inv`` pattern,
SNIPPETS.md), so after an O(capacity^2) carry per round every exact BIF
against Y is two triangular solves — amortized O(1) solves per round
(DESIGN.md Sec. 12).

Everything is fixed-shape and jit/scan-safe: the factor lives in a
``capacity x capacity`` buffer, slots ``0..count-1`` are occupied (in
insertion order), empty slots hold identity rows/columns (so triangular
solves pass through them as exact no-ops) and the sentinel index ``n``
(so ``jnp.take(..., fill_value=0)`` reads zeros for them).

  * ``extend``  — add item y: one triangular solve against the current
    factor plus a new pivot row (no re-factorization).
  * ``downdate`` — remove item y: the trailing block after deleting
    row/column j satisfies ``S'S'^T = S S^T + q q^T`` with
    ``q = chol[j+1:, j]`` — a rank-1 Cholesky UPDATE (numerically
    stable; no cancellation), then a fixed-shape compaction shift.
  * ``bif`` / ``gains`` — exact bilinear forms / all-candidate marginal
    gains off the factor (one multi-RHS triangular solve).

The carry contract (what may legally survive a round and why decisions
stay certified) is documented in DESIGN.md Sec. 12 and enforced by
quadlint QL001 (see ``FACTOR_REPLACE_EXCLUDED`` below and
analysis/contracts.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

Array = jax.Array

# Threading-contract registry (quadlint QL001): ChainFactor fields the
# writers (`extend` / `downdate`) deliberately never rewrite. `n` is the
# ground-set size — static metadata fixed at init_factor time (it keys
# the gather sentinel and must never change under a carry).
FACTOR_REPLACE_EXCLUDED = ("n",)

# Floor for squared pivots: a numerically singular extension (item
# already in span) gets a tiny positive pivot instead of NaN-poisoning
# the factor; the chain's certified race never selects such an item
# (its gain is ~0) so the floor is load-bearing only for garbage input.
_PIVOT_FLOOR = 1e-30


@dataclasses.dataclass(frozen=True)
class ChainFactor:
    """Fixed-capacity Cholesky factor of ``L[idx, idx]`` (see module doc).

    ``idx``  (capacity,) int32 — slot -> item; empty slots hold ``n``.
    ``chol`` (capacity, capacity) — lower Cholesky of the selected
             principal submatrix in slot order; identity on empty slots.
    ``count`` () int32 — number of occupied slots (always a prefix).
    ``ok``   () bool — False once an ``extend`` overflowed capacity
             (decisions made from an overflowed factor are uncertified;
             the chains surface this through their ``uncertified`` stat).
    ``n``    static ground-set size (gather sentinel).
    """
    idx: Array
    chol: Array
    count: Array
    ok: Array
    n: int

    @property
    def capacity(self) -> int:
        return self.chol.shape[-1]


# keyword field lists on purpose: quadlint QL001 reads them by AST to
# prove every dataclass field is registered (analysis/contracts.py)
jax.tree_util.register_dataclass(
    ChainFactor,
    data_fields=["idx", "chol", "count", "ok"],
    meta_fields=["n"])


def tree_select(pred, a: ChainFactor, b: ChainFactor) -> ChainFactor:
    """Leafwise ``where`` over two same-capacity factors (scan-safe
    branchless accept/reject: both move outcomes are computed, one is
    kept)."""
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def init_factor(n: int, capacity: int, dtype=jnp.float32) -> ChainFactor:
    """Empty factor over a ground set of ``n`` items."""
    m = int(capacity)
    return ChainFactor(idx=jnp.full((m,), n, jnp.int32),
                       chol=jnp.eye(m, dtype=dtype),
                       count=jnp.zeros((), jnp.int32),
                       ok=jnp.ones((), bool),
                       n=int(n))


def extend(f: ChainFactor, col: Array, y) -> ChainFactor:
    """Add item ``y`` to the factor: O(capacity^2), no re-factorization.

    ``col`` is the FULL (unmasked) column ``L[:, y]`` of the base matrix
    — only the entries at currently-selected items (and ``col[y]``
    itself) are read. Overflow (``count == capacity``) returns the
    factor unchanged with ``ok=False``.
    """
    m = f.capacity
    dt = f.chol.dtype
    col = col.astype(dt)
    v = jnp.take(col, f.idx, fill_value=0.0)       # L[sel, y]
    w = solve_triangular(f.chol, v, lower=True)
    l_yy = jnp.take(col, jnp.asarray(y))
    d2 = l_yy - jnp.sum(w * w)
    piv = jnp.sqrt(jnp.maximum(d2, jnp.asarray(_PIVOT_FLOOR, dt)))
    row = w.at[f.count].set(piv)           # w is 0 on empty slots
    fits = f.count < m
    new = ChainFactor(idx=f.idx.at[f.count].set(jnp.asarray(y, jnp.int32)),
                      chol=f.chol.at[f.count].set(row),
                      count=f.count + 1,
                      ok=f.ok,
                      n=f.n)
    overflowed = dataclasses.replace(f, idx=f.idx, chol=f.chol,
                                     count=f.count,
                                     ok=jnp.zeros((), bool))
    return tree_select(fits, new, overflowed)


def downdate(f: ChainFactor, y) -> ChainFactor:
    """Remove item ``y`` from the factor: O(capacity^2).

    Removing an item that is not selected is the exact identity (the
    chains rely on this: ``downdate(f, y)`` always represents
    ``Y \\ {y}`` whether or not y is in Y, so the accept/reject select
    stays branchless).
    """
    m = f.capacity
    dt = f.chol.dtype
    ar = jnp.arange(m)
    match = (f.idx == jnp.asarray(y, jnp.int32)) & (ar < f.count)
    found = jnp.any(match)
    j = jnp.argmax(match).astype(jnp.int32)

    # Deleting row/column j leaves the trailing block S = chol[j+1:, j+1:]
    # needing S'S'^T = S S^T + q q^T with q = chol[j+1:, j]: a rank-1
    # Cholesky UPDATE (stable — adds, never cancels). Empty slots
    # self-neutralize (L_pp = 1, q_p = 0 -> rotation is the identity).
    q0 = jnp.where(ar > j, f.chol[:, j], jnp.zeros((), dt))

    def body(p, carry):
        chol, q = carry
        active = p > j
        lpp = chol[p, p]
        qp = q[p]
        r = jnp.sqrt(lpp * lpp + qp * qp)
        c = r / lpp
        s = qp / lpp
        below = ar > p
        colp = jnp.where(below, (chol[:, p] + s * q) / c, chol[:, p])
        colp = colp.at[p].set(r)
        qn = jnp.where(below, c * q - s * colp, q)
        chol = jnp.where(active, chol.at[:, p].set(colp), chol)
        q = jnp.where(active, qn, q)
        return chol, q

    chol1, _ = jax.lax.fori_loop(0, m, body, (f.chol, q0))

    # Fixed-shape compaction: drop row/column j, shift the tail up/left,
    # restore identity rows/columns on the newly-empty slots.
    src = jnp.minimum(jnp.where(ar >= j, ar + 1, ar), m - 1)
    chol2 = chol1[src][:, src]
    idx2 = f.idx[src]
    cnew = f.count - 1
    occ = ar < cnew
    chol2 = jnp.where(occ[:, None] & occ[None, :], chol2,
                      jnp.eye(m, dtype=dt))
    idx2 = jnp.where(occ, idx2, jnp.asarray(f.n, jnp.int32))
    out = dataclasses.replace(f, idx=idx2, chol=chol2, count=cnew, ok=f.ok)
    return tree_select(found, out, f)


def solve_w(f: ChainFactor, u: Array) -> Array:
    """``chol^{-1} u_Y``: the half-solve whose squared norm is the BIF."""
    v = jnp.take(u.astype(f.chol.dtype), f.idx, fill_value=0.0)
    return solve_triangular(f.chol, v, lower=True)


def bif(f: ChainFactor, u: Array) -> Array:
    """Exact ``u^T L_Y^{-1} u`` for ``u`` supported on the selected set
    (only the entries of ``u`` at selected items are read)."""
    w = solve_w(f, u)
    return jnp.sum(w * w)


def gains(f: ChainFactor, diag: Array, cols: Array) -> Array:
    """Exact marginal gains ``diag_i - L[Y,i]^T L_Y^{-1} L[Y,i]`` for
    EVERY candidate i, from one (capacity, N) triangular solve.

    ``cols`` is the (N, N) stack with row i = column i of the symmetric
    base (greedy_map precomputes it once). Already-selected items get a
    ~0 gain (their column is in the span); callers mask them out.
    """
    dt = f.chol.dtype
    v = jnp.take(cols.astype(dt), f.idx, axis=0,
                 fill_value=0.0)                        # (cap, N)
    w = solve_triangular(f.chol, v, lower=True)
    return diag.astype(dt) - jnp.sum(w * w, axis=0)


def from_mask(op, mask: Array, capacity: int | None = None) -> ChainFactor:
    """Build the factor of an existing selection (chain warm start).

    ``capacity`` defaults to the ground-set size so add-heavy chains can
    never overflow; pass the known selection ceiling (e.g. k for a
    k-DPP) to shrink the carry.
    """
    n = op.n
    dt = op.diag().dtype
    f0 = init_factor(n, n if capacity is None else int(capacity), dtype=dt)
    mask = jnp.asarray(mask)

    def body(i, f):
        col = op.matvec(jax.nn.one_hot(i, n, dtype=dt))
        return tree_select(mask[i] > 0.5, extend(f, col, i), f)

    return jax.lax.fori_loop(0, n, body, f0)
