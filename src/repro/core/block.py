"""Block-Krylov quadrature: matrix-valued brackets on ``B^T f(A) B``.

The scalar machinery (gql.py / matfun.py) brackets ``u^T f(A) u`` one
vector at a time; every hot path that wants K coupled systems therefore
runs K gemv recurrences where a single gemm would do. Zimmerling,
Druskin & Simoncini (arxiv 2407.21505) extend the whole bracket story to
block Lanczos: for a starting block ``B = [u_1 .. u_b]`` the block
three-term recurrence builds a block-tridiagonal ``J_k``, and the
matrix-valued Gauss and Gauss-Radau rules

    G_k      =        R_0^T [f(J_k)]_{11}      R_0          (Gauss)
    G_k^lr   =        R_0^T [f(J_k^lr)]_{11}   R_0          (Radau @ lam_min)
    G_k^rr   =        R_0^T [f(J_k^rr)]_{11}   R_0          (Radau @ lam_max)

are Loewner-ordered PSD approximants of ``B^T f(A) B`` with the same
containment/monotonicity guarantees as the scalar rules (the
derivative-sign table of matfun.py decides which side each rule bounds,
exactly as for b = 1). Their TRACES feed the existing scalar decision
rules unchanged — ``tr B^T f(A) B`` is what the block Hutchinson
estimator wants anyway (one certificate per block of b probes).

Execution model mirrors gql.py:

  * row-convention storage: blocks live as (..., b, N) row stacks so one
    ``operators.matvec_mrhs`` call advances all b columns per iteration
    — ONE gemm instead of b gemvs on Dense/BELL backends;
  * QR-based ``B_j`` normalization by modified Gram-Schmidt with
    FIXED-SHAPE deflation: a residual column whose norm falls under the
    breakdown tolerance gets a zero basis row and a zero R diagonal
    (its projection coefficients are kept, so ``Z = Q B`` stays exact
    up to the tolerance). Dead slots self-propagate — their matvecs,
    recurrence rows and couplings are exact zeros — and contribute
    decoupled zero-eigenvalue / zero-weight pairs to ``J_k``, so the
    quadrature never sees them (clamped ``f`` keeps them finite). A
    fully deflated block is Krylov exhaustion: Gauss is exact and the
    bracket collapses onto it, the block twin of gql.py's Lemma-15 rule;
  * the Radau extensions use the block pivot recurrences
    ``D_1 = A_1 - lam I``, ``D_{j+1} = A_{j+1} - lam I - B_j D_j^-1
    B_j^T`` (the block twin of gql.py's running ``delta_lr/delta_rr``
    scalars) with eigenvalue-clamped inverses mirroring gql.py's
    ``max(d, eps)`` guards — at b = 1 the two reduce to the same
    formulas;
  * everything is lockstep-batched over leading lane dims with masked
    freezing; :class:`BlockState` rides ``QuadState.st`` exactly like
    the scalar ``GQLState`` (DESIGN.md Sec. 13).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import matfun as _matfun
from . import operators as _ops
from .lanczos import BREAKDOWN_TOL

Array = jax.Array

_EPS = 1e-30


@dataclasses.dataclass(frozen=True)
class BlockState:
    """Per-lane block-Lanczos recurrence state (DESIGN.md Sec. 13).

    Row convention: ``q``/``q_prev`` store Q_j^T — shape (..., b, N),
    slot i is the i-th block column. After iteration j the state holds
    Q_{j+1} in ``q`` (the next basis block, like ``LanczosState.v``),
    ``b_cur = B_j`` (the subdiagonal factor that produced it), the
    histories ``a_hist[..., i, :, :] = A_{i+1}`` / ``b_hist[..., i, :,
    :] = B_{i+1}`` for i < it, and the running Radau pivot blocks of
    ``J_j - lam_min I`` / ``J_j - lam_max I``. ``r0`` is the initial QR
    factor (U = Q_1 R_0) that scales the bracket matrices; ``fnidx``
    names each lane's spectral function by matfun registry index.
    ``live`` flags non-deflated slots of ``q``; ``done`` is full-block
    deflation (Krylov exhaustion), ``it`` the completed block
    iterations (each advancing b Krylov columns).
    """
    q_prev: Array     # (..., b, N)
    q: Array          # (..., b, N)
    b_cur: Array      # (..., b, b) B_it — couples block it to it+1
    a_hist: Array     # (..., M, b, b)
    b_hist: Array     # (..., M, b, b)
    delta_lr: Array   # (..., b, b) last block pivot of J_it - lam_min I
    delta_rr: Array   # (..., b, b) last block pivot of J_it - lam_max I
    r0: Array         # (..., b, b) initial QR factor of the probe block
    fnidx: Array      # (...,) int32 — matfun registry index
    live: Array       # (..., b) bool — non-deflated slots of q
    done: Array       # (...,) bool — fully deflated (exhausted)
    it: Array         # (...,) int32 — block iterations completed


jax.tree_util.register_dataclass(
    BlockState,
    data_fields=["q_prev", "q", "b_cur", "a_hist", "b_hist", "delta_lr",
                 "delta_rr", "r0", "fnidx", "live", "done", "it"],
    meta_fields=[])

# BlockState threading contract (quadlint QL001): fields the per-step
# writer deliberately never rewrites. `r0` is the initial QR factor and
# `fnidx` the lane's spectral function — both set at init, constant
# across steps; block_step only advances the recurrence fields.
BLOCK_REPLACE_EXCLUDED = ("r0", "fnidx")


def _gram(q: Array, w: Array) -> Array:
    """(..., b, N) x (..., b, N) -> (..., b, b): out[l, m] = q_l . w_m.
    Multiply-then-reduce (not dot_general) so the b = 1 slot reproduces
    the scalar recurrence's ``sum(v * w)`` bit-for-bit."""
    return jnp.sum(q[..., :, None, :] * w[..., None, :, :], axis=-1)


def _rowmat(a: Array, q: Array) -> Array:
    """(..., b, b) @ (..., b, N) row stacks: out[i] = sum_k a[i,k] q_k.
    Multiply-then-reduce for the same b = 1 bit-parity reason."""
    return jnp.sum(a[..., :, :, None] * q[..., None, :, :], axis=-2)


def block_qr(z: Array, live_in: Array, tol: Array):
    """Modified Gram-Schmidt QR of a (..., b, N) row stack with
    fixed-shape deflation: ``Z = R^T @ Q`` in row form (column form
    ``Z^T = Q^T R`` with R upper triangular).

    Slot i deflates when its orthogonalized residual norm is <= ``tol``
    (or ``live_in[i]`` is already False): its basis row and R diagonal
    are exact zeros, while the projection coefficients R[l, i] (l < i)
    are KEPT so the factorization stays exact up to the dropped-norm
    tolerance. Dead input rows (exact zeros) project to zero against
    everything and deflate for free.

    Returns ``(q, r, live)``: orthonormal live rows / zero dead rows,
    the (..., b, b) upper-triangular factor, and the per-slot liveness.
    """
    b = z.shape[-2]
    r = jnp.zeros(z.shape[:-2] + (b, b), z.dtype)
    qs, lives = [], []
    for i in range(b):
        zi = z[..., i, :]
        for l in range(i):  # noqa: E741 — textbook MGS index
            proj = jnp.sum(qs[l] * zi, axis=-1)
            r = r.at[..., l, i].set(proj)
            zi = zi - proj[..., None] * qs[l]
        nrm = jnp.linalg.norm(zi, axis=-1)
        alive = live_in[..., i] & (nrm > tol)
        qi = jnp.where(alive[..., None],
                       zi / jnp.maximum(nrm, _EPS)[..., None], 0.0)
        r = r.at[..., i, i].set(jnp.where(alive, nrm, 0.0))
        qs.append(qi)
        lives.append(alive)
    return (jnp.stack(qs, axis=-2), r, jnp.stack(lives, axis=-1))


def _clamped_inv(m: Array, lower: bool) -> Array:
    """Inverse of a (nearly) definite pivot block via eigenvalue
    clamping — the block twin of gql.py's ``max(d_lr, eps)`` /
    ``min(d_rr, -eps)`` sign guards. ``lower=True`` clamps eigenvalues
    up to +eps (pivots of J - lam_min I, PD on the live subspace);
    ``lower=False`` clamps down to -eps. Dead slots contribute exact
    decoupled eigenpairs whose coupling columns are exact zeros, so
    their clamped reciprocals never reach the recurrence."""
    ms = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    w, v = jnp.linalg.eigh(ms)
    w = jnp.maximum(w, _EPS) if lower else jnp.minimum(w, -_EPS)
    return jnp.einsum("...as,...s,...cs->...ac", v, 1.0 / w, v)


def _sandwich(b: Array, m: Array) -> Array:
    """B @ M @ B^T for (..., b, b) blocks."""
    return jnp.einsum("...ab,...bc,...dc->...ad", b, m, b)


def _lam_block(lam, b: int, dtype) -> Array:
    """lam * I_b with lam scalar or per-lane (...,)."""
    lam = jnp.asarray(lam, dtype)
    return lam[..., None, None] * jnp.eye(b, dtype=dtype)


def block_init(op, u: Array, lam_min, lam_max, fn: str | Array,
               rows: int) -> BlockState:
    """QR of the starting block + block iteration 1.

    ``u`` is the (..., b, N) row-stacked probe block; ``rows`` sizes the
    A/B history (capacity in block iterations — the solver freezes lanes
    at the cap exactly like an iteration budget). Rank-deficient
    starting blocks (duplicate / zero probe columns) deflate at the
    initial QR; a fully zero block is done at iteration one, the same
    dummy-lane rule the scalar driver uses.
    """
    u = jnp.asarray(u)
    b = u.shape[-2]
    dtype = u.dtype
    # relative deflation tolerance: duplicate columns deflate, tiny but
    # independent ones survive (scaled by the largest probe norm)
    norms = jnp.linalg.norm(u, axis=-1)
    tol0 = BREAKDOWN_TOL * jnp.maximum(jnp.max(norms, axis=-1), _EPS)
    live0 = jnp.ones(u.shape[:-1], bool)
    q1, r0, live1 = block_qr(u, live0, tol0)

    w = _ops.matvec_mrhs(op, q1)
    a_raw = _gram(q1, w)
    a1 = 0.5 * (a_raw + jnp.swapaxes(a_raw, -1, -2))
    scale = jnp.max(jnp.abs(a1), axis=(-2, -1))
    z = w - _rowmat(a1, q1)
    q2, b1, live2 = block_qr(z, live1,
                             BREAKDOWN_TOL * jnp.maximum(scale, 1.0))

    lane_shape = u.shape[:-2]
    if isinstance(fn, str):
        fnidx = jnp.full(lane_shape, _matfun.fn_index(fn), jnp.int32)
    else:
        fnidx = jnp.broadcast_to(jnp.asarray(fn, jnp.int32), lane_shape)

    hist_shape = lane_shape + (rows, b, b)
    a_hist = jnp.zeros(hist_shape, dtype).at[..., 0, :, :].set(a1)
    b_hist = jnp.zeros(hist_shape, dtype).at[..., 0, :, :].set(b1)
    return BlockState(
        q_prev=q1, q=q2, b_cur=b1, a_hist=a_hist, b_hist=b_hist,
        delta_lr=a1 - _lam_block(lam_min, b, dtype),
        delta_rr=a1 - _lam_block(lam_max, b, dtype),
        r0=r0, fnidx=fnidx, live=live2,
        done=~jnp.any(live2, axis=-1),
        it=jnp.ones(lane_shape, jnp.int32))


def block_step(op, st: BlockState, lam_min, lam_max) -> BlockState:
    """One block three-term-recurrence iteration; done lanes pass
    through unchanged (the solver's ``tree_freeze`` applies its own
    decision-rule freezing on top, exactly like the scalar path)."""
    b = st.q.shape[-2]
    dtype = st.q.dtype
    w = _ops.matvec_mrhs(op, st.q)
    a_raw = _gram(st.q, w)
    a_new = 0.5 * (a_raw + jnp.swapaxes(a_raw, -1, -2))
    scale = jnp.max(jnp.abs(a_new), axis=(-2, -1))
    z = w - _rowmat(a_new, st.q) - _rowmat(st.b_cur, st.q_prev)
    q_next, b_new, live_new = block_qr(
        z, st.live, BREAKDOWN_TOL * jnp.maximum(scale, 1.0))

    # block pivot recurrences D_{j+1} = A_{j+1} - lam I - B_j D_j^-1 B_j^T
    # (at b = 1: alpha_n - lam - beta_p^2 / delta, gql.recurrence_update)
    d_lr = a_new - _lam_block(lam_min, b, dtype) \
        - _sandwich(st.b_cur, _clamped_inv(st.delta_lr, lower=True))
    d_rr = a_new - _lam_block(lam_max, b, dtype) \
        - _sandwich(st.b_cur, _clamped_inv(st.delta_rr, lower=False))

    # history cursor write at the lane's own pre-step `it` (the
    # update_coeffs pattern: budget-frozen lanes resume gaplessly)
    m = st.a_hist.shape[-3]
    hit = ((jnp.arange(m, dtype=st.it.dtype) == st.it[..., None])
           & (~st.done)[..., None])[..., None, None]
    a_hist = jnp.where(hit, a_new[..., None, :, :], st.a_hist)
    b_hist = jnp.where(hit, b_new[..., None, :, :], st.b_hist)

    upd = ~st.done
    u1 = upd[..., None]
    u2 = upd[..., None, None]

    live = jnp.where(u1, live_new, st.live)
    return dataclasses.replace(
        st,
        q_prev=jnp.where(u2, st.q, st.q_prev),
        q=jnp.where(u2, q_next, st.q),
        b_cur=jnp.where(u2, b_new, st.b_cur),
        a_hist=a_hist, b_hist=b_hist,
        delta_lr=jnp.where(u2, d_lr, st.delta_lr),
        delta_rr=jnp.where(u2, d_rr, st.delta_rr),
        live=live,
        done=st.done | ~jnp.any(live, axis=-1),
        it=st.it + upd.astype(jnp.int32))


def _assemble(st: BlockState, lam_min, lam_max):
    """Stacked (..., 3, S, S) block-tridiagonal matrices — J_it (Gauss)
    plus its two one-BLOCK-row Radau extensions — in ONE fixed-size
    buffer of S = (M+1)*b, with a decoupled identity tail past the
    active blocks (zero off-diagonals => the tail's eigenvectors carry
    zero first-block components and drop out of the weights). Variant
    order on the stacked axis: (gauss, radau_left, radau_right)."""
    dtype = st.a_hist.dtype
    b = st.a_hist.shape[-1]
    m = st.a_hist.shape[-3]
    m1 = m + 1
    it = st.it
    eye_b = jnp.eye(b, dtype=dtype)

    j1 = jnp.arange(m1, dtype=it.dtype)
    jm = jnp.arange(m, dtype=it.dtype)
    in_j1 = (j1 < it[..., None])[..., None, None]
    at_ext = (j1 == it[..., None])[..., None, None]
    in_gauss = (jm < (it - 1)[..., None])[..., None, None]
    in_ext = (jm < it[..., None])[..., None, None]

    # Park dead-slot diagonals at 1.0, like the identity tail. A dead
    # slot's row/col of A_j is an exact zero, so leaving its eigenvalue
    # at 0 would sit exactly where the clamped f blows up (safe_inv(0)
    # ~ 1e30): the slot's weight is ~0, but eigh's ~eps eigenvector
    # contamination times 1e30 is O(1) garbage. At 1.0 every registered
    # f is tame, so the contamination stays ~eps. (Live diagonals of an
    # SPD Rayleigh block are strictly positive — exact zero <=> dead.)
    dead_fix = (jnp.diagonal(st.a_hist, axis1=-2, axis2=-1) == 0.0)
    a_fixed = st.a_hist + dead_fix.astype(dtype)[..., None] * eye_b
    hist_pad = jnp.concatenate(
        [a_fixed, jnp.broadcast_to(eye_b, st.a_hist.shape[:-3] + (1, b, b))],
        axis=-3)
    diag_base = jnp.where(in_j1, hist_pad, eye_b)

    # Radau extension blocks  A_hat = lam I + B_it D_it^-1 B_it^T
    # (at b = 1: gql.extension_coefficients' alpha_lr / alpha_rr)
    a_lr = _lam_block(lam_min, b, dtype) \
        + _sandwich(st.b_cur, _clamped_inv(st.delta_lr, lower=True))
    a_rr = _lam_block(lam_max, b, dtype) \
        + _sandwich(st.b_cur, _clamped_inv(st.delta_rr, lower=False))
    diag_lr = jnp.where(at_ext, a_lr[..., None, :, :], diag_base)
    diag_rr = jnp.where(at_ext, a_rr[..., None, :, :], diag_base)

    off_gauss = jnp.where(in_gauss, st.b_hist, 0.0)
    off_ext = jnp.where(in_ext, st.b_hist, 0.0)

    diags = jnp.stack([diag_base, diag_lr, diag_rr], axis=-4)
    offs = jnp.stack([off_gauss, off_ext, off_ext], axis=-4)

    # scatter the blocks into (..., 3, S, S): block-diagonal +
    # superdiagonal B^T placements + the transposed subdiagonal
    eye_m = jnp.eye(m1, dtype=dtype)
    up_m = jnp.eye(m1, k=1, dtype=dtype)
    offs = jnp.concatenate(
        [offs, jnp.zeros(offs.shape[:-3] + (1, b, b), dtype)], axis=-3)
    off_t = jnp.swapaxes(offs, -1, -2)     # B_{j+1}^T above the diagonal
    t = (jnp.einsum("...jac,jk->...jakc", diags, eye_m)
         + jnp.einsum("...jac,jk->...jakc", off_t, up_m))
    s = m1 * b
    t = t.reshape(t.shape[:-4] + (s, s))
    sup = jnp.einsum("...jac,jk->...jakc", off_t, up_m)
    sup = sup.reshape(sup.shape[:-4] + (s, s))
    return t + jnp.swapaxes(sup, -1, -2)


def _eig_weights(st: BlockState, lam_min, lam_max):
    """(theta, g) of the stacked variants: Ritz values (..., 3, S) and
    first-block weight vectors g[..., v, :, s] = R_0^T (v1)_s with v1
    the first b components of eigenvector s — the bracket matrix is
    ``sum_s f(theta_s) g_s g_s^T``."""
    b = st.a_hist.shape[-1]
    t = _assemble(st, lam_min, lam_max)
    theta, vecs = jnp.linalg.eigh(t)
    v1 = vecs[..., :b, :]                                  # (..., 3, b, S)
    g = jnp.einsum("...la,...vls->...vas", st.r0, v1)      # (..., 3, b, S)
    return theta, g


def estimates(st: BlockState, lam_min, lam_max) -> Array:
    """Traces of the three matrix-valued quadrature rules at the
    current iteration, stacked (..., 3) in the order (gauss,
    radau_left, radau_right). Exhausted lanes (full deflation — the
    block measure is fully resolved) collapse onto the exact Gauss
    value, the block twin of gql.py's Lemma-15 rule."""
    theta, g = _eig_weights(st, lam_min, lam_max)
    w = jnp.sum(g * g, axis=-2)                            # (..., 3, S)
    est = jnp.sum(w * _matfun._FNS[0].apply(theta), axis=-1)
    for f in _matfun._FNS[1:]:
        est = jnp.where((st.fnidx == f.index)[..., None],
                        jnp.sum(w * f.apply(theta), axis=-1), est)
    return jnp.where(st.done[..., None], est[..., :1], est)


def bracket_matrices(st: BlockState, lam_min, lam_max) -> Array:
    """The three (..., 3, b, b) matrix-valued rules themselves —
    Loewner-ordered PSD approximants of ``B^T f(A) B`` (oracle-checked
    in tests/test_block.py; the runtime decisions consume only their
    traces via :func:`bracket`)."""
    theta, g = _eig_weights(st, lam_min, lam_max)
    fv = _matfun._FNS[0].apply(theta)
    for f in _matfun._FNS[1:]:
        fv = jnp.where((st.fnidx == f.index)[..., None],
                       f.apply(theta), fv)
    mats = jnp.einsum("...vs,...vas,...vcs->...vac", fv, g, g)
    return jnp.where(st.done[..., None, None, None],
                     mats[..., :1, :, :], mats)


def bracket(st: BlockState, lam_min, lam_max):
    """Sign-aware oriented trace views: ``(lower, upper, loose_lower,
    loose_upper)`` on ``tr B^T f(A) B``, oriented per the matfun
    registry's derivative-sign table exactly like the scalar bracket.
    There is no block Lobatto rule here, so the loose side that Lobatto
    would supply duplicates the tight Radau bound on that side (the
    loose bracket is still valid, just not looser)."""
    est = estimates(st, lam_min, lam_max)
    g, rl, rr = est[..., 0], est[..., 1], est[..., 2]
    gauss_lower = jnp.asarray(_matfun._GAUSS_IS_LOWER)[st.fnidx]
    lower = jnp.where(gauss_lower, rr, rl)
    upper = jnp.where(gauss_lower, rl, rr)
    loose_lower = jnp.where(gauss_lower, g, lower)
    loose_upper = jnp.where(gauss_lower, upper, g)
    return lower, upper, loose_lower, loose_upper
