"""Gauss Quadrature Lanczos (GQL) — paper Alg. 5, batched for TPU.

Produces, per iteration i, the four quadrature estimates of
``u^T A^{-1} u``:

    g_i      Gauss             (lower bound, Thm. 2)
    g_i^rr   right Gauss-Radau (lower bound, tighter: Thm. 4)
    g_i^lr   left Gauss-Radau  (upper bound, tighter: Thm. 6)
    g_i^lo   Gauss-Lobatto     (upper bound)

Internally all estimates are for the *unit-normalized* problem
``e_1^T J_i^{-1} e_1`` and are multiplied by ||u||^2 at the API boundary.
(Alg. 5 in the paper carries a ||u|| factor that is inconsistent with the
||v||^2 scaling used by Alg. 7; we use the unambiguous Golub-Meurant
convention, which its own Appendix-B proofs follow.)

Modified Jacobi extensions (Radau/Lobatto) follow Golub (1973):
  alpha^lr = lam_min + beta_i^2 / delta_i^lr
  alpha^rr = lam_max + beta_i^2 / delta_i^rr
  (beta^lo)^2 = (lam_max - lam_min) * d_lr * d_rr / (d_rr - d_lr)
  alpha^lo    = (lam_max * d_rr - lam_min * d_lr) / (d_rr - d_lr)
where delta, delta^lr, delta^rr are the running last-pivot recurrences of
J_i, J_i - lam_min I and J_i - lam_max I.

Everything is lockstep-batched with per-lane freezing; see DESIGN.md Sec. 3.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import lanczos as _lz

Array = jax.Array

_EPS = 1e-30


class GQLState(NamedTuple):
    lz: _lz.LanczosState
    # Sherman-Morrison recursion state (unit-normalized)
    g: Array          # Gauss estimate g_i
    c: Array          # c_i = prod beta_j / delta_j  ( [J_i^-1]_{1i} * delta_i )
    delta: Array      # last pivot of J_i
    delta_lr: Array   # last pivot of J_i - lam_min I
    delta_rr: Array   # last pivot of J_i - lam_max I
    # Per-iteration quadrature estimates (unit-normalized)
    g_rr: Array
    g_lr: Array
    g_lo: Array
    # Scaling + termination
    u_norm_sq: Array
    done: Array       # lane finished (breakdown or externally frozen)
    it: Array         # int32, iterations completed


def extension_coefficients(beta, d_lr, d_rr, lam_min, lam_max):
    """Golub (1973) modified last-row entries of the Radau/Lobatto
    extensions of J_i, from the running pivot recurrences:
    ``(alpha_lr, alpha_rr, alpha_lo, b2_lo, b2)``. The ONE home for
    these formulas and their sign guards — shared by the
    Sherman-Morrison recurrence below and the matfun eigensolve
    (core/matfun.py), so the two routes cannot drift."""
    b2 = beta * beta
    d_lr_s = jnp.maximum(d_lr, _EPS)        # last pivot of (J - lmin I) > 0
    d_rr_s = jnp.minimum(d_rr, -_EPS)       # last pivot of (J - lmax I) < 0

    alpha_lr = lam_min + b2 / d_lr_s
    alpha_rr = lam_max + b2 / d_rr_s
    denom_lo = d_rr_s - d_lr_s              # < 0
    b2_lo = (lam_max - lam_min) * d_lr_s * d_rr_s / denom_lo
    alpha_lo = (lam_max * d_rr_s - lam_min * d_lr_s) / denom_lo
    return alpha_lr, alpha_rr, alpha_lo, b2_lo, b2


def _extensions(g, c, delta, d_lr, d_rr, beta, lam_min, lam_max):
    """Radau/Lobatto estimates for the J_i extended with off-diag ``beta``."""
    alpha_lr, alpha_rr, alpha_lo, b2_lo, b2 = extension_coefficients(
        beta, d_lr, d_rr, lam_min, lam_max)
    delta_s = jnp.maximum(delta, _EPS)

    c2 = c * c

    def sm(alpha_hat, b2_hat):
        den = delta_s * (alpha_hat * delta_s - b2_hat)
        # sign-preserving, never-zero guard (den > 0 for live PD lanes;
        # degenerate post-breakdown lanes are frozen by the caller)
        safe = jnp.where(den >= 0, jnp.maximum(den, _EPS),
                         jnp.minimum(den, -_EPS))
        return g + b2_hat * c2 / safe

    return sm(alpha_rr, b2), sm(alpha_lr, b2), sm(alpha_lo, b2_lo)


def gql_init(op, u: Array, lam_min: Array, lam_max: Array) -> GQLState:
    """Iteration i=1 of Alg. 5."""
    lam_min = jnp.asarray(lam_min, u.dtype)
    lam_max = jnp.asarray(lam_max, u.dtype)
    lz = _lz.lanczos_init(op, u)
    u_norm_sq = jnp.sum(u * u, axis=-1)

    alpha1, beta1 = lz.alpha, lz.beta
    g1 = 1.0 / jnp.maximum(alpha1, _EPS)
    c1 = jnp.ones_like(alpha1)
    delta1 = alpha1
    d_lr1 = alpha1 - lam_min
    d_rr1 = alpha1 - lam_max
    g_rr, g_lr, g_lo = _extensions(g1, c1, delta1, d_lr1, d_rr1, beta1,
                                   lam_min, lam_max)
    done = ~lz.live  # immediate breakdown => u is an eigvec combination hit
    zero_u = u_norm_sq <= 0.0
    g1 = jnp.where(zero_u, 0.0, g1)
    g_rr = jnp.where(done, g1, g_rr)
    g_lr = jnp.where(done, g1, g_lr)
    g_lo = jnp.where(done, g1, g_lo)
    return GQLState(lz=lz, g=g1, c=c1, delta=delta1, delta_lr=d_lr1,
                    delta_rr=d_rr1, g_rr=g_rr, g_lr=g_lr, g_lo=g_lo,
                    u_norm_sq=u_norm_sq, done=done | zero_u,
                    it=jnp.ones_like(lz.it))


def recurrence_update(alpha_n, beta_n, beta_p, g, c, delta, d_lr, d_rr,
                      lam_min, lam_max):
    """Pure-math body of one Alg. 5 iteration (no Lanczos, no freezing).

    Elementwise over lanes — this is exactly what the fused Pallas kernel
    ``kernels/gql_update.py`` computes on the VPU; kept here as the single
    source of truth and as its oracle.
    """
    b2p = beta_p * beta_p
    delta_s = jnp.maximum(delta, _EPS)
    d_lr_s = jnp.maximum(d_lr, _EPS)
    d_rr_s = jnp.minimum(d_rr, -_EPS)

    den_g = delta_s * (alpha_n * delta_s - b2p)
    g_new = g + b2p * (c * c) / jnp.maximum(den_g, _EPS)
    c_new = c * beta_p / delta_s
    delta_new = alpha_n - b2p / delta_s
    d_lr_new = alpha_n - lam_min - b2p / d_lr_s
    d_rr_new = alpha_n - lam_max - b2p / d_rr_s

    g_rr, g_lr, g_lo = _extensions(g_new, c_new, delta_new, d_lr_new,
                                   d_rr_new, beta_n, lam_min, lam_max)
    return g_new, c_new, delta_new, d_lr_new, d_rr_new, g_rr, g_lr, g_lo


def gql_assemble(st: GQLState, lz: _lz.LanczosState, raw) -> GQLState:
    """Fold one iteration's raw recurrence outputs into the next state:
    exact-collapse on Krylov exhaustion, frozen-lane pass-through, and
    done/it bookkeeping. The ONE home for this select logic — shared by
    :func:`gql_step` and the fused step kernel
    (``kernels/lanczos_step.py``), so the two routes cannot drift.

    ``lz`` is the post-step Lanczos state; ``raw`` is the 8-tuple
    returned by :func:`recurrence_update` (which may carry garbage on
    lanes with ``st.done`` — every output masks those back)."""
    (g_new, c_new, delta_new, d_lr_new, d_rr_new, g_rr, g_lr, g_lo) = raw

    # Lanes that just exhausted the Krylov space: estimate is exact
    # (Lemma 15); collapse the bracket onto g.
    just_died = st.lz.live & ~lz.live
    g_rr = jnp.where(just_died, g_new, g_rr)
    g_lr = jnp.where(just_died, g_new, g_lr)
    g_lo = jnp.where(just_died, g_new, g_lo)

    upd = ~st.done

    def sel(new, old):
        return jnp.where(upd, new, old)

    return GQLState(
        lz=lz,
        g=sel(g_new, st.g), c=sel(c_new, st.c),
        delta=sel(delta_new, st.delta),
        delta_lr=sel(d_lr_new, st.delta_lr),
        delta_rr=sel(d_rr_new, st.delta_rr),
        g_rr=sel(g_rr, st.g_rr), g_lr=sel(g_lr, st.g_lr),
        g_lo=sel(g_lo, st.g_lo),
        u_norm_sq=st.u_norm_sq,
        done=st.done | ~lz.live,
        it=st.it + upd.astype(jnp.int32),
    )


def gql_step(op, st: GQLState, lam_min: Array, lam_max: Array,
             basis: Array | None = None, recurrence=None) -> GQLState:
    """Iterations i>=2 of Alg. 5; frozen lanes pass through unchanged.

    ``recurrence`` lets callers swap the scalar-update implementation (same
    signature and return as ``recurrence_update``); the solver uses it to
    route the arithmetic through the fused Pallas kernel
    (``kernels/gql_update.py``) instead of the reference path.
    """
    if recurrence is None:
        recurrence = recurrence_update
    lam_min = jnp.asarray(lam_min, st.g.dtype)
    lam_max = jnp.asarray(lam_max, st.g.dtype)
    lz = _lz.lanczos_step(op, st.lz, basis=basis)
    # Quantities of the *new* iteration (i+1): lz.alpha / lz.beta are
    # alpha_{i+1} / beta_{i+1}; lz.beta_prev is beta_i.
    raw = recurrence(
        lz.alpha, lz.beta, lz.beta_prev, st.g, st.c, st.delta,
        st.delta_lr, st.delta_rr, lam_min, lam_max)
    return gql_assemble(st, lz, raw)


# ---------------------------------------------------------------------------
# Scaled views


def lower_bound(st: GQLState) -> Array:
    """Best available lower bound: right Gauss-Radau (Thm. 4)."""
    return st.g_rr * st.u_norm_sq


def lower_bound_gauss(st: GQLState) -> Array:
    return st.g * st.u_norm_sq


def upper_bound(st: GQLState) -> Array:
    """Best available upper bound: left Gauss-Radau (Thm. 6)."""
    return st.g_lr * st.u_norm_sq


def upper_bound_lobatto(st: GQLState) -> Array:
    return st.g_lo * st.u_norm_sq


def gap(st: GQLState) -> Array:
    return (st.g_lr - st.g_rr) * st.u_norm_sq
