from .loop import LoopConfig, LoopResult, train  # noqa: F401
from .monitor import (condition_number_bounds, fisher_proxy_bounds,  # noqa
                      gradient_sketch, make_monitor)
