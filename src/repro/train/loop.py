"""Fault-tolerant training loop.

Production behaviors, exercised by tests on CPU:

  * auto-resume from the latest committed checkpoint (crash == restart)
  * async checkpoint saves with retention
  * simulated preemption (raise at step k) -> restart loses at most
    ``save_every`` steps and replays the data stream deterministically
  * straggler watchdog: a per-step wall-clock budget; breaches trigger an
    early checkpoint + a report (on real fleets: slice exclusion)
  * elastic rescale: checkpoints restore onto a different device count
    (see checkpoint.io.restore with new shardings)
  * optional GQL spectral monitor (paper tie-in, train/monitor.py)
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..checkpoint import io as ckpt_io


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    save_every: int = 25
    keep_checkpoints: int = 3
    log_every: int = 10
    step_time_budget_s: Optional[float] = None   # straggler watchdog
    monitor_every: int = 0                        # 0 = off


@dataclasses.dataclass
class LoopResult:
    final_step: int
    losses: list
    resumed_from: Optional[int]
    straggler_events: int
    monitor_log: list


def train(
    *,
    loop_cfg: LoopConfig,
    ckpt_dir: str | Path,
    init_state: Callable[[], tuple],     # () -> (params, opt_state)
    step_fn: Callable,                   # (params, opt, batch) -> (p,o,m)
    batch_fn: Callable[[int], Any],      # step -> batch
    monitor_fn: Optional[Callable] = None,
    fail_at_step: Optional[int] = None,  # test hook: simulate preemption
) -> LoopResult:
    ckpt_dir = Path(ckpt_dir)
    saver = ckpt_io.AsyncSaver()

    params, opt_state = init_state()
    start = 0
    resumed_from = None
    latest = ckpt_io.latest_step(ckpt_dir)
    if latest is not None:
        params, opt_state = ckpt_io.restore(
            ckpt_dir, latest, (params, opt_state))
        start = latest
        resumed_from = latest

    losses = []
    monitor_log = []
    stragglers = 0
    for step in range(start, loop_cfg.total_steps):
        if fail_at_step is not None and step == fail_at_step:
            saver.wait()
            raise RuntimeError(f"simulated preemption at step {step}")
        t0 = time.time()
        batch = batch_fn(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0

        if loop_cfg.step_time_budget_s and dt > loop_cfg.step_time_budget_s:
            stragglers += 1
            # straggler mitigation: persist progress immediately so a
            # slice swap / restart loses nothing
            saver.save(ckpt_dir, step + 1, (params, opt_state),
                       extra={"straggler": True, "step_time": dt})

        if loop_cfg.monitor_every and monitor_fn is not None \
                and (step + 1) % loop_cfg.monitor_every == 0:
            monitor_log.append((step + 1, monitor_fn(params, batch)))

        if (step + 1) % loop_cfg.save_every == 0 \
                or step + 1 == loop_cfg.total_steps:
            saver.save(ckpt_dir, step + 1, (params, opt_state))
            ckpt_io.retain(ckpt_dir, keep=loop_cfg.keep_checkpoints)

    saver.wait()
    ckpt_io.retain(ckpt_dir, keep=loop_cfg.keep_checkpoints)
    return LoopResult(final_step=loop_cfg.total_steps, losses=losses,
                      resumed_from=resumed_from,
                      straggler_events=stragglers,
                      monitor_log=monitor_log)
