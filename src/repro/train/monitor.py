"""GQL spectral monitor — paper tie-in #2 (DESIGN.md Sec. 4.2).

During training we bracket, with certified Gauss-Radau bounds,

    g^T (F + lam I)^{-1} g     (natural-gradient norm proxy)

where F is the Gram matrix of per-example gradient sketches (a Fisher
proxy). The operator is never materialized beyond a (B, K) sketch; the
matvec is two small matmuls, and under data parallelism XLA reduces the
sketch products across shards automatically. A handful of Lanczos
iterations per probe gives tight intervals (Thm. 5/8) — orders of
magnitude cheaper than an eigendecomposition, and the bracket width is a
built-in error certificate.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..core import bounds as core_bounds
from ..core import operators as core_ops
from ..core import solver as core_solver
from ..core import spectrum as core_spectrum
from ..core import trace as core_trace


def gradient_sketch(grads: Any, num_probes: int = 128,
                    seed: int = 0) -> jax.Array:
    """Random-projection sketch of the gradient tree -> (num_probes,)."""
    leaves = jax.tree.leaves(grads)
    outs = []
    key = jax.random.key(seed)
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        proj = jax.random.normal(k, (num_probes, leaf.size),
                                 jnp.float32) / (leaf.size ** 0.5)
        outs.append(proj @ leaf.reshape(-1).astype(jnp.float32))
    return sum(outs)


def fisher_proxy_bounds(example_sketches: jax.Array, probe: jax.Array,
                        lam: float = 1e-3, max_iters: int = 24):
    """Bracket probe^T (F + lam I)^-1 probe for F = S^T S / B.

    example_sketches: (B, K) per-example gradient sketches; probe: (K,).
    Returns core_bounds.BIFBounds (lower/upper certified).
    """
    b, k = example_sketches.shape
    s = example_sketches.astype(jnp.float32)

    def matvec(x):
        return s.T @ (s @ x) / b + lam * x

    diag = jnp.sum(s * s, axis=0) / b + lam
    op = core_ops.MatvecFn(fn=matvec, n_static=k, diag_vals=diag)
    est = core_spectrum.lanczos_extremal(op, probe, num_iters=12)
    lam_min = max(lam * 0.5, 0.0) or float(est.lam_min)
    res = core_solver.BIFSolver.create(max_iters=max_iters, rtol=1e-2).solve(
        op, probe, lam_min=lam_min, lam_max=float(est.lam_max))
    return core_bounds.BIFBounds(lower=res.lower, upper=res.upper,
                                 iterations=res.iterations,
                                 converged=res.converged)


def logdet_bounds(example_sketches: jax.Array, lam: float = 1e-3,
                  num_probes: int | None = None, max_iters: int = 24):
    """Bracketed ``logdet(F + lam I)`` for the Fisher-proxy Gram matrix
    (a volume/entropy-style collapse signal: the logdet crashing toward
    ``K log lam`` means the gradient sketches span a shrinking
    subspace). Runs the retrospective logdet estimator
    (``core.trace.trace_quad`` with f=log, DESIGN.md Sec. 9) on the
    same never-materialized sketch matvec as the BIF monitor.

    The spectral interval is certified, not estimated: F is PSD so
    ``lam`` floors the spectrum, and ``lam_max <= tr(F + lam I)``
    (= the sketch diagonal sum) caps it — loose caps only slow
    convergence, never break the bounds. ``num_probes=None`` uses the K
    unit probes (deterministic bracket containing the true logdet);
    an integer runs that many Hutchinson probes instead.
    """
    b, k = example_sketches.shape
    s = example_sketches.astype(jnp.float32)

    def matvec(x):
        # batched over leading dims of x (trace probes run as stacked
        # lanes), unlike the single-vector closures above
        return (x @ s.T) @ s / b + lam * x

    diag = jnp.sum(s * s, axis=0) / b + lam
    op = core_ops.MatvecFn(fn=matvec, n_static=k, diag_vals=diag)
    return core_trace.trace_quad(
        op, "log", num_probes, lam_min=lam * 0.999,
        lam_max=float(jnp.sum(diag)), max_iters=max_iters, rtol=1e-6,
        atol=1e-6)


def condition_number_bounds(example_sketches: jax.Array, lam: float = 1e-3,
                            num_iters: int = 16):
    """Certified interval containing kappa(F + lam I) via Ritz values."""
    b, k = example_sketches.shape
    s = example_sketches.astype(jnp.float32)

    def matvec(x):
        return s.T @ (s @ x) / b + lam * x

    diag = jnp.sum(s * s, axis=0) / b + lam
    op = core_ops.MatvecFn(fn=matvec, n_static=k, diag_vals=diag)
    probe = jnp.ones((k,), jnp.float32)
    est = core_spectrum.lanczos_extremal(op, probe, num_iters=num_iters)
    # Ritz interval is INNER for the spectrum: lam_max est is a lower
    # bound on lam_N, so kappa_lower is certified; kappa_upper uses the
    # known floor lam on the bottom.
    kappa_lower = float(est.lam_max) / float(jnp.maximum(est.lam_min, lam))
    kappa_upper = float(est.lam_max) * 1.1 / lam
    return {"kappa_lower": kappa_lower, "kappa_upper": kappa_upper,
            "lam_max_est": float(est.lam_max)}


def make_monitor(loss_fn, cfg, lam: float = 1e-3, sketch_dim: int = 64,
                 per_example: int = 8):
    """Returns monitor_fn(params, batch) for train.loop (logs certified
    natural-grad-norm brackets + condition estimates)."""

    def monitor(params, batch):
        def one_example(i):
            mb = jax.tree.map(lambda x: x[i:i + 1], batch)
            g = jax.grad(lambda p: loss_fn(cfg, p, mb)[0])(params)
            return gradient_sketch(g, num_probes=sketch_dim)

        n = min(per_example,
                jax.tree.leaves(batch)[0].shape[0])
        sketches = jnp.stack([one_example(i) for i in range(n)])
        mean_sketch = sketches.mean(0)
        bif = fisher_proxy_bounds(sketches, mean_sketch, lam=lam)
        cond = condition_number_bounds(sketches, lam=lam)
        ld = logdet_bounds(sketches, lam=lam)
        return {"nat_norm_lower": float(bif.lower),
                "nat_norm_upper": float(bif.upper),
                "quad_iters": int(bif.iterations),
                "logdet_lower": float(ld.lower),
                "logdet_upper": float(ld.upper), **cond}

    return monitor
