"""Sharded checkpointing with async save, retention, and elastic restore.

Layout: <dir>/step_<N>/
    manifest.json          — tree structure, shapes, dtypes, step, mesh
    shard_<i>.npz          — flat param/opt arrays (chunked by size)
    _COMMITTED             — written last; restore ignores uncommitted dirs

Elastic restore: arrays are saved unsharded-logical (gathered); restoring
onto any device count / mesh re-shards from the logical view. For
multi-host deployments the same format is written per-process with
disjoint shard ownership — on this single-process container that
degenerates to one writer, which keeps tests honest but simple.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any, *,
         extra: Optional[dict] = None) -> Path:
    """Synchronous commit-marked save."""
    out = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = out.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)

    manifest = {"step": step, "treedef": str(treedef),
                "n_leaves": len(leaves),
                "extra": extra or {},
                "leaves": [], "shards": []}
    shard, shard_bytes, shard_idx = {}, 0, 0
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"].append({"index": i, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype),
                                   "shard": shard_idx})
        shard[f"leaf_{i}"] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _SHARD_BYTES:
            np.savez(tmp / f"shard_{shard_idx}.npz", **shard)
            manifest["shards"].append(shard_idx)
            shard, shard_bytes, shard_idx = {}, 0, shard_idx + 1
    if shard:
        np.savez(tmp / f"shard_{shard_idx}.npz", **shard)
        manifest["shards"].append(shard_idx)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "_COMMITTED").write_text(str(time.time()))
    if out.exists():
        shutil.rmtree(out)
    tmp.rename(out)
    return out


class AsyncSaver:
    """Overlap checkpoint I/O with training (one in flight at a time)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[Path] = None

    def save(self, ckpt_dir, step, tree, extra=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def run():
            self.last_path = save(ckpt_dir, step, host_tree, extra=extra)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.name.startswith("step_") and (p / "_COMMITTED").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (elastic: any mesh/devices).

    If ``shardings`` (a matching tree of NamedSharding) is given, leaves
    are placed sharded with jax.device_put — this is the elastic-rescale
    path: the on-disk logical arrays re-shard onto the new topology.
    """
    src = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((src / "manifest.json").read_text())
    shards = {i: np.load(src / f"shard_{i}.npz")
              for i in manifest["shards"]}
    leaves_like, treedef = _flatten(like)
    assert len(leaves_like) == manifest["n_leaves"], \
        f"leaf count mismatch {len(leaves_like)} vs {manifest['n_leaves']}"
    out = []
    sh_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                 else [None] * len(leaves_like))
    for meta, proto, sh in zip(manifest["leaves"], leaves_like, sh_leaves):
        arr = shards[meta["shard"]][f"leaf_{meta['index']}"]
        assert list(arr.shape) == list(proto.shape), \
            f"shape mismatch {arr.shape} vs {proto.shape}"
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr, dtype=proto.dtype))
    return jax.tree.unflatten(treedef, out)


def retain(ckpt_dir: str | Path, keep: int = 3):
    """Delete all but the newest ``keep`` committed checkpoints."""
    d = Path(ckpt_dir)
    if not d.exists():
        return
    steps = sorted(
        int(p.name.split("_")[1]) for p in d.iterdir()
        if p.name.startswith("step_") and (p / "_COMMITTED").exists())
    for s in steps[:-keep]:
        shutil.rmtree(d / f"step_{s:08d}", ignore_errors=True)
