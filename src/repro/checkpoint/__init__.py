from .io import AsyncSaver, latest_step, restore, retain, save  # noqa
