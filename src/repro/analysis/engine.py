"""quadlint engine: findings, suppressions, file walking, rule dispatch.

The engine is deliberately dependency-free (stdlib ``ast`` + ``re``):
per-file rules (rules_ast.py, collectives.py) parse one file at a time,
and the cross-file pytree-contract checker (contracts.py) runs once per
invocation when the runtime's core files are in the scan set. Findings
print as ``path:line RULE message`` and the CLI exits non-zero when any
survive suppression.

Suppression syntax (DESIGN.md Sec. 10)::

    jfn = jax.jit(fn)  # quadlint: disable=QL003 -- one-shot lowering

The comment silences the named rule(s) on its own line and on the line
directly below it (for comments placed above a long statement). The
reason after ``--`` is REQUIRED: a bare ``disable=`` is itself a
finding (QL000), so every suppression documents why the contract does
not apply.
"""
from __future__ import annotations

import ast
import os
import re
from pathlib import Path
from typing import Callable, Iterable, NamedTuple, Optional

SUPPRESSION_RULE = "QL000"

_SUPPRESS_RE = re.compile(
    r"#\s*quadlint:\s*disable=(?P<rules>[A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)"
    r"(?P<reason>\s*--\s*\S.*)?")


class Finding(NamedTuple):
    """One rule violation, anchored to a source line."""
    path: str     # display path (relative to the invocation cwd)
    line: int     # 1-based
    rule: str     # "QLxxx"
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"


class FileContext(NamedTuple):
    """Everything a per-file rule needs about one parsed source file."""
    path: Path    # resolved absolute path
    rel: str      # display path
    source: str
    tree: ast.Module

    @property
    def parts(self) -> tuple:
        return self.path.parts

    @property
    def in_src(self) -> bool:
        """Library code: anything under a directory named ``src``."""
        return "src" in self.parts

    @property
    def in_serve(self) -> bool:
        return self.in_src and "serve" in self.parts

    @property
    def in_tests(self) -> bool:
        return "tests" in self.parts


def parse_suppressions(source: str, rel: str
                       ) -> tuple[dict[int, set], list]:
    """Scan COMMENT tokens for ``# quadlint: disable=...`` directives
    (tokenize-based, so docstrings/strings describing the syntax never
    count as directives).

    Returns (line -> suppressed rule set, findings for malformed
    suppressions). A suppression covers its own line and the next one.
    """
    import io
    import tokenize

    suppressed: dict[int, set] = {}
    findings: list[Finding] = []
    try:
        tokens = list(tokenize.generate_tokens(
            io.StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError):  # load_context reports it
        return suppressed, findings
    for tok in tokens:
        if tok.type != tokenize.COMMENT or "quadlint" not in tok.string:
            continue
        lineno = tok.start[0]
        m = _SUPPRESS_RE.search(tok.string)
        if m is None:
            if "quadlint:" in tok.string:
                findings.append(Finding(
                    rel, lineno, SUPPRESSION_RULE,
                    "malformed quadlint directive (expected "
                    "'# quadlint: disable=QLxxx -- reason')"))
            continue
        rules = {r.strip() for r in m.group("rules").split(",")}
        if not m.group("reason"):
            findings.append(Finding(
                rel, lineno, SUPPRESSION_RULE,
                "suppression requires a reason: "
                "'# quadlint: disable=" + ",".join(sorted(rules))
                + " -- why the rule does not apply here'"))
            continue
        for covered in (lineno, lineno + 1):
            suppressed.setdefault(covered, set()).update(rules)
    return suppressed, findings


def collect_files(paths: Iterable[str]) -> list[Path]:
    """Expand files/directories into a sorted, de-duplicated .py list."""
    out: dict[Path, None] = {}
    for raw in paths:
        p = Path(raw)
        if not p.exists():
            raise FileNotFoundError(f"quadlint: no such path: {raw}")
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" not in f.parts:
                    out[f.resolve()] = None
        else:
            out[p.resolve()] = None
    return list(out)


def _display(path: Path) -> str:
    try:
        rel = os.path.relpath(path)
    except ValueError:  # different drive (windows); keep absolute
        return str(path)
    return str(path) if rel.startswith("..") else rel


def load_context(path: Path) -> tuple[Optional[FileContext], list]:
    rel = _display(path)
    source = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return None, [Finding(rel, e.lineno or 1, SUPPRESSION_RULE,
                              f"file does not parse: {e.msg}")]
    return FileContext(path=path, rel=rel, source=source, tree=tree), []


def _file_rules() -> list[Callable[[FileContext], Iterable[Finding]]]:
    # imported lazily so `load_context` has no circular dependency
    from . import collectives, rules_ast
    return [
        rules_ast.check_tracer_leaks,      # QL002
        rules_ast.check_jit_discipline,    # QL003
        rules_ast.check_shim_imports,      # QL005
        rules_ast.check_randomness,        # QL006
        rules_ast.check_host_telemetry,    # QL008
        collectives.check_collective_pairing,  # QL004
        collectives.check_collective_cadence,  # QL007
    ]


def run_paths(paths: Iterable[str], *,
              project_checks: bool = True) -> list:
    """Run every rule over ``paths``; returns unsuppressed findings
    sorted by (path, line, rule)."""
    files = collect_files(paths)
    rules = _file_rules()
    findings: list[Finding] = []
    suppressions: dict[str, dict[int, set]] = {}
    contexts: list[FileContext] = []
    for path in files:
        ctx, parse_findings = load_context(path)
        findings.extend(parse_findings)
        if ctx is None:
            continue
        contexts.append(ctx)
        supp, supp_findings = parse_suppressions(ctx.source, ctx.rel)
        suppressions[ctx.rel] = supp
        findings.extend(supp_findings)
        for rule in rules:
            findings.extend(rule(ctx))
    if project_checks:
        from . import contracts
        findings.extend(contracts.check_contracts(contexts))

    def keep(f: Finding) -> bool:
        if f.rule == SUPPRESSION_RULE:  # QL000 cannot be suppressed
            return True
        return f.rule not in suppressions.get(f.path, {}).get(f.line, ())

    kept = sorted({f for f in findings if keep(f)},
                  key=lambda f: (f.path, f.line, f.rule, f.message))
    return kept


def main(argv: Optional[list] = None) -> int:
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="quadlint: static checks for the quadrature runtime's "
                    "state-threading, jit, and collective contracts")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to scan")
    parser.add_argument("--no-project-checks", action="store_true",
                        help="skip the cross-file pytree-contract checker "
                             "(QL001)")
    args = parser.parse_args(argv)
    findings = run_paths(args.paths,
                         project_checks=not args.no_project_checks)
    for f in findings:
        print(f.render())
    if findings:
        print(f"quadlint: {len(findings)} finding(s)")
        return 1
    return 0
