"""quadlint: repo-specific static analysis for the quadrature runtime.

``python -m repro.analysis src tests benchmarks`` walks the given paths
and mechanically enforces the contracts DESIGN.md states in prose (full
catalog with motivating bugs: DESIGN.md Sec. 10):

  QL001  state-threading completeness: every field of QuadState /
         GQLState / CoeffHistory is claimed by a threading-contract
         registry and handled by the freeze loops, the sharded driver,
         and the serving pool's admission/banking.
  QL002  tracer leaks: python `if`/`while`/`bool()`/`float()`/`int()`/
         `.item()` on traced values inside jit / shard_map /
         lax.while_loop bodies.
  QL003  jit discipline: module-level jits in serve/ carry a trace
         counter; no jax.jit constructed inside function bodies.
  QL004  collective pairing: collectives under a while_loop inside
         shard_map require a globally-reduced continue flag.
  QL005  no imports of the removed PR-2 deprecation shims.
  QL006  no unkeyed randomness in library/benchmark code.
  QL007  collective cadence: core/ while_loop bodies may not issue raw
         collectives — round-boundary communication goes through the
         sanctioned cadence helper (one packed all_gather per
         decide_every round, DESIGN.md Sec. 11).

Findings print as ``path:line RULE message``; suppress a deliberate
exception with ``# quadlint: disable=QLxxx -- reason`` (the reason is
mandatory). The engine is stdlib-only (``ast``); QL001 additionally
imports the runtime modules to read the live field sets.
"""
from .engine import Finding, main, run_paths  # noqa: F401
