"""Per-file AST rules: tracer leaks, jit discipline, shim imports,
unkeyed randomness, host-side-only telemetry (QL002 / QL003 / QL005 /
QL006 / QL008).

Every rule here works on one parsed file at a time and knows nothing
about the runtime beyond naming conventions (the cross-file pytree
contracts live in contracts.py). The rules encode bugs PRs 3-5 actually
shipped fixes for — see DESIGN.md Sec. 10 for the catalog.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from .engine import FileContext, Finding

# ---------------------------------------------------------------------------
# shared helpers


def dotted(node: ast.AST) -> Optional[str]:
    """'jax.lax.while_loop' for an attribute chain, or None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def last_component(node: ast.AST) -> Optional[str]:
    d = dotted(node)
    return None if d is None else d.rsplit(".", 1)[-1]


_JIT_DOTTED = {"jax.jit", "jit"}
_PARTIAL_DOTTED = {"partial", "functools.partial"}


def jit_expr_info(node: ast.AST) -> Optional[ast.Call]:
    """If ``node`` is a jit-construction expression — ``jax.jit``,
    ``jax.jit(...)`` or ``partial(jax.jit, ...)`` — return the Call
    carrying static-arg keywords (or the node itself for a bare
    ``@jax.jit``); else None."""
    if dotted(node) in _JIT_DOTTED:
        return node if isinstance(node, ast.Call) else ast.Call(
            func=node, args=[], keywords=[])
    if isinstance(node, ast.Call):
        if dotted(node.func) in _JIT_DOTTED:
            return node
        if dotted(node.func) in _PARTIAL_DOTTED and node.args \
                and dotted(node.args[0]) in _JIT_DOTTED:
            return node
    return None


def _static_names(call: Optional[ast.Call]) -> set:
    """Literal static_argnames of a jit decorator (static params are
    python values inside the trace, exempt from tracer-leak checks)."""
    names: set = set()
    if call is None:
        return names
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
        for e in elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                names.add(e.value)
    return names


_FunctionNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# callees whose function-valued arguments run under trace
_TRACED_CALLEES = {"while_loop", "scan", "cond", "fori_loop", "shard_map",
                   "jit", "vmap", "pmap", "checkpoint", "remat"}

# attributes whose value is static metadata even on a traced array
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "_fields"}
_STATIC_CALLS = {"len", "isinstance", "type"}


class _Scopes(ast.NodeVisitor):
    """Index every function node with its parent function and the jit
    decorator (if any), plus name -> [def] for traced-callee resolution."""

    def __init__(self):
        self.parent: dict = {}
        self.jit_call: dict = {}
        self.by_name: dict = {}
        self._stack: list = []

    def _enter(self, node):
        self.parent[node] = self._stack[-1] if self._stack else None
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.by_name.setdefault(node.name, []).append(node)
            for dec in node.decorator_list:
                call = jit_expr_info(dec)
                if call is not None:
                    self.jit_call[node] = call
        self._stack.append(node)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _enter
    visit_AsyncFunctionDef = _enter
    visit_Lambda = _enter


def _params(fn) -> list:
    a = fn.args
    return [x.arg for x in
            a.posonlyargs + a.args + a.kwonlyargs
            + ([a.vararg] if a.vararg else [])
            + ([a.kwarg] if a.kwarg else [])]


def _traced_roots(tree: ast.Module, scopes: _Scopes) -> set:
    roots = set(scopes.jit_call)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if last_component(node.func) not in _TRACED_CALLEES:
            continue
        cands = list(node.args) + [kw.value for kw in node.keywords]
        for arg in cands:
            if isinstance(arg, ast.Lambda):
                roots.add(arg)
            elif isinstance(arg, ast.Name):
                roots.update(scopes.by_name.get(arg.id, ()))
    return roots


def _is_traced(fn, roots, parent) -> bool:
    while fn is not None:
        if fn in roots:
            return True
        fn = parent[fn]
    return False


def _refs_traced(node: ast.AST, traced: set) -> bool:
    """Does ``node`` read a traced name as a VALUE (not just static
    metadata like ``x.shape`` / ``len(x)``)?"""
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return False
    if isinstance(node, ast.Call) and \
            last_component(node.func) in _STATIC_CALLS:
        return any(_refs_traced(kw.value, traced) for kw in node.keywords)
    if isinstance(node, ast.Name):
        return node.id in traced
    return any(_refs_traced(c, traced) for c in ast.iter_child_nodes(node))


def _static_test(test: ast.AST, traced: set) -> bool:
    """A branch condition that is legal under trace: no traced-value
    reads, or pure ``is (not) None`` structure checks."""
    if not _refs_traced(test, traced):
        return True
    if isinstance(test, ast.Compare) and \
            all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return True
    if isinstance(test, ast.BoolOp):
        return all(_static_test(v, traced) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _static_test(test.operand, traced)
    return False


def _walk_pruned(nodes) -> Iterable[ast.AST]:
    """Walk a function body WITHOUT descending into nested function
    definitions (which get their own scan with inherited names)."""
    stack = list(nodes)
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, _FunctionNode):
            stack.extend(ast.iter_child_nodes(node))


def _traced_names(fn, inherited: set, statics: set) -> set:
    """Params + names assigned from traced-name expressions (two passes
    cover use-before-def between sibling statements)."""
    names = (set(_params(fn)) - statics) | inherited
    if isinstance(fn, ast.Lambda):
        return names

    def targets(t) -> Iterable[str]:
        if isinstance(t, ast.Name):
            yield t.id
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                yield from targets(e)
        elif isinstance(t, ast.Starred):
            yield from targets(t.value)

    for _ in range(2):
        for node in _walk_pruned(fn.body):
            value, tgts = None, []
            if isinstance(node, ast.Assign):
                value, tgts = node.value, node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                value, tgts = node.value, [node.target]
            elif isinstance(node, ast.AugAssign):
                value, tgts = node.value, [node.target]
            if value is not None and _refs_traced(value, names):
                for t in tgts:
                    names.update(targets(t))
    return names


def check_tracer_leaks(ctx: FileContext) -> Iterable[Finding]:
    """QL002: python control flow / concretization on traced arrays.

    Inside a jit-decorated function or a function passed to
    lax.while_loop/scan/cond/fori_loop/shard_map/vmap, an ``if``/
    ``while`` on a traced value, or ``bool()/float()/int()/.item()`` of
    one, raises ``TracerBoolConversionError`` at trace time — or worse,
    silently bakes in the first trace's value via weak typing. PR 4's
    review fixed exactly this class in the scheduler loop."""
    scopes = _Scopes()
    scopes.visit(ctx.tree)
    roots = _traced_roots(ctx.tree, scopes)
    findings: list = []

    def scan_fn(fn, inherited: set):
        statics = _static_names(scopes.jit_call.get(fn))
        traced = _traced_names(fn, inherited, statics)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for node in _walk_pruned(body):
            if isinstance(node, _FunctionNode):
                # nested defs get their own scan, inheriting the
                # enclosing traced names through the closure
                scan_fn(node, traced)
                continue
            if isinstance(node, (ast.If, ast.While)) and \
                    not _static_test(node.test, traced):
                findings.append(Finding(
                    ctx.rel, node.lineno, "QL002",
                    f"python `{type(node).__name__.lower()}` on a "
                    f"traced value inside a traced scope (use lax.cond"
                    f"/jnp.where/while_loop)"))
            if isinstance(node, ast.Call):
                callee = dotted(node.func)
                if callee in ("bool", "float", "int") and node.args \
                        and _refs_traced(node.args[0], traced):
                    findings.append(Finding(
                        ctx.rel, node.lineno, "QL002",
                        f"`{callee}()` concretizes a traced value "
                        f"inside a traced scope"))
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" \
                        and _refs_traced(node.func.value, traced):
                    findings.append(Finding(
                        ctx.rel, node.lineno, "QL002",
                        "`.item()` concretizes a traced value inside "
                        "a traced scope"))

    for fn in scopes.parent:
        if fn in roots and not _is_traced(scopes.parent[fn], roots,
                                          scopes.parent):
            # only scan outermost traced functions; nested defs are
            # visited recursively with inherited traced names
            scan_fn(fn, set())
    return findings


# ---------------------------------------------------------------------------
# QL003: jit discipline


def _has_trace_counter(fn) -> bool:
    """A trace counter anywhere in the function body: either the
    central-registry idiom ``<...>registry.count("name")`` (obs.registry,
    the serve/engine.py convention since the obs migration) or the
    legacy ``_*_TRACES[0] += 1`` bump."""
    for node in ast.walk(fn):
        if isinstance(node, ast.AugAssign) and \
                isinstance(node.op, ast.Add) and \
                isinstance(node.target, ast.Subscript) and \
                isinstance(node.target.value, ast.Name) and \
                node.target.value.id.endswith("TRACES"):
            return True
        if isinstance(node, ast.Call):
            parts = (dotted(node.func) or "").split(".")
            if len(parts) >= 2 and parts[-1] == "count" \
                    and parts[-2].endswith("registry"):
                return True
    return False


def check_jit_discipline(ctx: FileContext) -> Iterable[Finding]:
    """QL003 (library code only).

    (a) Module-level jits in serve/ need a paired trace counter: the
    engine's shared drivers are cache-keyed on (config, treedef,
    shapes), and the ONLY way tests pin "this path reuses a compile" is
    the flush_trace_count convention. A counter-less jit silently loses
    that contract (the PR 4 kv_select padding-bucket regression).

    (b) ``jax.jit`` constructed inside a function body builds a fresh
    cache per call — the per-call retrace trap serve/kv_select.py
    documents. Hoist to module level, or suppress with a reason for
    genuine one-shot factories (launch/dryrun.py)."""
    if not ctx.in_src:
        return []
    findings: list = []

    if ctx.in_serve:
        for node in ctx.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                jitted = any(jit_expr_info(d) is not None
                             for d in node.decorator_list)
                if jitted and not _has_trace_counter(node):
                    findings.append(Finding(
                        ctx.rel, node.lineno, "QL003",
                        f"module-level jit `{node.name}` has no paired "
                        f"trace counter (call `obs.registry.count(name)` "
                        f"in the body, or bump a legacy "
                        f"`*_TRACES[0] += 1`)"))

    stack: list = []

    def visit(node):
        if isinstance(node, _FunctionNode):
            if not isinstance(node, ast.Lambda):
                for dec in node.decorator_list:
                    visit(dec)  # decorators evaluate in the OUTER scope
            stack.append(node)
            children = node.body if isinstance(node.body, list) \
                else [node.body]
            for child in children:
                visit(child)
            if not isinstance(node, ast.Lambda):
                for default in node.args.defaults + \
                        [d for d in node.args.kw_defaults if d]:
                    visit(default)
            stack.pop()
            return
        if isinstance(node, ast.Call) and stack \
                and dotted(node.func) in _JIT_DOTTED:
            findings.append(Finding(
                ctx.rel, node.lineno, "QL003",
                "jax.jit constructed inside a function body (fresh "
                "compile cache per call); hoist to module level"))
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(ctx.tree)
    return findings


# ---------------------------------------------------------------------------
# QL005: the PR-2 shim names, removed in PR 6, stay removed

_BANNED_FUNCTIONS = {"bif_bounds", "bif_refine_until", "judge_threshold",
                     "judge_kdpp_swap", "judge_double_greedy",
                     "preconditioned_bif_bounds"}
_BANNED_MODULES = {"deprecation", "judge", "precond"}


def check_shim_imports(ctx: FileContext) -> Iterable[Finding]:
    """QL005 (library code only): no imports of the deleted PR-2
    deprecation shims (DESIGN.md Sec. 5 removal schedule) — callers use
    ``BIFSolver.create(...)`` methods."""
    if not ctx.in_src:
        return []
    findings: list = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            from_repro = node.level > 0 or mod.startswith("repro")
            if from_repro and mod.rsplit(".", 1)[-1] in _BANNED_MODULES:
                findings.append(Finding(
                    ctx.rel, node.lineno, "QL005",
                    f"import from removed shim module '{mod}' (deleted "
                    f"per DESIGN.md Sec. 5; use BIFSolver)"))
                continue
            for alias in node.names:
                if from_repro and alias.name in _BANNED_FUNCTIONS:
                    findings.append(Finding(
                        ctx.rel, node.lineno, "QL005",
                        f"import of removed shim `{alias.name}` (use the "
                        f"BIFSolver.create(...) equivalent)"))
                elif from_repro and alias.name in _BANNED_MODULES:
                    findings.append(Finding(
                        ctx.rel, node.lineno, "QL005",
                        f"import of removed shim module "
                        f"`{alias.name}` (deleted per DESIGN.md Sec. 5)"))
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith("repro") and \
                        alias.name.rsplit(".", 1)[-1] in _BANNED_MODULES:
                    findings.append(Finding(
                        ctx.rel, node.lineno, "QL005",
                        f"import of removed shim module '{alias.name}'"))
    return findings


# ---------------------------------------------------------------------------
# QL006: unkeyed randomness

_ALLOWED_NP_RANDOM = {"default_rng", "Generator", "SeedSequence", "PCG64",
                      "Philox", "SFC64", "BitGenerator"}


def check_randomness(ctx: FileContext) -> Iterable[Finding]:
    """QL006 (library + benchmark code; tests may do as they like):
    randomness must flow from an explicit seed — legacy global-state
    ``np.random.*``, argless ``default_rng()``, and the stdlib ``random``
    module all draw OS entropy, which breaks the repo's reproducibility
    contract (every stream/benchmark is seed-addressable)."""
    if ctx.in_tests:
        return []
    findings: list = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if d is None:
                continue
            parts = d.split(".")
            if len(parts) >= 3 and parts[-2] == "random" \
                    and parts[-3] in ("np", "numpy") \
                    and parts[-1] not in _ALLOWED_NP_RANDOM:
                findings.append(Finding(
                    ctx.rel, node.lineno, "QL006",
                    f"legacy global-state `{d}(...)` (use a seeded "
                    f"np.random.default_rng)"))
            elif parts[-1] == "default_rng" and not node.args \
                    and not node.keywords:
                findings.append(Finding(
                    ctx.rel, node.lineno, "QL006",
                    "argless default_rng() draws an OS seed; pass an "
                    "explicit seed"))
        elif isinstance(node, ast.Import):
            if any(alias.name == "random" for alias in node.names):
                findings.append(Finding(
                    ctx.rel, node.lineno, "QL006",
                    "stdlib `random` is process-global and unseeded here; "
                    "use np.random.default_rng(seed) or jax.random"))
        elif isinstance(node, ast.ImportFrom) and node.module == "random" \
                and node.level == 0:
            findings.append(Finding(
                ctx.rel, node.lineno, "QL006",
                "stdlib `random` is process-global and unseeded here; "
                "use np.random.default_rng(seed) or jax.random"))
    return findings


# ---------------------------------------------------------------------------
# QL008: host-side-only telemetry (obs.metrics / obs.spans / print)


def _obs_banned_refs(tree: ast.Module) -> tuple:
    """Resolve this file's import aliases for the BANNED obs modules.

    Returns (prefixes, names): ``prefixes`` are dotted call prefixes that
    denote obs.metrics / obs.spans modules (calls on their attributes are
    banned in traced scopes), ``names`` are directly-imported callables
    from them. ``obs.registry`` is deliberately absent — its trace-time
    ``count()`` is the sanctioned compile probe QL003 requires.
    """
    prefixes: set = set()
    names: set = set()

    def classify(full_parts: list, bound: str, is_from: bool) -> None:
        if "obs" not in full_parts:
            return
        tail = full_parts[full_parts.index("obs") + 1:]
        if not tail:
            # the obs package itself: `from repro import obs [as o]` /
            # `import repro.obs` — ban the metric/span submodule paths
            prefixes.add(f"{bound}.metrics")
            prefixes.add(f"{bound}.spans")
        elif tail[0] in ("metrics", "spans"):
            if len(tail) == 1:
                prefixes.add(bound)     # module alias (obs_metrics.foo())
            elif is_from:
                names.add(bound)        # from ..obs.spans import span

    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = (node.module or "").split(".") if node.module else []
            for alias in node.names:
                bound = alias.asname or alias.name
                classify(mod + alias.name.split("."), bound, True)
        elif isinstance(node, ast.Import):
            for alias in node.names:
                parts = alias.name.split(".")
                if alias.asname is not None:
                    classify(parts, alias.asname, False)
                else:
                    # `import repro.obs.metrics` binds the full path
                    classify(parts, alias.name, False)
                    if parts == ["repro", "obs"]:
                        classify(parts, alias.name, False)
    return prefixes, names


def check_host_telemetry(ctx: FileContext) -> Iterable[Finding]:
    """QL008 (library code only): obs.metrics / obs.spans calls and
    ``print()`` must not be reachable inside a traced scope (jit /
    while_loop / scan / cond / fori_loop / shard_map / vmap bodies, or
    helpers they call).

    Python side effects under a trace run at TRACE time, once per
    compile: a counter there counts compiles, a span times tracing, a
    print shows abstract tracers — all three silently lie. Telemetry is
    host-side by contract (DESIGN.md Sec. 14); the one sanctioned
    trace-time probe is ``obs.registry.count`` (that lying-per-compile
    behavior is exactly what a retrace counter wants)."""
    if not ctx.in_src:
        return []
    prefixes, names = _obs_banned_refs(ctx.tree)
    scopes = _Scopes()
    scopes.visit(ctx.tree)
    roots = _traced_roots(ctx.tree, scopes)
    if not roots:
        return []

    # transitive closure over same-module helpers: a call from a traced
    # scope to a module function runs under the same trace (the QL007
    # reachability argument, scoped to one file)
    traced_fns: set = set()
    queue = list(roots)
    while queue:
        fn = queue.pop()
        if fn in traced_fns:
            continue
        traced_fns.add(fn)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for node in _walk_pruned(body):
            if isinstance(node, _FunctionNode):
                queue.append(node)  # nested def: traced when invoked
                continue
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name):
                queue.extend(scopes.by_name.get(node.func.id, ()))

    findings: list = []
    for fn in traced_fns:
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for node in _walk_pruned(body):
            if isinstance(node, _FunctionNode) or \
                    not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d is None:
                continue
            if d == "print":
                findings.append(Finding(
                    ctx.rel, node.lineno, "QL008",
                    "print() inside a traced scope runs at trace time "
                    "(use jax.debug.print, or log host-side)"))
            elif d in names or any(d == p or d.startswith(p + ".")
                                   for p in prefixes):
                findings.append(Finding(
                    ctx.rel, node.lineno, "QL008",
                    f"`{d}(...)` inside a traced scope: obs.metrics/"
                    f"obs.spans are host-side-only (they would record "
                    f"trace-time, once per compile; only "
                    f"obs.registry.count is trace-sanctioned)"))
    return findings
