"""CLI entry: ``python -m repro.analysis <paths...>``."""
import sys

from .engine import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
