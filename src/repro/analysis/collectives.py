"""QL004 + QL007: collective discipline under ``lax.while_loop``.

QL004 — the PR 3 lockstep invariant (DESIGN.md Sec. 7): when a
while_loop body issues collectives (``all_gather``/``psum``/...) inside
a shard_map scope, every device must take exactly the same number of
trips, or the body's collectives stop pairing and the program deadlocks
/ corrupts. The historical guard pattern is a globally-reduced continue
flag carried through the loop::

    def cont_of(nm):
        return jax.lax.psum(jnp.any(nm).astype(jnp.int32), axis) > 0

This rule finds while_loops whose bodies reach a collective
(transitively, through calls to sibling helpers in the same shard_map
scope) and flags them unless the scope contains a psum-of-reduction
continue flag.

QL007 — the PR 7 cadence invariant (DESIGN.md Sec. 11): ``core/`` loop
bodies may not issue raw collectives at all. Round-boundary
communication must go through the sanctioned cadence helper
(``core.sharded._round_gather``): one packed ``all_gather`` per
``decide_every`` round carrying the brackets AND the folded continue
flag, so the hot loop never pays a per-iteration collective pair. The
walk is transitive through *module-wide* helper defs (unlike QL004's
same-scope walk, which a module-level helper would evade) and each
finding anchors at the collective call's own line — the cadence helper
itself carries the one documented suppression.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from .engine import FileContext, Finding
from .rules_ast import last_component

_COLLECTIVES = {"all_gather", "psum", "psum_scatter", "all_to_all",
                "ppermute", "pmax", "pmin", "pmean", "pshuffle"}
_REDUCERS = {"any", "all", "max", "min", "sum", "pmax", "pmin"}


def _shard_map_scopes(tree: ast.Module) -> list:
    """Function nodes passed (as names or lambdas) to shard_map(...)."""
    by_name: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, []).append(node)
    scopes = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and last_component(node.func) == "shard_map"):
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Lambda):
                scopes.append(arg)
            elif isinstance(arg, ast.Name):
                scopes.extend(by_name.get(arg.id, ()))
    return scopes


def _local_defs(scope) -> dict:
    """name -> def for every function defined anywhere in the scope."""
    defs: dict = {}
    for node in ast.walk(scope):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, node)
    return defs


def _reachable_collectives(fn, defs: dict) -> set:
    """Collective callees reachable from ``fn`` following calls to
    same-scope helper functions (the repo's body -> needs_more ->
    gather chain)."""
    seen_fns: set = set()
    found: set = set()
    stack = [fn]
    while stack:
        cur = stack.pop()
        if id(cur) in seen_fns:
            continue
        seen_fns.add(id(cur))
        for node in ast.walk(cur):
            if not isinstance(node, ast.Call):
                continue
            name = last_component(node.func)
            if name in _COLLECTIVES:
                found.add(name)
            elif name in defs:
                stack.append(defs[name])
    return found


def _has_psum_continue_flag(scope) -> bool:
    """A ``psum(<reduction(...)>, axis)``-style globally-reduced flag
    anywhere in the shard_map scope."""
    for node in ast.walk(scope):
        if not (isinstance(node, ast.Call)
                and last_component(node.func) in ("psum", "pmax", "pmin")
                and node.args):
            continue
        for sub in ast.walk(node.args[0]):
            if isinstance(sub, ast.Call) \
                    and last_component(sub.func) in _REDUCERS:
                return True
    return False


def _resolve_fn(arg, defs: dict) -> Optional[ast.AST]:
    if isinstance(arg, ast.Lambda):
        return arg
    if isinstance(arg, ast.Name):
        return defs.get(arg.id)
    return None


def check_collective_pairing(ctx: FileContext) -> Iterable[Finding]:
    findings: list = []
    for scope in _shard_map_scopes(ctx.tree):
        if isinstance(scope, ast.Lambda):
            continue  # a lambda cannot hold a while_loop
        defs = _local_defs(scope)
        guarded = _has_psum_continue_flag(scope)
        for node in ast.walk(scope):
            if not (isinstance(node, ast.Call)
                    and last_component(node.func) == "while_loop"):
                continue
            args = list(node.args) + [kw.value for kw in node.keywords]
            if len(args) < 2:
                continue
            body = _resolve_fn(args[1], defs)
            if body is None:
                continue
            reached = _reachable_collectives(body, defs)
            if reached and not guarded:
                findings.append(Finding(
                    ctx.rel, node.lineno, "QL004",
                    f"while_loop body issues collectives "
                    f"({', '.join(sorted(reached))}) inside shard_map "
                    f"without a psum-carried continue flag — trip counts "
                    f"can diverge across devices (DESIGN.md Sec. 7)"))
    return findings


def _module_defs(tree: ast.Module) -> dict:
    """name -> def for every named function in the module (first def
    wins, matching ``_local_defs``); QL007 walks these so a collective
    hidden behind a module-level helper is still reached."""
    defs: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, node)
    return defs


def _reachable_collective_calls(fn, defs: dict) -> list:
    """(name, lineno) for every collective CALL SITE reachable from
    ``fn`` through calls to helpers in ``defs`` — call sites, not just
    names, so findings anchor where the collective is issued (and a
    suppression on the sanctioned helper's line covers exactly it)."""
    seen_fns: set = set()
    found: list = []
    stack = [fn]
    while stack:
        cur = stack.pop()
        if id(cur) in seen_fns:
            continue
        seen_fns.add(id(cur))
        for node in ast.walk(cur):
            if not isinstance(node, ast.Call):
                continue
            name = last_component(node.func)
            if name in _COLLECTIVES:
                found.append((name, node.lineno))
            elif name in defs:
                stack.append(defs[name])
    return found


def check_collective_cadence(ctx: FileContext) -> Iterable[Finding]:
    """QL007: no raw collectives reachable from while_loop bodies in
    ``core/`` — the hot loop's only collective is the cadence helper's
    single per-round gather."""
    if not (ctx.in_src and "core" in ctx.parts):
        return []
    defs = _module_defs(ctx.tree)
    findings: list = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and last_component(node.func) == "while_loop"):
            continue
        args = list(node.args) + [kw.value for kw in node.keywords]
        for arg in args[:2]:  # cond and body both run once per trip
            fn = _resolve_fn(arg, defs)
            if fn is None:
                continue
            for name, lineno in _reachable_collective_calls(fn, defs):
                findings.append(Finding(
                    ctx.rel, lineno, "QL007",
                    f"raw {name} reachable from a core/ while_loop "
                    f"(entered at line {node.lineno}) — route round-"
                    f"boundary communication through the cadence helper "
                    f"so each decide_every round pays one packed "
                    f"collective (DESIGN.md Sec. 11)"))
    return findings
