"""QL001: state-threading completeness for the runtime's pytrees.

The resumable runtime carries three registered state containers —
``QuadState`` (core/solver.py), ``GQLState`` (core/gql.py), and
``CoeffHistory`` (core/matfun.py) — through four independent handler
layers: the single-device freeze loops (``step_n``/``resume``), the
sharded driver (``_drive_sharded``), the serving pool
(``_pool_admit_run`` + per-lane banking), and the matfun coefficient
writer. PRs 3-5 each shipped a review fix for a field added to one of
these pytrees but not threaded through every handler; ROADMAP adds more
(block-Krylov buffers, rank-update caches). This checker makes that a
CI failure instead:

  * the LIVE field sets come from importing the modules
    (``QuadState._fields`` etc.), so a field added to the class is seen
    the moment it exists;
  * each field must be claimed by the threading-contract registries
    next to the classes (``QUADSTATE_PER_LANE`` / ``QUADSTATE_CARRIED``
    / ``QUADSTATE_PREPARED`` in solver.py), exactly once;
  * the handler sites are checked by AST against those registries:
    ``_replace``/ctor keyword coverage, ``tree_freeze`` arguments, and
    the documented per-handler exclusions (``SHARDED_STATE_EXCLUDED``,
    ``ENGINE_ADMIT_EXCLUDED``, ``COEFF_REPLACE_EXCLUDED``).

Adding a ``block_basis`` field to QuadState without freezing, sharding,
and banking it now fails ``python -m repro.analysis src`` (pinned by the
mutation tests in tests/test_analysis.py).
"""
from __future__ import annotations

import ast
import dataclasses
import importlib
import sys
from pathlib import Path
from typing import Iterable, Optional

from .engine import FileContext, Finding

RULE = "QL001"

# repo-relative suffixes of the contract's handler files
_ROLE_SUFFIX = {
    "solver": ("src", "repro", "core", "solver.py"),
    "gql": ("src", "repro", "core", "gql.py"),
    "matfun": ("src", "repro", "core", "matfun.py"),
    "sharded": ("src", "repro", "core", "sharded.py"),
    "engine": ("src", "repro", "serve", "engine.py"),
    "update": ("src", "repro", "core", "update.py"),
    "block": ("src", "repro", "core", "block.py"),
}
_ROLE_MODULE = {
    "solver": "repro.core.solver",
    "gql": "repro.core.gql",
    "matfun": "repro.core.matfun",
    "sharded": "repro.core.sharded",
    "engine": "repro.serve.engine",
    "update": "repro.core.update",
    "block": "repro.core.block",
}


def _role_paths(contexts: Iterable[FileContext]) -> Optional[dict]:
    """Locate the five handler files. Activation is keyed on solver.py
    being in the scan set; the siblings are derived from its location
    (the contract is cross-file — scanning src/ always covers all)."""
    anchor = None
    for ctx in contexts:
        if ctx.parts[-len(_ROLE_SUFFIX["solver"]):] \
                == _ROLE_SUFFIX["solver"]:
            anchor = ctx
            break
    if anchor is None:
        return None
    root = Path(*anchor.parts[:-len(_ROLE_SUFFIX["solver"])])
    by_path = {c.path: c for c in contexts}
    roles: dict = {}
    for role, suffix in _ROLE_SUFFIX.items():
        p = root.joinpath(*suffix)
        roles[role] = by_path.get(p) or p
    return roles


def _parse(roles: dict, role: str) -> tuple:
    """(rel display path, ast.Module) for a role file — from the scanned
    context when available, from disk otherwise."""
    entry = roles[role]
    if isinstance(entry, FileContext):
        return entry.rel, entry.tree
    source = entry.read_text(encoding="utf-8")
    return str(entry), ast.parse(source, filename=str(entry))


def _import_role(roles: dict, role: str):
    """Import the live module (registry + field sets). The already-
    imported module is reused, so tests can monkeypatch mutations."""
    mod_name = _ROLE_MODULE[role]
    if mod_name in sys.modules:
        return sys.modules[mod_name]
    entry = roles[role]
    path = entry.path if isinstance(entry, FileContext) else entry
    src_dir = str(Path(*path.parts[:path.parts.index("repro")]))
    if src_dir not in sys.path:
        sys.path.insert(0, src_dir)
    return importlib.import_module(mod_name)


# ---------------------------------------------------------------------------
# AST helpers


def _find_def(tree: ast.Module, name: str):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == name:
            return node
    return None


def _class_line(tree: ast.Module, name: str) -> int:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node.lineno
    return 1


def _call_name(node: ast.Call) -> Optional[str]:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _replace_kwargs(fn) -> set:
    """Keyword names across every ``<expr>._replace(...)`` call in fn."""
    out: set = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and _call_name(node) == "_replace":
            out.update(kw.arg for kw in node.keywords if kw.arg)
    return out


def _frozen_names(fn) -> set:
    """Names a ``tree_freeze(new, old, flag)`` call site threads: the
    bare names / attribute tails of its first two arguments (so both
    ``tree_freeze(st1, st, ...)`` and ``tree_freeze(state.st, st, ...)``
    claim the field ``st``; the ``X1`` convention strips a trailing 1)."""
    out: set = set()
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Call)
                and _call_name(node) == "tree_freeze"):
            continue
        for arg in node.args[:2]:
            name = None
            if isinstance(arg, ast.Name):
                name = arg.id
            elif isinstance(arg, ast.Attribute):
                name = arg.attr
            if name:
                out.add(name)
                if name.endswith("1"):
                    out.add(name[:-1])
    return out


def _round_body_frozen(fn, solver_tree) -> set:
    """PR 7 moved the per-substep freeze into the shared cadence round
    driver ``BIFSolver._round_body`` (so single-device and sharded
    drives cannot drift); a handler that delegates to it inherits its
    tree_freeze coverage. Only handlers that actually reference
    ``_round_body`` get the credit — a new handler that skips the round
    driver still has to freeze for itself."""
    uses = any(
        (isinstance(node, ast.Attribute) and node.attr == "_round_body")
        or (isinstance(node, ast.Name) and node.id == "_round_body")
        for node in ast.walk(fn))
    if not uses:
        return set()
    rb = _find_def(solver_tree, "_round_body")
    return _frozen_names(rb) if rb is not None else set()


def _ctor_calls(tree: ast.Module, class_name: str) -> list:
    return [node for node in ast.walk(tree)
            if isinstance(node, ast.Call)
            and _call_name(node) == class_name]


def _tuple_literal(mod, attr: str) -> Optional[tuple]:
    val = getattr(mod, attr, None)
    if isinstance(val, (tuple, list)) \
            and all(isinstance(x, str) for x in val):
        return tuple(val)
    return None


# ---------------------------------------------------------------------------
# the checks


def check_contracts(contexts: Iterable[FileContext]) -> list:
    contexts = list(contexts)
    roles = _role_paths(contexts)
    if roles is None:
        return []
    for role, entry in roles.items():
        if not isinstance(entry, FileContext) and not entry.exists():
            return [Finding(str(entry), 1, RULE,
                            f"contract handler file for role '{role}' "
                            f"is missing")]
    findings: list = []
    try:
        solver_mod = _import_role(roles, "solver")
        gql_mod = _import_role(roles, "gql")
        matfun_mod = _import_role(roles, "matfun")
        sharded_mod = _import_role(roles, "sharded")
        engine_mod = _import_role(roles, "engine")
    except Exception as e:  # pragma: no cover - import environment broken
        rel, _ = _parse(roles, "solver")
        return [Finding(rel, 1, RULE,
                        f"cannot import the runtime modules to read the "
                        f"live field sets: {e!r}")]

    solver_rel, solver_tree = _parse(roles, "solver")
    sharded_rel, sharded_tree = _parse(roles, "sharded")
    engine_rel, engine_tree = _parse(roles, "engine")
    gql_rel, gql_tree = _parse(roles, "gql")
    matfun_rel, matfun_tree = _parse(roles, "matfun")

    # ---- QuadState: registry partition --------------------------------
    qfields = tuple(solver_mod.QuadState._fields)
    qline = _class_line(solver_tree, "QuadState")
    buckets = {}
    for name in ("QUADSTATE_PER_LANE", "QUADSTATE_CARRIED",
                 "QUADSTATE_PREPARED"):
        bucket = _tuple_literal(solver_mod, name)
        if bucket is None:
            findings.append(Finding(
                solver_rel, qline, RULE,
                f"threading-contract registry `{name}` missing from "
                f"core/solver.py (tuple of field-name strings)"))
            bucket = ()
        buckets[name] = bucket
    claimed: list = [f for b in buckets.values() for f in b]
    for f in qfields:
        n = claimed.count(f)
        if n == 0:
            findings.append(Finding(
                solver_rel, qline, RULE,
                f"QuadState field '{f}' is not claimed by any threading-"
                f"contract registry (QUADSTATE_PER_LANE/_CARRIED/"
                f"_PREPARED) — decide how it threads before it ships"))
        elif n > 1:
            findings.append(Finding(
                solver_rel, qline, RULE,
                f"QuadState field '{f}' is claimed by {n} registries; "
                f"buckets must partition the fields"))
    for f in claimed:
        if f not in qfields:
            findings.append(Finding(
                solver_rel, qline, RULE,
                f"threading-contract registry names '{f}', which is not "
                f"a QuadState field"))

    per_lane = tuple(buckets["QUADSTATE_PER_LANE"])
    threaded = per_lane + tuple(buckets["QUADSTATE_CARRIED"])

    # ---- QuadState: ctor completeness ---------------------------------
    for rel, tree in ((solver_rel, solver_tree), (sharded_rel,
                      sharded_tree), (engine_rel, engine_tree)):
        for call in _ctor_calls(tree, "QuadState"):
            kwargs = {kw.arg for kw in call.keywords if kw.arg}
            for f in qfields:
                if f not in kwargs:
                    findings.append(Finding(
                        rel, call.lineno, RULE,
                        f"QuadState(...) omits field '{f}' — every "
                        f"construction site must thread all fields "
                        f"explicitly (keyword form)"))

    # ---- QuadState: freeze-loop handlers (step_n / resume) ------------
    for fn_name in ("step_n", "resume"):
        fn = _find_def(solver_tree, fn_name)
        if fn is None:
            findings.append(Finding(
                solver_rel, 1, RULE,
                f"BIFSolver.{fn_name} not found (the freeze-loop "
                f"handler the contract is checked against)"))
            continue
        replaced = _replace_kwargs(fn)
        frozen = _frozen_names(fn) | _round_body_frozen(fn, solver_tree)
        for f in threaded:
            if f not in replaced:
                findings.append(Finding(
                    solver_rel, fn.lineno, RULE,
                    f"BIFSolver.{fn_name} does not thread QuadState "
                    f"field '{f}' through its _replace"))
        for f in per_lane:
            if f not in frozen:
                findings.append(Finding(
                    solver_rel, fn.lineno, RULE,
                    f"BIFSolver.{fn_name} never tree_freeze-s per-lane "
                    f"QuadState field '{f}' (resolved lanes would keep "
                    f"stepping)"))

    # ---- QuadState: sharded driver ------------------------------------
    sharded_excluded = _tuple_literal(sharded_mod,
                                      "SHARDED_STATE_EXCLUDED") or ()
    if _tuple_literal(sharded_mod, "SHARDED_STATE_EXCLUDED") is None:
        findings.append(Finding(
            sharded_rel, 1, RULE,
            "`SHARDED_STATE_EXCLUDED` registry missing from "
            "core/sharded.py (fields the sharded driver rejects "
            "up front)"))
    drive = _find_def(sharded_tree, "_drive_sharded")
    if drive is None:
        findings.append(Finding(
            sharded_rel, 1, RULE,
            "_drive_sharded not found (the sharded threading handler)"))
    else:
        replaced = _replace_kwargs(drive)
        frozen = _frozen_names(drive) \
            | _round_body_frozen(drive, solver_tree)
        for f in threaded:
            if f not in replaced and f not in sharded_excluded:
                findings.append(Finding(
                    sharded_rel, drive.lineno, RULE,
                    f"_drive_sharded neither threads QuadState field "
                    f"'{f}' through _replace nor lists it in "
                    f"SHARDED_STATE_EXCLUDED"))
        for f in per_lane:
            if f not in frozen and f not in sharded_excluded:
                findings.append(Finding(
                    sharded_rel, drive.lineno, RULE,
                    f"_drive_sharded never tree_freeze-s per-lane "
                    f"field '{f}' (and it is not excluded)"))

    # ---- QuadState: serving pool admission / banking ------------------
    engine_excluded = _tuple_literal(engine_mod,
                                     "ENGINE_ADMIT_EXCLUDED") or ()
    if _tuple_literal(engine_mod, "ENGINE_ADMIT_EXCLUDED") is None:
        findings.append(Finding(
            engine_rel, 1, RULE,
            "`ENGINE_ADMIT_EXCLUDED` registry missing from "
            "serve/engine.py (per-lane fields the pool scheduler "
            "refuses via its lockstep fallback)"))
    admit = _find_def(engine_tree, "_pool_admit_run")
    if admit is None:
        findings.append(Finding(
            engine_rel, 1, RULE,
            "_pool_admit_run not found (the pool-admission handler)"))
    else:
        replaced = _replace_kwargs(admit)
        frozen = _frozen_names(admit)
        for f in per_lane:
            if f not in replaced and f not in engine_excluded:
                findings.append(Finding(
                    engine_rel, admit.lineno, RULE,
                    f"_pool_admit_run neither merges per-lane QuadState "
                    f"field '{f}' through _replace nor lists it in "
                    f"ENGINE_ADMIT_EXCLUDED"))
            if f not in frozen and f not in engine_excluded:
                findings.append(Finding(
                    engine_rel, admit.lineno, RULE,
                    f"_pool_admit_run never tree_freeze-s occupied lanes "
                    f"of per-lane field '{f}' (admission would clobber "
                    f"in-flight lanes)"))

    # ---- GQLState: ctor completeness ----------------------------------
    gfields = tuple(gql_mod.GQLState._fields)
    gql_ctors = _ctor_calls(gql_tree, "GQLState")
    if not gql_ctors:
        findings.append(Finding(
            gql_rel, 1, RULE, "no GQLState construction sites found"))
    for call in gql_ctors:
        kwargs = {kw.arg for kw in call.keywords if kw.arg}
        for f in gfields:
            if f not in kwargs:
                findings.append(Finding(
                    gql_rel, call.lineno, RULE,
                    f"GQLState(...) omits field '{f}' — the recurrence "
                    f"update must thread every field explicitly"))

    # ---- CoeffHistory: pytree registration + writer -------------------
    cfields = tuple(f.name for f in
                    dataclasses.fields(matfun_mod.CoeffHistory))
    cline = _class_line(matfun_tree, "CoeffHistory")
    reg = None
    for node in ast.walk(matfun_tree):
        if isinstance(node, ast.Call) \
                and _call_name(node) == "register_dataclass":
            reg = node
            break
    if reg is None:
        findings.append(Finding(
            matfun_rel, cline, RULE,
            "CoeffHistory is not register_dataclass-ed (it would stop "
            "being a pytree and fall out of freeze/shard/bank)"))
    else:
        declared: set = set()
        for kw in reg.keywords:
            if kw.arg in ("data_fields", "meta_fields") \
                    and isinstance(kw.value, (ast.List, ast.Tuple)):
                declared.update(e.value for e in kw.value.elts
                                if isinstance(e, ast.Constant))
        for f in cfields:
            if f not in declared:
                findings.append(Finding(
                    matfun_rel, reg.lineno, RULE,
                    f"CoeffHistory field '{f}' missing from its "
                    f"register_dataclass field lists — the pytree would "
                    f"silently drop it"))
    coeff_excluded = _tuple_literal(matfun_mod,
                                    "COEFF_REPLACE_EXCLUDED") or ()
    if _tuple_literal(matfun_mod, "COEFF_REPLACE_EXCLUDED") is None:
        findings.append(Finding(
            matfun_rel, cline, RULE,
            "`COEFF_REPLACE_EXCLUDED` registry missing from "
            "core/matfun.py (fields the per-step writer deliberately "
            "never rewrites)"))
    upd = _find_def(matfun_tree, "update_coeffs")
    if upd is None:
        findings.append(Finding(
            matfun_rel, cline, RULE,
            "update_coeffs not found (the coefficient writer)"))
    else:
        written: set = set()
        for node in ast.walk(upd):
            if isinstance(node, ast.Call) \
                    and _call_name(node) == "replace":
                written.update(kw.arg for kw in node.keywords if kw.arg)
        for f in cfields:
            if f not in written and f not in coeff_excluded:
                findings.append(Finding(
                    matfun_rel, upd.lineno, RULE,
                    f"update_coeffs neither writes CoeffHistory field "
                    f"'{f}' nor lists it in COEFF_REPLACE_EXCLUDED"))

    # ---- BlockState: pytree registration + step writer ----------------
    # The block-Krylov recurrence state (core/block.py, DESIGN.md
    # Sec. 13) rides QuadState.st through the same freeze/shard/resume
    # handlers as GQLState; its per-step writer is `block_step`'s
    # dataclasses.replace. A field added to the dataclass but not
    # registered would silently fall out of the pytree; one the writer
    # neither rewrites nor excludes would go stale across steps.
    try:
        block_mod = _import_role(roles, "block")
    except Exception as e:  # pragma: no cover - import environment broken
        rel, _ = _parse(roles, "block")
        findings.append(Finding(rel, 1, RULE,
                                f"cannot import repro.core.block to read "
                                f"the live BlockState fields: {e!r}"))
        return findings
    block_rel, block_tree = _parse(roles, "block")
    bfields = tuple(f.name for f in
                    dataclasses.fields(block_mod.BlockState))
    bline = _class_line(block_tree, "BlockState")
    reg = None
    for node in ast.walk(block_tree):
        if isinstance(node, ast.Call) \
                and _call_name(node) == "register_dataclass":
            reg = node
            break
    if reg is None:
        findings.append(Finding(
            block_rel, bline, RULE,
            "BlockState is not register_dataclass-ed (it would stop "
            "being a pytree and fall out of freeze/shard/resume)"))
    else:
        declared = set()
        for kw in reg.keywords:
            if kw.arg in ("data_fields", "meta_fields") \
                    and isinstance(kw.value, (ast.List, ast.Tuple)):
                declared.update(e.value for e in kw.value.elts
                                if isinstance(e, ast.Constant))
        for f in bfields:
            if f not in declared:
                findings.append(Finding(
                    block_rel, reg.lineno, RULE,
                    f"BlockState field '{f}' missing from its "
                    f"register_dataclass field lists — the pytree would "
                    f"silently drop it"))
    block_excluded = _tuple_literal(block_mod,
                                    "BLOCK_REPLACE_EXCLUDED") or ()
    if _tuple_literal(block_mod, "BLOCK_REPLACE_EXCLUDED") is None:
        findings.append(Finding(
            block_rel, bline, RULE,
            "`BLOCK_REPLACE_EXCLUDED` registry missing from "
            "core/block.py (fields the per-step writer deliberately "
            "never rewrites)"))
    bstep = _find_def(block_tree, "block_step")
    if bstep is None:
        findings.append(Finding(
            block_rel, bline, RULE,
            "block_step not found (the block recurrence writer)"))
    else:
        written = set()
        for node in ast.walk(bstep):
            if isinstance(node, ast.Call) \
                    and _call_name(node) in ("replace", "BlockState"):
                written.update(kw.arg for kw in node.keywords if kw.arg)
        for f in bfields:
            if f not in written and f not in block_excluded:
                findings.append(Finding(
                    block_rel, bstep.lineno, RULE,
                    f"block_step neither writes BlockState field '{f}' "
                    f"nor lists it in BLOCK_REPLACE_EXCLUDED — the "
                    f"recurrence would silently carry a stale value"))

    # ---- ChainFactor: pytree registration + carry writers -------------
    # The incremental-chain factor (core/update.py, DESIGN.md Sec. 12)
    # is carried through lax.scan rounds by its two writers; a field
    # added to the dataclass but not registered or not rewritten by a
    # writer would silently drop out of the carry.
    try:
        update_mod = _import_role(roles, "update")
    except Exception as e:  # pragma: no cover - import environment broken
        rel, _ = _parse(roles, "update")
        findings.append(Finding(rel, 1, RULE,
                                f"cannot import repro.core.update to read "
                                f"the live ChainFactor fields: {e!r}"))
        return findings
    update_rel, update_tree = _parse(roles, "update")
    ffields = tuple(f.name for f in
                    dataclasses.fields(update_mod.ChainFactor))
    fline = _class_line(update_tree, "ChainFactor")
    reg = None
    for node in ast.walk(update_tree):
        if isinstance(node, ast.Call) \
                and _call_name(node) == "register_dataclass":
            reg = node
            break
    if reg is None:
        findings.append(Finding(
            update_rel, fline, RULE,
            "ChainFactor is not register_dataclass-ed (it would stop "
            "being a pytree and fall out of the scan carry / "
            "tree_select accept-reject)"))
    else:
        declared: set = set()
        for kw in reg.keywords:
            if kw.arg in ("data_fields", "meta_fields") \
                    and isinstance(kw.value, (ast.List, ast.Tuple)):
                declared.update(e.value for e in kw.value.elts
                                if isinstance(e, ast.Constant))
        for f in ffields:
            if f not in declared:
                findings.append(Finding(
                    update_rel, reg.lineno, RULE,
                    f"ChainFactor field '{f}' missing from its "
                    f"register_dataclass field lists — the pytree would "
                    f"silently drop it"))
    factor_excluded = _tuple_literal(update_mod,
                                     "FACTOR_REPLACE_EXCLUDED") or ()
    if _tuple_literal(update_mod, "FACTOR_REPLACE_EXCLUDED") is None:
        findings.append(Finding(
            update_rel, fline, RULE,
            "`FACTOR_REPLACE_EXCLUDED` registry missing from "
            "core/update.py (fields the carry writers deliberately "
            "never rewrite)"))
    for writer in ("extend", "downdate"):
        fn = _find_def(update_tree, writer)
        if fn is None:
            findings.append(Finding(
                update_rel, fline, RULE,
                f"{writer} not found (a ChainFactor carry writer)"))
            continue
        written = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if _call_name(node) in ("replace", "ChainFactor"):
                written.update(kw.arg for kw in node.keywords if kw.arg)
        for f in ffields:
            if f not in written and f not in factor_excluded:
                findings.append(Finding(
                    update_rel, fn.lineno, RULE,
                    f"{writer} neither writes ChainFactor field '{f}' "
                    f"nor lists it in FACTOR_REPLACE_EXCLUDED — the "
                    f"carry would silently keep a stale value"))
    return findings
