"""Architecture + run configuration system.

``ArchConfig`` captures everything the model zoo needs to build any of the
ten assigned architectures (dense / MoE / SSM / hybrid / enc-dec / VLM).
Exact figures come from the assignment table; sources are cited in each
``configs/<arch>.py``.

``reduced()`` derives the family-preserving smoke configuration used by
per-arch CPU tests (small widths, few experts, tiny vocab), as required:
full configs are only ever lowered via the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str = "dense"            # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int = 12
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: Optional[int] = None   # default: d_model // n_heads
    d_ff: int = 2048
    vocab: int = 32000
    norm: str = "rmsnorm"            # rmsnorm | layernorm | layernorm_np
    act: str = "swiglu"              # swiglu | gelu
    rope: str = "rope"               # rope | mrope | none
    rope_theta: float = 1e4
    use_bias: bool = False
    tie_embeddings: bool = False
    attn_window: Optional[int] = None  # sliding-window attention (tokens)
    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 1
    moe_every: int = 1               # every k-th block is MoE (1 = all)
    moe_shared_expert: bool = False
    moe_dense_residual: bool = False  # arctic: parallel dense MLP
    moe_capacity_factor: float = 1.25
    # --- SSM ---
    ssm_state: int = 0
    ssm_variant: str = "mamba1"      # mamba1 | mamba2
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_heads: int = 0               # mamba2 value heads
    ssm_impl: str = "scan"           # scan | chunked (mamba2 SSD matmuls)
    ssm_chunk: int = 128             # SSD chunk length Q
    hybrid_attn_every: int = 0       # zamba: shared attn block every k
    # --- encoder-decoder ---
    enc_layers: int = 0              # >0 => enc-dec (whisper)
    # --- multimodal stub ---
    vision_tokens: int = 0           # qwen2-vl: patch-embedding slots
    # --- numerics / execution ---
    dtype: str = "bfloat16"
    remat: str = "block"             # none | block
    logits_chunk: int = 1024         # chunked CE to avoid (B,T,V) logits
    attn_impl: str = "auto"          # full | chunked | auto
    attn_chunk: int = 512            # query-block size for chunked attn
    scan_unroll: bool = False        # unroll all scans (dry-run analysis
    #                                  only: makes XLA cost_analysis count
    #                                  loop bodies exactly; see dryrun.py)
    decode_constrain_kv: bool = False  # pin seq-sharded KV math in decode
    #                                   (hillclimb knob; EXPERIMENTS Perf)

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid-with-window)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS = 6*N*D)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb

        def attn_params():
            return d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d

        def mlp_params(width):
            mult = 3 if self.act == "swiglu" else 2
            return mult * d * width

        if self.family in ("dense", "vlm"):
            total += self.n_layers * (attn_params() + mlp_params(ff))
        elif self.family == "moe":
            n_moe = len([i for i in range(self.n_layers)
                         if (i + 1) % self.moe_every == 0])
            n_dense = self.n_layers - n_moe
            per_moe = self.moe_experts * mlp_params(ff)
            if self.moe_shared_expert:
                per_moe += mlp_params(ff)
            if self.moe_dense_residual:
                per_moe += mlp_params(ff)
            total += self.n_layers * attn_params() \
                + n_moe * per_moe + n_dense * mlp_params(ff)
            total += n_moe * d * self.moe_experts  # router
        elif self.family in ("ssm", "hybrid"):
            di = self.ssm_expand * d
            per_ssm = 2 * d * di + di * d + di * self.ssm_conv \
                + 2 * di * self.ssm_state + di  # in/out proj, conv, B/C, dt
            if self.family == "ssm":
                total += self.n_layers * per_ssm
            else:
                total += self.n_layers * per_ssm
                # one shared attention+MLP block (parameters reused)
                total += attn_params() + mlp_params(ff)
        elif self.family == "encdec":
            # encoder: self-attn + mlp; decoder: self + cross + mlp
            total += self.enc_layers * (attn_params() + mlp_params(ff))
            total += self.n_layers * (2 * attn_params() + mlp_params(ff))
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k of experts)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff

        def attn_params():
            hd = self.head_dim_
            return d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d

        def mlp_params(width):
            mult = 3 if self.act == "swiglu" else 2
            return mult * d * width

        n_moe = len([i for i in range(self.n_layers)
                     if (i + 1) % self.moe_every == 0])
        n_dense = self.n_layers - n_moe
        per_moe_active = self.moe_top_k * mlp_params(ff)
        if self.moe_shared_expert:
            per_moe_active += mlp_params(ff)
        if self.moe_dense_residual:
            per_moe_active += mlp_params(ff)
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        total += self.n_layers * attn_params() + n_moe * per_moe_active \
            + n_dense * mlp_params(ff) + n_moe * d * self.moe_experts
        return total

    def reduced(self) -> "ArchConfig":
        """Family-preserving smoke config: runs a CPU step in <seconds."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.hybrid_attn_every else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, int(4 * self.n_kv_heads
                                         / max(self.n_heads, 1)))),
            head_dim=16,
            d_ff=128,
            vocab=256,
            moe_experts=min(self.moe_experts, 4),
            moe_capacity_factor=8.0,  # effectively dropless at smoke scale
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            enc_layers=min(self.enc_layers, 2),
            vision_tokens=min(self.vision_tokens, 8),
            logits_chunk=64,
            attn_chunk=16,
            dtype="float32",
        )


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import (arctic_480b, command_r_plus_104b, falcon_mamba_7b,  # noqa
                   llama3_405b, llama4_maverick, olmo_1b, qwen2_vl_2b,
                   stablelm_1_6b, whisper_medium, zamba2_1_2b)
