"""qwen2-vl-2b [arXiv:2409.12191; hf] — VLM backbone; M-RoPE; vision
frontend is a STUB (input_specs provides patch embeddings)."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936, norm="rmsnorm", act="swiglu", rope="mrope",
    use_bias=True, vision_tokens=256,
))
