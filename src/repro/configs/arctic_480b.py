"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf] — 128 experts
top-2 with a parallel dense residual MLP."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab=32000, norm="rmsnorm", act="swiglu", rope="rope",
    moe_experts=128, moe_top_k=2, moe_every=1, moe_dense_residual=True,
))
