"""olmo-1b [arXiv:2402.00838; hf] — dense, non-parametric LayerNorm."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50304, norm="layernorm_np", act="swiglu", rope="rope",
    tie_embeddings=True,
))
