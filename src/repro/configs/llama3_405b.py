"""llama3-405b [arXiv:2407.21783; unverified] — dense GQA, 128k vocab."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=53248, vocab=128256, norm="rmsnorm", act="swiglu", rope="rope",
    rope_theta=5e5,
))
