from .base import ArchConfig, get_arch, list_archs, register  # noqa: F401
