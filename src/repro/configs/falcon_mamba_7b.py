"""falcon-mamba-7b [arXiv:2410.05355; unverified] — pure Mamba1, no attn."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=65024, norm="rmsnorm", act="swiglu", rope="none",
    ssm_state=16, ssm_variant="mamba1", ssm_expand=2, ssm_conv=4,
))
