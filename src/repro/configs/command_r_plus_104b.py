"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-v01; unverified] —
dense GQA, no biases, 256k vocab."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab=256000, norm="layernorm", act="swiglu", rope="rope",
    use_bias=False,
))
