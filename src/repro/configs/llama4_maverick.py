"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified] — MoE 128 experts top-1, shared expert, early fusion."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048, norm="rmsnorm", act="swiglu", rope="rope",
    moe_experts=128, moe_top_k=1, moe_every=2, moe_shared_expert=True,
))
