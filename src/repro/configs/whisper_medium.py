"""whisper-medium [arXiv:2212.04356; unverified] — enc-dec; the conv/audio
frontend is a STUB per assignment (input_specs provides frame embeddings)."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab=51865, norm="layernorm", act="gelu",
    rope="none", use_bias=True,
))
