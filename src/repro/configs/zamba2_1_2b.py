"""zamba2-1.2b [arXiv:2411.15242; hf] — Mamba2 backbone with a single
parameter-shared attention block applied periodically."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000, norm="rmsnorm", act="gelu", rope="rope",
    ssm_state=64, ssm_variant="mamba2", ssm_expand=2, ssm_conv=4,
    ssm_heads=32, hybrid_attn_every=6,
    attn_window=8192,  # for long_500k: windowed shared-attention block
))
