"""HLO text analysis: collective-traffic accounting for the roofline.

``collective_bytes`` parses a compiled (SPMD-partitioned, per-device) HLO
module and sums the wire bytes of every collective op, with ring-cost
multipliers:

    all-reduce          2x buffer   (reduce-scatter + all-gather phases)
    all-gather          1x larger buffer
    reduce-scatter      1x larger buffer
    all-to-all          1x buffer
    collective-permute  1x buffer

Shapes in partitioned HLO are already per-device, so the returned number
is bytes-per-device on the wire — the collective roofline numerator.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _iter_collective_lines(hlo_text: str):
    """Yields ``(kind, stripped_line)`` per collective op instruction
    (async ``-start``/``-done`` pairs counted once)."""
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        kind = None
        for c in _COLLECTIVES:
            # match op name with optional `-start`/`-done` suffix
            if re.search(rf"\b{c}(-start)?\(", rhs):
                kind = c
                break
        if kind is None:
            continue
        if re.search(rf"\b{kind}-done\(", rhs):
            continue  # avoid double counting async pairs
        yield kind, s


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op_kind: wire_bytes} plus 'total' and 'count'."""
    out: dict = defaultdict(float)
    count = 0
    for kind, s in _iter_collective_lines(hlo_text):
        shapes = _SHAPE_RE.findall(s)
        if not shapes:
            continue
        biggest = max(_shape_bytes(d, dims) for d, dims in shapes)
        out[kind] += _MULT[kind] * biggest
        count += 1
    out["total"] = sum(v for k, v in out.items() if k in _COLLECTIVES)
    out["count"] = count
    return dict(out)


def collective_counts(hlo_text: str) -> dict:
    """{op_kind: number of collective instructions} plus 'count'.

    A ``lax.while`` body lowers to ONE computation in compiled HLO, so
    for a loop-dominated program the module-wide census reads as
    "collectives per loop trip plus loop-boundary collectives" — the
    number the round-cadence work pins (one packed all-gather per
    ``decide_every`` round, zero psum; DESIGN.md Sec. 11)."""
    out: dict = defaultdict(int)
    for kind, _ in _iter_collective_lines(hlo_text):
        out[kind] += 1
    out["count"] = sum(v for k, v in out.items() if k in _COLLECTIVES)
    return dict(out)


def op_flops_table(hlo_text: str) -> dict:
    """Rough per-op-kind dot FLOP census (fallback when cost_analysis is
    unavailable): sums 2*M*N*K over dot/convolution ops."""
    flops = 0.0
    dot_re = re.compile(
        r"= ([a-z0-9]+)\[([0-9,]*)\][^=]*\b(dot|convolution)\(")
    for line in hlo_text.splitlines():
        m = dot_re.search(line)
        if not m:
            continue
        # output shape elements * 2 * contraction size: contraction size
        # is not in the output; approximate from operand shapes
        shapes = _SHAPE_RE.findall(line)
        if len(shapes) < 3:
            continue
        out_elems = 1
        if m.group(2):
            for d in m.group(2).split(","):
                out_elems *= int(d)
        lhs_elems = 1
        if shapes[1][1]:
            for d in shapes[1][1].split(","):
                lhs_elems *= int(d)
        out_nonbatch = max(out_elems, 1)
        k = max(lhs_elems // max(out_nonbatch, 1), 1)
        flops += 2.0 * out_elems * k
    return {"dot_flops_estimate": flops}
